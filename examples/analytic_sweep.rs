//! Analytic-backend triage: sweep the full {8..128}^3 evaluation space
//! for the paper's pick (zonl48db) in well under a second, then
//! spot-check the extremes against the cycle-accurate ground truth —
//! the fast-explore / slow-confirm workflow the multi-backend service
//! enables.

use zerostall::cluster::ConfigId;
use zerostall::coordinator::experiments::{run_point_with, sweep_grid};
use zerostall::kernels::{GemmService, LayoutKind};

fn main() -> anyhow::Result<()> {
    let id = ConfigId::Zonl48Db;

    let analytic = GemmService::analytic();
    let t0 = std::time::Instant::now();
    let rows = sweep_grid(&analytic, &[id], 0)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "analytic sweep: {} points in {:.3} s ({:.0} points/s)\n",
        rows.len(),
        dt,
        rows.len() as f64 / dt.max(1e-9)
    );

    let mut sorted = rows.clone();
    sorted.sort_by(|x, y| x.utilization.total_cmp(&y.utilization));
    let worst = &sorted[0];
    let best = sorted.last().unwrap();
    println!(
        "predicted worst point: {} util {:.1}%",
        worst.problem,
        worst.utilization * 100.0
    );
    println!(
        "predicted best  point: {} util {:.1}%\n",
        best.problem,
        best.utilization * 100.0
    );

    // Confirm the triage picks cycle-accurately.
    let cycle = GemmService::cycle();
    for row in [worst, best] {
        let measured =
            run_point_with(&cycle, id, row.problem, LayoutKind::Grouped)?;
        println!(
            "{}: analytic {:.1}% vs cycle-accurate {:.1}% \
             (window {} vs {})",
            row.problem,
            row.utilization * 100.0,
            measured.utilization * 100.0,
            row.window_cycles,
            measured.window_cycles,
        );
    }
    Ok(())
}
