//! Explore TCDM bank-conflict behaviour across layouts and
//! configurations — the §III-B diagnosis tool, and the demonstration
//! that the grouped (superbank-confined) layout plus hyperbank double
//! buffering is what makes the memory subsystem conflict-free.

use zerostall::cluster::ConfigId;
use zerostall::coordinator::workload::Problem;
use zerostall::kernels::{run_matmul_layout, test_matrices, LayoutKind};

fn main() -> anyhow::Result<()> {
    let p = Problem { m: 64, n: 64, k: 64 }; // multi-pass: DMA active
    let (a, b) = test_matrices(p.m, p.n, p.k, 42);
    for (lname, layout) in [
        ("grouped (paper)", LayoutKind::Grouped),
        ("linear", LayoutKind::Linear { pad_words: 0 }),
        ("linear+pad", LayoutKind::Linear { pad_words: 1 }),
    ] {
        println!("=== layout: {lname}  ({p}) ===");
        for id in ConfigId::all() {
            let r =
                run_matmul_layout(id, p.m, p.n, p.k, &a, &b, layout)?;
            println!(
                "{:<10} util={:>5.1}%  ssr_conflicts={:<7} \
                 lost-to-DMA={:<6} ssr_empty_stalls={:<7} wfifo={:<5}",
                id.name(),
                r.utilization() * 100.0,
                r.perf.ssr_conflicts,
                r.perf.tcdm_conflicts_dma,
                r.perf.stall_ssr_empty,
                r.perf.stall_wfifo,
            );
        }
        println!();
    }
    println!(
        "note: with the grouped layout the Dobu configurations report\n\
         zero DMA-induced conflicts — the zero-conflict memory\n\
         subsystem of §III-B."
    );
    Ok(())
}
