//! Design-space exploration: sweep the banking/interconnect/layout
//! space and report the area-vs-performance Pareto frontier the paper
//! navigates when it picks Zonl48Db.

use zerostall::cluster::ConfigId;
use zerostall::coordinator::experiments::run_point;
use zerostall::coordinator::workload::Problem;
use zerostall::kernels::LayoutKind;
use zerostall::model::area;
use zerostall::util::stats::median;

fn main() -> anyhow::Result<()> {
    let sizes = [
        Problem { m: 32, n: 32, k: 32 },
        Problem { m: 64, n: 64, k: 64 },
        Problem { m: 128, n: 128, k: 128 },
        Problem { m: 16, n: 120, k: 24 },
        Problem { m: 96, n: 48, k: 112 },
    ];
    println!(
        "{:<10} {:<11} {:>9} {:>10} {:>10}",
        "config", "layout", "area MGE", "med util", "med eff"
    );
    let mut points: Vec<(String, f64, f64)> = Vec::new();
    for id in ConfigId::all() {
        for (lname, layout) in [
            ("grouped", LayoutKind::Grouped),
            ("linear", LayoutKind::Linear { pad_words: 0 }),
        ] {
            let mut utils = Vec::new();
            let mut effs = Vec::new();
            for &p in &sizes {
                let r = run_point(id, p, layout)?;
                utils.push(r.utilization);
                effs.push(r.gflops_per_w);
            }
            let a = area(id).total_mge();
            let mu = median(&utils);
            let me = median(&effs);
            points.push((format!("{}:{}", id.name(), lname), a, mu));
            println!(
                "{:<10} {:<11} {:>9.2} {:>9.1}% {:>10.2}",
                id.name(),
                lname,
                a,
                mu * 100.0,
                me,
            );
        }
    }
    // Pareto: not dominated in (smaller area, higher util).
    println!("\nPareto frontier (area vs median utilization):");
    for (name, a, u) in &points {
        let dominated = points.iter().any(|(n2, a2, u2)| {
            n2 != name && a2 <= a && u2 >= u && (a2 < a || u2 > u)
        });
        if !dominated {
            println!("  {name}: {a:.2} MGE, {:.1}% util", u * 100.0);
        }
    }
    Ok(())
}
