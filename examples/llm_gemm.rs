//! End-to-end driver on a realistic workload: one transformer layer's
//! projection GEMMs (the workloads the paper's introduction motivates)
//! as a `NetGraph` from the model zoo — bias adds and activations
//! fused into the kernels' writeback pass, residuals scheduled by the
//! DAG runner, batched token processing on the optimized cluster.
//!
//! For both the baseline and the paper's zonl48db configuration we run
//! the whole network through the cycle-accurate backend and report
//! per-layer latency, utilization, and energy, the end-to-end
//! tokens/s, and the TCDM round-trips the fused epilogues avoided.

use zerostall::cluster::ConfigId;
use zerostall::coordinator::net::run_net;
use zerostall::coordinator::workload::graph::TensorKind;
use zerostall::coordinator::workload::zoo;
use zerostall::kernels::{GemmService, LayoutKind};

fn main() -> anyhow::Result<()> {
    let g = zoo::build("llm")?;
    let tokens = g
        .tensors
        .iter()
        .find(|t| t.kind == TensorKind::Input)
        .map(|t| t.rows)
        .unwrap_or(0);
    println!(
        "transformer-layer network `{}`: {} ops, {} MACs (batch = \
         {tokens} tokens)\n",
        g.name,
        g.ops.len(),
        g.macs(),
    );
    for id in [ConfigId::Base32Fc, ConfigId::Zonl48Db] {
        println!("=== {} ===", id.name());
        let svc = GemmService::cycle();
        let run =
            run_net(&svc, &g, id, LayoutKind::Grouped, 4, 2026)?;
        let r = &run.report;
        for l in &r.layers {
            let shape = l
                .problem
                .map(|p| p.to_string())
                .unwrap_or_else(|| "elementwise".into());
            println!(
                "  {:<13} {:>12}  epi={:<9} {:>8} cyc  util {:>5.1}%  \
                 {:>7.2} uJ  trips+{}",
                l.name,
                shape,
                l.epilogue,
                l.cycles,
                l.utilization * 100.0,
                l.energy_uj,
                l.extra_roundtrips,
            );
        }
        let tokens_per_s =
            tokens as f64 / (r.total_cycles as f64 * 1e-9) / 1e3;
        println!(
            "  network: {} cycles, {:.1} uJ, util {:.1}%, {:.1} ktok/s \
             at 1 GHz",
            r.total_cycles,
            r.total_energy_uj,
            r.utilization * 100.0,
            tokens_per_s,
        );
        println!(
            "  fused epilogue elements: {} (zero extra TCDM \
             round-trips from GEMM layers; residual adds pay {})\n",
            r.fused_elems, r.extra_roundtrips,
        );
    }
    Ok(())
}
