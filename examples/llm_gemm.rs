//! End-to-end driver on a realistic workload: the GEMM trace of one
//! small transformer layer (the workloads the paper's introduction
//! motivates), batched token processing on the optimized cluster.
//!
//! For every projection of the layer we simulate the full
//! load-compute-store pipeline on both the baseline and the paper's
//! zonl48db configuration and report per-layer latency, utilization,
//! energy, and the resulting end-to-end tokens/s of the layer.

use zerostall::cluster::ConfigId;
use zerostall::coordinator::workload::llm_problems;
use zerostall::kernels::{host_ref, run_matmul, test_matrices};
use zerostall::model::energy;

fn main() -> anyhow::Result<()> {
    println!("transformer-layer GEMM trace (batch = M tokens)\n");
    for id in [ConfigId::Base32Fc, ConfigId::Zonl48Db] {
        println!("=== {} ===", id.name());
        let mut total_cycles = 0u64;
        let mut total_uj = 0.0f64;
        let mut batch_tokens = 0usize;
        for (name, p) in llm_problems() {
            let (a, b) = test_matrices(p.m, p.n, p.k, 2026);
            let r = run_matmul(id, p.m, p.n, p.k, &a, &b)?;
            // verify numerics on every layer
            let want = host_ref(p.m, p.n, p.k, &a, &b);
            let ok = r
                .c
                .iter()
                .zip(&want)
                .all(|(g, w)| (g - w).abs() <= 1e-9 * w.abs().max(1.0));
            anyhow::ensure!(ok, "numerics mismatch on {name}");
            let e = energy(id, &r.perf);
            println!(
                "  {:<9} {:>12}  {:>8} cyc  util {:>5.1}%  {:>6.2} \
                 DPGflop/s  {:>7.2} uJ",
                name,
                p.to_string(),
                r.cycles,
                r.utilization() * 100.0,
                e.gflops,
                e.energy_uj,
            );
            total_cycles += r.cycles;
            total_uj += e.energy_uj;
            batch_tokens = p.m;
        }
        let tokens_per_s =
            batch_tokens as f64 / (total_cycles as f64 * 1e-9);
        println!(
            "  layer total: {total_cycles} cycles, {total_uj:.1} uJ, \
             {:.1} ktok/s at 1 GHz\n",
            tokens_per_s / 1e3,
        );
    }
    Ok(())
}
