//! Regenerate every table and figure of the paper in one run
//! (a quick-look version of the CLI's fig5/table1/table2/fig4 with a
//! reduced sample count; use `zerostall fig5 --samples 50` for the
//! full evaluation).

use zerostall::coordinator::{experiments, report};

fn main() -> anyhow::Result<()> {
    println!("{}", report::render_table1(&experiments::table1()));
    println!("{}", report::render_table2(&experiments::table2()?));
    println!("{}", report::render_fig4());

    eprintln!("running a 16-sample Fig. 5 sweep...");
    let rows = experiments::fig5(16, 42, 0)?;
    let summary = experiments::fig5_summary(&rows);
    println!("{}", report::render_fig5(&summary));
    println!(
        "{}",
        report::render_headline(&experiments::headline(&rows))
    );
    Ok(())
}
