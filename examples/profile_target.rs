//! perf-pass driver: many 64^3 sims back to back.
use zerostall::cluster::ConfigId;
use zerostall::coordinator::{experiments::run_point, workload::Problem};
use zerostall::kernels::LayoutKind;
fn main() {
    let p = Problem { m: 64, n: 64, k: 64 };
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let mut cycles = 0u64;
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        let r = run_point(ConfigId::Zonl48Db, p, LayoutKind::Grouped).unwrap();
        cycles += r.cycles;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("{} sims, {:.2} Msim-cycles/s", n, cycles as f64 / dt / 1e6);
}
