//! Quickstart: run one DP matmul on the optimized cluster and print the
//! paper's headline metrics.
use zerostall::cluster::ConfigId;
use zerostall::kernels::{host_ref, run_matmul, test_matrices};

fn main() -> anyhow::Result<()> {
    let (m, n, k) = (32, 32, 32);
    let (a, b) = test_matrices(m, n, k, 42);
    println!("simulating {m}x{n}x{k} DP GEMM on all configurations\n");
    for id in ConfigId::all() {
        let r = run_matmul(id, m, n, k, &a, &b)?;
        let want = host_ref(m, n, k, &a, &b);
        let ok = r.c.iter().zip(&want).all(|(g, w)| (g - w).abs() < 1e-9);
        println!(
            "{:<10} cycles={:<7} util={:>5.1}%  perf={:.2} DPGflop/s  \
             conflicts={:<6} numerics={}",
            id.name(),
            r.cycles,
            r.utilization() * 100.0,
            r.gflops(),
            r.perf.tcdm_conflicts,
            if ok { "OK" } else { "MISMATCH" },
        );
    }
    Ok(())
}
