"""AOT: lower the L2 model to HLO *text* artifacts for the rust runtime.

HLO text — NOT ``lowered.compile()`` / serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate links)
rejects (``proto.id() <= INT_MAX``).  The HLO text parser reassigns ids,
so text round-trips cleanly.  See /opt/xla-example/README.md.

Artifacts (all f64, the paper's DP evaluation precision):

  matmul_acc_32.hlo.txt  — (C, A, B) 32x32x32 accumulate tile; the rust
                           golden runner composes it (with zero padding)
                           for every M,N,K in the paper's {8..128} grid.
  matmul_acc_8.hlo.txt   — 8x8x8 variant for small-problem fast paths.
  matmul_32.hlo.txt      — plain 32^3 C = A @ B used by the quickstart.
  matmul_128.hlo.txt     — 128^3 full-size Pallas-tiled matmul: proves
                           the L1 kernel + L2 grid lower into one module.

Run once via ``make artifacts``; never on the request path.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

jax.config.update("jax_enable_x64", True)

F64 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float64)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_specs():
    """(name, jitted fn, example args) for every artifact."""
    return [
        (
            "matmul_acc_32",
            model.matmul_acc_step,
            (F64((32, 32)), F64((32, 32)), F64((32, 32))),
        ),
        (
            "matmul_acc_8",
            model.matmul_acc_step,
            (F64((8, 8)), F64((8, 8)), F64((8, 8))),
        ),
        (
            "matmul_32",
            jax.jit(lambda a, b: model.cluster_matmul(a, b)),
            (F64((32, 32)), F64((32, 32))),
        ),
        (
            "matmul_128",
            jax.jit(lambda a, b: model.cluster_matmul(a, b)),
            (F64((128, 128)), F64((128, 128))),
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))), "artifacts"))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn, example_args in artifact_specs():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "sha256_16": digest,
            "args": [list(a.shape) for a in example_args],
            "dtype": "f64",
        }
        print(f"wrote {path} ({len(text)} chars, sha {digest})")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
