"""L1 — Pallas matmul tile kernels (the cluster's compute hot-spot).

The paper's hot-spot is the double-buffered, SSR-fed, FREP-driven matmul
inner loop running on the 8 Snitch cores of a cluster (Fig. 1b).  On the
TPU-style Pallas abstraction this maps as follows (DESIGN.md
§Hardware-Adaptation):

  * TCDM tile residency        -> BlockSpec-sized VMEM blocks per grid step
  * DMA double buffering       -> the pipelined Pallas grid over K tiles
                                  (the index_map expresses the HBM<->VMEM
                                  schedule the DM core performs in HW)
  * FREP/SSR fmadd inner loop  -> jnp.dot on (bm, bk) x (bk, bn) tiles,
                                  accumulated in the output ref across the
                                  K grid dimension
  * bank-conflict-free layout  -> tile dims kept multiples of 8 to match
                                  the paper's {8..128} problem grid

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the correctness
path and real-TPU performance is *estimated* analytically (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper evaluates double-precision GEMM; enable x64 once at import.
jax.config.update("jax_enable_x64", True)


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """Grid-step body: accumulate one (bm, bk) x (bk, bn) product.

    Runs with grid (M/bm, N/bn, K/bk); the K axis is the innermost grid
    dimension, and the output block index_map ignores it, so ``o_ref`` is
    revisited across K steps and carries the partial sum — the software
    analog of the FREP accumulation registers c0..c7 in Fig. 1b.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 32, bn: int = 32,
           bk: int = 32) -> jax.Array:
    """Tiled Pallas matmul ``C = A @ B``.

    Shapes must be divisible by the tile sizes; the driver (model.py /
    the rust golden runner) pads to tile multiples exactly like the
    cluster's tiling pads the L1 blocks.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{n},{k}) not divisible by tile ({bm},{bn},{bk})")
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def _matmul_acc_kernel(c_ref, a_ref, b_ref, o_ref):
    """Single-tile accumulate step ``O = C + A @ B``.

    This is the unit the rust golden runner composes: it mirrors one
    cluster double-buffer iteration (compute a C tile given resident A/B
    blocks, accumulating over the K block loop in the caller).
    """
    o_ref[...] = c_ref[...] + jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)


@jax.jit
def matmul_acc_tile(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """``C + A @ B`` over a single resident tile (no grid)."""
    m, k = a.shape
    _, n = b.shape
    return pl.pallas_call(
        _matmul_acc_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(c, a, b)


def vmem_footprint_bytes(bm: int, bn: int, bk: int,
                         dtype_bytes: int = 8) -> int:
    """Analytic VMEM footprint of one grid step (double-buffered inputs).

    Pallas pipelines the next (A, B) blocks while computing the current
    one — the same double buffering the paper implements with the DMA —
    so input blocks count twice; the accumulator/output block counts once.
    """
    a_blk = bm * bk * dtype_bytes
    b_blk = bk * bn * dtype_bytes
    o_blk = bm * bn * dtype_bytes
    return 2 * (a_blk + b_blk) + o_blk


def mxu_utilization_estimate(bm: int, bn: int, bk: int,
                             mxu: int = 128) -> float:
    """Estimated MXU utilization for a (bm, bn, bk) tile on a 128x128 MXU.

    Fraction of each systolic pass doing useful work — the TPU analog of
    the paper's FPU-utilization metric.
    """
    def eff(d: int) -> float:
        full, rem = divmod(d, mxu)
        passes = full + (1 if rem else 0)
        return d / (passes * mxu)

    return eff(bm) * eff(bn) * eff(bk)
