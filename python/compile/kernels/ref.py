"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every oracle is written in the most obvious jnp form (no Pallas, no
tiling tricks) so that a disagreement always indicts the kernel, never
the reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """``C = A @ B`` — the oracle for kernels.matmul."""
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def matmul_acc_ref(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """``C + A @ B`` — the oracle for kernels.matmul_acc_tile."""
    return c + jnp.dot(a, b, preferred_element_type=c.dtype)


def blocked_matmul_ref(a: jax.Array, b: jax.Array, bm: int, bn: int,
                       bk: int) -> jax.Array:
    """Blocked matmul in plain python loops over jnp slices (unjitted).

    Mirrors the cluster's L1 tiling order (C-stationary, K innermost) so
    its FP association order matches what the simulated cluster computes;
    used by tests that require matching association.
    """
    m, k = a.shape
    _, n = b.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    c = jnp.zeros((m, n), dtype=a.dtype)
    for i in range(0, m, bm):
        for j in range(0, n, bn):
            acc = jnp.zeros((bm, bn), dtype=a.dtype)
            for kk in range(0, k, bk):
                acc = acc + a[i:i + bm, kk:kk + bk] @ b[kk:kk + bk, j:j + bn]
            c = c.at[i:i + bm, j:j + bn].set(acc)
    return c


def cluster_sharded_ref(a: jax.Array, b: jax.Array,
                        n_cores: int = 8) -> jax.Array:
    """Row-sharded matmul: core ``i`` computes rows ``i::n_cores``.

    This is the work split the cluster kernel codegen uses (each Snitch
    core takes an interleaved row slice of the C tile).
    """
    m, _ = a.shape
    c = jnp.zeros((m, b.shape[1]), dtype=a.dtype)
    for core in range(n_cores):
        rows = jnp.arange(core, m, n_cores)
        c = c.at[rows].set(a[rows] @ b)
    return c
