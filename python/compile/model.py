"""L2 — the JAX compute graph of the cluster's matmul workload.

The paper's "model" is a double-buffered, L1-tiled GEMM distributed over
8 compute cores.  This module expresses exactly that dataflow in JAX,
calling the L1 Pallas kernel for the per-tile compute, so that one
lowering captures both layers in a single HLO module:

  cluster_matmul   — full C = A @ B, L1-tiled (grid over tiles, K
                     innermost) — the end-to-end golden model.
  matmul_acc_step  — one double-buffer iteration C += A_blk @ B_blk —
                     the unit the rust runtime composes for arbitrary
                     problem sizes (padding to tile multiples).
  sharded_cluster_matmul — the 8-way row-interleaved split the kernel
                     codegen uses; numerically identical to
                     cluster_matmul, exercised by tests.

Build-time only: lowered once by aot.py, never imported at runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import matmul as kernels

jax.config.update("jax_enable_x64", True)

N_CORES = 8


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def cluster_matmul(a: jax.Array, b: jax.Array, *, bm: int = 32,
                   bn: int = 32, bk: int = 32) -> jax.Array:
    """Full L1-tiled matmul via the Pallas kernel (C-stationary)."""
    return kernels.matmul(a, b, bm=bm, bn=bn, bk=bk)


@jax.jit
def matmul_acc_step(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """One double-buffer iteration: ``C + A @ B`` on resident tiles."""
    return kernels.matmul_acc_tile(c, a, b)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def sharded_cluster_matmul(a: jax.Array, b: jax.Array, *, bm: int = 32,
                           bn: int = 32, bk: int = 32) -> jax.Array:
    """Row-interleaved 8-core split of cluster_matmul.

    Core ``i`` computes C rows ``i::8`` — the same static work split the
    rust kernel codegen assigns to the 8 Snitch cores.  Reassembled with
    a scatter; numerically equal to cluster_matmul (same K order).
    """
    m, _ = a.shape
    c = jnp.zeros((m, b.shape[1]), dtype=a.dtype)
    # vmap over the core index would force dynamic gather shapes; the
    # loop is unrolled at trace time (N_CORES is static).
    for core in range(N_CORES):
        rows = jnp.arange(core, m, N_CORES)
        part = jnp.dot(a[rows], b, preferred_element_type=a.dtype)
        c = c.at[rows].set(part)
    return c
