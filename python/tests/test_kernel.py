"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

The CORE correctness signal of the python side: hypothesis sweeps the
kernel's shape/tile/dtype space and asserts allclose against ref.py.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import matmul as kernels
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

# interpret-mode pallas is slow; keep example counts deliberate.
COMMON = dict(deadline=None, max_examples=20,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------- basic --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_matmul_32cube(dtype):
    a = _rand((32, 32), dtype, 0)
    b = _rand((32, 32), dtype, 1)
    got = kernels.matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5 if dtype == jnp.float32
                               else 1e-12)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_matmul_acc_tile(dtype):
    c = _rand((16, 24), dtype, 2)
    a = _rand((16, 8), dtype, 3)
    b = _rand((8, 24), dtype, 4)
    got = kernels.matmul_acc_tile(c, a, b)
    want = ref.matmul_acc_ref(c, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5 if dtype == jnp.float32
                               else 1e-12)


def test_matmul_rejects_untiled_shapes():
    a = jnp.zeros((30, 32))
    b = jnp.zeros((32, 32))
    with pytest.raises(AssertionError):
        kernels.matmul(a, b)


def test_matmul_rejects_mismatched_inner():
    with pytest.raises(AssertionError):
        kernels.matmul(jnp.zeros((32, 32)), jnp.zeros((64, 32)))


# ----------------------------------------------------------- hypothesis --

DIMS = st.sampled_from([8, 16, 24, 32, 48, 64])
TILE = st.sampled_from([8, 16, 32])


@hypothesis.settings(**COMMON)
@hypothesis.given(m=DIMS, n=DIMS, k=DIMS, bm=TILE, bn=TILE, bk=TILE,
                  seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_f64(m, n, k, bm, bn, bk, seed):
    hypothesis.assume(m % bm == 0 and n % bn == 0 and k % bk == 0)
    a = _rand((m, k), jnp.float64, seed)
    b = _rand((k, n), jnp.float64, seed + 1)
    got = kernels.matmul(a, b, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


@hypothesis.settings(**COMMON)
@hypothesis.given(m=DIMS, n=DIMS, k=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_f32(m, n, k, seed):
    a = _rand((m, k), jnp.float32, seed)
    b = _rand((k, n), jnp.float32, seed + 1)
    got = kernels.matmul(a, b, bm=8, bn=8, bk=8)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@hypothesis.settings(**COMMON)
@hypothesis.given(m=TILE, n=TILE, k=TILE, seed=st.integers(0, 2**31 - 1))
def test_acc_tile_matches_ref(m, n, k, seed):
    c = _rand((m, n), jnp.float64, seed)
    a = _rand((m, k), jnp.float64, seed + 1)
    b = _rand((k, n), jnp.float64, seed + 2)
    got = kernels.matmul_acc_tile(c, a, b)
    want = ref.matmul_acc_ref(c, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


# ------------------------------------------------- analytic estimators --

def test_vmem_footprint_formula():
    # 32^3 f64 tiles: 2*(8K+8K)+8K = 40 KiB
    assert kernels.vmem_footprint_bytes(32, 32, 32) == 40 * 1024
    # must fit a 16 MiB VMEM for the default tiling
    assert kernels.vmem_footprint_bytes(32, 32, 32) < 16 * 2**20


def test_mxu_utilization_estimate():
    assert kernels.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert kernels.mxu_utilization_estimate(64, 128, 128) == 0.5
    u = kernels.mxu_utilization_estimate(32, 32, 32)
    assert 0 < u < 1
