"""L2 correctness: model graph shapes, sharding equivalence, AOT lowering."""

import os
import sys

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

COMMON = dict(deadline=None, max_examples=15,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _rand(shape, seed, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def test_cluster_matmul_matches_blocked_ref():
    a = _rand((64, 96), 0)
    b = _rand((96, 32), 1)
    got = model.cluster_matmul(a, b, bm=32, bn=32, bk=32)
    want = ref.blocked_matmul_ref(a, b, 32, 32, 32)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_sharded_equals_unsharded():
    a = _rand((64, 64), 2)
    b = _rand((64, 64), 3)
    got = model.sharded_cluster_matmul(a, b)
    want = model.cluster_matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_sharded_matches_cluster_sharded_ref():
    a = _rand((32, 32), 4)
    b = _rand((32, 32), 5)
    got = model.sharded_cluster_matmul(a, b)
    want = ref.cluster_sharded_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-12)


@hypothesis.settings(**COMMON)
@hypothesis.given(mt=st.integers(1, 3), nt=st.integers(1, 3),
                  kt=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_cluster_matmul_tile_grid(mt, nt, kt, seed):
    a = _rand((32 * mt, 32 * kt), seed)
    b = _rand((32 * kt, 32 * nt), seed + 1)
    got = model.cluster_matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_acc_step_composition_matches_full():
    """Composing acc steps over K blocks == full matmul (the rust golden
    runner's composition scheme)."""
    a = _rand((32, 96), 6)
    b = _rand((96, 32), 7)
    c = jnp.zeros((32, 32), dtype=jnp.float64)
    for kk in range(0, 96, 32):
        c = model.matmul_acc_step(c, a[:, kk:kk + 32], b[kk:kk + 32, :])
    np.testing.assert_allclose(c, ref.matmul_ref(a, b), rtol=1e-12)


# ------------------------------------------------------------------ AOT --

def test_aot_lowering_produces_hlo_text():
    specs = aot.artifact_specs()
    assert {n for n, _, _ in specs} == {
        "matmul_acc_32", "matmul_acc_8", "matmul_32", "matmul_128"}
    name, fn, args = specs[0]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f64" in text


def test_aot_text_is_deterministic():
    _, fn, args = aot.artifact_specs()[0]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert t1 == t2
