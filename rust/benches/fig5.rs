//! Bench: Fig. 5 regenerator — end-to-end simulation throughput per
//! configuration on the 32^3 kernel, plus the metrics each box plot
//! reports. `cargo bench --bench fig5`.

use zerostall::cluster::ConfigId;
use zerostall::coordinator::experiments::run_point;
use zerostall::coordinator::workload::Problem;
use zerostall::kernels::LayoutKind;
use zerostall::util::bench::Bencher;

fn main() {
    println!("== fig5 bench: one 32^3 GEMM simulation per iteration ==");
    let b = Bencher::default();
    let p = Problem { m: 32, n: 32, k: 32 };
    for id in ConfigId::all() {
        let sample = b.run(&format!("fig5/sim/{}", id.name()), || {
            run_point(id, p, LayoutKind::Grouped).unwrap()
        });
        let point = run_point(id, p, LayoutKind::Grouped).unwrap();
        let cycles_per_s =
            point.cycles as f64 / sample.median.as_secs_f64();
        println!(
            "    -> util {:.1}%, {:.2} Msim-cycles/s, {:.1} mW model",
            point.utilization * 100.0,
            cycles_per_s / 1e6,
            point.power_mw
        );
    }
    // A bigger, multi-pass case (DMA overlap active).
    let p2 = Problem { m: 128, n: 128, k: 128 };
    let s = b.run("fig5/sim/zonl48db/128cube", || {
        run_point(ConfigId::Zonl48Db, p2, LayoutKind::Grouped).unwrap()
    });
    let point = run_point(ConfigId::Zonl48Db, p2, LayoutKind::Grouped)
        .unwrap();
    println!(
        "    -> util {:.1}%, {:.2} Msim-cycles/s",
        point.utilization * 100.0,
        point.cycles as f64 / s.median.as_secs_f64() / 1e6
    );
}
