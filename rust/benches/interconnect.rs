//! Bench: interconnect arbitration (E7) — per-cycle throughput of the
//! fully-connected vs Dobu arbiters under realistic and adversarial
//! request mixes; this is the simulator's hottest function.

use zerostall::mem::{
    DmaBeat, Interconnect, PortRequest, Tcdm, Topology, TCDM_BASE,
};
use zerostall::util::bench::Bencher;
use zerostall::util::rng::Rng;

fn requests(n: usize, banks: usize, seed: u64) -> Vec<PortRequest> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| PortRequest {
            port: i as u16,
            addr: TCDM_BASE + (rng.below(banks as u64 * 16) as u32) * 8,
            write: i % 4 == 3,
            data: 0x3FF0_0000_0000_0000,
        })
        .collect()
}

fn bench_topology(b: &Bencher, name: &str, topo: Topology, bytes: usize) {
    let mut tcdm = Tcdm::new(topo, bytes);
    let mut x = Interconnect::new(topo.total_banks(), 36);
    let reqs = requests(24, topo.total_banks(), 1);
    let beat = DmaBeat {
        addr: TCDM_BASE + 512,
        n_words: 8,
        write: true,
        data: [7; 8],
    };
    let mut grants = vec![false; reqs.len()];
    let mut data = vec![0u64; reqs.len()];
    let s = b.run(&format!("interconnect/{name}/24req+dma"), || {
        grants.fill(false);
        x.arbitrate(&mut tcdm, &reqs, &mut grants, &mut data, Some(&beat))
            .dma_granted
    });
    println!(
        "    -> {:.1} M arbitration-cycles/s",
        s.throughput(1.0) / 1e6
    );
}

fn main() {
    println!("== interconnect bench: arbitration cycles per second ==");
    let b = Bencher::default();
    bench_topology(&b, "fc32", Topology::Fc { banks: 32 }, 128 * 1024);
    bench_topology(&b, "fc64", Topology::Fc { banks: 64 }, 128 * 1024);
    bench_topology(
        &b,
        "dobu48",
        Topology::Dobu { banks_per_hyper: 24 },
        96 * 1024,
    );
    bench_topology(
        &b,
        "dobu64",
        Topology::Dobu { banks_per_hyper: 32 },
        128 * 1024,
    );

    // Adversarial: all requests to one bank (worst-case rr scan).
    let topo = Topology::Fc { banks: 32 };
    let mut tcdm = Tcdm::new(topo, 128 * 1024);
    let mut x = Interconnect::new(32, 36);
    let reqs: Vec<PortRequest> = (0..24)
        .map(|i| PortRequest {
            port: i as u16,
            addr: TCDM_BASE,
            write: false,
            data: 0,
        })
        .collect();
    let mut grants = vec![false; reqs.len()];
    let mut data = vec![0u64; reqs.len()];
    b.run("interconnect/fc32/adversarial_same_bank", || {
        grants.fill(false);
        x.arbitrate(&mut tcdm, &reqs, &mut grants, &mut data, None);
        grants.iter().filter(|&&g| g).count()
    });
}
