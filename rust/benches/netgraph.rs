//! Bench: NetGraph DAG execution throughput — layers/sec through the
//! DAG scheduler with a warm plan cache, on both backends.

use zerostall::cluster::ConfigId;
use zerostall::coordinator::net::run_net;
use zerostall::coordinator::workload::zoo;
use zerostall::kernels::{GemmService, LayoutKind};
use zerostall::util::bench::Bencher;

fn main() {
    println!("== netgraph bench: DAG-scheduled network execution ==");
    let b = Bencher::default();
    let g = zoo::build("ffn").unwrap();
    let layers = g.ops.len() as f64;

    // Analytic backend: pure scheduling + model evaluation rate.
    let ana = GemmService::analytic();
    // warm the plan cache outside the timed region
    run_net(&ana, &g, ConfigId::Zonl48Db, LayoutKind::Grouped, 2, 1)
        .unwrap();
    let s = b.run("net/ffn/analytic_warm", || {
        run_net(&ana, &g, ConfigId::Zonl48Db, LayoutKind::Grouped, 2, 1)
            .unwrap()
    });
    println!(
        "    -> {:.0} layers/s analytic (plan cache {:?})",
        s.throughput(layers),
        ana.stats(),
    );

    // Cycle backend: functional network execution with fused
    // epilogues, warm plan cache (programs Arc-shared across runs).
    let cyc = GemmService::cycle();
    run_net(&cyc, &g, ConfigId::Zonl48Db, LayoutKind::Grouped, 2, 1)
        .unwrap();
    let s2 = b.run("net/ffn/cycle_warm", || {
        run_net(&cyc, &g, ConfigId::Zonl48Db, LayoutKind::Grouped, 2, 1)
            .unwrap()
    });
    println!(
        "    -> {:.2} layers/s cycle-accurate (plan cache {:?})",
        s2.throughput(layers),
        cyc.stats(),
    );
}
