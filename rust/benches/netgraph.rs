//! Bench: NetGraph DAG execution throughput across the cycle-engine
//! tiers (naive / FastPath / replay) and the analytic backend.
//!
//! Emits `BENCH_netgraph.json` at the repo root (wall time, simulated
//! cycles/sec, speedup vs naive stepping, layers/sec). The file is
//! committed; CI re-runs the bench and diffs against the baseline via
//! `scripts/check_bench.py`. The cycle tiers are pinned bit-identical
//! on total cycles before timing. `BENCH_QUICK` shortens the
//! measurement budget for CI.

use zerostall::cluster::ConfigId;
use zerostall::coordinator::net::run_net;
use zerostall::coordinator::workload::zoo;
use zerostall::kernels::{GemmService, LayoutKind};
use zerostall::util::bench::{repo_root, write_json, Bencher, JsonRow};

fn main() {
    println!(
        "== netgraph bench: DAG execution (naive / fastpath / replay) =="
    );
    let b = if std::env::var("BENCH_QUICK").is_ok() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let g = zoo::build("ffn").unwrap();
    let layers = g.ops.len() as f64;
    let exec = |svc: &GemmService| {
        run_net(svc, &g, ConfigId::Zonl48Db, LayoutKind::Grouped, 2, 1)
            .unwrap()
    };

    // Equivalence pin across tiers.
    let naive = exec(&GemmService::cycle_naive());
    let fast = exec(&GemmService::cycle());
    let replay = exec(&GemmService::replay());
    assert_eq!(
        naive.report.total_cycles, fast.report.total_cycles,
        "fastpath total cycles deviate from naive stepping"
    );
    assert_eq!(
        naive.report.total_cycles, replay.report.total_cycles,
        "replay total cycles deviate from naive stepping"
    );
    let sim_cycles = naive.report.total_cycles;

    let s_naive = b.run("net/ffn/cycle_naive", || {
        exec(&GemmService::cycle_naive())
    });
    let s_fast =
        b.run("net/ffn/cycle_fastpath", || exec(&GemmService::cycle()));
    let s_replay =
        b.run("net/ffn/replay", || exec(&GemmService::replay()));
    let s_ana =
        b.run("net/ffn/analytic", || exec(&GemmService::analytic()));
    println!(
        "    -> {:.2} layers/s naive, {:.2} fastpath, {:.2} replay",
        s_naive.throughput(layers),
        s_fast.throughput(layers),
        s_replay.throughput(layers),
    );

    let rows = vec![
        JsonRow::new("net/ffn/cycle_naive", &s_naive, sim_cycles, None)
            .with_items_per_sec(s_naive.throughput(layers)),
        JsonRow::new(
            "net/ffn/cycle_fastpath",
            &s_fast,
            sim_cycles,
            Some(&s_naive),
        )
        .with_items_per_sec(s_fast.throughput(layers)),
        JsonRow::new("net/ffn/replay", &s_replay, sim_cycles, Some(&s_naive))
            .with_items_per_sec(s_replay.throughput(layers)),
        JsonRow::new("net/ffn/analytic", &s_ana, sim_cycles, Some(&s_naive))
            .with_items_per_sec(s_ana.throughput(layers)),
    ];
    for r in &rows {
        println!(
            "    -> {:<22} {:>12.0} sim cycles/s  ({:.2}x vs naive)",
            r.name, r.sim_cycles_per_sec, r.speedup_vs_naive
        );
    }
    let path = repo_root().join("BENCH_netgraph.json");
    write_json(&path, &rows).unwrap();
    println!("wrote {} ({} rows)", path.display(), rows.len());
}
