//! Bench: FREP sequencer issue throughput (E6) — the zero-overhead
//! loop-nest engine must sustain one instruction per cycle; this bench
//! measures the *simulator's* issue rate on the matmul nest shape and
//! on adversarial nests (shared start/end instructions).

use zerostall::core::sequencer::{
    run_sequencer, NestItem, SeqConfig, Sequencer,
};
use zerostall::util::bench::Bencher;

fn matmul_nest(k: u32, outer: u32) -> Vec<NestItem> {
    let mut v = vec![NestItem::Loop { n_inst: 24, n_iter: outer }];
    for i in 0..8 {
        v.push(NestItem::Op(i));
    }
    v.push(NestItem::Loop { n_inst: 8, n_iter: k - 2 });
    for i in 8..16 {
        v.push(NestItem::Op(i));
    }
    for i in 16..24 {
        v.push(NestItem::Op(i));
    }
    v
}

fn shared_edges_nest() -> Vec<NestItem> {
    // outer{ inner{ inner2{ a b } } c } — three loops sharing starts.
    vec![
        NestItem::Loop { n_inst: 3, n_iter: 8 },
        NestItem::Loop { n_inst: 2, n_iter: 8 },
        NestItem::Loop { n_inst: 2, n_iter: 8 },
        NestItem::Op(1),
        NestItem::Op(2),
        NestItem::Op(3),
    ]
}

fn main() {
    println!("== sequencer bench: issued instructions per second ==");
    let b = Bencher::default();

    let items = matmul_nest(32, 16);
    let s = b.run("sequencer/matmul_nest_32x16", || {
        let mut seq = Sequencer::new(SeqConfig::zonl());
        run_sequencer(&mut seq, &items)
    });
    let (trace, cycles) = {
        let mut seq = Sequencer::new(SeqConfig::zonl());
        run_sequencer(&mut seq, &items)
    };
    println!(
        "    -> {} instrs in {} cycles ({:.4} instr/cycle), {:.1} M \
         instr/s simulated",
        trace.len(),
        cycles,
        trace.len() as f64 / cycles as f64,
        s.throughput(trace.len() as f64) / 1e6
    );

    let adv = shared_edges_nest();
    let s2 = b.run("sequencer/shared_start_end", || {
        let mut seq = Sequencer::new(SeqConfig::zonl());
        run_sequencer(&mut seq, &adv)
    });
    let (t2, c2) = {
        let mut seq = Sequencer::new(SeqConfig::zonl());
        run_sequencer(&mut seq, &adv)
    };
    println!(
        "    -> {} instrs / {} cycles = {:.4} instr/cycle; {:.1} M/s",
        t2.len(),
        c2,
        t2.len() as f64 / c2 as f64,
        s2.throughput(t2.len() as f64) / 1e6
    );

    // Baseline comparison: blocking sequencer on sequential loops.
    let s3 = b.run("sequencer/baseline_blocking", || {
        let mut seq = Sequencer::new(SeqConfig::baseline());
        let items = vec![
            NestItem::Loop { n_inst: 8, n_iter: 30 },
            NestItem::Op(1),
            NestItem::Op(2),
            NestItem::Op(3),
            NestItem::Op(4),
            NestItem::Op(5),
            NestItem::Op(6),
            NestItem::Op(7),
            NestItem::Op(8),
        ];
        run_sequencer(&mut seq, &items)
    });
    let _ = s3;
}
