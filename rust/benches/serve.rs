//! Bench: ServeSim throughput — how fast the serving engine drains a
//! request trace through the analytic backend (the triage
//! configuration for capacity planning), FIFO vs continuous batching.

use zerostall::coordinator::serve::{serve, Policy, ServeConfig};
use zerostall::kernels::GemmService;
use zerostall::util::bench::Bencher;

fn main() {
    println!("== serve bench: request-level serving engine ==");
    let b = Bencher::default();

    let mut cfg =
        ServeConfig::new(vec!["ffn".to_string(), "qkv".to_string()]);
    cfg.clusters = 4;
    cfg.requests = 64;
    cfg.rate_per_mcycle = 50.0;
    cfg.burst = 0.2;
    cfg.slo = Some(u64::MAX);
    cfg.threads = 4;
    cfg.seed = 42;

    for policy in [Policy::Fifo, Policy::Continuous] {
        let mut c = cfg.clone();
        c.policy = policy;
        // Warm service: steady-state serving is plan-cache hits.
        let svc = GemmService::analytic();
        let s = b.run(
            &format!("serve/analytic_{}_64req_4cl", policy.name()),
            || serve(&svc, &c).unwrap(),
        );
        let run = serve(&svc, &c).unwrap();
        println!(
            "    -> {:.0} requests/s engine rate; simulated {:.3} \
             req/Mcycle sustained, p99 {} cycles, plan cache {:?}",
            s.throughput(c.requests as f64),
            run.report.throughput_per_mcycle(),
            run.report.p99(),
            run.report.plan_stats,
        );
    }

    // Cold-cache serving: every request stream against a fresh
    // service — the delta is what plan memoization buys a server.
    let mut c = cfg.clone();
    c.policy = Policy::Continuous;
    let s_cold = b.run("serve/analytic_cb_64req_cold_cache", || {
        serve(&GemmService::analytic(), &c).unwrap()
    });
    println!(
        "    -> {:.0} requests/s cold",
        s_cold.throughput(c.requests as f64)
    );
}
