//! Bench: ServeSim throughput across the cycle-engine tiers — naive
//! per-cycle stepping vs FastPath vs the replay/memo backend — plus
//! the analytic triage configuration for context, and the MegaServe
//! event core vs the wave-synchronous legacy serve loop.
//!
//! Emits `BENCH_serve.json` at the repo root (wall time, simulated
//! cycles/sec, speedup vs the relevant baseline, requests/sec). The
//! file is *committed*: CI re-runs the bench and fails on a >20%
//! throughput regression against the committed baseline
//! (`scripts/check_bench.py`). Before timing anything, the cycle
//! tiers are pinned bit-identical on the trace's observables, and the
//! two serve engines are pinned bit-identical on report + rows.
//!
//! Knobs: `BENCH_REQUESTS` scales the tier trace (default 24),
//! `BENCH_ENGINE_REQUESTS` the engine trace (default 512; the event
//! core's advantage grows with trace length), `BENCH_QUICK` shortens
//! the measurement budget for CI.

use zerostall::coordinator::node::{
    run_node, FaultPlan, NodeConfig, RouterPolicy,
};
use zerostall::coordinator::serve::{
    serve, Policy, ServeConfig, ServeEngine,
};
use zerostall::kernels::GemmService;
use zerostall::util::bench::{repo_root, write_json, Bencher, JsonRow};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    println!(
        "== serve bench: cycle tiers (naive / fastpath / replay) =="
    );
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let requests = env_usize("BENCH_REQUESTS", 24);

    let mut cfg =
        ServeConfig::new(vec!["ffn".to_string(), "qkv".to_string()]);
    cfg.clusters = 4;
    cfg.requests = requests;
    cfg.rate_per_mcycle = 50.0;
    cfg.burst = 0.2;
    cfg.policy = Policy::Continuous;
    cfg.slo = Some(u64::MAX);
    cfg.threads = 4;
    cfg.seed = 42;

    // Equivalence pin: all three tiers must report the same simulated
    // trace before their wall times mean anything.
    let naive = serve(&GemmService::cycle_naive(), &cfg).unwrap();
    let fast = serve(&GemmService::cycle(), &cfg).unwrap();
    let replay = serve(&GemmService::replay(), &cfg).unwrap();
    for (tier, run) in [("fastpath", &fast), ("replay", &replay)] {
        assert_eq!(
            naive.report.makespan_cycles, run.report.makespan_cycles,
            "{tier} makespan deviates from naive stepping"
        );
        assert_eq!(
            naive.report.completed, run.report.completed,
            "{tier} completion count deviates"
        );
        assert_eq!(
            naive.report.p99(),
            run.report.p99(),
            "{tier} p99 latency deviates"
        );
    }
    let sim_cycles = naive.report.makespan_cycles;

    // Fresh service per iteration: every tier pays planning + its own
    // stepping, so the ratio isolates the engine.
    let tag = format!("{requests}req_4cl");
    let s_naive = b.run(&format!("serve/cycle_naive_{tag}"), || {
        serve(&GemmService::cycle_naive(), &cfg).unwrap()
    });
    let s_fast = b.run(&format!("serve/cycle_fastpath_{tag}"), || {
        serve(&GemmService::cycle(), &cfg).unwrap()
    });
    let s_replay = b.run(&format!("serve/replay_{tag}"), || {
        serve(&GemmService::replay(), &cfg).unwrap()
    });
    let s_ana = b.run(&format!("serve/analytic_{tag}"), || {
        serve(&GemmService::analytic(), &cfg).unwrap()
    });

    // MegaServe vs the wave-synchronous loop, analytic backend: the
    // long-trace regime the event core exists for. Equivalence is
    // asserted on the full run before timing.
    println!("== serve bench: event core vs legacy wave loop ==");
    let engine_requests = env_usize(
        "BENCH_ENGINE_REQUESTS",
        if quick { 64 } else { 512 },
    );
    let mut ecfg = cfg.clone();
    ecfg.requests = engine_requests;
    ecfg.engine = ServeEngine::Event;
    let ev = serve(&GemmService::analytic(), &ecfg).unwrap();
    let mut lcfg = ecfg.clone();
    lcfg.engine = ServeEngine::Legacy;
    let lg = serve(&GemmService::analytic(), &lcfg).unwrap();
    assert_eq!(
        ev.report, lg.report,
        "event core report deviates from the wave-synchronous loop"
    );
    assert_eq!(ev.rows, lg.rows, "event core rows deviate");
    let engine_sim_cycles = ev.report.makespan_cycles;

    let etag = format!("{engine_requests}req_4cl");
    let s_legacy = b.run(&format!("serve/engine_legacy_{etag}"), || {
        serve(&GemmService::analytic(), &lcfg).unwrap()
    });
    let s_event = b.run(&format!("serve/engine_event_{etag}"), || {
        serve(&GemmService::analytic(), &ecfg).unwrap()
    });

    // NodeSim: 4 fabrics behind the p2c router with a mid-trace
    // fabric failure, analytic backend — the event-heap drain rate is
    // the metric. Determinism is pinned across host thread counts
    // before timing (the node tier only touches the backend via the
    // per-model cost probes).
    println!("== serve bench: node tier (4 fabrics, p2c, fault) ==");
    let node_requests = env_usize(
        "BENCH_NODE_REQUESTS",
        if quick { 2_000 } else { 20_000 },
    );
    let mut ncfg = NodeConfig::new(cfg.clone(), 4);
    ncfg.serve.requests = node_requests;
    ncfg.serve.rate_per_mcycle = 100.0;
    ncfg.router = RouterPolicy::PowerOfTwo;
    ncfg.faults =
        FaultPlan::parse("t=30000000,fabric=1,restore=60000000")
            .unwrap();
    let node_a = run_node(&GemmService::analytic(), &ncfg).unwrap();
    let mut ncfg8 = ncfg.clone();
    ncfg8.serve.threads = 8;
    let node_b = run_node(&GemmService::analytic(), &ncfg8).unwrap();
    assert_eq!(
        node_a, node_b,
        "node run deviates across host thread counts"
    );
    assert_eq!(
        node_a.report.completed + node_a.report.shed_total(),
        node_requests,
        "node run lost requests"
    );
    let node_sim_cycles = node_a.report.makespan_cycles;
    let ntag = format!("{node_requests}req_4fab");
    let s_node = b.run(&format!("serve/node_p2c_{ntag}"), || {
        run_node(&GemmService::analytic(), &ncfg).unwrap()
    });

    let reqs = engine_requests as f64;
    let rows = vec![
        JsonRow::new("serve/cycle_naive", &s_naive, sim_cycles, None),
        JsonRow::new(
            "serve/cycle_fastpath",
            &s_fast,
            sim_cycles,
            Some(&s_naive),
        ),
        JsonRow::new("serve/replay", &s_replay, sim_cycles, Some(&s_naive)),
        JsonRow::new("serve/analytic", &s_ana, sim_cycles, Some(&s_naive)),
        // Engine rows: speedup is event-vs-legacy (the acceptance
        // metric), items_per_sec is requests drained per wall second.
        JsonRow::new(
            "serve/engine_legacy",
            &s_legacy,
            engine_sim_cycles,
            None,
        )
        .with_items_per_sec(s_legacy.throughput(reqs)),
        JsonRow::new(
            "serve/engine_event",
            &s_event,
            engine_sim_cycles,
            Some(&s_legacy),
        )
        .with_items_per_sec(s_event.throughput(reqs)),
        // Node row: requests drained through the node event heap per
        // wall second (no speedup baseline — it is its own tier).
        JsonRow::new("serve/node_p2c", &s_node, node_sim_cycles, None)
            .with_items_per_sec(
                s_node.throughput(node_requests as f64),
            ),
    ];
    for r in &rows {
        println!(
            "    -> {:<22} {:>12.0} sim cycles/s  ({:.2}x vs baseline)",
            r.name, r.sim_cycles_per_sec, r.speedup_vs_naive
        );
    }
    let path = repo_root().join("BENCH_serve.json");
    write_json(&path, &rows).unwrap();
    println!(
        "wrote {} ({} rows, {} simulated cycles/run)",
        path.display(),
        rows.len(),
        sim_cycles
    );
}
