//! Bench: ServeSim throughput across the cycle-engine tiers — naive
//! per-cycle stepping vs FastPath vs the replay/memo backend — plus
//! the analytic triage configuration for context.
//!
//! Emits `BENCH_serve.json` (wall time, simulated cycles/sec, speedup
//! vs naive stepping) so the perf trajectory is tracked across PRs;
//! CI uploads it as an artifact. Before timing anything, the three
//! cycle tiers are pinned bit-identical on the trace's observables.
//!
//! Knobs: `BENCH_REQUESTS` scales the trace (default 24),
//! `BENCH_QUICK` shortens the measurement budget for CI.

use std::path::Path;

use zerostall::coordinator::serve::{serve, Policy, ServeConfig};
use zerostall::kernels::GemmService;
use zerostall::util::bench::{write_json, Bencher, JsonRow};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    println!(
        "== serve bench: cycle tiers (naive / fastpath / replay) =="
    );
    let b = if std::env::var("BENCH_QUICK").is_ok() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let requests = env_usize("BENCH_REQUESTS", 24);

    let mut cfg =
        ServeConfig::new(vec!["ffn".to_string(), "qkv".to_string()]);
    cfg.clusters = 4;
    cfg.requests = requests;
    cfg.rate_per_mcycle = 50.0;
    cfg.burst = 0.2;
    cfg.policy = Policy::Continuous;
    cfg.slo = Some(u64::MAX);
    cfg.threads = 4;
    cfg.seed = 42;

    // Equivalence pin: all three tiers must report the same simulated
    // trace before their wall times mean anything.
    let naive = serve(&GemmService::cycle_naive(), &cfg).unwrap();
    let fast = serve(&GemmService::cycle(), &cfg).unwrap();
    let replay = serve(&GemmService::replay(), &cfg).unwrap();
    for (tier, run) in [("fastpath", &fast), ("replay", &replay)] {
        assert_eq!(
            naive.report.makespan_cycles, run.report.makespan_cycles,
            "{tier} makespan deviates from naive stepping"
        );
        assert_eq!(
            naive.report.completed, run.report.completed,
            "{tier} completion count deviates"
        );
        assert_eq!(
            naive.report.p99(),
            run.report.p99(),
            "{tier} p99 latency deviates"
        );
    }
    let sim_cycles = naive.report.makespan_cycles;

    // Fresh service per iteration: every tier pays planning + its own
    // stepping, so the ratio isolates the engine.
    let tag = format!("{requests}req_4cl");
    let s_naive = b.run(&format!("serve/cycle_naive_{tag}"), || {
        serve(&GemmService::cycle_naive(), &cfg).unwrap()
    });
    let s_fast = b.run(&format!("serve/cycle_fastpath_{tag}"), || {
        serve(&GemmService::cycle(), &cfg).unwrap()
    });
    let s_replay = b.run(&format!("serve/replay_{tag}"), || {
        serve(&GemmService::replay(), &cfg).unwrap()
    });
    let s_ana = b.run(&format!("serve/analytic_{tag}"), || {
        serve(&GemmService::analytic(), &cfg).unwrap()
    });

    let rows = vec![
        JsonRow::new("serve/cycle_naive", &s_naive, sim_cycles, None),
        JsonRow::new(
            "serve/cycle_fastpath",
            &s_fast,
            sim_cycles,
            Some(&s_naive),
        ),
        JsonRow::new("serve/replay", &s_replay, sim_cycles, Some(&s_naive)),
        JsonRow::new("serve/analytic", &s_ana, sim_cycles, Some(&s_naive)),
    ];
    for r in &rows {
        println!(
            "    -> {:<22} {:>12.0} sim cycles/s  ({:.2}x vs naive)",
            r.name, r.sim_cycles_per_sec, r.speedup_vs_naive
        );
    }
    write_json(Path::new("BENCH_serve.json"), &rows).unwrap();
    println!(
        "wrote BENCH_serve.json ({} rows, {} simulated cycles/run)",
        rows.len(),
        sim_cycles
    );
}
