//! Bench: GemmService batched throughput — the plan cache + Arc'd
//! program sharing on the hot submission path, and the analytic
//! backend's triage rate over the full evaluation grid.

use zerostall::cluster::ConfigId;
use zerostall::coordinator::workload::dim_grid;
use zerostall::kernels::{GemmJob, GemmService, LayoutKind};
use zerostall::util::bench::Bencher;

fn main() {
    println!("== service bench: batched GEMM submissions ==");
    let b = Bencher::default();

    // Hot path: a 16-job cycle-accurate batch of one problem shape —
    // after the first iteration every submission is a plan-cache hit.
    let jobs: Vec<GemmJob> = (0..16)
        .map(|_| {
            GemmJob::for_problem(
                ConfigId::Zonl48Db,
                32,
                32,
                32,
                LayoutKind::Grouped,
            )
        })
        .collect();
    let svc = GemmService::cycle();
    let s = b.run("service/cycle_batch16_32cube", || {
        svc.run_batch(&jobs, 4).unwrap()
    });
    println!(
        "    -> {:.1} sims/s batched (plan cache: {:?})",
        s.throughput(jobs.len() as f64),
        svc.stats()
    );

    // Cold path: same batch against a fresh service every iteration
    // (every plan is a miss) — the delta is what memoization buys.
    let s_cold = b.run("service/cycle_batch16_cold", || {
        GemmService::cycle().run_batch(&jobs, 4).unwrap()
    });
    println!(
        "    -> {:.1} sims/s cold",
        s_cold.throughput(jobs.len() as f64)
    );

    // Analytic triage rate: one full {8..128}^3 grid per iteration.
    let dims = dim_grid();
    let mut grid_jobs = Vec::new();
    for &m in &dims {
        for &n in &dims {
            for &k in &dims {
                grid_jobs.push(GemmJob::for_problem(
                    ConfigId::Zonl48Db,
                    m,
                    n,
                    k,
                    LayoutKind::Grouped,
                ));
            }
        }
    }
    let svc2 = GemmService::analytic();
    let s2 = b.run("service/analytic_full_grid_4096", || {
        svc2.run_batch(&grid_jobs, 4).unwrap()
    });
    println!(
        "    -> {:.0} analytic points/s",
        s2.throughput(grid_jobs.len() as f64)
    );
}
