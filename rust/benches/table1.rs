//! Bench: Table I regenerator — evaluates the area model for all
//! configurations and prints the table (model evaluation is cheap;
//! the bench guards against regressions in the modeling path).

use zerostall::coordinator::{experiments, report};
use zerostall::util::bench::Bencher;

fn main() {
    println!("== table1 bench ==");
    let b = Bencher::quick();
    b.run("table1/area_model_all_configs", || {
        experiments::table1()
    });
    println!();
    println!("{}", report::render_table1(&experiments::table1()));
}
