//! Bench: Table II regenerator — the full SoA comparison (two
//! end-to-end 32^3 simulations + the OpenGeMM comparator model).

use zerostall::coordinator::{experiments, report};
use zerostall::util::bench::Bencher;

fn main() {
    println!("== table2 bench: full SoA comparison per iteration ==");
    let b = Bencher::default();
    b.run("table2/ours_vs_snitch_vs_opengemm", || {
        experiments::table2().unwrap()
    });
    println!();
    println!(
        "{}",
        report::render_table2(&experiments::table2().unwrap())
    );
}
