//! First-order analytic backend — predicts cycles, utilization, and
//! conflicts without stepping the machine.
//!
//! The model follows the paper's Section-IV overhead accounting. Per
//! double-buffer pass the compute window costs
//!
//! ```text
//! window_pass = max(comp_pass, dma_pass) + alpha
//! comp_pass   = fp_pass + beta * outer_pass + conflict_pass
//! ```
//!
//! where `fp_pass = mt*nt*k / 8` is the exact per-core FP issue count
//! of the Fig. 1b kernel (the zero-stall bound), `outer_pass` is the
//! number of (row x column-group) outer iterations, and `alpha`/`beta`
//! are per-configuration overhead constants: `beta` captures the
//! loop-management + writeback-drain cost per outer iteration (large
//! for the baseline's software loop, small for ZONL's nested FREP) and
//! `alpha` the per-pass fixed cost (SSR re-arm shadowing, CSR toggles,
//! barrier handshake, FPU drain).
//!
//! `conflict_pass` models TCDM bank contention: on configurations
//! whose grouped layout cannot give every buffer a private superbank
//! (32 banks = 4 groups), double-buffered DMA traffic lands on bank
//! groups the compute streams occupy; each overlapping DMA beat then
//! costs `gamma` core-side cycles, scaled by the routing-pressure
//! proxy from `model::congestion` (the same structural quantity that
//! makes the 64-bank fully-connected crossbar overflow in Fig. 4).
//! `dma_pass` is the DMA's own beat count for the next-tile loads and
//! previous-C store — passes become DMA-bound when it exceeds compute.
//!
//! The constants ship with hand-derived defaults and can be *fitted*
//! against the cycle-accurate backend with [`fit_calibration`] (the
//! CLI's `calibrate` subcommand), which solves the per-configuration
//! least-squares problem over measured compute windows.

use crate::cluster::{ClusterPerf, ConfigId};
use crate::kernels::codegen::{N_CORES, UNROLL};
use crate::kernels::{GemmPlan, GemmResult, LayoutKind};
use crate::mem::{Topology, BANKS_PER_SUPERBANK};
use crate::model::congestion;
use crate::profile::{
    quantize, CoreStalls, StallClass, StallProfile, N_CLASSES,
};

use super::{BackendKind, PreparedGemm, SimBackend};

/// Extra conflict fraction of compute cycles for bank-interleaved
/// (Linear) layouts, where all three streams share every bank.
const LIN_CONFLICT_FRAC_FC: f64 = 0.10;
const LIN_CONFLICT_FRAC_DOBU: f64 = 0.05;

/// Per-configuration overhead constants (cycles).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConfigCal {
    /// Fixed overhead per double-buffer pass.
    pub alpha: f64,
    /// Overhead per outer (row x column-group) kernel iteration.
    pub beta: f64,
    /// Core-side cycles lost per pressure-scaled DMA beat that
    /// overlaps compute on a shared bank group.
    pub gamma: f64,
    /// Issue cost per fused-epilogue FP op (activation writeback rows;
    /// a fused bias costs nothing — it rides the peeled first
    /// k-iteration). 1.0 = one issue slot per op, the zero-stall bound.
    pub epsilon: f64,
    /// NoC-contention coefficient for multi-cluster fabrics: the
    /// fraction of the theoretical round-robin DMA serialization
    /// (`beats x clusters / link_bandwidth`) that materializes as
    /// pass-level DMA time. 1.0 = full serialization (fair per-beat
    /// arbitration hides nothing); calibratable against cycle-fabric
    /// ground truth with [`fit_delta`].
    pub delta: f64,
}

/// The full per-configuration constant table.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    entries: [(ConfigId, ConfigCal); 5],
}

impl Calibration {
    pub fn get(&self, id: ConfigId) -> ConfigCal {
        self.entries
            .iter()
            .find(|(c, _)| *c == id)
            .map(|(_, k)| *k)
            .expect("all configs present")
    }

    pub fn set(&mut self, id: ConfigId, cal: ConfigCal) {
        for e in self.entries.iter_mut() {
            if e.0 == id {
                e.1 = cal;
            }
        }
    }

    pub fn entries(&self) -> &[(ConfigId, ConfigCal); 5] {
        &self.entries
    }
}

impl Default for Calibration {
    /// Hand-derived defaults: the baseline pays ~35 cycles of software
    /// loop management + offload blocking per 8-wide outer iteration
    /// (§III-A), ZONL ~8 (write-FIFO drain on the peeled writeback
    /// row); 32-bank configurations additionally lose ~0.6 cycles per
    /// contested DMA beat at the superbank mux.
    fn default() -> Self {
        let zonl = ConfigCal {
            alpha: 24.0,
            beta: 8.0,
            gamma: 0.6,
            epsilon: 1.0,
            delta: 1.0,
        };
        Self {
            entries: [
                (
                    ConfigId::Base32Fc,
                    ConfigCal {
                        alpha: 80.0,
                        beta: 35.0,
                        gamma: 0.6,
                        epsilon: 1.0,
                        delta: 1.0,
                    },
                ),
                (ConfigId::Zonl32Fc, zonl),
                (ConfigId::Zonl64Fc, zonl),
                (ConfigId::Zonl64Db, zonl),
                (ConfigId::Zonl48Db, zonl),
            ],
        }
    }
}

/// Does this (topology, layout) pair force DMA traffic onto bank
/// groups that compute streams occupy?
fn shares_groups(topology: Topology, layout: LayoutKind) -> bool {
    match layout {
        // Six private superbanks (2 phases x {A,B,C}) need 48+ banks.
        LayoutKind::Grouped => {
            topology.total_banks() / BANKS_PER_SUPERBANK < 6
        }
        // Interleaved rows touch every bank by construction.
        LayoutKind::Linear { .. } => true,
    }
}

/// Structural regressors of the overhead model for one planned GEMM —
/// computed once by [`features`] and consumed verbatim by both
/// [`predict_perf`] and [`fit_calibration`], so the two can never
/// disagree on a formula.
#[derive(Clone, Copy, Debug)]
pub struct Features {
    /// Per-core FP issue cycles per pass (`mt*nt*k / 8`) — exact.
    pub fp_pass: f64,
    pub passes: f64,
    /// Outer kernel iterations per pass.
    pub outer_pass: f64,
    /// Outer kernel iterations, summed over passes.
    pub outer_total: f64,
    /// Pressure-scaled DMA beats contending with compute, summed over
    /// passes (zero when every buffer owns a private superbank).
    pub overlap_total: f64,
    /// Raw DMA beats for one next-tile A+B load.
    pub load_beats: f64,
    /// Raw DMA beats for one previous-C store.
    pub store_beats: f64,
    /// Raw worst-case per-pass DMA beats (for DMA-bound detection).
    pub dma_pass: f64,
    /// DMA traffic lands on bank groups compute streams occupy.
    pub shared: bool,
    /// Clamped routing-pressure proxy (`model::congestion`).
    pub pressure: f64,
    /// Fused-epilogue FP issues per core per pass (activation rows).
    pub epi_pass: f64,
    /// Fused-epilogue FP issues per core, summed over passes.
    pub epi_total: f64,
}

pub fn features(config: ConfigId, plan: &GemmPlan) -> Features {
    let t = plan.tiling;
    let cfg = config.cluster_config();
    let passes = t.passes();
    let fp_pass = (t.mt * t.nt * t.k) as f64 / N_CORES as f64;
    let outer_pass = ((t.mt / N_CORES) * (t.nt / UNROLL)) as f64;
    let epi_pass = (t.mt * t.nt * plan.epi.ops_per_elem()) as f64
        / N_CORES as f64;
    let bias_beats = if plan.epi.bias { (t.nt / 8) as f64 } else { 0.0 };
    let load_beats =
        ((t.mt * t.k + t.k * t.nt) / 8) as f64 + bias_beats;
    let store_beats = (t.mt * t.nt / 8) as f64;
    // Loads overlap compute in passes 0..passes-1, stores in
    // 1..passes: each occurs (passes - 1) times.
    let mid = passes.saturating_sub(1) as f64;
    let raw_overlap = mid * (load_beats + store_beats);
    let pressure = congestion::congestion(config).pressure.min(1.5);
    let shared = shares_groups(cfg.topology, plan.layout);
    let overlap_total = if shared { raw_overlap * pressure } else { 0.0 };
    Features {
        fp_pass,
        passes: passes as f64,
        outer_pass,
        outer_total: passes as f64 * outer_pass,
        overlap_total,
        load_beats,
        store_beats,
        dma_pass: load_beats + store_beats,
        shared,
        pressure,
        epi_pass,
        epi_total: passes as f64 * epi_pass,
    }
}

/// Predict the full performance-counter snapshot for one planned GEMM.
pub fn predict_perf(
    cal: &Calibration,
    config: ConfigId,
    plan: &GemmPlan,
) -> ClusterPerf {
    predict_perf_noc(cal, config, plan, 1.0)
}

/// [`predict_perf`] for one shard of a multi-cluster fabric run:
/// `noc_factor = clusters / link_budget` is the theoretical DMA
/// serialization of the shared NoC (1.0 = private link, the
/// single-cluster model).
pub fn predict_perf_noc(
    cal: &Calibration,
    config: ConfigId,
    plan: &GemmPlan,
    noc_factor: f64,
) -> ClusterPerf {
    let noc_factor = noc_factor.max(1.0);
    let t = plan.tiling;
    let cfg = config.cluster_config();
    let cc = cal.get(config);
    let f = features(config, plan);
    let passes = t.passes();
    let fp_pass = f.fp_pass;
    let outer_pass = f.outer_pass;
    let (load, store) = (f.load_beats, f.store_beats);
    let shared = f.shared;
    let pressure = f.pressure;
    let lin_frac = match (plan.layout, cfg.topology) {
        (LayoutKind::Grouped, _) => 0.0,
        (LayoutKind::Linear { .. }, Topology::Fc { .. }) => {
            LIN_CONFLICT_FRAC_FC
        }
        (LayoutKind::Linear { .. }, Topology::Dobu { .. }) => {
            LIN_CONFLICT_FRAC_DOBU
        }
    };

    let mut window = 0.0f64;
    let mut conflict_cycles = 0.0f64;
    let mut dma_conflict_cycles = 0.0f64;
    let mut dma_wait = 0.0f64;
    // Per-core predicted StallScope buckets (same per-pass terms the
    // window is assembled from, so the decomposition and the window
    // can never disagree). The fixed alpha cost is split evenly
    // between Barrier and Drain — it models the pass-boundary
    // handshake (barrier + CSR/FPU drain) the cycle backend
    // attributes to those two classes.
    let mut acc = [0.0f64; N_CLASSES];
    for p in 0..passes {
        let mut overlap = 0.0;
        if p + 1 < passes {
            overlap += load;
        }
        if p >= 1 {
            overlap += store;
        }
        let shared_conf =
            if shared { cc.gamma * overlap * pressure } else { 0.0 };
        let conf = shared_conf + lin_frac * fp_pass;
        let comp = fp_pass
            + cc.epsilon * f.epi_pass
            + cc.beta * outer_pass
            + conf;
        // Contested beats are retried at the superbank mux: the engine
        // sustains roughly 2 cycles per beat while compute is active
        // on the same group. On a multi-cluster fabric the shared NoC
        // additionally serializes the branches: with C clusters behind
        // B beats/cycle of link budget the branch sustains B/C beats
        // per cycle, and `delta` calibrates how much of that
        // theoretical stretch materializes.
        let dma_raw = overlap * if shared { 2.0 } else { 1.0 };
        let dma = dma_raw * (1.0 + cc.delta * (noc_factor - 1.0));
        window += comp.max(dma) + cc.alpha;
        if dma > comp {
            dma_wait += dma - comp;
        }
        conflict_cycles += conf;
        dma_conflict_cycles += shared_conf;

        // Stall decomposition of this pass (sums to its window
        // contribution exactly: comp terms + DMA excess + alpha).
        let eps = cc.epsilon.max(0.0);
        acc[StallClass::Useful as usize] +=
            fp_pass + eps.min(1.0) * f.epi_pass;
        acc[StallClass::SsrOperandWait as usize] +=
            (eps - 1.0).max(0.0) * f.epi_pass;
        acc[StallClass::ControlOverhead as usize] +=
            cc.beta * outer_pass;
        acc[StallClass::BankConflict as usize] += conf;
        let excess_total = (dma - comp).max(0.0);
        let excess_private = (dma_raw - comp).max(0.0);
        acc[StallClass::DmaWait as usize] += excess_private;
        acc[StallClass::NocGated as usize] +=
            (excess_total - excess_private).max(0.0);
        acc[StallClass::Barrier as usize] += cc.alpha * 0.5;
        acc[StallClass::Drain as usize] += cc.alpha * 0.5;
    }

    // Epilogue FP ops count toward issue (and the FPU-op counters),
    // exactly as the cycle backend counts them.
    let epi_ops = (t.m * t.n * plan.epi.ops_per_elem()) as u64;
    let fp_total = (t.m * t.n * t.k) as u64 + epi_ops;
    let window_cycles = window.round().max(1.0) as u64;
    let utilization =
        fp_total as f64 / (window_cycles as f64 * N_CORES as f64);

    // Prologue: SSR geometry setup (~52 issue cycles) shadows the
    // first A/B load; epilogue drains the last C store.
    let prologue = (18.0 + load).max(52.0) + 2.0;
    let epilogue = store + 14.0;
    let cycles = (prologue + window + epilogue).round() as u64;

    // Event estimates for the energy model.
    let outer_total = passes as f64 * outer_pass;
    let k = t.k as f64;
    let (rb, icache, int_core) = if cfg.zonl {
        (
            fp_total as f64,
            60.0 + 14.0 * passes as f64,
            10.0 * passes as f64 + 80.0,
        )
    } else {
        (
            outer_total * 8.0 * (k - 3.0).max(0.0),
            60.0 + 28.0 * outer_total,
            4.0 * outer_total + 10.0 * passes as f64 + 80.0,
        )
    };
    let dm_int = 40.0 * passes as f64 + 30.0;
    let macs = (t.m * t.n * t.k) as u64;
    let a_reqs = macs / 8;
    let b_reqs = macs;
    let c_reqs = (t.m * t.n) as u64;
    let bias_reqs = if plan.epi.bias { (t.m * t.n) as u64 } else { 0 };
    let grants = a_reqs + b_reqs + c_reqs + bias_reqs;
    let conflicts = conflict_cycles.round() as u64;
    // Disjoint split, mirroring the cycle backend's XbarStats: the
    // DMA-mux share of the conflicts vs bank-level round-robin losses.
    let dma_conflicts =
        (dma_conflict_cycles.round() as u64).min(conflicts);
    let bias_bytes = if plan.epi.bias { t.nt * 8 } else { 0 };
    let dma_bytes = passes as u64
        * ((t.mt * t.k + t.k * t.nt + t.mt * t.nt) * 8 + bias_bytes)
            as u64;
    let dma_beats = dma_bytes / 64;
    let dma_echo = if shared { dma_beats / 4 } else { 0 };

    // Predicted StallScope profile: each compute core gets the
    // quantized per-pass decomposition (conserving `sum == window`
    // bit-exactly, like the measured profile); the DM core splits its
    // window between engine-busy waiting and control.
    let core_counts = quantize(&acc, window_cycles);
    let dm_wait = (dma_beats + dma_echo).min(window_cycles);
    let mut dm_counts = [0u64; N_CLASSES];
    dm_counts[StallClass::DmaWait as usize] = dm_wait;
    dm_counts[StallClass::ControlOverhead as usize] =
        window_cycles - dm_wait;
    let mut per_core_stalls = vec![
        CoreStalls { cycles: window_cycles, counts: core_counts };
        N_CORES
    ];
    per_core_stalls
        .push(CoreStalls { cycles: window_cycles, counts: dm_counts });
    let stalls = StallProfile {
        per_core: per_core_stalls,
        n_compute: N_CORES,
        window_cycles,
        window_core_cycles: window_cycles * N_CORES as u64,
    };

    let per_core = fp_total / N_CORES as u64;
    ClusterPerf {
        cycles,
        window_cycles,
        fpu_ops_per_core: vec![per_core; N_CORES],
        fpu_ops_total: fp_total,
        utilization,
        stall_ssr_empty: conflicts,
        fpu_idle_no_instr: dma_wait.round() as u64,
        int_instrs: (int_core * N_CORES as f64 + dm_int).round() as u64,
        icache_fetches: (icache * N_CORES as f64).round() as u64
            + (30.0 * passes as f64) as u64,
        rb_replays: (rb).round() as u64,
        csr_instrs: 2 * N_CORES as u64 * passes as u64,
        tcdm_core_accesses: grants,
        tcdm_conflicts: conflicts - dma_conflicts,
        tcdm_conflicts_dma: dma_conflicts,
        ssr_requests: grants + conflicts,
        ssr_conflicts: conflicts,
        dma_beats,
        dma_bytes,
        dma_busy_cycles: dma_beats + dma_echo,
        dma_stall_cycles: dma_echo,
        barriers_completed: passes as u64 + 1,
        stalls,
        ..ClusterPerf::default()
    }
}

/// One calibration observation: a planned GEMM plus the compute window
/// the cycle-accurate backend measured for it.
#[derive(Clone, Copy, Debug)]
pub struct CalSample {
    pub config: ConfigId,
    pub features: Features,
    pub window_measured: f64,
}

impl CalSample {
    pub fn from_result(r: &GemmResult) -> CalSample {
        CalSample {
            config: r.config,
            features: features(r.config, &r.plan),
            window_measured: r.perf.window_cycles as f64,
        }
    }
}

/// Solve the NxN linear system `m x = b` by Gaussian elimination with
/// partial pivoting; near-singular pivots zero their unknown (the
/// regressor was absent from the sample set).
fn solve<const N: usize>(mut m: [[f64; N]; N], mut b: [f64; N]) -> [f64; N] {
    let mut x = [0.0f64; N];
    let mut skip = [false; N];
    for col in 0..N {
        // pivot
        let mut piv = col;
        for r in col + 1..N {
            if m[r][col].abs() > m[piv][col].abs() {
                piv = r;
            }
        }
        if m[piv][col].abs() < 1e-9 {
            skip[col] = true;
            continue;
        }
        m.swap(col, piv);
        b.swap(col, piv);
        for r in 0..N {
            if r != col {
                let f = m[r][col] / m[col][col];
                for c in 0..N {
                    m[r][c] -= f * m[col][c];
                }
                b[r] -= f * b[col];
            }
        }
    }
    for col in 0..N {
        if !skip[col] && m[col][col].abs() > 1e-9 {
            x[col] = b[col] / m[col][col];
        }
    }
    x
}

/// Fit per-configuration `(alpha, beta, gamma, epsilon)` by least
/// squares on measured compute windows: minimize over the
/// compute-bound samples
///
/// ```text
/// window - passes*fp_pass ~= alpha*passes + beta*outer
///                          + gamma*overlap + epsilon*epi
/// ```
///
/// Configurations with fewer than 4 usable samples (one per unknown —
/// fewer would leave the normal system rank-deficient) or no variation
/// in a regressor keep the shipped defaults for the unresolved terms.
pub fn fit_calibration(samples: &[CalSample]) -> Calibration {
    let mut cal = Calibration::default();
    for id in ConfigId::all() {
        let rows: Vec<&CalSample> = samples
            .iter()
            .filter(|s| {
                s.config == id
                    // keep compute-bound points: the max() with the
                    // DMA term would otherwise poison the fit
                    && s.features.fp_pass > 1.5 * s.features.dma_pass
            })
            .collect();
        if rows.len() < 4 {
            continue;
        }
        // normal equations for
        // [passes, outer_total, overlap_total, epi_total]
        let mut ata = [[0.0f64; 4]; 4];
        let mut atb = [0.0f64; 4];
        for s in &rows {
            let f = s.features;
            let xs =
                [f.passes, f.outer_total, f.overlap_total, f.epi_total];
            let y = s.window_measured - f.passes * f.fp_pass;
            for i in 0..4 {
                for j in 0..4 {
                    ata[i][j] += xs[i] * xs[j];
                }
                atb[i] += xs[i] * y;
            }
        }
        let x = solve(ata, atb);
        let default = cal.get(id);
        let pick = |v: f64, d: f64| {
            if v.is_finite() && v >= 0.0 && v < 1e6 {
                v
            } else {
                d
            }
        };
        let fitted = ConfigCal {
            alpha: pick(x[0], default.alpha),
            beta: pick(x[1], default.beta),
            gamma: if rows.iter().any(|s| s.features.overlap_total > 0.0) {
                pick(x[2], default.gamma)
            } else {
                default.gamma
            },
            epsilon: if rows.iter().any(|s| s.features.epi_total > 0.0) {
                pick(x[3], default.epsilon)
            } else {
                default.epsilon
            },
            // Single-cluster samples carry no NoC signal; `delta` is
            // fitted separately from fabric runs via `fit_delta`.
            delta: default.delta,
        };
        cal.set(id, fitted);
    }
    cal
}

/// One NoC-calibration observation: a shard plan evaluated both on a
/// multi-cluster cycle fabric (`window_measured`) and predicted with
/// `delta = 0` (`window_free`) / `delta = 1` (`window_serialized`).
#[derive(Clone, Copy, Debug)]
pub struct NocSample {
    pub window_measured: f64,
    pub window_free: f64,
    pub window_serialized: f64,
}

/// Fit the NoC-contention coefficient `delta` from measured fabric
/// windows: each sample pins where the measurement falls between the
/// contention-free and fully-serialized predictions; the fit is the
/// clamped least-squares blend over the samples with a usable spread.
/// Returns `None` when no sample separates the two predictions (the
/// samples were all compute-bound — contention never surfaced).
pub fn fit_delta(samples: &[NocSample]) -> Option<f64> {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for s in samples {
        let spread = s.window_serialized - s.window_free;
        if spread > 1.0 {
            num += (s.window_measured - s.window_free) * spread;
            den += spread * spread;
        }
    }
    if den > 0.0 {
        Some((num / den).clamp(0.0, 2.0))
    } else {
        None
    }
}

/// The analytic backend: [`predict_perf`] behind the `SimBackend`
/// trait. Produces no functional output (`GemmResult::c` is empty).
pub struct Analytic {
    cal: Calibration,
}

impl Default for Analytic {
    fn default() -> Self {
        Self { cal: Calibration::default() }
    }
}

impl Analytic {
    pub fn with(cal: Calibration) -> Self {
        Self { cal }
    }

    pub fn calibration(&self) -> &Calibration {
        &self.cal
    }
}

impl SimBackend for Analytic {
    fn kind(&self) -> BackendKind {
        BackendKind::Analytic
    }

    fn needs_data(&self) -> bool {
        false
    }

    fn needs_programs(&self) -> bool {
        false
    }

    fn run_fused(
        &self,
        prep: &PreparedGemm,
        _a: &[f64],
        _b: &[f64],
        _bias: &[f64],
    ) -> anyhow::Result<GemmResult> {
        let perf = predict_perf(&self.cal, prep.config, &prep.plan);
        Ok(GemmResult {
            c: Vec::new(),
            cycles: perf.cycles,
            perf,
            plan: prep.plan,
            config: prep.config,
        })
    }

    /// Predict the sharded run: one per-shard prediction (shards are
    /// uniform) with the NoC-contention term
    /// `beats x clusters / link_bandwidth` applied to the DMA side of
    /// every pass, scaled by the calibrated `delta` constant.
    fn run_sharded(
        &self,
        sh: &crate::backend::ShardedGemm,
        noc: &crate::fabric::NocConfig,
        _a: &[f64],
        _b: &[f64],
        _bias: &[f64],
    ) -> anyhow::Result<crate::fabric::FabricResult> {
        use crate::fabric::{FabricResult, NocStats, ShardRun};
        let clusters = sh.shards.len().max(1);
        let factor = (clusters as f64 / noc.budget() as f64).max(1.0);
        let perf =
            predict_perf_noc(&self.cal, sh.config, &sh.prep.plan, factor);
        let beats_total = perf.dma_beats * clusters as u64;
        let shards: Vec<ShardRun> = sh
            .shards
            .iter()
            .map(|s| ShardRun {
                shard: *s,
                cycles: perf.cycles,
                perf: perf.clone(),
            })
            .collect();
        Ok(FabricResult {
            c: Vec::new(),
            cycles: perf.cycles,
            shards,
            noc: NocStats {
                grants: beats_total,
                denials: (beats_total as f64 * (factor - 1.0)
                    / factor.max(1.0))
                    .round() as u64,
                saturated_cycles: 0,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::plan_gemm;

    fn plan(id: ConfigId, m: usize, n: usize, k: usize) -> GemmPlan {
        plan_gemm(&id.cluster_config(), m, n, k, LayoutKind::Grouped)
            .unwrap()
    }

    #[test]
    fn predictions_in_range_all_configs() {
        let cal = Calibration::default();
        for id in ConfigId::all() {
            for (m, n, k) in [(8, 8, 8), (32, 32, 32), (96, 64, 80)] {
                let p = plan(id, m, n, k);
                let perf = predict_perf(&cal, id, &p);
                assert!(perf.utilization > 0.0 && perf.utilization <= 1.0);
                assert!(perf.window_cycles > 0);
                assert!(perf.cycles > perf.window_cycles);
                assert_eq!(perf.fpu_ops_total, (m * n * k) as u64);
            }
        }
    }

    #[test]
    fn zonl_predicted_faster_than_baseline() {
        let cal = Calibration::default();
        let pb = plan(ConfigId::Base32Fc, 32, 32, 32);
        let pz = plan(ConfigId::Zonl48Db, 32, 32, 32);
        let ub = predict_perf(&cal, ConfigId::Base32Fc, &pb).utilization;
        let uz = predict_perf(&cal, ConfigId::Zonl48Db, &pz).utilization;
        assert!(uz > ub, "zonl {uz:.3} <= base {ub:.3}");
        assert!(uz > 0.9, "zonl48db should predict near-peak: {uz:.3}");
    }

    #[test]
    fn dma_bytes_match_conservation_law() {
        // Same formula the cycle-accurate integration test asserts.
        let cal = Calibration::default();
        let p = plan(ConfigId::Zonl48Db, 64, 64, 64);
        let perf = predict_perf(&cal, ConfigId::Zonl48Db, &p);
        let t = p.tiling;
        let expect = t.passes() as u64
            * ((t.mt * t.k + t.k * t.nt + t.mt * t.nt) * 8) as u64;
        assert_eq!(perf.dma_bytes, expect);
    }

    #[test]
    fn larger_k_amortizes_overhead() {
        let cal = Calibration::default();
        let small = plan(ConfigId::Zonl48Db, 16, 16, 8);
        let big = plan(ConfigId::Zonl48Db, 16, 16, 128);
        let us =
            predict_perf(&cal, ConfigId::Zonl48Db, &small).utilization;
        let ub = predict_perf(&cal, ConfigId::Zonl48Db, &big).utilization;
        assert!(ub > us, "k=128 {ub:.3} <= k=8 {us:.3}");
    }

    #[test]
    fn solve_recovers_coefficients() {
        // x = (2, 3, 5) under a full-rank system.
        let m = [[4.0, 1.0, 0.0], [1.0, 3.0, 1.0], [0.0, 1.0, 5.0]];
        let want = [2.0, 3.0, 5.0];
        let b = [
            m[0][0] * want[0] + m[0][1] * want[1] + m[0][2] * want[2],
            m[1][0] * want[0] + m[1][1] * want[1] + m[1][2] * want[2],
            m[2][0] * want[0] + m[2][1] * want[1] + m[2][2] * want[2],
        ];
        let x = solve(m, b);
        for (g, w) in x.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6, "{x:?}");
        }
    }

    #[test]
    fn solve_zero_column_skips_unknown() {
        // Third regressor absent: coefficient must come out 0.
        let m = [[2.0, 1.0, 0.0], [1.0, 2.0, 0.0], [0.0, 0.0, 0.0]];
        let b = [5.0, 4.0, 0.0];
        let x = solve(m, b);
        assert_eq!(x[2], 0.0);
        assert!((2.0 * x[0] + x[1] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn fit_recovers_synthetic_constants() {
        // Generate windows from known constants; the fit must recover
        // them (compute-bound, varied shapes).
        let truth = ConfigCal {
            alpha: 50.0,
            beta: 12.0,
            gamma: 0.0,
            epsilon: 1.0,
            delta: 1.0,
        };
        let mut samples = Vec::new();
        for (m, n, k) in
            [(16, 16, 16), (32, 32, 32), (32, 16, 48), (48, 48, 32)]
        {
            let p = plan(ConfigId::Zonl64Db, m, n, k);
            let f = features(ConfigId::Zonl64Db, &p);
            let window = f.passes * f.fp_pass
                + truth.alpha * f.passes
                + truth.beta * f.outer_total;
            samples.push(CalSample {
                config: ConfigId::Zonl64Db,
                features: f,
                window_measured: window,
            });
        }
        let cal = fit_calibration(&samples);
        let got = cal.get(ConfigId::Zonl64Db);
        assert!((got.alpha - truth.alpha).abs() < 1.0, "{got:?}");
        assert!((got.beta - truth.beta).abs() < 0.5, "{got:?}");
        // no fused samples: epsilon keeps its default
        assert_eq!(got.epsilon, 1.0);
        // untouched configs keep defaults
        assert_eq!(
            cal.get(ConfigId::Base32Fc),
            Calibration::default().get(ConfigId::Base32Fc)
        );
    }

    #[test]
    fn fit_recovers_epsilon_from_fused_samples() {
        use crate::kernels::epilogue::{Activation, Epilogue};
        use crate::kernels::plan_gemm_fused;
        let truth = ConfigCal {
            alpha: 40.0,
            beta: 9.0,
            gamma: 0.0,
            epsilon: 1.4,
            delta: 1.0,
        };
        let epi = Epilogue { bias: true, act: Some(Activation::Relu) };
        let mut samples = Vec::new();
        for (m, n, k, fused) in [
            (16, 16, 16, false),
            (32, 32, 32, false),
            (32, 16, 48, true),
            (48, 48, 32, true),
            (16, 32, 40, true),
        ] {
            let e = if fused { epi } else { Epilogue::NONE };
            let p = plan_gemm_fused(
                &ConfigId::Zonl48Db.cluster_config(),
                m,
                n,
                k,
                LayoutKind::Grouped,
                e,
            )
            .unwrap();
            let f = features(ConfigId::Zonl48Db, &p);
            let window = f.passes * f.fp_pass
                + truth.alpha * f.passes
                + truth.beta * f.outer_total
                + truth.epsilon * f.epi_total;
            samples.push(CalSample {
                config: ConfigId::Zonl48Db,
                features: f,
                window_measured: window,
            });
        }
        let cal = fit_calibration(&samples);
        let got = cal.get(ConfigId::Zonl48Db);
        assert!((got.epsilon - truth.epsilon).abs() < 0.1, "{got:?}");
        assert!((got.alpha - truth.alpha).abs() < 2.0, "{got:?}");
    }

    #[test]
    fn noc_factor_one_is_the_single_cluster_model() {
        let cal = Calibration::default();
        for id in [ConfigId::Base32Fc, ConfigId::Zonl48Db] {
            let p = plan(id, 64, 64, 64);
            let lone = predict_perf(&cal, id, &p);
            let fab = predict_perf_noc(&cal, id, &p, 1.0);
            assert_eq!(lone.window_cycles, fab.window_cycles);
            assert_eq!(lone.cycles, fab.cycles);
        }
    }

    #[test]
    fn noc_contention_only_slows_dma_bound_passes() {
        let cal = Calibration::default();
        // Compute-bound shard (long K): contention stays under the
        // compute roofline, window unchanged.
        let pc = plan(ConfigId::Zonl48Db, 64, 64, 128);
        let w1 = predict_perf_noc(&cal, ConfigId::Zonl48Db, &pc, 1.0)
            .window_cycles;
        let w2 = predict_perf_noc(&cal, ConfigId::Zonl48Db, &pc, 2.0)
            .window_cycles;
        assert_eq!(w1, w2, "compute-bound shard must not stretch");
        // Thin-K multi-pass shard on a starved NoC (8 branches on one
        // link): serialization pushes the DMA over the compute
        // roofline and the window stretches.
        let pd = plan(ConfigId::Zonl48Db, 128, 128, 8);
        let d1 = predict_perf_noc(&cal, ConfigId::Zonl48Db, &pd, 1.0)
            .window_cycles;
        let d8 = predict_perf_noc(&cal, ConfigId::Zonl48Db, &pd, 8.0)
            .window_cycles;
        assert!(
            d8 > d1,
            "DMA-bound shard must stretch under NoC contention: \
             {d8} vs {d1}"
        );
    }

    #[test]
    fn conflict_split_is_disjoint() {
        // The analytic counters mirror the cycle backend's XbarStats
        // split: DMA-mux losses and bank-level losses never overlap.
        let cal = Calibration::default();
        let p = plan(ConfigId::Base32Fc, 64, 64, 64);
        let perf = predict_perf(&cal, ConfigId::Base32Fc, &p);
        assert!(perf.tcdm_conflicts_dma > 0, "32-bank grouped contends");
        assert_eq!(
            perf.ssr_conflicts,
            perf.tcdm_conflicts + perf.tcdm_conflicts_dma,
            "split must partition the total"
        );
    }

    #[test]
    fn fit_delta_recovers_blend() {
        // measured = free + 0.6 * (serialized - free)
        let samples: Vec<NocSample> = [(100.0, 300.0), (80.0, 400.0)]
            .iter()
            .map(|&(free, ser)| NocSample {
                window_measured: free + 0.6 * (ser - free),
                window_free: free,
                window_serialized: ser,
            })
            .collect();
        let d = fit_delta(&samples).unwrap();
        assert!((d - 0.6).abs() < 1e-9, "{d}");
        // No spread -> no signal.
        assert!(fit_delta(&[NocSample {
            window_measured: 50.0,
            window_free: 50.0,
            window_serialized: 50.0,
        }])
        .is_none());
    }

    #[test]
    fn predicted_stall_profile_conserves_and_decomposes() {
        let cal = Calibration::default();
        for id in ConfigId::all() {
            let p = plan(id, 64, 64, 64);
            let perf = predict_perf(&cal, id, &p);
            perf.stalls.check_conservation().unwrap();
            assert_eq!(perf.stalls.window_cycles, perf.window_cycles);
            assert_eq!(perf.stalls.n_compute, N_CORES);
            assert_eq!(perf.stalls.dm_cores().len(), 1);
            // The quantized Useful share reproduces the predicted
            // utilization up to rounding.
            assert!(
                (perf.stalls.utilization() - perf.utilization).abs()
                    < 0.02,
                "{}: {} vs {}",
                id.name(),
                perf.stalls.utilization(),
                perf.utilization
            );
        }
        // Structure: the baseline predicts a larger control-overhead
        // share than the zero-overhead loop nest; a 32-bank shared
        // layout predicts bank conflicts where Dobu predicts ~none.
        use crate::profile::StallClass;
        let shares = |id: ConfigId| {
            predict_perf(&cal, id, &plan(id, 64, 64, 64))
                .stalls
                .shares()
        };
        let base = shares(ConfigId::Base32Fc);
        let dobu = shares(ConfigId::Zonl48Db);
        let co = StallClass::ControlOverhead as usize;
        let bc = StallClass::BankConflict as usize;
        assert!(base[co] > dobu[co], "{} <= {}", base[co], dobu[co]);
        assert!(base[bc] > 0.0);
        assert!(dobu[bc] < 0.02, "Dobu predicts ~zero conflicts");
    }

    #[test]
    fn predicted_noc_gating_appears_on_starved_fabrics() {
        use crate::profile::StallClass;
        let cal = Calibration::default();
        // Thin-K multi-pass shard (DMA-heavy) on an 8-way serialized
        // NoC: the prediction must attribute cycles to NocGated.
        let p = plan(ConfigId::Zonl48Db, 128, 128, 8);
        let lone = predict_perf_noc(&cal, ConfigId::Zonl48Db, &p, 1.0);
        let starved =
            predict_perf_noc(&cal, ConfigId::Zonl48Db, &p, 8.0);
        let ng = StallClass::NocGated as usize;
        assert_eq!(lone.stalls.totals()[ng], 0, "private link: no gating");
        assert!(starved.stalls.totals()[ng] > 0);
        starved.stalls.check_conservation().unwrap();
    }

    #[test]
    fn fused_epilogue_prediction_adds_issue_cost() {
        use crate::kernels::epilogue::{Activation, Epilogue};
        use crate::kernels::plan_gemm_fused;
        let cal = Calibration::default();
        let cfg = ConfigId::Zonl48Db.cluster_config();
        let plain = plan(ConfigId::Zonl48Db, 32, 32, 32);
        let fused = plan_gemm_fused(
            &cfg,
            32,
            32,
            32,
            LayoutKind::Grouped,
            Epilogue { bias: true, act: Some(Activation::Gelu) },
        )
        .unwrap();
        let wp = predict_perf(&cal, ConfigId::Zonl48Db, &plain);
        let wf = predict_perf(&cal, ConfigId::Zonl48Db, &fused);
        assert!(
            wf.window_cycles > wp.window_cycles,
            "activation row must cost issue cycles: {} vs {}",
            wf.window_cycles,
            wp.window_cycles
        );
        // one extra op per element
        assert_eq!(
            wf.fpu_ops_total,
            wp.fpu_ops_total + 32 * 32,
            "epilogue ops counted"
        );
    }
}
