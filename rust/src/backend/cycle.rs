//! The cycle-accurate backend — today's full machine model behind the
//! [`SimBackend`] trait.
//!
//! This owns the run-to-completion loop that used to live inline in
//! `kernels::driver::run_matmul_layout`: build the cluster from the
//! shared programs, load A/B into simulated main memory, step to
//! halt, read C back. It is a pure refactor: given the same prepared
//! GEMM and operands it reproduces the pre-trait cycles, utilization,
//! and output matrix bit for bit.

use anyhow::{Context, Result};

use crate::cluster::Cluster;
use crate::fabric::{
    ClusterFabric, FabricResult, NocConfig, ShardRun,
};
use crate::kernels::codegen::N_CORES;
use crate::kernels::GemmResult;

use super::{BackendKind, PreparedGemm, ShardedGemm, SimBackend};

/// Cycle-engine configuration. `fast_forward` (on by default) routes
/// runs through the FastPath steppers — quiescent DMA regions advance
/// with closed-form bookkeeping and fabric shards step on threads —
/// which is bit-identical to naive per-cycle stepping (see DESIGN.md
/// §11). `threads` bounds the fabric's parallel shard stepping
/// (0 = machine parallelism); it affects wall time only, never
/// results.
#[derive(Clone, Copy, Debug)]
pub struct CycleAccurate {
    pub fast_forward: bool,
    pub threads: usize,
}

impl Default for CycleAccurate {
    fn default() -> Self {
        CycleAccurate { fast_forward: true, threads: 0 }
    }
}

impl CycleAccurate {
    /// The pre-FastPath stepper: every core ticked every cycle,
    /// serial fabric. The differential baseline for the equivalence
    /// tests and benches.
    pub fn naive() -> Self {
        CycleAccurate { fast_forward: false, threads: 1 }
    }

    /// Simulation deadline: ideal cycles x 64 + fixed slack (the
    /// deadlock detector's budget; generous by construction).
    pub fn deadline(m: usize, n: usize, k: usize) -> u64 {
        let ideal = (m * n * k) as u64 / (N_CORES as u64);
        100_000 + ideal * 64
    }

    /// Deadline for a sharded fabric run: NoC serialization can
    /// stretch DMA phases by up to the cluster count, so the
    /// per-shard deadline scales with it. Shared by `run_sharded` and
    /// the StallScope profiler so the two can never desynchronize.
    pub fn shard_deadline(sh: &ShardedGemm) -> u64 {
        Self::deadline(sh.grid.sm, sh.grid.sn, sh.k)
            * sh.shards.len().max(1) as u64
    }

    /// Build the cluster for one prepared GEMM with operands loaded
    /// into simulated main memory — the run-ready machine, exposed so
    /// callers (the StallScope profiler) can attach trace collectors
    /// before stepping it.
    pub fn build_cluster(
        prep: &PreparedGemm,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<Cluster> {
        let t = prep.plan.tiling;
        anyhow::ensure!(
            a.len() == t.m * t.k && b.len() == t.k * t.n,
            "cycle backend needs operand data: A {} (want {}), B {} \
             (want {})",
            a.len(),
            t.m * t.k,
            b.len(),
            t.k * t.n
        );
        anyhow::ensure!(
            !prep.plan.epi.bias || bias.len() == t.n,
            "fused bias epilogue needs a length-{} bias vector (got {})",
            t.n,
            bias.len()
        );
        let cfg = prep.config.cluster_config();
        let mut cl = Cluster::from_shared(cfg, &prep.programs);
        cl.mem.write_slice_f64(prep.plan.main.a, a);
        cl.mem.write_slice_f64(prep.plan.main.b, b);
        if prep.plan.epi.bias {
            cl.mem.write_slice_f64(prep.plan.main.bias, bias);
        }
        Ok(cl)
    }

    /// Extract the result from a halted cluster.
    pub fn collect(prep: &PreparedGemm, cl: &Cluster) -> GemmResult {
        let t = prep.plan.tiling;
        let c = cl.mem.read_vec_f64(prep.plan.main.c, t.m * t.n);
        GemmResult {
            c,
            cycles: cl.cycle,
            perf: cl.perf(),
            plan: prep.plan,
            config: prep.config,
        }
    }

    /// Build one scatter-loaded cluster per shard (run-ready; callers
    /// assemble them into a [`ClusterFabric`]).
    pub fn build_shard_clusters(
        sh: &ShardedGemm,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<Vec<Cluster>> {
        let (m, n, k) = (sh.m, sh.n, sh.k);
        anyhow::ensure!(
            a.len() == m * k && b.len() == k * n,
            "sharded cycle run needs full operands: A {} (want {}), \
             B {} (want {})",
            a.len(),
            m * k,
            b.len(),
            k * n
        );
        anyhow::ensure!(
            !sh.prep.plan.epi.bias || bias.len() == n,
            "fused bias epilogue needs a length-{n} bias vector \
             (got {})",
            bias.len()
        );
        let cfg = sh.config.cluster_config();
        let plan = &sh.prep.plan;
        let (sm, sn) = (sh.grid.sm, sh.grid.sn);
        let mut clusters = Vec::with_capacity(sh.shards.len());
        let mut b_block = vec![0.0f64; k * sn];
        for s in &sh.shards {
            let mut cl = Cluster::from_shared(cfg, &sh.prep.programs);
            // A block: sm contiguous rows of the full A.
            cl.mem.write_slice_f64(
                plan.main.a,
                &a[s.m0 * k..(s.m0 + sm) * k],
            );
            // B block: sn columns gathered row by row.
            for kk in 0..k {
                let src = kk * n + s.n0;
                b_block[kk * sn..(kk + 1) * sn]
                    .copy_from_slice(&b[src..src + sn]);
            }
            cl.mem.write_slice_f64(plan.main.b, &b_block);
            if plan.epi.bias {
                cl.mem.write_slice_f64(
                    plan.main.bias,
                    &bias[s.n0..s.n0 + sn],
                );
            }
            clusters.push(cl);
        }
        Ok(clusters)
    }

    /// Gather the sharded result from a halted fabric.
    pub fn gather(sh: &ShardedGemm, fab: &ClusterFabric) -> FabricResult {
        let (m, n) = (sh.m, sh.n);
        let plan = &sh.prep.plan;
        let (sm, sn) = (sh.grid.sm, sh.grid.sn);
        let mut c = vec![0.0f64; m * n];
        let mut shards = Vec::with_capacity(sh.shards.len());
        for (s, cl) in sh.shards.iter().zip(&fab.clusters) {
            let cs = cl.mem.read_vec_f64(plan.main.c, sm * sn);
            for r in 0..sm {
                let dst = (s.m0 + r) * n + s.n0;
                c[dst..dst + sn]
                    .copy_from_slice(&cs[r * sn..(r + 1) * sn]);
            }
            shards.push(ShardRun {
                shard: *s,
                cycles: cl.cycle,
                perf: cl.perf(),
            });
        }
        FabricResult { c, cycles: fab.cycle, shards, noc: fab.noc }
    }
}

impl SimBackend for CycleAccurate {
    fn kind(&self) -> BackendKind {
        BackendKind::Cycle
    }

    fn run_fused(
        &self,
        prep: &PreparedGemm,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<GemmResult> {
        let t = prep.plan.tiling;
        let mut cl = Self::build_cluster(prep, a, b, bias)?;
        let deadline = Self::deadline(t.m, t.n, t.k);
        if self.fast_forward {
            cl.run_fast(deadline)
        } else {
            cl.run(deadline)
        }
        .context("cluster run")?;
        Ok(Self::collect(prep, &cl))
    }

    /// Scatter operand blocks, run every shard's cluster in lockstep
    /// against the shared NoC arbiter, gather C. Bit-identical to the
    /// single-cluster driver: K stays shard-local, so each output
    /// element keeps its exact FMA association order.
    fn run_sharded(
        &self,
        sh: &ShardedGemm,
        noc: &NocConfig,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<FabricResult> {
        let clusters = Self::build_shard_clusters(sh, a, b, bias)?;
        let deadline = Self::shard_deadline(sh);
        let mut fab = ClusterFabric::new(clusters, *noc);
        if self.fast_forward {
            fab.run_fast(deadline, self.threads)
        } else {
            fab.run(deadline)
        }
        .context("fabric run")?;
        Ok(Self::gather(sh, &fab))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ConfigId;
    use crate::kernels::{host_ref, run_matmul, test_matrices};

    #[test]
    fn matches_driver_path_bit_for_bit() {
        // The driver funnels through this backend; cross-check against
        // the host reference to pin the refactor down.
        let (m, n, k) = (16, 16, 16);
        let (a, b) = test_matrices(m, n, k, 77);
        let r = run_matmul(ConfigId::Zonl48Db, m, n, k, &a, &b).unwrap();
        let want = host_ref(m, n, k, &a, &b);
        for (g, w) in r.c.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-9 * w.abs().max(1.0));
        }
        assert!(r.cycles > 0);
    }

    #[test]
    fn rejects_missing_operands() {
        let svc = crate::kernels::GemmService::cycle();
        let prep = svc
            .prepare(
                ConfigId::Base32Fc,
                8,
                8,
                8,
                crate::kernels::LayoutKind::Grouped,
            )
            .unwrap();
        assert!(CycleAccurate::default().run(&prep, &[], &[]).is_err());
    }
}
