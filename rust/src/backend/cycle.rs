//! The cycle-accurate backend — today's full machine model behind the
//! [`SimBackend`] trait.
//!
//! This owns the run-to-completion loop that used to live inline in
//! `kernels::driver::run_matmul_layout`: build the cluster from the
//! shared programs, load A/B into simulated main memory, step to
//! halt, read C back. It is a pure refactor: given the same prepared
//! GEMM and operands it reproduces the pre-trait cycles, utilization,
//! and output matrix bit for bit.

use anyhow::{Context, Result};

use crate::cluster::Cluster;
use crate::kernels::codegen::N_CORES;
use crate::kernels::GemmResult;

use super::{BackendKind, PreparedGemm, SimBackend};

pub struct CycleAccurate;

impl CycleAccurate {
    /// Simulation deadline: ideal cycles x 64 + fixed slack (the
    /// deadlock detector's budget; generous by construction).
    pub fn deadline(m: usize, n: usize, k: usize) -> u64 {
        let ideal = (m * n * k) as u64 / (N_CORES as u64);
        100_000 + ideal * 64
    }
}

impl SimBackend for CycleAccurate {
    fn kind(&self) -> BackendKind {
        BackendKind::Cycle
    }

    fn run_fused(
        &self,
        prep: &PreparedGemm,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<GemmResult> {
        let t = prep.plan.tiling;
        anyhow::ensure!(
            a.len() == t.m * t.k && b.len() == t.k * t.n,
            "cycle backend needs operand data: A {} (want {}), B {} \
             (want {})",
            a.len(),
            t.m * t.k,
            b.len(),
            t.k * t.n
        );
        anyhow::ensure!(
            !prep.plan.epi.bias || bias.len() == t.n,
            "fused bias epilogue needs a length-{} bias vector (got {})",
            t.n,
            bias.len()
        );
        let cfg = prep.config.cluster_config();
        let mut cl = Cluster::from_shared(cfg, &prep.programs);
        cl.mem.write_slice_f64(prep.plan.main.a, a);
        cl.mem.write_slice_f64(prep.plan.main.b, b);
        if prep.plan.epi.bias {
            cl.mem.write_slice_f64(prep.plan.main.bias, bias);
        }
        let cycles = cl
            .run(Self::deadline(t.m, t.n, t.k))
            .context("cluster run")?;
        let c = cl.mem.read_vec_f64(prep.plan.main.c, t.m * t.n);
        Ok(GemmResult {
            c,
            cycles,
            perf: cl.perf(),
            plan: prep.plan,
            config: prep.config,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ConfigId;
    use crate::kernels::{host_ref, run_matmul, test_matrices};

    #[test]
    fn matches_driver_path_bit_for_bit() {
        // The driver funnels through this backend; cross-check against
        // the host reference to pin the refactor down.
        let (m, n, k) = (16, 16, 16);
        let (a, b) = test_matrices(m, n, k, 77);
        let r = run_matmul(ConfigId::Zonl48Db, m, n, k, &a, &b).unwrap();
        let want = host_ref(m, n, k, &a, &b);
        for (g, w) in r.c.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-9 * w.abs().max(1.0));
        }
        assert!(r.cycles > 0);
    }

    #[test]
    fn rejects_missing_operands() {
        let svc = crate::kernels::GemmService::cycle();
        let prep = svc
            .prepare(
                ConfigId::Base32Fc,
                8,
                8,
                8,
                crate::kernels::LayoutKind::Grouped,
            )
            .unwrap();
        assert!(CycleAccurate.run(&prep, &[], &[]).is_err());
    }
}
