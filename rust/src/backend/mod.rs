//! Simulation backends — the engines that evaluate a planned GEMM.
//!
//! The run path is split in two layers:
//!
//! * planning (`kernels::{tiling, layout, codegen}`) produces a
//!   [`PreparedGemm`]: the tile plan, buffer map, and generated
//!   programs (including any fused bias/activation epilogue).
//!   Preparation is pure and memoizable — the
//!   `kernels::service::GemmService` caches it per
//!   `(M, N, K, config, layout, epilogue)` key.
//! * evaluation (this module) turns a prepared GEMM into a
//!   `GemmResult`. Three engines implement the [`SimBackend`] trait:
//!
//!   - [`CycleAccurate`] steps the full `Cluster` machine model to
//!     completion — bit-exact numerics plus the complete perf-counter
//!     taxonomy. This is the ground truth (and the pre-refactor
//!     behaviour of `kernels::driver`). Its FastPath mode
//!     (`fast_forward`, on by default) fast-forwards quiescent DMA
//!     regions and steps fabric shards in parallel — bit-identical
//!     results, roughly an order of magnitude faster on
//!     DMA-phase-heavy runs.
//!   - [`Replay`] memoizes the cycle engine per
//!     `(shape, config, layout, epilogue[, grid, NoC])` key: the
//!     first evaluation of a shape runs the machine model, repeats
//!     replay the cached timing and recompute C functionally (the
//!     cycle kernel is bit-exact against the host oracle, so the
//!     replayed result is indistinguishable from a fresh run).
//!   - [`Analytic`] predicts cycles / utilization / conflicts from
//!     the tiling, the congestion proxy, and the paper's Section-IV
//!     overhead structure without stepping the machine — ~1000x
//!     faster, for triaging large design-space sweeps. It produces no
//!     functional output (`GemmResult::c` is empty).
//!
//! Backends are object-safe (`Box<dyn SimBackend>`): the service and
//! the CLI select one at runtime via [`BackendKind`].

pub mod analytic;
pub mod cycle;
pub mod replay;

pub use analytic::{
    fit_calibration, fit_delta, predict_perf_noc, Analytic, CalSample,
    Calibration, ConfigCal, NocSample,
};
pub use cycle::CycleAccurate;
pub use replay::{Replay, ReplayStats};

use std::sync::Arc;

use crate::cluster::ConfigId;
use crate::fabric::{FabricResult, NocConfig};
use crate::isa::Program;
use crate::kernels::tiling::{Shard, ShardGrid};
use crate::kernels::{GemmPlan, GemmResult};

/// Which engine evaluates a GEMM point.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Full machine-model simulation (ground truth).
    Cycle,
    /// First-order performance model (no functional simulation).
    Analytic,
    /// Memoized cycle engine: first run per shape is cycle-accurate,
    /// repeats replay the cached timing (C recomputed functionally).
    Replay,
}

impl BackendKind {
    pub fn all() -> [BackendKind; 3] {
        [BackendKind::Cycle, BackendKind::Analytic, BackendKind::Replay]
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Cycle => "cycle",
            BackendKind::Analytic => "analytic",
            BackendKind::Replay => "replay",
        }
    }

    pub fn from_name(s: &str) -> Option<BackendKind> {
        BackendKind::all().into_iter().find(|b| b.name() == s)
    }
}

/// A fully planned GEMM: everything evaluation needs, shareable across
/// batched submissions (programs are `Arc`ed so repeated runs never
/// re-clone instruction streams).
#[derive(Clone, Debug)]
pub struct PreparedGemm {
    pub config: ConfigId,
    pub plan: GemmPlan,
    /// One program per compute core plus the DM core's last — empty
    /// when the owning backend reports `needs_programs() == false`.
    pub programs: Vec<Arc<Program>>,
    /// Lazily computed ProofScope verdict for this plan (shared by
    /// every run of the prepared GEMM; see `lint()`).
    pub lint_cache: std::sync::OnceLock<Arc<crate::verify::StaticStallReport>>,
}

impl PreparedGemm {
    /// The ProofScope static stall verdict for this plan, computed on
    /// first use and cached alongside the plan for its lifetime.
    pub fn lint(&self) -> Arc<crate::verify::StaticStallReport> {
        Arc::clone(self.lint_cache.get_or_init(|| {
            Arc::new(crate::verify::verify_prepared(self))
        }))
    }

    pub fn m(&self) -> usize {
        self.plan.tiling.m
    }

    pub fn n(&self) -> usize {
        self.plan.tiling.n
    }

    pub fn k(&self) -> usize {
        self.plan.tiling.k
    }
}

/// A fabric-sharded GEMM: the full problem, the M x N shard grid (K
/// stays local to every shard), and the *one* prepared per-shard plan
/// every cluster reuses (shards are uniform by construction, so the
/// plan cache serves the whole fabric from a single entry).
#[derive(Clone, Debug)]
pub struct ShardedGemm {
    pub config: ConfigId,
    /// Full-problem dimensions.
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub grid: ShardGrid,
    /// Row-major shard list (one per busy cluster).
    pub shards: Vec<Shard>,
    /// Shared per-shard plan (`grid.sm x grid.sn x k`).
    pub prep: Arc<PreparedGemm>,
}

/// A simulation engine.
///
/// Implementations must be `Send + Sync`: the service drains batches
/// through `coordinator::runner::parallel_map` with one shared backend.
pub trait SimBackend: Send + Sync {
    fn kind(&self) -> BackendKind;

    /// Whether `run` consumes operand data (functional simulation).
    /// Non-functional backends are handed empty slices.
    fn needs_data(&self) -> bool {
        true
    }

    /// Whether `run` executes the generated programs. Model-only
    /// backends skip code generation entirely (`PreparedGemm::programs`
    /// stays empty), which is what makes full-grid sweeps cheap.
    fn needs_programs(&self) -> bool {
        true
    }

    /// Evaluate one prepared GEMM. `a` is row-major `m x k`, `b` is
    /// row-major `k x n`; both may be empty iff `needs_data()` is
    /// false. Plans with a fused bias epilogue additionally consume a
    /// length-`n` bias vector via [`SimBackend::run_fused`]; this
    /// convenience passes an empty one.
    fn run(
        &self,
        prep: &PreparedGemm,
        a: &[f64],
        b: &[f64],
    ) -> anyhow::Result<GemmResult> {
        self.run_fused(prep, a, b, &[])
    }

    /// Evaluate one prepared GEMM with its fused-epilogue operands.
    fn run_fused(
        &self,
        prep: &PreparedGemm,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> anyhow::Result<GemmResult>;

    /// Evaluate one sharded GEMM across a multi-cluster fabric behind
    /// a shared NoC. Operands are the *full* problem's (`a` row-major
    /// `m x k`, `b` row-major `k x n`, `bias` length `n` when the
    /// plan fuses one); scatter/gather is the backend's job. Both may
    /// be empty iff `needs_data()` is false.
    fn run_sharded(
        &self,
        sharded: &ShardedGemm,
        noc: &NocConfig,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> anyhow::Result<FabricResult>;

    /// Memo-tier hit/miss counters, for backends that cache timing
    /// per shape ([`Replay`]). `None` for engines that simulate every
    /// submission.
    fn memo_stats(&self) -> Option<ReplayStats> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_roundtrip() {
        for b in BackendKind::all() {
            assert_eq!(BackendKind::from_name(b.name()), Some(b));
        }
        assert_eq!(BackendKind::from_name("rtl"), None);
    }
}
