//! The replay/memo backend tier — a cycle engine that only simulates
//! each shape once.
//!
//! Serve traces hit the same GEMM shapes over and over (a model's
//! layer zoo is small; a trace is long). The cycle engine's timing is
//! *data-oblivious*: no floating-point value ever reaches control
//! flow — programs, DMA descriptors, SSR patterns, and arbitration
//! all derive from `(shape, config, layout, epilogue)` alone — so two
//! submissions with the same key produce identical cycles, perf
//! counters, and NoC statistics regardless of operand values. This
//! tier exploits that: the first submission per key runs the real
//! machine model (via the wrapped [`CycleAccurate`]) and caches the
//! timing; repeats replay the cached timing and recompute C with the
//! host oracle `host_ref_fused`, which the cycle kernel matches bit
//! for bit (pinned by the service and fabric test suites — the
//! generated kernels preserve the oracle's FMA fold order).
//!
//! The memo layers over `GemmService`'s plan cache: the service
//! dedups *planning* per key, this tier dedups *evaluation*. Hit and
//! miss accounting follows the same racing-miss discipline as
//! `GemmService::prepare_fused`: concurrent first submissions both
//! simulate, the insertion loser counts as a hit.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::cluster::{ClusterPerf, ConfigId};
use crate::fabric::{FabricResult, NocConfig, NocStats, ShardRun};
use crate::kernels::{host_ref_fused, Epilogue, GemmResult, LayoutKind};

use super::{
    BackendKind, CycleAccurate, PreparedGemm, ShardedGemm, SimBackend,
};

/// Memo-tier counters (snapshot; monotone within a run).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplayStats {
    /// Submissions served from the timing memo.
    pub hits: u64,
    /// Submissions that ran the cycle engine (first per key, plus
    /// racing duplicates' winners).
    pub misses: u64,
}

impl ReplayStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Everything that determines a fused run's timing.
type FusedKey = (usize, usize, usize, ConfigId, LayoutKind, Epilogue);

/// Everything that determines a sharded run's timing: the full
/// problem + plan key, the shard grid (`sm x sn` blocks, shard
/// count), and the NoC budget the fabric arbitrates under.
type ShardKey = (FusedKey, usize, usize, usize, usize, usize);

struct FusedMemo {
    cycles: u64,
    perf: ClusterPerf,
}

struct ShardMemo {
    cycles: u64,
    noc: NocStats,
    shards: Vec<ShardRun>,
}

/// The third [`SimBackend`]: memoized cycle-accurate evaluation.
pub struct Replay {
    inner: CycleAccurate,
    fused: RwLock<HashMap<FusedKey, Arc<FusedMemo>>>,
    sharded: RwLock<HashMap<ShardKey, Arc<ShardMemo>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for Replay {
    fn default() -> Self {
        Replay::with(CycleAccurate::default())
    }
}

impl Replay {
    /// Memoize over a specific cycle-engine configuration (the memo
    /// is equivalence-safe either way: FastPath and naive stepping
    /// are bit-identical).
    pub fn with(inner: CycleAccurate) -> Self {
        Replay {
            inner,
            fused: RwLock::new(HashMap::new()),
            sharded: RwLock::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn stats(&self) -> ReplayStats {
        ReplayStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    fn fused_key(prep: &PreparedGemm) -> FusedKey {
        let t = prep.plan.tiling;
        (t.m, t.n, t.k, prep.config, prep.plan.layout, prep.plan.epi)
    }

    fn shard_key(sh: &ShardedGemm, noc: &NocConfig) -> ShardKey {
        (
            (
                sh.m,
                sh.n,
                sh.k,
                sh.config,
                sh.prep.plan.layout,
                sh.prep.plan.epi,
            ),
            sh.grid.sm,
            sh.grid.sn,
            sh.shards.len(),
            noc.links,
            noc.beats_per_link,
        )
    }

    /// Replay a fused hit: cached timing, functionally recomputed C.
    /// Operand validation mirrors the cycle engine so a hit and a
    /// miss reject exactly the same malformed submissions.
    fn replay_fused(
        prep: &PreparedGemm,
        memo: &FusedMemo,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<GemmResult> {
        let t = prep.plan.tiling;
        anyhow::ensure!(
            a.len() == t.m * t.k && b.len() == t.k * t.n,
            "cycle backend needs operand data: A {} (want {}), B {} \
             (want {})",
            a.len(),
            t.m * t.k,
            b.len(),
            t.k * t.n
        );
        anyhow::ensure!(
            !prep.plan.epi.bias || bias.len() == t.n,
            "fused bias epilogue needs a length-{} bias vector (got {})",
            t.n,
            bias.len()
        );
        let c = host_ref_fused(t.m, t.n, t.k, prep.plan.epi, a, b, bias);
        Ok(GemmResult {
            c,
            cycles: memo.cycles,
            perf: memo.perf.clone(),
            plan: prep.plan,
            config: prep.config,
        })
    }

    /// Replay a sharded hit: cached fabric timing + per-shard runs,
    /// C recomputed on the full problem (bit-identical to gather — K
    /// stays shard-local, so every element keeps its FMA order).
    fn replay_sharded(
        sh: &ShardedGemm,
        memo: &ShardMemo,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<FabricResult> {
        let (m, n, k) = (sh.m, sh.n, sh.k);
        anyhow::ensure!(
            a.len() == m * k && b.len() == k * n,
            "sharded cycle run needs full operands: A {} (want {}), \
             B {} (want {})",
            a.len(),
            m * k,
            b.len(),
            k * n
        );
        anyhow::ensure!(
            !sh.prep.plan.epi.bias || bias.len() == n,
            "fused bias epilogue needs a length-{n} bias vector \
             (got {})",
            bias.len()
        );
        let c = host_ref_fused(m, n, k, sh.prep.plan.epi, a, b, bias);
        Ok(FabricResult {
            c,
            cycles: memo.cycles,
            shards: memo.shards.clone(),
            noc: memo.noc,
        })
    }
}

impl SimBackend for Replay {
    fn kind(&self) -> BackendKind {
        BackendKind::Replay
    }

    fn memo_stats(&self) -> Option<ReplayStats> {
        Some(self.stats())
    }

    fn run_fused(
        &self,
        prep: &PreparedGemm,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<GemmResult> {
        let key = Self::fused_key(prep);
        if let Some(memo) = self.fused.read().unwrap().get(&key).cloned()
        {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Self::replay_fused(prep, &memo, a, b, bias);
        }
        // Miss: simulate outside the lock, then publish. A racing
        // duplicate also simulates; whoever loses the insert counts
        // a hit (same discipline as the service's plan cache).
        let r = self.inner.run_fused(prep, a, b, bias)?;
        let memo = Arc::new(FusedMemo {
            cycles: r.cycles,
            perf: r.perf.clone(),
        });
        match self.fused.write().unwrap().entry(key) {
            Entry::Occupied(_) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
            }
            Entry::Vacant(v) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                v.insert(memo);
            }
        }
        Ok(r)
    }

    fn run_sharded(
        &self,
        sh: &ShardedGemm,
        noc: &NocConfig,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<FabricResult> {
        let key = Self::shard_key(sh, noc);
        if let Some(memo) =
            self.sharded.read().unwrap().get(&key).cloned()
        {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Self::replay_sharded(sh, &memo, a, b, bias);
        }
        let r = self.inner.run_sharded(sh, noc, a, b, bias)?;
        let memo = Arc::new(ShardMemo {
            cycles: r.cycles,
            noc: r.noc,
            shards: r.shards.clone(),
        });
        match self.sharded.write().unwrap().entry(key) {
            Entry::Occupied(_) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
            }
            Entry::Vacant(v) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                v.insert(memo);
            }
        }
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{test_matrices, GemmService};

    #[test]
    fn replay_matches_cycle_and_counts_hits() {
        let cycle = GemmService::cycle();
        let replay = GemmService::replay();
        let (m, n, k) = (16, 16, 16);
        let (a, b) = test_matrices(m, n, k, 42);
        let want = cycle
            .run(
                ConfigId::Zonl48Db,
                m,
                n,
                k,
                LayoutKind::Grouped,
                &a,
                &b,
            )
            .unwrap();
        // First submission simulates (miss), second replays (hit).
        for pass in 0..2 {
            let got = replay
                .run(
                    ConfigId::Zonl48Db,
                    m,
                    n,
                    k,
                    LayoutKind::Grouped,
                    &a,
                    &b,
                )
                .unwrap();
            assert_eq!(got.c, want.c, "pass {pass}: C bit-identical");
            assert_eq!(got.cycles, want.cycles, "pass {pass}");
            assert_eq!(
                got.perf.stalls, want.perf.stalls,
                "pass {pass}: stall taxonomy replays exactly"
            );
        }
        assert_eq!(
            replay.memo_stats(),
            Some(ReplayStats { hits: 1, misses: 1 })
        );
        assert_eq!(cycle.memo_stats(), None);
    }

    #[test]
    fn replay_hit_still_validates_operands() {
        let svc = GemmService::cycle();
        let prep = svc
            .prepare(ConfigId::Base32Fc, 8, 8, 8, LayoutKind::Grouped)
            .unwrap();
        let be = Replay::default();
        let (a, b) = test_matrices(8, 8, 8, 7);
        be.run(&prep, &a, &b).unwrap();
        // Same key, missing operands: the hit path must reject the
        // submission exactly like a fresh simulation would.
        assert!(be.run(&prep, &[], &[]).is_err());
        assert_eq!(be.stats().misses, 1);
    }
}
