//! The five cluster configurations evaluated in the paper (Table I).

use crate::core::{CoreConfig, SeqConfig};
use crate::core::fpu::FpuConfig;
use crate::mem::Topology;

/// Named configuration id — the rows of Table I / boxes of Fig. 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ConfigId {
    /// Baseline: 128 KiB, 32 banks, fully-connected, plain FREP.
    Base32Fc,
    /// + zero-overhead loop nests.
    Zonl32Fc,
    /// + 64 banks, still fully-connected (area/energy hungry).
    Zonl64Fc,
    /// 64 banks behind the Dobu interconnect (2x32).
    Zonl64Db,
    /// The paper's pick: 96 KiB, 48 banks, Dobu (2x24).
    Zonl48Db,
}

impl ConfigId {
    pub fn all() -> [ConfigId; 5] {
        [
            ConfigId::Base32Fc,
            ConfigId::Zonl32Fc,
            ConfigId::Zonl64Fc,
            ConfigId::Zonl64Db,
            ConfigId::Zonl48Db,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            ConfigId::Base32Fc => "base32fc",
            ConfigId::Zonl32Fc => "zonl32fc",
            ConfigId::Zonl64Fc => "zonl64fc",
            ConfigId::Zonl64Db => "zonl64db",
            ConfigId::Zonl48Db => "zonl48db",
        }
    }

    pub fn from_name(s: &str) -> Option<ConfigId> {
        ConfigId::all().into_iter().find(|c| c.name() == s)
    }

    pub fn cluster_config(&self) -> ClusterConfig {
        let (topology, tcdm_bytes, zonl) = match self {
            ConfigId::Base32Fc => {
                (Topology::Fc { banks: 32 }, 128 * 1024, false)
            }
            ConfigId::Zonl32Fc => {
                (Topology::Fc { banks: 32 }, 128 * 1024, true)
            }
            ConfigId::Zonl64Fc => {
                (Topology::Fc { banks: 64 }, 128 * 1024, true)
            }
            ConfigId::Zonl64Db => {
                (Topology::Dobu { banks_per_hyper: 32 }, 128 * 1024, true)
            }
            ConfigId::Zonl48Db => {
                (Topology::Dobu { banks_per_hyper: 24 }, 96 * 1024, true)
            }
        };
        ClusterConfig {
            id: *self,
            n_compute: 8,
            topology,
            tcdm_bytes,
            zonl,
            core: if zonl {
                CoreConfig::zonl()
            } else {
                CoreConfig::baseline()
            },
            dma_queue: 4,
            main_mem_bytes: 2 << 20,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    pub id: ConfigId,
    /// Compute cores (the DM core is additional).
    pub n_compute: usize,
    pub topology: Topology,
    pub tcdm_bytes: usize,
    /// Zero-overhead loop nests available?
    pub zonl: bool,
    pub core: CoreConfig,
    pub dma_queue: usize,
    pub main_mem_bytes: usize,
}

impl ClusterConfig {
    /// Total request ports on the core side of the interconnect:
    /// (4 SSR + 1 LSU) per compute core, plus a full 5-port slot for
    /// the DM core (its SSR ports stay idle; its LSU sits at the
    /// slot's last port, matching the cluster's `base_port = core*5`
    /// numbering).
    pub fn n_ports(&self) -> usize {
        (self.n_compute + 1) * 5
    }

    /// Custom core parameters (used by ablation studies).
    pub fn with_core(mut self, seq: SeqConfig, fpu: FpuConfig) -> Self {
        self.core.seq = seq;
        self.core.fpu = fpu;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_configs_match_table1() {
        assert_eq!(ConfigId::all().len(), 5);
        let base = ConfigId::Base32Fc.cluster_config();
        assert_eq!(base.topology.total_banks(), 32);
        assert_eq!(base.tcdm_bytes, 128 * 1024);
        assert!(!base.zonl);
        let z48 = ConfigId::Zonl48Db.cluster_config();
        assert_eq!(z48.topology.total_banks(), 48);
        assert_eq!(z48.tcdm_bytes, 96 * 1024);
        assert_eq!(z48.topology.hyperbanks(), 2);
        assert!(z48.zonl);
    }

    #[test]
    fn names_roundtrip() {
        for id in ConfigId::all() {
            assert_eq!(ConfigId::from_name(id.name()), Some(id));
        }
        assert_eq!(ConfigId::from_name("nope"), None);
    }

    #[test]
    fn zonl_cores_get_nested_sequencer() {
        let z = ConfigId::Zonl64Db.cluster_config();
        assert!(z.core.seq.max_nest_depth > 1);
        assert!(!z.core.seq.block_offload_during_loop);
        let b = ConfigId::Base32Fc.cluster_config();
        assert_eq!(b.core.seq.max_nest_depth, 1);
        assert!(b.core.seq.block_offload_during_loop);
    }
}
