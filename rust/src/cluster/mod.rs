//! Cluster composition and the cycle-stepped simulation loop.
//!
//! One `Cluster` owns 8 compute Snitch cores + 1 DM core (with the DMA
//! engine), the multi-banked TCDM behind its interconnect, and main
//! memory.  `step()` advances the whole machine one cycle in four
//! phases:
//!
//! 1. FP subsystems tick (writebacks, sequencer → FPU issue) — uses
//!    the FIFO state left by the previous cycle's memory phase, giving
//!    the 1-cycle TCDM load-use latency.
//! 2. Barrier release, then frontends execute one instruction each.
//! 3. Request collection: SSR streamers, LSUs, and the DMA beat.
//! 4. Interconnect arbitration + commit: grants move data, losers
//!    retry next cycle (counted as conflicts).

pub mod config;
pub mod perf;

pub use config::{ClusterConfig, ConfigId};
pub use perf::ClusterPerf;

use std::sync::Arc;

use crate::core::snitch::CoreRequest;
use crate::core::Core;
use crate::dma::Dma;
use crate::isa::Program;
use crate::mem::{
    Interconnect, MainMemory, PortRequest, Tcdm,
};
use crate::profile::{trace, FpEvent, FrontPhase, StallClass, TraceBuf};
use crate::ssr::SsrMode;

/// Which unit of a core issued a request (for grant routing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Owner {
    Ssr { core: u8, stream: u8 },
    Lsu { core: u8 },
}

pub struct Cluster {
    pub cfg: ClusterConfig,
    /// `cores[0..n_compute]` are compute cores; the last is the DM core.
    pub cores: Vec<Core>,
    pub tcdm: Tcdm,
    pub mem: MainMemory,
    pub xbar: Interconnect,
    pub dma: Dma,
    pub cycle: u64,
    pub barriers_completed: u64,
    /// Cycle of the first barrier release (compute-window start).
    pub first_barrier_cycle: u64,
    /// Cycle of the most recent barrier release (compute-window end).
    pub last_barrier_cycle: u64,
    /// Optional StallScope Chrome-trace collector. The per-cycle
    /// classifier always runs (it fills the `CorePerf::stalls`
    /// buckets); attaching a buffer additionally records per-core
    /// stall spans, a DMA track, and barrier markers.
    pub trace: Option<Box<TraceBuf>>,
    // reusable per-cycle scratch
    reqs: Vec<PortRequest>,
    owners: Vec<Owner>,
    grants: Vec<bool>,
    rdata: Vec<u64>,
    /// Memoized region-safety verdict for the DM core's program
    /// (programs are immutable once a cluster is built).
    dm_region_safe: Option<bool>,
}

/// Map one core's FP event to its StallScope bucket. Shared by the
/// per-cycle classifier ([`Cluster::attribute_cycle`]) and the
/// fast-forward region step, so the two paths cannot drift.
fn classify(
    ev: FpEvent,
    ci: usize,
    dm: usize,
    c: &Core,
    now: u64,
    noc_grant: bool,
    dma_busy: bool,
) -> StallClass {
    match ev {
        FpEvent::Issued => StallClass::Useful,
        FpEvent::RawHazard | FpEvent::FpuFull => StallClass::RawHazard,
        FpEvent::SsrEmpty | FpEvent::WFifoFull => {
            if c.ssr_denied_at(now) {
                StallClass::BankConflict
            } else {
                StallClass::SsrOperandWait
            }
        }
        FpEvent::NoInstr(phase) => match phase {
            FrontPhase::Drain => StallClass::Drain,
            FrontPhase::Barrier => {
                if dma_busy {
                    if noc_grant {
                        StallClass::DmaWait
                    } else {
                        StallClass::NocGated
                    }
                } else {
                    StallClass::Barrier
                }
            }
            FrontPhase::Lsu => {
                if c.lsu_denied_at(now) {
                    StallClass::BankConflict
                } else {
                    StallClass::ControlOverhead
                }
            }
            FrontPhase::Running => {
                // The DM core spinning on `dmstat` while the engine
                // moves data is waiting on the DMA, not doing control
                // work.
                if ci == dm && dma_busy {
                    if noc_grant {
                        StallClass::DmaWait
                    } else {
                        StallClass::NocGated
                    }
                } else {
                    StallClass::ControlOverhead
                }
            }
        },
    }
}

/// A DM-core program is *region-safe* when it can never touch the FP
/// subsystem or the SSR streamers. The scan itself lives in the
/// ProofScope analyzer ([`crate::verify::dm_program_region_safe`]) so
/// fast-forwarding and the static stall verdicts rest on one
/// soundness story (DESIGN.md §13); this is the cluster's memoization
/// point for it.
fn dm_prog_region_safe(p: &Program) -> bool {
    crate::verify::dm_program_region_safe(p)
}

impl Cluster {
    /// Build a cluster; `programs` holds one program per compute core
    /// plus the DM core's program last (n_compute + 1 total).
    pub fn new(cfg: ClusterConfig, programs: Vec<Program>) -> Self {
        let shared: Vec<Arc<Program>> =
            programs.into_iter().map(Arc::new).collect();
        Self::from_shared(cfg, &shared)
    }

    /// Build a cluster from shared (memoized) programs without cloning
    /// the instruction streams — the batched `GemmService` run path.
    pub fn from_shared(cfg: ClusterConfig, programs: &[Arc<Program>]) -> Self {
        assert_eq!(
            programs.len(),
            cfg.n_compute + 1,
            "need one program per compute core plus the DM core"
        );
        let cores = programs
            .iter()
            .enumerate()
            .map(|(id, p)| Core::new(id, cfg.core, Arc::clone(p)))
            .collect();
        let cap = cfg.n_ports();
        Self {
            cores,
            tcdm: Tcdm::new(cfg.topology, cfg.tcdm_bytes),
            mem: MainMemory::new(cfg.main_mem_bytes),
            xbar: Interconnect::new(cfg.topology.total_banks(), cfg.n_ports()),
            dma: Dma::new(cfg.dma_queue),
            cycle: 0,
            barriers_completed: 0,
            first_barrier_cycle: 0,
            last_barrier_cycle: 0,
            trace: None,
            reqs: Vec::with_capacity(cap),
            owners: Vec::with_capacity(cap),
            grants: vec![false; cap],
            rdata: vec![0u64; cap],
            dm_region_safe: None,
            cfg,
        }
    }

    pub fn dm_core_id(&self) -> usize {
        self.cfg.n_compute
    }

    pub fn all_halted(&self) -> bool {
        self.cores.iter().all(|c| c.halted())
    }

    /// Advance one cycle.
    pub fn step(&mut self) {
        self.step_gated(true);
    }

    /// Advance one cycle with the fabric NoC's verdict for this
    /// cluster's DMA branch: when `noc_grant` is false the branch is
    /// held off the shared links this cycle — no beat reaches the
    /// TCDM mux, and a pending beat counts a stall. Single-cluster
    /// runs always pass `true` (a private link is never contended).
    pub fn step_gated(&mut self, noc_grant: bool) {
        let now = self.cycle;

        // ---- phase 1: FP subsystems --------------------------------
        for c in self.cores.iter_mut() {
            if !c.halted() {
                c.fp_tick(now);
            }
        }

        // ---- phase 2a: barrier release ------------------------------
        let all_at_barrier = self
            .cores
            .iter()
            .all(|c| c.halted() || c.at_barrier());
        if all_at_barrier && !self.all_halted() {
            for c in self.cores.iter_mut() {
                if c.at_barrier() {
                    c.barrier_release();
                }
            }
            self.barriers_completed += 1;
            if self.barriers_completed == 1 {
                self.first_barrier_cycle = now;
            }
            self.last_barrier_cycle = now;
            if let Some(t) = self.trace.as_mut() {
                t.instant("barrier", now);
            }
        }

        // ---- phase 2b: frontends ------------------------------------
        let dma_ready = self.dma.can_push();
        let dma_inflight = self.dma.in_flight();
        for c in self.cores.iter_mut() {
            if c.try_dmstat(dma_inflight) {
                continue;
            }
            match c.frontend_tick(now, dma_ready) {
                CoreRequest::None => {}
                CoreRequest::DmaPush(desc) => {
                    let ok = self.dma.push(desc);
                    debug_assert!(ok, "frontend checked dma_ready");
                }
            }
        }

        // ---- phase 3: request collection ----------------------------
        self.reqs.clear();
        self.owners.clear();
        for (ci, c) in self.cores.iter().enumerate() {
            let base_port = (ci * 5) as u16;
            for s in 0..4u8 {
                let str_ = &c.ssrs[s as usize];
                match str_.mode {
                    // Read prefetch is gated on the SSR-enable CSR:
                    // kernels arm stream bases in the shadow of the
                    // previous pass / prologue DMA, and the generator
                    // must not fetch until the buffers are valid.
                    SsrMode::Read if c.ssr_enable => {
                        if let Some(addr) = str_.read_request() {
                            self.reqs.push(PortRequest {
                                port: base_port + s as u16,
                                addr,
                                write: false,
                                data: 0,
                            });
                            self.owners.push(Owner::Ssr {
                                core: ci as u8,
                                stream: s,
                            });
                        }
                    }
                    SsrMode::Write => {
                        if let Some((addr, v)) = str_.write_request() {
                            self.reqs.push(PortRequest {
                                port: base_port + s as u16,
                                addr,
                                write: true,
                                data: v.to_bits(),
                            });
                            self.owners.push(Owner::Ssr {
                                core: ci as u8,
                                stream: s,
                            });
                        }
                    }
                    SsrMode::Read | SsrMode::Idle => {}
                }
            }
            if let Some((addr, write, data)) = c.lsu_request() {
                debug_assert!(
                    self.tcdm.contains(addr),
                    "LSU outside TCDM unsupported: {addr:#x}"
                );
                self.reqs.push(PortRequest {
                    port: base_port + 4,
                    addr,
                    write,
                    data,
                });
                self.owners.push(Owner::Lsu { core: ci as u8 });
            }
        }

        let beat = if noc_grant {
            self.dma.next_beat(&self.mem)
        } else {
            if self.dma.busy() {
                self.dma.stall_cycles += 1;
                self.dma.noc_gated_cycles += 1;
            }
            None
        };
        let dma_busy = self.dma.busy();
        if dma_busy {
            self.dma.busy_cycles += 1;
        }

        // ---- phase 4: arbitration + commit --------------------------
        let n = self.reqs.len();
        self.grants[..n].fill(false);
        let outcome = self.xbar.arbitrate(
            &mut self.tcdm,
            &self.reqs[..n],
            &mut self.grants[..n],
            &mut self.rdata[..n],
            beat.as_ref(),
        );
        if let Some(b) = &beat {
            if outcome.dma_granted {
                self.dma.beat_granted(b, &outcome.dma_read, &mut self.mem);
            } else {
                self.dma.beat_denied();
            }
        }
        for i in 0..n {
            let owner = self.owners[i];
            match owner {
                Owner::Ssr { core, stream } => {
                    let s = &mut self.cores[core as usize].ssrs
                        [stream as usize];
                    s.total_requests += 1;
                    if self.grants[i] {
                        if self.reqs[i].write {
                            s.write_granted();
                        } else {
                            s.read_granted(f64::from_bits(self.rdata[i]));
                        }
                    } else {
                        s.conflicts += 1;
                        s.note_denied(now);
                    }
                }
                Owner::Lsu { core } => {
                    if self.grants[i] {
                        self.cores[core as usize]
                            .lsu_granted(self.rdata[i]);
                    } else {
                        self.cores[core as usize].note_lsu_denied(now);
                    }
                }
            }
        }

        // ---- phase 5: StallScope attribution -------------------------
        self.attribute_cycle(now, noc_grant, dma_busy);

        self.cycle += 1;
    }

    /// Attribute this cycle to exactly one stall class per active
    /// core (the StallScope classifier). Runs after arbitration so
    /// TCDM denials of the same cycle can explain operand waits;
    /// every core that ticked this cycle gets exactly one bucket
    /// incremented, which is what makes the conservation invariant
    /// `stalls.sum() == cycles` hold per core.
    fn attribute_cycle(&mut self, now: u64, noc_grant: bool, dma_busy: bool) {
        let dm = self.dm_core_id();
        // Split borrow: cores and the trace buffer are disjoint
        // fields, so the per-cycle `Option::take`/put shuffle of the
        // trace box is unnecessary.
        let Self { cores, trace, .. } = self;
        let mut trace_buf = trace.as_deref_mut();
        for (ci, c) in cores.iter_mut().enumerate() {
            let ev = match c.take_fp_event() {
                Some(ev) => ev,
                None => {
                    // Halted before this cycle: never ticked. Mark the
                    // track idle so the core's last open span is
                    // flushed at its true end instead of stretching to
                    // the cluster's halt cycle.
                    if let Some(t) = trace_buf.as_deref_mut() {
                        t.record(ci, now, trace::CODE_IDLE);
                    }
                    continue;
                }
            };
            let class = classify(ev, ci, dm, c, now, noc_grant, dma_busy);
            c.perf.stalls[class as usize] += 1;
            if let Some(t) = trace_buf.as_deref_mut() {
                if t.record(ci, now, class as u8) {
                    t.counter(ci, now, c.seq.occupancy() as u64);
                }
            }
        }
        if let Some(t) = trace_buf {
            let code = if !dma_busy {
                trace::CODE_IDLE
            } else if noc_grant {
                trace::CODE_DMA_BUSY
            } else {
                trace::CODE_DMA_GATED
            };
            t.record(cores.len(), now, code);
        }
    }

    /// Run to completion (all cores halted). Returns total cycles.
    pub fn run(&mut self, max_cycles: u64) -> anyhow::Result<u64> {
        while !self.all_halted() {
            self.step();
            if self.cycle >= max_cycles {
                anyhow::bail!(
                    "cluster exceeded {max_cycles} cycles (deadlock?); \
                     pcs={:?}",
                    self.cores.iter().map(|c| c.halted()).collect::<Vec<_>>()
                );
            }
        }
        Ok(self.cycle)
    }

    // ============================================================
    // FastPath: quiescent-region specialized stepping
    // ============================================================

    /// Do the fast-forward preconditions hold at this cycle boundary?
    ///
    /// A *quiescent region* needs: no trace collector attached (the
    /// Chrome trace wants per-cycle spans); every compute core halted
    /// or parked at the barrier with quiescent streamers (no TCDM
    /// requests now — and, as [`Core::mem_quiescent`] argues, for the
    /// whole region); and a DM core that is neither halted nor at the
    /// barrier, running a region-safe program. Parked compute cores
    /// cannot change state while the DM core stays away from the
    /// barrier (release needs *all* cores arrived), so this scan
    /// holds on every subsequent cycle until the DM core halts or
    /// arrives — the region exit condition checked in
    /// [`Cluster::step_fast`].
    fn fast_region_ok(&mut self) -> bool {
        if self.trace.is_some() {
            return false;
        }
        let dm = self.dm_core_id();
        {
            let c = &self.cores[dm];
            if c.halted() || c.at_barrier() {
                return false;
            }
        }
        for c in &self.cores[..dm] {
            if !(c.halted() || c.at_barrier()) || !c.mem_quiescent() {
                return false;
            }
        }
        let safe = match self.dm_region_safe {
            Some(s) => s,
            None => {
                let s = dm_prog_region_safe(self.cores[dm].program());
                self.dm_region_safe = Some(s);
                s
            }
        };
        if !safe {
            return false;
        }
        // Region-safe programs can never arm a streamer.
        debug_assert!(self.cores[dm].mem_quiescent());
        true
    }

    /// One specialized cycle inside a quiescent region
    /// ([`Cluster::fast_region_ok`]): the DM core, the DMA engine,
    /// and the interconnect run the *real* per-cycle machinery (the
    /// interconnect must arbitrate even DMA-only cycles — its
    /// round-robin rotors and stats advance), while each parked
    /// compute core gets the closed form of its tick: `fp_tick` on an
    /// empty sequencer counts `cycles`/`fpu_idle_no_instr`, the
    /// `BarrierWait` frontend counts `barrier_cycles`, and the
    /// classifier books exactly one Barrier/DmaWait/NocGated stall.
    /// Halted cores are untouched, exactly as in the naive step.
    fn step_region(&mut self, noc_grant: bool) {
        let now = self.cycle;
        let dm = self.dm_core_id();

        // Phases 1 + 2b for the DM core. Phase 2a cannot fire: the
        // DM core is not at the barrier, so `all_at_barrier` is false.
        self.cores[dm].fp_tick(now);
        let dma_ready = self.dma.can_push();
        let dma_inflight = self.dma.in_flight();
        {
            let c = &mut self.cores[dm];
            if !c.try_dmstat(dma_inflight) {
                match c.frontend_tick(now, dma_ready) {
                    CoreRequest::None => {}
                    CoreRequest::DmaPush(desc) => {
                        let ok = self.dma.push(desc);
                        debug_assert!(ok, "frontend checked dma_ready");
                    }
                }
            }
        }

        // Phase 3: the DM core's LSU is the only possible TCDM
        // requester (compute cores are quiescent, DM streams idle).
        self.reqs.clear();
        if let Some((addr, write, data)) = self.cores[dm].lsu_request() {
            debug_assert!(
                self.tcdm.contains(addr),
                "LSU outside TCDM unsupported: {addr:#x}"
            );
            self.reqs.push(PortRequest {
                port: (dm * 5 + 4) as u16,
                addr,
                write,
                data,
            });
        }
        let beat = if noc_grant {
            self.dma.next_beat(&self.mem)
        } else {
            if self.dma.busy() {
                self.dma.stall_cycles += 1;
                self.dma.noc_gated_cycles += 1;
            }
            None
        };
        let dma_busy = self.dma.busy();
        if dma_busy {
            self.dma.busy_cycles += 1;
        }

        // Phase 4: arbitration + commit.
        let n = self.reqs.len();
        self.grants[..n].fill(false);
        let outcome = self.xbar.arbitrate(
            &mut self.tcdm,
            &self.reqs[..n],
            &mut self.grants[..n],
            &mut self.rdata[..n],
            beat.as_ref(),
        );
        if let Some(b) = &beat {
            if outcome.dma_granted {
                self.dma.beat_granted(b, &outcome.dma_read, &mut self.mem);
            } else {
                self.dma.beat_denied();
            }
        }
        if n > 0 {
            if self.grants[0] {
                self.cores[dm].lsu_granted(self.rdata[0]);
            } else {
                self.cores[dm].note_lsu_denied(now);
            }
        }

        // Phase 5: attribution. Parked compute cores all land in the
        // same bucket this cycle; the DM core goes through the shared
        // classifier on its real event.
        let parked = if dma_busy {
            if noc_grant {
                StallClass::DmaWait
            } else {
                StallClass::NocGated
            }
        } else {
            StallClass::Barrier
        };
        for c in self.cores[..dm].iter_mut() {
            if c.halted() {
                continue;
            }
            c.perf.cycles += 1;
            c.perf.fpu_idle_no_instr += 1;
            c.perf.barrier_cycles += 1;
            c.perf.stalls[parked as usize] += 1;
        }
        let c = &mut self.cores[dm];
        if let Some(ev) = c.take_fp_event() {
            let class = classify(ev, dm, dm, c, now, noc_grant, dma_busy);
            c.perf.stalls[class as usize] += 1;
        }

        self.cycle += 1;
    }

    /// One cycle, choosing the specialized region step when its
    /// preconditions hold. `region` caches the precondition scan
    /// across consecutive cycles: inside a region only the DM core
    /// can change the machine shape, so after a region cycle the full
    /// scan reduces to the DM exit check.
    pub(crate) fn step_fast(&mut self, region: &mut bool, noc_grant: bool) {
        if !*region {
            if !self.fast_region_ok() {
                self.step_gated(noc_grant);
                return;
            }
            *region = true;
        }
        self.step_region(noc_grant);
        let c = &self.cores[self.dm_core_id()];
        if c.halted() || c.at_barrier() {
            *region = false;
        }
    }

    /// [`Cluster::run`] through the FastPath stepper: bit-identical
    /// machine evolution (C, cycles, every counter, the full stall
    /// profile), reached by specializing provably quiescent DMA-phase
    /// regions instead of ticking all nine cores and scanning all 45
    /// ports every cycle.
    pub fn run_fast(&mut self, max_cycles: u64) -> anyhow::Result<u64> {
        let mut region = false;
        while !self.all_halted() {
            self.step_fast(&mut region, true);
            if self.cycle >= max_cycles {
                anyhow::bail!(
                    "cluster exceeded {max_cycles} cycles (deadlock?); \
                     pcs={:?}",
                    self.cores.iter().map(|c| c.halted()).collect::<Vec<_>>()
                );
            }
        }
        Ok(self.cycle)
    }

    /// Fabric free-run helper: advance with the NoC grant held open
    /// while this cluster's DMA branch is idle — an idle branch never
    /// competes for the shared links, so the fabric arbiter grants it
    /// unconditionally and uncounted. Pauses at the first cycle
    /// boundary where the engine has work queued (the cycle *after*
    /// the `dmcpy` push, matching the per-cycle fabric's phase-start
    /// busy check), or when the cluster halts or reaches `max_cycles`.
    pub(crate) fn advance_free(&mut self, max_cycles: u64) {
        let mut region = false;
        while !self.all_halted()
            && !self.dma.busy()
            && self.cycle < max_cycles
        {
            self.step_fast(&mut region, true);
        }
    }

    /// Fabric uncontested-batch helper: advance to absolute cycle
    /// `until` with the NoC grant held open, returning how many
    /// stepped cycles *began* with the DMA branch busy — the fabric
    /// books one NoC grant for each, exactly as its per-cycle arbiter
    /// would. Stops early when the cluster halts.
    pub(crate) fn advance_granted(&mut self, until: u64) -> u64 {
        let mut region = false;
        let mut granted = 0;
        while !self.all_halted() && self.cycle < until {
            if self.dma.busy() {
                granted += 1;
            }
            self.step_fast(&mut region, true);
        }
        granted
    }

    /// Aggregate performance summary.
    pub fn perf(&self) -> ClusterPerf {
        ClusterPerf::collect(self)
    }

    /// Attach a StallScope Chrome-trace collector: one track per core
    /// plus the DMA track, on a timeline offset by `t0` (so multiple
    /// layers / clusters stitch onto one trace).
    pub fn attach_trace(&mut self, pid: u32, t0: u64) {
        self.trace = Some(Box::new(TraceBuf::new(
            pid,
            self.cores.len() + 1,
            t0,
        )));
    }

    /// Detach the trace collector, closing all open spans at the
    /// current cycle.
    pub fn take_trace(&mut self) -> Option<Box<TraceBuf>> {
        let mut t = self.trace.take();
        if let Some(b) = t.as_mut() {
            b.finish(self.cycle);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::Asm;
    use crate::isa::{reg, Instr, SsrField};
    use crate::mem::{MAIN_MEM_BASE, TCDM_BASE};

    fn empty_prog() -> Program {
        let mut a = Asm::new();
        a.push(Instr::Ecall);
        a.assemble()
    }

    fn barrier_then_halt() -> Program {
        let mut a = Asm::new();
        a.push(Instr::Barrier);
        a.push(Instr::Ecall);
        a.assemble()
    }

    #[test]
    fn trivial_programs_halt() {
        let cfg = ConfigId::Base32Fc.cluster_config();
        let progs = (0..9).map(|_| empty_prog()).collect();
        let mut cl = Cluster::new(cfg, progs);
        let cycles = cl.run(1000).unwrap();
        assert!(cycles <= 3, "halt within a couple of cycles: {cycles}");
    }

    #[test]
    fn barrier_synchronizes_all_cores() {
        let cfg = ConfigId::Base32Fc.cluster_config();
        // Core 0 spins before its barrier; everyone else waits.
        let mut slow = Asm::new();
        slow.li(reg::T0, 50);
        let top = slow.label();
        slow.bind(top);
        slow.push(Instr::Addi { rd: reg::T0, rs1: reg::T0, imm: -1 });
        slow.bne(reg::T0, 0, top);
        slow.push(Instr::Barrier);
        slow.push(Instr::Ecall);
        let mut progs = vec![slow.assemble()];
        for _ in 1..9 {
            progs.push(barrier_then_halt());
        }
        let mut cl = Cluster::new(cfg, progs);
        let cycles = cl.run(10_000).unwrap();
        assert!(cycles > 100, "must wait for the slow core: {cycles}");
        assert_eq!(cl.barriers_completed, 1);
    }

    #[test]
    fn single_barrier_window_excludes_prologue() {
        // One barrier then work: the compute window must run from the
        // barrier release to halt, not from cycle 0 (the old fallback
        // folded the pre-barrier prologue into the denominator).
        let cfg = ConfigId::Base32Fc.cluster_config();
        // Core 0: spin 60 cycles (the "prologue"), barrier, spin 40
        // more, halt. Everyone else: barrier, halt.
        let spin = |a: &mut Asm, n: u32| {
            a.li(reg::T0, n);
            let top = a.label();
            a.bind(top);
            a.push(Instr::Addi { rd: reg::T0, rs1: reg::T0, imm: -1 });
            a.bne(reg::T0, 0, top);
        };
        let mut slow = Asm::new();
        spin(&mut slow, 60);
        slow.push(Instr::Barrier);
        spin(&mut slow, 40);
        slow.push(Instr::Ecall);
        let mut progs = vec![slow.assemble()];
        for _ in 1..9 {
            progs.push(barrier_then_halt());
        }
        let mut cl = Cluster::new(cfg, progs);
        cl.run(100_000).unwrap();
        assert_eq!(cl.barriers_completed, 1);
        let perf = cl.perf();
        assert_eq!(
            perf.window_cycles,
            cl.cycle - cl.first_barrier_cycle,
            "window = first barrier .. halt"
        );
        assert!(
            perf.window_cycles < perf.cycles,
            "prologue must be excluded: window {} vs cycles {}",
            perf.window_cycles,
            perf.cycles
        );
    }

    #[test]
    fn gated_step_defers_dma_beats() {
        // A cluster stepped with the NoC grant withheld must not move
        // any DMA data; granting it resumes bit-identical transfers.
        let cfg = ConfigId::Base32Fc.cluster_config();
        let mut dm = Asm::new();
        dm.li(reg::A0, MAIN_MEM_BASE);
        dm.push(Instr::Dmsrc { rs1: reg::A0 });
        dm.li(reg::A1, TCDM_BASE);
        dm.push(Instr::Dmdst { rs1: reg::A1 });
        dm.li(reg::A2, 16 * 8);
        dm.push(Instr::Dmcpy { rd: reg::T0, rs1: reg::A2 });
        let poll = dm.label();
        dm.bind(poll);
        dm.push(Instr::Dmstat { rd: reg::T1 });
        dm.bne(reg::T1, 0, poll);
        dm.push(Instr::Ecall);
        let mut progs: Vec<Program> =
            (0..8).map(|_| empty_prog()).collect();
        progs.push(dm.assemble());
        let mut cl = Cluster::new(cfg, progs);
        let xs: Vec<f64> = (0..16).map(|i| i as f64 + 0.5).collect();
        cl.mem.write_slice_f64(MAIN_MEM_BASE, &xs);
        // Hold the NoC closed: no bytes may move.
        for _ in 0..50 {
            cl.step_gated(false);
        }
        assert_eq!(cl.dma.bytes_moved, 0, "gated branch moved data");
        assert!(cl.dma.stall_cycles > 0, "pending beats count stalls");
        // Open it: transfer completes normally.
        while !cl.all_halted() {
            cl.step_gated(true);
            assert!(cl.cycle < 10_000);
        }
        assert_eq!(cl.dma.bytes_moved, 16 * 8);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(cl.tcdm.read_f64(TCDM_BASE + (i as u32) * 8), x);
        }
    }

    #[test]
    fn stall_attribution_conserves_every_cycle() {
        // Every active core-cycle lands in exactly one StallScope
        // bucket — on a run mixing spins, barriers, and DMA waits.
        let cfg = ConfigId::Base32Fc.cluster_config();
        let mut slow = Asm::new();
        slow.li(reg::T0, 50);
        let top = slow.label();
        slow.bind(top);
        slow.push(Instr::Addi { rd: reg::T0, rs1: reg::T0, imm: -1 });
        slow.bne(reg::T0, 0, top);
        slow.push(Instr::Barrier);
        slow.push(Instr::Ecall);
        let mut progs = vec![slow.assemble()];
        for _ in 1..8 {
            progs.push(barrier_then_halt());
        }
        let mut dm = Asm::new();
        dm.li(reg::A0, MAIN_MEM_BASE);
        dm.push(Instr::Dmsrc { rs1: reg::A0 });
        dm.li(reg::A1, TCDM_BASE);
        dm.push(Instr::Dmdst { rs1: reg::A1 });
        dm.li(reg::A2, 32 * 8);
        dm.push(Instr::Dmcpy { rd: reg::T0, rs1: reg::A2 });
        let poll = dm.label();
        dm.bind(poll);
        dm.push(Instr::Dmstat { rd: reg::T1 });
        dm.bne(reg::T1, 0, poll);
        dm.push(Instr::Barrier);
        dm.push(Instr::Ecall);
        progs.push(dm.assemble());
        let mut cl = Cluster::new(cfg, progs);
        cl.attach_trace(0, 0);
        cl.run(100_000).unwrap();
        let perf = cl.perf();
        perf.stalls.check_conservation().unwrap();
        // The slow core burned ControlOverhead; waiters sat in
        // Barrier/DmaWait; the DM core saw DmaWait while polling.
        let totals = perf.stalls.totals();
        assert!(totals[StallClass::ControlOverhead as usize] > 0);
        assert!(
            totals[StallClass::Barrier as usize]
                + totals[StallClass::DmaWait as usize]
                > 0
        );
        let dm_core = perf.stalls.dm_cores()[0];
        assert!(
            dm_core.counts[StallClass::DmaWait as usize] > 0,
            "DM core polling a busy engine must count DmaWait"
        );
        // The trace collector saw the same run.
        let t = cl.take_trace().unwrap();
        assert!(!t.events.is_empty());
    }

    #[test]
    fn dma_roundtrip_through_dm_core() {
        let cfg = ConfigId::Base32Fc.cluster_config();
        // DM core: copy 64 words in, then out to a second region, wait,
        // halt. Compute cores: just halt.
        let mut dm = Asm::new();
        dm.li(reg::A0, MAIN_MEM_BASE);
        dm.push(Instr::Dmsrc { rs1: reg::A0 });
        dm.li(reg::A1, TCDM_BASE);
        dm.push(Instr::Dmdst { rs1: reg::A1 });
        dm.li(reg::A2, 64 * 8);
        dm.push(Instr::Dmcpy { rd: reg::T0, rs1: reg::A2 });
        // poll until idle
        let poll1 = dm.label();
        dm.bind(poll1);
        dm.push(Instr::Dmstat { rd: reg::T1 });
        dm.bne(reg::T1, 0, poll1);
        // copy back out
        dm.push(Instr::Dmsrc { rs1: reg::A1 });
        dm.li(reg::A3, MAIN_MEM_BASE + 0x10000);
        dm.push(Instr::Dmdst { rs1: reg::A3 });
        dm.push(Instr::Dmcpy { rd: reg::T0, rs1: reg::A2 });
        let poll2 = dm.label();
        dm.bind(poll2);
        dm.push(Instr::Dmstat { rd: reg::T1 });
        dm.bne(reg::T1, 0, poll2);
        dm.push(Instr::Ecall);

        let mut progs: Vec<Program> = (0..8).map(|_| empty_prog()).collect();
        progs.push(dm.assemble());
        let mut cl = Cluster::new(cfg, progs);
        let xs: Vec<f64> = (0..64).map(|i| (i * 3) as f64).collect();
        cl.mem.write_slice_f64(MAIN_MEM_BASE, &xs);
        cl.run(100_000).unwrap();
        assert_eq!(cl.mem.read_vec_f64(MAIN_MEM_BASE + 0x10000, 64), xs);
        assert_eq!(cl.dma.bytes_moved, 2 * 64 * 8);
    }

    #[test]
    fn ssr_stream_feeds_fpu() {
        // Compute core 0: stream 4 values from TCDM through ft0 and
        // ft1, fmadd-accumulate into fa0, fsd the result.
        let cfg = ConfigId::Zonl48Db.cluster_config();
        let mut a = Asm::new();
        // ssr0: read 4 elems at TCDM_BASE stride 8
        a.li(reg::T0, 3);
        a.push(Instr::SsrCfgW {
            value: reg::T0,
            ssr: 0,
            field: SsrField::Bound(0),
        });
        a.li(reg::T0, 8);
        a.push(Instr::SsrCfgW {
            value: reg::T0,
            ssr: 0,
            field: SsrField::Stride(0),
        });
        a.li(reg::T0, TCDM_BASE);
        a.push(Instr::SsrCfgW {
            value: reg::T0,
            ssr: 0,
            field: SsrField::ReadBase(0),
        });
        // ssr1: read 4 elems at TCDM_BASE + 0x100
        a.li(reg::T0, 3);
        a.push(Instr::SsrCfgW {
            value: reg::T0,
            ssr: 1,
            field: SsrField::Bound(0),
        });
        a.li(reg::T0, 8);
        a.push(Instr::SsrCfgW {
            value: reg::T0,
            ssr: 1,
            field: SsrField::Stride(0),
        });
        a.li(reg::T0, TCDM_BASE + 0x100);
        a.push(Instr::SsrCfgW {
            value: reg::T0,
            ssr: 1,
            field: SsrField::ReadBase(0),
        });
        // zero fa0, enable ssr, 4x fmadd, disable, store
        a.li(reg::T1, 0);
        a.push(Instr::FcvtDW { frd: reg::FA0, rs1: reg::T1 });
        a.push(Instr::Csrrsi { csr: crate::isa::csr::SSR_ENABLE, imm: 1 });
        for _ in 0..4 {
            a.push(Instr::FmaddD {
                frd: reg::FA0,
                frs1: reg::FT0,
                frs2: reg::FT1,
                frs3: reg::FA0,
            });
        }
        a.push(Instr::Csrrci { csr: crate::isa::csr::SSR_ENABLE, imm: 1 });
        a.li(reg::T2, TCDM_BASE + 0x200);
        a.push(Instr::Fsd { frs2: reg::FA0, rs1: reg::T2, imm: 0 });
        a.push(Instr::Ecall);

        let mut progs = vec![a.assemble()];
        for _ in 1..9 {
            progs.push(empty_prog());
        }
        let mut cl = Cluster::new(cfg, progs);
        for i in 0..4u32 {
            cl.tcdm
                .write_f64(TCDM_BASE + i * 8, (i + 1) as f64);
            cl.tcdm
                .write_f64(TCDM_BASE + 0x100 + i * 8, 10.0);
        }
        cl.run(10_000).unwrap();
        // sum (i+1)*10 = 100
        assert_eq!(cl.tcdm.read_f64(TCDM_BASE + 0x200), 100.0);
        assert_eq!(cl.cores[0].perf.fpu_ops, 4, "4 fmadds through the FPU");
    }
}
