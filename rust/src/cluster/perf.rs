//! Aggregated cluster performance counters — the raw material for the
//! utilization metric (Fig. 5) and the event-based energy model.

use crate::profile::{CoreStalls, StallProfile};
use crate::util::stats::ratio;

use super::Cluster;

/// Snapshot of everything the experiments and the power model need.
#[derive(Clone, Debug, Default)]
pub struct ClusterPerf {
    pub cycles: u64,
    /// Compute-window length: first barrier release (phase-0 tiles
    /// ready) to last barrier release (final compute pass done). The
    /// paper's FPU-utilization methodology measures the kernel region,
    /// not the cold prologue load / epilogue store.
    pub window_cycles: u64,
    /// Per-compute-core FPU op counts.
    pub fpu_ops_per_core: Vec<u64>,
    pub fpu_ops_total: u64,
    /// Mean FPU utilization over the compute cores.
    pub utilization: f64,
    // stall taxonomy (summed over compute cores)
    pub stall_ssr_empty: u64,
    pub stall_wfifo: u64,
    pub stall_raw: u64,
    pub stall_fpu_full: u64,
    pub fpu_idle_no_instr: u64,
    pub offload_stalls: u64,
    pub branch_bubbles: u64,
    pub barrier_cycles: u64,
    pub lsu_stalls: u64,
    // activity events (energy model inputs)
    pub int_instrs: u64,
    pub icache_fetches: u64,
    pub rb_replays: u64,
    pub csr_instrs: u64,
    pub tcdm_core_accesses: u64,
    pub tcdm_conflicts: u64,
    pub tcdm_conflicts_dma: u64,
    pub ssr_requests: u64,
    pub ssr_conflicts: u64,
    pub dma_beats: u64,
    pub dma_bytes: u64,
    pub dma_busy_cycles: u64,
    pub dma_stall_cycles: u64,
    /// Subset of `dma_stall_cycles` gated by the fabric NoC.
    pub dma_noc_gated_cycles: u64,
    /// Cycles with at least one denied core-side TCDM request.
    pub tcdm_conflict_cycles: u64,
    pub barriers_completed: u64,
    /// StallScope attribution: per-core per-cycle stall classes over
    /// the run (measured by the cycle backend, *predicted* by the
    /// analytic backend). `stalls.utilization()` equals
    /// [`ClusterPerf::utilization`] on measured runs — `Useful`
    /// counts exactly the `fpu_ops` events over the same window.
    pub stalls: StallProfile,
}

impl ClusterPerf {
    pub fn collect(cl: &Cluster) -> Self {
        let n = cl.cfg.n_compute;
        let compute = &cl.cores[..n];
        let cycles = cl.cycle;
        let fpu_ops_per_core: Vec<u64> =
            compute.iter().map(|c| c.perf.fpu_ops).collect();
        let fpu_ops_total: u64 = fpu_ops_per_core.iter().sum();
        // All FP work happens between the first and last barrier
        // (prologue = DMA fill, epilogue = DMA drain, both FP-free).
        // With exactly one release the window runs from that barrier
        // to halt — folding the DMA prologue in (the old `cycles`
        // fallback) would underreport utilization on single-pass
        // problems. Only barrier-free runs measure the whole run.
        let window_cycles = match cl.barriers_completed {
            0 => cycles,
            1 => cycles - cl.first_barrier_cycle,
            _ => cl.last_barrier_cycle - cl.first_barrier_cycle,
        };
        let utilization = ratio(
            fpu_ops_total as f64,
            window_cycles as f64 * n as f64,
        );
        let stalls = StallProfile {
            per_core: cl
                .cores
                .iter()
                .map(|c| CoreStalls {
                    cycles: c.perf.cycles,
                    counts: c.perf.stalls,
                })
                .collect(),
            n_compute: n,
            window_cycles,
            window_core_cycles: window_cycles * n as u64,
        };
        let sum = |f: fn(&crate::core::CorePerf) -> u64| -> u64 {
            compute.iter().map(|c| f(&c.perf)).sum()
        };
        Self {
            cycles,
            window_cycles,
            fpu_ops_per_core,
            fpu_ops_total,
            utilization,
            stall_ssr_empty: sum(|p| p.stall_ssr_empty),
            stall_wfifo: sum(|p| p.stall_wfifo),
            stall_raw: sum(|p| p.stall_raw),
            stall_fpu_full: sum(|p| p.stall_fpu_full),
            fpu_idle_no_instr: sum(|p| p.fpu_idle_no_instr),
            offload_stalls: sum(|p| p.offload_stalls),
            branch_bubbles: sum(|p| p.branch_bubbles),
            barrier_cycles: sum(|p| p.barrier_cycles),
            lsu_stalls: sum(|p| p.lsu_stalls),
            int_instrs: sum(|p| p.int_instrs)
                + cl.cores[n].perf.int_instrs,
            icache_fetches: sum(|p| p.icache_fetches)
                + cl.cores[n].perf.icache_fetches,
            rb_replays: sum(|p| p.rb_replays),
            csr_instrs: sum(|p| p.csr_instrs),
            tcdm_core_accesses: cl.xbar.stats.core_grants,
            tcdm_conflicts: cl.xbar.stats.core_conflicts,
            tcdm_conflicts_dma: cl.xbar.stats.core_conflicts_dma,
            ssr_requests: cl
                .cores
                .iter()
                .flat_map(|c| c.ssrs.iter())
                .map(|s| s.total_requests)
                .sum(),
            ssr_conflicts: cl
                .cores
                .iter()
                .flat_map(|c| c.ssrs.iter())
                .map(|s| s.conflicts)
                .sum(),
            dma_beats: cl.dma.beats,
            dma_bytes: cl.dma.bytes_moved,
            dma_busy_cycles: cl.dma.busy_cycles,
            dma_stall_cycles: cl.dma.stall_cycles,
            dma_noc_gated_cycles: cl.dma.noc_gated_cycles,
            tcdm_conflict_cycles: cl.xbar.stats.conflict_cycles,
            barriers_completed: cl.barriers_completed,
            stalls,
        }
    }

    /// All retried core-side TCDM requests: bank-level round-robin
    /// losses plus DMA-superbank-mux captures (the two counters are a
    /// disjoint split; mirrors `XbarStats::core_conflicts_total`).
    pub fn conflicts_total(&self) -> u64 {
        self.tcdm_conflicts + self.tcdm_conflicts_dma
    }

    /// Fraction of cycles lost to TCDM conflicts (approximate: each
    /// conflict delays one stream element by one cycle). Guarded
    /// against empty windows — zero-cycle runs report 0, never NaN.
    pub fn conflict_rate(&self) -> f64 {
        ratio(self.ssr_conflicts as f64, self.ssr_requests as f64)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "cycles={} util={:.1}% fpu_ops={} conflicts={} ({:.2}% of SSR \
             reqs) dma_beats={} barriers={}",
            self.cycles,
            self.utilization * 100.0,
            self.fpu_ops_total,
            self.conflicts_total(),
            self.conflict_rate() * 100.0,
            self.dma_beats,
            self.barriers_completed,
        )
    }
}
