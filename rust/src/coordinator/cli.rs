//! Hand-rolled CLI (clap is unavailable offline).
//!
//! Subcommands:
//!   run       — one GEMM on one configuration, print metrics
//!   net       — a multi-layer zoo network through the DAG scheduler
//!   serve     — request-level serving simulation (open-loop arrivals,
//!               FIFO vs continuous batching, latency percentiles)
//!   profile   — StallScope: cycle-accurate per-cycle stall
//!               attribution of a zoo model, with roofline placement
//!               and optional Chrome-trace export (`--trace f.json`)
//!   lint      — ProofScope: static stall verdicts (impossible /
//!               bounded / unknown per class) for every GEMM kernel of
//!               a zoo model, differentially gated against StallScope
//!               measurements on the cycle and analytic backends
//!   sweep     — the full {8..128}^3 grid through a chosen backend
//!   calibrate — fit the analytic model vs cycle-accurate ground truth
//!   fig5      — the random-size sweep (box plots + CSV + headline)
//!   table1    — area model rows
//!   table2    — SoA comparison rows
//!   fig4      — congestion proxy
//!   ablation  — layout ablation
//!   validate  — simulator vs PJRT golden model (needs --features xla)
//!   seqdemo   — FREP sequencer demo trace
//!
//! `run`, `net`, `serve`, `sweep`, and `fig5` accept `--backend
//! {cycle,analytic,replay}`: `cycle` steps the full machine model,
//! `analytic` evaluates the calibrated first-order model (~1000x
//! faster, no numerics), `replay` memoizes the cycle engine per shape
//! (first run simulates, repeats replay cached timing — bit-identical
//! results). `--fast-forward false` drops the cycle engine back to
//! naive per-cycle stepping (the differential baseline; results are
//! bit-identical either way).

use std::collections::HashMap;
use std::path::PathBuf;

use crate::backend::BackendKind;
use crate::cluster::ConfigId;
use crate::coordinator::workload::zoo;
use crate::coordinator::{
    experiments, lint, net, node, profile, report, runner, serve,
    workload,
};
use crate::kernels::{GemmService, LayoutKind};
use crate::util::log;

pub fn usage() -> &'static str {
    "zerostall — cycle-accurate RISC-V cluster co-design framework\n\
     \n\
     USAGE: zerostall <command> [--key value]...\n\
     \n\
     COMMANDS:\n\
     \x20 run       --config <name> --m <M> --n <N> --k <K> \
     [--layout grouped|linear|linear-pad] \
     [--backend cycle|analytic|replay] [--fast-forward true|false] \
     [--clusters N] [--profile true]\n\
     \x20 net       --model mlp|ffn|qkv|attn|conv|llm \
     [--config <name>] [--backend cycle|analytic|replay] \
     [--fast-forward true|false] [--threads N] \
     [--seed S] [--clusters N] [--profile true] [--out results]\n\
     \x20 serve     --model <zoo[,zoo...]> [--rate R] [--burst B] \
     [--policy fifo|cb] [--clusters N] [--requests N] \
     [--backend cycle|analytic|replay] [--fast-forward true|false] \
     [--seed S] [--slo CYCLES] [--serve-engine event|legacy] \
     [--threads N] [--profile true] [--out results]\n\
     \x20           node tier: [--fabrics N] \
     [--router rr|ll|p2c|affinity] \
     [--fault \"t=T,fabric=F[,restore=T'][;...]\"] [--retries N] \
     [--admit-factor K] [--sessions N] \
     [--autoscale \"low=L,high=H,cooldown=C\"]\n\
     \x20           telemetry: [--telemetry] [--telemetry-window W] \
     [--trace out.json] [--quiet]\n\
     \x20 profile   --model mlp|ffn|qkv|attn|conv|llm \
     [--config <name>] [--clusters N] [--trace out.json] \
     [--fast-forward true|false] [--out results]\n\
     \x20 lint      [--model all|<zoo[,zoo...]>] [--config <name>] \
     [--clusters N] [--layout grouped|linear|linear-pad] \
     [--gate true|false] [--out results]\n\
     \x20 sweep     [--backend analytic|cycle] [--config <name>|all] \
     [--threads N] [--clusters N] [--out results]\n\
     \x20 calibrate [--threads N] [--out results]\n\
     \x20 fig5      [--samples 50] [--seed 42] [--threads N] \
     [--backend cycle|analytic] [--out results]\n\
     \x20 table1    [--out results]\n\
     \x20 table2    [--out results]\n\
     \x20 fig4      [--out results]\n\
     \x20 ablation  [--m 32 --n 32 --k 32] [--out results]\n\
     \x20 validate  [--artifacts artifacts] [--sizes 32,64] \
     [--config zonl48db]   (requires --features xla)\n\
     \x20 configs   (list configurations)\n\
     \n\
     CONFIGS: base32fc zonl32fc zonl64fc zonl64db zonl48db\n"
}

/// Boolean flags that may appear bare (no value) and mean `true`.
const BARE_FLAGS: &[&str] = &["quiet", "telemetry"];

/// Parse `--key value` pairs after the subcommand. The flags in
/// [`BARE_FLAGS`] may also appear bare (`--quiet`) and parse as
/// `true`; everything else requires an explicit value.
pub fn parse_flags(args: &[String]) -> anyhow::Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = &args[i];
        anyhow::ensure!(
            k.starts_with("--"),
            "expected --flag, got `{k}`"
        );
        let key = &k[2..];
        if BARE_FLAGS.contains(&key)
            && (i + 1 >= args.len() || args[i + 1].starts_with("--"))
        {
            map.insert(key.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        anyhow::ensure!(
            i + 1 < args.len(),
            "flag {k} needs a value"
        );
        map.insert(key.to_string(), args[i + 1].clone());
        i += 2;
    }
    Ok(map)
}

fn flag<T: std::str::FromStr>(
    m: &HashMap<String, String>,
    key: &str,
    default: T,
) -> anyhow::Result<T> {
    match m.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v}")),
    }
}

fn layout_of(s: &str) -> anyhow::Result<LayoutKind> {
    Ok(match s {
        "grouped" => LayoutKind::Grouped,
        "linear" => LayoutKind::Linear { pad_words: 0 },
        "linear-pad" => LayoutKind::Linear { pad_words: 1 },
        other => anyhow::bail!("unknown layout `{other}`"),
    })
}

fn backend_of(
    flags: &HashMap<String, String>,
    default: BackendKind,
) -> anyhow::Result<BackendKind> {
    match flags.get("backend") {
        None => Ok(default),
        Some(s) => BackendKind::from_name(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown backend `{s}` (cycle|analytic|replay)"
            )
        }),
    }
}

pub fn main_with_args(args: Vec<String>) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    log::set_level(if flag(&flags, "quiet", false)? {
        log::Level::Quiet
    } else {
        log::Level::Info
    });
    let out_dir =
        PathBuf::from(flags.get("out").cloned().unwrap_or_else(|| {
            "results".to_string()
        }));

    match cmd.as_str() {
        "configs" => {
            for id in ConfigId::all() {
                let c = id.cluster_config();
                println!(
                    "{:<10} banks={:<3} tcdm={:>3}KiB zonl={} topo={:?}",
                    id.name(),
                    c.topology.total_banks(),
                    c.tcdm_bytes / 1024,
                    c.zonl,
                    c.topology,
                );
            }
        }
        "run" => {
            let name = flags
                .get("config")
                .cloned()
                .unwrap_or_else(|| "zonl48db".into());
            let id = ConfigId::from_name(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown config {name}"))?;
            let m = flag(&flags, "m", 32usize)?;
            let n = flag(&flags, "n", 32usize)?;
            let k = flag(&flags, "k", 32usize)?;
            let layout = layout_of(
                flags.get("layout").map(|s| s.as_str()).unwrap_or("grouped"),
            )?;
            let backend = backend_of(&flags, BackendKind::Cycle)?;
            let ff = flag(&flags, "fast-forward", true)?;
            let clusters = flag(&flags, "clusters", 1usize)?;
            let profile_on = flag(&flags, "profile", false)?;
            let svc = GemmService::of_kind_ff(backend, ff);
            let p = workload::Problem { m, n, k };
            let fabric = crate::fabric::FabricConfig::new(clusters);
            let (row, stalls) = if clusters > 1 {
                experiments::profile_point_sharded(
                    &svc, id, p, layout, &fabric,
                )?
            } else {
                experiments::profile_point(&svc, id, p, layout)?
            };
            println!(
                "{} {} layout={:?} backend={} clusters={}\n  \
                 cycles={} window={} util={:.2}% perf={:.2} DPGflop/s \
                 power={:.1} mW eff={:.2} DPGflop/s/W conflicts={}{}",
                id.name(),
                p,
                layout,
                backend.name(),
                clusters,
                row.cycles,
                row.window_cycles,
                row.utilization * 100.0,
                row.gflops,
                row.power_mw,
                row.gflops_per_w,
                row.conflicts,
                if backend == BackendKind::Analytic {
                    "\n  (analytic prediction — no functional output)"
                } else {
                    ""
                },
            );
            if clusters > 1 {
                println!(
                    "  (fabric metrics: mean per-cluster utilization, \
                     throughput x{} clusters, NoC-inclusive power)",
                    clusters,
                );
            }
            if profile_on {
                println!("\n{}", report::render_stall_breakdown(&stalls));
                if backend == BackendKind::Analytic {
                    println!(
                        "  (analytic backend: *predicted* breakdown \
                         from the calibrated terms, quantized to \
                         conserve — not a measurement)"
                    );
                }
            }
        }
        "profile" => {
            let model = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "ffn".into());
            let name = flags
                .get("config")
                .cloned()
                .unwrap_or_else(|| "zonl48db".into());
            let id = ConfigId::from_name(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown config {name}"))?;
            let clusters = flag(&flags, "clusters", 1usize)?;
            let trace_path = flags.get("trace").map(PathBuf::from);
            let mut opts = profile::ProfileOpts::new(&model);
            opts.config = id;
            opts.clusters = clusters;
            opts.trace = trace_path.is_some();
            opts.fast_forward = flag(&flags, "fast-forward", true)?;
            log::info(
                "profile",
                &[
                    ("model", log::v(&model)),
                    ("config", log::v(id.name())),
                    ("clusters", log::v(clusters)),
                    ("chrome_trace", log::v(opts.trace)),
                ],
            );
            let (rep, trace) = profile::run_profile(&opts)?;
            let doc = report::render_profile(&rep);
            println!("{doc}");
            let stem = format!("profile-{model}-{}", id.name());
            report::save(&out_dir, &format!("{stem}.md"), &doc)?;
            report::stall_csv(&rep)
                .write(&out_dir.join(format!("{stem}-stalls.csv")))?;
            let points: Vec<_> =
                rep.layers.iter().map(|l| l.roofline.clone()).collect();
            report::roofline_csv(&points)
                .write(&out_dir.join(format!("{stem}-roofline.csv")))?;
            log::info(
                "profile_artifacts",
                &[
                    ("dir", log::v(out_dir.display())),
                    ("stem", log::v(&stem)),
                ],
            );
            if let (Some(path), Some(tr)) = (trace_path, trace) {
                tr.write(&path)?;
                log::info(
                    "chrome_trace",
                    &[
                        ("path", log::v(path.display())),
                        ("events", log::v(tr.events.len())),
                    ],
                );
            }
        }
        "lint" => {
            let model_s = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "all".into());
            let name = flags
                .get("config")
                .cloned()
                .unwrap_or_else(|| "zonl48db".into());
            let id = ConfigId::from_name(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown config {name}"))?;
            let clusters = flag(&flags, "clusters", 1usize)?;
            let layout = layout_of(
                flags.get("layout").map(|s| s.as_str()).unwrap_or("grouped"),
            )?;
            let gate = flag(&flags, "gate", true)?;
            let models: Vec<String> = if model_s == "all" {
                zoo::models().iter().map(|m| m.to_string()).collect()
            } else {
                model_s.split(',').map(|s| s.trim().to_string()).collect()
            };
            let mut all_fails = Vec::new();
            for model in &models {
                let mut opts = lint::LintOpts::new(model);
                opts.config = id;
                opts.clusters = clusters;
                opts.layout = layout;
                opts.gate = gate;
                log::info(
                    "lint",
                    &[
                        ("model", log::v(model)),
                        ("config", log::v(id.name())),
                        ("clusters", log::v(clusters)),
                        ("gate", log::v(gate)),
                    ],
                );
                let rep = lint::run_lint(&opts)?;
                let doc = report::render_lint(&rep);
                println!("{doc}");
                let stem = format!("lint-{model}-{}", id.name());
                report::save(&out_dir, &format!("{stem}.md"), &doc)?;
                report::lint_csv(&rep)
                    .write(&out_dir.join(format!("{stem}.csv")))?;
                report::lint_theorems_csv(&rep).write(
                    &out_dir.join(format!("{stem}-theorems.csv")),
                )?;
                log::info(
                    "lint_artifacts",
                    &[
                        ("dir", log::v(out_dir.display())),
                        ("stem", log::v(&stem)),
                    ],
                );
                all_fails.extend(
                    rep.failures()
                        .into_iter()
                        .map(|f| format!("{model}: {f}")),
                );
            }
            anyhow::ensure!(
                all_fails.is_empty(),
                "differential soundness gate failed:\n  {}",
                all_fails.join("\n  ")
            );
        }
        "net" => {
            let model = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "ffn".into());
            let name = flags
                .get("config")
                .cloned()
                .unwrap_or_else(|| "zonl48db".into());
            let id = ConfigId::from_name(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown config {name}"))?;
            let backend = backend_of(&flags, BackendKind::Cycle)?;
            let threads =
                flag(&flags, "threads", runner::default_threads())?;
            let seed = flag(&flags, "seed", 2026u64)?;
            let clusters = flag(&flags, "clusters", 1usize)?;
            let g = zoo::build(&model)?;
            log::info(
                "net",
                &[
                    ("model", log::v(&model)),
                    ("ops", log::v(g.ops.len())),
                    ("macs", log::v(g.macs())),
                    ("config", log::v(id.name())),
                    ("clusters", log::v(clusters)),
                    ("backend", log::v(backend.name())),
                    ("threads", log::v(threads)),
                ],
            );
            let profile_on = flag(&flags, "profile", false)?;
            let ff = flag(&flags, "fast-forward", true)?;
            let svc = GemmService::of_kind_ff(backend, ff);
            let run = net::run_net_clustered(
                &svc,
                &g,
                id,
                LayoutKind::Grouped,
                threads,
                seed,
                &crate::fabric::FabricConfig::new(clusters),
            )?;
            let mut doc = report::render_net(&run.report);
            if profile_on {
                doc.push('\n');
                doc.push_str(&report::render_net_profile(&run.report));
            }
            println!("{doc}");
            let stem = format!("net-{model}-{}", backend.name());
            report::save(&out_dir, &format!("{stem}.md"), &doc)?;
            report::net_csv(&run.report)
                .write(&out_dir.join(format!("{stem}.csv")))?;
            log::info(
                "net_artifacts",
                &[
                    ("dir", log::v(out_dir.display())),
                    ("stem", log::v(&stem)),
                ],
            );
        }
        "serve" => {
            let models_s = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "ffn".into());
            let models: Vec<String> = models_s
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let name = flags
                .get("config")
                .cloned()
                .unwrap_or_else(|| "zonl48db".into());
            let id = ConfigId::from_name(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown config {name}"))?;
            let backend = backend_of(&flags, BackendKind::Analytic)?;
            let policy_s = flags
                .get("policy")
                .cloned()
                .unwrap_or_else(|| "cb".into());
            let policy =
                serve::Policy::from_name(&policy_s).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown policy `{policy_s}` (fifo|cb)"
                    )
                })?;
            let slo = match flags.get("slo") {
                None => None,
                Some(v) => Some(v.parse::<u64>().map_err(|_| {
                    anyhow::anyhow!("bad value for --slo: {v}")
                })?),
            };
            let mut cfg = serve::ServeConfig::new(models);
            cfg.config = id;
            cfg.policy = policy;
            cfg.clusters = flag(&flags, "clusters", 1usize)?;
            cfg.requests = flag(&flags, "requests", 64usize)?;
            cfg.rate_per_mcycle = flag(&flags, "rate", 5.0f64)?;
            anyhow::ensure!(
                cfg.rate_per_mcycle.is_finite()
                    && cfg.rate_per_mcycle > 0.0,
                "--rate must be a positive request rate per Mcycle, \
                 got {}",
                cfg.rate_per_mcycle
            );
            cfg.burst = flag(&flags, "burst", 0.0f64)?;
            anyhow::ensure!(
                (0.0..1.0).contains(&cfg.burst),
                "--burst is a probability in [0, 1), got {}",
                cfg.burst
            );
            cfg.seed = flag(&flags, "seed", 2026u64)?;
            cfg.threads =
                flag(&flags, "threads", runner::default_threads())?;
            cfg.slo = slo;
            let engine_s = flags
                .get("serve-engine")
                .cloned()
                .unwrap_or_else(|| "event".into());
            cfg.engine = serve::ServeEngine::from_name(&engine_s)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown serve engine `{engine_s}` \
                         (event|legacy)"
                    )
                })?;
            // TimeScope: `--telemetry` enables the bus at the default
            // window; `--telemetry-window W` sets the window (and
            // implies the bus is on).
            if flag(&flags, "telemetry", false)?
                || flags.contains_key("telemetry-window")
            {
                cfg.telemetry = Some(flag(
                    &flags,
                    "telemetry-window",
                    crate::profile::telemetry::DEFAULT_WINDOW,
                )?);
            }
            let trace_path = flags.get("trace").map(PathBuf::from);
            // Node tier: any node flag routes the run through
            // NodeSim (N fabrics behind a front-end router) instead
            // of a single-fabric serve.
            let node_mode = flags.contains_key("fabrics")
                || flags.contains_key("router")
                || flags.contains_key("fault")
                || flags.contains_key("autoscale");
            if node_mode {
                let mut ncfg = node::NodeConfig::new(
                    cfg.clone(),
                    flag(&flags, "fabrics", 2usize)?,
                );
                let router_s = flags
                    .get("router")
                    .cloned()
                    .unwrap_or_else(|| "ll".into());
                ncfg.router = node::RouterPolicy::from_name(&router_s)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown router `{router_s}` \
                             (rr|ll|p2c|affinity)"
                        )
                    })?;
                if let Some(s) = flags.get("fault") {
                    ncfg.faults = node::FaultPlan::parse(s)?;
                }
                ncfg.max_retries = flag(&flags, "retries", 3u32)?;
                ncfg.sessions = flag(&flags, "sessions", 16usize)?;
                if flags.contains_key("admit-factor") {
                    ncfg.admit_factor =
                        Some(flag(&flags, "admit-factor", 1.0f64)?);
                }
                if let Some(s) = flags.get("autoscale") {
                    ncfg.autoscale =
                        Some(node::AutoscalePolicy::parse(s)?);
                }
                let ff = flag(&flags, "fast-forward", true)?;
                let svc = GemmService::of_kind_ff(backend, ff);
                log::info(
                    "node_serve",
                    &[
                        ("requests", log::v(cfg.requests)),
                        ("model", log::v(cfg.models.join("+"))),
                        ("rate", log::v(cfg.rate_per_mcycle)),
                        ("fabrics", log::v(ncfg.fabrics)),
                        ("clusters", log::v(cfg.clusters)),
                        ("backend", log::v(backend.name())),
                        ("router", log::v(ncfg.router.name())),
                        ("faults", ncfg.faults.summary()),
                        (
                            "autoscale",
                            ncfg.autoscale
                                .map(|p| p.summary())
                                .unwrap_or_else(|| "off".into()),
                        ),
                    ],
                );
                let run = node::run_node(&svc, &ncfg)?;
                let mut doc = report::render_node(&run.report);
                if let Some(tel) = &run.telemetry {
                    doc.push('\n');
                    doc.push_str(&report::render_telemetry(tel));
                }
                println!("{doc}");
                let stem = format!(
                    "node-{}-{}",
                    cfg.models.join("+"),
                    ncfg.router.name()
                );
                report::save(&out_dir, &format!("{stem}.md"), &doc)?;
                report::node_csv(&run)
                    .write(&out_dir.join(format!("{stem}.csv")))?;
                report::node_fabric_csv(&run.report).write(
                    &out_dir.join(format!("{stem}-fabrics.csv")),
                )?;
                report::node_sheds_csv(&run).write(
                    &out_dir.join(format!("{stem}-sheds.csv")),
                )?;
                if let Some(tel) = &run.telemetry {
                    report::telemetry_csv(tel).write(
                        &out_dir
                            .join(format!("{stem}-telemetry.csv")),
                    )?;
                    if let Some(path) = &trace_path {
                        let tr = tel.to_chrome("fabric");
                        tr.write(path)?;
                        log::info(
                            "chrome_trace",
                            &[
                                ("path", log::v(path.display())),
                                ("events", log::v(tr.events.len())),
                            ],
                        );
                    }
                }
                log::info(
                    "node_artifacts",
                    &[
                        ("dir", log::v(out_dir.display())),
                        ("stem", log::v(&stem)),
                        (
                            "digest",
                            format!("0x{:016x}", run.report.digest),
                        ),
                        (
                            "telemetry",
                            log::v(run.telemetry.is_some()),
                        ),
                    ],
                );
                return Ok(());
            }
            log::info(
                "serve",
                &[
                    ("requests", log::v(cfg.requests)),
                    ("model", log::v(cfg.models.join("+"))),
                    ("rate", log::v(cfg.rate_per_mcycle)),
                    ("burst", log::v(cfg.burst)),
                    ("config", log::v(id.name())),
                    ("clusters", log::v(cfg.clusters)),
                    ("backend", log::v(backend.name())),
                    ("policy", log::v(policy.name())),
                ],
            );
            let profile_on = flag(&flags, "profile", false)?;
            let ff = flag(&flags, "fast-forward", true)?;
            let svc = GemmService::of_kind_ff(backend, ff);
            let run = serve::serve(&svc, &cfg)?;
            if cfg.engine == serve::ServeEngine::Event {
                let es = run.engine_stats;
                log::info(
                    "serve_event_core",
                    &[
                        ("events", log::v(es.events)),
                        ("memo_hits", log::v(es.memo_hits)),
                        ("memo_misses", log::v(es.memo_misses)),
                    ],
                );
            }
            if let Some(ms) = svc.memo_stats() {
                log::info(
                    "memo_tier",
                    &[
                        ("hits", log::v(ms.hits)),
                        ("misses", log::v(ms.misses)),
                        (
                            "replayed_pct",
                            format!("{:.0}", ms.hit_rate() * 100.0),
                        ),
                    ],
                );
            }
            let mut doc = report::render_serve(&run.report);
            if profile_on {
                doc.push('\n');
                doc.push_str(&report::render_serve_profile(&run.report));
            }
            if let Some(tel) = &run.telemetry {
                doc.push('\n');
                doc.push_str(&report::render_telemetry(tel));
            }
            println!("{doc}");
            let stem = format!(
                "serve-{}-{}",
                cfg.models.join("+"),
                policy.name()
            );
            report::save(&out_dir, &format!("{stem}.md"), &doc)?;
            report::serve_csv(&run)
                .write(&out_dir.join(format!("{stem}.csv")))?;
            if let Some(tel) = &run.telemetry {
                report::telemetry_csv(tel).write(
                    &out_dir.join(format!("{stem}-telemetry.csv")),
                )?;
                if let Some(path) = &trace_path {
                    let tr = tel.to_chrome("serve");
                    tr.write(path)?;
                    log::info(
                        "chrome_trace",
                        &[
                            ("path", log::v(path.display())),
                            ("events", log::v(tr.events.len())),
                        ],
                    );
                }
            }
            log::info(
                "serve_artifacts",
                &[
                    ("dir", log::v(out_dir.display())),
                    ("stem", log::v(&stem)),
                    ("telemetry", log::v(run.telemetry.is_some())),
                ],
            );
        }
        "sweep" => {
            let backend = backend_of(&flags, BackendKind::Analytic)?;
            let threads =
                flag(&flags, "threads", runner::default_threads())?;
            let clusters = flag(&flags, "clusters", 1usize)?;
            let configs: Vec<ConfigId> = match flags
                .get("config")
                .map(|s| s.as_str())
                .unwrap_or("all")
            {
                "all" => ConfigId::all().to_vec(),
                name => vec![ConfigId::from_name(name).ok_or_else(
                    || anyhow::anyhow!("unknown config {name}"),
                )?],
            };
            let dims = workload::dim_grid().len();
            let points = dims * dims * dims * configs.len();
            log::info(
                "sweep",
                &[
                    ("points", log::v(points)),
                    ("configs", log::v(configs.len())),
                    ("dims", log::v(dims)),
                    ("backend", log::v(backend.name())),
                    ("threads", log::v(threads)),
                ],
            );
            if backend == BackendKind::Cycle {
                log::info(
                    "sweep_note",
                    &[(
                        "hint",
                        "cycle-accurate full-grid sweeps take \
                         hours; use --backend analytic for triage"
                            .into(),
                    )],
                );
            }
            let svc = GemmService::of_kind(backend);
            let t0 = std::time::Instant::now();
            let rows = experiments::sweep_grid_on(
                &svc,
                &configs,
                threads,
                &crate::fabric::FabricConfig::new(clusters),
            )?;
            let elapsed = t0.elapsed().as_secs_f64();
            let doc = report::render_sweep(&rows, backend.name(), elapsed);
            println!("{doc}");
            let stats = svc.stats();
            log::info(
                "plan_cache",
                &[
                    ("hits", log::v(stats.plan_hits)),
                    ("misses", log::v(stats.plan_misses)),
                    (
                        "hit_rate_pct",
                        format!("{:.0}", stats.hit_rate() * 100.0),
                    ),
                ],
            );
            let name = format!("sweep-{}.csv", backend.name());
            report::fig5_csv(&rows).write(&out_dir.join(&name))?;
            report::save(
                &out_dir,
                &format!("sweep-{}.md", backend.name()),
                &doc,
            )?;
            log::info(
                "sweep_artifacts",
                &[
                    ("dir", log::v(out_dir.display())),
                    (
                        "stem",
                        format!("sweep-{}", backend.name()),
                    ),
                ],
            );
        }
        "calibrate" => {
            let threads =
                flag(&flags, "threads", runner::default_threads())?;
            log::info(
                "calibrate",
                &[
                    (
                        "grid_points",
                        log::v(experiments::calibration_grid().len()),
                    ),
                    ("threads", log::v(threads)),
                ],
            );
            let out = experiments::calibrate(threads)?;
            let doc = format!(
                "{}\n{}",
                report::render_calibration(&out.calibration),
                report::render_error_table(&out.errors)
            );
            println!("{doc}");
            report::save(&out_dir, "calibration.md", &doc)?;
            report::error_csv(&out.errors)
                .write(&out_dir.join("calibration_errors.csv"))?;
            log::info(
                "calibrate_artifacts",
                &[("dir", log::v(out_dir.display()))],
            );
        }
        "fig5" => {
            let samples = flag(&flags, "samples", 50usize)?;
            let seed = flag(&flags, "seed", 42u64)?;
            let threads =
                flag(&flags, "threads", runner::default_threads())?;
            let backend = backend_of(&flags, BackendKind::Cycle)?;
            log::info(
                "fig5",
                &[
                    ("samples", log::v(samples)),
                    ("backend", log::v(backend.name())),
                    ("threads", log::v(threads)),
                ],
            );
            let svc = GemmService::of_kind(backend);
            let rows = experiments::fig5_with(&svc, samples, seed, threads)?;
            let summary = experiments::fig5_summary(&rows);
            let head = experiments::headline(&rows);
            let doc = format!(
                "{}\n{}",
                report::render_fig5(&summary),
                report::render_headline(&head)
            );
            println!("{doc}");
            report::save(&out_dir, "fig5.md", &doc)?;
            report::fig5_csv(&rows).write(&out_dir.join("fig5.csv"))?;
            log::info(
                "fig5_artifacts",
                &[("dir", log::v(out_dir.display()))],
            );
        }
        "table1" => {
            let rows = experiments::table1();
            let doc = report::render_table1(&rows);
            println!("{doc}");
            report::save(&out_dir, "table1.md", &doc)?;
            report::table1_csv(&rows)
                .write(&out_dir.join("table1.csv"))?;
        }
        "table2" => {
            let rows = experiments::table2()?;
            let doc = report::render_table2(&rows);
            println!("{doc}");
            report::save(&out_dir, "table2.md", &doc)?;
            report::table2_csv(&rows)
                .write(&out_dir.join("table2.csv"))?;
        }
        "fig4" => {
            let doc = report::render_fig4();
            println!("{doc}");
            report::save(&out_dir, "fig4.md", &doc)?;
        }
        "ablation" => {
            let m = flag(&flags, "m", 32usize)?;
            let n = flag(&flags, "n", 32usize)?;
            let k = flag(&flags, "k", 32usize)?;
            let rows = experiments::layout_ablation(
                workload::Problem { m, n, k },
            )?;
            let doc = report::render_ablation(&rows);
            println!("{doc}");
            report::save(&out_dir, "ablation.md", &doc)?;
        }
        "validate" => {
            #[cfg(feature = "xla")]
            {
                let dir = flags
                    .get("artifacts")
                    .map(PathBuf::from)
                    .unwrap_or_else(crate::runtime::Runtime::default_dir);
                let name = flags
                    .get("config")
                    .cloned()
                    .unwrap_or_else(|| "zonl48db".into());
                let id = ConfigId::from_name(&name).ok_or_else(|| {
                    anyhow::anyhow!("unknown config {name}")
                })?;
                let sizes: Vec<usize> = flags
                    .get("sizes")
                    .map(|s| s.as_str())
                    .unwrap_or("16,32,40")
                    .split(',')
                    .map(|x| x.trim().parse())
                    .collect::<Result<_, _>>()
                    .map_err(|e| anyhow::anyhow!("bad --sizes: {e}"))?;
                let rt = crate::runtime::Runtime::new(&dir)?;
                for s in sizes {
                    let (a, b) = crate::kernels::test_matrices(s, s, s, 99);
                    let sim =
                        crate::kernels::run_matmul(id, s, s, s, &a, &b)?;
                    let gold = crate::runtime::golden_matmul(
                        &rt, s, s, s, &a, &b,
                    )?;
                    let err = crate::runtime::max_rel_error(&sim.c, &gold);
                    let ok = err < 1e-9;
                    println!(
                        "{name} {s}x{s}x{s}: max rel err vs PJRT golden = \
                         {err:.2e} {}",
                        if ok { "OK" } else { "FAIL" }
                    );
                    anyhow::ensure!(ok, "golden mismatch at {s}^3");
                }
                println!("golden validation passed");
            }
            #[cfg(not(feature = "xla"))]
            {
                anyhow::bail!(
                    "`validate` needs the PJRT golden model: uncomment \
                     the `xla` dependency in rust/Cargo.toml, rebuild \
                     with `--features xla`, and run `make artifacts`"
                );
            }
        }
        "help" | "--help" | "-h" => println!("{}", usage()),
        other => {
            anyhow::bail!("unknown command `{other}`\n\n{}", usage())
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_flags_pairs() {
        let f = parse_flags(&[
            "--m".into(),
            "32".into(),
            "--config".into(),
            "zonl48db".into(),
        ])
        .unwrap();
        assert_eq!(f.get("m").unwrap(), "32");
        assert_eq!(f.get("config").unwrap(), "zonl48db");
    }

    #[test]
    fn parse_flags_rejects_dangling() {
        assert!(parse_flags(&["--m".into()]).is_err());
        assert!(parse_flags(&["m".into(), "32".into()]).is_err());
    }

    #[test]
    fn layout_parsing() {
        assert_eq!(layout_of("grouped").unwrap(), LayoutKind::Grouped);
        assert!(layout_of("bogus").is_err());
    }

    #[test]
    fn backend_parsing() {
        let mut f = HashMap::new();
        assert_eq!(
            backend_of(&f, BackendKind::Cycle).unwrap(),
            BackendKind::Cycle
        );
        f.insert("backend".to_string(), "analytic".to_string());
        assert_eq!(
            backend_of(&f, BackendKind::Cycle).unwrap(),
            BackendKind::Analytic
        );
        f.insert("backend".to_string(), "replay".to_string());
        assert_eq!(
            backend_of(&f, BackendKind::Cycle).unwrap(),
            BackendKind::Replay
        );
        f.insert("backend".to_string(), "rtl".to_string());
        assert!(backend_of(&f, BackendKind::Cycle).is_err());
    }

    #[test]
    fn run_command_replay_backend_and_naive_stepping() {
        main_with_args(vec![
            "run".into(),
            "--backend".into(),
            "replay".into(),
            "--m".into(),
            "16".into(),
            "--n".into(),
            "16".into(),
            "--k".into(),
            "16".into(),
        ])
        .unwrap();
        main_with_args(vec![
            "run".into(),
            "--fast-forward".into(),
            "false".into(),
            "--m".into(),
            "16".into(),
            "--n".into(),
            "16".into(),
            "--k".into(),
            "16".into(),
        ])
        .unwrap();
    }

    #[test]
    fn run_command_executes() {
        main_with_args(vec![
            "run".into(),
            "--config".into(),
            "zonl48db".into(),
            "--m".into(),
            "16".into(),
            "--n".into(),
            "16".into(),
            "--k".into(),
            "16".into(),
        ])
        .unwrap();
    }

    #[test]
    fn run_command_analytic_backend() {
        main_with_args(vec![
            "run".into(),
            "--backend".into(),
            "analytic".into(),
            "--m".into(),
            "32".into(),
            "--n".into(),
            "32".into(),
            "--k".into(),
            "32".into(),
        ])
        .unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(main_with_args(vec!["bogus".into()]).is_err());
    }

    #[test]
    fn run_command_sharded_cycle() {
        main_with_args(vec![
            "run".into(),
            "--m".into(),
            "32".into(),
            "--n".into(),
            "32".into(),
            "--k".into(),
            "16".into(),
            "--clusters".into(),
            "4".into(),
        ])
        .unwrap();
    }

    #[test]
    fn net_command_clustered_analytic() {
        let dir = std::env::temp_dir().join("zerostall-net-fabric-test");
        main_with_args(vec![
            "net".into(),
            "--model".into(),
            "ffn".into(),
            "--backend".into(),
            "analytic".into(),
            "--clusters".into(),
            "2".into(),
            "--threads".into(),
            "2".into(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        assert!(dir.join("net-ffn-analytic.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn net_command_runs_both_backends() {
        let dir = std::env::temp_dir().join("zerostall-net-cli-test");
        for backend in ["analytic", "cycle"] {
            main_with_args(vec![
                "net".into(),
                "--model".into(),
                "ffn".into(),
                "--backend".into(),
                backend.into(),
                "--threads".into(),
                "2".into(),
                "--out".into(),
                dir.display().to_string(),
            ])
            .unwrap();
        }
        assert!(dir.join("net-ffn-cycle.csv").exists());
        assert!(dir.join("net-ffn-analytic.md").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_command_runs_cb_analytic() {
        let dir = std::env::temp_dir().join("zerostall-serve-cli-test");
        main_with_args(vec![
            "serve".into(),
            "--model".into(),
            "ffn".into(),
            "--backend".into(),
            "analytic".into(),
            "--policy".into(),
            "cb".into(),
            "--clusters".into(),
            "2".into(),
            "--requests".into(),
            "8".into(),
            "--threads".into(),
            "2".into(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        assert!(dir.join("serve-ffn-cb.md").exists());
        assert!(dir.join("serve-ffn-cb.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_command_model_mix_and_fifo() {
        let dir =
            std::env::temp_dir().join("zerostall-serve-cli-mix-test");
        main_with_args(vec![
            "serve".into(),
            "--model".into(),
            "ffn,qkv".into(),
            "--policy".into(),
            "fifo".into(),
            "--requests".into(),
            "6".into(),
            "--rate".into(),
            "2.5".into(),
            "--burst".into(),
            "0.25".into(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        assert!(dir.join("serve-ffn+qkv-fifo.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_command_rejects_bad_inputs() {
        assert!(main_with_args(vec![
            "serve".into(),
            "--policy".into(),
            "lifo".into(),
        ])
        .is_err());
        assert!(main_with_args(vec![
            "serve".into(),
            "--model".into(),
            "resnet9000".into(),
            "--requests".into(),
            "1".into(),
        ])
        .is_err());
        assert!(main_with_args(vec![
            "serve".into(),
            "--slo".into(),
            "soon".into(),
        ])
        .is_err());
        assert!(main_with_args(vec![
            "serve".into(),
            "--rate".into(),
            "-3".into(),
        ])
        .is_err());
        assert!(main_with_args(vec![
            "serve".into(),
            "--burst".into(),
            "2".into(),
        ])
        .is_err());
        assert!(main_with_args(vec![
            "serve".into(),
            "--serve-engine".into(),
            "waveish".into(),
        ])
        .is_err());
    }

    #[test]
    fn serve_command_node_mode_writes_all_csvs() {
        let dir =
            std::env::temp_dir().join("zerostall-node-cli-test");
        main_with_args(vec![
            "serve".into(),
            "--model".into(),
            "ffn".into(),
            "--backend".into(),
            "analytic".into(),
            "--fabrics".into(),
            "2".into(),
            "--router".into(),
            "p2c".into(),
            "--fault".into(),
            "t=500000,fabric=1,restore=900000".into(),
            "--requests".into(),
            "12".into(),
            "--rate".into(),
            "20".into(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        assert!(dir.join("node-ffn-p2c.md").exists());
        assert!(dir.join("node-ffn-p2c.csv").exists());
        assert!(dir.join("node-ffn-p2c-fabrics.csv").exists());
        assert!(dir.join("node-ffn-p2c-sheds.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_command_node_mode_rejects_bad_inputs() {
        assert!(main_with_args(vec![
            "serve".into(),
            "--router".into(),
            "hashring".into(),
        ])
        .is_err());
        assert!(main_with_args(vec![
            "serve".into(),
            "--fabrics".into(),
            "2".into(),
            "--fault".into(),
            "t=1,fabric=7".into(),
        ])
        .is_err());
        assert!(main_with_args(vec![
            "serve".into(),
            "--fabrics".into(),
            "2".into(),
            "--fault".into(),
            "whenever".into(),
        ])
        .is_err());
        assert!(main_with_args(vec![
            "serve".into(),
            "--fabrics".into(),
            "2".into(),
            "--admit-factor".into(),
            "-1".into(),
        ])
        .is_err());
    }

    #[test]
    fn parse_flags_accepts_bare_boolean_flags() {
        // `--telemetry` / `--quiet` may appear bare (no value) even
        // directly before another flag; everything else still needs
        // its value.
        let f = parse_flags(&[
            "--telemetry".into(),
            "--requests".into(),
            "4".into(),
            "--quiet".into(),
        ])
        .unwrap();
        assert_eq!(f.get("telemetry").unwrap(), "true");
        assert_eq!(f.get("quiet").unwrap(), "true");
        assert_eq!(f.get("requests").unwrap(), "4");
        // An explicit value still parses.
        let f = parse_flags(&["--telemetry".into(), "false".into()])
            .unwrap();
        assert_eq!(f.get("telemetry").unwrap(), "false");
    }

    #[test]
    fn serve_command_telemetry_writes_csv_and_trace() {
        let dir =
            std::env::temp_dir().join("zerostall-serve-cli-tel-test");
        let trace = dir.join("spans.trace.json");
        main_with_args(vec![
            "serve".into(),
            "--model".into(),
            "ffn".into(),
            "--requests".into(),
            "8".into(),
            "--telemetry".into(),
            "--trace".into(),
            trace.display().to_string(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        let csv = std::fs::read_to_string(
            dir.join("serve-ffn-cb-telemetry.csv"),
        )
        .unwrap();
        assert!(csv.starts_with(
            "metric,labels,window,t_start,t_end,kind,value"
        ));
        assert!(csv.contains("arrivals"));
        let json = std::fs::read_to_string(&trace).unwrap();
        assert!(json.contains("traceEvents"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_command_autoscale_enters_node_mode() {
        // `--autoscale` alone must switch into node mode, imply
        // telemetry, and surface the policy in the report.
        let dir =
            std::env::temp_dir().join("zerostall-node-cli-auto-test");
        main_with_args(vec![
            "serve".into(),
            "--model".into(),
            "ffn".into(),
            "--requests".into(),
            "8".into(),
            "--rate".into(),
            "5".into(),
            "--autoscale".into(),
            "low=0.2,high=0.7,cooldown=3".into(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        assert!(dir.join("node-ffn-ll-telemetry.csv").exists());
        let md = std::fs::read_to_string(dir.join("node-ffn-ll.md"))
            .unwrap();
        assert!(md.contains("autoscale: low=0.2,high=0.7,cooldown=3"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_command_rejects_bad_autoscale() {
        assert!(main_with_args(vec![
            "serve".into(),
            "--autoscale".into(),
            "low=0.9,high=0.1".into(),
        ])
        .is_err());
        assert!(main_with_args(vec![
            "serve".into(),
            "--autoscale".into(),
            "verve=1".into(),
        ])
        .is_err());
    }

    #[test]
    fn serve_command_legacy_engine() {
        let dir = std::env::temp_dir()
            .join("zerostall-serve-cli-legacy-test");
        main_with_args(vec![
            "serve".into(),
            "--model".into(),
            "ffn".into(),
            "--serve-engine".into(),
            "legacy".into(),
            "--requests".into(),
            "4".into(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        assert!(dir.join("serve-ffn-cb.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_command_with_profile_breakdown() {
        main_with_args(vec![
            "run".into(),
            "--m".into(),
            "16".into(),
            "--n".into(),
            "16".into(),
            "--k".into(),
            "16".into(),
            "--profile".into(),
            "true".into(),
        ])
        .unwrap();
    }

    #[test]
    fn profile_command_writes_artifacts_and_trace() {
        let dir = std::env::temp_dir().join("zerostall-profile-cli-test");
        let trace = dir.join("trace.json");
        main_with_args(vec![
            "profile".into(),
            "--model".into(),
            "qkv".into(),
            "--trace".into(),
            trace.display().to_string(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        assert!(dir.join("profile-qkv-zonl48db.md").exists());
        assert!(dir.join("profile-qkv-zonl48db-stalls.csv").exists());
        assert!(dir.join("profile-qkv-zonl48db-roofline.csv").exists());
        let json = std::fs::read_to_string(&trace).unwrap();
        assert!(json.contains("traceEvents"));
        assert!(json.contains("Useful"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lint_command_writes_artifacts() {
        let dir = std::env::temp_dir().join("zerostall-lint-cli-test");
        main_with_args(vec![
            "lint".into(),
            "--model".into(),
            "ffn".into(),
            "--gate".into(),
            "false".into(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        assert!(dir.join("lint-ffn-zonl48db.md").exists());
        assert!(dir.join("lint-ffn-zonl48db.csv").exists());
        assert!(dir.join("lint-ffn-zonl48db-theorems.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lint_command_gated_passes_on_attn() {
        let dir =
            std::env::temp_dir().join("zerostall-lint-cli-gate-test");
        main_with_args(vec![
            "lint".into(),
            "--model".into(),
            "attn".into(),
            "--out".into(),
            dir.display().to_string(),
        ])
        .unwrap();
        let csv = std::fs::read_to_string(
            dir.join("lint-attn-zonl48db.csv"),
        )
        .unwrap();
        assert!(csv.contains("pass"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lint_command_rejects_unknown_model() {
        assert!(main_with_args(vec![
            "lint".into(),
            "--model".into(),
            "resnet9000".into(),
            "--gate".into(),
            "false".into(),
        ])
        .is_err());
    }

    #[test]
    fn profile_command_rejects_unknown_model() {
        assert!(main_with_args(vec![
            "profile".into(),
            "--model".into(),
            "resnet9000".into(),
        ])
        .is_err());
    }

    #[test]
    fn net_command_rejects_unknown_model() {
        assert!(main_with_args(vec![
            "net".into(),
            "--model".into(),
            "resnet9000".into(),
        ])
        .is_err());
    }

    #[test]
    fn validate_without_xla_feature_errors() {
        #[cfg(not(feature = "xla"))]
        assert!(main_with_args(vec!["validate".into()]).is_err());
    }
}
