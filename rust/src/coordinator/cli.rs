//! Hand-rolled CLI (clap is unavailable offline).
//!
//! Subcommands:
//!   run       — one GEMM on one configuration, print metrics
//!   fig5      — the random-size sweep (box plots + CSV + headline)
//!   table1    — area model rows
//!   table2    — SoA comparison rows
//!   fig4      — congestion proxy
//!   ablation  — layout ablation
//!   validate  — simulator vs PJRT golden model (needs artifacts/)
//!   seqdemo   — FREP sequencer demo trace

use std::collections::HashMap;
use std::path::PathBuf;

use crate::cluster::ConfigId;
use crate::coordinator::{experiments, report, runner, workload};
use crate::kernels::{self, LayoutKind};
use crate::runtime;

pub fn usage() -> &'static str {
    "zerostall — cycle-accurate RISC-V cluster co-design framework\n\
     \n\
     USAGE: zerostall <command> [--key value]...\n\
     \n\
     COMMANDS:\n\
     \x20 run       --config <name> --m <M> --n <N> --k <K> \
     [--layout grouped|linear|linear-pad]\n\
     \x20 fig5      [--samples 50] [--seed 42] [--threads N] \
     [--out results]\n\
     \x20 table1    [--out results]\n\
     \x20 table2    [--out results]\n\
     \x20 fig4      [--out results]\n\
     \x20 ablation  [--m 32 --n 32 --k 32] [--out results]\n\
     \x20 validate  [--artifacts artifacts] [--sizes 32,64] \
     [--config zonl48db]\n\
     \x20 configs   (list configurations)\n\
     \n\
     CONFIGS: base32fc zonl32fc zonl64fc zonl64db zonl48db\n"
}

/// Parse `--key value` pairs after the subcommand.
pub fn parse_flags(args: &[String]) -> anyhow::Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = &args[i];
        anyhow::ensure!(
            k.starts_with("--"),
            "expected --flag, got `{k}`"
        );
        anyhow::ensure!(
            i + 1 < args.len(),
            "flag {k} needs a value"
        );
        map.insert(k[2..].to_string(), args[i + 1].clone());
        i += 2;
    }
    Ok(map)
}

fn flag<T: std::str::FromStr>(
    m: &HashMap<String, String>,
    key: &str,
    default: T,
) -> anyhow::Result<T> {
    match m.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v}")),
    }
}

fn layout_of(s: &str) -> anyhow::Result<LayoutKind> {
    Ok(match s {
        "grouped" => LayoutKind::Grouped,
        "linear" => LayoutKind::Linear { pad_words: 0 },
        "linear-pad" => LayoutKind::Linear { pad_words: 1 },
        other => anyhow::bail!("unknown layout `{other}`"),
    })
}

pub fn main_with_args(args: Vec<String>) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    let out_dir =
        PathBuf::from(flags.get("out").cloned().unwrap_or_else(|| {
            "results".to_string()
        }));

    match cmd.as_str() {
        "configs" => {
            for id in ConfigId::all() {
                let c = id.cluster_config();
                println!(
                    "{:<10} banks={:<3} tcdm={:>3}KiB zonl={} topo={:?}",
                    id.name(),
                    c.topology.total_banks(),
                    c.tcdm_bytes / 1024,
                    c.zonl,
                    c.topology,
                );
            }
        }
        "run" => {
            let name = flags
                .get("config")
                .cloned()
                .unwrap_or_else(|| "zonl48db".into());
            let id = ConfigId::from_name(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown config {name}"))?;
            let m = flag(&flags, "m", 32usize)?;
            let n = flag(&flags, "n", 32usize)?;
            let k = flag(&flags, "k", 32usize)?;
            let layout = layout_of(
                flags.get("layout").map(|s| s.as_str()).unwrap_or("grouped"),
            )?;
            let p = workload::Problem { m, n, k };
            let row = experiments::run_point(id, p, layout)?;
            println!(
                "{} {} layout={:?}\n  cycles={} window={} util={:.2}% \
                 perf={:.2} DPGflop/s power={:.1} mW eff={:.2} \
                 DPGflop/s/W conflicts={}",
                id.name(),
                p,
                layout,
                row.cycles,
                row.window_cycles,
                row.utilization * 100.0,
                row.gflops,
                row.power_mw,
                row.gflops_per_w,
                row.conflicts,
            );
        }
        "fig5" => {
            let samples = flag(&flags, "samples", 50usize)?;
            let seed = flag(&flags, "seed", 42u64)?;
            let threads =
                flag(&flags, "threads", runner::default_threads())?;
            eprintln!(
                "fig5: {samples} sizes x 5 configs on {threads} threads..."
            );
            let rows = experiments::fig5(samples, seed, threads)?;
            let summary = experiments::fig5_summary(&rows);
            let head = experiments::headline(&rows);
            let doc = format!(
                "{}\n{}",
                report::render_fig5(&summary),
                report::render_headline(&head)
            );
            println!("{doc}");
            report::save(&out_dir, "fig5.md", &doc)?;
            report::fig5_csv(&rows).write(&out_dir.join("fig5.csv"))?;
            eprintln!("wrote {}/fig5.{{md,csv}}", out_dir.display());
        }
        "table1" => {
            let rows = experiments::table1();
            let doc = report::render_table1(&rows);
            println!("{doc}");
            report::save(&out_dir, "table1.md", &doc)?;
            report::table1_csv(&rows)
                .write(&out_dir.join("table1.csv"))?;
        }
        "table2" => {
            let rows = experiments::table2()?;
            let doc = report::render_table2(&rows);
            println!("{doc}");
            report::save(&out_dir, "table2.md", &doc)?;
            report::table2_csv(&rows)
                .write(&out_dir.join("table2.csv"))?;
        }
        "fig4" => {
            let doc = report::render_fig4();
            println!("{doc}");
            report::save(&out_dir, "fig4.md", &doc)?;
        }
        "ablation" => {
            let m = flag(&flags, "m", 32usize)?;
            let n = flag(&flags, "n", 32usize)?;
            let k = flag(&flags, "k", 32usize)?;
            let rows = experiments::layout_ablation(
                workload::Problem { m, n, k },
            )?;
            let doc = report::render_ablation(&rows);
            println!("{doc}");
            report::save(&out_dir, "ablation.md", &doc)?;
        }
        "validate" => {
            let dir = flags
                .get("artifacts")
                .map(PathBuf::from)
                .unwrap_or_else(runtime::Runtime::default_dir);
            let name = flags
                .get("config")
                .cloned()
                .unwrap_or_else(|| "zonl48db".into());
            let id = ConfigId::from_name(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown config {name}"))?;
            let sizes: Vec<usize> = flags
                .get("sizes")
                .map(|s| s.as_str())
                .unwrap_or("16,32,40")
                .split(',')
                .map(|x| x.trim().parse())
                .collect::<Result<_, _>>()
                .map_err(|e| anyhow::anyhow!("bad --sizes: {e}"))?;
            let rt = runtime::Runtime::new(&dir)?;
            for s in sizes {
                let (a, b) = kernels::test_matrices(s, s, s, 99);
                let sim = kernels::run_matmul(id, s, s, s, &a, &b)?;
                let gold = runtime::golden_matmul(&rt, s, s, s, &a, &b)?;
                let err = runtime::max_rel_error(&sim.c, &gold);
                let ok = err < 1e-9;
                println!(
                    "{name} {s}x{s}x{s}: max rel err vs PJRT golden = \
                     {err:.2e} {}",
                    if ok { "OK" } else { "FAIL" }
                );
                anyhow::ensure!(ok, "golden mismatch at {s}^3");
            }
            println!("golden validation passed");
        }
        "help" | "--help" | "-h" => println!("{}", usage()),
        other => {
            anyhow::bail!("unknown command `{other}`\n\n{}", usage())
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_flags_pairs() {
        let f = parse_flags(&[
            "--m".into(),
            "32".into(),
            "--config".into(),
            "zonl48db".into(),
        ])
        .unwrap();
        assert_eq!(f.get("m").unwrap(), "32");
        assert_eq!(f.get("config").unwrap(), "zonl48db");
    }

    #[test]
    fn parse_flags_rejects_dangling() {
        assert!(parse_flags(&["--m".into()]).is_err());
        assert!(parse_flags(&["m".into(), "32".into()]).is_err());
    }

    #[test]
    fn layout_parsing() {
        assert_eq!(layout_of("grouped").unwrap(), LayoutKind::Grouped);
        assert!(layout_of("bogus").is_err());
    }

    #[test]
    fn run_command_executes() {
        main_with_args(vec![
            "run".into(),
            "--config".into(),
            "zonl48db".into(),
            "--m".into(),
            "16".into(),
            "--n".into(),
            "16".into(),
            "--k".into(),
            "16".into(),
        ])
        .unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(main_with_args(vec!["bogus".into()]).is_err());
    }
}
