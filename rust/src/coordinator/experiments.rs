//! The paper's experiments: Fig. 5 sweep, Table I, Table II, Fig. 4,
//! the §IV-B headline numbers, the layout/design ablations — plus the
//! backend-agnostic sweep machinery: every point is evaluated through
//! a `GemmService` (cycle-accurate or analytic), and `calibrate` fits
//! the analytic model's constants against cycle-accurate ground truth
//! and reports the per-configuration error table.

use crate::backend::{
    fit_calibration, fit_delta, predict_perf_noc, CalSample, Calibration,
    NocSample,
};
use crate::cluster::ConfigId;
use crate::fabric::{FabricConfig, NocConfig};
use crate::kernels::{
    test_matrices, Activation, Epilogue, GemmJob, GemmResult,
    GemmService, LayoutKind,
};
use crate::model::{self, area::AreaBreakdown};
use crate::opengemm;
use crate::util::stats::{box_stats, BoxStats};

use super::runner;
use super::workload::{dim_grid, sample_problems, Problem};

/// One simulated point of the Fig. 5 sweep.
#[derive(Clone, Copy, Debug)]
pub struct Fig5Row {
    pub config: ConfigId,
    pub problem: Problem,
    pub utilization: f64,
    pub power_mw: f64,
    pub gflops: f64,
    pub gflops_per_w: f64,
    pub cycles: u64,
    pub window_cycles: u64,
    pub conflicts: u64,
}

/// Run one (config, problem) point cycle-accurately (a fresh one-shot
/// service; sweeps should share one via [`run_point_with`]).
pub fn run_point(
    config: ConfigId,
    p: Problem,
    layout: LayoutKind,
) -> anyhow::Result<Fig5Row> {
    run_point_with(&GemmService::cycle(), config, p, layout)
}

/// Run one (config, problem) point through a shared service. Operand
/// matrices are derived from the problem (deterministic, and identical
/// across configs so numerics can be cross-checked); non-functional
/// backends skip them entirely.
pub fn run_point_with(
    svc: &GemmService,
    config: ConfigId,
    p: Problem,
    layout: LayoutKind,
) -> anyhow::Result<Fig5Row> {
    profile_point(svc, config, p, layout).map(|(row, _)| row)
}

fn fig5_row(p: Problem, r: &GemmResult) -> Fig5Row {
    let e = model::energy(r.config, &r.perf);
    Fig5Row {
        config: r.config,
        problem: p,
        utilization: r.utilization(),
        power_mw: e.power.total_mw(),
        gflops: e.gflops,
        gflops_per_w: e.gflops_per_w,
        cycles: r.cycles,
        window_cycles: r.perf.window_cycles,
        conflicts: r.perf.conflicts_total(),
    }
}

/// [`run_point_with`] plus the point's StallScope breakdown (measured
/// on the cycle backend, predicted on the analytic one) — the CLI's
/// `run --profile true` path, one simulation for both outputs.
pub fn profile_point(
    svc: &GemmService,
    config: ConfigId,
    p: Problem,
    layout: LayoutKind,
) -> anyhow::Result<(Fig5Row, crate::profile::StallProfile)> {
    let job = GemmJob::for_problem(config, p.m, p.n, p.k, layout);
    let r = svc.run_job(&job)?;
    Ok((fig5_row(p, &r), r.perf.stalls))
}

/// [`run_point_sharded`] plus the fabric-merged StallScope breakdown.
pub fn profile_point_sharded(
    svc: &GemmService,
    config: ConfigId,
    p: Problem,
    layout: LayoutKind,
    fabric: &FabricConfig,
) -> anyhow::Result<(Fig5Row, crate::profile::StallProfile)> {
    let job = GemmJob::for_problem(config, p.m, p.n, p.k, layout);
    let fr = svc.run_sharded_job(&job, fabric)?;
    let fe = model::fabric_energy(config, &fr.perfs(), fr.cycles);
    let row = Fig5Row {
        config,
        problem: p,
        utilization: fr.mean_utilization(),
        power_mw: fe.power_mw,
        gflops: fe.gflops,
        gflops_per_w: fe.gflops_per_w,
        cycles: fr.cycles,
        window_cycles: fr.window_cycles(),
        conflicts: fr.conflicts_total(),
    };
    Ok((row, fr.stall_profile()))
}

/// Run one (config, problem) point sharded across a cluster fabric.
/// The row carries fabric-level metrics: mean per-cluster utilization,
/// fabric throughput (util x 8 x busy clusters), fabric power
/// including the NoC tax, and end-to-end (slowest-cluster) cycles.
pub fn run_point_sharded(
    svc: &GemmService,
    config: ConfigId,
    p: Problem,
    layout: LayoutKind,
    fabric: &FabricConfig,
) -> anyhow::Result<Fig5Row> {
    profile_point_sharded(svc, config, p, layout, fabric)
        .map(|(row, _)| row)
}

/// The Fig. 5 experiment: `samples` random sizes on every
/// configuration, in parallel across `threads` workers.
pub fn fig5(
    samples: usize,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Vec<Fig5Row>> {
    fig5_with(&GemmService::cycle(), samples, seed, threads)
}

/// Backend-agnostic Fig. 5 sweep through a shared service.
pub fn fig5_with(
    svc: &GemmService,
    samples: usize,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Vec<Fig5Row>> {
    let problems = sample_problems(samples, seed);
    let mut jobs: Vec<(ConfigId, Problem)> = Vec::new();
    for id in ConfigId::all() {
        for &p in &problems {
            jobs.push((id, p));
        }
    }
    let rows = runner::parallel_map(&jobs, threads, |&(id, p)| {
        run_point_with(svc, id, p, LayoutKind::Grouped)
    })?;
    Ok(rows)
}

/// The exhaustive evaluation space: every (M, N, K) in {8..128}^3 on
/// the given configurations. 4096 problems per configuration — triage
/// territory for the analytic backend; hours for the cycle-accurate
/// one.
pub fn sweep_grid(
    svc: &GemmService,
    configs: &[ConfigId],
    threads: usize,
) -> anyhow::Result<Vec<Fig5Row>> {
    sweep_grid_on(svc, configs, threads, &FabricConfig::single())
}

/// [`sweep_grid`] on an N-cluster fabric: every point is sharded
/// through `GemmService::run_sharded` (fabric-level rows).
pub fn sweep_grid_on(
    svc: &GemmService,
    configs: &[ConfigId],
    threads: usize,
    fabric: &FabricConfig,
) -> anyhow::Result<Vec<Fig5Row>> {
    let dims = dim_grid();
    let mut jobs: Vec<(ConfigId, Problem)> = Vec::new();
    for &id in configs {
        for &m in &dims {
            for &n in &dims {
                for &k in &dims {
                    jobs.push((id, Problem { m, n, k }));
                }
            }
        }
    }
    let single = fabric.clusters <= 1;
    runner::parallel_map(&jobs, threads, |&(id, p)| {
        if single {
            run_point_with(svc, id, p, LayoutKind::Grouped)
        } else {
            run_point_sharded(svc, id, p, LayoutKind::Grouped, fabric)
        }
    })
}

// ------------------------------------------------------------------
// Analytic-model calibration
// ------------------------------------------------------------------

/// The default calibration grid: small but structurally diverse
/// (single- and multi-pass, square and skewed, short and long K).
pub fn calibration_grid() -> Vec<Problem> {
    [
        (8, 8, 8),
        (16, 16, 16),
        (32, 32, 32),
        (32, 32, 8),
        (16, 64, 32),
        (64, 32, 16),
        (48, 48, 48),
        (64, 64, 64),
        (96, 64, 80),
    ]
    .iter()
    .map(|&(m, n, k)| Problem { m, n, k })
    .collect()
}

/// Analytic-vs-cycle error summary for one configuration.
#[derive(Clone, Copy, Debug)]
pub struct ErrorRow {
    pub config: ConfigId,
    pub points: usize,
    pub mean_util_err: f64,
    pub max_util_err: f64,
    pub mean_window_err: f64,
    pub max_window_err: f64,
}

pub struct CalibrationOutcome {
    pub calibration: Calibration,
    pub errors: Vec<ErrorRow>,
}

/// Per-configuration error table of a calibrated analytic model
/// against measured cycle-accurate results.
pub fn error_table(
    cal: &Calibration,
    measured: &[GemmResult],
) -> Vec<ErrorRow> {
    ConfigId::all()
        .iter()
        .map(|&id| {
            let mut util_errs = Vec::new();
            let mut win_errs = Vec::new();
            for r in measured.iter().filter(|r| r.config == id) {
                let pred =
                    crate::backend::analytic::predict_perf(cal, id, &r.plan);
                let u_err = (pred.utilization - r.perf.utilization).abs()
                    / r.perf.utilization.max(1e-9);
                let w_err = (pred.window_cycles as f64
                    - r.perf.window_cycles as f64)
                    .abs()
                    / (r.perf.window_cycles as f64).max(1.0);
                util_errs.push(u_err);
                win_errs.push(w_err);
            }
            let mean = |xs: &[f64]| {
                if xs.is_empty() {
                    0.0
                } else {
                    xs.iter().sum::<f64>() / xs.len() as f64
                }
            };
            let max = |xs: &[f64]| {
                xs.iter().cloned().fold(0.0f64, f64::max)
            };
            ErrorRow {
                config: id,
                points: util_errs.len(),
                mean_util_err: mean(&util_errs),
                max_util_err: max(&util_errs),
                mean_window_err: mean(&win_errs),
                max_window_err: max(&win_errs),
            }
        })
        .collect()
}

/// Fit the analytic model against cycle-accurate runs of the default
/// calibration grid and summarize the residual error per config.
pub fn calibrate(threads: usize) -> anyhow::Result<CalibrationOutcome> {
    calibrate_on(&calibration_grid(), threads)
}

pub fn calibrate_on(
    grid: &[Problem],
    threads: usize,
) -> anyhow::Result<CalibrationOutcome> {
    let svc = GemmService::cycle();
    let mut jobs = Vec::new();
    for id in ConfigId::all() {
        for p in grid {
            jobs.push(GemmJob::for_problem(
                id,
                p.m,
                p.n,
                p.k,
                LayoutKind::Grouped,
            ));
        }
        // Fused-epilogue samples so the fit resolves epsilon (the
        // per-element epilogue issue cost) alongside alpha/beta/gamma.
        for (p, epi) in grid.iter().zip(
            [
                Epilogue { bias: true, act: Some(Activation::Relu) },
                Epilogue { bias: true, act: Some(Activation::Gelu) },
            ]
            .iter()
            .cycle(),
        ) {
            jobs.push(GemmJob::fused(
                id,
                p.m,
                p.n,
                p.k,
                LayoutKind::Grouped,
                *epi,
            ));
        }
    }
    let measured = svc.run_batch(&jobs, threads)?;
    let samples: Vec<CalSample> =
        measured.iter().map(CalSample::from_result).collect();
    let mut calibration = fit_calibration(&samples);

    // NoC-contention calibration: a DMA-bound sharded shape measured
    // on a deliberately starved cycle fabric (8 branches, 1 beat/cycle
    // of link budget) pins each config's `delta` between the
    // contention-free and fully-serialized analytic predictions.
    // Compute-bound samples carry no signal (the spread is zero) and
    // leave the shipped default in place.
    let fabric = FabricConfig {
        clusters: 8,
        noc: NocConfig { links: 1, beats_per_link: 1 },
    };
    let factor = fabric.noc_factor();
    let (nm, nn, nk) = (256usize, 256usize, 8usize);
    for id in ConfigId::all() {
        let sh = svc.prepare_sharded(
            id,
            nm,
            nn,
            nk,
            LayoutKind::Grouped,
            Epilogue::NONE,
            fabric.clusters,
        )?;
        if sh.grid.used_clusters() < 2 {
            continue;
        }
        let job =
            GemmJob::for_problem(id, nm, nn, nk, LayoutKind::Grouped);
        let fr = svc.run_sharded_job(&job, &fabric)?;
        let predict = |delta: f64| -> f64 {
            let mut c = calibration.clone();
            let mut cc = c.get(id);
            cc.delta = delta;
            c.set(id, cc);
            predict_perf_noc(&c, id, &sh.prep.plan, factor)
                .window_cycles as f64
        };
        let sample = NocSample {
            window_measured: fr.window_cycles() as f64,
            window_free: predict(0.0),
            window_serialized: predict(1.0),
        };
        if let Some(d) = fit_delta(&[sample]) {
            let mut cc = calibration.get(id);
            cc.delta = d;
            calibration.set(id, cc);
        }
    }
    // The error table reports the plain-GEMM points (the paper's
    // evaluation space); fused accuracy is covered by the NetGraph
    // tests and the `net` report.
    let plain: Vec<GemmResult> = measured
        .into_iter()
        .filter(|r| r.plan.epi.is_none())
        .collect();
    let errors = error_table(&calibration, &plain);
    Ok(CalibrationOutcome { calibration, errors })
}

/// Per-configuration box statistics over a metric.
#[derive(Clone, Debug)]
pub struct Fig5Summary {
    pub config: ConfigId,
    pub utilization: BoxStats,
    pub power_mw: BoxStats,
    pub gflops_per_w: BoxStats,
}

pub fn fig5_summary(rows: &[Fig5Row]) -> Vec<Fig5Summary> {
    ConfigId::all()
        .iter()
        .map(|&id| {
            let sel: Vec<&Fig5Row> =
                rows.iter().filter(|r| r.config == id).collect();
            let take = |f: fn(&Fig5Row) -> f64| -> BoxStats {
                box_stats(&sel.iter().map(|r| f(r)).collect::<Vec<_>>())
            };
            Fig5Summary {
                config: id,
                utilization: take(|r| r.utilization),
                power_mw: take(|r| r.power_mw),
                gflops_per_w: take(|r| r.gflops_per_w),
            }
        })
        .collect()
}

/// The §IV-B / abstract headline: median performance and energy-
/// efficiency improvement of Zonl48Db over Base32fc, and the
/// utilization band of the optimized configurations.
#[derive(Clone, Copy, Debug)]
pub struct Headline {
    pub perf_gain_pct: f64,
    pub eff_gain_pct: f64,
    pub zonl48_util_min: f64,
    pub zonl48_util_max: f64,
    pub base_util_median: f64,
    pub zonl48_util_median: f64,
}

pub fn headline(rows: &[Fig5Row]) -> Headline {
    let summaries = fig5_summary(rows);
    let get = |id: ConfigId| {
        summaries.iter().find(|s| s.config == id).unwrap().clone()
    };
    let base = get(ConfigId::Base32Fc);
    let z48 = get(ConfigId::Zonl48Db);
    // Per-problem speedup medians (paired, like the paper's median
    // performance improvement).
    let mut speedups = Vec::new();
    let mut eff_gains = Vec::new();
    for r in rows.iter().filter(|r| r.config == ConfigId::Zonl48Db) {
        if let Some(b) = rows.iter().find(|b| {
            b.config == ConfigId::Base32Fc && b.problem == r.problem
        }) {
            speedups
                .push(b.window_cycles as f64 / r.window_cycles as f64);
            eff_gains.push(r.gflops_per_w / b.gflops_per_w);
        }
    }
    let med = |xs: &[f64]| box_stats(xs).median;
    // Utilization band excluding Tukey outliers (paper: "excluding a
    // few outliers").
    let z48_utils: Vec<f64> = rows
        .iter()
        .filter(|r| r.config == ConfigId::Zonl48Db)
        .map(|r| r.utilization)
        .collect();
    let (wlo, whi) = box_stats(&z48_utils).whiskers(&z48_utils);
    Headline {
        perf_gain_pct: (med(&speedups) - 1.0) * 100.0,
        eff_gain_pct: (med(&eff_gains) - 1.0) * 100.0,
        zonl48_util_min: wlo,
        zonl48_util_max: whi,
        base_util_median: base.utilization.median,
        zonl48_util_median: z48.utilization.median,
    }
}

// ------------------------------------------------------------------
// Table I / Fig. 4
// ------------------------------------------------------------------

pub fn table1() -> Vec<AreaBreakdown> {
    model::table1()
}

// ------------------------------------------------------------------
// Table II
// ------------------------------------------------------------------

/// One comparison row of Table II.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub name: String,
    pub area_comp: f64,
    pub area_mem: f64,
    pub area_interco: f64,
    pub area_ctrl: f64,
    pub area_total: f64,
    pub pow_comp: f64,
    pub pow_mem: f64,
    pub pow_interco: f64,
    pub pow_ctrl: f64,
    pub pow_total: f64,
    pub utilization: f64,
    pub perf_gflops: f64,
    pub area_eff: f64,
    pub energy_eff: f64,
}

/// Table II: ours (Zonl48Db) vs baseline Snitch vs OpenGeMM on 32^3.
pub fn table2() -> anyhow::Result<Vec<Table2Row>> {
    let p = Problem { m: 32, n: 32, k: 32 };
    let mut rows = Vec::new();
    for (name, id) in [
        ("ours [zonl48db]", ConfigId::Zonl48Db),
        ("snitch [base32fc]", ConfigId::Base32Fc),
    ] {
        let point = run_point(id, p, LayoutKind::Grouped)?;
        let seed = (p.m as u64) << 32 | (p.n as u64) << 16 | p.k as u64;
        let (a, b) = test_matrices(p.m, p.n, p.k, seed);
        let r = crate::kernels::run_matmul(id, p.m, p.n, p.k, &a, &b)?;
        let e = model::energy(id, &r.perf);
        let ar = model::area(id);
        rows.push(Table2Row {
            name: name.to_string(),
            area_comp: ar.compute_mge,
            area_mem: ar.mem_mge,
            area_interco: ar.interco_mge,
            area_ctrl: ar.ctrl_mge,
            area_total: ar.total_mge(),
            pow_comp: e.power.compute_mw,
            pow_mem: e.power.mem_mw,
            pow_interco: e.power.interco_mw,
            pow_ctrl: e.power.ctrl_mw,
            pow_total: e.power.total_mw(),
            utilization: point.utilization,
            perf_gflops: e.gflops,
            area_eff: e.gflops_per_mm2,
            energy_eff: e.gflops_per_w,
        });
    }
    let (og, oa, op) = opengemm::table2_row();
    rows.push(Table2Row {
        name: "opengemm [6]".to_string(),
        area_comp: oa.compute_mge,
        area_mem: oa.mem_interco_mge,
        area_interco: 0.0, // folded into mem (paper's column layout)
        area_ctrl: oa.ctrl_mge,
        area_total: oa.total_mge(),
        pow_comp: op.compute_mw,
        pow_mem: op.mem_interco_mw,
        pow_interco: 0.0,
        pow_ctrl: op.ctrl_mw,
        pow_total: op.total_mw(),
        utilization: og.utilization,
        perf_gflops: og.gflops,
        area_eff: og.gflops / oa.total_mm2(),
        energy_eff: og.gflops / (op.total_mw() / 1e3),
    });
    Ok(rows)
}

// ------------------------------------------------------------------
// Ablations
// ------------------------------------------------------------------

/// Layout ablation: grouped (paper) vs linear placement.
#[derive(Clone, Copy, Debug)]
pub struct AblationRow {
    pub config: ConfigId,
    pub layout: &'static str,
    pub utilization: f64,
    pub conflicts: u64,
}

pub fn layout_ablation(p: Problem) -> anyhow::Result<Vec<AblationRow>> {
    let mut out = Vec::new();
    for id in ConfigId::all() {
        for (name, kind) in [
            ("grouped", LayoutKind::Grouped),
            ("linear", LayoutKind::Linear { pad_words: 0 }),
            ("linear+pad", LayoutKind::Linear { pad_words: 1 }),
        ] {
            let r = run_point(id, p, kind)?;
            out.push(AblationRow {
                config: id,
                layout: name,
                utilization: r.utilization,
                conflicts: r.conflicts,
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_small_sweep_ordering() {
        // 6 samples is enough to check the structural ordering.
        let rows = fig5(6, 123, 2).unwrap();
        assert_eq!(rows.len(), 6 * 5);
        let s = fig5_summary(&rows);
        let med = |id: ConfigId| {
            s.iter().find(|x| x.config == id).unwrap().utilization.median
        };
        assert!(med(ConfigId::Zonl48Db) > med(ConfigId::Base32Fc));
        assert!(med(ConfigId::Zonl64Fc) >= med(ConfigId::Zonl32Fc));
    }

    #[test]
    fn headline_positive_gains() {
        let rows = fig5(8, 7, 2).unwrap();
        let h = headline(&rows);
        assert!(h.perf_gain_pct > 0.0, "perf gain {}", h.perf_gain_pct);
        assert!(
            h.zonl48_util_median > h.base_util_median,
            "median ordering"
        );
    }

    #[test]
    fn table2_rows_complete() {
        let rows = table2().unwrap();
        assert_eq!(rows.len(), 3);
        let ours = &rows[0];
        let og = &rows[2];
        // The paper's story: comparable perf, within ~12% energy eff.
        assert!(ours.utilization > 0.95);
        let eff_gap = (og.energy_eff - ours.energy_eff) / og.energy_eff;
        assert!(
            eff_gap.abs() < 0.25,
            "energy-eff gap {:.2} too large",
            eff_gap
        );
    }

    #[test]
    fn fig5_identical_through_shared_service() {
        // The memoizing service path must reproduce the one-shot path
        // bit for bit (pure refactor guarantee).
        let svc = GemmService::cycle();
        let p = Problem { m: 16, n: 16, k: 16 };
        let via_svc =
            run_point_with(&svc, ConfigId::Zonl48Db, p, LayoutKind::Grouped)
                .unwrap();
        let one_shot =
            run_point(ConfigId::Zonl48Db, p, LayoutKind::Grouped).unwrap();
        assert_eq!(via_svc.cycles, one_shot.cycles);
        assert_eq!(via_svc.window_cycles, one_shot.window_cycles);
        assert_eq!(via_svc.utilization, one_shot.utilization);
        assert_eq!(via_svc.conflicts, one_shot.conflicts);
    }

    #[test]
    fn analytic_full_grid_sweep_completes() {
        // The whole {8..128}^3 space on one config — plan-only, no
        // machine stepping, so this stays test-suite fast.
        let svc = GemmService::analytic();
        let rows = sweep_grid(&svc, &[ConfigId::Zonl48Db], 4).unwrap();
        assert_eq!(rows.len(), 16 * 16 * 16);
        for r in &rows {
            assert!(
                r.utilization > 0.0 && r.utilization <= 1.0,
                "{} {}: util {}",
                r.config.name(),
                r.problem,
                r.utilization
            );
            assert!(r.cycles > 0);
        }
    }

    #[test]
    fn calibration_fits_and_bounds_error() {
        // Small compute-bound grid: after fitting, the analytic model
        // must track the cycle-accurate windows closely on it.
        let grid: Vec<Problem> = [
            (8, 8, 8),
            (16, 16, 16),
            (32, 32, 32),
            (16, 32, 24),
            (32, 16, 40),
        ]
        .iter()
        .map(|&(m, n, k)| Problem { m, n, k })
        .collect();
        let out = calibrate_on(&grid, 2).unwrap();
        for e in &out.errors {
            assert_eq!(e.points, grid.len());
            assert!(
                e.mean_window_err < 0.20,
                "{}: mean window err {:.3}",
                e.config.name(),
                e.mean_window_err
            );
            assert!(
                e.mean_util_err < 0.20,
                "{}: mean util err {:.3}",
                e.config.name(),
                e.mean_util_err
            );
        }
    }

    #[test]
    fn layout_ablation_grouped_wins() {
        let rows =
            layout_ablation(Problem { m: 32, n: 32, k: 32 }).unwrap();
        let get = |id: ConfigId, l: &str| {
            rows.iter()
                .find(|r| r.config == id && r.layout == l)
                .unwrap()
                .utilization
        };
        assert!(
            get(ConfigId::Zonl48Db, "grouped")
                > get(ConfigId::Zonl48Db, "linear")
        );
    }
}
