//! The `zerostall lint` runner: ProofScope static stall verdicts for
//! every GEMM layer of a zoo model, differentially gated against
//! StallScope measurements.
//!
//! For each layer the runner asks the analyzer (`crate::verify`) for
//! a [`StaticStallReport`] on the exact plan the `GemmService` would
//! execute, then — unless `--gate false` — runs the layer on the
//! cycle engine (FastPath on *and* off) and the analytic model and
//! checks every measurement against the verdicts:
//!
//! * cycle sources: `Impossible` ⇒ 0 measured cycles, `Bounded(n)` ⇒
//!   at most `n`, plus the DMA facet (`dma_phase_disjoint` proved ⇒
//!   the interconnect counted zero DMA-vs-core conflicts);
//! * the analytic source: `Impossible`-only (plus the DMA facet) —
//!   its stall decomposition approximates magnitudes, so structural
//!   bounds are the cycle engine's contract, but a class proved
//!   impossible must be absent from a faithful prediction too.
//!
//! A violation is a soundness bug — in the analyzer or in the machine
//! model — and fails the run (and CI). Elementwise ops have no kernel
//! to verify and are skipped, mirroring `zerostall profile`.

use anyhow::Result;

use crate::backend::BackendKind;
use crate::cluster::ConfigId;
use crate::fabric::FabricConfig;
use crate::kernels::{
    choose_shard_grid, GemmJob, GemmService, LayoutKind,
};
use crate::profile::N_CLASSES;
use crate::verify::{class_totals, StaticStallReport};

use super::workload::graph::NetOp;
use super::workload::{zoo, Problem};

/// Lint-run parameters.
#[derive(Clone, Debug)]
pub struct LintOpts {
    pub model: String,
    pub config: ConfigId,
    pub clusters: usize,
    pub layout: LayoutKind,
    /// Run the measured backends and assert the differential gate
    /// (off = static verdicts only, no simulation).
    pub gate: bool,
}

impl LintOpts {
    pub fn new(model: &str) -> LintOpts {
        LintOpts {
            model: model.to_string(),
            config: ConfigId::Zonl48Db,
            clusters: 1,
            layout: LayoutKind::Grouped,
            gate: true,
        }
    }
}

/// One measured source checked against the verdicts.
#[derive(Clone, Debug)]
pub struct SourceMeasure {
    /// "cycle+ff" | "cycle" | "analytic".
    pub source: &'static str,
    /// Stall cycles per class, summed over every core.
    pub classes: [u64; N_CLASSES],
    /// DMA-vs-core conflicts counted by the interconnect(s).
    pub tcdm_conflicts_dma: u64,
}

/// One linted GEMM layer.
#[derive(Clone, Debug)]
pub struct LayerLint {
    pub name: String,
    pub problem: Problem,
    pub epilogue: String,
    /// Clusters the layer would run on (1 = whole on one cluster);
    /// the verdict is scaled to this placement.
    pub shards: usize,
    pub report: StaticStallReport,
    /// Empty unless `gate` was off.
    pub measured: Vec<SourceMeasure>,
    /// Differential-gate violations for this layer.
    pub failures: Vec<String>,
}

/// The whole lint run.
#[derive(Clone, Debug)]
pub struct LintReport {
    pub model: String,
    pub config: ConfigId,
    pub clusters: usize,
    pub gated: bool,
    pub layers: Vec<LayerLint>,
    /// Elementwise ops skipped (no kernel to verify).
    pub skipped_adds: usize,
}

impl LintReport {
    /// Every differential-gate violation across all layers.
    pub fn failures(&self) -> Vec<String> {
        self.layers.iter().flat_map(|l| l.failures.clone()).collect()
    }
}

/// Run the linter. A non-empty `report.failures()` means the
/// differential soundness gate failed; the caller decides whether
/// that is fatal (the CLI and CI treat it as such).
pub fn run_lint(opts: &LintOpts) -> Result<LintReport> {
    let g = zoo::build(&opts.model)?;
    let order = g.topo_order()?;
    let clusters = opts.clusters.max(1);
    let fabric = FabricConfig::new(clusters);
    // Plans (and their cached verdicts) come from a cycle service so
    // the analyzer sees the real encoded programs, not a regeneration.
    let plan_svc = GemmService::cycle();
    let sources: Vec<(&'static str, GemmService)> = if opts.gate {
        vec![
            (
                "cycle+ff",
                GemmService::of_kind_ff(BackendKind::Cycle, true),
            ),
            ("cycle", GemmService::of_kind_ff(BackendKind::Cycle, false)),
            ("analytic", GemmService::analytic()),
        ]
    } else {
        Vec::new()
    };

    let mut layers = Vec::new();
    let mut skipped_adds = 0usize;
    for &oi in &order {
        let NetOp::Gemm { name, x, w, epi, .. } = &g.ops[oi] else {
            skipped_adds += 1;
            continue;
        };
        let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
        let p = Problem { m: xt.rows, n: wt.cols, k: xt.cols };
        let grid = choose_shard_grid(p.m, p.n, clusters);
        let sharded = clusters > 1 && grid.used_clusters() > 1;

        let (base, shards) = if sharded {
            let sh = plan_svc.prepare_sharded(
                opts.config,
                p.m,
                p.n,
                p.k,
                opts.layout,
                *epi,
                clusters,
            )?;
            (sh.prep.lint(), grid.used_clusters())
        } else {
            let prep = plan_svc.prepare_fused(
                opts.config,
                p.m,
                p.n,
                p.k,
                opts.layout,
                *epi,
            )?;
            (prep.lint(), 1)
        };
        let report = base.for_clusters(shards);

        let mut measured = Vec::new();
        let mut failures = Vec::new();
        for (source, svc) in &sources {
            let job = GemmJob::fused(
                opts.config,
                p.m,
                p.n,
                p.k,
                opts.layout,
                *epi,
            );
            let (classes, dma_conf) = if sharded {
                let fr = svc.run_sharded_job(&job, &fabric)?;
                let conf: u64 = fr
                    .perfs()
                    .iter()
                    .map(|pf| pf.tcdm_conflicts_dma)
                    .sum();
                (class_totals(&fr.stall_profile()), conf)
            } else {
                let res = svc.run_job(&job)?;
                (
                    class_totals(&res.perf.stalls),
                    res.perf.tcdm_conflicts_dma,
                )
            };
            let tag = format!("{name}[{source}]");
            let gate_report = if *source == "analytic" {
                report.impossible_only()
            } else {
                report.clone()
            };
            failures.extend(gate_report.gate(&tag, &classes));
            failures.extend(report.gate_dma(&tag, dma_conf));
            measured.push(SourceMeasure {
                source: *source,
                classes,
                tcdm_conflicts_dma: dma_conf,
            });
        }

        layers.push(LayerLint {
            name: name.clone(),
            problem: p,
            epilogue: epi.name(),
            shards,
            report,
            measured,
            failures,
        });
    }

    Ok(LintReport {
        model: opts.model.clone(),
        config: opts.config,
        clusters,
        gated: opts.gate,
        layers,
        skipped_adds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::StallClass;
    use crate::verify::Verdict;

    #[test]
    fn lint_ffn_static_only() {
        let mut opts = LintOpts::new("ffn");
        opts.gate = false;
        let rep = run_lint(&opts).unwrap();
        assert_eq!(rep.layers.len(), 2);
        assert_eq!(rep.skipped_adds, 1);
        assert!(!rep.gated);
        for l in &rep.layers {
            assert!(l.measured.is_empty());
            assert!(l.failures.is_empty());
            assert_eq!(
                l.report.verdict(StallClass::RawHazard),
                Verdict::Impossible,
                "{}",
                l.name
            );
        }
    }

    #[test]
    fn lint_gated_mlp_passes_the_differential_gate() {
        let rep = run_lint(&LintOpts::new("mlp")).unwrap();
        assert!(rep.gated);
        let fails = rep.failures();
        assert!(fails.is_empty(), "soundness gate violated: {fails:?}");
        for l in &rep.layers {
            assert_eq!(l.measured.len(), 3, "{}", l.name);
        }
    }

    #[test]
    fn lint_rejects_unknown_model() {
        assert!(run_lint(&LintOpts::new("resnet9000")).is_err());
    }
}
