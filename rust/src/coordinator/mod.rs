//! Experiment orchestration: workload sampling, the NetGraph DAG
//! runner, the request-level serving simulator, the multi-threaded
//! sweep runner, report rendering, and the CLI.

pub mod cli;
pub mod experiments;
pub mod lint;
pub mod net;
pub mod node;
pub mod profile;
pub mod report;
pub mod runner;
pub mod serve;
pub mod workload;
