//! Experiment orchestration: workload sampling, the NetGraph DAG
//! runner, the multi-threaded sweep runner, report rendering, and the
//! CLI.

pub mod cli;
pub mod experiments;
pub mod net;
pub mod report;
pub mod runner;
pub mod workload;
