//! NetRunner — dependency-aware DAG execution of a [`NetGraph`] on a
//! [`GemmService`].
//!
//! The scheduler derives readiness purely from the tensor dependency
//! structure (not from op order, which property tests shuffle), runs
//! each ready *wave* in parallel through the service's plan cache, and
//! feeds layer outputs forward as next-layer operands. GEMM epilogues
//! (bias/activation) execute fused in the kernels' writeback pass;
//! standalone residual adds run as an elementwise pass with an
//! explicit cost model and are charged the TCDM round-trips fusion
//! avoids — the report's tensor-lifetime accounting makes the "zero
//! extra round-trips" claim checkable.
//!
//! On the cycle backend execution is functional: inputs and parameters
//! are generated deterministically from the run seed, every layer's
//! output tensor is real, and results are bit-identical to running
//! each layer sequentially through the one-shot driver. The analytic
//! backend schedules the same DAG without materializing data.

use anyhow::{bail, Result};

use crate::backend::BackendKind;
use crate::cluster::{ClusterPerf, ConfigId};
use crate::kernels::{GemmService, LayoutKind, ServiceStats, N_CORES};
use crate::model;
use crate::util::rng::Rng;

use super::runner;
use super::workload::graph::{NetGraph, NetOp, TensorKind};
use super::workload::Problem;

/// Per-layer execution record.
#[derive(Clone, Debug)]
pub struct LayerRow {
    pub name: String,
    /// "gemm" or "add".
    pub kind: &'static str,
    /// GEMM shape (None for elementwise ops).
    pub problem: Option<Problem>,
    /// Fused-epilogue label ("bias+gelu", "none", ...).
    pub epilogue: String,
    pub cycles: u64,
    pub window_cycles: u64,
    pub utilization: f64,
    pub power_mw: f64,
    pub energy_uj: f64,
    /// Exact FPU ops this layer issued (MACs + epilogue/elementwise).
    pub fpu_ops: u64,
    /// Elementwise ops folded into the GEMM writeback (bias adds +
    /// activations), i.e. TCDM round-trips fusion avoided.
    pub fused_elems: u64,
    /// TCDM round-trips this layer performs *beyond* the GEMM's own
    /// streaming (unfused elementwise passes). Zero for fused layers.
    pub extra_roundtrips: u64,
}

/// Whole-network execution report.
#[derive(Clone, Debug)]
pub struct NetReport {
    pub model: String,
    pub config: ConfigId,
    pub backend: BackendKind,
    pub layers: Vec<LayerRow>,
    /// End-to-end cycles, layers serialized in wave order (one
    /// cluster executes the whole network).
    pub total_cycles: u64,
    pub total_energy_uj: f64,
    /// End-to-end FPU utilization over the summed compute windows.
    pub utilization: f64,
    pub total_macs: u64,
    /// Peak bytes of simultaneously-live tensors (lifetime
    /// accounting over the wave schedule).
    pub peak_live_bytes: usize,
    pub fused_elems: u64,
    pub extra_roundtrips: u64,
    pub plan_stats: ServiceStats,
}

/// A completed network run: the report plus the network's output
/// tensors (empty data vectors on non-functional backends).
pub struct NetRun {
    pub report: NetReport,
    pub outputs: Vec<(String, Vec<f64>)>,
}

/// Deterministic contents for an input/parameter tensor.
pub fn tensor_data(seed: u64, tid: usize, elems: usize) -> Vec<f64> {
    let mut rng =
        Rng::new(seed ^ (tid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..elems).map(|_| rng.normal()).collect()
}

/// Cost model for an unfused elementwise pass over `elems` elements:
/// the compute cores split the rows, each element is a
/// load-compute-store round trip through the LSU (3 TCDM accesses),
/// plus a fixed pass overhead.
fn add_pass_cycles(elems: usize) -> u64 {
    (elems as u64).div_ceil(N_CORES as u64) * 3 + 64
}

/// Synthetic perf-counter snapshot for an elementwise pass (feeds the
/// energy model with its actual activity).
fn add_pass_perf(elems: usize) -> ClusterPerf {
    let cycles = add_pass_cycles(elems);
    ClusterPerf {
        cycles,
        window_cycles: cycles,
        fpu_ops_total: elems as u64,
        utilization: elems as f64
            / (cycles as f64 * N_CORES as f64),
        int_instrs: 2 * (elems as u64) + 64,
        icache_fetches: 4 * (elems as u64).div_ceil(N_CORES as u64) + 64,
        tcdm_core_accesses: 3 * elems as u64,
        ssr_requests: 3 * elems as u64,
        ..ClusterPerf::default()
    }
}

enum WaveOut {
    Gemm(crate::kernels::GemmResult),
    Add { data: Vec<f64>, elems: usize },
}

/// Execute a network graph on one cluster configuration through a
/// shared service.
pub fn run_net(
    svc: &GemmService,
    g: &NetGraph,
    config: ConfigId,
    layout: LayoutKind,
    threads: usize,
    seed: u64,
) -> Result<NetRun> {
    let functional = svc.backend_kind() == BackendKind::Cycle;
    let nt = g.tensors.len();

    // --- dependency structure (derived, not trusted from op order) ---
    let (_, mut deps, dependents) = g.dependency_structure()?;
    // consumers per tensor (for lifetime accounting)
    let mut consumers: Vec<usize> = vec![0; nt];
    for op in &g.ops {
        for t in op.inputs() {
            consumers[t] += 1;
        }
    }

    // --- materialize inputs / parameters ------------------------------
    let mut store: Vec<Option<Vec<f64>>> = vec![None; nt];
    let mut live_bytes = 0usize;
    let mut peak_live_bytes = 0usize;
    for (tid, t) in g.tensors.iter().enumerate() {
        if t.kind != TensorKind::Computed {
            if functional {
                store[tid] = Some(tensor_data(seed, tid, t.elems()));
            }
            live_bytes += t.bytes();
        }
    }
    peak_live_bytes = peak_live_bytes.max(live_bytes);

    // --- wave-scheduled execution -------------------------------------
    let mut done = vec![false; g.ops.len()];
    let mut n_done = 0usize;
    let mut layers: Vec<LayerRow> = Vec::new();
    let mut total_cycles = 0u64;
    let mut total_energy = 0.0f64;
    let mut window_sum = 0u64;
    let mut fpu_sum = 0u64;
    let mut fused_elems = 0u64;
    let mut extra_roundtrips = 0u64;

    while n_done < g.ops.len() {
        let wave: Vec<usize> = (0..g.ops.len())
            .filter(|&i| !done[i] && deps[i] == 0)
            .collect();
        if wave.is_empty() {
            bail!(
                "network graph deadlocked: {} of {} ops unschedulable",
                g.ops.len() - n_done,
                g.ops.len()
            );
        }
        let outs: Vec<WaveOut> =
            runner::parallel_map(&wave, threads, |&i| {
                match &g.ops[i] {
                    NetOp::Gemm { x, w, bias, epi, .. } => {
                        let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
                        let (m, n, k) = (xt.rows, wt.cols, xt.cols);
                        let x_data: &[f64] =
                            store[*x].as_deref().unwrap_or(&[]);
                        let w_data: &[f64] =
                            store[*w].as_deref().unwrap_or(&[]);
                        let bias_data: &[f64] = match bias {
                            Some(b) if functional => {
                                store[*b].as_deref().unwrap_or(&[])
                            }
                            _ => &[],
                        };
                        let r = svc.run_fused(
                            config,
                            m,
                            n,
                            k,
                            layout,
                            *epi,
                            x_data,
                            w_data,
                            bias_data,
                        )?;
                        Ok(WaveOut::Gemm(r))
                    }
                    NetOp::Add { a, b, out, .. } => {
                        let elems = g.tensors[*out].elems();
                        let data = if functional {
                            let av = store[*a].as_ref().unwrap();
                            let bv = store[*b].as_ref().unwrap();
                            av.iter()
                                .zip(bv.iter())
                                .map(|(x, y)| x + y)
                                .collect()
                        } else {
                            Vec::new()
                        };
                        Ok(WaveOut::Add { data, elems })
                    }
                }
            })?;

        // Commit the wave: record rows, store outputs, free dead
        // tensors, release dependents.
        for (&i, out) in wave.iter().zip(outs) {
            let op = &g.ops[i];
            let row = match (op, out) {
                (NetOp::Gemm { name, epi, out, .. }, WaveOut::Gemm(r)) => {
                    let e = model::energy(config, &r.perf);
                    let t = &g.tensors[*out];
                    let fused =
                        (t.elems() * (usize::from(epi.bias)
                            + usize::from(epi.act.is_some())))
                            as u64;
                    if functional {
                        store[*out] = Some(r.c.clone());
                    }
                    live_bytes += t.bytes();
                    // peak while output and inputs coexist, before
                    // dead inputs are freed below
                    peak_live_bytes = peak_live_bytes.max(live_bytes);
                    LayerRow {
                        name: name.clone(),
                        kind: "gemm",
                        problem: Some(Problem {
                            m: r.plan.tiling.m,
                            n: r.plan.tiling.n,
                            k: r.plan.tiling.k,
                        }),
                        epilogue: epi.name(),
                        cycles: r.cycles,
                        window_cycles: r.perf.window_cycles,
                        utilization: r.perf.utilization,
                        power_mw: e.power.total_mw(),
                        energy_uj: e.energy_uj,
                        fpu_ops: r.perf.fpu_ops_total,
                        fused_elems: fused,
                        extra_roundtrips: 0,
                    }
                }
                (
                    NetOp::Add { name, out, .. },
                    WaveOut::Add { data, elems },
                ) => {
                    let perf = add_pass_perf(elems);
                    let e = model::energy(config, &perf);
                    let t = &g.tensors[*out];
                    if functional {
                        store[*out] = Some(data);
                    }
                    live_bytes += t.bytes();
                    peak_live_bytes = peak_live_bytes.max(live_bytes);
                    LayerRow {
                        name: name.clone(),
                        kind: "add",
                        problem: None,
                        epilogue: "unfused".to_string(),
                        cycles: perf.cycles,
                        window_cycles: perf.window_cycles,
                        utilization: perf.utilization,
                        power_mw: e.power.total_mw(),
                        energy_uj: e.energy_uj,
                        fpu_ops: perf.fpu_ops_total,
                        fused_elems: 0,
                        extra_roundtrips: elems as u64,
                    }
                }
                _ => unreachable!("wave output kind matches its op"),
            };
            total_cycles += row.cycles;
            total_energy += row.energy_uj;
            window_sum += row.window_cycles;
            fpu_sum += row.fpu_ops;
            fused_elems += row.fused_elems;
            extra_roundtrips += row.extra_roundtrips;
            layers.push(row);

            done[i] = true;
            n_done += 1;
            for t in op.inputs() {
                consumers[t] -= 1;
                if consumers[t] == 0 {
                    // dead tensor: release it
                    live_bytes =
                        live_bytes.saturating_sub(g.tensors[t].bytes());
                    store[t] = None;
                }
            }
            for &d in &dependents[i] {
                deps[d] -= 1;
            }
        }
        peak_live_bytes = peak_live_bytes.max(live_bytes);
    }

    // --- collect network outputs --------------------------------------
    let out_ids = g.outputs();
    let outputs: Vec<(String, Vec<f64>)> = out_ids
        .iter()
        .map(|&tid| {
            (
                g.tensors[tid].name.clone(),
                store[tid].take().unwrap_or_default(),
            )
        })
        .collect();

    let report = NetReport {
        model: g.name.clone(),
        config,
        backend: svc.backend_kind(),
        layers,
        total_cycles,
        total_energy_uj: total_energy,
        utilization: if window_sum == 0 {
            0.0
        } else {
            fpu_sum as f64 / (window_sum as f64 * N_CORES as f64)
        },
        total_macs: g.macs(),
        peak_live_bytes,
        fused_elems,
        extra_roundtrips,
        plan_stats: svc.stats(),
    };
    Ok(NetRun { report, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::workload::zoo;

    #[test]
    fn analytic_net_run_schedules_all_layers() {
        let svc = GemmService::analytic();
        let g = zoo::build("ffn").unwrap();
        let run = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            2,
            7,
        )
        .unwrap();
        assert_eq!(run.report.layers.len(), g.ops.len());
        assert!(run.report.total_cycles > 0);
        assert!(run.report.utilization > 0.0);
        assert!(run.report.peak_live_bytes > 0);
        // both GEMMs fused: only the residual add pays round-trips
        assert_eq!(run.report.extra_roundtrips, 64 * 64);
        assert!(run.report.fused_elems > 0);
    }

    #[test]
    fn cycle_net_run_is_functional_and_fused() {
        let svc = GemmService::cycle();
        let g = zoo::mlp(16, &[16, 24, 16]).unwrap();
        let run = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            2,
            11,
        )
        .unwrap();
        assert_eq!(run.outputs.len(), 1);
        let (_, y) = &run.outputs[0];
        assert_eq!(y.len(), 16 * 16);
        assert!(y.iter().all(|v| v.is_finite()));
        // all layers fused -> zero extra TCDM round-trips
        assert_eq!(run.report.extra_roundtrips, 0);
        assert_eq!(
            run.report.fused_elems,
            (16 * 24 * 2 + 16 * 16) as u64,
            "bias+relu on layer 0, bias on layer 1"
        );
    }

    #[test]
    fn scheduler_detects_cycles() {
        use crate::coordinator::workload::graph::{
            NetGraph, NetOp, Tensor, TensorKind,
        };
        // Hand-assemble a 2-op cycle: op0 reads t1 writes t0, op1
        // reads t0 writes t1.
        let mut g = NetGraph::new("cyclic");
        for name in ["t0", "t1"] {
            g.tensors.push(Tensor {
                name: name.to_string(),
                rows: 8,
                cols: 8,
                kind: TensorKind::Computed,
            });
        }
        g.ops.push(NetOp::Add {
            name: "a".into(),
            a: 1,
            b: 1,
            out: 0,
        });
        g.ops.push(NetOp::Add {
            name: "b".into(),
            a: 0,
            b: 0,
            out: 1,
        });
        let svc = GemmService::analytic();
        let err = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            1,
            0,
        );
        assert!(err.is_err());
        assert!(g.topo_order().is_err());
    }
}
