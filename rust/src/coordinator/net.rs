//! NetRunner — dependency-aware DAG execution of a [`NetGraph`] on a
//! [`GemmService`].
//!
//! The scheduler derives readiness purely from the tensor dependency
//! structure (not from op order, which property tests shuffle), runs
//! each ready *wave* in parallel through the service's plan cache, and
//! feeds layer outputs forward as next-layer operands. GEMM epilogues
//! (bias/activation) execute fused in the kernels' writeback pass;
//! standalone residual adds run as an elementwise pass with an
//! explicit cost model and are charged the TCDM round-trips fusion
//! avoids — the report's tensor-lifetime accounting makes the "zero
//! extra round-trips" claim checkable.
//!
//! On the cycle backend execution is functional: inputs and parameters
//! are generated deterministically from the run seed, every layer's
//! output tensor is real, and results are bit-identical to running
//! each layer sequentially through the one-shot driver. The analytic
//! backend schedules the same DAG without materializing data.
//!
//! On a multi-cluster fabric ([`run_net_clustered`]) the scheduler
//! exploits both parallelism axes: independent ops of one ready wave
//! are placed on different clusters round-robin (*layer-parallel* —
//! the wave takes the busiest cluster's time), and a wave that is one
//! large GEMM is sharded across the fabric through
//! `GemmService::run_sharded` (*tensor-parallel* — numerics stay
//! bit-identical because K is shard-local). The report carries
//! per-cluster and fabric-level utilization/energy next to the
//! single-cluster serialization baseline.

use anyhow::{bail, Result};

use crate::backend::BackendKind;
use crate::cluster::{ClusterPerf, ConfigId};
use crate::fabric::{FabricConfig, FabricResult};
use crate::kernels::tiling::choose_shard_grid;
use crate::kernels::{GemmService, LayoutKind, ServiceStats, N_CORES};
use crate::model;
use crate::profile::roofline::{self, Ceilings, RooflinePoint};
use crate::profile::N_CLASSES;
use crate::util::rng::Rng;
use crate::util::stats::ratio;

use super::runner;
use super::workload::graph::{NetGraph, NetOp, TensorKind};
use super::workload::Problem;

/// Per-layer execution record.
#[derive(Clone, Debug)]
pub struct LayerRow {
    pub name: String,
    /// "gemm" or "add".
    pub kind: &'static str,
    /// GEMM shape (None for elementwise ops).
    pub problem: Option<Problem>,
    /// Fused-epilogue label ("bias+gelu", "none", ...).
    pub epilogue: String,
    pub cycles: u64,
    pub window_cycles: u64,
    pub utilization: f64,
    pub power_mw: f64,
    pub energy_uj: f64,
    /// Exact FPU ops this layer issued (MACs + epilogue/elementwise).
    pub fpu_ops: u64,
    /// Elementwise ops folded into the GEMM writeback (bias adds +
    /// activations), i.e. TCDM round-trips fusion avoided.
    pub fused_elems: u64,
    /// TCDM round-trips this layer performs *beyond* the GEMM's own
    /// streaming (unfused elementwise passes). Zero for fused layers.
    pub extra_roundtrips: u64,
    /// Cluster the wave scheduler placed this layer on
    /// (layer-parallel assignment; sharded layers span the fabric and
    /// report cluster 0).
    pub cluster: usize,
    /// Clusters a tensor-parallel layer was sharded across (1 = ran
    /// whole on one cluster).
    pub shards: usize,
}

/// Whole-network execution report.
#[derive(Clone, Debug)]
pub struct NetReport {
    pub model: String,
    pub config: ConfigId,
    pub backend: BackendKind,
    pub layers: Vec<LayerRow>,
    /// End-to-end cycles over the wave schedule: each wave costs its
    /// busiest cluster's time. On a 1-cluster fabric this equals
    /// [`NetReport::serial_cycles`].
    pub total_cycles: u64,
    pub total_energy_uj: f64,
    /// End-to-end FPU utilization over the summed compute windows.
    pub utilization: f64,
    pub total_macs: u64,
    /// Peak bytes of simultaneously-live tensors (lifetime
    /// accounting over the wave schedule).
    pub peak_live_bytes: usize,
    pub fused_elems: u64,
    pub extra_roundtrips: u64,
    pub plan_stats: ServiceStats,
    /// Fabric size the network was scheduled on.
    pub clusters: usize,
    /// Serialization baseline: every scheduled work unit — each layer
    /// and, for tensor-parallel layers, each *shard* — executed back
    /// to back instead of in parallel. Shard cycles are the ones the
    /// fabric run measured (NoC contention included), so the ratio to
    /// [`NetReport::total_cycles`] isolates the *scheduling* gain; it
    /// is not a contention-free 1-cluster rerun.
    pub serial_cycles: u64,
    /// Per-cluster busy cycles over the whole run.
    pub per_cluster_cycles: Vec<u64>,
    /// Per-cluster energy share (uJ).
    pub per_cluster_energy_uj: Vec<f64>,
    /// Whole-fabric FPU utilization: total FPU ops over end-to-end
    /// time across *all* clusters' FPUs — idle clusters count against
    /// it, unlike the compute-window metric above.
    pub fabric_utilization: f64,
    /// StallScope class totals summed over every GEMM layer's compute
    /// cores (measured on the cycle backend, predicted on the
    /// analytic one). Indexed by `profile::StallClass as usize`.
    pub stall_totals: [u64; N_CLASSES],
    /// Per-GEMM-layer roofline placement (ops/byte vs the compute,
    /// L1, and NoC ceilings of the fabric this net ran on).
    pub rooflines: Vec<RooflinePoint>,
}

/// A completed network run: the report plus the network's output
/// tensors (empty data vectors on non-functional backends).
pub struct NetRun {
    pub report: NetReport,
    pub outputs: Vec<(String, Vec<f64>)>,
}

/// Deterministic contents for an input/parameter tensor.
pub fn tensor_data(seed: u64, tid: usize, elems: usize) -> Vec<f64> {
    let mut rng =
        Rng::new(seed ^ (tid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..elems).map(|_| rng.normal()).collect()
}

/// Cost model for an unfused elementwise pass over `elems` elements:
/// the compute cores split the rows, each element is a
/// load-compute-store round trip through the LSU (3 TCDM accesses),
/// plus a fixed pass overhead. Shared with `coordinator::serve` (and
/// the serve golden test, which reconstructs expected totals from it).
pub fn add_pass_cycles(elems: usize) -> u64 {
    (elems as u64).div_ceil(N_CORES as u64) * 3 + 64
}

/// Synthetic perf-counter snapshot for an elementwise pass (feeds the
/// energy model with its actual activity).
fn add_pass_perf(elems: usize) -> ClusterPerf {
    let cycles = add_pass_cycles(elems);
    ClusterPerf {
        cycles,
        window_cycles: cycles,
        fpu_ops_total: elems as u64,
        utilization: elems as f64
            / (cycles as f64 * N_CORES as f64),
        int_instrs: 2 * (elems as u64) + 64,
        icache_fetches: 4 * (elems as u64).div_ceil(N_CORES as u64) + 64,
        tcdm_core_accesses: 3 * elems as u64,
        ssr_requests: 3 * elems as u64,
        ..ClusterPerf::default()
    }
}

enum WaveOut {
    Gemm(crate::kernels::GemmResult),
    Add { data: Vec<f64>, elems: usize },
    Sharded(FabricResult),
}

/// Execute a network graph on one cluster configuration through a
/// shared service.
pub fn run_net(
    svc: &GemmService,
    g: &NetGraph,
    config: ConfigId,
    layout: LayoutKind,
    threads: usize,
    seed: u64,
) -> Result<NetRun> {
    run_net_clustered(
        svc,
        g,
        config,
        layout,
        threads,
        seed,
        &FabricConfig::single(),
    )
}

/// [`run_net`] on an N-cluster fabric: independent waves spread
/// layer-parallel across clusters; a wave that is a single shardable
/// GEMM runs tensor-parallel through `GemmService::run_sharded`.
#[allow(clippy::too_many_arguments)]
pub fn run_net_clustered(
    svc: &GemmService,
    g: &NetGraph,
    config: ConfigId,
    layout: LayoutKind,
    threads: usize,
    seed: u64,
    fabric: &FabricConfig,
) -> Result<NetRun> {
    let functional = svc.needs_data();
    let n_clusters = fabric.clusters.max(1);
    let nt = g.tensors.len();

    // --- dependency structure (derived, not trusted from op order) ---
    let (_, mut deps, dependents) = g.dependency_structure()?;
    // consumers per tensor (for lifetime accounting)
    let mut consumers: Vec<usize> = vec![0; nt];
    for op in &g.ops {
        for t in op.inputs() {
            consumers[t] += 1;
        }
    }

    // --- materialize inputs / parameters ------------------------------
    let mut store: Vec<Option<Vec<f64>>> = vec![None; nt];
    let mut live_bytes = 0usize;
    let mut peak_live_bytes = 0usize;
    for (tid, t) in g.tensors.iter().enumerate() {
        if t.kind != TensorKind::Computed {
            if functional {
                store[tid] = Some(tensor_data(seed, tid, t.elems()));
            }
            live_bytes += t.bytes();
        }
    }
    peak_live_bytes = peak_live_bytes.max(live_bytes);

    // --- wave-scheduled execution -------------------------------------
    let mut done = vec![false; g.ops.len()];
    let mut n_done = 0usize;
    let mut layers: Vec<LayerRow> = Vec::new();
    let mut total_cycles = 0u64;
    let mut serial_cycles = 0u64;
    let mut total_energy = 0.0f64;
    let mut window_sum = 0u64;
    let mut fpu_sum = 0u64;
    let mut fused_elems = 0u64;
    let mut extra_roundtrips = 0u64;
    let mut per_cluster_cycles = vec![0u64; n_clusters];
    let mut per_cluster_energy = vec![0.0f64; n_clusters];
    // Roofline ceilings must match where a layer actually ran: a
    // layer-parallel GEMM occupies one cluster (8 op/cyc, private
    // link), only tensor-parallel layers see the aggregate fabric
    // ceilings — otherwise a near-peak single-cluster layer would
    // print ~1/N attainment against roofs it never had.
    let lone_ceilings = Ceilings::new(1, &fabric.noc);
    let mut stall_totals = [0u64; N_CLASSES];
    let mut rooflines: Vec<RooflinePoint> = Vec::new();

    while n_done < g.ops.len() {
        let wave: Vec<usize> = (0..g.ops.len())
            .filter(|&i| !done[i] && deps[i] == 0)
            .collect();
        if wave.is_empty() {
            bail!(
                "network graph deadlocked: {} of {} ops unschedulable",
                g.ops.len() - n_done,
                g.ops.len()
            );
        }
        // A lone GEMM wave on a multi-cluster fabric goes
        // tensor-parallel when the partitioner finds a useful grid —
        // the only way to keep more than one cluster busy.
        let shard_wave = n_clusters > 1
            && wave.len() == 1
            && match &g.ops[wave[0]] {
                NetOp::Gemm { x, w, .. } => {
                    let (m, n) =
                        (g.tensors[*x].rows, g.tensors[*w].cols);
                    choose_shard_grid(m, n, n_clusters).used_clusters()
                        > 1
                }
                NetOp::Add { .. } => false,
            };
        let outs: Vec<WaveOut> = if shard_wave {
            let NetOp::Gemm { x, w, bias, epi, .. } = &g.ops[wave[0]]
            else {
                unreachable!("shard_wave implies a GEMM op")
            };
            let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
            let (m, n, k) = (xt.rows, wt.cols, xt.cols);
            let x_data: &[f64] = store[*x].as_deref().unwrap_or(&[]);
            let w_data: &[f64] = store[*w].as_deref().unwrap_or(&[]);
            let bias_data: &[f64] = match bias {
                Some(b) if functional => {
                    store[*b].as_deref().unwrap_or(&[])
                }
                _ => &[],
            };
            let fr = svc.run_sharded(
                config, m, n, k, layout, *epi, x_data, w_data,
                bias_data, fabric,
            )?;
            vec![WaveOut::Sharded(fr)]
        } else {
            runner::parallel_map(&wave, threads, |&i| {
                match &g.ops[i] {
                    NetOp::Gemm { x, w, bias, epi, .. } => {
                        let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
                        let (m, n, k) = (xt.rows, wt.cols, xt.cols);
                        let x_data: &[f64] =
                            store[*x].as_deref().unwrap_or(&[]);
                        let w_data: &[f64] =
                            store[*w].as_deref().unwrap_or(&[]);
                        let bias_data: &[f64] = match bias {
                            Some(b) if functional => {
                                store[*b].as_deref().unwrap_or(&[])
                            }
                            _ => &[],
                        };
                        let r = svc.run_fused(
                            config,
                            m,
                            n,
                            k,
                            layout,
                            *epi,
                            x_data,
                            w_data,
                            bias_data,
                        )?;
                        Ok(WaveOut::Gemm(r))
                    }
                    NetOp::Add { a, b, out, .. } => {
                        let elems = g.tensors[*out].elems();
                        let data = if functional {
                            let av = store[*a].as_ref().unwrap();
                            let bv = store[*b].as_ref().unwrap();
                            av.iter()
                                .zip(bv.iter())
                                .map(|(x, y)| x + y)
                                .collect()
                        } else {
                            Vec::new()
                        };
                        Ok(WaveOut::Add { data, elems })
                    }
                }
            })?
        };

        // Commit the wave: record rows, store outputs, free dead
        // tensors, release dependents. Layer-parallel placement:
        // wave position p lands on cluster p % n_clusters; the wave
        // costs its busiest cluster's time.
        let mut wave_busy = vec![0u64; n_clusters];
        for (pos, (&i, out)) in wave.iter().zip(outs).enumerate() {
            let assigned = pos % n_clusters;
            let op = &g.ops[i];
            // Serialization baseline contribution of a sharded layer:
            // all its shards back to back on one cluster (set in the
            // Sharded arm; plain layers just use their own cycles).
            let mut serial_contrib: Option<u64> = None;
            let row = match (op, out) {
                (
                    NetOp::Gemm { name, x, w, epi, out, .. },
                    WaveOut::Sharded(mut fr),
                ) => {
                    let sp = fr.stall_profile();
                    for (t, v) in
                        stall_totals.iter_mut().zip(sp.totals())
                    {
                        *t += v;
                    }
                    let layer_bytes: u64 = fr
                        .shards
                        .iter()
                        .map(|s| s.perf.dma_bytes)
                        .sum();
                    rooflines.push(roofline::point(
                        name.clone(),
                        fr.fpu_ops_total(),
                        layer_bytes,
                        fr.window_cycles(),
                        &Ceilings::new(fr.clusters(), &fabric.noc),
                    ));
                    let fe = model::fabric_energy(
                        config,
                        &fr.perfs(),
                        fr.cycles,
                    );
                    let t = &g.tensors[*out];
                    let fused = (t.elems()
                        * (usize::from(epi.bias)
                            + usize::from(epi.act.is_some())))
                        as u64;
                    if functional {
                        store[*out] = Some(std::mem::take(&mut fr.c));
                    }
                    live_bytes += t.bytes();
                    peak_live_bytes = peak_live_bytes.max(live_bytes);
                    // every shard's cluster is busy for its own run
                    for (ci, s) in fr.shards.iter().enumerate() {
                        let slot = ci % n_clusters;
                        wave_busy[slot] =
                            wave_busy[slot].max(s.cycles);
                        per_cluster_energy[slot] +=
                            fe.per_cluster[ci].energy_uj;
                    }
                    serial_contrib = Some(
                        fr.shards.iter().map(|s| s.cycles).sum(),
                    );
                    let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
                    LayerRow {
                        name: name.clone(),
                        kind: "gemm",
                        problem: Some(Problem {
                            m: xt.rows,
                            n: wt.cols,
                            k: xt.cols,
                        }),
                        epilogue: epi.name(),
                        cycles: fr.cycles,
                        window_cycles: fr.window_cycles(),
                        utilization: fr.mean_utilization(),
                        power_mw: fe.power_mw,
                        energy_uj: fe.total_uj,
                        fpu_ops: fr.fpu_ops_total(),
                        fused_elems: fused,
                        extra_roundtrips: 0,
                        cluster: 0,
                        shards: fr.clusters(),
                    }
                }
                (NetOp::Gemm { name, epi, out, .. }, WaveOut::Gemm(r)) => {
                    for (t, v) in
                        stall_totals.iter_mut().zip(r.perf.stalls.totals())
                    {
                        *t += v;
                    }
                    rooflines.push(roofline::point(
                        name.clone(),
                        r.perf.fpu_ops_total,
                        r.perf.dma_bytes,
                        r.perf.window_cycles,
                        &lone_ceilings,
                    ));
                    let e = model::energy(config, &r.perf);
                    let t = &g.tensors[*out];
                    let fused =
                        (t.elems() * (usize::from(epi.bias)
                            + usize::from(epi.act.is_some())))
                            as u64;
                    if functional {
                        store[*out] = Some(r.c.clone());
                    }
                    live_bytes += t.bytes();
                    // peak while output and inputs coexist, before
                    // dead inputs are freed below
                    peak_live_bytes = peak_live_bytes.max(live_bytes);
                    LayerRow {
                        name: name.clone(),
                        kind: "gemm",
                        problem: Some(Problem {
                            m: r.plan.tiling.m,
                            n: r.plan.tiling.n,
                            k: r.plan.tiling.k,
                        }),
                        epilogue: epi.name(),
                        cycles: r.cycles,
                        window_cycles: r.perf.window_cycles,
                        utilization: r.perf.utilization,
                        power_mw: e.power.total_mw(),
                        energy_uj: e.energy_uj,
                        fpu_ops: r.perf.fpu_ops_total,
                        fused_elems: fused,
                        extra_roundtrips: 0,
                        cluster: assigned,
                        shards: 1,
                    }
                }
                (
                    NetOp::Add { name, out, .. },
                    WaveOut::Add { data, elems },
                ) => {
                    let perf = add_pass_perf(elems);
                    let e = model::energy(config, &perf);
                    let t = &g.tensors[*out];
                    if functional {
                        store[*out] = Some(data);
                    }
                    live_bytes += t.bytes();
                    peak_live_bytes = peak_live_bytes.max(live_bytes);
                    LayerRow {
                        name: name.clone(),
                        kind: "add",
                        problem: None,
                        epilogue: "unfused".to_string(),
                        cycles: perf.cycles,
                        window_cycles: perf.window_cycles,
                        utilization: perf.utilization,
                        power_mw: e.power.total_mw(),
                        energy_uj: e.energy_uj,
                        fpu_ops: perf.fpu_ops_total,
                        fused_elems: 0,
                        extra_roundtrips: elems as u64,
                        cluster: assigned,
                        shards: 1,
                    }
                }
                _ => unreachable!("wave output kind matches its op"),
            };
            serial_cycles += serial_contrib.unwrap_or(row.cycles);
            total_energy += row.energy_uj;
            // A sharded layer's window is per-cluster time but its
            // fpu_ops span all shards: weight the window by the shard
            // count so utilization stays a per-FPU fraction (<= 1).
            window_sum += row.window_cycles * row.shards as u64;
            fpu_sum += row.fpu_ops;
            fused_elems += row.fused_elems;
            extra_roundtrips += row.extra_roundtrips;
            if row.shards == 1 {
                wave_busy[assigned] += row.cycles;
                per_cluster_energy[assigned] += row.energy_uj;
            }
            layers.push(row);

            done[i] = true;
            n_done += 1;
            for t in op.inputs() {
                consumers[t] -= 1;
                if consumers[t] == 0 {
                    // dead tensor: release it
                    live_bytes =
                        live_bytes.saturating_sub(g.tensors[t].bytes());
                    store[t] = None;
                }
            }
            for &d in &dependents[i] {
                deps[d] -= 1;
            }
        }
        // the wave ends when its busiest cluster does
        let elapsed = wave_busy.iter().copied().max().unwrap_or(0);
        total_cycles += elapsed;
        for (ci, &busy) in wave_busy.iter().enumerate() {
            per_cluster_cycles[ci] += busy;
        }
        peak_live_bytes = peak_live_bytes.max(live_bytes);
    }

    // --- collect network outputs --------------------------------------
    let out_ids = g.outputs();
    let outputs: Vec<(String, Vec<f64>)> = out_ids
        .iter()
        .map(|&tid| {
            (
                g.tensors[tid].name.clone(),
                store[tid].take().unwrap_or_default(),
            )
        })
        .collect();

    let fabric_utilization = ratio(
        fpu_sum as f64,
        total_cycles as f64 * N_CORES as f64 * n_clusters as f64,
    );
    let report = NetReport {
        model: g.name.clone(),
        config,
        backend: svc.backend_kind(),
        layers,
        total_cycles,
        total_energy_uj: total_energy,
        utilization: ratio(
            fpu_sum as f64,
            window_sum as f64 * N_CORES as f64,
        ),
        total_macs: g.macs(),
        peak_live_bytes,
        fused_elems,
        extra_roundtrips,
        plan_stats: svc.stats(),
        clusters: n_clusters,
        serial_cycles,
        per_cluster_cycles,
        per_cluster_energy_uj: per_cluster_energy,
        fabric_utilization,
        stall_totals,
        rooflines,
    };
    Ok(NetRun { report, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::workload::zoo;

    #[test]
    fn analytic_net_run_schedules_all_layers() {
        let svc = GemmService::analytic();
        let g = zoo::build("ffn").unwrap();
        let run = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            2,
            7,
        )
        .unwrap();
        assert_eq!(run.report.layers.len(), g.ops.len());
        assert!(run.report.total_cycles > 0);
        assert!(run.report.utilization > 0.0);
        assert!(run.report.peak_live_bytes > 0);
        // both GEMMs fused: only the residual add pays round-trips
        assert_eq!(run.report.extra_roundtrips, 64 * 64);
        assert!(run.report.fused_elems > 0);
    }

    #[test]
    fn net_report_carries_stallscope_and_rooflines() {
        use crate::profile::StallClass;
        // Analytic: predicted breakdown; cycle: measured — both must
        // populate the report with one roofline per GEMM layer and a
        // nonzero Useful total.
        for svc in [GemmService::analytic(), GemmService::cycle()] {
            let g = zoo::mlp(16, &[16, 24, 16]).unwrap();
            let run = run_net(
                &svc,
                &g,
                ConfigId::Zonl48Db,
                LayoutKind::Grouped,
                2,
                5,
            )
            .unwrap();
            let r = &run.report;
            let gemms = r.layers.iter().filter(|l| l.kind == "gemm");
            assert_eq!(r.rooflines.len(), gemms.count());
            assert!(
                r.stall_totals[StallClass::Useful as usize] > 0,
                "{:?}",
                r.stall_totals
            );
            for p in &r.rooflines {
                assert!(p.ops > 0 && p.bytes > 0);
                assert!(p.roof_ops_per_cycle > 0.0);
            }
        }
    }

    #[test]
    fn cycle_net_run_is_functional_and_fused() {
        let svc = GemmService::cycle();
        let g = zoo::mlp(16, &[16, 24, 16]).unwrap();
        let run = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            2,
            11,
        )
        .unwrap();
        assert_eq!(run.outputs.len(), 1);
        let (_, y) = &run.outputs[0];
        assert_eq!(y.len(), 16 * 16);
        assert!(y.iter().all(|v| v.is_finite()));
        // all layers fused -> zero extra TCDM round-trips
        assert_eq!(run.report.extra_roundtrips, 0);
        assert_eq!(
            run.report.fused_elems,
            (16 * 24 * 2 + 16 * 16) as u64,
            "bias+relu on layer 0, bias on layer 1"
        );
    }

    #[test]
    fn clustered_net_beats_serialization() {
        let svc = GemmService::analytic();
        let g = zoo::build("llm").unwrap();
        let run = run_net_clustered(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            2,
            7,
            &FabricConfig::new(4),
        )
        .unwrap();
        let r = &run.report;
        assert_eq!(r.clusters, 4);
        assert_eq!(r.per_cluster_cycles.len(), 4);
        assert_eq!(r.per_cluster_energy_uj.len(), 4);
        assert!(
            r.total_cycles < r.serial_cycles,
            "fabric schedule must beat 1-cluster serialization: \
             {} vs {}",
            r.total_cycles,
            r.serial_cycles
        );
        assert!(r.fabric_utilization > 0.0);
        // every large single-GEMM wave went tensor-parallel
        assert!(
            r.layers.iter().any(|l| l.shards > 1),
            "llm waves of one GEMM must shard"
        );
        // single-cluster path still reports itself faithfully
        let lone = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            2,
            7,
        )
        .unwrap();
        assert_eq!(lone.report.clusters, 1);
        assert_eq!(
            lone.report.total_cycles, lone.report.serial_cycles,
            "one cluster: wave schedule == serialization"
        );
    }

    #[test]
    fn clustered_cycle_net_stays_bit_exact() {
        let g = zoo::mlp(16, &[16, 24, 16]).unwrap();
        let seed = 11;
        let svc = GemmService::cycle();
        let lone = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            2,
            seed,
        )
        .unwrap();
        let fab = run_net_clustered(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            2,
            seed,
            &FabricConfig::new(2),
        )
        .unwrap();
        assert_eq!(lone.outputs.len(), fab.outputs.len());
        for ((ln, lv), (fn_, fv)) in
            lone.outputs.iter().zip(&fab.outputs)
        {
            assert_eq!(ln, fn_);
            assert_eq!(
                lv, fv,
                "tensor-parallel execution must stay bit-identical"
            );
        }
    }

    #[test]
    fn scheduler_detects_cycles() {
        use crate::coordinator::workload::graph::{
            NetGraph, NetOp, Tensor, TensorKind,
        };
        // Hand-assemble a 2-op cycle: op0 reads t1 writes t0, op1
        // reads t0 writes t1.
        let mut g = NetGraph::new("cyclic");
        for name in ["t0", "t1"] {
            g.tensors.push(Tensor {
                name: name.to_string(),
                rows: 8,
                cols: 8,
                kind: TensorKind::Computed,
            });
        }
        g.ops.push(NetOp::Add {
            name: "a".into(),
            a: 1,
            b: 1,
            out: 0,
        });
        g.ops.push(NetOp::Add {
            name: "b".into(),
            a: 0,
            b: 0,
            out: 1,
        });
        let svc = GemmService::analytic();
        let err = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            1,
            0,
        );
        assert!(err.is_err());
        assert!(g.topo_order().is_err());
    }
}
