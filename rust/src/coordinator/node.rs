//! NodeSim — a deterministic multi-fabric serving node on top of
//! ServeSim ([`super::serve`]).
//!
//! The paper proves 96-99% utilization for one cluster fabric; the
//! ROADMAP north star is a production-shaped serving system. This
//! module composes `N` fabrics into a node behind a front-end router
//! and closes the fleet-level gap: routing policy, SLO-aware
//! admission control, overload shedding, and deterministic fault
//! injection — all on **one** event heap in virtual time, so a
//! million-request node trace with mid-trace fabric failures is
//! bit-for-bit reproducible across runs and host thread counts.
//!
//! Architecture (DESIGN.md §14 carries the full determinism
//! argument):
//!
//! * **Service model.** Each fabric serves its queue serially; one
//!   request of model `m` costs [`solo_latency`]`(m, Continuous)` —
//!   what the request costs an otherwise-idle fabric, waves going
//!   tensor-parallel across its clusters. The costs are probed once
//!   per model through the real serve engine (so they inherit the
//!   backend, `--fast-forward`, calibration, ...), and the node tier
//!   itself never touches the backend again: 10^6 requests drain in
//!   pure event time.
//! * **One heap.** Arrivals, completions, and fault transitions are
//!   totally ordered by `(cycle, kind, fabric, epoch)` with the fixed
//!   kind order `DOWN < UP < DONE < ARRIVE` — at equal cycles a
//!   fault lands before the completion it kills, a restore lands
//!   before work is routed to it, and completions commit before
//!   same-cycle arrivals route. No ordering ever depends on host
//!   threads or hash iteration.
//! * **Faults.** A seeded [`FaultPlan`] drops a fabric at virtual
//!   time `T` and optionally restores it at `T'`. A down fabric bumps
//!   its `epoch`, which lazily invalidates the in-flight completion
//!   event; the interrupted request and everything queued behind it
//!   requeue through the router with `retries + 1`, shedding only
//!   past `max_retries`. Requests are **never silently lost**: the
//!   engine `ensure!`s `arrivals == completions + sheds` on every
//!   run, and a shrinkable property test re-proves it over random
//!   plans.
//! * **Digest.** [`run_digest`] folds `(id, completion, fabric,
//!   retries)` of every completion (plus the shed stream) through
//!   FNV-1a 64 in id order — the checksum the determinism harness
//!   pins bit-identical across 1/2/8 threads and `--fast-forward
//!   on|off`.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use anyhow::{bail, ensure, Result};

use crate::backend::BackendKind;
use crate::cluster::ConfigId;
use crate::fabric::NodeTopology;
use crate::kernels::{GemmService, ServiceStats};
use crate::profile::telemetry::{self, SpanKind, Telemetry};
use crate::util::prop::Shrink;
use crate::util::rng::Rng;
use crate::util::stats::{ratio, CycleHistogram, Fnv64};

use super::serve::{
    gen_arrivals, solo_latency, ArrivalTrace, Policy, ServeConfig,
};

// -------------------------------------------------------- routing --

/// Front-end routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Round-robin over up fabrics (the baseline the others beat).
    RoundRobin,
    /// Least-loaded: smallest backlog, ties to the lowest fabric id.
    LeastLoaded,
    /// Power-of-two-choices: two seeded draws among up fabrics, pick
    /// the less loaded.
    PowerOfTwo,
    /// Session affinity: a session sticks to one fabric until that
    /// fabric dies, then remaps via least-loaded (and stays remapped).
    Affinity,
}

impl RouterPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "rr",
            RouterPolicy::LeastLoaded => "ll",
            RouterPolicy::PowerOfTwo => "p2c",
            RouterPolicy::Affinity => "affinity",
        }
    }

    pub fn from_name(s: &str) -> Option<RouterPolicy> {
        match s {
            "rr" => Some(RouterPolicy::RoundRobin),
            "ll" => Some(RouterPolicy::LeastLoaded),
            "p2c" => Some(RouterPolicy::PowerOfTwo),
            "affinity" => Some(RouterPolicy::Affinity),
            _ => None,
        }
    }
}

// --------------------------------------------------------- faults --

/// One injected fabric failure: down at `at`, optionally back up at
/// `restore` (`None` = dead for the rest of the run).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub at: u64,
    pub fabric: usize,
    pub restore: Option<u64>,
}

impl Shrink for FaultEvent {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.at > 0 {
            out.push(FaultEvent { at: self.at / 2, ..*self });
        }
        if let Some(r) = self.restore {
            out.push(FaultEvent { restore: None, ..*self });
            let mid = (self.at + 1).max(self.at / 2 + r / 2);
            if mid < r {
                out.push(FaultEvent { restore: Some(mid), ..*self });
            }
        }
        if self.fabric > 0 {
            out.push(FaultEvent { fabric: 0, ..*self });
        }
        out
    }
}

/// A deterministic fault schedule. Overlapping windows on one fabric
/// are legal: down/up transitions are idempotent (a second DOWN on a
/// dead fabric is a no-op, its paired restore still fires), so any
/// plan the property generator draws is a valid input.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse the CLI syntax: `t=T,fabric=F[,restore=T']`, multiple
    /// events joined with `;`.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (mut at, mut fabric, mut restore) = (None, None, None);
            for kv in part.split(',') {
                let kv = kv.trim();
                let Some((k, v)) = kv.split_once('=') else {
                    bail!("fault event field `{kv}` is not key=value");
                };
                match k.trim() {
                    "t" => at = Some(v.trim().parse::<u64>()?),
                    "fabric" => {
                        fabric = Some(v.trim().parse::<usize>()?)
                    }
                    "restore" => {
                        restore = Some(v.trim().parse::<u64>()?)
                    }
                    other => bail!(
                        "unknown fault field `{other}` \
                         (t|fabric|restore)"
                    ),
                }
            }
            let at = at
                .ok_or_else(|| anyhow::anyhow!("fault event needs t="))?;
            let fabric = fabric.ok_or_else(|| {
                anyhow::anyhow!("fault event needs fabric=")
            })?;
            events.push(FaultEvent { at, fabric, restore });
        }
        Ok(FaultPlan { events })
    }

    /// Check the plan against a node of `fabrics` fabrics.
    pub fn validate(&self, fabrics: usize) -> Result<()> {
        for ev in &self.events {
            ensure!(
                ev.fabric < fabrics,
                "fault names fabric {} (node has {})",
                ev.fabric,
                fabrics
            );
            if let Some(r) = ev.restore {
                ensure!(
                    r > ev.at,
                    "fault restore {} must come after t {}",
                    r,
                    ev.at
                );
            }
        }
        Ok(())
    }

    /// Human/report form, the inverse of [`FaultPlan::parse`].
    pub fn summary(&self) -> String {
        if self.events.is_empty() {
            return "none".into();
        }
        self.events
            .iter()
            .map(|ev| match ev.restore {
                Some(r) => format!(
                    "t={},fabric={},restore={r}",
                    ev.at, ev.fabric
                ),
                None => format!("t={},fabric={}", ev.at, ev.fabric),
            })
            .collect::<Vec<_>>()
            .join(";")
    }
}

impl Shrink for FaultPlan {
    fn shrinks(&self) -> Vec<Self> {
        self.events
            .shrinks()
            .into_iter()
            .map(|events| FaultPlan { events })
            .collect()
    }
}

// ----------------------------------------------------- autoscaling --

/// Signal-driven autoscaler policy (TimeScope's first consumer,
/// DESIGN.md §15): at every telemetry-window boundary the node reads
/// the *just-recorded* windowed utilization and queue-depth gauges
/// and parks (low) / unparks (high) fabrics with hysteresis.
///
/// * a fabric is **parked** when the mean utilization of active
///   fabrics over the closed window sits below `low` — only an idle
///   fabric (nothing queued or in service) is eligible, so parking
///   can never orphan work;
/// * a fabric is **unparked** when mean utilization exceeds `high`
///   or queue depth spikes past twice the active-fabric count;
/// * `cooldown` windows must pass between scaling actions, which —
///   together with `low < high` — is the hysteresis band that keeps
///   the controller from oscillating on a signal that hovers near
///   one threshold.
///
/// Parking is a routing property: a parked fabric takes no new work
/// but stays `up` (faults and restores still apply). When every
/// routable fabric is down, the router force-unparks before it would
/// park a request or shed it, so autoscaling never *adds* sheds in a
/// scenario fixed provisioning would survive.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoscalePolicy {
    /// Park when mean active-fabric utilization < `low` (fraction).
    pub low: f64,
    /// Unpark when mean active-fabric utilization > `high`.
    pub high: f64,
    /// Minimum telemetry windows between scaling actions.
    pub cooldown: u64,
}

impl AutoscalePolicy {
    /// Parse the CLI syntax `low=L,high=H,cooldown=C` (any subset;
    /// defaults `low=0.2,high=0.7,cooldown=3`).
    pub fn parse(s: &str) -> Result<AutoscalePolicy> {
        let mut p = AutoscalePolicy {
            low: 0.2,
            high: 0.7,
            cooldown: 3,
        };
        for kv in s.split(',') {
            let kv = kv.trim();
            if kv.is_empty() {
                continue;
            }
            let Some((k, v)) = kv.split_once('=') else {
                bail!("autoscale field `{kv}` is not key=value");
            };
            match k.trim() {
                "low" => p.low = v.trim().parse::<f64>()?,
                "high" => p.high = v.trim().parse::<f64>()?,
                "cooldown" => {
                    p.cooldown = v.trim().parse::<u64>()?
                }
                other => bail!(
                    "unknown autoscale field `{other}` \
                     (low|high|cooldown)"
                ),
            }
        }
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.low.is_finite() && self.low >= 0.0,
            "autoscale low must be a nonnegative fraction"
        );
        ensure!(
            self.high.is_finite() && self.high > self.low,
            "autoscale needs low < high (hysteresis band), got \
             low={} high={}",
            self.low,
            self.high
        );
        ensure!(
            self.cooldown >= 1,
            "autoscale cooldown must be at least 1 window"
        );
        Ok(())
    }

    pub fn summary(&self) -> String {
        format!(
            "low={},high={},cooldown={}",
            self.low, self.high, self.cooldown
        )
    }
}

// --------------------------------------------------------- config --

/// Node-run parameters: a per-fabric [`ServeConfig`] (shape + arrival
/// process) plus the node tier's knobs.
#[derive(Clone, Debug)]
pub struct NodeConfig {
    /// Per-fabric shape, model mix, and arrival process. The node
    /// serves `serve.requests` arrivals at `serve.rate_per_mcycle`
    /// across all fabrics.
    pub serve: ServeConfig,
    pub fabrics: usize,
    pub router: RouterPolicy,
    pub faults: FaultPlan,
    /// Requeue attempts a request survives before it is shed.
    pub max_retries: u32,
    /// Admission control: shed on arrival when the estimated latency
    /// exceeds `admit_factor x SLO`. `None` admits everything.
    pub admit_factor: Option<f64>,
    /// Session-id space for the affinity router (a request's session
    /// is its seed modulo this).
    pub sessions: usize,
    /// Signal-driven fabric park/unpark policy. Implies telemetry
    /// (the policy reads the windowed gauges); `None` keeps fixed
    /// provisioning.
    pub autoscale: Option<AutoscalePolicy>,
}

impl NodeConfig {
    /// Defaults: least-loaded routing, no faults, 3 retries, no
    /// admission control, 16 sessions, fixed provisioning.
    pub fn new(serve: ServeConfig, fabrics: usize) -> NodeConfig {
        NodeConfig {
            serve,
            fabrics: fabrics.max(1),
            router: RouterPolicy::LeastLoaded,
            faults: FaultPlan::default(),
            max_retries: 3,
            admit_factor: None,
            sessions: 16,
            autoscale: None,
        }
    }

    pub fn topology(&self) -> NodeTopology {
        NodeTopology::new(self.fabrics, self.serve.clusters)
    }
}

// -------------------------------------------------------- results --

/// Why a request was shed instead of served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// Admission control: estimated latency past `admit_factor x SLO`.
    Admission,
    /// Requeued more than `max_retries` times by faults.
    RetryBudget,
    /// Every fabric down with no restore scheduled.
    Unroutable,
}

impl ShedReason {
    pub fn name(&self) -> &'static str {
        match self {
            ShedReason::Admission => "admission",
            ShedReason::RetryBudget => "retry-budget",
            ShedReason::Unroutable => "unroutable",
        }
    }

    /// Stable code folded into the run digest.
    fn code(&self) -> u64 {
        match self {
            ShedReason::Admission => 1,
            ShedReason::RetryBudget => 2,
            ShedReason::Unroutable => 3,
        }
    }
}

/// Per-completed-request outcome row (CSV material).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeRow {
    pub id: usize,
    /// Index into the config's model mix.
    pub model: usize,
    pub session: u64,
    /// Fabric the request finally completed on.
    pub fabric: usize,
    pub arrival: u64,
    /// Cycle its (final) service began.
    pub dispatched: u64,
    pub completion: u64,
    pub latency: u64,
    /// Fault-driven requeues this request survived.
    pub retries: u32,
    pub slo_met: bool,
}

/// Per-shed-request outcome row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShedRow {
    pub id: usize,
    pub model: usize,
    pub session: u64,
    pub arrival: u64,
    /// Cycle the shed decision was made.
    pub at: u64,
    pub retries: u32,
    pub reason: ShedReason,
}

/// One fabric's telemetry. `latency` is a per-fabric histogram shard;
/// the node report's overall histogram is the bucket-wise merge of
/// all shards (exercising [`CycleHistogram::merge`] at scale).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FabricStats {
    /// Requests completed on this fabric.
    pub served: u64,
    /// Cycles spent on work that completed.
    pub busy_cycles: u64,
    /// Cycles of partial service discarded by faults.
    pub lost_cycles: u64,
    /// Cycles spent down.
    pub downtime: u64,
    pub latency: CycleHistogram,
}

/// Aggregate node report. Derives `PartialEq` so the determinism
/// harness can compare entire runs bit for bit.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeReport {
    /// `+`-joined model mix.
    pub model: String,
    pub config: ConfigId,
    pub backend: BackendKind,
    pub router: RouterPolicy,
    pub topo: NodeTopology,
    pub rate_per_mcycle: f64,
    pub burst: f64,
    pub seed: u64,
    pub faults: FaultPlan,
    pub max_retries: u32,
    pub autoscale: Option<AutoscalePolicy>,
    pub requests: usize,
    pub completed: usize,
    pub shed_admission: usize,
    pub shed_retry: usize,
    pub shed_unroutable: usize,
    /// Fault-driven requeues across all requests (served and shed).
    pub retries_total: u64,
    /// Last request-completion cycle (0 when nothing completed).
    pub makespan_cycles: u64,
    /// Merged per-fabric latency shards (p50/p95/p99 source).
    pub latency: CycleHistogram,
    pub slo_cycles: u64,
    pub slo_attained: usize,
    /// Per-model service cost (solo continuous-batching latency) the
    /// queueing model ran on.
    pub model_costs: Vec<u64>,
    pub per_fabric: Vec<FabricStats>,
    /// Plan-cache counters for this run's cost probes (delta over the
    /// service totals).
    pub plan_stats: ServiceStats,
    /// Heap events processed.
    pub events: u64,
    /// Provisioned fabric-cycles: Σ over fabrics of cycles spent
    /// `up && !parked` within the makespan — the energy/provisioning
    /// proxy the autoscaler minimizes. Fixed provisioning with no
    /// faults makes this `fabrics x makespan`.
    pub active_cycles: u64,
    /// FNV-1a fold of the outcome streams ([`run_digest`]); with
    /// telemetry enabled, the sealed telemetry stream is folded on
    /// top, so the windowed signals are digest-checked too.
    pub digest: u64,
}

impl NodeReport {
    pub fn p50(&self) -> u64 {
        self.latency.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.latency.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.latency.quantile(0.99)
    }

    pub fn shed_total(&self) -> usize {
        self.shed_admission + self.shed_retry + self.shed_unroutable
    }

    /// Completed requests per million cycles of makespan.
    pub fn throughput_per_mcycle(&self) -> f64 {
        ratio(self.completed as f64, self.makespan_cycles as f64)
            * 1.0e6
    }

    /// Fraction of completed requests that met the SLO.
    pub fn slo_attainment(&self) -> f64 {
        ratio(self.slo_attained as f64, self.completed as f64)
    }

    /// Per-fabric busy fraction of the makespan.
    pub fn fabric_utilization(&self) -> Vec<f64> {
        self.per_fabric
            .iter()
            .map(|f| {
                ratio(
                    f.busy_cycles as f64,
                    self.makespan_cycles as f64,
                )
            })
            .collect()
    }
}

/// A completed node run: report plus per-request outcome rows, both
/// streams sorted by request id.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeRun {
    pub report: NodeReport,
    /// Model-name table the row `model` indexes resolve against.
    pub models: Vec<String>,
    pub rows: Vec<NodeRow>,
    pub sheds: Vec<ShedRow>,
    /// Sealed TimeScope stream (`Some` when telemetry or autoscaling
    /// was enabled). Compared bit for bit by the determinism tests.
    pub telemetry: Option<Telemetry>,
}

/// The canonical run digest: FNV-1a 64 over `(id, completion cycle,
/// fabric id, retry count)` of every completed request in id order,
/// a domain separator, then `(id, shed cycle, reason, retries)` of
/// every shed request in id order. Two runs of the same scenario —
/// across thread counts, FastPath settings, or refactors — must agree
/// on all 64 bits.
pub fn run_digest(rows: &[NodeRow], sheds: &[ShedRow]) -> u64 {
    let mut h = Fnv64::new();
    for r in rows {
        h.write_u64(r.id as u64);
        h.write_u64(r.completion);
        h.write_u64(r.fabric as u64);
        h.write_u64(r.retries as u64);
    }
    // Separator: a shed stream can never alias a completion stream.
    h.write_u64(0x5EED_5EED_5EED_5EED);
    for s in sheds {
        h.write_u64(s.id as u64);
        h.write_u64(s.at);
        h.write_u64(s.reason.code());
        h.write_u64(s.retries as u64);
    }
    h.finish()
}

// --------------------------------------------------------- engine --

/// Heap event kinds, in tie-break order at equal cycles: a fault
/// lands before the completion it kills, a restore lands before work
/// routes to it, completions commit before the telemetry sampler
/// closes the window they belong to, and the sampler reads state
/// before same-cycle arrivals route.
const EV_DOWN: u8 = 0;
const EV_UP: u8 = 1;
const EV_DONE: u8 = 2;
const EV_SAMPLE: u8 = 3;
const EV_ARRIVE: u8 = 4;

struct FabricSim {
    up: bool,
    /// Bumped on every DOWN; a DONE event carrying a stale epoch is
    /// a completion from before the fault and is discarded.
    epoch: u32,
    /// `(request index, enqueue cycle)` — the enqueue cycle feeds
    /// the per-attempt queue lifecycle span.
    queue: VecDeque<(u32, u64)>,
    in_service: Option<u32>,
    service_start: u64,
    /// Virtual cycle the backlog drains at (load estimate).
    backlog_end: u64,
    served: u64,
    busy: u64,
    lost: u64,
    down_at: u64,
    downtime: u64,
    /// Autoscaler state: a parked fabric takes no new routes but
    /// stays `up` (faults still apply).
    parked: bool,
    /// Start of the current `up && !parked` period.
    active_since: u64,
    /// Accumulated provisioned (`up && !parked`) cycles.
    active: u64,
    hist: CycleHistogram,
}

impl FabricSim {
    fn new() -> FabricSim {
        FabricSim {
            up: true,
            epoch: 0,
            queue: VecDeque::new(),
            in_service: None,
            service_start: 0,
            backlog_end: 0,
            served: 0,
            busy: 0,
            lost: 0,
            down_at: 0,
            downtime: 0,
            parked: false,
            active_since: 0,
            active: 0,
            hist: CycleHistogram::new(),
        }
    }

    /// Routable: up and not parked by the autoscaler.
    fn routable(&self) -> bool {
        self.up && !self.parked
    }
}

/// One arrival's immutable fields, indexed by the engine's `u32`
/// request index (sorted arrival order).
struct Req {
    id: usize,
    model: usize,
    arrival: u64,
    session: u64,
}

struct Engine<'a> {
    cfg: &'a NodeConfig,
    reqs: Vec<Req>,
    costs: Vec<u64>,
    slo: u64,
    fabrics: Vec<FabricSim>,
    heap: BinaryHeap<Reverse<(u64, u8, u32, u32)>>,
    /// Requests parked while every fabric is down but a restore is
    /// still scheduled.
    pending: VecDeque<u32>,
    /// UP events still in the heap — when this hits zero with every
    /// fabric down, requests are unroutable rather than parked.
    future_ups: usize,
    next_arr: usize,
    rr_next: usize,
    sticky: HashMap<u64, usize>,
    p2c_rng: Rng,
    retries: Vec<u32>,
    rows: Vec<NodeRow>,
    sheds: Vec<ShedRow>,
    shed_admission: usize,
    shed_retry: usize,
    shed_unroutable: usize,
    retries_total: u64,
    slo_attained: usize,
    makespan: u64,
    events: u64,
    /// TimeScope stream (`Some` when telemetry is enabled).
    tel: Option<Telemetry>,
    /// Pre-rendered `fabric=F` label strings (avoids re-formatting
    /// on every telemetry record).
    fab_labels: Vec<String>,
    /// Window index of the last autoscaler action (cooldown gate).
    last_scale: u64,
}

impl Engine<'_> {
    fn load(&self, f: usize, now: u64) -> u64 {
        self.fabrics[f].backlog_end.saturating_sub(now)
    }

    fn least_loaded(&self, now: u64) -> usize {
        (0..self.fabrics.len())
            .filter(|&f| self.fabrics[f].routable())
            .min_by_key(|&f| (self.load(f, now), f))
            .expect("least_loaded with no fabric routable")
    }

    fn shed(&mut self, ri: u32, at: u64, reason: ShedReason) {
        match reason {
            ShedReason::Admission => self.shed_admission += 1,
            ShedReason::RetryBudget => self.shed_retry += 1,
            ShedReason::Unroutable => self.shed_unroutable += 1,
        }
        let r = &self.reqs[ri as usize];
        let row = ShedRow {
            id: r.id,
            model: r.model,
            session: r.session,
            arrival: r.arrival,
            at,
            retries: self.retries[ri as usize],
            reason,
        };
        if let Some(tel) = self.tel.as_mut() {
            tel.count("sheds", reason.name(), at, 1);
            tel.instant(
                SpanKind::Shed,
                0,
                row.id as u64,
                at,
                reason.code(),
            );
        }
        self.sheds.push(row);
    }

    /// If `f` is up and idle, begin serving its queue head and
    /// schedule the completion event under the current epoch.
    fn start_next(&mut self, f: usize, now: u64) {
        if !self.fabrics[f].up || self.fabrics[f].in_service.is_some()
        {
            return;
        }
        let Some((ri, enq)) = self.fabrics[f].queue.pop_front() else {
            return;
        };
        let cost = self.costs[self.reqs[ri as usize].model];
        let fb = &mut self.fabrics[f];
        fb.in_service = Some(ri);
        fb.service_start = now;
        let epoch = fb.epoch;
        let depth = fb.queue.len() as u64;
        if let Some(tel) = self.tel.as_mut() {
            // One queue span per routing attempt (retries get one
            // span per fabric they waited on).
            tel.span(
                SpanKind::Queue,
                f as u32,
                self.reqs[ri as usize].id as u64,
                enq,
                now,
                self.retries[ri as usize] as u64,
            );
            tel.gauge("queue_depth", &self.fab_labels[f], now, depth);
        }
        self.heap.push(Reverse((
            now.saturating_add(cost),
            EV_DONE,
            f as u32,
            epoch,
        )));
    }

    /// Route one request through the configured policy at `now`.
    fn route(&mut self, ri: u32, now: u64) {
        let n = self.fabrics.len();
        if !self.fabrics.iter().any(|f| f.routable()) {
            // Safety valve: before parking the request (or shedding
            // it), force-unpark an up-but-parked fabric — the
            // autoscaler must never turn a survivable scenario into
            // a shed.
            if let Some(f) =
                (0..n).find(|&f| self.fabrics[f].up)
            {
                self.unpark(f, now);
            } else if self.future_ups > 0 {
                self.pending.push_back(ri);
                return;
            } else {
                self.shed(ri, now, ShedReason::Unroutable);
                return;
            }
        }
        let f = match self.cfg.router {
            RouterPolicy::RoundRobin => {
                let mut pick = self.rr_next;
                while !self.fabrics[pick].routable() {
                    pick = (pick + 1) % n;
                }
                self.rr_next = (pick + 1) % n;
                pick
            }
            RouterPolicy::LeastLoaded => self.least_loaded(now),
            RouterPolicy::PowerOfTwo => {
                let ups: Vec<usize> = (0..n)
                    .filter(|&f| self.fabrics[f].routable())
                    .collect();
                if ups.len() == 1 {
                    ups[0]
                } else {
                    // Two distinct seeded draws; less loaded wins,
                    // ties to the lower fabric id.
                    let i =
                        self.p2c_rng.below(ups.len() as u64) as usize;
                    let mut j = self
                        .p2c_rng
                        .below(ups.len() as u64 - 1)
                        as usize;
                    if j >= i {
                        j += 1;
                    }
                    let (a, b) = (ups[i], ups[j]);
                    if (self.load(a, now), a) <= (self.load(b, now), b)
                    {
                        a
                    } else {
                        b
                    }
                }
            }
            RouterPolicy::Affinity => {
                let s = self.reqs[ri as usize].session;
                match self.sticky.get(&s) {
                    Some(&f) if self.fabrics[f].routable() => f,
                    _ => {
                        let f = self.least_loaded(now);
                        self.sticky.insert(s, f);
                        f
                    }
                }
            }
        };
        let cost = self.costs[self.reqs[ri as usize].model];
        if let Some(k) = self.cfg.admit_factor {
            // Estimated latency = waiting so far + the target's
            // backlog + own service.
            let waited = now - self.reqs[ri as usize].arrival;
            let est = waited
                .saturating_add(self.load(f, now))
                .saturating_add(cost);
            if (est as f64) > (self.slo as f64) * k {
                self.shed(ri, now, ShedReason::Admission);
                return;
            }
        }
        let fb = &mut self.fabrics[f];
        fb.backlog_end = fb.backlog_end.max(now).saturating_add(cost);
        fb.queue.push_back((ri, now));
        let depth = fb.queue.len() as u64;
        if let Some(tel) = self.tel.as_mut() {
            tel.gauge("queue_depth", &self.fab_labels[f], now, depth);
        }
        self.start_next(f, now);
    }

    fn on_down(&mut self, f: usize, t: u64) {
        if !self.fabrics[f].up {
            return; // overlapping plan: already down
        }
        let fb = &mut self.fabrics[f];
        fb.up = false;
        if !fb.parked {
            fb.active += t.saturating_sub(fb.active_since);
        }
        fb.epoch = fb.epoch.wrapping_add(1);
        fb.down_at = t;
        fb.backlog_end = t;
        // Orphans requeue in a fixed order: the interrupted request
        // first, then the queue front to back.
        let mut orphans: Vec<u32> = Vec::new();
        let mut lost_span = None;
        if let Some(ri) = fb.in_service.take() {
            fb.lost += t - fb.service_start;
            lost_span = Some((fb.service_start, t));
            orphans.push(ri);
        }
        orphans.extend(fb.queue.drain(..).map(|(ri, _)| ri));
        if let Some(tel) = self.tel.as_mut() {
            if let Some((start, end)) = lost_span {
                tel.count_span(
                    "fabric_lost_cycles",
                    &self.fab_labels[f],
                    start,
                    end,
                );
            }
            tel.gauge("queue_depth", &self.fab_labels[f], t, 0);
        }
        for ri in orphans {
            self.retries[ri as usize] += 1;
            self.retries_total += 1;
            if let Some(tel) = self.tel.as_mut() {
                tel.count("retries", "", t, 1);
                tel.instant(
                    SpanKind::Retry,
                    f as u32,
                    self.reqs[ri as usize].id as u64,
                    t,
                    self.retries[ri as usize] as u64,
                );
            }
            if self.retries[ri as usize] > self.cfg.max_retries {
                self.shed(ri, t, ShedReason::RetryBudget);
            } else {
                self.route(ri, t);
            }
        }
    }

    fn on_up(&mut self, f: usize, t: u64) {
        let fb = &mut self.fabrics[f];
        if !fb.up {
            fb.up = true;
            fb.downtime += t - fb.down_at;
            fb.backlog_end = t;
            if !fb.parked {
                fb.active_since = t;
            }
            let down_at = fb.down_at;
            if let Some(tel) = self.tel.as_mut() {
                tel.span(SpanKind::Outage, f as u32, 0, down_at, t, 0);
                tel.count_span(
                    "fabric_downtime_cycles",
                    &self.fab_labels[f],
                    down_at,
                    t,
                );
            }
        }
        // A fabric is up, so parked requests are routable again.
        while let Some(ri) = self.pending.pop_front() {
            self.route(ri, t);
        }
    }

    fn on_done(&mut self, f: usize, epoch: u32, t: u64) {
        if !self.fabrics[f].up || epoch != self.fabrics[f].epoch {
            return; // completion from before a fault — discarded
        }
        let ri = self.fabrics[f]
            .in_service
            .take()
            .expect("live DONE event on an idle fabric");
        let r = &self.reqs[ri as usize];
        let latency = t - r.arrival;
        let slo_met = latency <= self.slo;
        let row = NodeRow {
            id: r.id,
            model: r.model,
            session: r.session,
            fabric: f,
            arrival: r.arrival,
            dispatched: self.fabrics[f].service_start,
            completion: t,
            latency,
            retries: self.retries[ri as usize],
            slo_met,
        };
        let fb = &mut self.fabrics[f];
        fb.busy += t - fb.service_start;
        fb.served += 1;
        fb.hist.record(latency);
        if let Some(tel) = self.tel.as_mut() {
            // Busy cycles are attributed window-exactly from the
            // same span `fb.busy` integrates, so
            // `Σ per-window busy == fabric total busy` holds by
            // construction — and is still `ensure!`d after the run.
            tel.count_span(
                "fabric_busy_cycles",
                &self.fab_labels[f],
                row.dispatched,
                t,
            );
            tel.count("completions", &self.fab_labels[f], t, 1);
            tel.observe("latency", "", t, latency);
            tel.span(
                SpanKind::Service,
                f as u32,
                row.id as u64,
                row.dispatched,
                t,
                row.retries as u64,
            );
            tel.span(
                SpanKind::Request,
                f as u32,
                row.id as u64,
                row.arrival,
                t,
                row.retries as u64,
            );
        }
        if slo_met {
            self.slo_attained += 1;
        }
        self.makespan = self.makespan.max(t);
        self.rows.push(row);
        self.start_next(f, t);
    }

    fn on_arrive(&mut self, t: u64) {
        while self.next_arr < self.reqs.len()
            && self.reqs[self.next_arr].arrival <= t
        {
            let ri = self.next_arr as u32;
            self.next_arr += 1;
            if let Some(tel) = self.tel.as_mut() {
                tel.count("arrivals", "", t, 1);
            }
            self.route(ri, t);
        }
        if self.next_arr < self.reqs.len() {
            self.heap.push(Reverse((
                self.reqs[self.next_arr].arrival,
                EV_ARRIVE,
                0,
                0,
            )));
        }
    }

    // ------------------------------------- autoscaler + sampler --

    fn park(&mut self, f: usize, t: u64) {
        let fb = &mut self.fabrics[f];
        debug_assert!(fb.routable() && fb.in_service.is_none());
        fb.parked = true;
        fb.active += t.saturating_sub(fb.active_since);
        if let Some(tel) = self.tel.as_mut() {
            tel.count("autoscale_park", "", t, 1);
            tel.instant(SpanKind::Scale, f as u32, 0, t, 1);
        }
    }

    fn unpark(&mut self, f: usize, t: u64) {
        let fb = &mut self.fabrics[f];
        if !fb.parked {
            return;
        }
        fb.parked = false;
        if fb.up {
            fb.active_since = t;
            fb.backlog_end = fb.backlog_end.max(t);
        }
        if let Some(tel) = self.tel.as_mut() {
            tel.count("autoscale_unpark", "", t, 1);
            tel.instant(SpanKind::Scale, f as u32, 0, t, 0);
        }
    }

    /// Telemetry sampler, fired at every window boundary `t = k*W`
    /// while work remains: closes window `k-1` by recording the
    /// utilization and queue-depth gauges, then lets the autoscale
    /// policy act on exactly those recorded values.
    fn on_sample(&mut self, t: u64) {
        let w = match &self.tel {
            Some(tel) => tel.window(),
            None => return,
        };
        let closed = (t / w).saturating_sub(1);
        let win_start = closed * w;
        let n = self.fabrics.len();
        let mut util_sum = 0u64;
        let mut active_n = 0u64;
        let mut queue_total = 0u64;
        for f in 0..n {
            let fb = &self.fabrics[f];
            queue_total += fb.queue.len() as u64;
            // Busy cycles already committed to the closed window by
            // completed service, plus the still-in-flight span's
            // overlap with it — all pure virtual time.
            let mut busy = self
                .tel
                .as_ref()
                .unwrap()
                .counter_window(
                    "fabric_busy_cycles",
                    &self.fab_labels[f],
                    closed,
                );
            if fb.up && fb.in_service.is_some() {
                let lo = fb.service_start.max(win_start);
                busy += t.saturating_sub(lo).min(w);
            }
            let util = (busy.min(w) * 1000) / w;
            if fb.routable() {
                util_sum += util;
                active_n += 1;
            }
            let depth = fb.queue.len() as u64;
            let tel = self.tel.as_mut().unwrap();
            tel.gauge("util_permille", &self.fab_labels[f], t - 1, util);
            tel.gauge("queue_depth", &self.fab_labels[f], t - 1, depth);
        }
        queue_total += self.pending.len() as u64;
        let mean_util = if active_n == 0 {
            0
        } else {
            util_sum / active_n
        };
        {
            let tel = self.tel.as_mut().unwrap();
            tel.gauge("util_permille", "node", t - 1, mean_util);
            tel.gauge("queue_depth", "node", t - 1, queue_total);
        }

        if let Some(pol) = self.cfg.autoscale {
            let now_w = t / w;
            // Read back exactly what was just recorded: the policy
            // consumes telemetry gauges, nothing else.
            let tel = self.tel.as_ref().unwrap();
            let util_g = tel
                .gauge_window("util_permille", "node", closed)
                .map(|c| c.max)
                .unwrap_or(0);
            let queue_g = tel
                .gauge_window("queue_depth", "node", closed)
                .map(|c| c.max)
                .unwrap_or(0);
            let cooled = now_w >= self.last_scale + pol.cooldown;
            let high = (util_g as f64) > pol.high * 1000.0;
            let spike = queue_g > active_n.max(1) * 2;
            let low = (util_g as f64) < pol.low * 1000.0;
            if cooled && (high || spike) {
                if let Some(f) = (0..n)
                    .find(|&f| self.fabrics[f].up && self.fabrics[f].parked)
                {
                    self.unpark(f, t);
                    self.last_scale = now_w;
                }
            } else if cooled && low && queue_total == 0 && active_n > 1
            {
                // Park the highest-id idle routable fabric.
                if let Some(f) = (0..n).rev().find(|&f| {
                    let fb = &self.fabrics[f];
                    fb.routable()
                        && fb.in_service.is_none()
                        && fb.queue.is_empty()
                }) {
                    self.park(f, t);
                    self.last_scale = now_w;
                }
            }
        }

        // Keep sampling only while work remains; otherwise let the
        // heap drain.
        let work_left = self.next_arr < self.reqs.len()
            || !self.pending.is_empty()
            || self.fabrics.iter().any(|f| {
                f.in_service.is_some() || !f.queue.is_empty()
            });
        if work_left {
            self.heap.push(Reverse((t + w, EV_SAMPLE, 0, 0)));
        }
    }

    fn run(&mut self) {
        for ev in &self.cfg.faults.events {
            self.heap.push(Reverse((
                ev.at,
                EV_DOWN,
                ev.fabric as u32,
                0,
            )));
            if let Some(r) = ev.restore {
                self.heap.push(Reverse((
                    r,
                    EV_UP,
                    ev.fabric as u32,
                    0,
                )));
                self.future_ups += 1;
            }
        }
        if !self.reqs.is_empty() {
            self.heap.push(Reverse((
                self.reqs[0].arrival,
                EV_ARRIVE,
                0,
                0,
            )));
        }
        if let Some(tel) = &self.tel {
            // First sampler fires at the end of window 0.
            self.heap.push(Reverse((tel.window(), EV_SAMPLE, 0, 0)));
        }
        while let Some(Reverse((t, kind, a, b))) = self.heap.pop() {
            self.events += 1;
            match kind {
                EV_DOWN => self.on_down(a as usize, t),
                EV_UP => {
                    self.future_ups -= 1;
                    self.on_up(a as usize, t);
                }
                EV_DONE => self.on_done(a as usize, b, t),
                EV_SAMPLE => self.on_sample(t),
                _ => self.on_arrive(t),
            }
        }
        debug_assert!(self.pending.is_empty());
    }
}

// ---------------------------------------------------- entry points --

/// Generate the arrival trace for `cfg.serve` and run the node.
pub fn run_node(
    svc: &GemmService,
    cfg: &NodeConfig,
) -> Result<NodeRun> {
    let trace = gen_arrivals(&cfg.serve);
    run_node_trace(svc, cfg, &trace)
}

/// Run the node over an explicit arrival trace (the property tests
/// feed shrunk traces through this entry point). Requests may arrive
/// unsorted; the engine orders them by `(arrival, id)` itself.
pub fn run_node_trace(
    svc: &GemmService,
    cfg: &NodeConfig,
    trace: &ArrivalTrace,
) -> Result<NodeRun> {
    ensure!(cfg.fabrics >= 1, "node needs at least one fabric");
    ensure!(
        !cfg.serve.models.is_empty(),
        "node serve needs at least one model"
    );
    ensure!(cfg.sessions >= 1, "node needs at least one session");
    if let Some(k) = cfg.admit_factor {
        ensure!(
            k.is_finite() && k > 0.0,
            "admit factor must be positive, got {k}"
        );
    }
    if let Some(pol) = &cfg.autoscale {
        pol.validate()?;
    }
    cfg.faults.validate(cfg.fabrics)?;
    // Telemetry window: explicit `--telemetry[-window]`, or implied
    // by the autoscaler (its signals *are* the windowed gauges).
    let tel_window = cfg.serve.telemetry.or_else(|| {
        cfg.autoscale.map(|_| telemetry::DEFAULT_WINDOW)
    });
    for r in &trace.requests {
        ensure!(
            r.model < cfg.serve.models.len(),
            "request {} names model index {} (mix has {})",
            r.id,
            r.model,
            cfg.serve.models.len()
        );
    }
    // Snapshot plan-cache counters before the cost probes so the
    // report covers the run's full cache behavior.
    let stats0 = svc.stats();
    // Per-model service cost: solo continuous-batching latency on one
    // idle fabric, probed through the real serve engine (backend,
    // FastPath, and calibration all apply). `max(1)` keeps the event
    // clock strictly progressing on degenerate costs.
    let costs: Vec<u64> = (0..cfg.serve.models.len())
        .map(|mi| {
            solo_latency(svc, &cfg.serve, mi, Policy::Continuous)
                .map(|c| c.max(1))
        })
        .collect::<Result<_>>()?;
    // SLO convention matches ServeSim: explicit, or 4x the isolated
    // FIFO latency of the mix's first model.
    let slo = match cfg.serve.slo {
        Some(s) => s,
        None => solo_latency(svc, &cfg.serve, 0, Policy::Fifo)?
            .saturating_mul(4),
    };

    let mut arrivals = trace.requests.clone();
    arrivals.sort_by_key(|r| (r.arrival, r.id));
    let reqs: Vec<Req> = arrivals
        .iter()
        .map(|r| Req {
            id: r.id,
            model: r.model,
            arrival: r.arrival,
            session: r.seed % cfg.sessions as u64,
        })
        .collect();
    let n_reqs = reqs.len();

    let mut eng = Engine {
        cfg,
        reqs,
        costs,
        slo,
        fabrics: (0..cfg.fabrics).map(|_| FabricSim::new()).collect(),
        heap: BinaryHeap::new(),
        pending: VecDeque::new(),
        future_ups: 0,
        next_arr: 0,
        rr_next: 0,
        sticky: HashMap::new(),
        p2c_rng: Rng::new(cfg.serve.seed ^ 0xD06_F00D),
        retries: vec![0; n_reqs],
        rows: Vec::with_capacity(n_reqs),
        sheds: Vec::new(),
        shed_admission: 0,
        shed_retry: 0,
        shed_unroutable: 0,
        retries_total: 0,
        slo_attained: 0,
        makespan: 0,
        events: 0,
        tel: tel_window.map(Telemetry::new),
        fab_labels: (0..cfg.fabrics)
            .map(|f| format!("fabric={f}"))
            .collect(),
        last_scale: 0,
    };
    eng.run();

    // Conservation is a hard runtime invariant, not just a test: a
    // node run that lost or double-counted a request is invalid.
    ensure!(
        eng.rows.len() + eng.sheds.len() == n_reqs,
        "request conservation violated: {} arrivals != {} \
         completions + {} sheds",
        n_reqs,
        eng.rows.len(),
        eng.sheds.len()
    );

    let mut rows = eng.rows;
    rows.sort_by_key(|r| r.id);
    let mut sheds = eng.sheds;
    sheds.sort_by_key(|s| s.id);

    // Close per-fabric accounting at the makespan: outage spans of
    // still-dead fabrics, and the provisioned-cycle integral.
    let mut active_cycles = 0u64;
    for (f, fb) in eng.fabrics.iter_mut().enumerate() {
        if !fb.up {
            if let Some(tel) = eng.tel.as_mut() {
                let end = eng.makespan.max(fb.down_at);
                tel.span(
                    SpanKind::Outage,
                    f as u32,
                    0,
                    fb.down_at,
                    end,
                    0,
                );
                tel.count_span(
                    "fabric_downtime_cycles",
                    &eng.fab_labels[f],
                    fb.down_at,
                    end,
                );
            }
        } else if !fb.parked {
            fb.active += eng.makespan.saturating_sub(fb.active_since);
        }
        active_cycles += fb.active;
    }
    let telemetry = eng.tel.take().map(|mut tel| {
        tel.seal(eng.makespan);
        tel
    });
    // The windowed busy series must conserve the fabric totals
    // exactly — a split that loses or duplicates cycles would make
    // every derived utilization signal a lie.
    if let Some(tel) = &telemetry {
        for (f, fb) in eng.fabrics.iter().enumerate() {
            let windowed =
                tel.counter_total("fabric_busy_cycles", &eng.fab_labels[f]);
            ensure!(
                windowed == fb.busy,
                "telemetry busy-cycle conservation violated on \
                 fabric {f}: Σ per-window {windowed} != total {}",
                fb.busy
            );
        }
    }

    let base_digest = run_digest(&rows, &sheds);
    let digest = match &telemetry {
        Some(tel) => {
            let mut h = Fnv64::new();
            h.write_u64(base_digest);
            tel.fold(&mut h);
            h.finish()
        }
        None => base_digest,
    };

    let per_fabric: Vec<FabricStats> = eng
        .fabrics
        .iter()
        .map(|f| FabricStats {
            served: f.served,
            busy_cycles: f.busy,
            lost_cycles: f.lost,
            downtime: match f.up {
                true => f.downtime,
                // Dead at end of run: downtime runs to the makespan.
                false => {
                    f.downtime
                        + eng.makespan.saturating_sub(f.down_at)
                }
            },
            latency: f.hist.clone(),
        })
        .collect();
    let mut latency = CycleHistogram::new();
    for f in &per_fabric {
        latency.merge(&f.latency);
    }

    let report = NodeReport {
        model: cfg.serve.models.join("+"),
        config: cfg.serve.config,
        backend: svc.backend_kind(),
        router: cfg.router,
        topo: cfg.topology(),
        rate_per_mcycle: cfg.serve.rate_per_mcycle,
        burst: cfg.serve.burst,
        seed: cfg.serve.seed,
        faults: cfg.faults.clone(),
        max_retries: cfg.max_retries,
        autoscale: cfg.autoscale,
        requests: n_reqs,
        completed: rows.len(),
        shed_admission: eng.shed_admission,
        shed_retry: eng.shed_retry,
        shed_unroutable: eng.shed_unroutable,
        retries_total: eng.retries_total,
        makespan_cycles: eng.makespan,
        latency,
        slo_cycles: slo,
        slo_attained: eng.slo_attained,
        model_costs: eng.costs,
        per_fabric,
        plan_stats: svc.stats().delta_since(&stats0),
        events: eng.events,
        active_cycles,
        digest,
    };
    Ok(NodeRun {
        report,
        models: cfg.serve.models.clone(),
        rows,
        sheds,
        telemetry,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg(fabrics: usize) -> NodeConfig {
        let mut serve = ServeConfig::new(vec!["ffn".into()]);
        serve.clusters = 2;
        serve.requests = 32;
        serve.rate_per_mcycle = 20.0;
        serve.seed = 7;
        NodeConfig::new(serve, fabrics)
    }

    #[test]
    fn router_names_round_trip() {
        for p in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PowerOfTwo,
            RouterPolicy::Affinity,
        ] {
            assert_eq!(RouterPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(RouterPolicy::from_name("nope"), None);
    }

    #[test]
    fn fault_plan_parse_round_trip() {
        let p =
            FaultPlan::parse("t=100,fabric=1,restore=200;t=5,fabric=0")
                .unwrap();
        assert_eq!(
            p.events,
            vec![
                FaultEvent {
                    at: 100,
                    fabric: 1,
                    restore: Some(200)
                },
                FaultEvent { at: 5, fabric: 0, restore: None },
            ]
        );
        assert_eq!(FaultPlan::parse(&p.summary()).unwrap(), p);
        assert_eq!(FaultPlan::parse("").unwrap().summary(), "none");
    }

    #[test]
    fn fault_plan_rejects_garbage() {
        assert!(FaultPlan::parse("t=1,fabric").is_err());
        assert!(FaultPlan::parse("t=1,rack=0").is_err());
        assert!(FaultPlan::parse("fabric=0").is_err());
        let p = FaultPlan::parse("t=9,fabric=4").unwrap();
        assert!(p.validate(4).is_err());
        assert!(p.validate(5).is_ok());
        let p = FaultPlan::parse("t=9,fabric=0,restore=9").unwrap();
        assert!(p.validate(1).is_err());
    }

    #[test]
    fn round_robin_balances_uniform_load() {
        let mut cfg = base_cfg(4);
        cfg.router = RouterPolicy::RoundRobin;
        let svc = GemmService::analytic();
        let run = run_node(&svc, &cfg).unwrap();
        assert_eq!(run.report.completed, 32);
        assert_eq!(run.report.shed_total(), 0);
        for f in &run.report.per_fabric {
            assert_eq!(f.served, 8);
        }
    }

    #[test]
    fn single_fabric_matches_serial_queue_recurrence() {
        let cfg = base_cfg(1);
        let svc = GemmService::analytic();
        let run = run_node(&svc, &cfg).unwrap();
        let cost = run.report.model_costs[0];
        // One fabric, one queue: completion is the textbook M/G/1
        // recurrence over arrivals.
        let mut prev = 0u64;
        for row in &run.rows {
            let expect = row.arrival.max(prev) + cost;
            assert_eq!(row.completion, expect, "req {}", row.id);
            prev = expect;
        }
    }

    #[test]
    fn admission_control_sheds_under_overload() {
        let mut cfg = base_cfg(1);
        cfg.serve.requests = 64;
        cfg.serve.rate_per_mcycle = 5000.0;
        cfg.admit_factor = Some(1.0);
        let svc = GemmService::analytic();
        let run = run_node(&svc, &cfg).unwrap();
        let r = &run.report;
        assert!(r.shed_admission > 0, "overload must shed");
        assert!(r.completed > 0, "some requests must still complete");
        assert_eq!(r.completed + r.shed_total(), r.requests);
        // Survivors met the admission bound at dispatch time, so the
        // tail is controlled: every completion is within factor x SLO
        // (service adds nothing past the estimate on one fabric).
        for row in &run.rows {
            assert!(row.latency <= r.slo_cycles);
        }
    }

    /// Eight requests all arrive at cycle 0, so both fabrics hold
    /// work mid-service at any fault time in `(0, cost]` — the
    /// scenario is valid whatever the probed service cost is.
    fn burst_trace(n: usize) -> ArrivalTrace {
        ArrivalTrace {
            requests: (0..n)
                .map(|id| crate::coordinator::serve::ServeRequest {
                    id,
                    model: 0,
                    arrival: 0,
                    seed: id as u64,
                })
                .collect(),
        }
    }

    #[test]
    fn retry_budget_exhaustion_sheds() {
        let mut cfg = base_cfg(2);
        cfg.max_retries = 0;
        let svc = GemmService::analytic();
        let cost =
            solo_latency(&svc, &cfg.serve, 0, Policy::Continuous)
                .unwrap()
                .max(1);
        // Down both fabrics while the first requests are still in
        // service: every orphan exceeds its zero retry budget.
        let at = (cost / 2).max(1);
        cfg.faults = FaultPlan {
            events: vec![
                FaultEvent {
                    at,
                    fabric: 0,
                    restore: Some(cost.saturating_mul(100)),
                },
                FaultEvent {
                    at,
                    fabric: 1,
                    restore: Some(cost.saturating_mul(100)),
                },
            ],
        };
        let run =
            run_node_trace(&svc, &cfg, &burst_trace(8)).unwrap();
        let r = &run.report;
        assert!(r.shed_retry > 0, "expected retry-budget sheds");
        assert_eq!(r.completed + r.shed_total(), r.requests);
        for s in &run.sheds {
            if s.reason == ShedReason::RetryBudget {
                assert!(s.retries > cfg.max_retries);
            }
        }
    }

    #[test]
    fn unroutable_when_every_fabric_dies_for_good() {
        let mut cfg = base_cfg(2);
        let svc = GemmService::analytic();
        let cost =
            solo_latency(&svc, &cfg.serve, 0, Policy::Continuous)
                .unwrap()
                .max(1);
        let at = (cost / 2).max(1);
        cfg.faults = FaultPlan {
            events: vec![
                FaultEvent { at, fabric: 0, restore: None },
                FaultEvent { at, fabric: 1, restore: None },
            ],
        };
        let run =
            run_node_trace(&svc, &cfg, &burst_trace(8)).unwrap();
        let r = &run.report;
        // Orphans keep retry budget but have nowhere to go: with no
        // restore scheduled they shed as unroutable, never parked.
        assert!(r.shed_unroutable > 0);
        assert_eq!(r.completed + r.shed_total(), r.requests);
        // Nothing completes after the node is dead.
        for row in &run.rows {
            assert!(row.completion < at);
        }
    }

    #[test]
    fn digest_tracks_outcome_not_incidentals() {
        let cfg = base_cfg(2);
        let svc = GemmService::analytic();
        let a = run_node(&svc, &cfg).unwrap();
        let b = run_node(&svc, &cfg).unwrap();
        assert_eq!(a.report.digest, b.report.digest);
        assert_eq!(a, b);
        let mut cfg2 = base_cfg(2);
        cfg2.serve.seed = 8;
        let c = run_node(&svc, &cfg2).unwrap();
        assert_ne!(a.report.digest, c.report.digest);
    }

    #[test]
    fn autoscale_parse_round_trip_and_rejects() {
        let p = AutoscalePolicy::parse("low=0.1,high=0.9,cooldown=5")
            .unwrap();
        assert_eq!(p.low, 0.1);
        assert_eq!(p.high, 0.9);
        assert_eq!(p.cooldown, 5);
        assert_eq!(AutoscalePolicy::parse(&p.summary()).unwrap(), p);
        // Any subset of fields keeps the other defaults.
        let d = AutoscalePolicy::parse("cooldown=7").unwrap();
        assert_eq!((d.low, d.high, d.cooldown), (0.2, 0.7, 7));
        assert!(AutoscalePolicy::parse("low=0.9,high=0.1").is_err());
        assert!(AutoscalePolicy::parse("cooldown=0").is_err());
        assert!(AutoscalePolicy::parse("verve=1").is_err());
        assert!(AutoscalePolicy::parse("low").is_err());
    }

    #[test]
    fn telemetry_conserves_busy_cycles_and_folds_into_digest() {
        let mut cfg = base_cfg(2);
        cfg.serve.telemetry = Some(50_000);
        let svc = GemmService::analytic();
        let run = run_node(&svc, &cfg).unwrap();
        let tel = run.telemetry.as_ref().expect("telemetry enabled");
        // Σ per-window busy == fabric total busy (also a runtime
        // ensure!; re-checked here against the report).
        for (f, fs) in run.report.per_fabric.iter().enumerate() {
            let label = format!("fabric={f}");
            assert_eq!(
                tel.counter_total("fabric_busy_cycles", &label),
                fs.busy_cycles,
            );
        }
        // Arrivals/completions counters conserve the request streams.
        assert_eq!(
            tel.counter_total("arrivals", "") as usize,
            run.report.requests,
        );
        let completions: u64 = (0..cfg.fabrics)
            .map(|f| {
                tel.counter_total("completions", &format!("fabric={f}"))
            })
            .sum();
        assert_eq!(completions as usize, run.report.completed);
        // The report digest is exactly base run_digest + tel fold.
        let mut h = Fnv64::new();
        h.write_u64(run_digest(&run.rows, &run.sheds));
        tel.fold(&mut h);
        assert_eq!(run.report.digest, h.finish());
        // And with telemetry off the digest is the bare run_digest.
        let mut plain = base_cfg(2);
        plain.serve.telemetry = None;
        let p = run_node(&svc, &plain).unwrap();
        assert!(p.telemetry.is_none());
        assert_eq!(p.report.digest, run_digest(&p.rows, &p.sheds));
        // Telemetry never changes the outcome streams themselves.
        assert_eq!(p.rows, run.rows);
        assert_eq!(p.sheds, run.sheds);
    }

    #[test]
    fn autoscaler_parks_idle_fabrics_without_adding_sheds() {
        // 4 fabrics at a trickle rate: fixed provisioning keeps all
        // four active for the whole makespan; the autoscaler should
        // park surplus fabrics (fewer provisioned cycles) while
        // shedding nothing the fixed node wouldn't.
        let mut fixed = base_cfg(4);
        fixed.serve.requests = 48;
        fixed.serve.rate_per_mcycle = 1.0;
        let svc = GemmService::analytic();
        let base = run_node(&svc, &fixed).unwrap();
        let mut auto_cfg = fixed.clone();
        auto_cfg.autoscale = Some(
            AutoscalePolicy::parse("low=0.3,high=0.9,cooldown=1")
                .unwrap(),
        );
        let auto_run = run_node(&svc, &auto_cfg).unwrap();
        let tel =
            auto_run.telemetry.as_ref().expect("autoscale implies tel");
        assert!(
            tel.counter_total("autoscale_park", "") > 0,
            "a trickle load on 4 fabrics must trigger parking"
        );
        assert!(
            auto_run.report.shed_total() <= base.report.shed_total(),
            "autoscaling must not add sheds at equal offered load"
        );
        assert!(
            auto_run.report.active_cycles
                < base.report.active_cycles,
            "parking must reduce provisioned fabric-cycles: {} vs {}",
            auto_run.report.active_cycles,
            base.report.active_cycles,
        );
        assert_eq!(
            auto_run.report.completed + auto_run.report.shed_total(),
            auto_run.report.requests,
        );
    }

    #[test]
    fn fault_shrinks_stay_valid() {
        let ev = FaultEvent { at: 100, fabric: 2, restore: Some(900) };
        for s in ev.shrinks() {
            if let Some(r) = s.restore {
                assert!(r > s.at, "shrink broke restore>at: {s:?}");
            }
        }
        let plan = FaultPlan { events: vec![ev, ev] };
        for p in plan.shrinks() {
            assert!(p.events.len() <= 2);
        }
    }
}
