//! The `zerostall profile` runner: cycle-accurate StallScope profiling
//! of a zoo model, layer by layer.
//!
//! Each GEMM layer of the model runs through the shared `GemmService`
//! plan cache on the cycle backend — on one cluster, or sharded
//! across a fabric when `--clusters N` and the partitioner finds a
//! useful grid — with the per-cycle stall classifier always on and an
//! optional Chrome-trace collector attached. Layers execute
//! sequentially on one stitched timeline (layer `i+1` starts at layer
//! `i`'s halt cycle), so the exported trace shows the whole model.
//!
//! The run *fails* if any layer violates the stall-conservation
//! invariant `useful + Σstalls == cycles` on any core — this is the
//! check the CI smoke step leans on.
//!
//! Unfused elementwise ops (residual adds) have no kernel to profile;
//! they are skipped and reported, with their fused counterparts
//! visible inside the GEMM layers' epilogues.

use anyhow::{Context, Result};

use crate::backend::CycleAccurate;
use crate::cluster::ConfigId;
use crate::fabric::{ClusterFabric, FabricConfig};
use crate::kernels::{
    choose_shard_grid, problem_seed, test_bias, test_matrices,
    Epilogue, GemmService, LayoutKind,
};
use crate::profile::roofline::{self, Ceilings, RooflinePoint};
use crate::profile::{ChromeTrace, StallProfile};

use super::workload::graph::NetOp;
use super::workload::{zoo, Problem};

/// Profiling-run parameters.
#[derive(Clone, Debug)]
pub struct ProfileOpts {
    pub model: String,
    pub config: ConfigId,
    pub clusters: usize,
    pub layout: LayoutKind,
    /// Collect a Chrome trace (costs memory proportional to the
    /// number of stall-class transitions).
    pub trace: bool,
    /// FastPath stepping (bit-identical; regions auto-disable while a
    /// trace collector is attached, so `--trace` runs stay exact too).
    pub fast_forward: bool,
}

impl ProfileOpts {
    pub fn new(model: &str) -> ProfileOpts {
        ProfileOpts {
            model: model.to_string(),
            config: ConfigId::Zonl48Db,
            clusters: 1,
            layout: LayoutKind::Grouped,
            trace: false,
            fast_forward: true,
        }
    }
}

/// One profiled GEMM layer.
#[derive(Clone, Debug)]
pub struct LayerProfile {
    pub name: String,
    pub problem: Problem,
    pub epilogue: String,
    /// Clusters the layer actually ran on (1 = whole on one cluster).
    pub shards: usize,
    /// End-to-end layer cycles (slowest cluster on sharded layers).
    pub cycles: u64,
    pub stalls: StallProfile,
    pub roofline: RooflinePoint,
}

/// The whole profiling run.
#[derive(Clone, Debug)]
pub struct ProfileReport {
    pub model: String,
    pub config: ConfigId,
    pub clusters: usize,
    pub layers: Vec<LayerProfile>,
    /// Layer-serial merge of every layer's profile (per-core counters
    /// add, windows add): the model-level breakdown.
    pub merged: StallProfile,
    pub total_cycles: u64,
    /// Elementwise ops skipped (no kernel to profile).
    pub skipped_adds: usize,
    pub ceilings: Ceilings,
}

/// Run the profiler. Returns the report plus the Chrome trace when
/// `opts.trace` is set.
pub fn run_profile(
    opts: &ProfileOpts,
) -> Result<(ProfileReport, Option<ChromeTrace>)> {
    let g = zoo::build(&opts.model)?;
    let order = g.topo_order()?;
    let clusters = opts.clusters.max(1);
    let fabric = FabricConfig::new(clusters);
    let ceilings = Ceilings::new(clusters, &fabric.noc);
    let svc = GemmService::cycle();

    let mut chrome = if opts.trace {
        let mut t = ChromeTrace::default();
        let n_compute = opts.config.cluster_config().n_compute;
        for pid in 0..clusters as u32 {
            t.label_cluster(pid, n_compute);
        }
        Some(t)
    } else {
        None
    };

    let mut layers = Vec::new();
    let mut t_off = 0u64;
    let mut skipped_adds = 0usize;
    for &oi in &order {
        let NetOp::Gemm { name, x, w, epi, .. } = &g.ops[oi] else {
            skipped_adds += 1;
            continue;
        };
        let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
        let p = Problem { m: xt.rows, n: wt.cols, k: xt.cols };
        let seed = problem_seed(p.m, p.n, p.k);
        let (a, b) = test_matrices(p.m, p.n, p.k, seed);
        let bias = if epi.bias {
            test_bias(p.n, seed)
        } else {
            Vec::new()
        };

        let grid = choose_shard_grid(p.m, p.n, clusters);
        let (cycles, stalls, shards, ops, bytes, window) =
            if clusters > 1 && grid.used_clusters() > 1 {
                run_layer_sharded(
                    &svc, opts, p, *epi, &a, &b, &bias, &fabric,
                    chrome.as_mut(), t_off, name,
                )?
            } else {
                run_layer_single(
                    &svc, opts, p, *epi, &a, &b, &bias,
                    chrome.as_mut(), t_off, name,
                )?
            };

        stalls.check_conservation().map_err(|e| {
            anyhow::anyhow!("layer `{name}`: {e}")
        })?;
        // Place the point against the ceilings of where it actually
        // ran: unsharded layers occupy one cluster, never the fabric
        // aggregate.
        let roof = roofline::point(
            name.clone(),
            ops,
            bytes,
            window,
            &Ceilings::new(shards, &fabric.noc),
        );
        layers.push(LayerProfile {
            name: name.clone(),
            problem: p,
            epilogue: epi.name(),
            shards,
            cycles,
            stalls,
            roofline: roof,
        });
        t_off += cycles;
    }

    let mut merged = StallProfile::default();
    for l in &layers {
        merged.merge_serial(&l.stalls);
    }
    merged
        .check_conservation()
        .map_err(|e| anyhow::anyhow!("merged profile: {e}"))?;

    let report = ProfileReport {
        model: opts.model.clone(),
        config: opts.config,
        clusters,
        layers,
        merged,
        total_cycles: t_off,
        skipped_adds,
        ceilings,
    };
    Ok((report, chrome))
}

#[allow(clippy::too_many_arguments)]
fn run_layer_single(
    svc: &GemmService,
    opts: &ProfileOpts,
    p: Problem,
    epi: Epilogue,
    a: &[f64],
    b: &[f64],
    bias: &[f64],
    chrome: Option<&mut ChromeTrace>,
    t_off: u64,
    name: &str,
) -> Result<(u64, StallProfile, usize, u64, u64, u64)> {
    let prep = svc.prepare_fused(
        opts.config, p.m, p.n, p.k, opts.layout, epi,
    )?;
    let mut cl = CycleAccurate::build_cluster(&prep, a, b, bias)?;
    if chrome.is_some() {
        cl.attach_trace(0, t_off);
        if let Some(t) = cl.trace.as_mut() {
            t.instant(format!("layer:{name}"), 0);
        }
    }
    let deadline = CycleAccurate::deadline(p.m, p.n, p.k);
    if opts.fast_forward {
        cl.run_fast(deadline)
    } else {
        cl.run(deadline)
    }
    .with_context(|| format!("layer `{name}`"))?;
    let perf = cl.perf();
    if let (Some(t), Some(buf)) = (chrome, cl.take_trace()) {
        t.push(*buf);
    }
    Ok((
        cl.cycle,
        perf.stalls.clone(),
        1,
        perf.fpu_ops_total,
        perf.dma_bytes,
        perf.window_cycles,
    ))
}

#[allow(clippy::too_many_arguments)]
fn run_layer_sharded(
    svc: &GemmService,
    opts: &ProfileOpts,
    p: Problem,
    epi: Epilogue,
    a: &[f64],
    b: &[f64],
    bias: &[f64],
    fabric: &FabricConfig,
    chrome: Option<&mut ChromeTrace>,
    t_off: u64,
    name: &str,
) -> Result<(u64, StallProfile, usize, u64, u64, u64)> {
    let sh = svc.prepare_sharded(
        opts.config,
        p.m,
        p.n,
        p.k,
        opts.layout,
        epi,
        fabric.clusters,
    )?;
    let mut clusters =
        CycleAccurate::build_shard_clusters(&sh, a, b, bias)?;
    if chrome.is_some() {
        for (ci, cl) in clusters.iter_mut().enumerate() {
            cl.attach_trace(ci as u32, t_off);
        }
        if let Some(t) = clusters[0].trace.as_mut() {
            t.instant(format!("layer:{name}"), 0);
        }
    }
    let deadline = CycleAccurate::shard_deadline(&sh);
    let mut fab = ClusterFabric::new(clusters, fabric.noc);
    if opts.fast_forward {
        fab.run_fast(deadline, 0)
    } else {
        fab.run(deadline)
    }
    .with_context(|| format!("layer `{name}`"))?;
    let fr = CycleAccurate::gather(&sh, &fab);
    if let Some(t) = chrome {
        for cl in fab.clusters.iter_mut() {
            if let Some(buf) = cl.take_trace() {
                t.push(*buf);
            }
        }
    }
    let bytes: u64 = fr.shards.iter().map(|s| s.perf.dma_bytes).sum();
    Ok((
        fr.cycles,
        fr.stall_profile(),
        fr.clusters(),
        fr.fpu_ops_total(),
        bytes,
        fr.window_cycles(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::StallClass;

    #[test]
    fn profile_ffn_conserves_and_matches_utilization() {
        let opts = ProfileOpts::new("ffn");
        let (rep, trace) = run_profile(&opts).unwrap();
        assert!(trace.is_none(), "trace off by default");
        assert_eq!(rep.skipped_adds, 1, "ffn has one residual add");
        assert_eq!(rep.layers.len(), 2);
        assert!(rep.total_cycles > 0);
        rep.merged.check_conservation().unwrap();
        for l in &rep.layers {
            // Useful share over the window == ClusterPerf utilization
            // convention; near-peak on the Dobu config.
            assert!(
                l.stalls.utilization() > 0.5,
                "{}: util {}",
                l.name,
                l.stalls.utilization()
            );
            assert!(l.roofline.ops > 0);
            assert!(l.roofline.bytes > 0);
        }
        // Dobu: ~no bank conflicts (the paper's zero-conflict claim).
        let shares = rep.merged.shares();
        assert!(
            shares[StallClass::BankConflict as usize] < 0.05,
            "Dobu bank-conflict share {}",
            shares[StallClass::BankConflict as usize]
        );
    }

    #[test]
    fn profile_sharded_with_trace_stitches_clusters() {
        let mut opts = ProfileOpts::new("qkv");
        opts.clusters = 2;
        opts.trace = true;
        let (rep, trace) = run_profile(&opts).unwrap();
        let trace = trace.unwrap();
        assert_eq!(rep.clusters, 2);
        assert!(rep.layers.iter().any(|l| l.shards > 1));
        assert!(!trace.events.is_empty());
        assert!(trace.processes.len() >= 2, "both clusters labeled");
        let json = trace.to_json();
        assert!(json.contains("layer:qkv_proj"));
        assert!(json.contains("Useful"));
    }

    #[test]
    fn profile_rejects_unknown_model() {
        assert!(run_profile(&ProfileOpts::new("resnet9000")).is_err());
    }
}
