//! Report rendering: the paper's tables/figures as markdown + CSV, and
//! ASCII box plots for Fig. 5.

use std::path::Path;

use crate::cluster::ConfigId;
use crate::model::congestion;
use crate::profile::telemetry::Telemetry;
use crate::profile::{RooflinePoint, StallClass, StallProfile, N_CLASSES};
use crate::util::csv::{f, Csv};
use crate::util::stats::{box_stats, ratio, BoxStats};

use super::experiments::{
    AblationRow, ErrorRow, Fig5Row, Fig5Summary, Headline, Table2Row,
};
use crate::backend::Calibration;
use crate::model::area::AreaBreakdown;

// ------------------------------------------------------------- Table I --

pub fn render_table1(rows: &[AreaBreakdown]) -> String {
    let base = rows
        .iter()
        .find(|r| r.id == ConfigId::Base32Fc)
        .expect("base config present");
    let mut out = String::new();
    out.push_str(
        "## Table I — area [MGE] and routing [mm] per configuration\n\n",
    );
    out.push_str(
        "| Configuration | Cell area | Macro area | Wire length | Total \
         area | Δ total |\n|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let d_wire =
            (r.wire_mm - base.wire_mm) / base.wire_mm * 100.0;
        let d_tot = (r.total_mge() - base.total_mge())
            / base.total_mge()
            * 100.0;
        out.push_str(&format!(
            "| {} | {} | {} | {} ({:+.1}%) | {} | {:+.1}% |\n",
            r.id.name(),
            f(r.cell_mge, 2),
            f(r.macro_mge, 2),
            f(r.wire_mm, 1),
            d_wire,
            f(r.total_mge(), 2),
            d_tot,
        ));
    }
    out
}

pub fn table1_csv(rows: &[AreaBreakdown]) -> Csv {
    let mut c = Csv::new(vec![
        "config", "cell_mge", "macro_mge", "wire_mm", "total_mge",
    ]);
    for r in rows {
        c.row(vec![
            r.id.name().to_string(),
            f(r.cell_mge, 3),
            f(r.macro_mge, 3),
            f(r.wire_mm, 2),
            f(r.total_mge(), 3),
        ]);
    }
    c
}

// ------------------------------------------------------------- Fig. 5 --

/// ASCII box plot of one metric across configurations.
pub fn render_boxes(
    title: &str,
    items: &[(&str, BoxStats)],
    unit: &str,
) -> String {
    let lo = items.iter().map(|(_, s)| s.min).fold(f64::MAX, f64::min);
    let hi = items.iter().map(|(_, s)| s.max).fold(f64::MIN, f64::max);
    let span = (hi - lo).max(1e-9);
    let width = 56usize;
    let pos =
        |x: f64| (((x - lo) / span) * (width - 1) as f64).round() as usize;
    let mut out = format!("{title} [{unit}]  ({lo:.3} .. {hi:.3})\n");
    for (name, s) in items {
        let mut line = vec![b' '; width];
        let (q1, q3) = (pos(s.q1), pos(s.q3));
        let med = pos(s.median);
        let (mn, mx) = (pos(s.min), pos(s.max));
        for c in line.iter_mut().take(q3.max(q1) + 1).skip(q1.min(q3)) {
            *c = b'=';
        }
        for c in line.iter_mut().take(q1).skip(mn) {
            *c = b'-';
        }
        for c in line.iter_mut().take(mx + 1).skip(q3 + 1) {
            *c = b'-';
        }
        line[med] = b'|';
        out.push_str(&format!(
            "{:<10} {}  med {}\n",
            name,
            String::from_utf8(line).unwrap(),
            f(s.median, 3)
        ));
    }
    out
}

pub fn render_fig5(summary: &[Fig5Summary]) -> String {
    let mut out = String::new();
    out.push_str("## Fig. 5 — distributions over the random-size sweep\n\n");
    let utils: Vec<(&str, BoxStats)> = summary
        .iter()
        .map(|s| (s.config.name(), s.utilization))
        .collect();
    out.push_str(&render_boxes("FPU utilization", &utils, "frac"));
    out.push('\n');
    let pw: Vec<(&str, BoxStats)> = summary
        .iter()
        .map(|s| (s.config.name(), s.power_mw))
        .collect();
    out.push_str(&render_boxes("Average power", &pw, "mW"));
    out.push('\n');
    let eff: Vec<(&str, BoxStats)> = summary
        .iter()
        .map(|s| (s.config.name(), s.gflops_per_w))
        .collect();
    out.push_str(&render_boxes("Energy efficiency", &eff, "DPGflop/s/W"));
    out
}

pub fn fig5_csv(rows: &[Fig5Row]) -> Csv {
    let mut c = Csv::new(vec![
        "config", "m", "n", "k", "utilization", "power_mw", "gflops",
        "gflops_per_w", "cycles", "window_cycles", "conflicts",
    ]);
    for r in rows {
        c.row(vec![
            r.config.name().to_string(),
            r.problem.m.to_string(),
            r.problem.n.to_string(),
            r.problem.k.to_string(),
            f(r.utilization, 5),
            f(r.power_mw, 2),
            f(r.gflops, 3),
            f(r.gflops_per_w, 3),
            r.cycles.to_string(),
            r.window_cycles.to_string(),
            r.conflicts.to_string(),
        ]);
    }
    c
}

pub fn render_headline(h: &Headline) -> String {
    format!(
        "## Headline (abstract / §IV-B)\n\n\
         * zonl48db utilization: {:.1}% .. {:.1}% (whiskers), median \
         {:.1}% (paper: 96.1%..99.4%)\n\
         * baseline median utilization: {:.1}% (paper: 88.2%)\n\
         * median performance improvement vs baseline: {:+.1}% \
         (paper: +11%)\n\
         * median energy-efficiency improvement vs baseline: {:+.1}% \
         (paper: +8%)\n",
        h.zonl48_util_min * 100.0,
        h.zonl48_util_max * 100.0,
        h.zonl48_util_median * 100.0,
        h.base_util_median * 100.0,
        h.perf_gain_pct,
        h.eff_gain_pct,
    )
}

// ------------------------------------------------------------ Table II --

pub fn render_table2(rows: &[Table2Row]) -> String {
    let mut out = String::new();
    out.push_str("## Table II — SoA comparison on 32x32x32 DP GEMM\n\n");
    out.push_str(
        "| System | Area comp | mem | interco | ctrl | total [MGE] | \
         Power comp | mem | interco | ctrl | total [mW] | Util | Perf \
         [Gflop/s] | Area eff | Energy eff |\n",
    );
    out.push_str(
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | \
             {:.1}% | {} | {} | {} |\n",
            r.name,
            f(r.area_comp, 2),
            f(r.area_mem, 2),
            f(r.area_interco, 2),
            f(r.area_ctrl, 2),
            f(r.area_total, 2),
            f(r.pow_comp, 1),
            f(r.pow_mem, 1),
            f(r.pow_interco, 1),
            f(r.pow_ctrl, 1),
            f(r.pow_total, 1),
            r.utilization * 100.0,
            f(r.perf_gflops, 2),
            f(r.area_eff, 1),
            f(r.energy_eff, 1),
        ));
    }
    out
}

pub fn table2_csv(rows: &[Table2Row]) -> Csv {
    let mut c = Csv::new(vec![
        "system", "area_total_mge", "power_total_mw", "utilization",
        "perf_gflops", "area_eff", "energy_eff",
    ]);
    for r in rows {
        c.row(vec![
            r.name.clone(),
            f(r.area_total, 3),
            f(r.pow_total, 1),
            f(r.utilization, 4),
            f(r.perf_gflops, 3),
            f(r.area_eff, 2),
            f(r.energy_eff, 2),
        ]);
    }
    c
}

// ------------------------------------------------------------- Fig. 4 --

pub fn render_fig4() -> String {
    let mut out = String::new();
    out.push_str("## Fig. 4 — routing congestion proxy\n\n```\n");
    out.push_str(&congestion::render_fig4());
    out.push_str("```\n");
    out
}

// ----------------------------------------------------------- ablation --

pub fn render_ablation(rows: &[AblationRow]) -> String {
    let mut out = String::new();
    out.push_str("## Layout ablation (32x32x32)\n\n");
    out.push_str("| config | layout | utilization | conflicts |\n");
    out.push_str("|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {:.1}% | {} |\n",
            r.config.name(),
            r.layout,
            r.utilization * 100.0,
            r.conflicts
        ));
    }
    out
}

// -------------------------------------------- analytic calibration --

pub fn render_calibration(cal: &Calibration) -> String {
    let mut out = String::new();
    out.push_str("## Analytic-model calibration constants\n\n");
    out.push_str(
        "| config | alpha (cyc/pass) | beta (cyc/outer-iter) | gamma \
         (cyc/contested beat) | epsilon (cyc/epilogue op) | delta \
         (NoC serialization frac) |\n\
         |---|---|---|---|---|---|\n",
    );
    for (id, c) in cal.entries() {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} |\n",
            id.name(),
            f(c.alpha, 2),
            f(c.beta, 2),
            f(c.gamma, 3),
            f(c.epsilon, 3),
            f(c.delta, 3),
        ));
    }
    out
}

pub fn render_error_table(rows: &[ErrorRow]) -> String {
    let mut out = String::new();
    out.push_str("## Analytic vs cycle-accurate error\n\n");
    out.push_str(
        "| config | points | mean util err | max util err | mean \
         window err | max window err |\n|---|---|---|---|---|---|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {:.1}% | {:.1}% | {:.1}% | {:.1}% |\n",
            r.config.name(),
            r.points,
            r.mean_util_err * 100.0,
            r.max_util_err * 100.0,
            r.mean_window_err * 100.0,
            r.max_window_err * 100.0,
        ));
    }
    out
}

pub fn error_csv(rows: &[ErrorRow]) -> Csv {
    let mut c = Csv::new(vec![
        "config",
        "points",
        "mean_util_err",
        "max_util_err",
        "mean_window_err",
        "max_window_err",
    ]);
    for r in rows {
        c.row(vec![
            r.config.name().to_string(),
            r.points.to_string(),
            f(r.mean_util_err, 5),
            f(r.max_util_err, 5),
            f(r.mean_window_err, 5),
            f(r.max_window_err, 5),
        ]);
    }
    c
}

// -------------------------------------------------------- NetGraph --

pub fn render_net(r: &crate::coordinator::net::NetReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "## Network `{}` on {} via the `{}` backend\n\n",
        r.model,
        r.config.name(),
        r.backend.name(),
    ));
    out.push_str(
        "| layer | kind | shape | epilogue | placement | cycles | \
         window | util | power [mW] | energy [uJ] | fused elems | \
         extra TCDM trips |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for l in &r.layers {
        let shape = match &l.problem {
            Some(p) => p.to_string(),
            None => "-".to_string(),
        };
        let placement = if l.shards > 1 {
            format!("sharded x{}", l.shards)
        } else {
            format!("cl{}", l.cluster)
        };
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {:.1}% | {} | {} | \
             {} | {} |\n",
            l.name,
            l.kind,
            shape,
            l.epilogue,
            placement,
            l.cycles,
            l.window_cycles,
            l.utilization * 100.0,
            f(l.power_mw, 1),
            f(l.energy_uj, 2),
            l.fused_elems,
            l.extra_roundtrips,
        ));
    }
    out.push_str(&format!(
        "\n* end-to-end: {} cycles, {} uJ, {:.1}% utilization over \
         {} MACs\n\
         * fused epilogue elements: {} (TCDM round-trips avoided); \
         extra round-trips from unfused ops: {}\n\
         * peak live tensor bytes: {} | plan cache: {} hits / {} \
         misses\n",
        r.total_cycles,
        f(r.total_energy_uj, 2),
        r.utilization * 100.0,
        r.total_macs,
        r.fused_elems,
        r.extra_roundtrips,
        r.peak_live_bytes,
        r.plan_stats.plan_hits,
        r.plan_stats.plan_misses,
    ));
    if r.clusters > 1 {
        let speedup = r.serial_cycles as f64
            / (r.total_cycles.max(1)) as f64;
        out.push_str(&format!(
            "* fabric: {} clusters, scheduling speedup {:.2}x vs \
             serialized waves ({} cycles), fabric utilization {:.1}%\n",
            r.clusters,
            speedup,
            r.serial_cycles,
            r.fabric_utilization * 100.0,
        ));
        for (ci, (&cyc, &uj)) in r
            .per_cluster_cycles
            .iter()
            .zip(&r.per_cluster_energy_uj)
            .enumerate()
        {
            out.push_str(&format!(
                "  * cluster {ci}: busy {} cycles ({:.0}% of \
                 end-to-end), {} uJ\n",
                cyc,
                cyc as f64 / r.total_cycles.max(1) as f64 * 100.0,
                f(uj, 2),
            ));
        }
    }
    out
}

pub fn net_csv(r: &crate::coordinator::net::NetReport) -> Csv {
    let mut c = Csv::new(vec![
        "layer",
        "kind",
        "m",
        "n",
        "k",
        "epilogue",
        "cluster",
        "shards",
        "cycles",
        "window_cycles",
        "utilization",
        "power_mw",
        "energy_uj",
        "fused_elems",
        "extra_roundtrips",
    ]);
    for l in &r.layers {
        let (m, n, k) = match &l.problem {
            Some(p) => {
                (p.m.to_string(), p.n.to_string(), p.k.to_string())
            }
            None => ("".into(), "".into(), "".into()),
        };
        c.row(vec![
            l.name.clone(),
            l.kind.to_string(),
            m,
            n,
            k,
            l.epilogue.clone(),
            l.cluster.to_string(),
            l.shards.to_string(),
            l.cycles.to_string(),
            l.window_cycles.to_string(),
            f(l.utilization, 5),
            f(l.power_mw, 2),
            f(l.energy_uj, 4),
            l.fused_elems.to_string(),
            l.extra_roundtrips.to_string(),
        ]);
    }
    c
}

// ------------------------------------------------------------ serve --

pub fn render_serve(r: &crate::coordinator::serve::ServeReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "## Serve `{}` on {} via the `{}` backend — policy `{}`, \
         {} clusters\n\n",
        r.model,
        r.config.name(),
        r.backend.name(),
        r.policy.name(),
        r.clusters,
    ));
    out.push_str(&format!(
        "* offered load: {:.2} req/Mcycle (burst {:.2}), {} requests, \
         seed {}\n",
        r.rate_per_mcycle, r.burst, r.requests, r.seed,
    ));
    out.push_str(&format!(
        "* completed: {} in {} cycles -> sustained {:.3} req/Mcycle\n",
        r.completed,
        r.makespan_cycles,
        r.throughput_per_mcycle(),
    ));
    out.push_str(&format!(
        "* latency cycles: p50 {} / p95 {} / p99 {} (mean {:.0}, min \
         {}, max {})\n",
        r.p50(),
        r.p95(),
        r.p99(),
        r.latency.mean(),
        r.latency.min(),
        r.latency.max(),
    ));
    out.push_str(&format!(
        "* SLO {} cycles: {}/{} attained ({:.1}%) -> {:.3} attained \
         req/Mcycle\n",
        r.slo_cycles,
        r.slo_attained,
        r.completed,
        r.slo_attainment() * 100.0,
        r.slo_attained_throughput(),
    ));
    out.push_str(&format!(
        "* scheduler: {} waves ({} tensor-parallel), {} GEMM \
         dispatches over {} ops\n",
        r.waves, r.sharded_waves, r.gemm_ops, r.total_ops,
    ));
    out.push_str(&format!(
        "* plan cache: {} hits / {} misses ({:.1}% hit rate under \
         churn)\n",
        r.plan_stats.plan_hits,
        r.plan_stats.plan_misses,
        r.plan_stats.hit_rate() * 100.0,
    ));
    for (ci, u) in r.cluster_utilization().iter().enumerate() {
        out.push_str(&format!(
            "  * cluster {ci}: busy {} cycles ({:.1}% of makespan)\n",
            r.per_cluster_busy[ci],
            u * 100.0,
        ));
    }
    out
}

pub fn serve_csv(run: &crate::coordinator::serve::ServeRun) -> Csv {
    let mut c = Csv::new(vec![
        "req",
        "model",
        "arrival",
        "completion",
        "latency_cycles",
        "slo_met",
        "ops",
    ]);
    for row in &run.rows {
        c.row(vec![
            row.id.to_string(),
            run.models[row.model].clone(),
            row.arrival.to_string(),
            row.completion.to_string(),
            row.latency.to_string(),
            (row.slo_met as u8).to_string(),
            row.ops.to_string(),
        ]);
    }
    c
}

// ----------------------------------------------------- NodeSim --

pub fn render_node(r: &crate::coordinator::node::NodeReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "## Node serve `{}` on {} via the `{}` backend — router \
         `{}`, {} fabrics x {} clusters\n\n",
        r.model,
        r.config.name(),
        r.backend.name(),
        r.router.name(),
        r.topo.fabrics,
        r.topo.fabric.clusters,
    ));
    out.push_str(&format!(
        "* offered load: {:.2} req/Mcycle (burst {:.2}), {} requests, \
         seed {}\n",
        r.rate_per_mcycle, r.burst, r.requests, r.seed,
    ));
    out.push_str(&format!(
        "* fault plan: {} (max retries {})\n",
        r.faults.summary(),
        r.max_retries,
    ));
    if let Some(pol) = &r.autoscale {
        out.push_str(&format!(
            "* autoscale: {} — provisioned {} fabric-cycles\n",
            pol.summary(),
            r.active_cycles,
        ));
    }
    out.push_str(&format!(
        "* completed: {} in {} cycles -> sustained {:.3} req/Mcycle\n",
        r.completed,
        r.makespan_cycles,
        r.throughput_per_mcycle(),
    ));
    out.push_str(&format!(
        "* shed: {} ({} admission / {} retry-budget / {} \
         unroutable); retries: {}\n",
        r.shed_total(),
        r.shed_admission,
        r.shed_retry,
        r.shed_unroutable,
        r.retries_total,
    ));
    out.push_str(&format!(
        "* latency cycles: p50 {} / p95 {} / p99 {} (mean {:.0}, min \
         {}, max {})\n",
        r.p50(),
        r.p95(),
        r.p99(),
        r.latency.mean(),
        r.latency.min(),
        r.latency.max(),
    ));
    out.push_str(&format!(
        "* SLO {} cycles: {}/{} attained ({:.1}%)\n",
        r.slo_cycles,
        r.slo_attained,
        r.completed,
        r.slo_attainment() * 100.0,
    ));
    out.push_str(&format!(
        "* run digest: 0x{:016x} ({} heap events)\n",
        r.digest, r.events,
    ));
    out.push_str(&format!(
        "* service cost model (cycles/request): {}\n",
        r.model_costs
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(" / "),
    ));
    out.push_str(&format!(
        "* plan cache: {} hits / {} misses ({:.1}% hit rate over the \
         cost probes)\n",
        r.plan_stats.plan_hits,
        r.plan_stats.plan_misses,
        r.plan_stats.hit_rate() * 100.0,
    ));
    for (fi, (fs, u)) in r
        .per_fabric
        .iter()
        .zip(r.fabric_utilization())
        .enumerate()
    {
        out.push_str(&format!(
            "  * fabric {fi}: served {}, busy {} cycles ({:.1}% of \
             makespan), lost {}, down {}, p99 {}\n",
            fs.served,
            fs.busy_cycles,
            u * 100.0,
            fs.lost_cycles,
            fs.downtime,
            fs.latency.quantile(0.99),
        ));
    }
    out
}

pub fn node_csv(run: &crate::coordinator::node::NodeRun) -> Csv {
    let mut c = Csv::new(vec![
        "req",
        "model",
        "session",
        "fabric",
        "arrival",
        "dispatched",
        "completion",
        "latency_cycles",
        "retries",
        "slo_met",
    ]);
    for row in &run.rows {
        c.row(vec![
            row.id.to_string(),
            run.models[row.model].clone(),
            row.session.to_string(),
            row.fabric.to_string(),
            row.arrival.to_string(),
            row.dispatched.to_string(),
            row.completion.to_string(),
            row.latency.to_string(),
            row.retries.to_string(),
            (row.slo_met as u8).to_string(),
        ]);
    }
    c
}

pub fn node_sheds_csv(run: &crate::coordinator::node::NodeRun) -> Csv {
    let mut c = Csv::new(vec![
        "req", "model", "session", "arrival", "shed_at", "retries",
        "reason",
    ]);
    for s in &run.sheds {
        c.row(vec![
            s.id.to_string(),
            run.models[s.model].clone(),
            s.session.to_string(),
            s.arrival.to_string(),
            s.at.to_string(),
            s.retries.to_string(),
            s.reason.name().to_string(),
        ]);
    }
    c
}

pub fn node_fabric_csv(
    r: &crate::coordinator::node::NodeReport,
) -> Csv {
    let mut c = Csv::new(vec![
        "fabric",
        "served",
        "busy_cycles",
        "utilization",
        "lost_cycles",
        "downtime",
        "p50",
        "p99",
    ]);
    for (fi, (fs, u)) in r
        .per_fabric
        .iter()
        .zip(r.fabric_utilization())
        .enumerate()
    {
        c.row(vec![
            fi.to_string(),
            fs.served.to_string(),
            fs.busy_cycles.to_string(),
            f(u, 4),
            fs.lost_cycles.to_string(),
            fs.downtime.to_string(),
            fs.latency.quantile(0.50).to_string(),
            fs.latency.quantile(0.99).to_string(),
        ]);
    }
    c
}

// -------------------------------------------------- TimeScope --

/// The `telemetry.csv` time-series artifact: one row per
/// `(series, window, aggregate)` in canonical (BTreeMap) order.
/// Counter series are emitted **densely** over `0..=last_window` —
/// a window where nothing happened is an explicit `0` row, so a
/// utilization dip or completion stall during an outage is visible
/// in the artifact itself, not inferred from missing rows. Gauge
/// and histogram series are sparse (only observed windows).
pub fn telemetry_csv(tel: &Telemetry) -> Csv {
    let w = tel.window();
    let mut c = Csv::new(vec![
        "metric", "labels", "window", "t_start", "t_end", "kind",
        "value",
    ]);
    let span =
        |k: u64| ((k * w).to_string(), ((k + 1) * w).to_string());
    for ((metric, labels), series) in tel.counter_series() {
        for k in 0..=tel.last_window() {
            let (t0, t1) = span(k);
            c.row(vec![
                metric.to_string(),
                labels.clone(),
                k.to_string(),
                t0,
                t1,
                "count".to_string(),
                series.get(&k).copied().unwrap_or(0).to_string(),
            ]);
        }
    }
    for ((metric, labels), series) in tel.gauge_series() {
        for (&k, cell) in series {
            let (t0, t1) = span(k);
            for (kind, value) in [
                ("gauge_min", cell.min.to_string()),
                ("gauge_max", cell.max.to_string()),
                ("gauge_mean", f(cell.mean(), 3)),
            ] {
                c.row(vec![
                    metric.to_string(),
                    labels.clone(),
                    k.to_string(),
                    t0.clone(),
                    t1.clone(),
                    kind.to_string(),
                    value,
                ]);
            }
        }
    }
    for ((metric, labels), series) in tel.hist_series() {
        for (&k, h) in series {
            let (t0, t1) = span(k);
            for (kind, value) in [
                ("hist_n", h.count().to_string()),
                ("hist_p50", h.quantile(0.50).to_string()),
                ("hist_p99", h.quantile(0.99).to_string()),
            ] {
                c.row(vec![
                    metric.to_string(),
                    labels.clone(),
                    k.to_string(),
                    t0.clone(),
                    t1.clone(),
                    kind.to_string(),
                    value,
                ]);
            }
        }
    }
    c
}

/// Short markdown summary of a sealed telemetry stream (appended to
/// the serve/node report when `--telemetry` is on).
pub fn render_telemetry(tel: &Telemetry) -> String {
    let mut out = String::new();
    out.push_str("### TimeScope telemetry\n\n");
    out.push_str(&format!(
        "* window: {} cycles, {} windows over {} cycles\n",
        tel.window(),
        tel.last_window() + 1,
        tel.end(),
    ));
    out.push_str(&format!(
        "* series: {} ({} spans), stream digest 0x{:016x}\n",
        tel.series_count(),
        tel.spans().len(),
        tel.digest(),
    ));
    let parks = tel.counter_total("autoscale_park", "");
    let unparks = tel.counter_total("autoscale_unpark", "");
    if parks + unparks > 0 {
        out.push_str(&format!(
            "* autoscale: {parks} parks / {unparks} unparks\n",
        ));
    }
    out
}

// -------------------------------------------------- StallScope --

/// Markdown table of class totals (shares of all attributed cycles).
fn stall_table(totals: &[u64; N_CLASSES]) -> String {
    let all: u64 = totals.iter().sum();
    let mut out = String::new();
    out.push_str("| class | cycles | share |\n|---|---|---|\n");
    for c in StallClass::all() {
        let t = totals[c as usize];
        out.push_str(&format!(
            "| {} | {} | {:.2}% |\n",
            c.label(),
            t,
            ratio(t as f64, all as f64) * 100.0,
        ));
    }
    out
}

/// One cluster/fabric run's breakdown (the `run --profile` section).
pub fn render_stall_breakdown(p: &StallProfile) -> String {
    let mut out = String::new();
    out.push_str("### StallScope breakdown (compute cores)\n\n");
    out.push_str(&stall_table(&p.totals()));
    let conservation = match p.check_conservation() {
        Ok(()) => "OK".to_string(),
        Err(e) => format!("VIOLATED — {e}"),
    };
    out.push_str(&format!(
        "\n* StallScope utilization {:.2}% over a {}-cycle window; \
         conservation {} across {} cores\n",
        p.utilization() * 100.0,
        p.window_cycles,
        conservation,
        p.per_core.len(),
    ));
    // Per-core spread of the Useful share (reuses the Fig. 5 stats
    // machinery) — skew here means load imbalance, not overhead.
    let useful: Vec<f64> = p.per_core[..p.n_compute.min(p.per_core.len())]
        .iter()
        .map(|c| ratio(c.useful() as f64, c.total().max(1) as f64))
        .collect();
    if !useful.is_empty() {
        let s = box_stats(&useful);
        out.push_str(&format!(
            "* per-core Useful share: min {:.3} / median {:.3} / max \
             {:.3}\n",
            s.min, s.median, s.max,
        ));
    }
    out
}

fn roofline_table(points: &[RooflinePoint]) -> String {
    let mut out = String::new();
    out.push_str(
        "| layer | ops | bytes | OI [op/B] | attained [op/cyc] | \
         roof [op/cyc] | attainment | bound |\n\
         |---|---|---|---|---|---|---|---|\n",
    );
    for p in points {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {:.1}% | {} |\n",
            p.name,
            p.ops,
            p.bytes,
            f(p.oi, 3),
            f(p.attained_ops_per_cycle, 3),
            f(p.roof_ops_per_cycle, 3),
            p.attainment() * 100.0,
            p.bound.name(),
        ));
    }
    out
}

/// The `zerostall profile` report.
pub fn render_profile(
    r: &crate::coordinator::profile::ProfileReport,
) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "## StallScope profile — `{}` on {} x{} (cycle backend)\n\n",
        r.model,
        r.config.name(),
        r.clusters,
    ));
    out.push_str(
        "| layer | shape | epilogue | placement | cycles | util | \
         top stall |\n|---|---|---|---|---|---|---|\n",
    );
    for l in &r.layers {
        let totals = l.stalls.totals();
        let top = StallClass::all()
            .into_iter()
            .skip(1) // Useful is not a stall
            .max_by_key(|c| totals[*c as usize])
            .unwrap();
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {:.1}% | {} ({:.1}%) |\n",
            l.name,
            l.problem,
            l.epilogue,
            if l.shards > 1 {
                format!("sharded x{}", l.shards)
            } else {
                "1 cluster".to_string()
            },
            l.cycles,
            l.stalls.utilization() * 100.0,
            top.label(),
            ratio(
                totals[top as usize] as f64,
                l.stalls.cycles_total() as f64
            ) * 100.0,
        ));
    }
    out.push_str(&format!(
        "\n* end-to-end: {} cycles over {} GEMM layers ({} unfused \
         elementwise ops excluded)\n\n",
        r.total_cycles,
        r.layers.len(),
        r.skipped_adds,
    ));
    out.push_str("### Merged stall breakdown\n\n");
    out.push_str(&stall_table(&r.merged.totals()));
    out.push_str(&format!(
        "\n* conservation: {} ({} profiled cores x {} layers)\n\n",
        match r.merged.check_conservation() {
            Ok(()) => "OK".to_string(),
            Err(e) => format!("VIOLATED — {e}"),
        },
        r.merged.per_core.len(),
        r.layers.len(),
    ));
    out.push_str("### Roofline\n\n");
    out.push_str(&roofline_table(
        &r.layers.iter().map(|l| l.roofline.clone()).collect::<Vec<_>>(),
    ));
    out.push_str(&format!(
        "\n* fabric ceilings: compute {} op/cyc, L1 {} B/cyc{} — each \
         layer is placed against the roofs of the clusters it \
         actually occupied\n",
        f(r.ceilings.compute_ops_per_cycle, 1),
        f(r.ceilings.l1_bytes_per_cycle, 1),
        if r.ceilings.noc_bytes_per_cycle.is_finite() {
            format!(", NoC {} B/cyc", f(r.ceilings.noc_bytes_per_cycle, 1))
        } else {
            ", private NoC link".to_string()
        },
    ));
    out
}

/// Per-layer, per-core stall counters (schema pinned by the golden
/// test — extend only by appending columns).
pub fn stall_csv(
    r: &crate::coordinator::profile::ProfileReport,
) -> Csv {
    let mut header =
        vec!["layer".to_string(), "core".to_string(), "cycles".to_string()];
    for c in StallClass::all() {
        header.push(c.name().to_string());
    }
    let mut csv = Csv::new(header);
    for l in &r.layers {
        for (ci, core) in l.stalls.per_core.iter().enumerate() {
            let n = l.stalls.n_compute;
            let label = if ci < n {
                format!("c{ci}")
            } else {
                format!("dm{}", ci - n)
            };
            let mut row =
                vec![l.name.clone(), label, core.cycles.to_string()];
            for c in StallClass::all() {
                row.push(core.counts[c as usize].to_string());
            }
            csv.row(row);
        }
    }
    csv
}

/// Roofline points (schema pinned by the golden test).
pub fn roofline_csv(points: &[RooflinePoint]) -> Csv {
    let mut csv = Csv::new(vec![
        "layer",
        "ops",
        "bytes",
        "oi_ops_per_byte",
        "attained_ops_per_cycle",
        "roof_ops_per_cycle",
        "attainment",
        "bound",
    ]);
    for p in points {
        csv.row(vec![
            p.name.clone(),
            p.ops.to_string(),
            p.bytes.to_string(),
            f(p.oi, 5),
            f(p.attained_ops_per_cycle, 4),
            f(p.roof_ops_per_cycle, 4),
            f(p.attainment(), 4),
            p.bound.name().to_string(),
        ]);
    }
    csv
}

/// StallScope appendix for `net --profile true`.
pub fn render_net_profile(
    r: &crate::coordinator::net::NetReport,
) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "### StallScope ({} backend{})\n\n",
        r.backend.name(),
        if r.backend == crate::backend::BackendKind::Analytic {
            " — predicted breakdown"
        } else {
            ""
        },
    ));
    out.push_str(&stall_table(&r.stall_totals));
    out.push_str("\n### Roofline (per GEMM layer)\n\n");
    out.push_str(&roofline_table(&r.rooflines));
    out
}

/// StallScope appendix for `serve --profile true`: the aggregate
/// breakdown plus one roofline point per model of the mix.
pub fn render_serve_profile(
    r: &crate::coordinator::serve::ServeReport,
) -> String {
    use crate::profile::roofline;
    let mut out = String::new();
    out.push_str(&format!(
        "### StallScope ({} backend{})\n\n",
        r.backend.name(),
        if r.backend == crate::backend::BackendKind::Analytic {
            " — predicted breakdown"
        } else {
            ""
        },
    ));
    out.push_str(&stall_table(&r.stall_totals));
    // MixAccum is per-cluster normalized (every cluster's window is
    // summed), so every point places against one cluster's roofs —
    // never the fabric aggregate a batched dispatch can't reach.
    let ceilings = roofline::Ceilings::new(1, &r.noc);
    let points: Vec<RooflinePoint> = r
        .mix
        .iter()
        .filter(|m| m.gemm_ops > 0)
        .map(|m| {
            roofline::point(
                m.model.clone(),
                m.flops,
                m.dma_bytes,
                m.window_cycles,
                &ceilings,
            )
        })
        .collect();
    if !points.is_empty() {
        out.push_str("\n### Roofline (per request mix)\n\n");
        out.push_str(&roofline_table(&points));
    }
    out
}

// -------------------------------------------------- ProofScope lint --

/// One verdict as a table/CSV-friendly cell: `0` (impossible),
/// `<=N` (bounded), `?` (no claim).
fn verdict_cell(v: crate::verify::Verdict) -> String {
    use crate::verify::Verdict;
    match v {
        Verdict::Impossible => "0".to_string(),
        Verdict::Bounded(n) => format!("<={n}"),
        Verdict::Unknown => "?".to_string(),
    }
}

/// The `zerostall lint` report.
pub fn render_lint(r: &crate::coordinator::lint::LintReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "## ProofScope lint — `{}` on {} x{}\n\n",
        r.model,
        r.config.name(),
        r.clusters,
    ));
    out.push_str(
        "Static verdicts per stall class: `0` = proved impossible, \
         `<=N` = proved bounded by N core-cycles, `?` = no claim.\n\n",
    );
    out.push_str("| layer | shape | epilogue | placement |");
    for c in StallClass::all().into_iter().skip(1) {
        out.push_str(&format!(" {} |", c.label()));
    }
    out.push('\n');
    out.push_str("|---|---|---|---|");
    for _ in StallClass::all().into_iter().skip(1) {
        out.push_str("---|");
    }
    out.push('\n');
    for l in &r.layers {
        out.push_str(&format!(
            "| {} | {} | {} | {} |",
            l.name,
            l.problem,
            l.epilogue,
            if l.shards > 1 {
                format!("sharded x{}", l.shards)
            } else {
                "1 cluster".to_string()
            },
        ));
        for c in StallClass::all().into_iter().skip(1) {
            out.push_str(&format!(
                " {} |",
                verdict_cell(l.report.verdict(c))
            ));
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "\n* {} GEMM layers verified ({} unfused elementwise ops have \
         no kernel and are excluded)\n\n",
        r.layers.len(),
        r.skipped_adds,
    ));
    out.push_str("### Theorems\n\n");
    out.push_str("| layer | theorem | holds | detail |\n|---|---|---|---|\n");
    for l in &r.layers {
        for t in &l.report.theorems {
            out.push_str(&format!(
                "| {} | {} | {} | {} |\n",
                l.name,
                t.name,
                if t.holds { "yes" } else { "NO" },
                t.detail,
            ));
        }
    }
    if r.gated {
        out.push_str("\n### Differential gate (measured vs verdicts)\n\n");
        out.push_str(
            "| layer | source | ctrl_overhead | raw_hazard | \
             bank_conflict | drain | noc_gated | dma_conflicts |\n\
             |---|---|---|---|---|---|---|---|\n",
        );
        for l in &r.layers {
            for m in &l.measured {
                out.push_str(&format!(
                    "| {} | {} | {} | {} | {} | {} | {} | {} |\n",
                    l.name,
                    m.source,
                    m.classes[StallClass::ControlOverhead as usize],
                    m.classes[StallClass::RawHazard as usize],
                    m.classes[StallClass::BankConflict as usize],
                    m.classes[StallClass::Drain as usize],
                    m.classes[StallClass::NocGated as usize],
                    m.tcdm_conflicts_dma,
                ));
            }
        }
        let fails = r.failures();
        if fails.is_empty() {
            out.push_str(&format!(
                "\n* gate PASSED: {} layers x {} sources, 0 violations\n",
                r.layers.len(),
                r.layers.first().map_or(0, |l| l.measured.len()),
            ));
        } else {
            out.push_str(&format!(
                "\n* gate FAILED: {} violation(s)\n",
                fails.len()
            ));
            for f in &fails {
                out.push_str(&format!("  * {f}\n"));
            }
        }
    } else {
        out.push_str(
            "\n* static verdicts only (`--gate false`): no backend was \
             run against the claims\n",
        );
    }
    out
}

/// Per-layer, per-class verdicts and measurements (schema pinned by
/// the golden test — extend only by appending columns).
pub fn lint_csv(r: &crate::coordinator::lint::LintReport) -> Csv {
    let mut csv = Csv::new(vec![
        "model",
        "layer",
        "m",
        "n",
        "k",
        "config",
        "clusters",
        "shards",
        "class",
        "verdict",
        "bound",
        "measured_cycle_ff",
        "measured_cycle",
        "measured_analytic",
        "gate",
    ]);
    for l in &r.layers {
        let by = |src: &str| {
            l.measured.iter().find(|m| m.source == src)
        };
        let gate = if r.gated {
            if l.failures.is_empty() { "pass" } else { "fail" }
        } else {
            ""
        };
        for c in StallClass::all() {
            let v = l.report.verdict(c);
            let cell = |src: &str| {
                by(src).map_or(String::new(), |m| {
                    m.classes[c as usize].to_string()
                })
            };
            csv.row(vec![
                r.model.clone(),
                l.name.clone(),
                l.problem.m.to_string(),
                l.problem.n.to_string(),
                l.problem.k.to_string(),
                r.config.name().to_string(),
                r.clusters.to_string(),
                l.shards.to_string(),
                c.name().to_string(),
                v.name().to_string(),
                v.bound_str(),
                cell("cycle+ff"),
                cell("cycle"),
                cell("analytic"),
                gate.to_string(),
            ]);
        }
    }
    csv
}

/// Per-layer theorem facts (schema pinned by the golden test).
pub fn lint_theorems_csv(r: &crate::coordinator::lint::LintReport) -> Csv {
    let mut csv = Csv::new(vec![
        "model", "layer", "theorem", "holds", "detail",
    ]);
    for l in &r.layers {
        for t in &l.report.theorems {
            csv.row(vec![
                r.model.clone(),
                l.name.clone(),
                t.name.to_string(),
                (t.holds as u8).to_string(),
                t.detail.clone(),
            ]);
        }
    }
    csv
}

// ------------------------------------------------------------ sweep --

/// Summary of a (possibly full-grid) backend sweep: per-config
/// utilization distributions plus throughput of the engine itself.
pub fn render_sweep(
    rows: &[Fig5Row],
    backend: &str,
    elapsed_s: f64,
) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "## Sweep — {} points via the `{}` backend in {:.2} s \
         ({:.0} points/s)\n\n",
        rows.len(),
        backend,
        elapsed_s,
        rows.len() as f64 / elapsed_s.max(1e-9),
    ));
    // Per-config boxes, skipping configs absent from this sweep
    // (unlike fig5_summary, a sweep may cover a subset).
    let mut utils: Vec<(&str, BoxStats)> = Vec::new();
    for id in ConfigId::all() {
        let sel: Vec<f64> = rows
            .iter()
            .filter(|r| r.config == id)
            .map(|r| r.utilization)
            .collect();
        if !sel.is_empty() {
            utils.push((id.name(), crate::util::stats::box_stats(&sel)));
        }
    }
    if !utils.is_empty() {
        out.push_str(&render_boxes("FPU utilization", &utils, "frac"));
    }
    out
}

/// Write a string artifact under `results/`.
pub fn save(dir: &Path, name: &str, content: &str) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(name), content)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::box_stats;

    #[test]
    fn boxes_render_and_scale() {
        let s1 = box_stats(&[0.8, 0.85, 0.9, 0.95]);
        let s2 = box_stats(&[0.95, 0.96, 0.97, 0.99]);
        let out =
            render_boxes("util", &[("a", s1), ("b", s2)], "frac");
        assert!(out.contains("med"));
        assert!(out.lines().count() >= 3);
    }

    #[test]
    fn table1_renders_all_configs() {
        let t = render_table1(&crate::model::table1());
        for id in ConfigId::all() {
            assert!(t.contains(id.name()));
        }
        assert!(t.contains("Δ total"));
    }

    #[test]
    fn fig4_contains_pressure_bars() {
        let s = render_fig4();
        assert!(s.contains("zonl64fc"));
    }

    #[test]
    fn net_report_renders() {
        use crate::coordinator::net::run_net;
        use crate::coordinator::workload::zoo;
        use crate::kernels::{GemmService, LayoutKind};
        let svc = GemmService::analytic();
        let g = zoo::build("ffn").unwrap();
        let run = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            1,
            3,
        )
        .unwrap();
        let doc = render_net(&run.report);
        assert!(doc.contains("mlp_up"));
        assert!(doc.contains("bias+gelu"));
        assert!(doc.contains("end-to-end"));
        let csv = net_csv(&run.report);
        assert_eq!(csv.rows(), run.report.layers.len());
    }

    #[test]
    fn serve_report_renders_and_csv_matches_rows() {
        use crate::coordinator::serve::{serve, Policy, ServeConfig};
        use crate::kernels::GemmService;
        let svc = GemmService::analytic();
        let mut cfg = ServeConfig::new(vec!["ffn".to_string()]);
        cfg.requests = 4;
        cfg.clusters = 2;
        cfg.policy = Policy::Continuous;
        cfg.slo = Some(u64::MAX);
        let run = serve(&svc, &cfg).unwrap();
        let doc = render_serve(&run.report);
        assert!(doc.contains("## Serve `ffn`"));
        assert!(doc.contains("latency cycles: p50"));
        assert!(doc.contains("hit rate under churn"));
        assert!(doc.contains("cluster 1: busy"));
        let csv = serve_csv(&run);
        assert_eq!(csv.rows(), run.report.completed);
    }

    #[test]
    fn stall_breakdown_renders_shares_and_conservation() {
        use crate::profile::{CoreStalls, StallClass, N_CLASSES};
        let mut counts = [0u64; N_CLASSES];
        counts[StallClass::Useful as usize] = 90;
        counts[StallClass::Barrier as usize] = 10;
        let p = StallProfile {
            per_core: vec![CoreStalls { cycles: 100, counts }; 2],
            n_compute: 2,
            window_cycles: 100,
            window_core_cycles: 200,
        };
        let doc = render_stall_breakdown(&p);
        assert!(doc.contains("Useful"));
        assert!(doc.contains("90.00%"));
        assert!(doc.contains("conservation OK"));
        assert!(doc.contains("per-core Useful share"));
        assert!(!doc.contains("NaN"));
    }

    #[test]
    fn net_profile_section_renders() {
        use crate::coordinator::net::run_net;
        use crate::coordinator::workload::zoo;
        use crate::kernels::{GemmService, LayoutKind};
        let svc = GemmService::analytic();
        let g = zoo::build("ffn").unwrap();
        let run = run_net(
            &svc,
            &g,
            ConfigId::Zonl48Db,
            LayoutKind::Grouped,
            1,
            3,
        )
        .unwrap();
        let doc = render_net_profile(&run.report);
        assert!(doc.contains("StallScope"));
        assert!(doc.contains("predicted breakdown"));
        assert!(doc.contains("Roofline"));
        assert!(doc.contains("mlp_up"));
    }

    #[test]
    fn lint_report_renders_and_csvs_match() {
        use crate::coordinator::lint::{run_lint, LintOpts};
        let mut opts = LintOpts::new("ffn");
        opts.gate = false;
        let rep = run_lint(&opts).unwrap();
        let doc = render_lint(&rep);
        assert!(doc.contains("## ProofScope lint"));
        assert!(doc.contains("zonl_zero_loop_overhead"));
        assert!(doc.contains("static verdicts only"));
        let csv = lint_csv(&rep);
        assert_eq!(csv.rows(), rep.layers.len() * N_CLASSES);
        let th = lint_theorems_csv(&rep);
        assert_eq!(
            th.rows(),
            rep.layers
                .iter()
                .map(|l| l.report.theorems.len())
                .sum::<usize>()
        );
    }

    #[test]
    fn calibration_and_error_tables_render() {
        let cal = Calibration::default();
        let t = render_calibration(&cal);
        for id in ConfigId::all() {
            assert!(t.contains(id.name()));
        }
        let rows = vec![crate::coordinator::experiments::ErrorRow {
            config: ConfigId::Zonl48Db,
            points: 9,
            mean_util_err: 0.021,
            max_util_err: 0.043,
            mean_window_err: 0.018,
            max_window_err: 0.04,
        }];
        let e = render_error_table(&rows);
        assert!(e.contains("zonl48db"));
        assert!(e.contains("2.1%"));
        assert_eq!(error_csv(&rows).rows(), 1);
    }
}
