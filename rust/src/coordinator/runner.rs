//! Multi-threaded experiment runner (std::thread scoped workers; tokio
//! is unavailable offline and the workload is CPU-bound anyway).
//!
//! Work is distributed by index stealing over an atomic counter, so
//! results land at their job's index — fully deterministic output
//! order regardless of thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Apply `f` to each job on `threads` workers; preserves input order.
pub fn parallel_map<J, R, F>(
    jobs: &[J],
    threads: usize,
    f: F,
) -> anyhow::Result<Vec<R>>
where
    J: Sync,
    R: Send,
    F: Fn(&J) -> anyhow::Result<R> + Sync,
{
    let threads = threads.max(1).min(jobs.len().max(1));
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<anyhow::Result<R>>>> =
        jobs.iter().map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let r = f(&jobs[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job not run"))
        .collect()
}

/// A sensible default worker count.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<usize> = (0..100).collect();
        let out = parallel_map(&jobs, 8, |&x| Ok(x * 2)).unwrap();
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_works() {
        let jobs = vec![1, 2, 3];
        let out = parallel_map(&jobs, 1, |&x| Ok(x + 1)).unwrap();
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn propagates_errors() {
        let jobs = vec![1, 2, 3];
        let res: anyhow::Result<Vec<i32>> =
            parallel_map(&jobs, 2, |&x| {
                if x == 2 {
                    anyhow::bail!("boom")
                } else {
                    Ok(x)
                }
            });
        assert!(res.is_err());
    }

    #[test]
    fn empty_jobs_ok() {
        let jobs: Vec<u8> = vec![];
        let out = parallel_map(&jobs, 4, |&x| Ok(x)).unwrap();
        assert!(out.is_empty());
    }
}
