//! ServeSim — a deterministic request-level serving simulator on top
//! of `GemmService` + `ClusterFabric`.
//!
//! The paper's 96–99% per-cluster utilization only matters at the
//! system level if the fabric sustains it under realistic traffic, so
//! this module closes the loop the ROADMAP's "serve heavy traffic"
//! north star asks for:
//!
//! * an **open-loop arrival process** (seeded via [`crate::util::rng`])
//!   draws NetGraph inference requests from the workload zoo with
//!   exponential inter-arrival gaps (`rate_per_mcycle`) and an
//!   optional burstiness knob (`burst` = probability an arrival lands
//!   on the same cycle as its predecessor);
//! * a **scheduler with pluggable policies** drives the existing
//!   backends in virtual time:
//!   - [`Policy::Fifo`] — the baseline: strict one-request-at-a-time
//!     service in arrival order; each request's DAG executes wave by
//!     wave, multi-op waves spreading layer-parallel across clusters,
//!     but no request ever overlaps another;
//!   - [`Policy::Continuous`] — continuous batching: every wave pools
//!     the *ready* ops of **all** in-flight requests, merges the GEMMs
//!     into one [`GemmService::run_batch`] dispatch, and packs them
//!     onto the least-loaded clusters; a wave that is a single
//!     shardable GEMM with idle clusters goes tensor-parallel through
//!     [`GemmService::run_sharded_job`] instead.
//! * per-request latency accumulates from **backend cycle counts**
//!   (cycle-accurate or calibrated-analytic — the same `--backend`
//!   switch as everywhere else), and the report carries p50/p95/p99
//!   latency (streaming [`CycleHistogram`] accounting), sustained and
//!   SLO-attained throughput, plan-cache hit rate under model churn,
//!   and per-cluster utilization.
//!
//! Time advances wave-synchronously: a wave costs its busiest
//! cluster's assigned cycles, each assigned op finishes at its
//! cluster-local position inside the wave, and newly arrived requests
//! join at the next wave boundary. Everything — arrivals, costs,
//! placement, tie-breaks — is derived from the seed and the backend,
//! so a serve run is bit-for-bit reproducible across runs and thread
//! counts (a property test compares whole reports for equality).

use anyhow::{ensure, Result};

use crate::backend::BackendKind;
use crate::cluster::ConfigId;
use crate::fabric::{FabricConfig, NocConfig};
use crate::kernels::{
    choose_shard_grid, problem_seed, GemmJob, GemmService, LayoutKind,
    ServiceStats,
};
use crate::profile::N_CLASSES;
use crate::util::prop::Shrink;
use crate::util::rng::Rng;
use crate::util::stats::{ratio, CycleHistogram};

use super::net::add_pass_cycles;
use super::workload::graph::{NetGraph, NetOp};
use super::workload::zoo;

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// One request at a time, in arrival order (baseline).
    Fifo,
    /// Continuous batching across all in-flight requests.
    Continuous,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Continuous => "cb",
        }
    }

    pub fn from_name(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "cb" | "continuous" => Some(Policy::Continuous),
            _ => None,
        }
    }
}

/// Serving-run parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Zoo model names; each request samples one uniformly.
    pub models: Vec<String>,
    pub config: ConfigId,
    pub layout: LayoutKind,
    pub policy: Policy,
    pub clusters: usize,
    /// Number of requests the arrival process generates.
    pub requests: usize,
    /// Mean offered load, requests per million cycles.
    pub rate_per_mcycle: f64,
    /// Probability in `[0, 1)` that an arrival shares its
    /// predecessor's cycle (bursty traffic).
    pub burst: f64,
    pub seed: u64,
    /// Latency SLO in cycles; `None` derives `4 x` the isolated
    /// (unloaded FIFO) latency of the first model in the mix.
    pub slo: Option<u64>,
    /// Host threads for batched backend dispatches (never affects
    /// results — only wall-clock).
    pub threads: usize,
}

impl ServeConfig {
    /// Defaults: zonl48db / grouped layout, continuous batching on one
    /// cluster, 32 requests at 5 req/Mcycle, no bursts, auto SLO.
    pub fn new(models: Vec<String>) -> ServeConfig {
        ServeConfig {
            models,
            config: ConfigId::Zonl48Db,
            layout: LayoutKind::Grouped,
            policy: Policy::Continuous,
            clusters: 1,
            requests: 32,
            rate_per_mcycle: 5.0,
            burst: 0.0,
            seed: 0xC0FFEE,
            slo: None,
            threads: 2,
        }
    }
}

/// One inference request of the arrival trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeRequest {
    pub id: usize,
    /// Index into [`ServeConfig::models`].
    pub model: usize,
    /// Arrival cycle.
    pub arrival: u64,
    /// Per-request operand seed (functional backends).
    pub seed: u64,
}

impl Shrink for ServeRequest {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.arrival > 0 {
            out.push(ServeRequest { arrival: self.arrival / 2, ..*self });
            out.push(ServeRequest { arrival: 0, ..*self });
        }
        if self.model > 0 {
            out.push(ServeRequest { model: 0, ..*self });
        }
        out
    }
}

/// A full generated arrival trace. The engine sorts it by arrival
/// itself, so shrunk (re-timed) traces stay valid inputs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrivalTrace {
    pub requests: Vec<ServeRequest>,
}

impl Shrink for ArrivalTrace {
    fn shrinks(&self) -> Vec<Self> {
        self.requests
            .shrinks()
            .into_iter()
            .map(|requests| ArrivalTrace { requests })
            .collect()
    }
}

/// Generate the deterministic open-loop arrival trace for a config:
/// exponential gaps with mean `1e6 / rate_per_mcycle` cycles, each
/// arrival collapsing onto its predecessor's cycle with probability
/// `burst`, models sampled uniformly from the mix.
pub fn gen_arrivals(cfg: &ServeConfig) -> ArrivalTrace {
    let mut master = Rng::new(cfg.seed);
    let mut gap_rng = master.fork(1);
    let mut model_rng = master.fork(2);
    let mut seed_rng = master.fork(3);
    let mean_gap = 1.0e6 / cfg.rate_per_mcycle.max(1e-9);
    let n_models = cfg.models.len().max(1) as u64;
    let mut t = 0u64;
    let mut requests = Vec::with_capacity(cfg.requests);
    for id in 0..cfg.requests {
        if id > 0 && gap_rng.f64() >= cfg.burst {
            // -mean * ln(1-u) is >= 0 and finite (u in [0,1)); the
            // as-cast saturates on absurd rates instead of wrapping.
            let u = gap_rng.f64();
            let gap = (-mean_gap * (1.0 - u).ln()).round() as u64;
            t = t.saturating_add(gap.max(1));
        }
        requests.push(ServeRequest {
            id,
            model: model_rng.below(n_models) as usize,
            arrival: t,
            seed: seed_rng.next_u64(),
        });
    }
    ArrivalTrace { requests }
}

/// Per-request outcome row (CSV material).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeRow {
    pub id: usize,
    pub model: String,
    pub arrival: u64,
    pub completion: u64,
    pub latency: u64,
    pub slo_met: bool,
    pub ops: usize,
}

/// Aggregate serving report. Derives `PartialEq` so the determinism
/// property can compare entire runs bit for bit.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeReport {
    /// `+`-joined model mix.
    pub model: String,
    pub config: ConfigId,
    pub backend: BackendKind,
    pub policy: Policy,
    pub clusters: usize,
    pub rate_per_mcycle: f64,
    pub burst: f64,
    pub seed: u64,
    pub requests: usize,
    pub completed: usize,
    /// Last request-completion cycle (0 when nothing completed).
    pub makespan_cycles: u64,
    /// Streaming latency histogram (p50/p95/p99 source).
    pub latency: CycleHistogram,
    pub slo_cycles: u64,
    pub slo_attained: usize,
    /// Plan-cache counters *for this run* (delta over the service's
    /// totals). Covers every prepare the run triggered: when the SLO
    /// is derived (`ServeConfig::slo == None`), that includes the
    /// isolated-latency probe's dispatches, so `plan_hits +
    /// plan_misses` equals `gemm_ops` only for explicit-SLO runs.
    pub plan_stats: ServiceStats,
    pub per_cluster_busy: Vec<u64>,
    /// Scheduler waves executed.
    pub waves: u64,
    /// Waves dispatched tensor-parallel via `run_sharded_job`.
    pub sharded_waves: u64,
    /// GEMM ops dispatched (batched + sharded).
    pub gemm_ops: u64,
    /// All ops executed (GEMMs + elementwise adds).
    pub total_ops: u64,
    /// NoC provisioning of the fabric the run scheduled onto (the
    /// `--profile` roofline ceilings derive from this, never from a
    /// renderer-side assumption).
    pub noc: NocConfig,
    /// StallScope class totals summed over every dispatched GEMM's
    /// compute cores (measured or predicted, per the backend).
    pub stall_totals: [u64; N_CLASSES],
    /// Per-model roofline accumulators over the request mix (the
    /// `--profile` report derives per-mix roofline points from these).
    pub mix: Vec<MixAccum>,
}

/// Roofline raw material for one model of the serve mix: totals over
/// every GEMM dispatched on behalf of that model's requests.
///
/// All quantities are *per-cluster normalized*: `window_cycles` sums
/// the compute window of every cluster that worked on the model
/// (each shard of a tensor-parallel dispatch contributes its own
/// window), so `flops / window_cycles` is bounded by one cluster's
/// 8 op/cycle peak regardless of fabric size — batched and sharded
/// dispatches land in the same normalization.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MixAccum {
    pub model: String,
    pub gemm_ops: u64,
    /// FPU ops (MACs + fused-epilogue ops).
    pub flops: u64,
    pub dma_bytes: u64,
    /// Summed per-cluster compute windows the ops were issued over.
    pub window_cycles: u64,
}

impl ServeReport {
    pub fn p50(&self) -> u64 {
        self.latency.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.latency.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.latency.quantile(0.99)
    }

    /// Completed requests per million cycles of makespan (0 on
    /// zero-cycle runs — ratios are NaN-guarded).
    pub fn throughput_per_mcycle(&self) -> f64 {
        ratio(self.completed as f64, self.makespan_cycles as f64)
            * 1.0e6
    }

    /// Fraction of completed requests that met the SLO.
    pub fn slo_attainment(&self) -> f64 {
        ratio(self.slo_attained as f64, self.completed as f64)
    }

    /// SLO-attained requests per million cycles — the serving metric
    /// the policy comparison is judged on.
    pub fn slo_attained_throughput(&self) -> f64 {
        ratio(self.slo_attained as f64, self.makespan_cycles as f64)
            * 1.0e6
    }

    /// Per-cluster busy fraction of the makespan.
    pub fn cluster_utilization(&self) -> Vec<f64> {
        self.per_cluster_busy
            .iter()
            .map(|&b| ratio(b as f64, self.makespan_cycles as f64))
            .collect()
    }
}

/// A completed serving run: the report plus per-request rows (sorted
/// by request id).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRun {
    pub report: ServeReport,
    pub rows: Vec<ServeRow>,
}

/// One zoo model's immutable scheduling skeleton, shared by every
/// request of that model.
struct ModelPlan {
    name: String,
    graph: NetGraph,
    deps0: Vec<usize>,
    dependents: Vec<Vec<usize>>,
}

fn model_plans(models: &[String]) -> Result<Vec<ModelPlan>> {
    models
        .iter()
        .map(|name| {
            let graph = zoo::build(name)?;
            let (_, deps0, dependents) = graph.dependency_structure()?;
            Ok(ModelPlan { name: name.clone(), graph, deps0, dependents })
        })
        .collect()
}

/// Mutable per-request execution state.
struct ReqState {
    model: usize,
    arrival: u64,
    seed: u64,
    deps: Vec<usize>,
    done: Vec<bool>,
    remaining: usize,
    last_finish: u64,
}

fn gemm_job_of(
    cfg: &ServeConfig,
    g: &NetGraph,
    oi: usize,
    req_seed: u64,
) -> GemmJob {
    let NetOp::Gemm { x, w, epi, .. } = &g.ops[oi] else {
        unreachable!("gemm_job_of called on a non-GEMM op");
    };
    let (m, n, k) =
        (g.tensors[*x].rows, g.tensors[*w].cols, g.tensors[*x].cols);
    GemmJob {
        seed: req_seed ^ problem_seed(m, n, k),
        ..GemmJob::fused(cfg.config, m, n, k, cfg.layout, *epi)
    }
}

/// Latency of one request of `model` served alone on an idle system
/// under FIFO — the natural SLO / rate reference point for a config.
pub fn isolated_latency(
    svc: &GemmService,
    cfg: &ServeConfig,
    model: usize,
) -> Result<u64> {
    let mut solo = cfg.clone();
    solo.policy = Policy::Fifo;
    solo.requests = 1;
    solo.slo = Some(u64::MAX);
    let trace = ArrivalTrace {
        requests: vec![ServeRequest {
            id: 0,
            model,
            arrival: 0,
            seed: cfg.seed ^ 0x1501A7ED,
        }],
    };
    let run = serve_trace(svc, &solo, &trace)?;
    Ok(run.report.latency.max())
}

/// Generate the arrival trace for `cfg` and serve it.
pub fn serve(svc: &GemmService, cfg: &ServeConfig) -> Result<ServeRun> {
    let trace = gen_arrivals(cfg);
    serve_trace(svc, cfg, &trace)
}

/// Serve an explicit arrival trace (the property tests feed shrunk
/// traces through this entry point). Requests may arrive unsorted;
/// the engine orders them by `(arrival, id)` itself.
pub fn serve_trace(
    svc: &GemmService,
    cfg: &ServeConfig,
    trace: &ArrivalTrace,
) -> Result<ServeRun> {
    ensure!(!cfg.models.is_empty(), "serve needs at least one model");
    let plans = model_plans(&cfg.models)?;
    for r in &trace.requests {
        ensure!(
            r.model < plans.len(),
            "request {} names model index {} (mix has {})",
            r.id,
            r.model,
            plans.len()
        );
    }
    let n_clusters = cfg.clusters.max(1);
    let fabric = FabricConfig::new(n_clusters);
    // Snapshot plan-cache counters before everything — including the
    // SLO probe below — so the reported hit rate covers the whole
    // run's cache behavior, cold start included.
    let stats0 = svc.stats();
    let slo = match cfg.slo {
        Some(s) => s,
        None => {
            // 4x the isolated latency of the mix's first model — a
            // fixed reference, independent of which model the first
            // arrival happens to sample.
            isolated_latency(svc, cfg, 0)?.saturating_mul(4)
        }
    };

    // Arrival order (stable on same-cycle bursts by id).
    let mut arrivals: Vec<ServeRequest> = trace.requests.clone();
    arrivals.sort_by_key(|r| (r.arrival, r.id));

    let mut reqs: Vec<ReqState> = arrivals
        .iter()
        .map(|r| {
            let p = &plans[r.model];
            ReqState {
                model: r.model,
                arrival: r.arrival,
                seed: r.seed,
                deps: p.deps0.clone(),
                done: vec![false; p.graph.ops.len()],
                remaining: p.graph.ops.len(),
                last_finish: 0,
            }
        })
        .collect();

    let mut clock = 0u64;
    let mut next_arr = 0usize;
    // Admitted, incomplete requests in arrival order.
    let mut active: Vec<usize> = Vec::new();
    let mut busy = vec![0u64; n_clusters];
    let mut hist = CycleHistogram::new();
    let mut rows: Vec<ServeRow> = Vec::new();
    let mut slo_attained = 0usize;
    let mut makespan = 0u64;
    let mut waves = 0u64;
    let mut sharded_waves = 0u64;
    let mut gemm_ops = 0u64;
    let mut total_ops = 0u64;
    let mut stall_totals = [0u64; N_CLASSES];
    let mut mix: Vec<MixAccum> = plans
        .iter()
        .map(|p| MixAccum {
            model: p.name.clone(),
            gemm_ops: 0,
            flops: 0,
            dma_bytes: 0,
            window_cycles: 0,
        })
        .collect();

    while next_arr < reqs.len() || !active.is_empty() {
        while next_arr < reqs.len()
            && arrivals[next_arr].arrival <= clock
        {
            active.push(next_arr);
            next_arr += 1;
        }
        if active.is_empty() {
            // Idle: jump straight to the next arrival.
            clock = arrivals[next_arr].arrival;
            continue;
        }

        // Ready ops of the policy's scheduling pool.
        let ready: Vec<(usize, usize)> = {
            let pool: &[usize] = match cfg.policy {
                Policy::Fifo => &active[..1],
                Policy::Continuous => &active[..],
            };
            let mut v = Vec::new();
            for &ri in pool {
                let g = &plans[reqs[ri].model].graph;
                for oi in 0..g.ops.len() {
                    if !reqs[ri].done[oi] && reqs[ri].deps[oi] == 0 {
                        v.push((ri, oi));
                    }
                }
            }
            v
        };
        ensure!(
            !ready.is_empty(),
            "serve deadlocked: {} active requests with no ready op",
            active.len()
        );
        waves += 1;
        let mut finishes: Vec<u64> = vec![0; ready.len()];

        // A lone ready GEMM with idle clusters goes tensor-parallel
        // (continuous batching only — FIFO is the plain baseline).
        let single_shardable = cfg.policy == Policy::Continuous
            && n_clusters > 1
            && ready.len() == 1
            && {
                let (ri, oi) = ready[0];
                let g = &plans[reqs[ri].model].graph;
                match &g.ops[oi] {
                    NetOp::Gemm { x, w, .. } => choose_shard_grid(
                        g.tensors[*x].rows,
                        g.tensors[*w].cols,
                        n_clusters,
                    )
                    .used_clusters()
                        > 1,
                    NetOp::Add { .. } => false,
                }
            };

        if single_shardable {
            let (ri, oi) = ready[0];
            let job = gemm_job_of(
                cfg,
                &plans[reqs[ri].model].graph,
                oi,
                reqs[ri].seed,
            );
            let fr = svc.run_sharded_job(&job, &fabric)?;
            sharded_waves += 1;
            gemm_ops += 1;
            for (ci, s) in fr.shards.iter().enumerate() {
                busy[ci % n_clusters] += s.cycles;
            }
            for (t, v) in stall_totals
                .iter_mut()
                .zip(fr.stall_profile().totals())
            {
                *t += v;
            }
            let acc = &mut mix[reqs[ri].model];
            acc.gemm_ops += 1;
            acc.flops += fr.fpu_ops_total();
            acc.dma_bytes +=
                fr.shards.iter().map(|s| s.perf.dma_bytes).sum::<u64>();
            // Per-cluster normalization: every shard's window counts.
            acc.window_cycles += fr
                .shards
                .iter()
                .map(|s| s.perf.window_cycles)
                .sum::<u64>();
            finishes[0] = clock + fr.cycles;
            clock += fr.cycles;
        } else {
            // Merge the wave's GEMMs into one batched dispatch.
            let mut jobs: Vec<GemmJob> = Vec::new();
            let mut job_of: Vec<Option<usize>> =
                vec![None; ready.len()];
            for (ix, &(ri, oi)) in ready.iter().enumerate() {
                if matches!(
                    plans[reqs[ri].model].graph.ops[oi],
                    NetOp::Gemm { .. }
                ) {
                    job_of[ix] = Some(jobs.len());
                    jobs.push(gemm_job_of(
                        cfg,
                        &plans[reqs[ri].model].graph,
                        oi,
                        reqs[ri].seed,
                    ));
                }
            }
            gemm_ops += jobs.len() as u64;
            let results = svc.run_batch(&jobs, cfg.threads)?;
            for (ix, &(ri, _)) in ready.iter().enumerate() {
                let Some(ji) = job_of[ix] else { continue };
                let perf = &results[ji].perf;
                for (t, v) in
                    stall_totals.iter_mut().zip(perf.stalls.totals())
                {
                    *t += v;
                }
                let acc = &mut mix[reqs[ri].model];
                acc.gemm_ops += 1;
                acc.flops += perf.fpu_ops_total;
                acc.dma_bytes += perf.dma_bytes;
                acc.window_cycles += perf.window_cycles;
            }
            let costs: Vec<u64> = ready
                .iter()
                .enumerate()
                .map(|(ix, &(ri, oi))| {
                    match &plans[reqs[ri].model].graph.ops[oi] {
                        NetOp::Gemm { .. } => {
                            results[job_of[ix].unwrap()].cycles
                        }
                        NetOp::Add { out, .. } => add_pass_cycles(
                            plans[reqs[ri].model].graph.tensors[*out]
                                .elems(),
                        ),
                    }
                })
                .collect();
            // Longest-processing-time-first onto the least-loaded
            // cluster; every tie-break is deterministic.
            let mut by_cost: Vec<usize> = (0..ready.len()).collect();
            by_cost.sort_by(|&a, &b| {
                costs[b].cmp(&costs[a]).then(ready[a].cmp(&ready[b]))
            });
            let mut load = vec![0u64; n_clusters];
            for &ix in &by_cost {
                let c = (0..n_clusters)
                    .min_by_key(|&c| (load[c], c))
                    .unwrap();
                finishes[ix] = clock + load[c] + costs[ix];
                load[c] += costs[ix];
            }
            let elapsed = load.iter().copied().max().unwrap_or(0);
            for (ci, &l) in load.iter().enumerate() {
                busy[ci] += l;
            }
            clock += elapsed;
        }

        // Commit the wave: mark ops done, release dependents.
        for (&(ri, oi), &fin) in ready.iter().zip(&finishes) {
            total_ops += 1;
            let model = reqs[ri].model;
            reqs[ri].done[oi] = true;
            reqs[ri].remaining -= 1;
            reqs[ri].last_finish = reqs[ri].last_finish.max(fin);
            for &d in &plans[model].dependents[oi] {
                reqs[ri].deps[d] -= 1;
            }
        }

        // Retire completed requests.
        active.retain(|&ri| {
            if reqs[ri].remaining > 0 {
                return true;
            }
            let latency =
                reqs[ri].last_finish.saturating_sub(reqs[ri].arrival);
            hist.record(latency);
            if latency <= slo {
                slo_attained += 1;
            }
            makespan = makespan.max(reqs[ri].last_finish);
            rows.push(ServeRow {
                id: arrivals[ri].id,
                model: plans[reqs[ri].model].name.clone(),
                arrival: reqs[ri].arrival,
                completion: reqs[ri].last_finish,
                latency,
                slo_met: latency <= slo,
                ops: plans[reqs[ri].model].graph.ops.len(),
            });
            false
        });
    }

    rows.sort_by_key(|r| r.id);
    let stats1 = svc.stats();
    let completed = rows.len();
    let report = ServeReport {
        model: cfg.models.join("+"),
        config: cfg.config,
        backend: svc.backend_kind(),
        policy: cfg.policy,
        clusters: n_clusters,
        rate_per_mcycle: cfg.rate_per_mcycle,
        burst: cfg.burst,
        seed: cfg.seed,
        requests: trace.requests.len(),
        completed,
        makespan_cycles: makespan,
        latency: hist,
        slo_cycles: slo,
        slo_attained,
        plan_stats: ServiceStats {
            plan_hits: stats1.plan_hits - stats0.plan_hits,
            plan_misses: stats1.plan_misses - stats0.plan_misses,
        },
        per_cluster_busy: busy,
        waves,
        sharded_waves,
        gemm_ops,
        total_ops,
        noc: fabric.noc,
        stall_totals,
        mix,
    };
    Ok(ServeRun { report, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn analytic() -> GemmService {
        GemmService::analytic()
    }

    fn cfg_of(model: &str) -> ServeConfig {
        let mut c = ServeConfig::new(vec![model.to_string()]);
        c.slo = Some(u64::MAX);
        c.seed = 0x5EED;
        c
    }

    #[test]
    fn fifo_single_request_latency_is_the_chain_sum() {
        // One ffn request on one cluster: strict serialization, so
        // the latency is exactly the sum of the per-op backend costs.
        let svc = analytic();
        let mut cfg = cfg_of("ffn");
        cfg.policy = Policy::Fifo;
        cfg.requests = 1;
        let run = serve(&svc, &cfg).unwrap();
        let g = zoo::build("ffn").unwrap();
        let probe = analytic();
        let mut expect = 0u64;
        for (oi, op) in g.ops.iter().enumerate() {
            match op {
                NetOp::Gemm { .. } => {
                    let job = gemm_job_of(&cfg, &g, oi, 0);
                    expect += probe.run_job(&job).unwrap().cycles;
                }
                NetOp::Add { out, .. } => {
                    expect += add_pass_cycles(g.tensors[*out].elems());
                }
            }
        }
        assert_eq!(run.report.completed, 1);
        assert_eq!(run.report.makespan_cycles, expect);
        assert_eq!(run.report.latency.max(), expect);
        assert_eq!(run.report.p50(), run.report.p99());
        assert_eq!(run.report.total_ops, g.ops.len() as u64);
        assert_eq!(run.rows.len(), 1);
        assert_eq!(run.rows[0].latency, expect);
    }

    #[test]
    fn fifo_serializes_but_cb_overlaps_bursts() {
        // Two requests arriving together: FIFO serves them back to
        // back; continuous batching on 2 clusters overlaps them.
        let mut cfg = cfg_of("ffn");
        cfg.requests = 2;
        cfg.burst = 1.0; // both arrive on cycle 0
        cfg.clusters = 2;
        cfg.policy = Policy::Fifo;
        let fifo = serve(&analytic(), &cfg).unwrap();
        cfg.policy = Policy::Continuous;
        let cb = serve(&analytic(), &cfg).unwrap();
        assert_eq!(fifo.report.completed, 2);
        assert_eq!(cb.report.completed, 2);
        assert!(
            cb.report.makespan_cycles < fifo.report.makespan_cycles,
            "cb {} vs fifo {}",
            cb.report.makespan_cycles,
            fifo.report.makespan_cycles
        );
    }

    #[test]
    fn cb_shards_lone_gemm_waves() {
        // A solo ffn request under continuous batching on 4 clusters:
        // both GEMM waves are alone and shardable, the residual add
        // is not.
        let mut cfg = cfg_of("ffn");
        cfg.requests = 1;
        cfg.clusters = 4;
        cfg.policy = Policy::Continuous;
        let run = serve(&analytic(), &cfg).unwrap();
        assert_eq!(run.report.sharded_waves, 2);
        assert_eq!(run.report.gemm_ops, 2);
        assert_eq!(run.report.total_ops, 3);
        // FIFO never shards.
        cfg.policy = Policy::Fifo;
        let fifo = serve(&analytic(), &cfg).unwrap();
        assert_eq!(fifo.report.sharded_waves, 0);
        assert!(
            run.report.makespan_cycles < fifo.report.makespan_cycles,
            "tensor-parallel solo service must be faster"
        );
    }

    #[test]
    fn serve_accumulates_stallscope_and_mix_rooflines() {
        let svc = analytic();
        let mut cfg = cfg_of("ffn");
        cfg.requests = 3;
        let run = serve(&svc, &cfg).unwrap();
        let r = &run.report;
        assert_eq!(r.mix.len(), 1);
        assert_eq!(r.mix[0].model, "ffn");
        // One cluster, one model: every dispatched GEMM is ffn's.
        assert_eq!(r.mix[0].gemm_ops, r.gemm_ops);
        assert!(r.mix[0].flops > 0);
        assert!(r.mix[0].dma_bytes > 0);
        assert!(r.mix[0].window_cycles > 0);
        assert!(r.stall_totals.iter().sum::<u64>() > 0);
        // Sharded dispatches accumulate too.
        let mut cfg4 = cfg_of("ffn");
        cfg4.requests = 1;
        cfg4.clusters = 4;
        let run4 = serve(&analytic(), &cfg4).unwrap();
        assert!(run4.report.sharded_waves > 0);
        assert!(run4.report.mix[0].flops > 0);
    }

    #[test]
    fn arrivals_are_deterministic_and_bursty() {
        let mut cfg = cfg_of("ffn");
        cfg.requests = 16;
        let a = gen_arrivals(&cfg);
        let b = gen_arrivals(&cfg);
        assert_eq!(a, b);
        assert!(a
            .requests
            .windows(2)
            .all(|w| w[0].arrival <= w[1].arrival));
        cfg.seed ^= 1;
        assert_ne!(gen_arrivals(&cfg), a, "seed changes the trace");
        cfg.burst = 1.0;
        let burst = gen_arrivals(&cfg);
        assert!(
            burst.requests.iter().all(|r| r.arrival == 0),
            "burst=1 collapses every arrival onto cycle 0"
        );
    }

    #[test]
    fn plan_stats_are_run_local_deltas() {
        let svc = analytic();
        let mut cfg = cfg_of("ffn");
        cfg.requests = 4;
        let first = serve(&svc, &cfg).unwrap();
        assert!(first.report.plan_stats.plan_misses > 0);
        // A second run on the same warm service sees only hits.
        let second = serve(&svc, &cfg).unwrap();
        assert_eq!(second.report.plan_stats.plan_misses, 0);
        assert!(second.report.plan_stats.plan_hits > 0);
        assert!((second.report.plan_stats.hit_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_invalid_inputs() {
        let svc = analytic();
        let mut cfg = cfg_of("ffn");
        cfg.requests = 0;
        let run = serve(&svc, &cfg).unwrap();
        assert_eq!(run.report.completed, 0);
        assert_eq!(run.report.makespan_cycles, 0);
        assert_eq!(run.report.throughput_per_mcycle(), 0.0);

        let bad = ServeConfig::new(vec!["resnet9000".to_string()]);
        assert!(serve(&svc, &bad).is_err());
        let none = ServeConfig::new(Vec::new());
        assert!(serve(&svc, &none).is_err());

        // Trace referencing a model outside the mix is rejected.
        let trace = ArrivalTrace {
            requests: vec![ServeRequest {
                id: 0,
                model: 7,
                arrival: 0,
                seed: 1,
            }],
        };
        assert!(serve_trace(&svc, &cfg, &trace).is_err());
    }

    #[test]
    fn shrinking_produces_valid_smaller_traces() {
        let mut cfg = cfg_of("ffn");
        cfg.requests = 6;
        let trace = gen_arrivals(&cfg);
        let shrinks = trace.shrinks();
        assert!(!shrinks.is_empty());
        let svc = analytic();
        for s in shrinks.iter().take(6) {
            assert!(s.requests.len() <= trace.requests.len());
            // Every shrunk trace must still serve cleanly.
            let run = serve_trace(&svc, &cfg, s).unwrap();
            assert_eq!(run.report.completed, s.requests.len());
        }
        // Request-level shrinking lowers arrivals toward 0.
        let r = ServeRequest { id: 0, model: 1, arrival: 100, seed: 9 };
        assert!(r
            .shrinks()
            .iter()
            .all(|s| s.arrival <= r.arrival && s.model <= r.model));
    }

    #[test]
    fn isolated_latency_matches_solo_fifo_run() {
        let svc = analytic();
        let mut cfg = cfg_of("qkv");
        cfg.policy = Policy::Fifo;
        cfg.requests = 1;
        let iso = isolated_latency(&svc, &cfg, 0).unwrap();
        let run = serve(&svc, &cfg).unwrap();
        assert_eq!(iso, run.report.latency.max());
        assert!(iso > 0);
    }
}
