//! ServeSim — a deterministic request-level serving simulator on top
//! of `GemmService` + `ClusterFabric`.
//!
//! The paper's 96–99% per-cluster utilization only matters at the
//! system level if the fabric sustains it under realistic traffic, so
//! this module closes the loop the ROADMAP's "serve heavy traffic"
//! north star asks for:
//!
//! * an **open-loop arrival process** (seeded via [`crate::util::rng`])
//!   draws NetGraph inference requests from the workload zoo with
//!   exponential inter-arrival gaps (`rate_per_mcycle`) and an
//!   optional burstiness knob (`burst` = probability an arrival lands
//!   on the same cycle as its predecessor);
//! * a **scheduler with pluggable policies** drives the existing
//!   backends in virtual time:
//!   - [`Policy::Fifo`] — the baseline: strict one-request-at-a-time
//!     service in arrival order; each request's DAG executes wave by
//!     wave, multi-op waves spreading layer-parallel across clusters,
//!     but no request ever overlaps another;
//!   - [`Policy::Continuous`] — continuous batching: every wave pools
//!     the *ready* ops of **all** in-flight requests, merges the GEMMs
//!     into one [`GemmService::run_batch`] dispatch, and packs them
//!     onto the least-loaded clusters; a wave that is a single
//!     shardable GEMM with idle clusters goes tensor-parallel through
//!     [`GemmService::run_sharded_job`] instead.
//! * per-request latency accumulates from **backend cycle counts**
//!   (cycle-accurate or calibrated-analytic — the same `--backend`
//!   switch as everywhere else), and the report carries p50/p95/p99
//!   latency (streaming [`CycleHistogram`] accounting), sustained and
//!   SLO-attained throughput, plan-cache hit rate under model churn,
//!   and per-cluster utilization.
//!
//! Two engines implement the identical scheduling semantics (see
//! DESIGN.md §12 for the equivalence argument):
//!
//! * [`ServeEngine::Event`] (default) — MegaServe: a binary-heap
//!   event queue over request arrivals and wave completions, flat
//!   per-request state arenas (no per-wave allocation), and a per-run
//!   **shape-memo table** that serves every repeated
//!   `(shape, epilogue, placement)` dispatch from a hash lookup
//!   instead of a backend call — timing and perf counters are
//!   data-oblivious (DESIGN.md §11), so the memoization is bit-exact.
//!   Unseen shapes of a wave are deduplicated and evaluated in
//!   parallel on the host pool; latency accumulates into per-model
//!   [`CycleHistogram`] shards merged at the end. A 10^6-request
//!   analytic trace drains in seconds.
//! * [`ServeEngine::Legacy`] — the original wave-synchronous loop
//!   that re-scans all in-flight requests each wave and dispatches
//!   every op instance to the backend. It is kept as the differential
//!   baseline: a shrinkable property pins both engines bit-identical
//!   on random traces, gating its eventual removal.
//!
//! Time advances wave-synchronously in both: a wave costs its busiest
//! cluster's assigned cycles, each assigned op finishes at its
//! cluster-local position inside the wave, and newly arrived requests
//! join at the next wave boundary. Everything — arrivals, costs,
//! placement, tie-breaks — is derived from the seed and the backend,
//! so a serve run is bit-for-bit reproducible across runs and thread
//! counts (a property test compares whole reports for equality).

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, HashMap};
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::backend::BackendKind;
use crate::cluster::ConfigId;
use crate::fabric::{FabricConfig, NocConfig};
use crate::kernels::{
    choose_shard_grid, problem_seed, Epilogue, GemmJob, GemmService,
    LayoutKind, ServiceStats,
};
use crate::profile::telemetry::{SpanKind, Telemetry};
use crate::profile::N_CLASSES;
use crate::util::prop::Shrink;
use crate::util::rng::Rng;
use crate::util::stats::{ratio, CycleHistogram};

use super::net::add_pass_cycles;
use super::workload::graph::{NetGraph, NetOp};
use super::workload::zoo;

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// One request at a time, in arrival order (baseline).
    Fifo,
    /// Continuous batching across all in-flight requests.
    Continuous,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Continuous => "cb",
        }
    }

    pub fn from_name(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "cb" | "continuous" => Some(Policy::Continuous),
            _ => None,
        }
    }
}

/// Which serve core executes the trace. Both produce bit-identical
/// `ServeRun`s (a shrinkable differential property enforces it); the
/// event core is the shipping default, the wave-synchronous one the
/// baseline it is diffed against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeEngine {
    /// Event-queue core with shape-memoized dispatch (MegaServe).
    Event,
    /// Original wave-synchronous re-scan loop.
    Legacy,
}

impl ServeEngine {
    pub fn name(&self) -> &'static str {
        match self {
            ServeEngine::Event => "event",
            ServeEngine::Legacy => "legacy",
        }
    }

    pub fn from_name(s: &str) -> Option<ServeEngine> {
        match s {
            "event" => Some(ServeEngine::Event),
            "legacy" => Some(ServeEngine::Legacy),
            _ => None,
        }
    }
}

/// Serving-run parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Zoo model names; each request samples one uniformly.
    pub models: Vec<String>,
    pub config: ConfigId,
    pub layout: LayoutKind,
    pub policy: Policy,
    pub clusters: usize,
    /// Number of requests the arrival process generates.
    pub requests: usize,
    /// Mean offered load, requests per million cycles.
    pub rate_per_mcycle: f64,
    /// Probability in `[0, 1)` that an arrival shares its
    /// predecessor's cycle (bursty traffic).
    pub burst: f64,
    pub seed: u64,
    /// Latency SLO in cycles; `None` derives `4 x` the isolated
    /// (unloaded FIFO) latency of the first model in the mix.
    pub slo: Option<u64>,
    /// Host threads for batched backend dispatches (never affects
    /// results — only wall-clock).
    pub threads: usize,
    /// Serve core (event-driven by default; `Legacy` keeps the
    /// wave-synchronous loop for the differential property).
    pub engine: ServeEngine,
    /// Virtual-time telemetry window in cycles; `None` (default)
    /// disables the telemetry bus entirely. Event core only — the
    /// legacy engine ignores it (its run carries no telemetry), so
    /// the differential property keeps comparing runs with it off.
    pub telemetry: Option<u64>,
}

impl ServeConfig {
    /// Defaults: zonl48db / grouped layout, continuous batching on one
    /// cluster, 32 requests at 5 req/Mcycle, no bursts, auto SLO,
    /// event-driven core.
    pub fn new(models: Vec<String>) -> ServeConfig {
        ServeConfig {
            models,
            config: ConfigId::Zonl48Db,
            layout: LayoutKind::Grouped,
            policy: Policy::Continuous,
            clusters: 1,
            requests: 32,
            rate_per_mcycle: 5.0,
            burst: 0.0,
            seed: 0xC0FFEE,
            slo: None,
            threads: 2,
            engine: ServeEngine::Event,
            telemetry: None,
        }
    }
}

/// One inference request of the arrival trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeRequest {
    pub id: usize,
    /// Index into [`ServeConfig::models`].
    pub model: usize,
    /// Arrival cycle.
    pub arrival: u64,
    /// Per-request operand seed (functional backends).
    pub seed: u64,
}

impl Shrink for ServeRequest {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.arrival > 0 {
            out.push(ServeRequest { arrival: self.arrival / 2, ..*self });
            out.push(ServeRequest { arrival: 0, ..*self });
        }
        if self.model > 0 {
            out.push(ServeRequest { model: 0, ..*self });
        }
        out
    }
}

/// A full generated arrival trace. The engine sorts it by arrival
/// itself, so shrunk (re-timed) traces stay valid inputs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrivalTrace {
    pub requests: Vec<ServeRequest>,
}

impl Shrink for ArrivalTrace {
    fn shrinks(&self) -> Vec<Self> {
        self.requests
            .shrinks()
            .into_iter()
            .map(|requests| ArrivalTrace { requests })
            .collect()
    }
}

/// Generate the deterministic open-loop arrival trace for a config:
/// exponential gaps with mean `1e6 / rate_per_mcycle` cycles, each
/// arrival collapsing onto its predecessor's cycle with probability
/// `burst`, models sampled uniformly from the mix.
pub fn gen_arrivals(cfg: &ServeConfig) -> ArrivalTrace {
    let mut master = Rng::new(cfg.seed);
    let mut gap_rng = master.fork(1);
    let mut model_rng = master.fork(2);
    let mut seed_rng = master.fork(3);
    let mean_gap = 1.0e6 / cfg.rate_per_mcycle.max(1e-9);
    let n_models = cfg.models.len().max(1) as u64;
    let mut t = 0u64;
    let mut requests = Vec::with_capacity(cfg.requests);
    for id in 0..cfg.requests {
        if id > 0 && gap_rng.f64() >= cfg.burst {
            // -mean * ln(1-u) is >= 0 and finite (u in [0,1)); the
            // as-cast saturates on absurd rates instead of wrapping.
            let u = gap_rng.f64();
            let gap = (-mean_gap * (1.0 - u).ln()).round() as u64;
            t = t.saturating_add(gap.max(1));
        }
        requests.push(ServeRequest {
            id,
            model: model_rng.below(n_models) as usize,
            arrival: t,
            seed: seed_rng.next_u64(),
        });
    }
    ArrivalTrace { requests }
}

/// Per-request outcome row (CSV material).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeRow {
    pub id: usize,
    /// Index into [`ServeRun::models`] — resolved to the zoo name at
    /// render time so a million rows cost no string clones.
    pub model: usize,
    pub arrival: u64,
    pub completion: u64,
    pub latency: u64,
    pub slo_met: bool,
    pub ops: usize,
}

/// Aggregate serving report. Derives `PartialEq` so the determinism
/// property can compare entire runs bit for bit.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeReport {
    /// `+`-joined model mix.
    pub model: String,
    pub config: ConfigId,
    pub backend: BackendKind,
    pub policy: Policy,
    pub clusters: usize,
    pub rate_per_mcycle: f64,
    pub burst: f64,
    pub seed: u64,
    pub requests: usize,
    pub completed: usize,
    /// Last request-completion cycle (0 when nothing completed).
    pub makespan_cycles: u64,
    /// Streaming latency histogram (p50/p95/p99 source).
    pub latency: CycleHistogram,
    pub slo_cycles: u64,
    pub slo_attained: usize,
    /// Plan-cache counters *for this run* (delta over the service's
    /// totals). Covers every prepare the run triggered: when the SLO
    /// is derived (`ServeConfig::slo == None`), that includes the
    /// isolated-latency probe's dispatches, so `plan_hits +
    /// plan_misses` equals `gemm_ops` only for explicit-SLO runs.
    /// The event core reports the same numbers the wave-synchronous
    /// loop would: each shape-memo hit stands in for the plan-cache
    /// hit its skipped re-prepare would have recorded.
    pub plan_stats: ServiceStats,
    pub per_cluster_busy: Vec<u64>,
    /// Scheduler waves executed.
    pub waves: u64,
    /// Waves dispatched tensor-parallel via `run_sharded_job`.
    pub sharded_waves: u64,
    /// GEMM ops dispatched (batched + sharded).
    pub gemm_ops: u64,
    /// All ops executed (GEMMs + elementwise adds).
    pub total_ops: u64,
    /// NoC provisioning of the fabric the run scheduled onto (the
    /// `--profile` roofline ceilings derive from this, never from a
    /// renderer-side assumption).
    pub noc: NocConfig,
    /// StallScope class totals summed over every dispatched GEMM's
    /// compute cores (measured or predicted, per the backend).
    pub stall_totals: [u64; N_CLASSES],
    /// Per-model roofline accumulators over the request mix (the
    /// `--profile` report derives per-mix roofline points from these).
    pub mix: Vec<MixAccum>,
}

/// Roofline raw material for one model of the serve mix: totals over
/// every GEMM dispatched on behalf of that model's requests.
///
/// All quantities are *per-cluster normalized*: `window_cycles` sums
/// the compute window of every cluster that worked on the model
/// (each shard of a tensor-parallel dispatch contributes its own
/// window), so `flops / window_cycles` is bounded by one cluster's
/// 8 op/cycle peak regardless of fabric size — batched and sharded
/// dispatches land in the same normalization.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MixAccum {
    pub model: String,
    pub gemm_ops: u64,
    /// FPU ops (MACs + fused-epilogue ops).
    pub flops: u64,
    pub dma_bytes: u64,
    /// Summed per-cluster compute windows the ops were issued over.
    pub window_cycles: u64,
}

impl ServeReport {
    pub fn p50(&self) -> u64 {
        self.latency.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.latency.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.latency.quantile(0.99)
    }

    /// Completed requests per million cycles of makespan (0 on
    /// zero-cycle runs — ratios are NaN-guarded).
    pub fn throughput_per_mcycle(&self) -> f64 {
        ratio(self.completed as f64, self.makespan_cycles as f64)
            * 1.0e6
    }

    /// Fraction of completed requests that met the SLO.
    pub fn slo_attainment(&self) -> f64 {
        ratio(self.slo_attained as f64, self.completed as f64)
    }

    /// SLO-attained requests per million cycles — the serving metric
    /// the policy comparison is judged on.
    pub fn slo_attained_throughput(&self) -> f64 {
        ratio(self.slo_attained as f64, self.makespan_cycles as f64)
            * 1.0e6
    }

    /// Per-cluster busy fraction of the makespan.
    pub fn cluster_utilization(&self) -> Vec<f64> {
        self.per_cluster_busy
            .iter()
            .map(|&b| ratio(b as f64, self.makespan_cycles as f64))
            .collect()
    }
}

/// Event-core bookkeeping: how hard the heap and the shape memo
/// worked. Informational — `memo_hits` dispatches never touched the
/// backend. Deterministic across runs and thread counts (whole-run
/// equality in the determinism property covers it); all-zero for the
/// legacy engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Heap events processed (arrival batches + wave completions).
    pub events: u64,
    /// GEMM dispatches served from the shape memo (hash lookup, no
    /// backend call).
    pub memo_hits: u64,
    /// GEMM dispatches that reached the backend (first touch of a
    /// `(shape, epilogue, placement)` key).
    pub memo_misses: u64,
}

/// A completed serving run: the report plus per-request rows (sorted
/// by request id).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRun {
    pub report: ServeReport,
    /// Model-name table `ServeRow::model` indexes (the config's mix).
    pub models: Vec<String>,
    /// Event-core counters (zero under the legacy engine).
    pub engine_stats: EngineStats,
    /// Windowed metric registry + request-lifecycle spans; `Some`
    /// iff [`ServeConfig::telemetry`] was set on the event core.
    pub telemetry: Option<Telemetry>,
    pub rows: Vec<ServeRow>,
}

/// One zoo model's immutable scheduling skeleton, shared by every
/// request of that model (legacy engine).
struct ModelPlan {
    graph: NetGraph,
    deps0: Vec<usize>,
    dependents: Vec<Vec<usize>>,
}

fn model_plans(models: &[String]) -> Result<Vec<ModelPlan>> {
    models
        .iter()
        .map(|name| {
            let graph = zoo::build(name)?;
            let (_, deps0, dependents) = graph.dependency_structure()?;
            Ok(ModelPlan { graph, deps0, dependents })
        })
        .collect()
}

/// Mutable per-request execution state (legacy engine).
struct ReqState {
    model: usize,
    arrival: u64,
    seed: u64,
    deps: Vec<usize>,
    done: Vec<bool>,
    remaining: usize,
    last_finish: u64,
}

fn gemm_job_of(
    cfg: &ServeConfig,
    g: &NetGraph,
    oi: usize,
    req_seed: u64,
) -> GemmJob {
    let NetOp::Gemm { x, w, epi, .. } = &g.ops[oi] else {
        unreachable!("gemm_job_of called on a non-GEMM op");
    };
    let (m, n, k) =
        (g.tensors[*x].rows, g.tensors[*w].cols, g.tensors[*x].cols);
    GemmJob {
        seed: req_seed ^ problem_seed(m, n, k),
        ..GemmJob::fused(cfg.config, m, n, k, cfg.layout, *epi)
    }
}

/// Latency of one request of `model` served alone on an idle fabric
/// under `policy`. FIFO is the SLO / rate reference point every serve
/// config derives from; Continuous is the node tier's service-cost
/// model (a lone request's waves still go tensor-parallel, so this is
/// what one request actually costs an otherwise-idle fabric).
pub fn solo_latency(
    svc: &GemmService,
    cfg: &ServeConfig,
    model: usize,
    policy: Policy,
) -> Result<u64> {
    let mut solo = cfg.clone();
    solo.policy = policy;
    solo.requests = 1;
    solo.slo = Some(u64::MAX);
    solo.telemetry = None;
    let trace = ArrivalTrace {
        requests: vec![ServeRequest {
            id: 0,
            model,
            arrival: 0,
            seed: cfg.seed ^ 0x1501A7ED,
        }],
    };
    let run = serve_trace(svc, &solo, &trace)?;
    Ok(run.report.latency.max())
}

/// Latency of one request of `model` served alone on an idle system
/// under FIFO — the natural SLO / rate reference point for a config.
pub fn isolated_latency(
    svc: &GemmService,
    cfg: &ServeConfig,
    model: usize,
) -> Result<u64> {
    solo_latency(svc, cfg, model, Policy::Fifo)
}

/// Generate the arrival trace for `cfg` and serve it.
pub fn serve(svc: &GemmService, cfg: &ServeConfig) -> Result<ServeRun> {
    let trace = gen_arrivals(cfg);
    serve_trace(svc, cfg, &trace)
}

/// Serve an explicit arrival trace (the property tests feed shrunk
/// traces through this entry point). Requests may arrive unsorted;
/// the engine orders them by `(arrival, id)` itself.
pub fn serve_trace(
    svc: &GemmService,
    cfg: &ServeConfig,
    trace: &ArrivalTrace,
) -> Result<ServeRun> {
    ensure!(!cfg.models.is_empty(), "serve needs at least one model");
    match cfg.engine {
        ServeEngine::Legacy => serve_trace_legacy(svc, cfg, trace),
        ServeEngine::Event => {
            let plans = Arc::new(event_plans(cfg)?);
            serve_trace_event(svc, cfg, trace, &plans)
        }
    }
}

/// The original wave-synchronous serve loop, kept bit-identical to
/// the event core (differential property) until its removal is gated.
fn serve_trace_legacy(
    svc: &GemmService,
    cfg: &ServeConfig,
    trace: &ArrivalTrace,
) -> Result<ServeRun> {
    let plans = model_plans(&cfg.models)?;
    for r in &trace.requests {
        ensure!(
            r.model < plans.len(),
            "request {} names model index {} (mix has {})",
            r.id,
            r.model,
            plans.len()
        );
    }
    let n_clusters = cfg.clusters.max(1);
    let fabric = FabricConfig::new(n_clusters);
    // Snapshot plan-cache counters before everything — including the
    // SLO probe below — so the reported hit rate covers the whole
    // run's cache behavior, cold start included.
    let stats0 = svc.stats();
    let slo = match cfg.slo {
        Some(s) => s,
        None => {
            // 4x the isolated latency of the mix's first model — a
            // fixed reference, independent of which model the first
            // arrival happens to sample.
            isolated_latency(svc, cfg, 0)?.saturating_mul(4)
        }
    };

    // Arrival order (stable on same-cycle bursts by id).
    let mut arrivals: Vec<ServeRequest> = trace.requests.clone();
    arrivals.sort_by_key(|r| (r.arrival, r.id));

    let mut reqs: Vec<ReqState> = arrivals
        .iter()
        .map(|r| {
            let p = &plans[r.model];
            ReqState {
                model: r.model,
                arrival: r.arrival,
                seed: r.seed,
                deps: p.deps0.clone(),
                done: vec![false; p.graph.ops.len()],
                remaining: p.graph.ops.len(),
                last_finish: 0,
            }
        })
        .collect();

    let mut clock = 0u64;
    let mut next_arr = 0usize;
    // Admitted, incomplete requests in arrival order.
    let mut active: Vec<usize> = Vec::new();
    let mut busy = vec![0u64; n_clusters];
    let mut hist = CycleHistogram::new();
    let mut rows: Vec<ServeRow> = Vec::new();
    let mut slo_attained = 0usize;
    let mut makespan = 0u64;
    let mut waves = 0u64;
    let mut sharded_waves = 0u64;
    let mut gemm_ops = 0u64;
    let mut total_ops = 0u64;
    let mut stall_totals = [0u64; N_CLASSES];
    let mut mix: Vec<MixAccum> = cfg
        .models
        .iter()
        .map(|name| MixAccum {
            model: name.clone(),
            gemm_ops: 0,
            flops: 0,
            dma_bytes: 0,
            window_cycles: 0,
        })
        .collect();

    while next_arr < reqs.len() || !active.is_empty() {
        while next_arr < reqs.len()
            && arrivals[next_arr].arrival <= clock
        {
            active.push(next_arr);
            next_arr += 1;
        }
        if active.is_empty() {
            // Idle: jump straight to the next arrival.
            clock = arrivals[next_arr].arrival;
            continue;
        }

        // Ready ops of the policy's scheduling pool.
        let ready: Vec<(usize, usize)> = {
            let pool: &[usize] = match cfg.policy {
                Policy::Fifo => &active[..1],
                Policy::Continuous => &active[..],
            };
            let mut v = Vec::new();
            for &ri in pool {
                let g = &plans[reqs[ri].model].graph;
                for oi in 0..g.ops.len() {
                    if !reqs[ri].done[oi] && reqs[ri].deps[oi] == 0 {
                        v.push((ri, oi));
                    }
                }
            }
            v
        };
        ensure!(
            !ready.is_empty(),
            "serve deadlocked: {} active requests with no ready op",
            active.len()
        );
        waves += 1;
        let mut finishes: Vec<u64> = vec![0; ready.len()];

        // A lone ready GEMM with idle clusters goes tensor-parallel
        // (continuous batching only — FIFO is the plain baseline).
        let single_shardable = cfg.policy == Policy::Continuous
            && n_clusters > 1
            && ready.len() == 1
            && {
                let (ri, oi) = ready[0];
                let g = &plans[reqs[ri].model].graph;
                match &g.ops[oi] {
                    NetOp::Gemm { x, w, .. } => choose_shard_grid(
                        g.tensors[*x].rows,
                        g.tensors[*w].cols,
                        n_clusters,
                    )
                    .used_clusters()
                        > 1,
                    NetOp::Add { .. } => false,
                }
            };

        if single_shardable {
            let (ri, oi) = ready[0];
            let job = gemm_job_of(
                cfg,
                &plans[reqs[ri].model].graph,
                oi,
                reqs[ri].seed,
            );
            let fr = svc.run_sharded_job(&job, &fabric)?;
            sharded_waves += 1;
            gemm_ops += 1;
            for (ci, s) in fr.shards.iter().enumerate() {
                busy[ci % n_clusters] += s.cycles;
            }
            for (t, v) in stall_totals
                .iter_mut()
                .zip(fr.stall_profile().totals())
            {
                *t += v;
            }
            let acc = &mut mix[reqs[ri].model];
            acc.gemm_ops += 1;
            acc.flops += fr.fpu_ops_total();
            acc.dma_bytes +=
                fr.shards.iter().map(|s| s.perf.dma_bytes).sum::<u64>();
            // Per-cluster normalization: every shard's window counts.
            acc.window_cycles += fr
                .shards
                .iter()
                .map(|s| s.perf.window_cycles)
                .sum::<u64>();
            finishes[0] = clock + fr.cycles;
            clock += fr.cycles;
        } else {
            // Merge the wave's GEMMs into one batched dispatch.
            let mut jobs: Vec<GemmJob> = Vec::new();
            let mut job_of: Vec<Option<usize>> =
                vec![None; ready.len()];
            for (ix, &(ri, oi)) in ready.iter().enumerate() {
                if matches!(
                    plans[reqs[ri].model].graph.ops[oi],
                    NetOp::Gemm { .. }
                ) {
                    job_of[ix] = Some(jobs.len());
                    jobs.push(gemm_job_of(
                        cfg,
                        &plans[reqs[ri].model].graph,
                        oi,
                        reqs[ri].seed,
                    ));
                }
            }
            gemm_ops += jobs.len() as u64;
            let results = svc.run_batch(&jobs, cfg.threads)?;
            for (ix, &(ri, _)) in ready.iter().enumerate() {
                let Some(ji) = job_of[ix] else { continue };
                let perf = &results[ji].perf;
                for (t, v) in
                    stall_totals.iter_mut().zip(perf.stalls.totals())
                {
                    *t += v;
                }
                let acc = &mut mix[reqs[ri].model];
                acc.gemm_ops += 1;
                acc.flops += perf.fpu_ops_total;
                acc.dma_bytes += perf.dma_bytes;
                acc.window_cycles += perf.window_cycles;
            }
            let costs: Vec<u64> = ready
                .iter()
                .enumerate()
                .map(|(ix, &(ri, oi))| {
                    match &plans[reqs[ri].model].graph.ops[oi] {
                        NetOp::Gemm { .. } => {
                            results[job_of[ix].unwrap()].cycles
                        }
                        NetOp::Add { out, .. } => add_pass_cycles(
                            plans[reqs[ri].model].graph.tensors[*out]
                                .elems(),
                        ),
                    }
                })
                .collect();
            // Longest-processing-time-first onto the least-loaded
            // cluster; every tie-break is deterministic.
            let mut by_cost: Vec<usize> = (0..ready.len()).collect();
            by_cost.sort_by(|&a, &b| {
                costs[b].cmp(&costs[a]).then(ready[a].cmp(&ready[b]))
            });
            let mut load = vec![0u64; n_clusters];
            for &ix in &by_cost {
                let c = (0..n_clusters)
                    .min_by_key(|&c| (load[c], c))
                    .unwrap();
                finishes[ix] = clock + load[c] + costs[ix];
                load[c] += costs[ix];
            }
            let elapsed = load.iter().copied().max().unwrap_or(0);
            for (ci, &l) in load.iter().enumerate() {
                busy[ci] += l;
            }
            clock += elapsed;
        }

        // Commit the wave: mark ops done, release dependents.
        for (&(ri, oi), &fin) in ready.iter().zip(&finishes) {
            total_ops += 1;
            let model = reqs[ri].model;
            reqs[ri].done[oi] = true;
            reqs[ri].remaining -= 1;
            reqs[ri].last_finish = reqs[ri].last_finish.max(fin);
            for &d in &plans[model].dependents[oi] {
                reqs[ri].deps[d] -= 1;
            }
        }

        // Retire completed requests.
        active.retain(|&ri| {
            if reqs[ri].remaining > 0 {
                return true;
            }
            let latency =
                reqs[ri].last_finish.saturating_sub(reqs[ri].arrival);
            hist.record(latency);
            if latency <= slo {
                slo_attained += 1;
            }
            makespan = makespan.max(reqs[ri].last_finish);
            rows.push(ServeRow {
                id: arrivals[ri].id,
                model: reqs[ri].model,
                arrival: reqs[ri].arrival,
                completion: reqs[ri].last_finish,
                latency,
                slo_met: latency <= slo,
                ops: plans[reqs[ri].model].graph.ops.len(),
            });
            false
        });
    }

    rows.sort_by_key(|r| r.id);
    let stats1 = svc.stats();
    let completed = rows.len();
    let report = ServeReport {
        model: cfg.models.join("+"),
        config: cfg.config,
        backend: svc.backend_kind(),
        policy: cfg.policy,
        clusters: n_clusters,
        rate_per_mcycle: cfg.rate_per_mcycle,
        burst: cfg.burst,
        seed: cfg.seed,
        requests: trace.requests.len(),
        completed,
        makespan_cycles: makespan,
        latency: hist,
        slo_cycles: slo,
        slo_attained,
        plan_stats: stats1.delta_since(&stats0),
        per_cluster_busy: busy,
        waves,
        sharded_waves,
        gemm_ops,
        total_ops,
        noc: fabric.noc,
        stall_totals,
        mix,
    };
    Ok(ServeRun {
        report,
        models: cfg.models.clone(),
        engine_stats: EngineStats::default(),
        telemetry: None,
        rows,
    })
}

// ------------------------------------------------ event-driven core --

/// Maximum ops per model graph the event core's `u64` ready-bitmask
/// supports. Every zoo model is far below this; the legacy engine has
/// no such cap.
const MAX_EVENT_OPS: usize = 64;

/// Precomputed dispatch recipe for one op: shape, epilogue and
/// shardability for GEMMs, the closed-form cost for elementwise adds.
/// Resolved once per model instead of re-derived every wave.
#[derive(Clone, Copy)]
enum OpSpec {
    Gemm {
        m: usize,
        n: usize,
        k: usize,
        epi: Epilogue,
        /// `choose_shard_grid(m, n, clusters).used_clusters() > 1`,
        /// precomputed for the run's fabric size.
        shardable: bool,
    },
    Add {
        cycles: u64,
    },
}

/// One zoo model's immutable scheduling skeleton for the event core:
/// dependency arenas sized for flat `u8` fan-in counters and a `u64`
/// ready bitmask. Shared across requests (and with the SLO probe)
/// behind one `Arc`.
struct EventPlan {
    ops: usize,
    deps0: Vec<u8>,
    dependents: Vec<Vec<u32>>,
    specs: Vec<OpSpec>,
    /// Bit `oi` set when op `oi` has no producers (ready at admit).
    roots: u64,
}

fn event_plans(cfg: &ServeConfig) -> Result<Vec<EventPlan>> {
    let n_clusters = cfg.clusters.max(1);
    cfg.models
        .iter()
        .map(|name| {
            let graph = zoo::build(name)?;
            let (_, deps0, dependents) = graph.dependency_structure()?;
            let ops = graph.ops.len();
            ensure!(
                ops <= MAX_EVENT_OPS,
                "event engine caps model graphs at {MAX_EVENT_OPS} \
                 ops (`{name}` has {ops}); use --serve-engine legacy"
            );
            let specs = graph
                .ops
                .iter()
                .map(|op| match op {
                    NetOp::Gemm { x, w, epi, .. } => {
                        let (m, n, k) = (
                            graph.tensors[*x].rows,
                            graph.tensors[*w].cols,
                            graph.tensors[*x].cols,
                        );
                        OpSpec::Gemm {
                            m,
                            n,
                            k,
                            epi: *epi,
                            shardable: n_clusters > 1
                                && choose_shard_grid(m, n, n_clusters)
                                    .used_clusters()
                                    > 1,
                        }
                    }
                    NetOp::Add { out, .. } => OpSpec::Add {
                        cycles: add_pass_cycles(
                            graph.tensors[*out].elems(),
                        ),
                    },
                })
                .collect();
            let mut roots = 0u64;
            let mut deps = Vec::with_capacity(ops);
            for (oi, &d) in deps0.iter().enumerate() {
                ensure!(
                    d <= u8::MAX as usize,
                    "op fan-in {d} exceeds the event engine's u8 arena"
                );
                deps.push(d as u8);
                if d == 0 {
                    roots |= 1u64 << oi;
                }
            }
            let dependents = dependents
                .into_iter()
                .map(|v| v.into_iter().map(|d| d as u32).collect())
                .collect();
            Ok(EventPlan { ops, deps0: deps, dependents, specs, roots })
        })
        .collect()
}

/// How a dispatch was placed — part of the shape-memo key: a packed
/// (batched) dispatch and a tensor-parallel one of the same shape
/// have different timing.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
enum Placement {
    Packed,
    Sharded,
}

/// Shape-memo key. Config, layout and the shard grid are fixed for a
/// run, so `(m, n, k, epilogue, placement)` pins the dispatch.
type DispatchKey = (usize, usize, usize, Epilogue, Placement);

/// Memoized observables of one GEMM dispatch. Timing and perf are
/// data-oblivious (DESIGN.md §11's theorem), so every repeat of the
/// same key replays them bit for bit.
struct DispatchMemo {
    cycles: u64,
    stalls: [u64; N_CLASSES],
    flops: u64,
    dma_bytes: u64,
    window_cycles: u64,
    /// Per-shard busy cycles (sharded placements; empty for packed).
    shard_cycles: Vec<u64>,
}

/// Append the set bits of `mask` as `(ri, oi)` pool entries, in
/// ascending op order — the same order the legacy pool scan produces.
#[inline]
fn collect_ready(mask: u64, ri: u32, pool: &mut Vec<(u32, u32)>) {
    let mut m = mask;
    while m != 0 {
        pool.push((ri, m.trailing_zeros()));
        m &= m - 1;
    }
}

/// Event kinds, in tie-break order at equal virtual time: arrivals
/// admit before a co-temporal wave completion commits — both orders
/// leave the same state (admission only grows the active set, commit
/// only touches per-request progress), and the next wave dispatches
/// only after the instant fully drains, so the choice is free; it is
/// fixed here so runs are reproducible byte for byte.
const EV_ARRIVE: u8 = 0;
const EV_WAVE: u8 = 1;

/// MegaServe: the event-driven serve core. Semantics are identical to
/// [`serve_trace_legacy`] — waves are still serial scheduling quanta —
/// but the hot loop is allocation-free, repeated dispatches are
/// served from the shape memo, and only deduplicated *unseen* shapes
/// reach the backend (in one parallel batch per wave).
fn serve_trace_event(
    svc: &GemmService,
    cfg: &ServeConfig,
    trace: &ArrivalTrace,
    plans: &Arc<Vec<EventPlan>>,
) -> Result<ServeRun> {
    for r in &trace.requests {
        ensure!(
            r.model < plans.len(),
            "request {} names model index {} (mix has {})",
            r.id,
            r.model,
            plans.len()
        );
    }
    let n_clusters = cfg.clusters.max(1);
    let fabric = FabricConfig::new(n_clusters);
    // Snapshot plan-cache counters before everything — including the
    // SLO probe — exactly like the legacy engine; the memo-hit
    // synthesis below reconstructs the skipped re-prepares.
    let stats0 = svc.stats();
    let mut probe_stats = EngineStats::default();
    let slo = match cfg.slo {
        Some(s) => s,
        None => {
            // 4x the isolated latency of the mix's first model. The
            // probe reuses the same Arc'd plans instead of rebuilding.
            let mut solo = cfg.clone();
            solo.policy = Policy::Fifo;
            solo.requests = 1;
            solo.slo = Some(u64::MAX);
            // The probe is a measurement artifact, not traffic — keep
            // its events out of the parent telemetry stream.
            solo.telemetry = None;
            let ptrace = ArrivalTrace {
                requests: vec![ServeRequest {
                    id: 0,
                    model: 0,
                    arrival: 0,
                    seed: cfg.seed ^ 0x1501A7ED,
                }],
            };
            let probe = serve_trace_event(svc, &solo, &ptrace, plans)?;
            probe_stats = probe.engine_stats;
            probe.report.latency.max().saturating_mul(4)
        }
    };

    // Arrival order (stable on same-cycle bursts by id). Request
    // index == position in this order everywhere below.
    let mut arrivals: Vec<ServeRequest> = trace.requests.clone();
    arrivals.sort_by_key(|r| (r.arrival, r.id));
    let n = arrivals.len();

    // Flat per-request state arenas: fan-in counters live in one u8
    // slab addressed by `off`, readiness is a bitmask per request.
    let mut off = Vec::with_capacity(n + 1);
    off.push(0u32);
    for r in &arrivals {
        off.push(off.last().unwrap() + plans[r.model].ops as u32);
    }
    let mut deps = vec![0u8; *off.last().unwrap() as usize];
    for (ri, r) in arrivals.iter().enumerate() {
        deps[off[ri] as usize..off[ri + 1] as usize]
            .copy_from_slice(&plans[r.model].deps0);
    }
    let mut ready_mask: Vec<u64> =
        arrivals.iter().map(|r| plans[r.model].roots).collect();
    let mut remaining: Vec<u32> =
        arrivals.iter().map(|r| plans[r.model].ops as u32).collect();
    let mut last_finish = vec![0u64; n];

    // Report accumulators. Latency lands in per-model histogram
    // shards, merged (bit-exactly) into one at the end.
    let mut busy = vec![0u64; n_clusters];
    let mut hist_shards: Vec<CycleHistogram> =
        (0..plans.len()).map(|_| CycleHistogram::new()).collect();
    let mut rows: Vec<ServeRow> = Vec::with_capacity(n);
    let mut slo_attained = 0usize;
    let mut makespan = 0u64;
    let mut waves = 0u64;
    let mut sharded_waves = 0u64;
    let mut gemm_ops = 0u64;
    let mut total_ops = 0u64;
    let mut stall_totals = [0u64; N_CLASSES];
    let mut mix: Vec<MixAccum> = cfg
        .models
        .iter()
        .map(|name| MixAccum {
            model: name.clone(),
            gemm_ops: 0,
            flops: 0,
            dma_bytes: 0,
            window_cycles: 0,
        })
        .collect();

    // Engine state: the event heap (min on `(time, kind)`), the
    // active set (BTreeSet iterates in arrival order — request index
    // == sorted-arrival position), the shape memo, and per-wave
    // scratch buffers reused across the whole run.
    let mut heap: BinaryHeap<Reverse<(u64, u8)>> = BinaryHeap::new();
    let mut active: BTreeSet<u32> = BTreeSet::new();
    let mut memo: HashMap<DispatchKey, DispatchMemo> = HashMap::new();
    let mut next_arr = 0usize;
    let mut clock = 0u64;
    let mut wave_in_flight = false;
    let mut events_n = 0u64;
    let mut memo_hits = 0u64;
    let mut memo_misses = 0u64;
    // Wave scratch: `wave_pool`/`wave_fin` persist from dispatch to
    // the wave-completion event that commits them.
    let mut wave_pool: Vec<(u32, u32)> = Vec::new();
    let mut wave_fin: Vec<u64> = Vec::new();
    let mut costs: Vec<u64> = Vec::new();
    let mut by_cost: Vec<usize> = Vec::new();
    let mut load: Vec<u64> = vec![0; n_clusters];
    let mut fresh_jobs: Vec<GemmJob> = Vec::new();
    let mut fresh_keys: Vec<DispatchKey> = Vec::new();
    // Telemetry bus (optional). Every record below is keyed on the
    // virtual clock and engine state only, so the stream — like the
    // run itself — is bit-identical at any host thread count.
    let mut tel = cfg.telemetry.map(Telemetry::new);

    if n > 0 {
        heap.push(Reverse((arrivals[0].arrival, EV_ARRIVE)));
    }
    while let Some(&Reverse((t, _))) = heap.peek() {
        clock = t;
        // Drain every event at this instant before dispatching, so
        // admission and wave commit both precede the next scheduling
        // decision — the wave-synchronous loop's order.
        while let Some(&Reverse((t2, kind))) = heap.peek() {
            if t2 != clock {
                break;
            }
            heap.pop();
            events_n += 1;
            if kind == EV_ARRIVE {
                while next_arr < n
                    && arrivals[next_arr].arrival <= clock
                {
                    active.insert(next_arr as u32);
                    next_arr += 1;
                    if let Some(tel) = tel.as_mut() {
                        tel.count("arrivals", "", clock, 1);
                    }
                }
                if next_arr < n {
                    heap.push(Reverse((
                        arrivals[next_arr].arrival,
                        EV_ARRIVE,
                    )));
                }
            } else {
                // Wave completion: commit ops, release dependents,
                // retire finished requests. Accumulation is
                // commutative and rows are sorted by id at the end,
                // so commit order inside the wave is immaterial.
                for i in 0..wave_pool.len() {
                    let (ri, oi) = wave_pool[i];
                    let fin = wave_fin[i];
                    let riu = ri as usize;
                    let model = arrivals[riu].model;
                    total_ops += 1;
                    remaining[riu] -= 1;
                    if fin > last_finish[riu] {
                        last_finish[riu] = fin;
                    }
                    for &d in &plans[model].dependents[oi as usize] {
                        let slot = off[riu] as usize + d as usize;
                        deps[slot] -= 1;
                        if deps[slot] == 0 {
                            ready_mask[riu] |= 1u64 << d;
                        }
                    }
                    if remaining[riu] == 0 {
                        let arrival = arrivals[riu].arrival;
                        let completion = last_finish[riu];
                        let latency =
                            completion.saturating_sub(arrival);
                        hist_shards[model].record(latency);
                        if latency <= slo {
                            slo_attained += 1;
                        }
                        if completion > makespan {
                            makespan = completion;
                        }
                        rows.push(ServeRow {
                            id: arrivals[riu].id,
                            model,
                            arrival,
                            completion,
                            latency,
                            slo_met: latency <= slo,
                            ops: plans[model].ops,
                        });
                        active.remove(&ri);
                        if let Some(tel) = tel.as_mut() {
                            tel.count("completions", "", completion, 1);
                            tel.observe(
                                "latency_cycles",
                                "",
                                completion,
                                latency,
                            );
                            tel.span(
                                SpanKind::Request,
                                0,
                                arrivals[riu].id as u64,
                                arrival,
                                completion,
                                plans[model].ops as u64,
                            );
                        }
                    }
                }
                wave_in_flight = false;
            }
        }

        if wave_in_flight || active.is_empty() {
            continue;
        }

        // Dispatch the next wave: pool the policy's ready ops.
        wave_pool.clear();
        match cfg.policy {
            Policy::Fifo => {
                let &ri = active.iter().next().unwrap();
                collect_ready(
                    ready_mask[ri as usize],
                    ri,
                    &mut wave_pool,
                );
            }
            Policy::Continuous => {
                for &ri in active.iter() {
                    collect_ready(
                        ready_mask[ri as usize],
                        ri,
                        &mut wave_pool,
                    );
                }
            }
        }
        ensure!(
            !wave_pool.is_empty(),
            "serve deadlocked: {} active requests with no ready op",
            active.len()
        );
        waves += 1;
        let (hits0, misses0) = (memo_hits, memo_misses);
        for &(ri, oi) in &wave_pool {
            ready_mask[ri as usize] &= !(1u64 << oi);
        }

        let single_shardable = cfg.policy == Policy::Continuous
            && n_clusters > 1
            && wave_pool.len() == 1
            && matches!(
                plans[arrivals[wave_pool[0].0 as usize].model].specs
                    [wave_pool[0].1 as usize],
                OpSpec::Gemm { shardable: true, .. }
            );

        let elapsed;
        if single_shardable {
            let (ri, oi) = wave_pool[0];
            let model = arrivals[ri as usize].model;
            let OpSpec::Gemm { m, n: nn, k, epi, .. } =
                plans[model].specs[oi as usize]
            else {
                unreachable!("shardable op is a GEMM");
            };
            let key = (m, nn, k, epi, Placement::Sharded);
            if memo.contains_key(&key) {
                memo_hits += 1;
            } else {
                memo_misses += 1;
                let job = GemmJob {
                    seed: arrivals[ri as usize].seed
                        ^ problem_seed(m, nn, k),
                    ..GemmJob::fused(
                        cfg.config, m, nn, k, cfg.layout, epi,
                    )
                };
                let fr = svc.run_sharded_job(&job, &fabric)?;
                memo.insert(
                    key,
                    DispatchMemo {
                        cycles: fr.cycles,
                        stalls: fr.stall_profile().totals(),
                        flops: fr.fpu_ops_total(),
                        dma_bytes: fr
                            .shards
                            .iter()
                            .map(|s| s.perf.dma_bytes)
                            .sum(),
                        window_cycles: fr
                            .shards
                            .iter()
                            .map(|s| s.perf.window_cycles)
                            .sum(),
                        shard_cycles: fr
                            .shards
                            .iter()
                            .map(|s| s.cycles)
                            .collect(),
                    },
                );
            }
            let mo = &memo[&key];
            sharded_waves += 1;
            gemm_ops += 1;
            for (ci, &c) in mo.shard_cycles.iter().enumerate() {
                busy[ci % n_clusters] += c;
            }
            for (t, v) in stall_totals.iter_mut().zip(mo.stalls) {
                *t += v;
            }
            let acc = &mut mix[model];
            acc.gemm_ops += 1;
            acc.flops += mo.flops;
            acc.dma_bytes += mo.dma_bytes;
            acc.window_cycles += mo.window_cycles;
            wave_fin.clear();
            wave_fin.push(clock + mo.cycles);
            elapsed = mo.cycles;
        } else {
            // Pass 1: route every GEMM through the shape memo; each
            // unseen key queues exactly one backend job (in-wave
            // duplicates alias the first toucher's job).
            fresh_jobs.clear();
            fresh_keys.clear();
            for &(ri, oi) in &wave_pool {
                let model = arrivals[ri as usize].model;
                if let OpSpec::Gemm { m, n: nn, k, epi, .. } =
                    plans[model].specs[oi as usize]
                {
                    let key = (m, nn, k, epi, Placement::Packed);
                    if memo.contains_key(&key)
                        || fresh_keys.contains(&key)
                    {
                        memo_hits += 1;
                    } else {
                        memo_misses += 1;
                        fresh_keys.push(key);
                        fresh_jobs.push(GemmJob {
                            seed: arrivals[ri as usize].seed
                                ^ problem_seed(m, nn, k),
                            ..GemmJob::fused(
                                cfg.config, m, nn, k, cfg.layout, epi,
                            )
                        });
                    }
                }
            }
            if !fresh_jobs.is_empty() {
                // Deduplicated unseen shapes evaluate concurrently on
                // the host pool; `parallel_map`'s atomic-index grant
                // discipline keeps result order equal to submission
                // order at any thread count.
                let results = svc.run_batch(&fresh_jobs, cfg.threads)?;
                for (key, res) in fresh_keys.iter().zip(&results) {
                    let perf = &res.perf;
                    memo.insert(
                        *key,
                        DispatchMemo {
                            cycles: res.cycles,
                            stalls: perf.stalls.totals(),
                            flops: perf.fpu_ops_total,
                            dma_bytes: perf.dma_bytes,
                            window_cycles: perf.window_cycles,
                            shard_cycles: Vec::new(),
                        },
                    );
                }
            }
            // Pass 2: per-op costs and accounting, all from the memo.
            costs.clear();
            for &(ri, oi) in &wave_pool {
                let model = arrivals[ri as usize].model;
                match plans[model].specs[oi as usize] {
                    OpSpec::Gemm { m, n: nn, k, epi, .. } => {
                        let mo =
                            &memo[&(m, nn, k, epi, Placement::Packed)];
                        gemm_ops += 1;
                        for (t, v) in
                            stall_totals.iter_mut().zip(mo.stalls)
                        {
                            *t += v;
                        }
                        let acc = &mut mix[model];
                        acc.gemm_ops += 1;
                        acc.flops += mo.flops;
                        acc.dma_bytes += mo.dma_bytes;
                        acc.window_cycles += mo.window_cycles;
                        costs.push(mo.cycles);
                    }
                    OpSpec::Add { cycles } => costs.push(cycles),
                }
            }
            // Longest-processing-time-first onto the least-loaded
            // cluster; tie-breaks byte-identical to the legacy loop.
            by_cost.clear();
            by_cost.extend(0..wave_pool.len());
            by_cost.sort_by(|&a, &b| {
                costs[b]
                    .cmp(&costs[a])
                    .then(wave_pool[a].cmp(&wave_pool[b]))
            });
            load.iter_mut().for_each(|l| *l = 0);
            wave_fin.clear();
            wave_fin.resize(wave_pool.len(), 0);
            for &ix in &by_cost {
                let c = (0..n_clusters)
                    .min_by_key(|&c| (load[c], c))
                    .unwrap();
                wave_fin[ix] = clock + load[c] + costs[ix];
                load[c] += costs[ix];
            }
            elapsed = load.iter().copied().max().unwrap_or(0);
            for (ci, &l) in load.iter().enumerate() {
                busy[ci] += l;
            }
        }
        if let Some(tel) = tel.as_mut() {
            tel.count("waves", "", clock, 1);
            tel.count("memo_hits", "", clock, memo_hits - hits0);
            tel.count(
                "memo_misses",
                "",
                clock,
                memo_misses - misses0,
            );
            tel.gauge("in_flight", "", clock, active.len() as u64);
            tel.gauge("wave_ops", "", clock, wave_pool.len() as u64);
            tel.span(
                SpanKind::Wave,
                0,
                waves,
                clock,
                clock + elapsed,
                wave_pool.len() as u64,
            );
        }
        heap.push(Reverse((clock + elapsed, EV_WAVE)));
        wave_in_flight = true;
    }

    rows.sort_by_key(|r| r.id);
    // Merge the per-model latency shards (bucket-wise exact; the
    // stats property suite pins shard-merge == single-stream).
    let mut hist = CycleHistogram::new();
    for shard in &hist_shards {
        hist.merge(shard);
    }
    let stats1 = svc.stats();
    let mut plan_stats = stats1.delta_since(&stats0);
    // Every memo hit skipped a backend call whose plan re-prepare
    // would have been a cache hit (the first toucher installed the
    // plan), so folding the hits back in makes the run-local stats
    // equal to the legacy engine's, bit for bit. The derived-SLO
    // probe's hits fold in the same way.
    plan_stats.plan_hits += memo_hits + probe_stats.memo_hits;
    let completed = rows.len();
    let report = ServeReport {
        model: cfg.models.join("+"),
        config: cfg.config,
        backend: svc.backend_kind(),
        policy: cfg.policy,
        clusters: n_clusters,
        rate_per_mcycle: cfg.rate_per_mcycle,
        burst: cfg.burst,
        seed: cfg.seed,
        requests: trace.requests.len(),
        completed,
        makespan_cycles: makespan,
        latency: hist,
        slo_cycles: slo,
        slo_attained,
        plan_stats,
        per_cluster_busy: busy,
        waves,
        sharded_waves,
        gemm_ops,
        total_ops,
        noc: fabric.noc,
        stall_totals,
        mix,
    };
    Ok(ServeRun {
        report,
        models: cfg.models.clone(),
        engine_stats: EngineStats {
            events: events_n + probe_stats.events,
            memo_hits: memo_hits + probe_stats.memo_hits,
            memo_misses: memo_misses + probe_stats.memo_misses,
        },
        telemetry: tel.map(|mut t| {
            t.seal(makespan.max(clock));
            t
        }),
        rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn analytic() -> GemmService {
        GemmService::analytic()
    }

    fn cfg_of(model: &str) -> ServeConfig {
        let mut c = ServeConfig::new(vec![model.to_string()]);
        c.slo = Some(u64::MAX);
        c.seed = 0x5EED;
        c
    }

    #[test]
    fn fifo_single_request_latency_is_the_chain_sum() {
        // One ffn request on one cluster: strict serialization, so
        // the latency is exactly the sum of the per-op backend costs.
        let svc = analytic();
        let mut cfg = cfg_of("ffn");
        cfg.policy = Policy::Fifo;
        cfg.requests = 1;
        let run = serve(&svc, &cfg).unwrap();
        let g = zoo::build("ffn").unwrap();
        let probe = analytic();
        let mut expect = 0u64;
        for (oi, op) in g.ops.iter().enumerate() {
            match op {
                NetOp::Gemm { .. } => {
                    let job = gemm_job_of(&cfg, &g, oi, 0);
                    expect += probe.run_job(&job).unwrap().cycles;
                }
                NetOp::Add { out, .. } => {
                    expect += add_pass_cycles(g.tensors[*out].elems());
                }
            }
        }
        assert_eq!(run.report.completed, 1);
        assert_eq!(run.report.makespan_cycles, expect);
        assert_eq!(run.report.latency.max(), expect);
        assert_eq!(run.report.p50(), run.report.p99());
        assert_eq!(run.report.total_ops, g.ops.len() as u64);
        assert_eq!(run.rows.len(), 1);
        assert_eq!(run.rows[0].latency, expect);
    }

    #[test]
    fn fifo_serializes_but_cb_overlaps_bursts() {
        // Two requests arriving together: FIFO serves them back to
        // back; continuous batching on 2 clusters overlaps them.
        let mut cfg = cfg_of("ffn");
        cfg.requests = 2;
        cfg.burst = 1.0; // both arrive on cycle 0
        cfg.clusters = 2;
        cfg.policy = Policy::Fifo;
        let fifo = serve(&analytic(), &cfg).unwrap();
        cfg.policy = Policy::Continuous;
        let cb = serve(&analytic(), &cfg).unwrap();
        assert_eq!(fifo.report.completed, 2);
        assert_eq!(cb.report.completed, 2);
        assert!(
            cb.report.makespan_cycles < fifo.report.makespan_cycles,
            "cb {} vs fifo {}",
            cb.report.makespan_cycles,
            fifo.report.makespan_cycles
        );
    }

    #[test]
    fn cb_shards_lone_gemm_waves() {
        // A solo ffn request under continuous batching on 4 clusters:
        // both GEMM waves are alone and shardable, the residual add
        // is not.
        let mut cfg = cfg_of("ffn");
        cfg.requests = 1;
        cfg.clusters = 4;
        cfg.policy = Policy::Continuous;
        let run = serve(&analytic(), &cfg).unwrap();
        assert_eq!(run.report.sharded_waves, 2);
        assert_eq!(run.report.gemm_ops, 2);
        assert_eq!(run.report.total_ops, 3);
        // FIFO never shards.
        cfg.policy = Policy::Fifo;
        let fifo = serve(&analytic(), &cfg).unwrap();
        assert_eq!(fifo.report.sharded_waves, 0);
        assert!(
            run.report.makespan_cycles < fifo.report.makespan_cycles,
            "tensor-parallel solo service must be faster"
        );
    }

    #[test]
    fn serve_accumulates_stallscope_and_mix_rooflines() {
        let svc = analytic();
        let mut cfg = cfg_of("ffn");
        cfg.requests = 3;
        let run = serve(&svc, &cfg).unwrap();
        let r = &run.report;
        assert_eq!(r.mix.len(), 1);
        assert_eq!(r.mix[0].model, "ffn");
        // One cluster, one model: every dispatched GEMM is ffn's.
        assert_eq!(r.mix[0].gemm_ops, r.gemm_ops);
        assert!(r.mix[0].flops > 0);
        assert!(r.mix[0].dma_bytes > 0);
        assert!(r.mix[0].window_cycles > 0);
        assert!(r.stall_totals.iter().sum::<u64>() > 0);
        // Sharded dispatches accumulate too.
        let mut cfg4 = cfg_of("ffn");
        cfg4.requests = 1;
        cfg4.clusters = 4;
        let run4 = serve(&analytic(), &cfg4).unwrap();
        assert!(run4.report.sharded_waves > 0);
        assert!(run4.report.mix[0].flops > 0);
    }

    #[test]
    fn arrivals_are_deterministic_and_bursty() {
        let mut cfg = cfg_of("ffn");
        cfg.requests = 16;
        let a = gen_arrivals(&cfg);
        let b = gen_arrivals(&cfg);
        assert_eq!(a, b);
        assert!(a
            .requests
            .windows(2)
            .all(|w| w[0].arrival <= w[1].arrival));
        cfg.seed ^= 1;
        assert_ne!(gen_arrivals(&cfg), a, "seed changes the trace");
        cfg.burst = 1.0;
        let burst = gen_arrivals(&cfg);
        assert!(
            burst.requests.iter().all(|r| r.arrival == 0),
            "burst=1 collapses every arrival onto cycle 0"
        );
    }

    #[test]
    fn plan_stats_are_run_local_deltas() {
        let svc = analytic();
        let mut cfg = cfg_of("ffn");
        cfg.requests = 4;
        let first = serve(&svc, &cfg).unwrap();
        assert!(first.report.plan_stats.plan_misses > 0);
        // A second run on the same warm service sees only hits.
        let second = serve(&svc, &cfg).unwrap();
        assert_eq!(second.report.plan_stats.plan_misses, 0);
        assert!(second.report.plan_stats.plan_hits > 0);
        assert!((second.report.plan_stats.hit_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_invalid_inputs() {
        let svc = analytic();
        let mut cfg = cfg_of("ffn");
        cfg.requests = 0;
        let run = serve(&svc, &cfg).unwrap();
        assert_eq!(run.report.completed, 0);
        assert_eq!(run.report.makespan_cycles, 0);
        assert_eq!(run.report.throughput_per_mcycle(), 0.0);

        let bad = ServeConfig::new(vec!["resnet9000".to_string()]);
        assert!(serve(&svc, &bad).is_err());
        let none = ServeConfig::new(Vec::new());
        assert!(serve(&svc, &none).is_err());

        // Trace referencing a model outside the mix is rejected.
        let trace = ArrivalTrace {
            requests: vec![ServeRequest {
                id: 0,
                model: 7,
                arrival: 0,
                seed: 1,
            }],
        };
        assert!(serve_trace(&svc, &cfg, &trace).is_err());
        // The legacy engine rejects the same inputs.
        let mut lcfg = cfg.clone();
        lcfg.engine = ServeEngine::Legacy;
        assert!(serve_trace(&svc, &lcfg, &trace).is_err());
    }

    #[test]
    fn shrinking_produces_valid_smaller_traces() {
        let mut cfg = cfg_of("ffn");
        cfg.requests = 6;
        let trace = gen_arrivals(&cfg);
        let shrinks = trace.shrinks();
        assert!(!shrinks.is_empty());
        let svc = analytic();
        for s in shrinks.iter().take(6) {
            assert!(s.requests.len() <= trace.requests.len());
            // Every shrunk trace must still serve cleanly.
            let run = serve_trace(&svc, &cfg, s).unwrap();
            assert_eq!(run.report.completed, s.requests.len());
        }
        // Request-level shrinking lowers arrivals toward 0.
        let r = ServeRequest { id: 0, model: 1, arrival: 100, seed: 9 };
        assert!(r
            .shrinks()
            .iter()
            .all(|s| s.arrival <= r.arrival && s.model <= r.model));
    }

    #[test]
    fn isolated_latency_matches_solo_fifo_run() {
        let svc = analytic();
        let mut cfg = cfg_of("qkv");
        cfg.policy = Policy::Fifo;
        cfg.requests = 1;
        let iso = isolated_latency(&svc, &cfg, 0).unwrap();
        let run = serve(&svc, &cfg).unwrap();
        assert_eq!(iso, run.report.latency.max());
        assert!(iso > 0);
    }

    #[test]
    fn engine_names_round_trip() {
        for e in [ServeEngine::Event, ServeEngine::Legacy] {
            assert_eq!(ServeEngine::from_name(e.name()), Some(e));
        }
        assert_eq!(ServeEngine::from_name("waveish"), None);
        assert_eq!(
            ServeConfig::new(vec!["ffn".into()]).engine,
            ServeEngine::Event
        );
    }

    #[test]
    fn event_and_legacy_engines_agree_on_a_smoke_trace() {
        // Quick targeted differential (the shrinkable property lives
        // in tests/serve.rs): mixed models, bursts, sharding, and a
        // derived SLO all on — report and rows must be bit-identical.
        let mut cfg = ServeConfig::new(vec![
            "ffn".to_string(),
            "qkv".to_string(),
        ]);
        cfg.clusters = 2;
        cfg.requests = 8;
        cfg.rate_per_mcycle = 30.0;
        cfg.burst = 0.4;
        cfg.seed = 0x5EED;
        cfg.slo = None; // exercise the probe accounting too
        for policy in [Policy::Fifo, Policy::Continuous] {
            cfg.policy = policy;
            cfg.engine = ServeEngine::Event;
            let ev = serve(&analytic(), &cfg).unwrap();
            cfg.engine = ServeEngine::Legacy;
            let lg = serve(&analytic(), &cfg).unwrap();
            assert_eq!(ev.report, lg.report, "{policy:?} report");
            assert_eq!(ev.rows, lg.rows, "{policy:?} rows");
            assert_eq!(ev.models, lg.models);
            assert!(ev.engine_stats.events > 0);
            assert!(
                ev.engine_stats.memo_hits > 0,
                "repeated shapes must hit the dispatch memo"
            );
        }
    }

    #[test]
    fn event_dispatch_memo_first_touches_are_exact_under_threads() {
        // Satellite regression (extends PR 4's exact-miss accounting
        // to the shape memo): 16 simultaneous ffn requests on 8 host
        // threads. ffn has exactly two distinct GEMM shapes, so the
        // memo must record exactly 2 misses — the parallel fresh
        // batch races the plan cache, but the deduplicated dispatch
        // path makes the counters exact at any thread count.
        let svc = analytic();
        let mut cfg = cfg_of("ffn");
        cfg.requests = 16;
        cfg.burst = 1.0; // all arrive on cycle 0: maximal wave width
        cfg.threads = 8;
        let run = serve(&svc, &cfg).unwrap();
        let es = run.engine_stats;
        assert_eq!(es.memo_misses, 2, "{es:?}");
        assert_eq!(
            es.memo_hits + es.memo_misses,
            run.report.gemm_ops,
            "{es:?}"
        );
        let s = run.report.plan_stats;
        assert_eq!(s.plan_hits + s.plan_misses, run.report.gemm_ops);
        assert_eq!(s.plan_misses, 2, "{s:?}");
        // Warm service, fresh run memo: dispatch first touches now
        // land on cached plans — zero plan misses, same memo shape.
        let again = serve(&svc, &cfg).unwrap();
        assert_eq!(again.engine_stats.memo_misses, 2);
        assert_eq!(again.report.plan_stats.plan_misses, 0);
        assert_eq!(
            again.report.plan_stats.plan_hits,
            again.report.gemm_ops
        );
    }
}
