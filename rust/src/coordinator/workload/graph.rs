//! NetGraph — the multi-layer network IR.
//!
//! A [`NetGraph`] is a DAG of named tensors and ops. The op set is the
//! GEMM-centric slice real ML inference needs on this cluster:
//!
//! * [`NetOp::Gemm`] — `out = act(x * w [+ bias])` with the bias add
//!   and activation *fused into the kernel's writeback pass*
//!   (`kernels::Epilogue`), so layer outputs never round-trip through
//!   memory between the matmul and its elementwise tail;
//! * [`NetOp::Add`] — residual addition of two same-shape tensors
//!   (the skip connections of transformer blocks). Executed as an
//!   elementwise pass by the scheduler.
//!
//! Shape inference runs at construction: `gemm`/`add` validate operand
//! shapes immediately and allocate the output tensor, so an assembled
//! graph is well-formed by construction and `ops` is topologically
//! sorted (an op can only reference tensors that already exist). The
//! DAG *scheduler* (`coordinator::net`) still re-derives readiness
//! from the dependency structure — the property tests shuffle
//! execution order to prove it.

use anyhow::{ensure, Result};

use crate::kernels::Epilogue;

use super::Problem;

/// Index into [`NetGraph::tensors`].
pub type TensorId = usize;

/// What produces a tensor's contents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TensorKind {
    /// External activation input (generated per run from the seed).
    Input,
    /// Constant parameter (generated once from the seed).
    Weight,
    /// Per-column bias vector (constant parameter).
    Bias,
    /// Produced by an op.
    Computed,
}

/// A named, row-major 2-D tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub kind: TensorKind,
}

impl Tensor {
    pub fn elems(&self) -> usize {
        self.rows * self.cols
    }

    pub fn bytes(&self) -> usize {
        self.elems() * 8
    }
}

/// One network-level operation.
#[derive(Clone, Debug)]
pub enum NetOp {
    /// `out = epi(x * w [+ bias])` with the epilogue fused into the
    /// GEMM kernels.
    Gemm {
        name: String,
        x: TensorId,
        w: TensorId,
        bias: Option<TensorId>,
        epi: Epilogue,
        out: TensorId,
    },
    /// `out = a + b` (residual add), elementwise.
    Add { name: String, a: TensorId, b: TensorId, out: TensorId },
}

impl NetOp {
    pub fn name(&self) -> &str {
        match self {
            NetOp::Gemm { name, .. } | NetOp::Add { name, .. } => name,
        }
    }

    pub fn out(&self) -> TensorId {
        match self {
            NetOp::Gemm { out, .. } | NetOp::Add { out, .. } => *out,
        }
    }

    /// Tensors this op reads.
    pub fn inputs(&self) -> Vec<TensorId> {
        match self {
            NetOp::Gemm { x, w, bias, .. } => {
                let mut v = vec![*x, *w];
                if let Some(b) = bias {
                    v.push(*b);
                }
                v
            }
            NetOp::Add { a, b, .. } => vec![*a, *b],
        }
    }
}

/// A multi-layer network: tensors + topologically-constructed ops.
#[derive(Clone, Debug, Default)]
pub struct NetGraph {
    pub name: String,
    pub tensors: Vec<Tensor>,
    pub ops: Vec<NetOp>,
}

impl NetGraph {
    pub fn new(name: &str) -> NetGraph {
        NetGraph { name: name.to_string(), ..NetGraph::default() }
    }

    fn push_tensor(
        &mut self,
        name: &str,
        rows: usize,
        cols: usize,
        kind: TensorKind,
    ) -> TensorId {
        self.tensors.push(Tensor {
            name: name.to_string(),
            rows,
            cols,
            kind,
        });
        self.tensors.len() - 1
    }

    /// Declare an external activation input (`rows x cols`).
    pub fn input(&mut self, name: &str, rows: usize, cols: usize)
        -> TensorId {
        self.push_tensor(name, rows, cols, TensorKind::Input)
    }

    /// Declare a weight parameter (`rows x cols`, i.e. `k x n`).
    pub fn weight(&mut self, name: &str, rows: usize, cols: usize)
        -> TensorId {
        self.push_tensor(name, rows, cols, TensorKind::Weight)
    }

    /// Declare a per-column bias vector of length `cols`.
    pub fn bias(&mut self, name: &str, cols: usize) -> TensorId {
        self.push_tensor(name, 1, cols, TensorKind::Bias)
    }

    /// Append `out = act(x * w [+ bias])`. Shape-inferred and
    /// validated; returns the output tensor.
    pub fn gemm(
        &mut self,
        name: &str,
        x: TensorId,
        w: TensorId,
        bias: Option<TensorId>,
        act: Option<crate::kernels::Activation>,
    ) -> Result<TensorId> {
        let (xt, wt) = (&self.tensors[x], &self.tensors[w]);
        ensure!(
            xt.cols == wt.rows,
            "{name}: inner dims differ ({} vs {})",
            xt.cols,
            wt.rows
        );
        let (m, n, k) = (xt.rows, wt.cols, xt.cols);
        crate::kernels::driver::check_dims(m, n, k)?;
        if let Some(b) = bias {
            let bt = &self.tensors[b];
            ensure!(
                bt.rows == 1 && bt.cols == n,
                "{name}: bias must be 1x{n}, got {}x{}",
                bt.rows,
                bt.cols
            );
            ensure!(
                bt.kind == TensorKind::Bias,
                "{name}: bias operand must be a bias tensor"
            );
        }
        let epi = Epilogue { bias: bias.is_some(), act };
        let out =
            self.push_tensor(&format!("{name}.out"), m, n,
                             TensorKind::Computed);
        self.ops.push(NetOp::Gemm {
            name: name.to_string(),
            x,
            w,
            bias,
            epi,
            out,
        });
        Ok(out)
    }

    /// Append `out = a + b` (residual add).
    pub fn add(&mut self, name: &str, a: TensorId, b: TensorId)
        -> Result<TensorId> {
        let (at, bt) = (&self.tensors[a], &self.tensors[b]);
        ensure!(
            at.rows == bt.rows && at.cols == bt.cols,
            "{name}: shape mismatch {}x{} vs {}x{}",
            at.rows,
            at.cols,
            bt.rows,
            bt.cols
        );
        let (rows, cols) = (at.rows, at.cols);
        let out = self.push_tensor(
            &format!("{name}.out"),
            rows,
            cols,
            TensorKind::Computed,
        );
        self.ops.push(NetOp::Add { name: name.to_string(), a, b, out });
        Ok(out)
    }

    /// Tensors computed by some op but consumed by none — the network
    /// outputs.
    pub fn outputs(&self) -> Vec<TensorId> {
        let mut consumed = vec![false; self.tensors.len()];
        for op in &self.ops {
            for t in op.inputs() {
                consumed[t] = true;
            }
        }
        self.ops
            .iter()
            .map(|op| op.out())
            .filter(|&t| !consumed[t])
            .collect()
    }

    /// The GEMM shapes of the network, in op order (conversion point
    /// to the single-GEMM evaluation world).
    pub fn problems(&self) -> Vec<(String, Problem)> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                NetOp::Gemm { name, x, w, .. } => {
                    let (xt, wt) = (&self.tensors[*x], &self.tensors[*w]);
                    Some((
                        name.clone(),
                        Problem { m: xt.rows, n: wt.cols, k: xt.cols },
                    ))
                }
                NetOp::Add { .. } => None,
            })
            .collect()
    }

    /// Total MACs across all GEMM ops.
    pub fn macs(&self) -> u64 {
        self.problems().iter().map(|(_, p)| p.macs()).sum()
    }

    /// The tensor-derived dependency structure: producer op per
    /// tensor, initial unmet-dependency count per op (counting
    /// multi-edges), and the dependent-op adjacency (with
    /// multiplicity). Shared by [`NetGraph::topo_order`] and the
    /// NetRunner's wave scheduler. Errors on undefined or
    /// twice-written tensors (cannot happen for builder-constructed
    /// graphs; guards hand-assembled ones).
    #[allow(clippy::type_complexity)]
    pub fn dependency_structure(
        &self,
    ) -> Result<(Vec<Option<usize>>, Vec<usize>, Vec<Vec<usize>>)> {
        let mut producer: Vec<Option<usize>> =
            vec![None; self.tensors.len()];
        for (i, op) in self.ops.iter().enumerate() {
            ensure!(
                op.out() < self.tensors.len(),
                "op {i} writes undefined tensor"
            );
            ensure!(
                producer[op.out()].is_none(),
                "tensor {} written twice",
                self.tensors[op.out()].name
            );
            producer[op.out()] = Some(i);
        }
        let mut deps: Vec<usize> = vec![0; self.ops.len()];
        let mut dependents: Vec<Vec<usize>> =
            vec![Vec::new(); self.ops.len()];
        for (i, op) in self.ops.iter().enumerate() {
            for t in op.inputs() {
                ensure!(
                    t < self.tensors.len(),
                    "op {i} reads undefined tensor"
                );
                if let Some(p) = producer[t] {
                    deps[i] += 1;
                    dependents[p].push(i);
                }
            }
        }
        Ok((producer, deps, dependents))
    }

    /// Kahn topological order over ops (indices into `ops`), derived
    /// purely from the tensor dependency structure. Errors if the
    /// graph is cyclic or references undefined tensors.
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let (_, mut deps, dependents) = self.dependency_structure()?;
        let mut ready: Vec<usize> = (0..self.ops.len())
            .filter(|&i| deps[i] == 0)
            .collect();
        let mut order = Vec::with_capacity(self.ops.len());
        while let Some(i) = ready.pop() {
            order.push(i);
            for &d in &dependents[i] {
                deps[d] -= 1;
                if deps[d] == 0 {
                    ready.push(d);
                }
            }
        }
        ensure!(
            order.len() == self.ops.len(),
            "cycle in network graph ({} of {} ops schedulable)",
            order.len(),
            self.ops.len()
        );
        Ok(order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Activation;

    fn tiny() -> NetGraph {
        let mut g = NetGraph::new("tiny");
        let x = g.input("x", 16, 32);
        let w1 = g.weight("w1", 32, 16);
        let b1 = g.bias("b1", 16);
        let h = g
            .gemm("fc1", x, w1, Some(b1), Some(Activation::Relu))
            .unwrap();
        let w2 = g.weight("w2", 16, 32);
        let y = g.gemm("fc2", h, w2, None, None).unwrap();
        let r = g.add("res", y, x).unwrap();
        let _ = r;
        g
    }

    #[test]
    fn shapes_infer_and_chain() {
        let g = tiny();
        assert_eq!(g.ops.len(), 3);
        let probs = g.problems();
        assert_eq!(probs.len(), 2);
        assert_eq!(probs[0].1, Problem { m: 16, n: 16, k: 32 });
        assert_eq!(probs[1].1, Problem { m: 16, n: 32, k: 16 });
        assert_eq!(g.outputs().len(), 1, "single network output");
        assert_eq!(g.macs(), (16 * 16 * 32 + 16 * 32 * 16) as u64);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut g = NetGraph::new("bad");
        let x = g.input("x", 16, 32);
        let w = g.weight("w", 16, 16); // inner dim mismatch
        assert!(g.gemm("fc", x, w, None, None).is_err());
        // off-grid dims rejected too
        let x2 = g.input("x2", 12, 32);
        let w2 = g.weight("w2", 32, 16);
        assert!(g.gemm("fc2", x2, w2, None, None).is_err());
        // bias length must match n
        let x3 = g.input("x3", 16, 32);
        let w3 = g.weight("w3", 32, 16);
        let b = g.bias("b", 8);
        assert!(g.gemm("fc3", x3, w3, Some(b), None).is_err());
    }

    #[test]
    fn topo_order_respects_dependencies() {
        let g = tiny();
        let order = g.topo_order().unwrap();
        assert_eq!(order.len(), 3);
        let pos =
            |i: usize| order.iter().position(|&x| x == i).unwrap();
        // fc2 consumes fc1's output; res consumes fc2's.
        assert!(pos(0) < pos(1));
        assert!(pos(1) < pos(2));
    }

    #[test]
    fn epilogue_fused_into_gemm_op() {
        let g = tiny();
        let NetOp::Gemm { epi, .. } = &g.ops[0] else {
            panic!("first op is a gemm");
        };
        assert!(epi.bias);
        assert_eq!(epi.act, Some(Activation::Relu));
    }
}
