//! Workload generation — the paper's evaluation methodology (§IV-B):
//! 50 problem sizes with M, N, K drawn uniformly from
//! {8, 16, 24, ..., 128} — plus the network-level workload layer:
//! [`graph`] (the NetGraph multi-layer IR) and [`zoo`] (ready-made
//! models: MLP, transformer FFN / QKV blocks, conv-as-GEMM).

pub mod graph;
pub mod zoo;

pub use graph::{NetGraph, NetOp, Tensor, TensorId};

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Problem {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Problem {
    pub fn macs(&self) -> u64 {
        (self.m * self.n * self.k) as u64
    }
}

impl std::fmt::Display for Problem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.m, self.n, self.k)
    }
}

/// The paper's dimension grid.
pub fn dim_grid() -> Vec<usize> {
    (1..=16).map(|i| i * 8).collect()
}

/// Sample `count` problems with the paper's distribution.
pub fn sample_problems(count: usize, seed: u64) -> Vec<Problem> {
    let grid = dim_grid();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| Problem {
            m: *rng.choice(&grid),
            n: *rng.choice(&grid),
            k: *rng.choice(&grid),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_8_to_128() {
        let g = dim_grid();
        assert_eq!(g.first(), Some(&8));
        assert_eq!(g.last(), Some(&128));
        assert_eq!(g.len(), 16);
    }

    #[test]
    fn sampling_deterministic_and_on_grid() {
        let a = sample_problems(50, 42);
        let b = sample_problems(50, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 50);
        for p in &a {
            assert!(p.m % 8 == 0 && p.m >= 8 && p.m <= 128);
            assert!(p.n % 8 == 0 && p.n >= 8 && p.n <= 128);
            assert!(p.k % 8 == 0 && p.k >= 8 && p.k <= 128);
        }
        // different seeds differ
        let c = sample_problems(50, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn samples_cover_the_range() {
        let ps = sample_problems(200, 7);
        let small = ps.iter().filter(|p| p.m <= 32).count();
        let large = ps.iter().filter(|p| p.m >= 96).count();
        assert!(small > 20 && large > 20, "uniformity check");
    }
}
