//! Model zoo — ready-made [`NetGraph`]s for the workloads the paper's
//! introduction motivates, replacing the old hard-coded
//! `llm_problems()` list.
//!
//! All dimensions are multiples of 8 (the cluster's evaluation grid).
//! Attention score/context products (`softmax(QK^T)V`) are not GEMM
//! ops in this IR; transformer blocks model the *projection* GEMMs
//! (QKV, attention output, MLP) and take the attention-weighted values
//! as a second external input.

use anyhow::{bail, Result};

use crate::kernels::Activation;

use super::graph::NetGraph;
use super::Problem;

/// Names accepted by [`build`].
pub fn models() -> [&'static str; 6] {
    ["mlp", "ffn", "qkv", "attn", "conv", "llm"]
}

/// Build a zoo model by name with its canonical dimensions.
pub fn build(name: &str) -> Result<NetGraph> {
    Ok(match name {
        "mlp" => mlp(32, &[64, 128, 64, 32])?,
        "ffn" => transformer_ffn(64, 64, 128)?,
        "qkv" => qkv_projection(64, 64)?,
        "attn" => attention_block(64, 64)?,
        "conv" => conv3x3(16, 16, 8, 32)?,
        "llm" => transformer_layer()?,
        other => bail!(
            "unknown model `{other}` (choose from {})",
            models().join("|")
        ),
    })
}

/// Fully-connected MLP: `dims[0] -> dims[1] -> ...`, bias + ReLU on
/// every layer except the last (bias only).
pub fn mlp(batch: usize, dims: &[usize]) -> Result<NetGraph> {
    anyhow::ensure!(dims.len() >= 2, "mlp needs at least one layer");
    let mut g = NetGraph::new("mlp");
    let mut x = g.input("x", batch, dims[0]);
    for (i, win) in dims.windows(2).enumerate() {
        let last = i + 2 == dims.len();
        let w = g.weight(&format!("w{i}"), win[0], win[1]);
        let b = g.bias(&format!("b{i}"), win[1]);
        let act = if last { None } else { Some(Activation::Relu) };
        x = g.gemm(&format!("fc{i}"), x, w, Some(b), act)?;
    }
    Ok(g)
}

/// Transformer feed-forward block: up-projection with fused bias+GeLU,
/// down-projection with fused bias, residual add.
pub fn transformer_ffn(
    tokens: usize,
    d_model: usize,
    d_ff: usize,
) -> Result<NetGraph> {
    let mut g = NetGraph::new("ffn");
    let x = g.input("x", tokens, d_model);
    let w1 = g.weight("w_up", d_model, d_ff);
    let b1 = g.bias("b_up", d_ff);
    let h = g.gemm("mlp_up", x, w1, Some(b1), Some(Activation::Gelu))?;
    let w2 = g.weight("w_down", d_ff, d_model);
    let b2 = g.bias("b_down", d_model);
    let y = g.gemm("mlp_down", h, w2, Some(b2), None)?;
    g.add("residual", y, x)?;
    Ok(g)
}

/// Fused QKV projection: one `d_model x 3*d_model` GEMM.
pub fn qkv_projection(tokens: usize, d_model: usize) -> Result<NetGraph> {
    let mut g = NetGraph::new("qkv");
    let x = g.input("x", tokens, d_model);
    let w = g.weight("w_qkv", d_model, 3 * d_model);
    let b = g.bias("b_qkv", 3 * d_model);
    g.gemm("qkv_proj", x, w, Some(b), None)?;
    Ok(g)
}

/// Attention projection block: QKV projection + output projection of
/// the attention-weighted values (external input) + residual.
pub fn attention_block(tokens: usize, d_model: usize) -> Result<NetGraph> {
    let mut g = NetGraph::new("attn");
    let x = g.input("x", tokens, d_model);
    let wq = g.weight("w_qkv", d_model, 3 * d_model);
    let bq = g.bias("b_qkv", 3 * d_model);
    g.gemm("qkv_proj", x, wq, Some(bq), None)?;
    // softmax(QK^T)V happens outside the GEMM IR
    let av = g.input("attn_values", tokens, d_model);
    let wo = g.weight("w_out", d_model, d_model);
    let bo = g.bias("b_out", d_model);
    let o = g.gemm("attn_out", av, wo, Some(bo), None)?;
    g.add("residual", o, x)?;
    Ok(g)
}

/// Dimensions of a conv layer lowered to GEMM via im2col: each output
/// pixel's receptive field becomes a row of the `M x K` patch matrix
/// (`M = out_h*out_w`, `K = kh*kw*cin`), the filter bank the `K x N`
/// weight (`N = cout`). Dims round up to the cluster's 8-grid.
pub fn conv_as_gemm_dims(
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    kh: usize,
    kw: usize,
) -> Problem {
    let r8 = |x: usize| x.div_ceil(8) * 8;
    Problem {
        m: r8(h * w), // same-padded output map
        k: r8(kh * kw * cin),
        n: r8(cout),
    }
}

/// 3x3 same-padded conv + bias + ReLU as an im2col GEMM.
pub fn conv3x3(
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
) -> Result<NetGraph> {
    let p = conv_as_gemm_dims(h, w, cin, cout, 3, 3);
    let mut g = NetGraph::new("conv");
    let x = g.input("im2col_patches", p.m, p.k);
    let wt = g.weight("filters", p.k, p.n);
    let b = g.bias("b", p.n);
    g.gemm("conv3x3", x, wt, Some(b), Some(Activation::Relu))?;
    Ok(g)
}

/// One full transformer layer's projection GEMMs — the model the old
/// `llm_problems()` list approximated (same shapes, now with real
/// dataflow, fused epilogues, and residuals): 128 tokens, d_model 64,
/// 3x32 QKV heads, d_ff 128.
pub fn transformer_layer() -> Result<NetGraph> {
    let (tokens, d_model, d_qkv, d_ff) = (128, 64, 96, 128);
    let mut g = NetGraph::new("llm");
    let x = g.input("x", tokens, d_model);
    let wq = g.weight("w_qkv", d_model, d_qkv);
    let bq = g.bias("b_qkv", d_qkv);
    g.gemm("qkv_proj", x, wq, Some(bq), None)?;
    let av = g.input("attn_values", tokens, d_model);
    let wo = g.weight("w_out", d_model, d_model);
    let bo = g.bias("b_out", d_model);
    let o = g.gemm("attn_out", av, wo, Some(bo), None)?;
    let h = g.add("attn_residual", o, x)?;
    let w1 = g.weight("w_up", d_model, d_ff);
    let b1 = g.bias("b_up", d_ff);
    let up = g.gemm("mlp_up", h, w1, Some(b1), Some(Activation::Gelu))?;
    let w2 = g.weight("w_down", d_ff, d_model);
    let b2 = g.bias("b_down", d_model);
    let down = g.gemm("mlp_down", up, w2, Some(b2), None)?;
    g.add("mlp_residual", down, h)?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_zoo_models_build_and_validate() {
        for name in models() {
            let g = build(name).unwrap();
            assert!(!g.ops.is_empty(), "{name}: empty graph");
            assert!(!g.problems().is_empty(), "{name}: no GEMMs");
            g.topo_order().unwrap();
            for (_, p) in g.problems() {
                assert!(p.m % 8 == 0 && p.n % 8 == 0 && p.k % 8 == 0);
            }
        }
        assert!(build("bogus").is_err());
    }

    #[test]
    fn llm_model_matches_historic_projection_shapes() {
        // The shapes the old hard-coded llm_problems() list carried.
        let g = transformer_layer().unwrap();
        let probs = g.problems();
        let get = |n: &str| {
            probs.iter().find(|(name, _)| name == n).unwrap().1
        };
        assert_eq!(get("qkv_proj"), Problem { m: 128, n: 96, k: 64 });
        assert_eq!(get("attn_out"), Problem { m: 128, n: 64, k: 64 });
        assert_eq!(get("mlp_up"), Problem { m: 128, n: 128, k: 64 });
        assert_eq!(get("mlp_down"), Problem { m: 128, n: 64, k: 128 });
    }

    #[test]
    fn conv_lowering_rounds_to_grid() {
        let p = conv_as_gemm_dims(16, 16, 8, 32, 3, 3);
        assert_eq!(p.m, 256);
        assert_eq!(p.k, 72); // 3*3*8 = 72, already on-grid
        assert_eq!(p.n, 32);
        let p2 = conv_as_gemm_dims(5, 5, 3, 10, 3, 3);
        assert_eq!(p2.m, 32); // 25 -> 32
        assert_eq!(p2.k, 32); // 27 -> 32
        assert_eq!(p2.n, 16); // 10 -> 16
    }

    #[test]
    fn ffn_fuses_everything() {
        let g = transformer_ffn(64, 64, 128).unwrap();
        use crate::coordinator::workload::NetOp;
        let fused = g
            .ops
            .iter()
            .filter(|op| {
                matches!(op, NetOp::Gemm { epi, .. } if !epi.is_none())
            })
            .count();
        assert_eq!(fused, 2, "both projections carry fused epilogues");
    }
}
