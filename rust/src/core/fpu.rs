//! The Snitch FPU: a fully-pipelined 64-bit FP datapath with a register
//! file, a scoreboard, and SSR register interception on ft0/ft1/ft2.
//!
//! Timing model: every FP compute op has a fixed pipeline latency
//! (default 3 cycles, Snitch's FPU depth for FP64 FMA) and the unit
//! accepts one op per cycle.  Results write back to the FP register
//! file, or — when the destination is ft2 and SSRs are enabled — into
//! the SSR-2 write streamer's FIFO (handled by the core, which reserves
//! write-FIFO credit at issue so the writeback can never block).
//!
//! Numerics are real: `fmadd.d` uses `f64::mul_add` (fused, like the
//! RTL FPU), so the simulated cluster produces actual matrices that the
//! PJRT golden model checks end-to-end.

use crate::isa::Instr;

#[derive(Clone, Copy, Debug)]
pub struct FpuConfig {
    /// Pipeline latency of FMA-class ops (cycles from issue to
    /// writeback).
    pub latency: u32,
    /// Maximum in-flight ops (pipeline depth; issue stalls beyond).
    pub depth: usize,
}

impl Default for FpuConfig {
    fn default() -> Self {
        Self { latency: 3, depth: 8 }
    }
}

/// One in-flight operation.
#[derive(Clone, Copy, Debug)]
struct InFlight {
    done_at: u64,
    dest: u8,
    value: f64,
    /// Writeback goes to the SSR write stream instead of the RF.
    to_ssr: bool,
}

/// A completed writeback the core must commit this cycle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Writeback {
    pub dest: u8,
    pub value: f64,
    pub to_ssr: bool,
}

pub struct Fpu {
    cfg: FpuConfig,
    pub regs: [f64; 32],
    /// Scoreboard: in-flight writer count per FP register.
    busy: [u8; 32],
    pipe: Vec<InFlight>,
    /// Total compute ops executed (the utilization numerator).
    pub ops_issued: u64,
}

impl Fpu {
    pub fn new(cfg: FpuConfig) -> Self {
        Self {
            cfg,
            regs: [0.0; 32],
            busy: [0; 32],
            pipe: Vec::with_capacity(cfg.depth),
            ops_issued: 0,
        }
    }

    /// Pipeline has a free slot?
    #[inline(always)]
    pub fn can_issue(&self) -> bool {
        self.pipe.len() < self.cfg.depth
    }

    /// Is `reg` pending a writeback (RAW/WAW hazard)?
    #[inline(always)]
    pub fn reg_busy(&self, reg: u8) -> bool {
        self.busy[reg as usize] > 0
    }

    pub fn idle(&self) -> bool {
        self.pipe.is_empty()
    }

    /// Issue one FP compute op. `ssr_vals` provides the operand values
    /// for sources intercepted by SSR streams, *by source slot*
    /// (frs1/frs2/frs3 order, matching `Instr::fp_sources`), already
    /// popped by the caller; `None` slots read the RF.  `now` is the
    /// current cycle. Returns false if the op could not issue (pipeline
    /// full) — the caller must retry.
    pub fn issue(
        &mut self,
        i: &Instr,
        ssr_vals: &[Option<f64>; 3],
        ssr_write_dest: bool,
        now: u64,
    ) -> bool {
        if !self.can_issue() {
            return false;
        }
        let rd = |slot: usize, r: u8| -> f64 {
            ssr_vals[slot].unwrap_or(self.regs[r as usize])
        };
        let (dest, value) = match *i {
            Instr::FmaddD { frd, frs1, frs2, frs3 } => {
                let a = rd(0, frs1);
                let b = rd(1, frs2);
                let c = rd(2, frs3);
                (frd, a.mul_add(b, c))
            }
            Instr::FmulD { frd, frs1, frs2 } => {
                (frd, rd(0, frs1) * rd(1, frs2))
            }
            Instr::FaddD { frd, frs1, frs2 } => {
                (frd, rd(0, frs1) + rd(1, frs2))
            }
            Instr::FsubD { frd, frs1, frs2 } => {
                (frd, rd(0, frs1) - rd(1, frs2))
            }
            Instr::FmaxD { frd, frs1, frs2 } => {
                (frd, rd(0, frs1).max(rd(1, frs2)))
            }
            Instr::FsgnjD { frd, frs1, frs2 } => {
                (frd, rd(0, frs1).copysign(rd(1, frs2)))
            }
            Instr::FgeluD { frd, frs1 } => {
                (frd, crate::isa::gelu(rd(0, frs1)))
            }
            ref other => panic!("not an FPU compute op: {other:?}"),
        };
        self.pipe.push(InFlight {
            done_at: now + self.cfg.latency as u64,
            dest,
            value,
            to_ssr: ssr_write_dest,
        });
        if !ssr_write_dest {
            self.busy[dest as usize] += 1;
        }
        self.ops_issued += 1;
        true
    }

    /// Issue with a pre-resolved result value (the core's fast path
    /// computes operands inline). Same pipeline/scoreboard behaviour
    /// as [`Fpu::issue`].
    #[inline(always)]
    pub fn issue_resolved(
        &mut self,
        dest: u8,
        value: f64,
        ssr_write_dest: bool,
        now: u64,
    ) -> bool {
        if !self.can_issue() {
            return false;
        }
        self.pipe.push(InFlight {
            done_at: now + self.cfg.latency as u64,
            dest,
            value,
            to_ssr: ssr_write_dest,
        });
        if !ssr_write_dest {
            self.busy[dest as usize] += 1;
        }
        self.ops_issued += 1;
        true
    }

    /// Direct register write (fld data return, fcvt, fmv.d.x).
    pub fn write_reg(&mut self, reg: u8, value: f64) {
        self.regs[reg as usize] = value;
    }

    /// Mark a register busy (e.g. an fld in flight).
    pub fn mark_busy(&mut self, reg: u8) {
        self.busy[reg as usize] += 1;
    }

    pub fn clear_busy(&mut self, reg: u8) {
        debug_assert!(self.busy[reg as usize] > 0);
        self.busy[reg as usize] -= 1;
    }

    /// Advance to cycle `now`: commit all writebacks due. Returns the
    /// SSR-bound writebacks (RF writebacks are applied internally).
    pub fn tick(&mut self, now: u64, ssr_out: &mut Vec<Writeback>) {
        let mut i = 0;
        while i < self.pipe.len() {
            if self.pipe[i].done_at <= now {
                let f = self.pipe.swap_remove(i);
                if f.to_ssr {
                    ssr_out.push(Writeback {
                        dest: f.dest,
                        value: f.value,
                        to_ssr: true,
                    });
                } else {
                    self.regs[f.dest as usize] = f.value;
                    self.busy[f.dest as usize] -= 1;
                }
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fpu() -> Fpu {
        Fpu::new(FpuConfig::default())
    }

    const NO_SSR: [Option<f64>; 3] = [None, None, None];

    #[test]
    fn fmadd_is_fused() {
        let mut f = fpu();
        f.regs[4] = 3.0;
        f.regs[5] = 4.0;
        f.regs[6] = 0.5;
        let i = Instr::FmaddD { frd: 7, frs1: 4, frs2: 5, frs3: 6 };
        assert!(f.issue(&i, &NO_SSR, false, 0));
        assert!(f.reg_busy(7));
        let mut out = Vec::new();
        f.tick(3, &mut out);
        assert!(out.is_empty());
        assert_eq!(f.regs[7], 3.0f64.mul_add(4.0, 0.5));
        assert!(!f.reg_busy(7));
    }

    #[test]
    fn latency_respected() {
        let mut f = fpu();
        f.regs[4] = 1.0;
        f.regs[5] = 2.0;
        let i = Instr::FmulD { frd: 8, frs1: 4, frs2: 5 };
        assert!(f.issue(&i, &NO_SSR, false, 10));
        let mut out = Vec::new();
        f.tick(12, &mut out); // latency 3: not ready at cycle 12
        assert!(f.reg_busy(8));
        f.tick(13, &mut out);
        assert!(!f.reg_busy(8));
        assert_eq!(f.regs[8], 2.0);
    }

    #[test]
    fn ssr_operand_interception() {
        let mut f = fpu();
        f.regs[0] = 99.0; // must be ignored: SSR provides f0
        let i = Instr::FmulD { frd: 9, frs1: 0, frs2: 1 };
        let vals = [Some(6.0), Some(7.0), None];
        assert!(f.issue(&i, &vals, false, 0));
        let mut out = Vec::new();
        f.tick(3, &mut out);
        assert_eq!(f.regs[9], 42.0);
    }

    #[test]
    fn ssr_writeback_routed_out() {
        let mut f = fpu();
        f.regs[4] = 2.0;
        f.regs[5] = 3.0;
        let i = Instr::FmulD { frd: 2, frs1: 4, frs2: 5 };
        assert!(f.issue(&i, &NO_SSR, true, 0));
        // Destination is the SSR write stream: f2 itself is NOT busy.
        assert!(!f.reg_busy(2));
        let mut out = Vec::new();
        f.tick(3, &mut out);
        assert_eq!(
            out,
            vec![Writeback { dest: 2, value: 6.0, to_ssr: true }]
        );
        assert_eq!(f.regs[2], 0.0, "RF untouched");
    }

    #[test]
    fn pipeline_fills_and_drains() {
        let mut f = Fpu::new(FpuConfig { latency: 3, depth: 3 });
        let i = Instr::FaddD { frd: 10, frs1: 11, frs2: 12 };
        assert!(f.issue(&i, &NO_SSR, false, 0));
        assert!(f.issue(&i, &NO_SSR, false, 1));
        assert!(f.issue(&i, &NO_SSR, false, 2));
        assert!(!f.can_issue());
        let mut out = Vec::new();
        f.tick(3, &mut out);
        assert!(f.can_issue());
        f.tick(5, &mut out);
        assert!(f.idle());
        assert_eq!(f.ops_issued, 3);
    }

    #[test]
    fn waw_counting() {
        let mut f = fpu();
        let i = Instr::FaddD { frd: 10, frs1: 11, frs2: 12 };
        f.issue(&i, &NO_SSR, false, 0);
        f.issue(&i, &NO_SSR, false, 1);
        assert!(f.reg_busy(10));
        let mut out = Vec::new();
        f.tick(3, &mut out);
        assert!(f.reg_busy(10), "second writer still in flight");
        f.tick(4, &mut out);
        assert!(!f.reg_busy(10));
    }
}
