//! The Snitch core model: integer frontend, FP subsystem (FREP
//! sequencer + FPU), and the per-core perf counters.

pub mod fpu;
pub mod sequencer;
pub mod snitch;

pub use sequencer::{SeqConfig, Sequencer};
pub use snitch::{Core, CoreConfig, CorePerf};
