//! The FREP sequencer — the paper's §III-A contribution (Fig. 2).
//!
//! Instructions offloaded from the Snitch frontend are partially decoded
//! and binned:
//!
//! 1. **FREPs** are fully decoded into a loop config (`frep_cfg`) and
//!    forwarded to the *nest controller* (never stored in the ring
//!    buffer).
//! 2. **FP compute instructions** enter the ring buffer (RB) and can be
//!    re-issued if they fall inside an FREP body.
//! 3. Instructions with integer-RF operands bypass the sequencer (the
//!    core model routes those through the LSU directly).
//!
//! The nest controller dynamically constructs a loop nest from incoming
//! FREP instructions: a FREP whose body fits inside the currently
//! innermost active loop's window nests one level deeper (up to the
//! design-time `max_nest_depth`, the paper's `N`).  Loops may share
//! start and/or end instructions; entry/exit of *multiple* loops on one
//! instruction is resolved in a single cycle (the paper's
//! starting/ending-loops detectors built on leading/trailing-zero
//! counters) so the sequencer sustains one instruction per cycle on
//! both perfectly and imperfectly nested loops.
//!
//! Two design-time switches model the two generations of hardware:
//!
//! * `max_nest_depth = 1`, `block_offload_during_loop = true` — the
//!   baseline Zaruba-style FREP [3]: a single loop controller; while a
//!   loop is active the offload path is blocked, so post-loop
//!   instructions issue in lock-step with the frontend and the outer
//!   loop's management instructions create real FPU bubbles (the
//!   "2 instructions per iteration" overhead of §III-A).
//! * `max_nest_depth = N > 1`, `block_offload_during_loop = false` —
//!   the proposed zero-overhead loop-nest sequencer.

use crate::isa::Instr;

/// Design-time sequencer parameters.
#[derive(Clone, Copy, Debug)]
pub struct SeqConfig {
    /// Ring-buffer depth in instructions.
    pub rb_depth: usize,
    /// Maximum loop-nest depth (the paper's `N`). 1 = baseline FREP.
    pub max_nest_depth: usize,
    /// Baseline behaviour: refuse new offloads while a loop is active
    /// (except the active loop's own body still streaming in).
    pub block_offload_during_loop: bool,
}

impl SeqConfig {
    /// Baseline Zaruba-style FREP (Base32fc).
    pub fn baseline() -> Self {
        Self {
            rb_depth: 16,
            max_nest_depth: 1,
            block_offload_during_loop: true,
        }
    }

    /// Zero-overhead loop nest (Zonl* configurations).
    pub fn zonl() -> Self {
        Self {
            rb_depth: 32,
            max_nest_depth: 4,
            block_offload_during_loop: false,
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct LoopCfg {
    /// Sequence number of the loop's first body instruction.
    base: u64,
    /// Number of RB-resident instructions in the body.
    n_inst: u32,
    /// Total iterations.
    n_iter: u32,
    /// Current iteration (0-based).
    iter: u32,
}

impl LoopCfg {
    fn end(&self) -> u64 {
        self.base + self.n_inst as u64
    }

    fn last_iter(&self) -> bool {
        self.iter + 1 == self.n_iter
    }
}

/// Issue-side event summary for one `advance()` call (perf counters).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IssueInfo {
    /// Instruction came from RB replay (vs freshly streamed-in).
    pub replayed: bool,
}

pub struct Sequencer {
    cfg: SeqConfig,
    /// Ring buffer, indexed by `seq % rb_depth`.
    rb: Vec<Instr>,
    /// Next sequence number to be written.
    wseq: u64,
    /// Next sequence number to be issued (the paper's `rb_raddr`).
    raddr: u64,
    /// Oldest retained sequence number (RB tail).
    tail: u64,
    /// Active loop nest, outermost first (the paper's `cfg[N]` +
    /// loop controllers).
    nest: Vec<LoopCfg>,
    /// Sequence numbers < `first_pass` have been issued at least once.
    first_pass: u64,
}

impl Sequencer {
    pub fn new(cfg: SeqConfig) -> Self {
        assert!(cfg.rb_depth >= 4);
        assert!(
            cfg.rb_depth.is_power_of_two(),
            "rb_depth must be a power of two (index masking)"
        );
        assert!((1..=8).contains(&cfg.max_nest_depth));
        Self {
            rb: vec![Instr::Nop; cfg.rb_depth],
            cfg,
            wseq: 0,
            raddr: 0,
            tail: 0,
            nest: Vec::with_capacity(cfg.max_nest_depth),
            first_pass: 0,
        }
    }

    /// Number of live RB entries (public: StallScope's Chrome trace
    /// samples it as a counter track at every stall-class transition,
    /// which makes frontend-starvation vs backpressure visible).
    pub fn occupancy(&self) -> usize {
        (self.wseq - self.tail) as usize
    }

    fn rb_full(&self) -> bool {
        self.occupancy() >= self.cfg.rb_depth
    }

    /// Is the nest currently executing (configured and not finished)?
    pub fn loop_active(&self) -> bool {
        !self.nest.is_empty()
    }

    /// Anything left to issue?
    pub fn busy(&self) -> bool {
        self.raddr < self.wseq || self.loop_active()
    }

    /// In blocking (baseline) mode, offloads are refused while a loop is
    /// active, *except* the body of the active loop itself, which is
    /// still streaming in on its first pass.
    fn offload_blocked(&self) -> bool {
        if !self.cfg.block_offload_during_loop {
            return false;
        }
        match self.nest.last() {
            Some(l) => self.wseq >= l.end(),
            None => false,
        }
    }

    /// Can the frontend push an FP compute instruction this cycle?
    pub fn can_accept_fp(&self) -> bool {
        !self.rb_full() && !self.offload_blocked()
    }

    /// Push a category-2 instruction into the RB.
    /// Returns false (and consumes nothing) if it must retry.
    pub fn push_fp(&mut self, i: Instr) -> bool {
        debug_assert!(i.is_fp_compute());
        if !self.can_accept_fp() {
            return false;
        }
        let idx = (self.wseq & (self.cfg.rb_depth as u64 - 1)) as usize;
        self.rb[idx] = i;
        self.wseq += 1;
        true
    }

    /// Can the frontend push an FREP this cycle?
    ///
    /// A new loop is accepted iff:
    /// * no nest is active (starts a fresh nest), or
    /// * the new loop's window fits inside the loops that *contain*
    ///   it (dynamic nest construction — it may be a sibling of an
    ///   earlier, already-finished inner loop) and its containment
    ///   depth stays below N — only in non-blocking (ZONL) mode.
    pub fn can_accept_frep(&self, n_inst: u32) -> bool {
        if self.nest.is_empty() {
            return true;
        }
        if self.cfg.block_offload_during_loop {
            return false; // baseline: one loop at a time
        }
        let end = self.wseq + n_inst as u64;
        // Loops whose window contains the new one (a chain, since all
        // configured windows are properly nested).
        let chain = self
            .nest
            .iter()
            .filter(|l| l.base <= self.wseq && end <= l.end())
            .count();
        if chain == 0 {
            // Entirely outside the active nest: a *sequential* loop —
            // it must wait for the nest to complete.
            return false;
        }
        chain < self.cfg.max_nest_depth
    }

    /// Push a FREP (category 1). The loop body is the next `n_inst`
    /// RB-resident instructions; `n_iter` total iterations.
    pub fn push_frep(&mut self, n_inst: u32, n_iter: u32) -> bool {
        assert!(n_inst >= 1 && n_iter >= 1, "degenerate FREP");
        if !self.can_accept_frep(n_inst) {
            return false;
        }
        self.nest.push(LoopCfg {
            base: self.wseq,
            n_inst,
            n_iter,
            iter: 0,
        });
        true
    }

    /// Peek the instruction that would issue this cycle, if any.
    pub fn peek(&self) -> Option<&Instr> {
        if self.raddr >= self.wseq {
            return None;
        }
        // The issue pointer may sit at the base of a loop whose body has
        // not fully streamed in yet — that is fine, instructions issue
        // as they arrive (first pass).
        Some(&self.rb[(self.raddr & (self.cfg.rb_depth as u64 - 1)) as usize])
    }

    /// Commit the issue of the peeked instruction and update the nest
    /// state machine (the paper's single-cycle multi-loop entry/exit
    /// resolution). Must only be called after `peek()` returned `Some`.
    pub fn advance(&mut self) -> IssueInfo {
        debug_assert!(self.raddr < self.wseq);
        let pos = self.raddr;
        let info = IssueInfo {
            replayed: pos < self.first_pass,
        };

        // --- ending-loops detection (the paper's trailing-zero-counter
        // detector, resolved in a single cycle) ------------------------
        // E = indices of loops whose window's *last* instruction is
        // `pos`. Loops not in E but nested deeper may have ended at an
        // earlier position (dormant until re-entered) and must not be
        // touched here.
        // (fixed-size scratch: nest depth is tiny and this is the
        // simulator's hot path — no allocation per issued instruction)
        let mut enders_buf = [0usize; 8];
        let mut n_enders = 0;
        for (i, l) in self.nest.iter().enumerate() {
            if l.end() == pos + 1 {
                enders_buf[n_enders] = i;
                n_enders += 1;
            }
        }
        let enders = &enders_buf[..n_enders];

        if enders.is_empty() {
            // No loop ends here: plain advance.
            self.raddr = pos + 1;
        } else {
            // Innermost ending loop with iterations left iterates first
            // (standard nest semantics): rewind to its base and restart
            // every loop strictly inside it.
            let rewind_to = enders
                .iter()
                .rev()
                .copied()
                .find(|&i| !self.nest[i].last_iter());
            match rewind_to {
                Some(i) => {
                    self.nest[i].iter += 1;
                    let base = self.nest[i].base;
                    for l in self.nest.iter_mut().skip(i + 1) {
                        l.iter = 0;
                    }
                    self.raddr = base;
                }
                None => {
                    // Every loop ending here is in its last iteration.
                    if enders[0] == 0 {
                        // The outermost loop ends: the whole nest
                        // completes (`nest_ends`).
                        self.nest.clear();
                    } else {
                        // Inner loops finished this round; they stay
                        // configured (they re-run when an enclosing
                        // loop rewinds) with their counters reset.
                        for &i in enders {
                            self.nest[i].iter = 0;
                        }
                    }
                    self.raddr = pos + 1;
                }
            }
        }

        self.first_pass = self.first_pass.max(pos + 1);
        self.retire();
        info
    }

    /// Free RB entries that can no longer be revisited.
    fn retire(&mut self) {
        let keep_from = match self.nest.first() {
            Some(outer) => outer.base.min(self.raddr),
            None => self.raddr,
        };
        self.tail = self.tail.max(keep_from);
    }

    /// Hard reset (program end / fault).
    pub fn reset(&mut self) {
        self.wseq = 0;
        self.raddr = 0;
        self.tail = 0;
        self.first_pass = 0;
        self.nest.clear();
    }

    /// Current nest depth (for traces/tests).
    pub fn nest_depth(&self) -> usize {
        self.nest.len()
    }
}

// ===================================================================
// Software oracle: expand a loop-nest program to its flat issue trace.
// Used by unit and property tests.
// ===================================================================

/// A test-side description of a sequencer program: a mix of plain
/// instructions and loop declarations over the *following* `n_inst`
/// plain instructions.
#[derive(Clone, Debug)]
pub enum NestItem {
    /// A body instruction (identified by an id carried in the fmul's
    /// register fields for traceability).
    Op(u8),
    /// frep: loop over the next `n_inst` ops, `n_iter` times.
    Loop { n_inst: u32, n_iter: u32 },
}

/// Reference expansion: what the issue trace must be.
pub fn oracle_expand(items: &[NestItem]) -> Vec<u8> {
    // Build the op list and the loop list (base = index into ops).
    let mut ops: Vec<u8> = Vec::new();
    let mut loops: Vec<(usize, u32, u32)> = Vec::new(); // (base, n, iter)
    for it in items {
        match *it {
            NestItem::Op(id) => ops.push(id),
            NestItem::Loop { n_inst, n_iter } => {
                loops.push((ops.len(), n_inst, n_iter));
            }
        }
    }

    // Recursive expansion over [lo, hi) with the loops fully inside.
    fn expand(
        ops: &[u8],
        loops: &[(usize, u32, u32)],
        lo: usize,
        hi: usize,
        out: &mut Vec<u8>,
    ) {
        // Find the first (outermost) loop starting in [lo, hi).
        let next = loops
            .iter()
            .enumerate()
            .filter(|(_, &(b, n, _))| b >= lo && b + n as usize <= hi)
            .min_by_key(|(_, &(b, n, _))| (b, usize::MAX - n as usize));
        match next {
            None => out.extend_from_slice(&ops[lo..hi]),
            Some((idx, &(b, n, iters))) => {
                // Emit the prefix before the loop.
                out.extend_from_slice(&ops[lo..b]);
                let inner: Vec<(usize, u32, u32)> = loops
                    .iter()
                    .copied()
                    .enumerate()
                    .filter(|&(i, _)| i != idx)
                    .map(|(_, l)| l)
                    .collect();
                for _ in 0..iters {
                    expand(ops, &inner, b, b + n as usize, out);
                }
                expand(&ops, &inner, b + n as usize, hi, out);
            }
        }
    }

    let mut out = Vec::new();
    let all: Vec<(usize, u32, u32)> = loops.clone();
    expand(&ops, &all, 0, ops.len(), &mut out);
    out
}

/// Drive a `Sequencer` with `items`, pushing as fast as accepted and
/// issuing one instruction per cycle; return `(trace, cycles)`.
/// `cycles` counts every cycle the FPU could have consumed an
/// instruction — so `cycles - trace.len()` is the bubble count.
pub fn run_sequencer(seq: &mut Sequencer, items: &[NestItem]) -> (Vec<u8>, u64) {
    let mut trace: Vec<u8> = Vec::new();
    let mut cycles: u64 = 0;
    let mut feed = items.iter().peekable();
    let safety = 10_000_000u64;

    loop {
        // Frontend side: push at most one item per cycle.
        match feed.peek() {
            Some(NestItem::Op(id)) => {
                let i = Instr::FmulD { frd: *id, frs1: *id, frs2: *id };
                if seq.push_fp(i) {
                    feed.next();
                }
            }
            Some(NestItem::Loop { n_inst, n_iter }) => {
                if seq.push_frep(*n_inst, *n_iter) {
                    feed.next();
                    // FREP consumes a frontend slot but no FPU slot;
                    // fall through so an RB instruction can still issue
                    // this cycle (the sequencer and frontend are
                    // decoupled).
                }
            }
            None => {}
        }

        // Issue side: one instruction per cycle if available.
        if let Some(&Instr::FmulD { frd, .. }) = seq.peek() {
            trace.push(frd);
            seq.advance();
        }

        cycles += 1;
        if feed.peek().is_none() && !seq.busy() {
            break;
        }
        assert!(cycles < safety, "sequencer livelock");
    }
    (trace, cycles)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zonl() -> Sequencer {
        Sequencer::new(SeqConfig::zonl())
    }

    #[test]
    fn plain_stream_no_loops() {
        let items: Vec<NestItem> = (0..10).map(NestItem::Op).collect();
        let (trace, _) = run_sequencer(&mut zonl(), &items);
        assert_eq!(trace, oracle_expand(&items));
    }

    #[test]
    fn single_loop_baseline_equivalence() {
        let items = vec![
            NestItem::Op(1),
            NestItem::Loop { n_inst: 3, n_iter: 4 },
            NestItem::Op(2),
            NestItem::Op(3),
            NestItem::Op(4),
            NestItem::Op(5),
        ];
        let want = oracle_expand(&items);
        let (trace, _) =
            run_sequencer(&mut Sequencer::new(SeqConfig::baseline()), &items);
        assert_eq!(trace, want);
        let (trace2, _) = run_sequencer(&mut zonl(), &items);
        assert_eq!(trace2, want);
    }

    #[test]
    fn perfect_nest_shared_start_end() {
        // Outer and inner share both start and end instructions:
        // outer(3 iters) { inner(2 iters) { a b } }
        let items = vec![
            NestItem::Loop { n_inst: 2, n_iter: 3 },
            NestItem::Loop { n_inst: 2, n_iter: 2 },
            NestItem::Op(7),
            NestItem::Op(8),
        ];
        let want = oracle_expand(&items);
        assert_eq!(want, vec![7, 8, 7, 8, 7, 8, 7, 8, 7, 8, 7, 8]);
        let (trace, _) = run_sequencer(&mut zonl(), &items);
        assert_eq!(trace, want);
    }

    #[test]
    fn imperfect_nest_matmul_shape() {
        // The ZONL matmul pass: outer { fmul x2 ; inner{ fmadd x2 } ; wb x2 }
        let items = vec![
            NestItem::Loop { n_inst: 6, n_iter: 3 }, // outer
            NestItem::Op(1),
            NestItem::Op(2),
            NestItem::Loop { n_inst: 2, n_iter: 4 }, // inner
            NestItem::Op(3),
            NestItem::Op(4),
            NestItem::Op(5),
            NestItem::Op(6),
        ];
        let want = oracle_expand(&items);
        let (trace, cycles) = run_sequencer(&mut zonl(), &items);
        assert_eq!(trace, want);
        // Zero-overhead: issue rate 1/cycle after the pipeline fills.
        // Frontend feeds 8 items; issue starts on cycle 2 at the latest.
        assert!(
            cycles <= want.len() as u64 + 3,
            "{} bubbles",
            cycles - want.len() as u64
        );
    }

    #[test]
    fn imperfect_nest_prefix_only() {
        // outer { a ; inner{ b c } } — loop ends together with inner.
        let items = vec![
            NestItem::Loop { n_inst: 3, n_iter: 2 },
            NestItem::Op(1),
            NestItem::Loop { n_inst: 2, n_iter: 3 },
            NestItem::Op(2),
            NestItem::Op(3),
        ];
        let want = oracle_expand(&items);
        assert_eq!(
            want,
            vec![1, 2, 3, 2, 3, 2, 3, 1, 2, 3, 2, 3, 2, 3]
        );
        let (trace, _) = run_sequencer(&mut zonl(), &items);
        assert_eq!(trace, want);
    }

    #[test]
    fn triple_nest() {
        let items = vec![
            NestItem::Loop { n_inst: 4, n_iter: 2 },
            NestItem::Op(1),
            NestItem::Loop { n_inst: 3, n_iter: 2 },
            NestItem::Loop { n_inst: 2, n_iter: 2 },
            NestItem::Op(2),
            NestItem::Op(3),
            NestItem::Op(4),
        ];
        let want = oracle_expand(&items);
        let (trace, _) = run_sequencer(&mut zonl(), &items);
        assert_eq!(trace, want);
    }

    #[test]
    fn sequential_loops() {
        let items = vec![
            NestItem::Loop { n_inst: 2, n_iter: 2 },
            NestItem::Op(1),
            NestItem::Op(2),
            NestItem::Loop { n_inst: 2, n_iter: 3 },
            NestItem::Op(3),
            NestItem::Op(4),
        ];
        let want = oracle_expand(&items);
        assert_eq!(want, vec![1, 2, 1, 2, 3, 4, 3, 4, 3, 4]);
        for cfg in [SeqConfig::baseline(), SeqConfig::zonl()] {
            let (trace, _) = run_sequencer(&mut Sequencer::new(cfg), &items);
            assert_eq!(trace, want);
        }
    }

    #[test]
    fn baseline_blocks_offload_during_loop() {
        let mut seq = Sequencer::new(SeqConfig::baseline());
        assert!(seq.push_frep(2, 5));
        let op = |id| Instr::FmulD { frd: id, frs1: id, frs2: id };
        assert!(seq.push_fp(op(1)));
        assert!(seq.push_fp(op(2)));
        // Body complete: further offloads must now be refused.
        assert!(!seq.can_accept_fp());
        assert!(!seq.push_fp(op(3)));
        // And a second (sequential) FREP as well.
        assert!(!seq.can_accept_frep(2));
        // Drain the loop; acceptance resumes.
        let mut n = 0;
        while seq.peek().is_some() {
            seq.advance();
            n += 1;
        }
        assert_eq!(n, 10);
        assert!(seq.can_accept_fp());
        assert!(seq.can_accept_frep(2));
    }

    #[test]
    fn zonl_accepts_runahead_during_loop() {
        let mut seq = zonl();
        assert!(seq.push_frep(2, 8));
        let op = |id| Instr::FmulD { frd: id, frs1: id, frs2: id };
        assert!(seq.push_fp(op(1)));
        assert!(seq.push_fp(op(2)));
        // Body complete; run-ahead pushes are accepted (RB space left).
        assert!(seq.can_accept_fp());
        assert!(seq.push_fp(op(3)));
    }

    #[test]
    fn nest_depth_limit_respected() {
        let mut seq = Sequencer::new(SeqConfig {
            rb_depth: 32,
            max_nest_depth: 2,
            block_offload_during_loop: false,
        });
        assert!(seq.push_frep(8, 2));
        assert!(seq.push_frep(4, 2));
        assert!(!seq.can_accept_frep(2)); // depth 2 reached
    }

    #[test]
    fn frep_outside_window_not_nested() {
        let mut seq = zonl();
        assert!(seq.push_frep(2, 2));
        let op = |id| Instr::FmulD { frd: id, frs1: id, frs2: id };
        assert!(seq.push_fp(op(1)));
        assert!(seq.push_fp(op(2)));
        // This FREP starts beyond the active loop's window: it is a
        // *sequential* loop and must wait for the nest to finish.
        assert!(!seq.can_accept_frep(2));
    }

    #[test]
    fn rb_full_blocks_push() {
        let mut seq = Sequencer::new(SeqConfig {
            rb_depth: 4,
            max_nest_depth: 2,
            block_offload_during_loop: false,
        });
        let op = |id| Instr::FmulD { frd: id, frs1: id, frs2: id };
        // A long-running loop retains its body in the RB.
        assert!(seq.push_frep(2, 100));
        assert!(seq.push_fp(op(1)));
        assert!(seq.push_fp(op(2)));
        assert!(seq.push_fp(op(3)));
        assert!(seq.push_fp(op(4)));
        assert!(!seq.push_fp(op(5)), "RB must be full");
        // Issue a few: the loop body (ops 1-2) may not be evicted.
        for _ in 0..10 {
            assert!(seq.peek().is_some());
            seq.advance();
        }
        assert!(!seq.can_accept_fp(), "loop body still retained");
    }

    #[test]
    fn single_iteration_loop_degenerates() {
        let items = vec![
            NestItem::Loop { n_inst: 2, n_iter: 1 },
            NestItem::Op(1),
            NestItem::Op(2),
            NestItem::Op(3),
        ];
        let want = oracle_expand(&items);
        assert_eq!(want, vec![1, 2, 3]);
        let (trace, _) = run_sequencer(&mut zonl(), &items);
        assert_eq!(trace, want);
    }

    #[test]
    fn oracle_imperfect_suffix() {
        // outer(2) { inner(2){ a } b } => a a b a a b
        let items = vec![
            NestItem::Loop { n_inst: 2, n_iter: 2 },
            NestItem::Loop { n_inst: 1, n_iter: 2 },
            NestItem::Op(1),
            NestItem::Op(2),
        ];
        assert_eq!(oracle_expand(&items), vec![1, 1, 2, 1, 1, 2]);
        let (trace, _) = run_sequencer(&mut zonl(), &items);
        assert_eq!(trace, oracle_expand(&items));
    }
}
