//! The Snitch core: a tiny single-issue in-order RV32 integer pipeline
//! pseudo-dual-issued with the FP subsystem (FREP sequencer + FPU +
//! SSR streamers) [3].
//!
//! Timing model (cycle-stepped, two phases driven by the cluster):
//!
//! * `fp_tick` — FPU writebacks, then at most one instruction issues
//!   from the sequencer to the FPU if its operands are ready (SSR FIFO
//!   data available, write credit reservable, no RAW on RF operands).
//! * `frontend_tick` — fetch/decode/execute one instruction: integer
//!   ops retire immediately; taken branches inject
//!   `taken_branch_penalty` fetch bubbles; FP compute ops offload to
//!   the sequencer (stalling on RB-full or baseline replay-blocking);
//!   loads/stores stall the frontend until their TCDM request wins
//!   arbitration (grant paths are driven by the cluster).
//!
//! The frontend and FP subsystem are decoupled exactly as in the RTL:
//! integer instructions execute while the sequencer replays, which is
//! what makes the baseline's outer-loop overhead visible only when the
//! sequencer blocks offloads during replay (see `sequencer.rs`).

use std::sync::Arc;

use crate::dma::DmaDesc;
use crate::isa::{csr, Instr, Program};
use crate::profile::{FpEvent, FrontPhase, N_CLASSES};
use crate::ssr::{SsrMode, Streamer};

use super::fpu::{Fpu, FpuConfig, Writeback};
use super::sequencer::{SeqConfig, Sequencer};

#[derive(Clone, Copy, Debug)]
pub struct CoreConfig {
    pub seq: SeqConfig,
    pub fpu: FpuConfig,
    /// Fetch bubbles after a taken branch (Snitch's 3-stage frontend).
    pub taken_branch_penalty: u32,
    /// Fixed latency for LSU accesses that bypass the TCDM (main mem).
    pub main_mem_latency: u32,
}

impl CoreConfig {
    pub fn baseline() -> Self {
        Self {
            seq: SeqConfig::baseline(),
            fpu: FpuConfig::default(),
            taken_branch_penalty: 2,
            main_mem_latency: 10,
        }
    }

    pub fn zonl() -> Self {
        Self { seq: SeqConfig::zonl(), ..Self::baseline() }
    }
}

/// Per-core performance counters (the stall taxonomy of DESIGN.md §5).
#[derive(Clone, Copy, Debug, Default)]
pub struct CorePerf {
    /// Active cycles: incremented once per `fp_tick` (i.e. per cycle
    /// the core was stepped before halting). The StallScope invariant
    /// `stalls.sum() == cycles` is checked against this counter.
    pub cycles: u64,
    /// StallScope attribution buckets, indexed by
    /// `profile::StallClass as usize`; the cluster classifier
    /// increments exactly one per active cycle.
    pub stalls: [u64; N_CLASSES],
    pub fpu_ops: u64,
    pub fpu_idle_no_instr: u64,
    pub stall_ssr_empty: u64,
    pub stall_wfifo: u64,
    pub stall_raw: u64,
    pub stall_fpu_full: u64,
    pub int_instrs: u64,
    pub fp_offloads: u64,
    pub offload_stalls: u64,
    pub branch_bubbles: u64,
    pub barrier_cycles: u64,
    pub lsu_stalls: u64,
    /// Frontend stalls waiting for FP-subsystem drain (fsd ordering,
    /// SSR disable).
    pub drain_stalls: u64,
    pub icache_fetches: u64,
    pub rb_replays: u64,
    pub csr_instrs: u64,
}

impl CorePerf {
    /// FPU utilization over a cycle window.
    pub fn utilization(&self, total_cycles: u64) -> f64 {
        if total_cycles == 0 {
            0.0
        } else {
            self.fpu_ops as f64 / total_cycles as f64
        }
    }
}

/// Pending LSU operation.
#[derive(Clone, Copy, Debug, PartialEq)]
enum LsuOp {
    LoadInt { rd: u8 },
    LoadFp { frd: u8 },
    StoreInt { data: u32 },
    StoreFp { data: f64 },
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum State {
    Running,
    /// Waiting for a TCDM LSU grant.
    LsuWait { addr: u32, op: LsuOp },
    /// Parked on an in-order drain point (fsd ordering / SSR disable);
    /// the instruction at `pc` re-executes once the subsystem drains.
    DrainWait,
    /// Waiting for FP-subsystem drain, then for barrier release.
    BarrierWait,
    Halted,
}

/// Frontend requests the cluster must service (DM-core DMA ops).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CoreRequest {
    None,
    DmaPush(DmaDesc),
}

pub struct Core {
    pub id: usize,
    pub cfg: CoreConfig,
    prog: Arc<Program>,
    pc: usize,
    pub iregs: [u32; 32],
    pub fpu: Fpu,
    pub seq: Sequencer,
    /// ft0/ft1 reads, ft2 write, ft3 epilogue-bias read.
    pub ssrs: [Streamer; 4],
    pub ssr_enable: bool,
    state: State,
    bubbles: u32,
    barrier_arrived: bool,
    // DMA staging registers (DM core).
    dm_src: u32,
    dm_dst: u32,
    dm_src_stride: u32,
    dm_dst_stride: u32,
    dm_reps: u32,
    dm_src_stride2: u32,
    dm_dst_stride2: u32,
    dm_reps2: u32,
    dm_txid: u32,
    pub perf: CorePerf,
    /// What the FP subsystem did this cycle — set by `fp_tick`,
    /// consumed exactly once by the cluster's StallScope classifier.
    last_fp_event: Option<FpEvent>,
    /// Cycle of the most recent LSU arbitration loss (StallScope
    /// bank-conflict attribution for frontend LSU waits).
    lsu_denied_cycle: u64,
    wb_scratch: Vec<Writeback>,
}

impl Core {
    pub fn new(id: usize, cfg: CoreConfig, prog: Arc<Program>) -> Self {
        Self {
            id,
            cfg,
            prog,
            pc: 0,
            iregs: [0; 32],
            fpu: Fpu::new(cfg.fpu),
            seq: Sequencer::new(cfg.seq),
            ssrs: [
                Streamer::new(),
                Streamer::new(),
                Streamer::new(),
                Streamer::new(),
            ],
            ssr_enable: false,
            state: State::Running,
            bubbles: 0,
            barrier_arrived: false,
            dm_src: 0,
            dm_dst: 0,
            dm_src_stride: 0,
            dm_dst_stride: 0,
            dm_reps: 1,
            dm_src_stride2: 0,
            dm_dst_stride2: 0,
            dm_reps2: 1,
            dm_txid: 0,
            perf: CorePerf::default(),
            last_fp_event: None,
            lsu_denied_cycle: u64::MAX,
            wb_scratch: Vec::with_capacity(4),
        }
    }

    /// Take this cycle's FP event (None iff the core was halted and
    /// never ticked). The classifier's one-bucket-per-cycle guarantee
    /// rests on the take: each event is attributed exactly once.
    pub fn take_fp_event(&mut self) -> Option<FpEvent> {
        self.last_fp_event.take()
    }

    /// Did any of this core's SSR streams lose TCDM arbitration on
    /// cycle `now`?
    pub fn ssr_denied_at(&self, now: u64) -> bool {
        self.ssrs.iter().any(|s| s.denied_at(now))
    }

    pub fn note_lsu_denied(&mut self, now: u64) {
        self.lsu_denied_cycle = now;
    }

    pub fn lsu_denied_at(&self, now: u64) -> bool {
        self.lsu_denied_cycle == now
    }

    /// Frontend state snapshot for stall attribution.
    fn front_phase(&self) -> FrontPhase {
        match self.state {
            State::BarrierWait => FrontPhase::Barrier,
            State::DrainWait => FrontPhase::Drain,
            State::LsuWait { .. } => FrontPhase::Lsu,
            _ => FrontPhase::Running,
        }
    }

    pub fn halted(&self) -> bool {
        self.state == State::Halted
    }

    /// The program this core executes (fast-path safety scans).
    pub fn program(&self) -> &Program {
        &self.prog
    }

    /// True when no streamer of this core can issue a TCDM request
    /// this cycle. For a core that is halted or parked at a barrier
    /// this is also *stable*: nothing pushes or pops stream FIFOs
    /// while the frontend and FP subsystem are quiet, so a quiescent
    /// parked core stays off the interconnect until released — the
    /// precondition the cluster's fast-forward region relies on.
    pub fn mem_quiescent(&self) -> bool {
        self.ssrs.iter().all(|s| match s.mode {
            SsrMode::Read => {
                !self.ssr_enable || s.read_request().is_none()
            }
            SsrMode::Write => s.write_request().is_none(),
            SsrMode::Idle => true,
        })
    }

    /// Arrived at a barrier and fully drained?
    pub fn at_barrier(&self) -> bool {
        self.state == State::BarrierWait && self.barrier_arrived
    }

    pub fn barrier_release(&mut self) {
        debug_assert!(self.at_barrier());
        self.state = State::Running;
        self.barrier_arrived = false;
    }

    fn subsystem_drained(&self) -> bool {
        self.seq.busy() == false
            && self.fpu.idle()
            && self.ssrs.iter().all(|s| s.drained())
    }

    fn ssr_read(&self, r: u8) -> bool {
        self.ssr_enable
            && (r as usize) < 4
            && self.ssrs[r as usize].mode == SsrMode::Read
    }

    fn ssr_write(&self, r: u8) -> bool {
        self.ssr_enable
            && (r as usize) < 4
            && self.ssrs[r as usize].mode == SsrMode::Write
    }

    // ============================================================
    // FP subsystem tick
    // ============================================================

    /// One FP-subsystem cycle: counts the active cycle and records the
    /// issue/stall event StallScope attributes at end of cluster step.
    pub fn fp_tick(&mut self, now: u64) {
        self.perf.cycles += 1;
        let ev = self.fp_tick_inner(now);
        self.last_fp_event = Some(ev);
    }

    fn fp_tick_inner(&mut self, now: u64) -> FpEvent {
        // 1. FPU writebacks (SSR-bound results feed the write streamer).
        self.wb_scratch.clear();
        self.fpu.tick(now, &mut self.wb_scratch);
        for i in 0..self.wb_scratch.len() {
            let wb = self.wb_scratch[i];
            self.ssrs[wb.dest as usize].push_write(wb.value);
        }

        // 2. Sequencer -> FPU issue (at most one per cycle).
        let Some(&instr) = self.seq.peek() else {
            if self.state != State::Halted {
                self.perf.fpu_idle_no_instr += 1;
            }
            return FpEvent::NoInstr(self.front_phase());
        };
        if !self.fpu.can_issue() {
            self.perf.stall_fpu_full += 1;
            return FpEvent::FpuFull;
        }

        // Fast path: fmadd/fmul (the kernel hot loop). Checks and
        // pops are hand-inlined; semantics identical to the generic
        // path below.
        match instr {
            Instr::FmaddD { frd, frs1, frs2, frs3 } => {
                let s1 = self.ssr_read(frs1);
                let s2 = self.ssr_read(frs2);
                let s3 = self.ssr_read(frs3);
                let ready = (!s1 || self.ssrs[frs1 as usize].can_pop())
                    && (!s2 || self.ssrs[frs2 as usize].can_pop())
                    && (!s3 || self.ssrs[frs3 as usize].can_pop());
                if !ready {
                    self.perf.stall_ssr_empty += 1;
                    return FpEvent::SsrEmpty;
                }
                if (!s1 && self.fpu.reg_busy(frs1))
                    || (!s2 && self.fpu.reg_busy(frs2))
                    || (!s3 && self.fpu.reg_busy(frs3))
                {
                    self.perf.stall_raw += 1;
                    return FpEvent::RawHazard;
                }
                let ssr_dest = self.ssr_write(frd);
                if ssr_dest && !self.ssrs[frd as usize].can_reserve() {
                    self.perf.stall_wfifo += 1;
                    return FpEvent::WFifoFull;
                }
                let a = if s1 {
                    self.ssrs[frs1 as usize].pop()
                } else {
                    self.fpu.regs[frs1 as usize]
                };
                let b = if s2 {
                    self.ssrs[frs2 as usize].pop()
                } else {
                    self.fpu.regs[frs2 as usize]
                };
                let c = if s3 {
                    self.ssrs[frs3 as usize].pop()
                } else {
                    self.fpu.regs[frs3 as usize]
                };
                if ssr_dest {
                    self.ssrs[frd as usize].reserve();
                }
                let ok = self.fpu.issue_resolved(
                    frd,
                    a.mul_add(b, c),
                    ssr_dest,
                    now,
                );
                debug_assert!(ok);
                let info = self.seq.advance();
                if info.replayed {
                    self.perf.rb_replays += 1;
                }
                self.perf.fpu_ops += 1;
                return FpEvent::Issued;
            }
            Instr::FmulD { frd, frs1, frs2 } => {
                let s1 = self.ssr_read(frs1);
                let s2 = self.ssr_read(frs2);
                if (s1 && !self.ssrs[frs1 as usize].can_pop())
                    || (s2 && !self.ssrs[frs2 as usize].can_pop())
                {
                    self.perf.stall_ssr_empty += 1;
                    return FpEvent::SsrEmpty;
                }
                if (!s1 && self.fpu.reg_busy(frs1))
                    || (!s2 && self.fpu.reg_busy(frs2))
                {
                    self.perf.stall_raw += 1;
                    return FpEvent::RawHazard;
                }
                let ssr_dest = self.ssr_write(frd);
                if ssr_dest && !self.ssrs[frd as usize].can_reserve() {
                    self.perf.stall_wfifo += 1;
                    return FpEvent::WFifoFull;
                }
                let a = if s1 {
                    self.ssrs[frs1 as usize].pop()
                } else {
                    self.fpu.regs[frs1 as usize]
                };
                let b = if s2 {
                    self.ssrs[frs2 as usize].pop()
                } else {
                    self.fpu.regs[frs2 as usize]
                };
                if ssr_dest {
                    self.ssrs[frd as usize].reserve();
                }
                let ok =
                    self.fpu.issue_resolved(frd, a * b, ssr_dest, now);
                debug_assert!(ok);
                let info = self.seq.advance();
                if info.replayed {
                    self.perf.rb_replays += 1;
                }
                self.perf.fpu_ops += 1;
                return FpEvent::Issued;
            }
            _ => {}
        }

        // Generic path (fadd/fsub/fsgnj and exotic operand mixes).
        // Operand readiness (check all, then commit pops atomically).
        let sources = instr.fp_sources();
        for src in sources.iter().flatten() {
            if self.ssr_read(*src) {
                if !self.ssrs[*src as usize].can_pop() {
                    self.perf.stall_ssr_empty += 1;
                    return FpEvent::SsrEmpty;
                }
            } else if self.fpu.reg_busy(*src) {
                self.perf.stall_raw += 1;
                return FpEvent::RawHazard;
            }
        }
        let dest = instr.fp_dest().expect("compute op has a dest");
        let ssr_dest = self.ssr_write(dest);
        if ssr_dest && !self.ssrs[dest as usize].can_reserve() {
            self.perf.stall_wfifo += 1;
            return FpEvent::WFifoFull;
        }

        // Commit: pop SSR operands per source *occurrence*.
        let mut vals: [Option<f64>; 3] = [None, None, None];
        for (slot, src) in sources.iter().enumerate() {
            if let Some(r) = src {
                if self.ssr_read(*r) {
                    vals[slot] = Some(self.ssrs[*r as usize].pop());
                }
            }
        }
        if ssr_dest {
            self.ssrs[dest as usize].reserve();
        }
        let ok = self.fpu.issue(&instr, &vals, ssr_dest, now);
        debug_assert!(ok);
        let info = self.seq.advance();
        if info.replayed {
            self.perf.rb_replays += 1;
        }
        self.perf.fpu_ops += 1;
        FpEvent::Issued
    }

    // ============================================================
    // Frontend tick
    // ============================================================

    /// Execute one frontend cycle. Returns a request the cluster must
    /// service (DMA pushes from the DM core).
    pub fn frontend_tick(&mut self, now: u64, dma_ready: bool) -> CoreRequest {
        match self.state {
            State::Halted => return CoreRequest::None,
            State::LsuWait { .. } => {
                self.perf.lsu_stalls += 1;
                return CoreRequest::None;
            }
            State::DrainWait => {
                self.perf.drain_stalls += 1;
                if self.seq.busy()
                    || !self.fpu.idle()
                    || !self.ssrs.iter().all(|s| s.drained())
                {
                    return CoreRequest::None;
                }
                self.state = State::Running; // re-decode the instr now
            }
            State::BarrierWait => {
                self.perf.barrier_cycles += 1;
                if !self.barrier_arrived && self.subsystem_drained() {
                    self.barrier_arrived = true;
                }
                return CoreRequest::None;
            }
            State::Running => {}
        }
        if self.bubbles > 0 {
            self.bubbles -= 1;
            self.perf.branch_bubbles += 1;
            return CoreRequest::None;
        }
        let Some(&instr) = self.prog.instrs.get(self.pc) else {
            self.state = State::Halted;
            return CoreRequest::None;
        };

        // ---- FP offload path -------------------------------------
        if instr.is_fp_compute() {
            if self.seq.push_fp(instr) {
                self.pc += 1;
                self.perf.fp_offloads += 1;
                self.perf.icache_fetches += 1;
            } else {
                self.perf.offload_stalls += 1;
            }
            return CoreRequest::None;
        }
        if let Instr::Frep { iters_reg, n_inst, .. } = instr {
            let iters = self.iregs[iters_reg as usize].wrapping_add(1);
            if self.seq.push_frep(n_inst as u32 + 1, iters) {
                self.pc += 1;
                self.perf.icache_fetches += 1;
            } else {
                self.perf.offload_stalls += 1;
            }
            return CoreRequest::None;
        }

        // ---- integer / system path --------------------------------
        // (the fetch is counted at retire below — stall-retry paths
        // keep the instruction in the decode stage, one real fetch)
        let mut req = CoreRequest::None;
        let mut next_pc = self.pc + 1;
        let rs = |r: u8, regs: &[u32; 32]| -> u32 {
            if r == 0 {
                0
            } else {
                regs[r as usize]
            }
        };
        let wr = |core: &mut Self, r: u8, v: u32| {
            if r != 0 {
                core.iregs[r as usize] = v;
            }
        };
        match instr {
            Instr::Lui { rd, imm } => wr(self, rd, imm as u32),
            Instr::Auipc { rd, imm } => {
                wr(self, rd, (self.pc as u32 * 4).wrapping_add(imm as u32))
            }
            Instr::Addi { rd, rs1, imm } => {
                let v = rs(rs1, &self.iregs).wrapping_add(imm as u32);
                wr(self, rd, v);
            }
            Instr::Slli { rd, rs1, shamt } => {
                let v = rs(rs1, &self.iregs) << shamt;
                wr(self, rd, v);
            }
            Instr::Srli { rd, rs1, shamt } => {
                let v = rs(rs1, &self.iregs) >> shamt;
                wr(self, rd, v);
            }
            Instr::Andi { rd, rs1, imm } => {
                let v = rs(rs1, &self.iregs) & imm as u32;
                wr(self, rd, v);
            }
            Instr::Add { rd, rs1, rs2 } => {
                let v =
                    rs(rs1, &self.iregs).wrapping_add(rs(rs2, &self.iregs));
                wr(self, rd, v);
            }
            Instr::Sub { rd, rs1, rs2 } => {
                let v =
                    rs(rs1, &self.iregs).wrapping_sub(rs(rs2, &self.iregs));
                wr(self, rd, v);
            }
            Instr::Mul { rd, rs1, rs2 } => {
                let v =
                    rs(rs1, &self.iregs).wrapping_mul(rs(rs2, &self.iregs));
                wr(self, rd, v);
            }
            Instr::Beq { rs1, rs2, off }
            | Instr::Bne { rs1, rs2, off }
            | Instr::Blt { rs1, rs2, off }
            | Instr::Bge { rs1, rs2, off } => {
                let a = rs(rs1, &self.iregs);
                let b = rs(rs2, &self.iregs);
                let taken = match instr {
                    Instr::Beq { .. } => a == b,
                    Instr::Bne { .. } => a != b,
                    Instr::Blt { .. } => (a as i32) < (b as i32),
                    Instr::Bge { .. } => (a as i32) >= (b as i32),
                    _ => unreachable!(),
                };
                if taken {
                    next_pc =
                        (self.pc as i64 + (off / 4) as i64) as usize;
                    self.bubbles = self.cfg.taken_branch_penalty;
                }
            }
            Instr::Jal { rd, off } => {
                wr(self, rd, (self.pc as u32 + 1) * 4);
                next_pc = (self.pc as i64 + (off / 4) as i64) as usize;
                self.bubbles = self.cfg.taken_branch_penalty;
            }
            Instr::Lw { rd, rs1, imm } => {
                let addr =
                    rs(rs1, &self.iregs).wrapping_add(imm as u32);
                self.state = State::LsuWait {
                    addr,
                    op: LsuOp::LoadInt { rd },
                };
            }
            Instr::Sw { rs2, rs1, imm } => {
                let addr =
                    rs(rs1, &self.iregs).wrapping_add(imm as u32);
                self.state = State::LsuWait {
                    addr,
                    op: LsuOp::StoreInt { data: rs(rs2, &self.iregs) },
                };
            }
            Instr::Fld { frd, rs1, imm } => {
                let addr =
                    rs(rs1, &self.iregs).wrapping_add(imm as u32);
                self.state = State::LsuWait {
                    addr,
                    op: LsuOp::LoadFp { frd },
                };
            }
            Instr::Fsd { frs2, rs1, imm } => {
                // In-order FP semantics: the store must observe every
                // older offloaded op's writeback (the RTL enforces this
                // through the FP scoreboard).
                if self.seq.busy() || self.fpu.reg_busy(frs2) {
                    self.state = State::DrainWait;
                    return CoreRequest::None;
                }
                let addr =
                    rs(rs1, &self.iregs).wrapping_add(imm as u32);
                self.state = State::LsuWait {
                    addr,
                    op: LsuOp::StoreFp {
                        data: self.fpu.regs[frs2 as usize],
                    },
                };
            }
            Instr::Csrrw { rd, csr: c, rs1 } | Instr::Csrrs { rd, csr: c, rs1 } => {
                self.perf.csr_instrs += 1;
                let old = match c {
                    csr::MCYCLE => now as u32,
                    csr::MHARTID => self.id as u32,
                    csr::SSR_ENABLE => self.ssr_enable as u32,
                    _ => 0,
                };
                wr(self, rd, old);
                if c == csr::SSR_ENABLE && rs1 != 0 {
                    self.ssr_enable = rs(rs1, &self.iregs) & 1 == 1;
                }
            }
            Instr::Csrrsi { csr: c, imm } => {
                self.perf.csr_instrs += 1;
                if c == csr::SSR_ENABLE && imm & 1 == 1 {
                    self.ssr_enable = true;
                }
            }
            Instr::Csrrci { csr: c, imm } => {
                // Disabling SSR interception is only safe once every
                // offloaded op that consumes/produces stream data has
                // retired — kernels rely on this drain point.
                if c == csr::SSR_ENABLE
                    && (self.seq.busy()
                        || !self.fpu.idle()
                        || !self.ssrs.iter().all(|s| s.drained()))
                {
                    self.state = State::DrainWait;
                    return CoreRequest::None;
                }
                self.perf.csr_instrs += 1;
                if c == csr::SSR_ENABLE && imm & 1 == 1 {
                    self.ssr_enable = false;
                }
            }
            Instr::SsrCfgW { value, ssr, field } => {
                let v = rs(value, &self.iregs);
                self.ssrs[ssr as usize].config(field, v);
            }
            Instr::FcvtDW { frd, rs1 } => {
                let v = rs(rs1, &self.iregs) as i32 as f64;
                self.fpu.write_reg(frd, v);
            }
            Instr::Dmsrc { rs1 } => self.dm_src = rs(rs1, &self.iregs),
            Instr::Dmdst { rs1 } => self.dm_dst = rs(rs1, &self.iregs),
            Instr::Dmstr { rs1, rs2 } => {
                self.dm_src_stride = rs(rs1, &self.iregs);
                self.dm_dst_stride = rs(rs2, &self.iregs);
            }
            Instr::Dmrep { rs1 } => {
                self.dm_reps = rs(rs1, &self.iregs).max(1)
            }
            Instr::Dmstr2 { rs1, rs2 } => {
                self.dm_src_stride2 = rs(rs1, &self.iregs);
                self.dm_dst_stride2 = rs(rs2, &self.iregs);
            }
            Instr::Dmrep2 { rs1 } => {
                self.dm_reps2 = rs(rs1, &self.iregs).max(1)
            }
            Instr::Dmcpy { rd, rs1 } => {
                if dma_ready {
                    let desc = DmaDesc {
                        src: self.dm_src,
                        dst: self.dm_dst,
                        size: rs(rs1, &self.iregs),
                        src_stride: self.dm_src_stride,
                        dst_stride: self.dm_dst_stride,
                        reps: self.dm_reps,
                        src_stride2: self.dm_src_stride2,
                        dst_stride2: self.dm_dst_stride2,
                        reps2: self.dm_reps2,
                    };
                    self.dm_txid += 1;
                    let txid = self.dm_txid;
                    wr(self, rd, txid);
                    req = CoreRequest::DmaPush(desc);
                } else {
                    // DMA queue full: retry this instruction.
                    self.perf.lsu_stalls += 1;
                    return CoreRequest::None;
                }
            }
            Instr::Dmstat { .. } => {
                // The cluster substitutes the live in-flight count; the
                // core-side shim is patched by `set_dmstat` before this
                // executes (see cluster::step).
                unreachable!("Dmstat handled by the cluster wrapper")
            }
            Instr::Barrier => {
                self.state = State::BarrierWait;
                self.barrier_arrived = self.subsystem_drained();
            }
            Instr::Ecall => {
                self.state = State::Halted;
            }
            Instr::Nop => {}
            Instr::Frep { .. }
            | Instr::FmaddD { .. }
            | Instr::FmulD { .. }
            | Instr::FaddD { .. }
            | Instr::FsubD { .. }
            | Instr::FmaxD { .. }
            | Instr::FsgnjD { .. }
            | Instr::FgeluD { .. } => unreachable!("handled above"),
        }
        self.perf.int_instrs += 1;
        self.perf.icache_fetches += 1;
        // pc advances for every executed instruction, including those
        // that enter LsuWait (the wait resumes *after* the access).
        match self.state {
            State::BarrierWait | State::Halted => {
                self.pc += 1;
            }
            _ => {
                self.pc = next_pc;
            }
        }
        req
    }

    /// Execute a `dmstat` immediately with the cluster-provided count.
    /// Returns true if the current instruction was a dmstat.
    pub fn try_dmstat(&mut self, in_flight: u32) -> bool {
        if self.state != State::Running || self.bubbles > 0 {
            return false;
        }
        if let Some(Instr::Dmstat { rd }) = self.prog.instrs.get(self.pc) {
            if *rd != 0 {
                self.iregs[*rd as usize] = in_flight;
            }
            self.pc += 1;
            self.perf.int_instrs += 1;
            self.perf.icache_fetches += 1;
            return true;
        }
        false
    }

    // ============================================================
    // LSU interface (driven by the cluster's arbitration)
    // ============================================================

    /// The TCDM request this core's LSU presents this cycle.
    pub fn lsu_request(&self) -> Option<(u32, bool, u64)> {
        match self.state {
            State::LsuWait { addr, op } => {
                let (write, data) = match op {
                    LsuOp::LoadInt { .. } | LsuOp::LoadFp { .. } => {
                        (false, 0u64)
                    }
                    LsuOp::StoreInt { data } => (true, data as u64),
                    LsuOp::StoreFp { data } => (true, data.to_bits()),
                };
                Some((addr, write, data))
            }
            _ => None,
        }
    }

    /// The LSU request was granted; deliver data and resume.
    pub fn lsu_granted(&mut self, read_bits: u64) {
        let State::LsuWait { op, .. } = self.state else {
            panic!("lsu_granted while not waiting")
        };
        match op {
            LsuOp::LoadInt { rd } => {
                if rd != 0 {
                    self.iregs[rd as usize] = read_bits as u32;
                }
            }
            LsuOp::LoadFp { frd } => {
                self.fpu.write_reg(frd, f64::from_bits(read_bits));
            }
            LsuOp::StoreInt { .. } | LsuOp::StoreFp { .. } => {}
        }
        self.state = State::Running;
    }
}
