//! The cluster DMA engine (Snitch Xdma).
//!
//! A 512-bit engine for burst transfers between main memory and the
//! TCDM.  Each cycle it presents at most one *beat* — up to 8
//! consecutive 64-bit words, never crossing a superbank row boundary —
//! to the TCDM interconnect; the main-memory side is modeled with
//! matching bandwidth (one beat per cycle, burst latency hidden), so
//! the TCDM arbitration is the only source of DMA stalls, as in the
//! paper's cluster.
//!
//! Supports 1D and 2D transfers (inner size + strides + repetitions),
//! programmed from the DM core via the Xdma instructions
//! (`dmsrc`/`dmdst`/`dmstr`/`dmrep`/`dmcpy`) and polled with `dmstat`.

use std::collections::VecDeque;

use crate::mem::{DmaBeat, MainMemory, Tcdm, TCDM_BASE};

/// An up-to-3D transfer descriptor (1D when `reps == 1 && reps2 == 1`).
/// Dimension 2 wraps dimension 1 which wraps the contiguous inner row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DmaDesc {
    pub src: u32,
    pub dst: u32,
    /// Inner (row) size in bytes; must be a multiple of 8.
    pub size: u32,
    pub src_stride: u32,
    pub dst_stride: u32,
    pub reps: u32,
    /// 3rd dimension (iDMA-style); strides applied every `reps` rows.
    pub src_stride2: u32,
    pub dst_stride2: u32,
    pub reps2: u32,
}

impl DmaDesc {
    /// Plain 2D descriptor.
    pub fn d2(src: u32, dst: u32, size: u32, src_stride: u32,
              dst_stride: u32, reps: u32) -> Self {
        Self {
            src, dst, size, src_stride, dst_stride, reps,
            src_stride2: 0, dst_stride2: 0, reps2: 1,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.size as u64 * self.reps as u64 * self.reps2 as u64
    }
}

#[derive(Clone, Copy, Debug)]
struct Active {
    desc: DmaDesc,
    rep: u32,
    rep2: u32,
    /// Byte offset within the current row.
    off: u32,
}

/// Direction of the TCDM side of the current beat.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dir {
    /// main memory -> TCDM (beat is a TCDM write)
    ToTcdm,
    /// TCDM -> main memory (beat is a TCDM read)
    FromTcdm,
}

pub struct Dma {
    queue: VecDeque<DmaDesc>,
    active: Option<Active>,
    queue_depth: usize,
    /// Beat computed for the current cycle and retried after an
    /// arbitration loss. A denied beat is presented again unchanged,
    /// so recomputing it (including the eager main-memory read of up
    /// to 8 words) on every retry was pure hot-loop waste; the cache
    /// is invalidated exactly when the beat commits.
    pending: Option<DmaBeat>,
    // --- statistics ---
    pub beats: u64,
    pub stall_cycles: u64,
    pub bytes_moved: u64,
    pub busy_cycles: u64,
    /// Subset of `stall_cycles` where the *fabric NoC* withheld the
    /// grant (vs the TCDM superbank mux) — StallScope's NocGated
    /// evidence at the engine level.
    pub noc_gated_cycles: u64,
}

impl Dma {
    pub fn new(queue_depth: usize) -> Self {
        Self {
            queue: VecDeque::with_capacity(queue_depth),
            active: None,
            queue_depth,
            pending: None,
            beats: 0,
            stall_cycles: 0,
            bytes_moved: 0,
            busy_cycles: 0,
            noc_gated_cycles: 0,
        }
    }

    pub fn can_push(&self) -> bool {
        self.queue.len() < self.queue_depth
    }

    /// Enqueue a transfer (`dmcpy`). Returns false when the queue is
    /// full (the DM core retries).
    pub fn push(&mut self, d: DmaDesc) -> bool {
        assert_eq!(d.size % 8, 0, "DMA size must be 8-byte aligned");
        assert_eq!(d.src % 8, 0);
        assert_eq!(d.dst % 8, 0);
        assert!(d.reps >= 1 && d.reps2 >= 1);
        if !self.can_push() {
            return false;
        }
        self.queue.push_back(d);
        true
    }

    /// Number of transfers in flight (`dmstat`).
    pub fn in_flight(&self) -> u32 {
        self.queue.len() as u32 + self.active.is_some() as u32
    }

    pub fn busy(&self) -> bool {
        self.in_flight() > 0
    }

    fn dir(a: &Active) -> Dir {
        if a.desc.dst >= TCDM_BASE && a.desc.dst < crate::mem::MAIN_MEM_BASE {
            Dir::ToTcdm
        } else {
            Dir::FromTcdm
        }
    }

    /// Pop the next descriptor into the active slot if idle.
    fn activate(&mut self) {
        if self.active.is_none() {
            if let Some(d) = self.queue.pop_front() {
                self.active =
                    Some(Active { desc: d, rep: 0, rep2: 0, off: 0 });
            }
        }
    }

    /// Compute this cycle's beat, reading main-memory data eagerly for
    /// TCDM-write beats. Returns `None` when idle. A beat denied by
    /// arbitration is re-presented from the `pending` cache — the
    /// transfer state does not advance on a denial, so the retried
    /// beat is identical by construction.
    pub fn next_beat(&mut self, mem: &MainMemory) -> Option<DmaBeat> {
        if let Some(b) = self.pending {
            return Some(b);
        }
        self.activate();
        let a = self.active.as_ref()?;
        let d = &a.desc;
        let (src_addr, dst_addr) = (
            d.src + a.rep2 * d.src_stride2 + a.rep * d.src_stride + a.off,
            d.dst + a.rep2 * d.dst_stride2 + a.rep * d.dst_stride + a.off,
        );
        let remaining_row = (d.size - a.off) / 8;
        let (tcdm_addr, dir) = match Self::dir(a) {
            Dir::ToTcdm => (dst_addr, Dir::ToTcdm),
            Dir::FromTcdm => (src_addr, Dir::FromTcdm),
        };
        // Never cross the superbank row (8-word boundary) on the TCDM
        // side.
        let word = (tcdm_addr - TCDM_BASE) / 8;
        let to_boundary = 8 - (word % 8);
        let n_words = remaining_row.min(to_boundary).min(8) as u8;
        let mut data = [0u64; 8];
        let write = dir == Dir::ToTcdm;
        if write {
            for w in 0..n_words as usize {
                data[w] = mem.read_u64(src_addr + (w as u32) * 8);
            }
        }
        let beat = DmaBeat { addr: tcdm_addr, n_words, write, data };
        self.pending = Some(beat);
        Some(beat)
    }

    /// The interconnect granted this cycle's beat: commit the
    /// main-memory side and advance. `tcdm_read` carries the data for
    /// TCDM-read beats.
    pub fn beat_granted(
        &mut self,
        beat: &DmaBeat,
        tcdm_read: &[u64; 8],
        mem: &mut MainMemory,
    ) {
        self.pending = None;
        let a = self.active.as_mut().expect("no active transfer");
        let d = a.desc;
        if !beat.write {
            // TCDM -> main memory
            let dst = d.dst
                + a.rep2 * d.dst_stride2
                + a.rep * d.dst_stride
                + a.off;
            for w in 0..beat.n_words as usize {
                mem.write_u64(dst + (w as u32) * 8, tcdm_read[w]);
            }
        }
        let bytes = beat.n_words as u32 * 8;
        a.off += bytes;
        self.beats += 1;
        self.bytes_moved += bytes as u64;
        if a.off >= d.size {
            a.off = 0;
            a.rep += 1;
            if a.rep >= d.reps {
                a.rep = 0;
                a.rep2 += 1;
                if a.rep2 >= d.reps2 {
                    self.active = None;
                }
            }
        }
    }

    /// The beat lost superbank arbitration this cycle.
    pub fn beat_denied(&mut self) {
        self.stall_cycles += 1;
    }
}

/// Convenience: run a DMA transfer to completion against memory with no
/// contention (used by tests and by experiment setup fast paths).
pub fn run_uncontended(
    dma: &mut Dma,
    tcdm: &mut Tcdm,
    mem: &mut MainMemory,
) -> u64 {
    let mut cycles = 0;
    while dma.busy() {
        if let Some(beat) = dma.next_beat(mem) {
            let mut read = [0u64; 8];
            if beat.write {
                for w in 0..beat.n_words as usize {
                    tcdm.write_u64(beat.addr + (w as u32) * 8, beat.data[w]);
                }
            } else {
                for w in 0..beat.n_words as usize {
                    read[w] = tcdm.read_u64(beat.addr + (w as u32) * 8);
                }
            }
            dma.beat_granted(&beat, &read, mem);
        }
        cycles += 1;
        assert!(cycles < 10_000_000, "DMA livelock");
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{Topology, MAIN_MEM_BASE};

    fn setup() -> (Dma, Tcdm, MainMemory) {
        (
            Dma::new(4),
            Tcdm::new(Topology::Fc { banks: 32 }, 128 * 1024),
            MainMemory::new(1 << 20),
        )
    }

    #[test]
    fn one_d_roundtrip() {
        let (mut dma, mut tcdm, mut mem) = setup();
        let xs: Vec<f64> = (0..64).map(|i| i as f64 * 1.5).collect();
        mem.write_slice_f64(MAIN_MEM_BASE, &xs);
        // load to TCDM
        assert!(dma.push(DmaDesc::d2(MAIN_MEM_BASE, TCDM_BASE, 64 * 8,
                                     0, 0, 1)));
        let cycles = run_uncontended(&mut dma, &mut tcdm, &mut mem);
        assert_eq!(cycles, 8, "64 words = 8 beats at 64B/cycle");
        assert_eq!(tcdm.read_f64(TCDM_BASE + 63 * 8), 63.0 * 1.5);
        // store back to a different main-memory region
        assert!(dma.push(DmaDesc::d2(TCDM_BASE, MAIN_MEM_BASE + 0x8000,
                                     64 * 8, 0, 0, 1)));
        run_uncontended(&mut dma, &mut tcdm, &mut mem);
        assert_eq!(mem.read_vec_f64(MAIN_MEM_BASE + 0x8000, 64), xs);
    }

    #[test]
    fn two_d_strided_gather() {
        let (mut dma, mut tcdm, mut mem) = setup();
        // A 4x16 tile out of a 4x32 row-major matrix (stride 32 words).
        for r in 0..4u32 {
            for c in 0..32u32 {
                mem.write_f64(
                    MAIN_MEM_BASE + (r * 32 + c) * 8,
                    (r * 100 + c) as f64,
                );
            }
        }
        assert!(dma.push(DmaDesc::d2(MAIN_MEM_BASE, TCDM_BASE, 16 * 8,
                                     32 * 8, 16 * 8, 4)));
        run_uncontended(&mut dma, &mut tcdm, &mut mem);
        for r in 0..4u32 {
            for c in 0..16u32 {
                assert_eq!(
                    tcdm.read_f64(TCDM_BASE + (r * 16 + c) * 8),
                    (r * 100 + c) as f64,
                );
            }
        }
    }

    #[test]
    fn beats_respect_superbank_rows() {
        let (mut dma, mut mut_tcdm, mut mem) = setup();
        // Destination starts 3 words into a superbank row: first beat
        // must shorten to 5 words.
        assert!(dma.push(DmaDesc::d2(MAIN_MEM_BASE, TCDM_BASE + 3 * 8,
                                     16 * 8, 0, 0, 1)));
        let beat = dma.next_beat(&mem).unwrap();
        assert_eq!(beat.n_words, 5);
        let _ = run_uncontended(&mut dma, &mut mut_tcdm, &mut mem);
    }

    #[test]
    fn queue_depth_enforced() {
        let (mut dma, _, _) = setup();
        let d = DmaDesc::d2(MAIN_MEM_BASE, TCDM_BASE, 8, 0, 0, 1);
        for _ in 0..4 {
            assert!(dma.push(d));
        }
        assert!(!dma.push(d));
        assert_eq!(dma.in_flight(), 4);
    }

    #[test]
    fn three_d_strided_scatter() {
        let (mut dma, mut tcdm, mut mem) = setup();
        // 2 outer reps of (3 chunks of 64B): the grouped-layout pattern.
        for w in 0..48u32 {
            mem.write_u64(MAIN_MEM_BASE + w * 8, w as u64);
        }
        assert!(dma.push(DmaDesc {
            src: MAIN_MEM_BASE,
            dst: TCDM_BASE,
            size: 64,
            src_stride: 64,
            dst_stride: 32 * 8, // one chunk per 32-word "row"
            reps: 3,
            src_stride2: 3 * 64,
            dst_stride2: 3 * 32 * 8,
            reps2: 2,
        }));
        run_uncontended(&mut dma, &mut tcdm, &mut mem);
        for outer in 0..2u32 {
            for chunk in 0..3u32 {
                for w in 0..8u32 {
                    let addr = TCDM_BASE
                        + outer * 3 * 32 * 8
                        + chunk * 32 * 8
                        + w * 8;
                    assert_eq!(
                        tcdm.read_u64(addr),
                        ((outer * 3 + chunk) * 8 + w) as u64,
                    );
                }
            }
        }
        assert_eq!(dma.bytes_moved, 384);
    }

    #[test]
    fn stats_account_bytes() {
        let (mut dma, mut tcdm, mut mem) = setup();
        dma.push(DmaDesc::d2(MAIN_MEM_BASE, TCDM_BASE, 256, 0, 0, 2));
        run_uncontended(&mut dma, &mut tcdm, &mut mem);
        assert_eq!(dma.bytes_moved, 512);
        assert_eq!(dma.beats, 8);
    }
}
