//! ClusterFabric — N clusters behind a shared L2 / main-memory model
//! with a bandwidth-limited NoC.
//!
//! The cluster is the unit of replication in Occamy-style many-cluster
//! SoCs: once per-PE utilization is near-ideal (96–99% in Fig. 5), the
//! remaining scaling axis is sharding a GEMM — and a NetGraph DAG —
//! across clusters. This module owns that layer:
//!
//! * every cluster keeps its private TCDM, interconnect, and DMA
//!   branch, exactly as in the single-cluster model;
//! * the branches meet at a shared NoC into L2: per cycle the links
//!   sustain a fixed *beat budget* ([`NocConfig::budget`]), and a
//!   round-robin arbiter rotates grants across the clusters' pending
//!   DMA beats — branches beyond the budget stall that cycle;
//! * [`ClusterFabric::step`] advances all clusters in lockstep against
//!   the arbiter, so cross-cluster timing interference is modeled
//!   while numerics stay exactly per-cluster (operand blocks are
//!   scattered into each cluster's main-memory image up front).
//!
//! Shard partitioning lives in `kernels::tiling` (`choose_shard_grid`:
//! 2D M x N grid, K local, uniform blocks); backend-specific sharded
//! evaluation behind `SimBackend::run_sharded`; `GemmService` fronts
//! both with `run_sharded` / `prepare_sharded`.

use anyhow::Result;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cluster::{Cluster, ClusterPerf};
use crate::kernels::tiling::Shard;
use crate::profile::StallProfile;

fn auto_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f` over every target cluster on up to `threads` workers and
/// sum the returned counts. Work is handed out by atomic index, each
/// cluster is touched by exactly one worker, every cluster's own
/// evolution is deterministic, and the sum is order-independent — so
/// the machine state and all statistics are bit-identical for every
/// thread count.
fn par_each<F>(targets: Vec<&mut Cluster>, threads: usize, f: F) -> u64
where
    F: Fn(&mut Cluster) -> u64 + Sync,
{
    if threads <= 1 || targets.len() <= 1 {
        let mut total = 0;
        for cl in targets {
            total += f(cl);
        }
        return total;
    }
    let slots: Vec<Mutex<Option<&mut Cluster>>> =
        targets.into_iter().map(|c| Mutex::new(Some(c))).collect();
    let next = AtomicUsize::new(0);
    let total = AtomicU64::new(0);
    let workers = threads.min(slots.len());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= slots.len() {
                    break;
                }
                let mut guard = slots[i].lock().unwrap();
                if let Some(cl) = guard.as_deref_mut() {
                    total.fetch_add(f(cl), Ordering::Relaxed);
                }
            });
        }
    });
    total.into_inner()
}

/// Shared-NoC link provisioning: `links` parallel links, each
/// sustaining `beats_per_link` 512-bit beats per cycle into L2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NocConfig {
    pub links: usize,
    pub beats_per_link: usize,
}

impl NocConfig {
    /// Total beats the NoC can move per cycle (never 0).
    pub fn budget(&self) -> usize {
        (self.links * self.beats_per_link).max(1)
    }
}

impl Default for NocConfig {
    /// Two single-beat links — half a beat per cluster per cycle on
    /// the 4-cluster fabric, enough to keep double-buffered
    /// compute-bound GEMMs off the DMA roofline.
    fn default() -> Self {
        Self { links: 2, beats_per_link: 1 }
    }
}

/// Fabric shape: how many clusters share the NoC.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FabricConfig {
    pub clusters: usize,
    pub noc: NocConfig,
}

impl FabricConfig {
    pub fn new(clusters: usize) -> Self {
        Self { clusters: clusters.max(1), noc: NocConfig::default() }
    }

    /// The degenerate single-cluster fabric (private link semantics).
    pub fn single() -> Self {
        Self::new(1)
    }

    /// Theoretical DMA-branch serialization of this fabric: how many
    /// cycles the NoC needs per beat-per-cluster, relative to a
    /// private link (`>= 1`).
    pub fn noc_factor(&self) -> f64 {
        (self.clusters as f64 / self.noc.budget() as f64).max(1.0)
    }
}

/// Shape of a NodeSim node: `fabrics` identical cluster fabrics
/// behind one front-end router (`coordinator::node`). Fabrics share
/// nothing — each has its own NoC and L2 — so the node tier composes
/// them purely in virtual time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeTopology {
    pub fabrics: usize,
    pub fabric: FabricConfig,
}

impl NodeTopology {
    pub fn new(fabrics: usize, clusters: usize) -> Self {
        Self {
            fabrics: fabrics.max(1),
            fabric: FabricConfig::new(clusters),
        }
    }

    /// Clusters across the whole node.
    pub fn total_clusters(&self) -> usize {
        self.fabrics * self.fabric.clusters
    }
}

/// Shared-link traffic counters for one fabric run.
#[derive(Clone, Copy, Debug, Default)]
pub struct NocStats {
    /// Beats granted onto the shared links.
    pub grants: u64,
    /// Pending beats deferred because the cycle's budget was spent.
    pub denials: u64,
    /// Cycles in which demand exceeded the link budget.
    pub saturated_cycles: u64,
}

/// N lockstep clusters behind one NoC arbiter.
pub struct ClusterFabric {
    pub clusters: Vec<Cluster>,
    pub noc_cfg: NocConfig,
    pub noc: NocStats,
    pub cycle: u64,
    /// Round-robin start pointer for the next contested cycle.
    rr: usize,
    /// Per-cluster grant scratch (reused every cycle).
    grants: Vec<bool>,
}

impl ClusterFabric {
    pub fn new(clusters: Vec<Cluster>, noc_cfg: NocConfig) -> Self {
        assert!(!clusters.is_empty(), "fabric needs at least 1 cluster");
        let n = clusters.len();
        Self {
            clusters,
            noc_cfg,
            noc: NocStats::default(),
            cycle: 0,
            rr: 0,
            grants: vec![false; n],
        }
    }

    pub fn all_halted(&self) -> bool {
        self.clusters.iter().all(|c| c.all_halted())
    }

    /// Advance every live cluster one cycle against the shared NoC.
    ///
    /// Busy DMA branches contest the cycle's beat budget round-robin;
    /// idle branches keep their gate open so a transfer enqueued this
    /// very cycle starts without an artificial bubble (this is what
    /// makes a 1-cluster fabric cycle-identical to `Cluster::run`).
    pub fn step(&mut self) {
        let n = self.clusters.len();
        let budget = self.noc_cfg.budget();
        let mut want = 0usize;
        let mut granted = 0usize;
        self.grants.iter_mut().for_each(|g| *g = false);
        for off in 0..n {
            let i = (self.rr + off) % n;
            let cl = &self.clusters[i];
            if cl.all_halted() {
                continue;
            }
            if cl.dma.busy() {
                want += 1;
                if granted < budget {
                    self.grants[i] = true;
                    granted += 1;
                }
            } else {
                self.grants[i] = true;
            }
        }
        self.noc.grants += granted as u64;
        self.noc.denials += (want - granted) as u64;
        if want > budget {
            self.noc.saturated_cycles += 1;
        }
        self.rr = (self.rr + 1) % n;
        for i in 0..n {
            if !self.clusters[i].all_halted() {
                let g = self.grants[i];
                self.clusters[i].step_gated(g);
            }
        }
        self.cycle += 1;
    }

    /// Run to completion (every cluster halted). Returns fabric
    /// end-to-end cycles — the slowest cluster's halt time.
    pub fn run(&mut self, max_cycles: u64) -> Result<u64> {
        while !self.all_halted() {
            self.step();
            if self.cycle >= max_cycles {
                anyhow::bail!(
                    "fabric exceeded {max_cycles} cycles (deadlock?); \
                     halted={:?}",
                    self.clusters
                        .iter()
                        .map(|c| c.all_halted())
                        .collect::<Vec<_>>()
                );
            }
        }
        Ok(self.cycle)
    }

    /// Per-cluster performance snapshots.
    pub fn perfs(&self) -> Vec<ClusterPerf> {
        self.clusters.iter().map(|c| c.perf()).collect()
    }

    /// [`ClusterFabric::run`] through the FastPath stepper:
    /// bit-identical machine evolution and NoC statistics, without
    /// per-cycle lockstep.
    ///
    /// The naive fabric advances every cluster one cycle at a time so
    /// the arbiter can referee each cycle. But arbitration only
    /// *matters* on cycles where more busy DMA branches contest the
    /// links than the beat budget covers. This driver splits time into
    /// three exactly-equivalent regimes:
    ///
    /// 1. **Free-run** — a cluster whose DMA branch is idle never
    ///    competes for the shared links; the naive arbiter grants it
    ///    unconditionally and uncounted. Such clusters advance
    ///    independently (in parallel across `threads` workers) until
    ///    their branch wakes up.
    /// 2. **Uncontested batch** — when the busy clusters at the
    ///    earliest pending cycle `t` fit inside the beat budget, every
    ///    one of them is granted on every cycle until the next cluster
    ///    ahead could possibly join (`t2`): they advance independently
    ///    (again in parallel), each counting one NoC grant per cycle
    ///    its branch began busy — exactly what the per-cycle arbiter
    ///    would have booked. Demand can only shrink inside the window,
    ///    so no denial or saturation is missed.
    /// 3. **Contested lockstep** — when demand exceeds the budget, one
    ///    cycle is arbitrated exactly like [`ClusterFabric::step`],
    ///    with the round-robin pointer reconstructed as `t % n` (the
    ///    naive pointer increments once per cycle from 0).
    ///
    /// Soundness of the asynchronous advance: any live cluster whose
    /// local cycle is ahead of the global minimum was idle for the
    /// whole gap (free-run pauses *at* busy-onset), so it cannot have
    /// contended during the cycles the trailing clusters are about to
    /// simulate. `threads = 0` picks the machine's parallelism; all
    /// grant decisions are independent of worker scheduling, so every
    /// thread count produces the same bits.
    pub fn run_fast(
        &mut self,
        max_cycles: u64,
        threads: usize,
    ) -> Result<u64> {
        if self.all_halted() {
            return Ok(self.cycle);
        }
        let n = self.clusters.len();
        let budget = self.noc_cfg.budget();
        let threads =
            if threads == 0 { auto_threads().min(n) } else { threads.min(n) };
        loop {
            // ---- regime 1: free-run idle branches --------------------
            let targets: Vec<&mut Cluster> = self
                .clusters
                .iter_mut()
                .filter(|c| {
                    !c.all_halted()
                        && !c.dma.busy()
                        && c.cycle < max_cycles
                })
                .collect();
            if !targets.is_empty() {
                par_each(targets, threads, |cl| {
                    cl.advance_free(max_cycles);
                    0
                });
            }
            // Every live cluster below the deadline is now paused on a
            // busy DMA branch.
            let t = match self
                .clusters
                .iter()
                .filter(|c| !c.all_halted() && c.cycle < max_cycles)
                .map(|c| c.cycle)
                .min()
            {
                Some(t) => t,
                None => break,
            };
            let members = self
                .clusters
                .iter()
                .filter(|c| !c.all_halted() && c.cycle == t)
                .count();
            debug_assert!(members > 0);
            if members <= budget {
                // ---- regime 2: uncontested batch ---------------------
                let t2 = self
                    .clusters
                    .iter()
                    .filter(|c| !c.all_halted() && c.cycle > t)
                    .map(|c| c.cycle)
                    .min()
                    .unwrap_or(max_cycles);
                let until = t2.min(max_cycles);
                let targets: Vec<&mut Cluster> = self
                    .clusters
                    .iter_mut()
                    .filter(|c| !c.all_halted() && c.cycle == t)
                    .collect();
                let granted =
                    par_each(targets, threads, |cl| cl.advance_granted(until));
                self.noc.grants += granted;
            } else {
                // ---- regime 3: contested lockstep cycle at `t` -------
                let rr = (t % n as u64) as usize;
                let mut want = 0usize;
                let mut granted = 0usize;
                self.grants.iter_mut().for_each(|g| *g = false);
                for off in 0..n {
                    let i = (rr + off) % n;
                    let cl = &self.clusters[i];
                    if cl.all_halted() || cl.cycle != t {
                        continue;
                    }
                    if cl.dma.busy() {
                        want += 1;
                        if granted < budget {
                            self.grants[i] = true;
                            granted += 1;
                        }
                    } else {
                        self.grants[i] = true;
                    }
                }
                self.noc.grants += granted as u64;
                self.noc.denials += (want - granted) as u64;
                if want > budget {
                    self.noc.saturated_cycles += 1;
                }
                for i in 0..n {
                    if self.clusters[i].all_halted()
                        || self.clusters[i].cycle != t
                    {
                        continue;
                    }
                    let g = self.grants[i];
                    let mut region = false;
                    self.clusters[i].step_fast(&mut region, g);
                }
            }
        }
        // Fabric time is the slowest cluster's halt cycle, exactly the
        // lockstep driver's count; the rotor position matches its
        // one-increment-per-cycle evolution.
        self.cycle = self
            .clusters
            .iter()
            .map(|c| c.cycle)
            .max()
            .unwrap_or(self.cycle);
        self.rr = (self.cycle % n as u64) as usize;
        if self.cycle >= max_cycles {
            anyhow::bail!(
                "fabric exceeded {max_cycles} cycles (deadlock?); \
                 halted={:?}",
                self.clusters
                    .iter()
                    .map(|c| c.all_halted())
                    .collect::<Vec<_>>()
            );
        }
        Ok(self.cycle)
    }
}

/// Per-cluster outcome of a sharded fabric run.
#[derive(Clone, Debug)]
pub struct ShardRun {
    pub shard: Shard,
    /// This cluster's halt cycle.
    pub cycles: u64,
    pub perf: ClusterPerf,
}

/// Result of evaluating one sharded GEMM on a fabric (any backend).
#[derive(Clone, Debug)]
pub struct FabricResult {
    /// Gathered row-major `M x N` output — empty on non-functional
    /// backends, bit-identical to the single-cluster result otherwise
    /// (K stays shard-local, so every element keeps its FMA order).
    pub c: Vec<f64>,
    /// Fabric end-to-end cycles (slowest cluster).
    pub cycles: u64,
    pub shards: Vec<ShardRun>,
    pub noc: NocStats,
}

impl FabricResult {
    /// Clusters the run kept busy.
    pub fn clusters(&self) -> usize {
        self.shards.len()
    }

    /// Per-cluster performance snapshots in shard order (the shape
    /// `model::fabric_energy` consumes).
    pub fn perfs(&self) -> Vec<ClusterPerf> {
        self.shards.iter().map(|s| s.perf.clone()).collect()
    }

    /// Mean per-cluster FPU utilization over the compute windows.
    pub fn mean_utilization(&self) -> f64 {
        if self.shards.is_empty() {
            return 0.0;
        }
        self.shards.iter().map(|s| s.perf.utilization).sum::<f64>()
            / self.shards.len() as f64
    }

    /// Longest per-cluster compute window (the fabric-level window).
    pub fn window_cycles(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.perf.window_cycles)
            .max()
            .unwrap_or(0)
    }

    /// Total FPU ops across the fabric.
    pub fn fpu_ops_total(&self) -> u64 {
        self.shards.iter().map(|s| s.perf.fpu_ops_total).sum()
    }

    /// Total retried TCDM requests across the fabric (both halves of
    /// the conflict split).
    pub fn conflicts_total(&self) -> u64 {
        self.shards.iter().map(|s| s.perf.conflicts_total()).sum()
    }

    /// Fabric-level StallScope profile: every cluster's per-core
    /// attribution merged side by side (clusters ran in lockstep, so
    /// the window is the longest shard's).
    pub fn stall_profile(&self) -> StallProfile {
        let profiles: Vec<StallProfile> = self
            .shards
            .iter()
            .map(|s| s.perf.stalls.clone())
            .collect();
        StallProfile::merge_parallel(&profiles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ConfigId;
    use crate::isa::asm::Asm;
    use crate::isa::{reg, Instr, Program};
    use crate::mem::{MAIN_MEM_BASE, TCDM_BASE};

    fn empty_prog() -> Program {
        let mut a = Asm::new();
        a.push(Instr::Ecall);
        a.assemble()
    }

    /// A cluster whose DM core streams `words` words in from main
    /// memory, then halts.
    fn dma_cluster(words: u32) -> Cluster {
        let cfg = ConfigId::Base32Fc.cluster_config();
        let mut dm = Asm::new();
        dm.li(reg::A0, MAIN_MEM_BASE);
        dm.push(Instr::Dmsrc { rs1: reg::A0 });
        dm.li(reg::A1, TCDM_BASE);
        dm.push(Instr::Dmdst { rs1: reg::A1 });
        dm.li(reg::A2, words * 8);
        dm.push(Instr::Dmcpy { rd: reg::T0, rs1: reg::A2 });
        let poll = dm.label();
        dm.bind(poll);
        dm.push(Instr::Dmstat { rd: reg::T1 });
        dm.bne(reg::T1, 0, poll);
        dm.push(Instr::Ecall);
        let mut progs: Vec<Program> =
            (0..8).map(|_| empty_prog()).collect();
        progs.push(dm.assemble());
        let mut cl = Cluster::new(cfg, progs);
        let xs: Vec<f64> = (0..words).map(|i| i as f64).collect();
        cl.mem.write_slice_f64(MAIN_MEM_BASE, &xs);
        cl
    }

    #[test]
    fn noc_budget_math() {
        assert_eq!(NocConfig::default().budget(), 2);
        assert_eq!(
            NocConfig { links: 0, beats_per_link: 1 }.budget(),
            1,
            "budget never collapses to 0"
        );
        let f = FabricConfig::new(4);
        assert!((f.noc_factor() - 2.0).abs() < 1e-12);
        assert!((FabricConfig::single().noc_factor() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn saturated_noc_serializes_dma_branches() {
        // 4 DMA-only clusters behind a 1-beat/cycle NoC: the transfer
        // phase must stretch ~4x vs a private link, and every beat
        // still lands (data integrity under arbitration).
        let words = 64u32;
        let solo_cycles = {
            let mut fab = ClusterFabric::new(
                vec![dma_cluster(words)],
                NocConfig { links: 1, beats_per_link: 1 },
            );
            fab.run(100_000).unwrap()
        };
        let mut fab = ClusterFabric::new(
            (0..4).map(|_| dma_cluster(words)).collect(),
            NocConfig { links: 1, beats_per_link: 1 },
        );
        let cycles = fab.run(100_000).unwrap();
        // 4x8 beats over one link drain in 32 cycles vs 8 solo; allow
        // a little poll-loop granularity on the halt edge.
        assert!(
            cycles >= solo_cycles + 20,
            "4 branches over 1 link must serialize: {cycles} vs solo \
             {solo_cycles}"
        );
        assert!(fab.noc.denials > 0);
        assert!(fab.noc.saturated_cycles > 0);
        for cl in &fab.clusters {
            assert_eq!(cl.dma.bytes_moved, words as u64 * 8);
            for i in 0..words {
                assert_eq!(
                    cl.tcdm.read_f64(TCDM_BASE + i * 8),
                    i as f64,
                    "beat data must survive arbitration"
                );
            }
        }
    }

    #[test]
    fn single_cluster_fabric_matches_plain_run() {
        // The 1-cluster fabric is cycle-identical to Cluster::run —
        // the NoC gate must never insert bubbles on a private link.
        let mut plain = dma_cluster(64);
        let plain_cycles = plain.run(100_000).unwrap();
        let mut fab =
            ClusterFabric::new(vec![dma_cluster(64)], NocConfig::default());
        let fab_cycles = fab.run(100_000).unwrap();
        assert_eq!(fab_cycles, plain_cycles);
        assert_eq!(fab.noc.denials, 0);
    }

    #[test]
    fn round_robin_rotates_under_saturation() {
        // 2 clusters on a 1-beat link: grants must alternate, so both
        // finish within a beat of each other.
        let mut fab = ClusterFabric::new(
            vec![dma_cluster(64), dma_cluster(64)],
            NocConfig { links: 1, beats_per_link: 1 },
        );
        fab.run(100_000).unwrap();
        let halts: Vec<u64> =
            fab.clusters.iter().map(|c| c.cycle).collect();
        let spread = halts.iter().max().unwrap() - halts.iter().min().unwrap();
        assert!(
            spread <= 4,
            "fair round-robin keeps halt times together: {halts:?}"
        );
    }
}
