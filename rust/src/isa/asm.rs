//! A small assembler: symbolic labels over the [`Instr`] IR.
//!
//! The kernel code generator emits instructions through [`Asm`], using
//! labels for branch targets; `assemble()` resolves them to byte
//! offsets (instructions are 4 bytes) and produces a [`Program`] with
//! both the IR and the real RV32 encodings.

use std::collections::HashMap;

use super::encode::encode;
use super::{Instr, Program};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Label(usize);

#[derive(Clone, Debug)]
enum Item {
    Instr(Instr),
    /// Branch whose `off` field is patched from the label.
    Branch { template: Instr, target: Label },
}

#[derive(Default)]
pub struct Asm {
    items: Vec<Item>,
    labels: Vec<Option<usize>>, // label -> instruction index
    names: HashMap<String, Label>,
}

impl Asm {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create an unbound label.
    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    /// Create or look up a named label.
    pub fn named(&mut self, name: &str) -> Label {
        if let Some(&l) = self.names.get(name) {
            return l;
        }
        let l = self.label();
        self.names.insert(name.to_string(), l);
        l
    }

    /// Bind `label` to the current position.
    pub fn bind(&mut self, label: Label) {
        assert!(
            self.labels[label.0].is_none(),
            "label bound twice"
        );
        self.labels[label.0] = Some(self.items.len());
    }

    pub fn push(&mut self, i: Instr) -> &mut Self {
        self.items.push(Item::Instr(i));
        self
    }

    /// Current instruction index (for FREP body-length accounting).
    pub fn here(&self) -> usize {
        self.items.len()
    }

    // ---- branch helpers (offset patched at assembly) ----

    pub fn bne(&mut self, rs1: u8, rs2: u8, target: Label) -> &mut Self {
        self.items.push(Item::Branch {
            template: Instr::Bne { rs1, rs2, off: 0 },
            target,
        });
        self
    }

    pub fn beq(&mut self, rs1: u8, rs2: u8, target: Label) -> &mut Self {
        self.items.push(Item::Branch {
            template: Instr::Beq { rs1, rs2, off: 0 },
            target,
        });
        self
    }

    pub fn blt(&mut self, rs1: u8, rs2: u8, target: Label) -> &mut Self {
        self.items.push(Item::Branch {
            template: Instr::Blt { rs1, rs2, off: 0 },
            target,
        });
        self
    }

    pub fn jal(&mut self, rd: u8, target: Label) -> &mut Self {
        self.items.push(Item::Branch {
            template: Instr::Jal { rd, off: 0 },
            target,
        });
        self
    }

    /// Load a 32-bit immediate into `rd` (lui+addi as needed).
    pub fn li(&mut self, rd: u8, value: u32) -> &mut Self {
        let value = value as i32;
        let lo = (value << 20) >> 20; // sign-extended low 12 bits
        let hi = value.wrapping_sub(lo);
        if hi != 0 {
            self.push(Instr::Lui { rd, imm: hi });
            if lo != 0 {
                self.push(Instr::Addi { rd, rs1: rd, imm: lo });
            }
        } else {
            self.push(Instr::Addi { rd, rs1: 0, imm: lo });
        }
        self
    }

    pub fn assemble(self) -> Program {
        let resolve = |l: Label| -> usize {
            self.labels[l.0].expect("unbound label")
        };
        let mut instrs = Vec::with_capacity(self.items.len());
        for (idx, item) in self.items.iter().enumerate() {
            let i = match item {
                Item::Instr(i) => *i,
                Item::Branch { template, target } => {
                    let off = (resolve(*target) as i64 - idx as i64) * 4;
                    let off = i32::try_from(off).expect("branch too far");
                    match *template {
                        Instr::Beq { rs1, rs2, .. } => {
                            Instr::Beq { rs1, rs2, off }
                        }
                        Instr::Bne { rs1, rs2, .. } => {
                            Instr::Bne { rs1, rs2, off }
                        }
                        Instr::Blt { rs1, rs2, .. } => {
                            Instr::Blt { rs1, rs2, off }
                        }
                        Instr::Bge { rs1, rs2, .. } => {
                            Instr::Bge { rs1, rs2, off }
                        }
                        Instr::Jal { rd, .. } => Instr::Jal { rd, off },
                        other => unreachable!("not a branch: {other:?}"),
                    }
                }
            };
            instrs.push(i);
        }
        let words = instrs.iter().map(encode).collect();
        Program { instrs, words }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::decode::decode;

    #[test]
    fn forward_and_backward_branches() {
        let mut a = Asm::new();
        let top = a.label();
        let done = a.label();
        a.li(5, 3); // t0 = 3
        a.bind(top);
        a.push(Instr::Addi { rd: 5, rs1: 5, imm: -1 });
        a.beq(5, 0, done);
        a.bne(5, 0, top);
        a.bind(done);
        a.push(Instr::Ecall);
        let p = a.assemble();
        // li(3) is one addi; program: addi, addi, beq, bne, ecall
        assert_eq!(p.len(), 5);
        match p.instrs[2] {
            Instr::Beq { off, .. } => assert_eq!(off, 8), // 2 instrs fwd
            ref other => panic!("{other:?}"),
        }
        match p.instrs[3] {
            Instr::Bne { off, .. } => assert_eq!(off, -8), // 2 instrs back
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn li_small_and_large() {
        let mut a = Asm::new();
        a.li(1, 42);
        a.li(2, 0x1234_5678);
        a.li(3, 0x8000_0000);
        let p = a.assemble();
        // 42 -> addi; 0x12345678 -> lui+addi; 0x80000000 -> lui only
        assert_eq!(p.len(), 4);
        // Execute mentally: check encodings decode back.
        for (i, w) in p.instrs.iter().zip(&p.words) {
            assert_eq!(decode(*w).as_ref(), Some(i));
        }
    }

    #[test]
    fn li_negative_low_carry() {
        // Values whose low 12 bits are >= 0x800 need the +1 carry in hi.
        let mut a = Asm::new();
        a.li(1, 0x0000_0FFF);
        a.li(2, 0xFFFF_FFFF);
        let p = a.assemble();
        // Simulate the add to verify values.
        let mut regs = [0u32; 32];
        for i in &p.instrs {
            match *i {
                Instr::Lui { rd, imm } => regs[rd as usize] = imm as u32,
                Instr::Addi { rd, rs1, imm } => {
                    regs[rd as usize] =
                        regs[rs1 as usize].wrapping_add(imm as u32)
                }
                _ => {}
            }
        }
        assert_eq!(regs[1], 0x0000_0FFF);
        assert_eq!(regs[2], 0xFFFF_FFFF);
    }

    #[test]
    #[should_panic(expected = "unbound label")]
    fn unbound_label_panics() {
        let mut a = Asm::new();
        let l = a.label();
        a.bne(1, 2, l);
        let _ = a.assemble();
    }

    #[test]
    fn named_labels_dedupe() {
        let mut a = Asm::new();
        let l1 = a.named("loop");
        let l2 = a.named("loop");
        assert_eq!(l1, l2);
    }
}
