//! RV32 instruction decoding — the inverse of [`super::encode`].

use super::{Instr, SsrField};

fn rd(w: u32) -> u8 {
    ((w >> 7) & 0x1F) as u8
}

fn rs1(w: u32) -> u8 {
    ((w >> 15) & 0x1F) as u8
}

fn rs2(w: u32) -> u8 {
    ((w >> 20) & 0x1F) as u8
}

fn rs3(w: u32) -> u8 {
    ((w >> 27) & 0x1F) as u8
}

fn f3(w: u32) -> u32 {
    (w >> 12) & 0x7
}

fn f7(w: u32) -> u32 {
    w >> 25
}

fn imm_i(w: u32) -> i32 {
    (w as i32) >> 20
}

fn imm_s(w: u32) -> i32 {
    (((w as i32) >> 25) << 5) | ((w >> 7) & 0x1F) as i32
}

fn imm_b(w: u32) -> i32 {
    let sign = (w as i32) >> 31; // imm[12]
    ((sign << 12)
        | (((w >> 7) & 1) as i32) << 11
        | (((w >> 25) & 0x3F) as i32) << 5
        | (((w >> 8) & 0xF) as i32) << 1) as i32
}

fn imm_u(w: u32) -> i32 {
    (w & 0xFFFF_F000) as i32
}

fn imm_j(w: u32) -> i32 {
    let sign = (w as i32) >> 31; // imm[20]
    (sign << 20)
        | ((((w >> 12) & 0xFF) as i32) << 12)
        | ((((w >> 20) & 1) as i32) << 11)
        | ((((w >> 21) & 0x3FF) as i32) << 1)
}

/// Decode a 32-bit word; `None` for encodings outside the supported set.
pub fn decode(w: u32) -> Option<Instr> {
    use Instr::*;
    let op = w & 0x7F;
    Some(match op {
        0b0110111 => Lui { rd: rd(w), imm: imm_u(w) },
        0b0010111 => Auipc { rd: rd(w), imm: imm_u(w) },
        0b1101111 => Jal { rd: rd(w), off: imm_j(w) },
        0b0010011 => match f3(w) {
            0b000 => {
                if w == 0x0000_0013 {
                    Nop
                } else {
                    Addi { rd: rd(w), rs1: rs1(w), imm: imm_i(w) }
                }
            }
            0b001 => Slli { rd: rd(w), rs1: rs1(w), shamt: rs2(w) },
            0b101 => Srli { rd: rd(w), rs1: rs1(w), shamt: rs2(w) },
            0b111 => Andi { rd: rd(w), rs1: rs1(w), imm: imm_i(w) },
            _ => return None,
        },
        0b0110011 => match (f7(w), f3(w)) {
            (0b0000000, 0b000) => Add { rd: rd(w), rs1: rs1(w), rs2: rs2(w) },
            (0b0100000, 0b000) => Sub { rd: rd(w), rs1: rs1(w), rs2: rs2(w) },
            (0b0000001, 0b000) => Mul { rd: rd(w), rs1: rs1(w), rs2: rs2(w) },
            _ => return None,
        },
        0b1100011 => {
            let (r1, r2, off) = (rs1(w), rs2(w), imm_b(w));
            match f3(w) {
                0b000 => Beq { rs1: r1, rs2: r2, off },
                0b001 => Bne { rs1: r1, rs2: r2, off },
                0b100 => Blt { rs1: r1, rs2: r2, off },
                0b101 => Bge { rs1: r1, rs2: r2, off },
                _ => return None,
            }
        }
        0b0000011 if f3(w) == 0b010 => {
            Lw { rd: rd(w), rs1: rs1(w), imm: imm_i(w) }
        }
        0b0100011 if f3(w) == 0b010 => {
            Sw { rs2: rs2(w), rs1: rs1(w), imm: imm_s(w) }
        }
        0b1110011 => {
            let csr = (w >> 20) as u16;
            match f3(w) {
                0b000 if w == 0x0000_0073 => Ecall,
                0b001 => Csrrw { rd: rd(w), csr, rs1: rs1(w) },
                0b010 => Csrrs { rd: rd(w), csr, rs1: rs1(w) },
                0b110 => Csrrsi { csr, imm: rs1(w) },
                0b111 => Csrrci { csr, imm: rs1(w) },
                _ => return None,
            }
        }
        0b0000111 if f3(w) == 0b011 => {
            Fld { frd: rd(w), rs1: rs1(w), imm: imm_i(w) }
        }
        0b0100111 if f3(w) == 0b011 => {
            Fsd { frs2: rs2(w), rs1: rs1(w), imm: imm_s(w) }
        }
        0b1000011 if (w >> 25) & 0x3 == 0b01 => FmaddD {
            frd: rd(w),
            frs1: rs1(w),
            frs2: rs2(w),
            frs3: rs3(w),
        },
        0b1010011 => match f7(w) {
            0b0000001 => FaddD { frd: rd(w), frs1: rs1(w), frs2: rs2(w) },
            0b0000101 => FsubD { frd: rd(w), frs1: rs1(w), frs2: rs2(w) },
            0b0001001 => FmulD { frd: rd(w), frs1: rs1(w), frs2: rs2(w) },
            0b0010101 if f3(w) == 0b001 => {
                FmaxD { frd: rd(w), frs1: rs1(w), frs2: rs2(w) }
            }
            0b0010001 if f3(w) == 0 => {
                FsgnjD { frd: rd(w), frs1: rs1(w), frs2: rs2(w) }
            }
            0b1111111 if f3(w) == 0b001 && rs2(w) == 0 => {
                FgeluD { frd: rd(w), frs1: rs1(w) }
            }
            0b1101001 if rs2(w) == 0 => FcvtDW { frd: rd(w), rs1: rs1(w) },
            _ => return None,
        },
        // custom-1: FREP
        0b0101011 => Frep {
            outer: f3(w) == 0,
            iters_reg: rs1(w),
            n_inst: (imm_i(w) & 0xFF) as u8,
        },
        // custom-2: scfgw
        0b1011011 if f3(w) == 0b010 => {
            let imm = imm_i(w);
            SsrCfgW {
                value: rs1(w),
                ssr: (imm & 0x7) as u8,
                field: SsrField::from_word(((imm >> 3) & 0x1F) as u8)?,
            }
        }
        // custom-0: Xdma + barrier
        0b0001011 => match f3(w) {
            0b000 => Dmsrc { rs1: rs1(w) },
            0b001 => Dmdst { rs1: rs1(w) },
            0b010 if f7(w) == 0 => Dmstr { rs1: rs1(w), rs2: rs2(w) },
            0b010 if f7(w) == 1 => Dmstr2 { rs1: rs1(w), rs2: rs2(w) },
            0b011 if f7(w) == 0 => Dmrep { rs1: rs1(w) },
            0b011 if f7(w) == 1 => Dmrep2 { rs1: rs1(w) },
            0b100 => Dmcpy { rd: rd(w), rs1: rs1(w) },
            0b101 => Dmstat { rd: rd(w) },
            0b110 => Barrier,
            _ => return None,
        },
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::super::encode::encode;
    use super::*;
    use crate::isa::SsrField;

    fn roundtrip(i: Instr) {
        let w = encode(&i);
        assert_eq!(decode(w), Some(i), "word {w:#010x}");
    }

    #[test]
    fn roundtrip_integer() {
        roundtrip(Instr::Lui { rd: 3, imm: 0x7FFF_F000u32 as i32 });
        roundtrip(Instr::Auipc { rd: 4, imm: 0x1000 });
        roundtrip(Instr::Addi { rd: 1, rs1: 2, imm: -42 });
        roundtrip(Instr::Slli { rd: 1, rs1: 2, shamt: 31 });
        roundtrip(Instr::Srli { rd: 1, rs1: 2, shamt: 3 });
        roundtrip(Instr::Andi { rd: 9, rs1: 8, imm: 255 });
        roundtrip(Instr::Add { rd: 5, rs1: 6, rs2: 7 });
        roundtrip(Instr::Sub { rd: 5, rs1: 6, rs2: 7 });
        roundtrip(Instr::Mul { rd: 5, rs1: 6, rs2: 7 });
    }

    #[test]
    fn roundtrip_control() {
        roundtrip(Instr::Beq { rs1: 1, rs2: 2, off: -4096 });
        roundtrip(Instr::Bne { rs1: 1, rs2: 2, off: 4094 });
        roundtrip(Instr::Blt { rs1: 3, rs2: 4, off: -2 });
        roundtrip(Instr::Bge { rs1: 3, rs2: 4, off: 2048 });
        roundtrip(Instr::Jal { rd: 1, off: -1048576 });
        roundtrip(Instr::Jal { rd: 0, off: 1048574 });
    }

    #[test]
    fn roundtrip_memory_csr() {
        roundtrip(Instr::Lw { rd: 1, rs1: 2, imm: 2047 });
        roundtrip(Instr::Sw { rs2: 1, rs1: 2, imm: -2048 });
        roundtrip(Instr::Csrrw { rd: 0, csr: 0x7C0, rs1: 5 });
        roundtrip(Instr::Csrrs { rd: 3, csr: 0xB00, rs1: 0 });
        roundtrip(Instr::Csrrsi { csr: 0x7C0, imm: 1 });
        roundtrip(Instr::Csrrci { csr: 0x7C0, imm: 1 });
        roundtrip(Instr::Ecall);
        roundtrip(Instr::Nop);
    }

    #[test]
    fn roundtrip_fp() {
        roundtrip(Instr::Fld { frd: 31, rs1: 2, imm: 8 });
        roundtrip(Instr::Fsd { frs2: 30, rs1: 2, imm: -8 });
        roundtrip(Instr::FmaddD { frd: 10, frs1: 0, frs2: 1, frs3: 10 });
        roundtrip(Instr::FmulD { frd: 11, frs1: 0, frs2: 1 });
        roundtrip(Instr::FaddD { frd: 12, frs1: 13, frs2: 14 });
        roundtrip(Instr::FsubD { frd: 12, frs1: 13, frs2: 14 });
        roundtrip(Instr::FmaxD { frd: 2, frs1: 18, frs2: 9 });
        roundtrip(Instr::FgeluD { frd: 2, frs1: 10 });
        roundtrip(Instr::FsgnjD { frd: 15, frs1: 16, frs2: 16 });
        roundtrip(Instr::FcvtDW { frd: 17, rs1: 9 });
    }

    #[test]
    fn roundtrip_snitch_custom() {
        roundtrip(Instr::Frep { outer: true, iters_reg: 5, n_inst: 7 });
        roundtrip(Instr::Frep { outer: false, iters_reg: 6, n_inst: 23 });
        roundtrip(Instr::SsrCfgW {
            value: 9,
            ssr: 2,
            field: SsrField::Stride(3),
        });
        roundtrip(Instr::SsrCfgW {
            value: 9,
            ssr: 0,
            field: SsrField::ReadBase(3),
        });
        roundtrip(Instr::Dmsrc { rs1: 10 });
        roundtrip(Instr::Dmdst { rs1: 11 });
        roundtrip(Instr::Dmstr { rs1: 12, rs2: 13 });
        roundtrip(Instr::Dmrep { rs1: 14 });
        roundtrip(Instr::Dmstr2 { rs1: 12, rs2: 13 });
        roundtrip(Instr::Dmrep2 { rs1: 14 });
        roundtrip(Instr::Dmcpy { rd: 15, rs1: 16 });
        roundtrip(Instr::Dmstat { rd: 17 });
        roundtrip(Instr::Barrier);
    }

    #[test]
    fn garbage_decodes_to_none() {
        assert_eq!(decode(0xFFFF_FFFF), None);
        assert_eq!(decode(0x0000_0000), None);
    }
}
