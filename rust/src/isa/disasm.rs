//! Disassembler — used by execution traces and debugging output.

use super::{Instr, SsrField};

fn x(r: u8) -> String {
    format!("x{r}")
}

fn f(r: u8) -> String {
    format!("f{r}")
}

/// Render one instruction in a GNU-as-like syntax.
pub fn disasm(i: &Instr) -> String {
    use Instr::*;
    match *i {
        Lui { rd, imm } => format!("lui {}, {:#x}", x(rd), (imm as u32) >> 12),
        Auipc { rd, imm } => format!("auipc {}, {:#x}", x(rd), imm),
        Addi { rd, rs1, imm } => format!("addi {}, {}, {}", x(rd), x(rs1), imm),
        Slli { rd, rs1, shamt } => {
            format!("slli {}, {}, {}", x(rd), x(rs1), shamt)
        }
        Srli { rd, rs1, shamt } => {
            format!("srli {}, {}, {}", x(rd), x(rs1), shamt)
        }
        Andi { rd, rs1, imm } => format!("andi {}, {}, {}", x(rd), x(rs1), imm),
        Add { rd, rs1, rs2 } => format!("add {}, {}, {}", x(rd), x(rs1), x(rs2)),
        Sub { rd, rs1, rs2 } => format!("sub {}, {}, {}", x(rd), x(rs1), x(rs2)),
        Mul { rd, rs1, rs2 } => format!("mul {}, {}, {}", x(rd), x(rs1), x(rs2)),
        Beq { rs1, rs2, off } => format!("beq {}, {}, {}", x(rs1), x(rs2), off),
        Bne { rs1, rs2, off } => format!("bne {}, {}, {}", x(rs1), x(rs2), off),
        Blt { rs1, rs2, off } => format!("blt {}, {}, {}", x(rs1), x(rs2), off),
        Bge { rs1, rs2, off } => format!("bge {}, {}, {}", x(rs1), x(rs2), off),
        Jal { rd, off } => format!("jal {}, {}", x(rd), off),
        Lw { rd, rs1, imm } => format!("lw {}, {}({})", x(rd), imm, x(rs1)),
        Sw { rs2, rs1, imm } => format!("sw {}, {}({})", x(rs2), imm, x(rs1)),
        Csrrw { rd, csr, rs1 } => {
            format!("csrrw {}, {:#x}, {}", x(rd), csr, x(rs1))
        }
        Csrrs { rd, csr, rs1 } => {
            format!("csrrs {}, {:#x}, {}", x(rd), csr, x(rs1))
        }
        Csrrsi { csr, imm } => format!("csrrsi x0, {csr:#x}, {imm}"),
        Csrrci { csr, imm } => format!("csrrci x0, {csr:#x}, {imm}"),
        Fld { frd, rs1, imm } => format!("fld {}, {}({})", f(frd), imm, x(rs1)),
        Fsd { frs2, rs1, imm } => {
            format!("fsd {}, {}({})", f(frs2), imm, x(rs1))
        }
        FmaddD { frd, frs1, frs2, frs3 } => format!(
            "fmadd.d {}, {}, {}, {}",
            f(frd), f(frs1), f(frs2), f(frs3)
        ),
        FmulD { frd, frs1, frs2 } => {
            format!("fmul.d {}, {}, {}", f(frd), f(frs1), f(frs2))
        }
        FaddD { frd, frs1, frs2 } => {
            format!("fadd.d {}, {}, {}", f(frd), f(frs1), f(frs2))
        }
        FsubD { frd, frs1, frs2 } => {
            format!("fsub.d {}, {}, {}", f(frd), f(frs1), f(frs2))
        }
        FmaxD { frd, frs1, frs2 } => {
            format!("fmax.d {}, {}, {}", f(frd), f(frs1), f(frs2))
        }
        FgeluD { frd, frs1 } => {
            format!("fgelu.d {}, {}", f(frd), f(frs1))
        }
        FsgnjD { frd, frs1, frs2 } if frs1 == frs2 => {
            format!("fmv.d {}, {}", f(frd), f(frs1))
        }
        FsgnjD { frd, frs1, frs2 } => {
            format!("fsgnj.d {}, {}, {}", f(frd), f(frs1), f(frs2))
        }
        FcvtDW { frd, rs1 } => format!("fcvt.d.w {}, {}", f(frd), x(rs1)),
        Frep { outer, iters_reg, n_inst } => format!(
            "frep.{} {}, {}",
            if outer { "o" } else { "i" },
            x(iters_reg),
            n_inst
        ),
        SsrCfgW { value, ssr, field } => {
            let fname = match field {
                SsrField::Repeat => "repeat".to_string(),
                SsrField::Bound(d) => format!("bound[{d}]"),
                SsrField::Stride(d) => format!("stride[{d}]"),
                SsrField::ReadBase(d) => format!("rbase.{}d", d + 1),
                SsrField::WriteBase(d) => format!("wbase.{}d", d + 1),
            };
            format!("scfgw {}, ssr{ssr}.{fname}", x(value))
        }
        Dmsrc { rs1 } => format!("dmsrc {}", x(rs1)),
        Dmdst { rs1 } => format!("dmdst {}", x(rs1)),
        Dmstr { rs1, rs2 } => format!("dmstr {}, {}", x(rs1), x(rs2)),
        Dmrep { rs1 } => format!("dmrep {}", x(rs1)),
        Dmstr2 { rs1, rs2 } => format!("dmstr2 {}, {}", x(rs1), x(rs2)),
        Dmrep2 { rs1 } => format!("dmrep2 {}", x(rs1)),
        Dmcpy { rd, rs1 } => format!("dmcpy {}, {}", x(rd), x(rs1)),
        Dmstat { rd } => format!("dmstat {}", x(rd)),
        Barrier => "barrier".to_string(),
        Ecall => "ecall".to_string(),
        Nop => "nop".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readable_output() {
        assert_eq!(
            disasm(&Instr::FmaddD { frd: 10, frs1: 0, frs2: 1, frs3: 10 }),
            "fmadd.d f10, f0, f1, f10"
        );
        assert_eq!(
            disasm(&Instr::Frep { outer: true, iters_reg: 5, n_inst: 8 }),
            "frep.o x5, 8"
        );
        assert_eq!(
            disasm(&Instr::FsgnjD { frd: 3, frs1: 4, frs2: 4 }),
            "fmv.d f3, f4"
        );
        assert_eq!(disasm(&Instr::Barrier), "barrier");
    }
}
