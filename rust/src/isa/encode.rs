//! RV32 instruction encoding.
//!
//! Standard RV32IMFD encodings follow the ISA manual. Snitch custom
//! extensions occupy the custom opcode spaces:
//!
//! * `custom-1` (0b0101011): FREP — `funct3`=0 outer / 1 inner,
//!   rs1 = iteration-count register, imm[11:0] = body length - 1.
//!   (Upstream Snitch packs stagger fields too; we retain the register/
//!   body-length fields and drop staggering, which the paper never uses.)
//! * `custom-2` (0b1011011): `scfgw` — rs1 = value,
//!   imm[11:0] = ssr | field<<3.
//! * `custom-0` (0b0001011): Xdma + barrier, distinguished by funct3:
//!   0 dmsrc, 1 dmdst, 2 dmstr, 3 dmrep, 4 dmcpy, 5 dmstat, 6 barrier;
//!   funct7=1 on funct3 2/3 selects the 3rd-dimension variants
//!   (dmstr2/dmrep2).

use super::Instr;

const OP_LUI: u32 = 0b0110111;
const OP_AUIPC: u32 = 0b0010111;
const OP_JAL: u32 = 0b1101111;
const OP_BRANCH: u32 = 0b1100011;
const OP_LOAD: u32 = 0b0000011;
const OP_STORE: u32 = 0b0100011;
const OP_IMM: u32 = 0b0010011;
const OP_OP: u32 = 0b0110011;
const OP_SYSTEM: u32 = 0b1110011;
const OP_LOAD_FP: u32 = 0b0000111;
const OP_STORE_FP: u32 = 0b0100111;
const OP_MADD: u32 = 0b1000011;
const OP_FP: u32 = 0b1010011;
const OP_CUSTOM0: u32 = 0b0001011;
const OP_CUSTOM1: u32 = 0b0101011;
const OP_CUSTOM2: u32 = 0b1011011;

fn r_type(f7: u32, rs2: u8, rs1: u8, f3: u32, rd: u8, op: u32) -> u32 {
    (f7 << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | ((rd as u32) << 7)
        | op
}

fn i_type(imm: i32, rs1: u8, f3: u32, rd: u8, op: u32) -> u32 {
    (((imm as u32) & 0xFFF) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | ((rd as u32) << 7)
        | op
}

fn s_type(imm: i32, rs2: u8, rs1: u8, f3: u32, op: u32) -> u32 {
    let imm = imm as u32;
    (((imm >> 5) & 0x7F) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | ((imm & 0x1F) << 7)
        | op
}

fn b_type(off: i32, rs2: u8, rs1: u8, f3: u32, op: u32) -> u32 {
    let o = off as u32;
    (((o >> 12) & 1) << 31)
        | (((o >> 5) & 0x3F) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | (((o >> 1) & 0xF) << 8)
        | (((o >> 11) & 1) << 7)
        | op
}

fn u_type(imm: i32, rd: u8, op: u32) -> u32 {
    ((imm as u32) & 0xFFFF_F000) | ((rd as u32) << 7) | op
}

fn j_type(off: i32, rd: u8, op: u32) -> u32 {
    let o = off as u32;
    (((o >> 20) & 1) << 31)
        | (((o >> 1) & 0x3FF) << 21)
        | (((o >> 11) & 1) << 20)
        | (((o >> 12) & 0xFF) << 12)
        | ((rd as u32) << 7)
        | op
}

fn r4_type(rs3: u8, fmt: u32, rs2: u8, rs1: u8, f3: u32, rd: u8,
           op: u32) -> u32 {
    ((rs3 as u32) << 27)
        | (fmt << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | ((rd as u32) << 7)
        | op
}

fn csr_type(csr: u16, rs1_or_imm: u8, f3: u32, rd: u8) -> u32 {
    ((csr as u32) << 20)
        | ((rs1_or_imm as u32) << 15)
        | (f3 << 12)
        | ((rd as u32) << 7)
        | OP_SYSTEM
}

/// Encode one instruction to its 32-bit word.
pub fn encode(i: &Instr) -> u32 {
    use Instr::*;
    match *i {
        Lui { rd, imm } => u_type(imm, rd, OP_LUI),
        Auipc { rd, imm } => u_type(imm, rd, OP_AUIPC),
        Addi { rd, rs1, imm } => i_type(imm, rs1, 0b000, rd, OP_IMM),
        Slli { rd, rs1, shamt } => {
            i_type(shamt as i32, rs1, 0b001, rd, OP_IMM)
        }
        Srli { rd, rs1, shamt } => {
            i_type(shamt as i32, rs1, 0b101, rd, OP_IMM)
        }
        Andi { rd, rs1, imm } => i_type(imm, rs1, 0b111, rd, OP_IMM),
        Add { rd, rs1, rs2 } => r_type(0, rs2, rs1, 0b000, rd, OP_OP),
        Sub { rd, rs1, rs2 } => {
            r_type(0b0100000, rs2, rs1, 0b000, rd, OP_OP)
        }
        Mul { rd, rs1, rs2 } => {
            r_type(0b0000001, rs2, rs1, 0b000, rd, OP_OP)
        }
        Beq { rs1, rs2, off } => b_type(off, rs2, rs1, 0b000, OP_BRANCH),
        Bne { rs1, rs2, off } => b_type(off, rs2, rs1, 0b001, OP_BRANCH),
        Blt { rs1, rs2, off } => b_type(off, rs2, rs1, 0b100, OP_BRANCH),
        Bge { rs1, rs2, off } => b_type(off, rs2, rs1, 0b101, OP_BRANCH),
        Jal { rd, off } => j_type(off, rd, OP_JAL),
        Lw { rd, rs1, imm } => i_type(imm, rs1, 0b010, rd, OP_LOAD),
        Sw { rs2, rs1, imm } => s_type(imm, rs2, rs1, 0b010, OP_STORE),
        Csrrw { rd, csr, rs1 } => csr_type(csr, rs1, 0b001, rd),
        Csrrs { rd, csr, rs1 } => csr_type(csr, rs1, 0b010, rd),
        Csrrsi { csr, imm } => csr_type(csr, imm, 0b110, 0),
        Csrrci { csr, imm } => csr_type(csr, imm, 0b111, 0),
        Fld { frd, rs1, imm } => i_type(imm, rs1, 0b011, frd, OP_LOAD_FP),
        Fsd { frs2, rs1, imm } => {
            s_type(imm, frs2, rs1, 0b011, OP_STORE_FP)
        }
        FmaddD { frd, frs1, frs2, frs3 } => {
            r4_type(frs3, 0b01, frs2, frs1, 0b111, frd, OP_MADD)
        }
        FmulD { frd, frs1, frs2 } => {
            r_type(0b0001001, frs2, frs1, 0b111, frd, OP_FP)
        }
        FaddD { frd, frs1, frs2 } => {
            r_type(0b0000001, frs2, frs1, 0b111, frd, OP_FP)
        }
        FsubD { frd, frs1, frs2 } => {
            r_type(0b0000101, frs2, frs1, 0b111, frd, OP_FP)
        }
        FmaxD { frd, frs1, frs2 } => {
            r_type(0b0010101, frs2, frs1, 0b001, frd, OP_FP)
        }
        FsgnjD { frd, frs1, frs2 } => {
            r_type(0b0010001, frs2, frs1, 0b000, frd, OP_FP)
        }
        // Activation-unit extension (deviation: upstream Snitch has no
        // GeLU op; we claim the reserved funct7=0b1111111/funct3=001
        // point of the OP-FP space for the fused-epilogue unit).
        FgeluD { frd, frs1 } => {
            r_type(0b1111111, 0, frs1, 0b001, frd, OP_FP)
        }
        FcvtDW { frd, rs1 } => {
            r_type(0b1101001, 0, rs1, 0b000, frd, OP_FP)
        }
        Frep { outer, iters_reg, n_inst } => i_type(
            n_inst as i32,
            iters_reg,
            if outer { 0b000 } else { 0b001 },
            0,
            OP_CUSTOM1,
        ),
        SsrCfgW { value, ssr, field } => i_type(
            (ssr as i32) | ((field.to_word() as i32) << 3),
            value,
            0b010,
            0,
            OP_CUSTOM2,
        ),
        Dmsrc { rs1 } => r_type(0, 0, rs1, 0b000, 0, OP_CUSTOM0),
        Dmdst { rs1 } => r_type(0, 0, rs1, 0b001, 0, OP_CUSTOM0),
        Dmstr { rs1, rs2 } => r_type(0, rs2, rs1, 0b010, 0, OP_CUSTOM0),
        Dmrep { rs1 } => r_type(0, 0, rs1, 0b011, 0, OP_CUSTOM0),
        Dmstr2 { rs1, rs2 } => {
            r_type(1, rs2, rs1, 0b010, 0, OP_CUSTOM0)
        }
        Dmrep2 { rs1 } => r_type(1, 0, rs1, 0b011, 0, OP_CUSTOM0),
        Dmcpy { rd, rs1 } => r_type(0, 0, rs1, 0b100, rd, OP_CUSTOM0),
        Dmstat { rd } => r_type(0, 0, 0, 0b101, rd, OP_CUSTOM0),
        Barrier => r_type(0, 0, 0, 0b110, 0, OP_CUSTOM0),
        Ecall => i_type(0, 0, 0b000, 0, OP_SYSTEM),
        Nop => i_type(0, 0, 0b000, 0, OP_IMM),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_encodings() {
        // Cross-checked against riscv-gnu-toolchain output.
        // addi x1, x2, 42 -> 0x02A10093
        assert_eq!(
            encode(&Instr::Addi { rd: 1, rs1: 2, imm: 42 }),
            0x02A1_0093
        );
        // lui x5, 0x12345000 -> 0x123452B7
        assert_eq!(
            encode(&Instr::Lui { rd: 5, imm: 0x12345 << 12 }),
            0x1234_52B7
        );
        // lw x6, 8(x7) -> 0x0083A303
        assert_eq!(encode(&Instr::Lw { rd: 6, rs1: 7, imm: 8 }), 0x0083_A303);
        // sw x6, 12(x7) -> 0x0063A623
        assert_eq!(
            encode(&Instr::Sw { rs2: 6, rs1: 7, imm: 12 }),
            0x0063_A623
        );
        // fmadd.d f10, f0, f1, f10 -> rs3=01010 fmt=01
        let w = encode(&Instr::FmaddD { frd: 10, frs1: 0, frs2: 1,
                                        frs3: 10 });
        assert_eq!(w & 0x7F, 0b1000011);
        assert_eq!((w >> 27) & 0x1F, 10);
        // nop == addi x0,x0,0 -> 0x00000013
        assert_eq!(encode(&Instr::Nop), 0x0000_0013);
        // ecall -> 0x00000073
        assert_eq!(encode(&Instr::Ecall), 0x0000_0073);
    }

    #[test]
    fn branch_offset_bits() {
        // bne x1, x2, -4: B-type immediate encoding of -4.
        let w = encode(&Instr::Bne { rs1: 1, rs2: 2, off: -4 });
        assert_eq!(w & 0x7F, 0b1100011);
        assert_eq!((w >> 12) & 0x7, 0b001);
        // negative offsets set the sign bit (imm[12] at bit 31).
        assert_eq!(w >> 31, 1);
    }
}
