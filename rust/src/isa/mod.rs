//! Instruction-set definition for the simulated Snitch cluster.
//!
//! The simulator executes the decoded [`Instr`] IR directly; real 32-bit
//! RV32IMFD encodings (plus the Snitch custom-opcode extensions: FREP,
//! SSR config, Xdma, cluster barrier) are provided by [`encode`] /
//! [`decode`] and round-trip tested, so generated kernels are genuine
//! RISC-V instruction streams, not an ad-hoc VM.
//!
//! Deviations from upstream Snitch encodings are documented next to each
//! custom instruction in `encode.rs`.

pub mod asm;
pub mod decode;
pub mod disasm;
pub mod encode;

/// Integer register index (x0..x31). x0 is hardwired to zero.
pub type IReg = u8;
/// FP register index (f0..f31). f0..f2 double as SSR streams ft0..ft2.
pub type FReg = u8;

/// ABI names used by the kernel generator.
pub mod reg {
    pub const ZERO: u8 = 0;
    pub const RA: u8 = 1;
    pub const SP: u8 = 2;
    pub const T0: u8 = 5;
    pub const T1: u8 = 6;
    pub const T2: u8 = 7;
    pub const A0: u8 = 10;
    pub const A1: u8 = 11;
    pub const A2: u8 = 12;
    pub const A3: u8 = 13;
    pub const A4: u8 = 14;
    pub const A5: u8 = 15;
    // FP: ft0-ft3 are the SSR-mapped streams.
    pub const FT0: u8 = 0;
    pub const FT1: u8 = 1;
    pub const FT2: u8 = 2;
    /// ft3: the 4th SSR stream — the fused-epilogue bias operand.
    pub const FT3: u8 = 3;
    /// f9: holds 0.0 for the ReLU writeback row (`fmax.d ft2, x, f9`).
    pub const FZERO: u8 = 9;
    /// fa0..: accumulator registers used by the matmul kernels (c0..c7
    /// in Fig. 1b of the paper).
    pub const FA0: u8 = 10;
}

/// CSR addresses (Snitch custom space).
pub mod csr {
    /// SSR enable bit (bit 0). `csrrsi ssr, 1` / `csrrci ssr, 1`.
    pub const SSR_ENABLE: u16 = 0x7C0;
    /// Cycle counter (read-only).
    pub const MCYCLE: u16 = 0xB00;
    /// Hart id.
    pub const MHARTID: u16 = 0xF14;
}

/// SSR configuration fields (written via `scfgw`).
/// Word layout mirrors the Snitch SSR config address space: the 12-bit
/// immediate selects `(field, ssr)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SsrField {
    /// Element repeat count: each streamed element is served `n+1`
    /// times before the address generator advances (Fig. 1b streams the
    /// same A element to all `unroll` fmadds this way).
    Repeat,
    /// Loop bound for dimension d (iterations - 1).
    Bound(u8),
    /// Byte stride for dimension d.
    Stride(u8),
    /// Write the stream base address and ARM the stream for reading
    /// with `d+1` active dimensions.
    ReadBase(u8),
    /// Write the stream base address and ARM the stream for writing
    /// with `d+1` active dimensions.
    WriteBase(u8),
}

impl SsrField {
    pub fn to_word(self) -> u8 {
        match self {
            SsrField::Repeat => 1,
            SsrField::Bound(d) => 2 + d,
            SsrField::Stride(d) => 6 + d,
            SsrField::ReadBase(d) => 24 + d,
            SsrField::WriteBase(d) => 28 + d,
        }
    }

    pub fn from_word(w: u8) -> Option<Self> {
        Some(match w {
            1 => SsrField::Repeat,
            2..=5 => SsrField::Bound(w - 2),
            6..=9 => SsrField::Stride(w - 6),
            24..=27 => SsrField::ReadBase(w - 24),
            28..=31 => SsrField::WriteBase(w - 28),
            _ => return None,
        })
    }
}

/// Decoded instruction IR.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Instr {
    // ---- RV32I ----
    Lui { rd: IReg, imm: i32 },
    Auipc { rd: IReg, imm: i32 },
    Addi { rd: IReg, rs1: IReg, imm: i32 },
    Slli { rd: IReg, rs1: IReg, shamt: u8 },
    Srli { rd: IReg, rs1: IReg, shamt: u8 },
    Andi { rd: IReg, rs1: IReg, imm: i32 },
    Add { rd: IReg, rs1: IReg, rs2: IReg },
    Sub { rd: IReg, rs1: IReg, rs2: IReg },
    // ---- RV32M ----
    Mul { rd: IReg, rs1: IReg, rs2: IReg },
    // ---- control flow ----
    Beq { rs1: IReg, rs2: IReg, off: i32 },
    Bne { rs1: IReg, rs2: IReg, off: i32 },
    Blt { rs1: IReg, rs2: IReg, off: i32 },
    Bge { rs1: IReg, rs2: IReg, off: i32 },
    Jal { rd: IReg, off: i32 },
    // ---- memory ----
    Lw { rd: IReg, rs1: IReg, imm: i32 },
    Sw { rs2: IReg, rs1: IReg, imm: i32 },
    // ---- CSR ----
    Csrrw { rd: IReg, csr: u16, rs1: IReg },
    Csrrs { rd: IReg, csr: u16, rs1: IReg },
    Csrrsi { csr: u16, imm: u8 },
    Csrrci { csr: u16, imm: u8 },
    // ---- RV32D ----
    Fld { frd: FReg, rs1: IReg, imm: i32 },
    Fsd { frs2: FReg, rs1: IReg, imm: i32 },
    FmaddD { frd: FReg, frs1: FReg, frs2: FReg, frs3: FReg },
    FmulD { frd: FReg, frs1: FReg, frs2: FReg },
    FaddD { frd: FReg, frs1: FReg, frs2: FReg },
    FsubD { frd: FReg, frs1: FReg, frs2: FReg },
    /// fmax.d — the fused-ReLU writeback op (`fmax.d ft2, acc, f9`).
    FmaxD { frd: FReg, frs1: FReg, frs2: FReg },
    /// fsgnj.d frd, frs1, frs1 == fmv.d
    FsgnjD { frd: FReg, frs1: FReg, frs2: FReg },
    /// Custom activation-unit op: frd = gelu(frs1) (tanh approximation,
    /// see [`gelu`]). Real Snitch lowers GeLU to a software sequence;
    /// we model a single-issue activation FPU extension and document
    /// the deviation in encode.rs.
    FgeluD { frd: FReg, frs1: FReg },
    FcvtDW { frd: FReg, rs1: IReg },
    // ---- Snitch FREP (custom-1) ----
    /// Hardware loop: repeat the next `n_inst` FP instructions
    /// `iters_reg+1` times. `outer=false` (frep.i) is retained for
    /// encoding completeness; both map to the sequencer the same way in
    /// a nest (the paper keeps the original encoding [3]).
    Frep { outer: bool, iters_reg: IReg, n_inst: u8 },
    // ---- Snitch SSR config (custom-2) ----
    /// scfgw: write `rs1` to config word (`field`, `ssr`).
    SsrCfgW { value: IReg, ssr: u8, field: SsrField },
    // ---- Snitch Xdma (custom-0) ----
    /// Set DMA source address.
    Dmsrc { rs1: IReg },
    /// Set DMA destination address.
    Dmdst { rs1: IReg },
    /// Set 2D strides: rs1 = src stride, rs2 = dst stride (bytes).
    Dmstr { rs1: IReg, rs2: IReg },
    /// Set 2D repetition count.
    Dmrep { rs1: IReg },
    /// Set 3rd-dimension strides (iDMA-style N-D extension; upstream
    /// Snitch reaches N-D with software loops, we fold one level into
    /// the engine and document the deviation).
    Dmstr2 { rs1: IReg, rs2: IReg },
    /// Set 3rd-dimension repetition count.
    Dmrep2 { rs1: IReg },
    /// Launch: rs1 = inner size in bytes; rd receives transfer id.
    Dmcpy { rd: IReg, rs1: IReg },
    /// Poll: rd = number of in-flight transfers (0 == idle).
    Dmstat { rd: IReg },
    // ---- cluster ----
    /// Hardware barrier across all cluster cores (compute + DM).
    Barrier,
    /// End of program (halts the hart).
    Ecall,
    Nop,
}

impl Instr {
    /// Pure-FP data-path instruction (no integer RF source/dest)?
    /// These are category-2 in the paper's Fig. 2: they enter the FREP
    /// sequencer ring buffer and may be part of a loop body.
    pub fn is_fp_compute(&self) -> bool {
        matches!(
            self,
            Instr::FmaddD { .. }
                | Instr::FmulD { .. }
                | Instr::FaddD { .. }
                | Instr::FsubD { .. }
                | Instr::FmaxD { .. }
                | Instr::FsgnjD { .. }
                | Instr::FgeluD { .. }
        )
    }

    /// FP instruction with an integer-RF operand (category 3: bypasses
    /// the sequencer ring buffer, forwarded directly to the FPU).
    pub fn is_fp_bypass(&self) -> bool {
        matches!(
            self,
            Instr::Fld { .. } | Instr::Fsd { .. } | Instr::FcvtDW { .. }
        )
    }

    /// Any instruction handled by the FP subsystem.
    pub fn is_fp(&self) -> bool {
        self.is_fp_compute() || self.is_fp_bypass()
    }

    pub fn is_frep(&self) -> bool {
        matches!(self, Instr::Frep { .. })
    }

    /// Source FP registers read by this instruction (for SSR pops and
    /// the FP scoreboard).
    pub fn fp_sources(&self) -> [Option<FReg>; 3] {
        match *self {
            Instr::FmaddD { frs1, frs2, frs3, .. } => {
                [Some(frs1), Some(frs2), Some(frs3)]
            }
            Instr::FmulD { frs1, frs2, .. }
            | Instr::FaddD { frs1, frs2, .. }
            | Instr::FsubD { frs1, frs2, .. }
            | Instr::FmaxD { frs1, frs2, .. }
            | Instr::FsgnjD { frs1, frs2, .. } => {
                [Some(frs1), Some(frs2), None]
            }
            Instr::FgeluD { frs1, .. } => [Some(frs1), None, None],
            Instr::Fsd { frs2, .. } => [Some(frs2), None, None],
            _ => [None, None, None],
        }
    }

    /// Destination FP register, if any.
    pub fn fp_dest(&self) -> Option<FReg> {
        match *self {
            Instr::FmaddD { frd, .. }
            | Instr::FmulD { frd, .. }
            | Instr::FaddD { frd, .. }
            | Instr::FsubD { frd, .. }
            | Instr::FmaxD { frd, .. }
            | Instr::FsgnjD { frd, .. }
            | Instr::FgeluD { frd, .. }
            | Instr::Fld { frd, .. }
            | Instr::FcvtDW { frd, .. } => Some(frd),
            _ => None,
        }
    }
}

/// The GeLU the activation unit (and the host reference) computes: the
/// tanh approximation `0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3)))`.
/// One shared definition keeps the simulated cluster and the host
/// oracle bit-identical.
pub fn gelu(x: f64) -> f64 {
    const SQRT_2_OVER_PI: f64 = 0.797_884_560_802_865_4;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

/// An assembled program: decoded IR plus the raw encodings.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub instrs: Vec<Instr>,
    pub words: Vec<u32>,
}

impl Program {
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp_classification() {
        let fma = Instr::FmaddD { frd: 10, frs1: 0, frs2: 1, frs3: 10 };
        assert!(fma.is_fp_compute() && fma.is_fp() && !fma.is_fp_bypass());
        let fld = Instr::Fld { frd: 3, rs1: 5, imm: 0 };
        assert!(fld.is_fp_bypass() && fld.is_fp() && !fld.is_fp_compute());
        let addi = Instr::Addi { rd: 1, rs1: 1, imm: 4 };
        assert!(!addi.is_fp());
    }

    #[test]
    fn fp_sources_of_fmadd() {
        let fma = Instr::FmaddD { frd: 10, frs1: 0, frs2: 1, frs3: 10 };
        assert_eq!(fma.fp_sources(), [Some(0), Some(1), Some(10)]);
        assert_eq!(fma.fp_dest(), Some(10));
    }

    #[test]
    fn epilogue_ops_classify_as_fp_compute() {
        let fmax = Instr::FmaxD { frd: 2, frs1: 18, frs2: 9 };
        assert!(fmax.is_fp_compute());
        assert_eq!(fmax.fp_sources(), [Some(18), Some(9), None]);
        assert_eq!(fmax.fp_dest(), Some(2));
        let fgelu = Instr::FgeluD { frd: 2, frs1: 10 };
        assert!(fgelu.is_fp_compute());
        assert_eq!(fgelu.fp_sources(), [Some(10), None, None]);
        assert_eq!(fgelu.fp_dest(), Some(2));
    }

    #[test]
    fn gelu_reference_values() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!(gelu(-10.0).abs() < 1e-6, "saturates to 0 for large -x");
        assert!((gelu(10.0) - 10.0).abs() < 1e-6, "identity for large x");
    }

    #[test]
    fn ssr_field_word_roundtrip() {
        for f in [
            SsrField::Repeat,
            SsrField::Bound(0),
            SsrField::Bound(3),
            SsrField::Stride(0),
            SsrField::Stride(3),
            SsrField::ReadBase(2),
            SsrField::WriteBase(1),
        ] {
            assert_eq!(SsrField::from_word(f.to_word()), Some(f));
        }
        assert_eq!(SsrField::from_word(63), None);
    }
}
