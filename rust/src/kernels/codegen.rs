//! Kernel code generation — emits the paper's matmul kernels as real
//! instruction streams.
//!
//! Compute cores run the Fig. 1b kernel: SSR-fed, FREP-driven, unroll-8
//! dot products with peeled first (fmul) and last (fmadd → ft2)
//! iterations.  Two variants:
//!
//! * **baseline** — the inner K loop maps to `frep`, the collapsed
//!   (M/8·N/8)-iteration outer loop is software (`addi` + `bne`): the
//!   two loop-management instructions per iteration of §III-A.
//! * **zonl** — the outer loop maps to a second, *outer* FREP: the
//!   whole tile becomes one imperfect loop nest (fmul×8 ; [fmadd×8]^K-2
//!   ; fmadd×8) executed entirely from the sequencer ring buffer.
//!
//! The DM core runs the double-buffer schedule: load phase-0 tiles,
//! then per pass store the previous C tile and load the next A/B tiles
//! while the compute cores work, meeting them at a cluster barrier.
//!
//! **Fused epilogues** (`Epilogue`): a bias epilogue replaces the
//! peeled `fmul` row with `fmadd acc, a, b, bias` — the bias streams
//! through the 4th SSR (ft3) and costs zero extra issue slots; an
//! activation epilogue keeps the last k-iteration in the accumulators
//! and appends one `fmax.d`/`fgelu.d` writeback row per outer
//! iteration. The C tile never touches TCDM between GEMM and
//! elementwise work.

use crate::cluster::ClusterConfig;
use crate::isa::asm::Asm;
use crate::isa::{csr, reg, Instr, Program, SsrField};
use crate::mem::MAIN_MEM_BASE;

use super::epilogue::{Activation, Epilogue};
use super::layout::BufferMap;
use super::tiling::Tiling;

/// Column unroll factor (the paper's implementations use 8).
pub const UNROLL: usize = 8;
/// Compute cores per cluster.
pub const N_CORES: usize = 8;

/// Main-memory placement of the operand matrices.
#[derive(Clone, Copy, Debug)]
pub struct MainLayout {
    pub a: u32,
    pub b: u32,
    pub c: u32,
    /// Bias vector (`n` words) for fused epilogues; valid address
    /// either way, only DMA'd when the plan's epilogue has a bias.
    pub bias: u32,
}

pub fn main_layout(t: &Tiling) -> MainLayout {
    let align = |x: u32| (x + 63) & !63;
    let a = MAIN_MEM_BASE;
    let b = align(a + (t.m * t.k * 8) as u32);
    let c = align(b + (t.k * t.n * 8) as u32);
    let bias = align(c + (t.m * t.n * 8) as u32);
    MainLayout { a, b, c, bias }
}

/// One li+scfgw pair.
fn cfg(a: &mut Asm, ssr: u8, field: SsrField, value: u32) {
    a.li(reg::T0, value);
    a.push(Instr::SsrCfgW { value: reg::T0, ssr, field });
}

/// Emit the SSR loop geometry (bounds/strides/repeat) for this tiling.
/// Only needed once per program; per-pass re-arming writes bases only.
///
/// Works for both layouts through the chunk abstraction: a tile row is
/// a sequence of 8-word chunks spaced `chunk_stride` apart (64 B when
/// linear, one hyperbank row when grouped), rows are `row_stride`
/// apart.  The K walk of the A stream decomposes into (k_lo: within a
/// chunk) x (k_hi: across chunks) — 4 dims plus the element-repeat,
/// exactly Snitch's SSR capability.
fn emit_ssr_geometry(a: &mut Asm, t: &Tiling, map: &BufferMap) {
    let u = UNROLL as u32;
    let k = t.k as u32;
    let jn = (t.nt / UNROLL) as u32; // column groups
    let im = (t.mt / N_CORES) as u32; // rows per core
    // ssr0 = A reads: repeat u; [k_lo (8B x8), k_hi (chunk), j (0),
    //                            i (8 rows)]
    cfg(a, 0, SsrField::Repeat, u - 1);
    cfg(a, 0, SsrField::Bound(0), 8 - 1);
    cfg(a, 0, SsrField::Stride(0), 8);
    cfg(a, 0, SsrField::Bound(1), k / 8 - 1);
    cfg(a, 0, SsrField::Stride(1), map.a[0].chunk_stride);
    cfg(a, 0, SsrField::Bound(2), jn - 1);
    cfg(a, 0, SsrField::Stride(2), 0);
    cfg(a, 0, SsrField::Bound(3), im - 1);
    cfg(a, 0, SsrField::Stride(3), 8 * map.a[0].row_stride);
    // ssr1 = B reads: [u (8B), k (row), j (chunk), i (0)]
    cfg(a, 1, SsrField::Bound(0), u - 1);
    cfg(a, 1, SsrField::Stride(0), 8);
    cfg(a, 1, SsrField::Bound(1), k - 1);
    cfg(a, 1, SsrField::Stride(1), map.b[0].row_stride);
    cfg(a, 1, SsrField::Bound(2), jn - 1);
    cfg(a, 1, SsrField::Stride(2), map.b[0].chunk_stride);
    cfg(a, 1, SsrField::Bound(3), im - 1);
    cfg(a, 1, SsrField::Stride(3), 0);
    // ssr2 = C writes: [u (8B), j (chunk), i (8 rows)]
    cfg(a, 2, SsrField::Bound(0), u - 1);
    cfg(a, 2, SsrField::Stride(0), 8);
    cfg(a, 2, SsrField::Bound(1), jn - 1);
    cfg(a, 2, SsrField::Stride(1), map.c[0].chunk_stride);
    cfg(a, 2, SsrField::Bound(2), im - 1);
    cfg(a, 2, SsrField::Stride(2), 8 * map.c[0].row_stride);
    // ssr3 = epilogue bias reads: [u (8B), j (chunk), i (re-read)] —
    // every row of the tile consumes the same nt-word slice.
    if let Some(bias) = &map.bias {
        cfg(a, 3, SsrField::Bound(0), u - 1);
        cfg(a, 3, SsrField::Stride(0), 8);
        cfg(a, 3, SsrField::Bound(1), jn - 1);
        cfg(a, 3, SsrField::Stride(1), bias[0].chunk_stride);
        cfg(a, 3, SsrField::Bound(2), im - 1);
        cfg(a, 3, SsrField::Stride(2), 0);
    }
}

/// RB-resident FP ops per outer iteration of the fused kernel body.
pub fn body_ops(epi: Epilogue) -> usize {
    3 * UNROLL + epi.extra_rows() * UNROLL
}

/// The kernel body: peeled first row, FREP'd fmadd row, peeled
/// writeback row — 24 instructions for a plain GEMM, plus one 8-wide
/// activation row for fused activation epilogues. A fused bias rides
/// the peeled first row for free (`fmadd acc, a, b, bias` with the
/// bias streamed through ft3).
fn emit_kernel_body(a: &mut Asm, k: usize, zonl_nest: bool, epi: Epilogue) {
    debug_assert!(k >= 3, "kernel needs K >= 3 for the peel structure");
    // first iteration: c_u = a*b (+ bias) — no accumulator zeroing
    for uu in 0..UNROLL as u8 {
        if epi.bias {
            a.push(Instr::FmaddD {
                frd: reg::FA0 + uu,
                frs1: reg::FT0,
                frs2: reg::FT1,
                frs3: reg::FT3,
            });
        } else {
            a.push(Instr::FmulD {
                frd: reg::FA0 + uu,
                frs1: reg::FT0,
                frs2: reg::FT1,
            });
        }
    }
    // middle iterations: hardware loop over the 8-instruction body
    a.li(reg::T2, (k - 2 - 1) as u32); // frep iterates value+1 times
    a.push(Instr::Frep {
        outer: !zonl_nest, // frep.i when nested inside an outer frep.o
        iters_reg: reg::T2,
        n_inst: (UNROLL - 1) as u8,
    });
    for uu in 0..UNROLL as u8 {
        a.push(Instr::FmaddD {
            frd: reg::FA0 + uu,
            frs1: reg::FT0,
            frs2: reg::FT1,
            frs3: reg::FA0 + uu,
        });
    }
    // last iteration: without an activation the results stream to
    // memory through ft2; with one they stay in the accumulators for
    // the activation row.
    let last_dest = |uu: u8| {
        if epi.act.is_some() {
            reg::FA0 + uu
        } else {
            reg::FT2
        }
    };
    for uu in 0..UNROLL as u8 {
        a.push(Instr::FmaddD {
            frd: last_dest(uu),
            frs1: reg::FT0,
            frs2: reg::FT1,
            frs3: reg::FA0 + uu,
        });
    }
    // activation writeback row: act(acc) streams out through ft2
    if let Some(act) = epi.act {
        for uu in 0..UNROLL as u8 {
            match act {
                Activation::Relu => a.push(Instr::FmaxD {
                    frd: reg::FT2,
                    frs1: reg::FA0 + uu,
                    frs2: reg::FZERO,
                }),
                Activation::Gelu => a.push(Instr::FgeluD {
                    frd: reg::FT2,
                    frs1: reg::FA0 + uu,
                }),
            }
        }
    }
}

/// Build the program for compute core `core` (0..8).
pub fn compute_program(
    core: usize,
    t: &Tiling,
    map: &BufferMap,
    zonl: bool,
    epi: Epilogue,
) -> Program {
    assert!(core < N_CORES);
    assert_eq!(t.mt % N_CORES, 0, "tile height must cover all 8 cores");
    assert_eq!(t.nt % UNROLL, 0);
    assert!(!epi.bias || map.bias.is_some(), "bias epilogue needs buffers");
    let mut a = Asm::new();
    let (grid_m, grid_n) = t.grid();
    let outer_iters = (t.mt / N_CORES) * (t.nt / UNROLL);

    // Stream geometry and the pass-0 bases are configured in the
    // shadow of the prologue DMA load — they cost no compute-window
    // cycles (what an optimized kernel does in practice).
    emit_ssr_geometry(&mut a, t, map);
    if epi.act == Some(Activation::Relu) {
        // f9 := 0.0 for the fmax.d writeback row.
        a.push(Instr::FcvtDW { frd: reg::FZERO, rs1: reg::ZERO });
    }
    let arm = |a: &mut Asm, p: usize| {
        let a_base = map.a[p].base + core as u32 * map.a[p].row_stride;
        let c_base = map.c[p].base + core as u32 * map.c[p].row_stride;
        cfg(a, 0, SsrField::ReadBase(3), a_base);
        cfg(a, 1, SsrField::ReadBase(3), map.b[p].base);
        cfg(a, 2, SsrField::WriteBase(2), c_base);
        if let Some(bias) = &map.bias {
            cfg(a, 3, SsrField::ReadBase(2), bias[p].base);
        }
    };
    arm(&mut a, 0);
    a.push(Instr::Barrier); // b_0: phase-0 tiles ready

    for pass in 0..grid_m * grid_n {
        a.push(Instr::Csrrsi { csr: csr::SSR_ENABLE, imm: 1 });

        if zonl {
            // The whole tile is one imperfect FREP nest.
            a.li(reg::T1, (outer_iters - 1) as u32);
            a.push(Instr::Frep {
                outer: true,
                iters_reg: reg::T1,
                n_inst: (body_ops(epi) - 1) as u8,
            });
            emit_kernel_body(&mut a, t.k, true, epi);
        } else {
            // Software outer loop: addi + bne per iteration (§III-A).
            a.li(reg::T1, outer_iters as u32);
            let loop_top = a.label();
            a.bind(loop_top);
            emit_kernel_body(&mut a, t.k, false, epi);
            a.push(Instr::Addi { rd: reg::T1, rs1: reg::T1, imm: -1 });
            a.bne(reg::T1, reg::ZERO, loop_top);
        }

        a.push(Instr::Csrrci { csr: csr::SSR_ENABLE, imm: 1 });
        // Re-arm for the *next* pass before the barrier: the scfgw
        // writes overlap the wait for the DM core instead of eating
        // compute-window cycles.
        if pass + 1 < grid_m * grid_n {
            arm(&mut a, (pass + 1) % 2);
        }
        a.push(Instr::Barrier); // b_{pass+1}
    }
    a.push(Instr::Ecall);
    a.assemble()
}

// ------------------------------------------------------------------
// DM core program
// ------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn emit_dma3(
    a: &mut Asm,
    src: u32,
    dst: u32,
    size: u32,
    strides1: (u32, u32),
    reps1: u32,
    strides2: (u32, u32),
    reps2: u32,
) {
    a.li(reg::A0, src);
    a.push(Instr::Dmsrc { rs1: reg::A0 });
    a.li(reg::A1, dst);
    a.push(Instr::Dmdst { rs1: reg::A1 });
    a.li(reg::A2, strides1.0);
    a.li(reg::A3, strides1.1);
    a.push(Instr::Dmstr { rs1: reg::A2, rs2: reg::A3 });
    a.li(reg::A4, reps1);
    a.push(Instr::Dmrep { rs1: reg::A4 });
    a.li(reg::A2, strides2.0);
    a.li(reg::A3, strides2.1);
    a.push(Instr::Dmstr2 { rs1: reg::A2, rs2: reg::A3 });
    a.li(reg::A4, reps2);
    a.push(Instr::Dmrep2 { rs1: reg::A4 });
    a.li(reg::A5, size);
    a.push(Instr::Dmcpy { rd: reg::T0, rs1: reg::A5 });
}

fn emit_dma_wait(a: &mut Asm) {
    let poll = a.label();
    a.bind(poll);
    a.push(Instr::Dmstat { rd: reg::T1 });
    a.bne(reg::T1, reg::ZERO, poll);
}

/// Build the DM core's double-buffer schedule program.
pub fn dm_program(t: &Tiling, map: &BufferMap, main: &MainLayout) -> Program {
    let mut a = Asm::new();
    let (grid_m, grid_n) = t.grid();
    let passes: Vec<(usize, usize)> = (0..grid_m)
        .flat_map(|it| (0..grid_n).map(move |jt| (it, jt)))
        .collect();

    // All transfers are 3D: 64-byte chunks (dim 0), chunks-per-row
    // (dim 1), rows (dim 2).  Every beat is one full superbank row.
    let load_a = |a: &mut Asm, it: usize, p: usize| {
        emit_dma3(
            a,
            main.a + (it * t.mt * t.k * 8) as u32,
            map.a[p].base,
            64,
            (64, map.a[p].chunk_stride),
            (t.k / 8) as u32,
            ((t.k * 8) as u32, map.a[p].row_stride),
            t.mt as u32,
        );
    };
    let load_b = |a: &mut Asm, jt: usize, p: usize| {
        emit_dma3(
            a,
            main.b + (jt * t.nt * 8) as u32,
            map.b[p].base,
            64,
            (64, map.b[p].chunk_stride),
            (t.nt / 8) as u32,
            ((t.n * 8) as u32, map.b[p].row_stride),
            t.k as u32,
        );
    };
    let store_c = |a: &mut Asm, it: usize, jt: usize, p: usize| {
        emit_dma3(
            a,
            map.c[p].base,
            main.c + ((it * t.mt * t.n + jt * t.nt) * 8) as u32,
            64,
            (map.c[p].chunk_stride, 64),
            (t.nt / 8) as u32,
            (map.c[p].row_stride, (t.n * 8) as u32),
            t.mt as u32,
        );
    };
    // Fused-bias epilogue: the per-tile nt-word bias slice rides along
    // with each B tile load (a single chunk row).
    let load_bias = |a: &mut Asm, jt: usize, p: usize| {
        if let Some(bias) = &map.bias {
            emit_dma3(
                a,
                main.bias + (jt * t.nt * 8) as u32,
                bias[p].base,
                64,
                (64, bias[p].chunk_stride),
                (t.nt / 8) as u32,
                (0, 0),
                1,
            );
        }
    };

    // Prologue: fill phase 0.
    load_a(&mut a, passes[0].0, 0);
    load_b(&mut a, passes[0].1, 0);
    load_bias(&mut a, passes[0].1, 0);
    emit_dma_wait(&mut a);
    a.push(Instr::Barrier); // b_0

    for (pass, &(_it, _jt)) in passes.iter().enumerate() {
        // While compute runs pass `pass` out of phase pass%2:
        if pass + 1 < passes.len() {
            let (nit, njt) = passes[pass + 1];
            load_a(&mut a, nit, (pass + 1) % 2);
            load_b(&mut a, njt, (pass + 1) % 2);
            load_bias(&mut a, njt, (pass + 1) % 2);
        }
        if pass >= 1 {
            let (pit, pjt) = passes[pass - 1];
            store_c(&mut a, pit, pjt, (pass - 1) % 2);
        }
        emit_dma_wait(&mut a);
        a.push(Instr::Barrier); // b_{pass+1}
    }
    // Epilogue: store the final C tile.
    let (lit, ljt) = *passes.last().unwrap();
    store_c(&mut a, lit, ljt, (passes.len() - 1) % 2);
    emit_dma_wait(&mut a);
    a.push(Instr::Ecall);
    a.assemble()
}

/// Build all 9 programs (8 compute + DM) for a plain GEMM.
pub fn build_programs(
    cfg: &ClusterConfig,
    t: &Tiling,
    map: &BufferMap,
) -> Vec<Program> {
    build_programs_fused(cfg, t, map, Epilogue::NONE)
}

/// Build all 9 programs (8 compute + DM) with a fused epilogue.
pub fn build_programs_fused(
    cfg: &ClusterConfig,
    t: &Tiling,
    map: &BufferMap,
    epi: Epilogue,
) -> Vec<Program> {
    let main = main_layout(t);
    let mut progs: Vec<Program> = (0..N_CORES)
        .map(|c| compute_program(c, t, map, cfg.zonl, epi))
        .collect();
    progs.push(dm_program(t, map, &main));
    progs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ConfigId;
    use crate::kernels::layout::plan_buffers;
    use crate::kernels::tiling::choose_tiling;

    fn setup(id: ConfigId, m: usize, n: usize, k: usize)
        -> (Tiling, BufferMap, ClusterConfig) {
        let cfg = id.cluster_config();
        let t = choose_tiling(m, n, k, cfg.tcdm_bytes).unwrap();
        let map = plan_buffers(&t, cfg.topology, cfg.tcdm_bytes,
                               crate::kernels::LayoutKind::Grouped);
        (t, map, cfg)
    }

    #[test]
    fn baseline_kernel_has_software_loop() {
        let (t, map, _) = setup(ConfigId::Base32Fc, 32, 32, 32);
        let p = compute_program(0, &t, &map, false, Epilogue::NONE);
        let n_bne = p.instrs.iter()
            .filter(|i| matches!(i, Instr::Bne { .. })).count();
        let n_frep = p.instrs.iter()
            .filter(|i| matches!(i, Instr::Frep { .. })).count();
        assert_eq!(n_bne, 1, "one backedge for the software outer loop");
        assert_eq!(n_frep, 1, "inner K loop only");
    }

    #[test]
    fn zonl_kernel_has_no_branches() {
        let (t, map, _) = setup(ConfigId::Zonl48Db, 32, 32, 32);
        let p = compute_program(0, &t, &map, true, Epilogue::NONE);
        assert!(!p.instrs.iter().any(|i| matches!(
            i,
            Instr::Bne { .. } | Instr::Beq { .. } | Instr::Blt { .. }
        )));
        let freps: Vec<_> = p.instrs.iter()
            .filter(|i| matches!(i, Instr::Frep { .. })).collect();
        assert_eq!(freps.len(), 2, "outer + inner FREP");
    }

    #[test]
    fn fp_op_count_matches_tile_math() {
        let (t, map, _) = setup(ConfigId::Base32Fc, 32, 32, 32);
        let p = compute_program(0, &t, &map, false, Epilogue::NONE);
        // static FP compute instrs per pass: 24 (peel+body+wb)
        let fp = p.instrs.iter().filter(|i| i.is_fp_compute()).count();
        assert_eq!(fp, 24 * t.passes());
    }

    #[test]
    fn fused_bias_costs_no_extra_ops() {
        use crate::kernels::epilogue::{Activation, Epilogue};
        let cfg = ConfigId::Zonl48Db.cluster_config();
        let t = choose_tiling(32, 32, 32, cfg.tcdm_bytes).unwrap();
        let epi = Epilogue { bias: true, act: None };
        let map = crate::kernels::layout::plan_buffers_fused(
            &t,
            cfg.topology,
            cfg.tcdm_bytes,
            crate::kernels::LayoutKind::Grouped,
            epi,
        );
        assert_eq!(body_ops(epi), 24, "bias rides the peeled row");
        let p = compute_program(0, &t, &map, true, epi);
        // the peeled row became fmadd-from-ft3: no fmul remains
        assert!(!p.instrs.iter().any(|i| matches!(i, Instr::FmulD { .. })));
        let uses_ft3 = p.instrs.iter().any(|i| {
            matches!(i, Instr::FmaddD { frs3: 3, .. })
        });
        assert!(uses_ft3, "bias streams through ft3");
        // activation adds exactly one 8-wide row
        let epi2 = Epilogue { bias: true, act: Some(Activation::Relu) };
        assert_eq!(body_ops(epi2), 32);
        let p2 = compute_program(0, &t, &map, true, epi2);
        let n_fmax = p2.instrs.iter()
            .filter(|i| matches!(i, Instr::FmaxD { .. })).count();
        assert_eq!(n_fmax, 8 * t.passes());
    }

    #[test]
    fn fused_dm_program_loads_bias_per_pass() {
        use crate::kernels::epilogue::Epilogue;
        let cfg = ConfigId::Zonl48Db.cluster_config();
        let t = choose_tiling(64, 64, 64, cfg.tcdm_bytes).unwrap();
        let epi = Epilogue { bias: true, act: None };
        let map = crate::kernels::layout::plan_buffers_fused(
            &t,
            cfg.topology,
            cfg.tcdm_bytes,
            crate::kernels::LayoutKind::Grouped,
            epi,
        );
        let main = main_layout(&t);
        let p = dm_program(&t, &map, &main);
        let n_cpy = p.instrs.iter()
            .filter(|i| matches!(i, Instr::Dmcpy { .. })).count();
        let passes = t.passes();
        // loads: 3 per pass (A, B, bias), stores: 1 per pass.
        assert_eq!(n_cpy, 3 * passes + passes);
    }

    #[test]
    fn dm_program_transfer_count() {
        let (t, map, _) = setup(ConfigId::Base32Fc, 64, 64, 64);
        let main = main_layout(&t);
        let p = dm_program(&t, &map, &main);
        let n_cpy = p.instrs.iter()
            .filter(|i| matches!(i, Instr::Dmcpy { .. })).count();
        let passes = t.passes();
        // loads: 2 per pass (incl. prologue), stores: 1 per pass.
        assert_eq!(n_cpy, 2 * passes + passes);
    }

    #[test]
    fn barrier_counts_line_up() {
        let (t, map, cfg) = setup(ConfigId::Zonl64Db, 64, 32, 40);
        let progs = build_programs(&cfg, &t, &map);
        let barriers = |p: &Program| {
            p.instrs.iter()
                .filter(|i| matches!(i, Instr::Barrier)).count()
        };
        let expect = t.passes() + 1;
        for p in &progs {
            assert_eq!(barriers(p), expect);
        }
    }
}
