//! End-to-end matmul driver: the public "run a GEMM on a cluster" API.
//!
//! Plans the tiling and buffers; execution funnels through
//! `kernels::service::GemmService` (one-shot helpers build a throwaway
//! cycle-accurate service), so every run path shares the same
//! plan-and-prepare pipeline. The run-to-completion loop itself lives
//! in `backend::cycle`; batched / multi-backend evaluation uses a
//! long-lived `GemmService` directly.

use anyhow::{Context, Result};

use crate::cluster::{ClusterConfig, ClusterPerf, ConfigId};

use super::codegen::{main_layout, MainLayout, UNROLL};
use super::epilogue::Epilogue;
use super::layout::{plan_buffers_fused, BufferMap, LayoutKind};
use super::service::GemmService;
use super::tiling::{choose_tiling_for, Tiling};

/// A planned GEMM: everything needed to generate code and place data.
#[derive(Clone, Copy, Debug)]
pub struct GemmPlan {
    pub tiling: Tiling,
    pub map: BufferMap,
    pub main: MainLayout,
    pub layout: LayoutKind,
    /// Fused epilogue baked into the generated kernels.
    pub epi: Epilogue,
}

/// Result of an evaluated GEMM (any backend).
#[derive(Clone, Debug)]
pub struct GemmResult {
    /// Row-major `m x n` output — empty for non-functional backends
    /// (the analytic model predicts timing only).
    pub c: Vec<f64>,
    pub cycles: u64,
    pub perf: ClusterPerf,
    pub plan: GemmPlan,
    pub config: ConfigId,
}

impl GemmResult {
    /// FPU utilization as the paper reports it.
    pub fn utilization(&self) -> f64 {
        self.perf.utilization
    }

    /// Performance in DP Gflop/s at 1 GHz, using the paper's peak
    /// convention (Table II: 8 cores at 8 DPGflop/s peak, i.e. one MAC
    /// counted per FPU per cycle — see EXPERIMENTS.md §Conventions).
    pub fn gflops(&self) -> f64 {
        self.utilization() * 8.0
    }
}

/// Validate the problem against the paper's evaluation grid.
pub fn check_dims(m: usize, n: usize, k: usize) -> Result<()> {
    anyhow::ensure!(
        m % 8 == 0 && n % 8 == 0 && k % 8 == 0 && m > 0 && n > 0 && k > 0,
        "problem dims must be positive multiples of 8 (got {m}x{n}x{k})"
    );
    anyhow::ensure!(
        n % UNROLL == 0,
        "N must be a multiple of the unroll factor {UNROLL}"
    );
    anyhow::ensure!(k >= 8, "K must be at least 8");
    Ok(())
}

/// Plan a plain GEMM for a configuration.
pub fn plan_gemm(
    cfg: &ClusterConfig,
    m: usize,
    n: usize,
    k: usize,
    layout: LayoutKind,
) -> Result<GemmPlan> {
    plan_gemm_fused(cfg, m, n, k, layout, Epilogue::NONE)
}

/// Plan a GEMM with a fused epilogue: the tiling accounts for the
/// double-buffered bias slice and the buffer map places it.
pub fn plan_gemm_fused(
    cfg: &ClusterConfig,
    m: usize,
    n: usize,
    k: usize,
    layout: LayoutKind,
    epi: Epilogue,
) -> Result<GemmPlan> {
    check_dims(m, n, k)?;
    let tiling = choose_tiling_for(m, n, k, cfg.tcdm_bytes, epi.bias)
        .with_context(|| format!("no tiling fits {m}x{n}x{k}"))?;
    let map = plan_buffers_fused(
        &tiling,
        cfg.topology,
        cfg.tcdm_bytes,
        layout,
        epi,
    );
    let main = main_layout(&tiling);
    Ok(GemmPlan { tiling, map, main, layout, epi })
}

/// Simulate `C = A x B` on configuration `id`. The main entry point.
pub fn run_matmul(
    id: ConfigId,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
) -> Result<GemmResult> {
    // The grouped layout is the paper's bank-aware placement (§III-B,
    // footnote 5): each matrix confined to its own superbank, so the
    // concurrent core requests hit disjoint bank groups.
    run_matmul_layout(id, m, n, k, a, b, LayoutKind::Grouped)
}

/// Like [`run_matmul`] with an explicit layout (the layout ablation).
/// One-shot convenience over a throwaway cycle-accurate service — the
/// pre-refactor direct codegen path is gone, so this can never bypass
/// the plan-and-prepare pipeline batched runs use.
pub fn run_matmul_layout(
    id: ConfigId,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
    layout: LayoutKind,
) -> Result<GemmResult> {
    GemmService::cycle().run(id, m, n, k, layout, a, b)
}

/// Simulate `C = epilogue(A x B [+ bias])` with the epilogue fused
/// into the kernels' writeback pass.
pub fn run_matmul_fused(
    id: ConfigId,
    m: usize,
    n: usize,
    k: usize,
    epi: Epilogue,
    a: &[f64],
    b: &[f64],
    bias: &[f64],
) -> Result<GemmResult> {
    GemmService::cycle()
        .run_fused(id, m, n, k, LayoutKind::Grouped, epi, a, b, bias)
}

/// Host-side reference with the same FMA association order as the
/// kernel (fused multiply-add over ascending k): bit-exact against the
/// simulated cluster.
pub fn host_ref(m: usize, n: usize, k: usize, a: &[f64], b: &[f64])
    -> Vec<f64> {
    host_ref_fused(m, n, k, Epilogue::NONE, a, b, &[])
}

/// [`host_ref`] with a fused epilogue: seeds each accumulator exactly
/// like the kernel's peeled first row (`fmadd(a0, b0, bias)` when the
/// epilogue carries a bias) and applies the activation last.
pub fn host_ref_fused(
    m: usize,
    n: usize,
    k: usize,
    epi: Epilogue,
    a: &[f64],
    b: &[f64],
    bias: &[f64],
) -> Vec<f64> {
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let bj = if epi.bias { bias[j] } else { 0.0 };
            let mut acc = epi.seed(a[i * k], b[j], bj);
            for kk in 1..k {
                acc = a[i * k + kk].mul_add(b[kk * n + j], acc);
            }
            c[i * n + j] = epi.finish(acc);
        }
    }
    c
}

/// Deterministic test matrices.
pub fn test_matrices(m: usize, n: usize, k: usize, seed: u64)
    -> (Vec<f64>, Vec<f64>) {
    let mut rng = crate::util::rng::Rng::new(seed);
    let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
    (a, b)
}

/// Deterministic test bias vector (decorrelated from the matrices).
pub fn test_bias(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = crate::util::rng::Rng::new(seed ^ 0xB1A5_B1A5);
    (0..n).map(|_| rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(id: ConfigId, m: usize, n: usize, k: usize) -> GemmResult {
        let (a, b) = test_matrices(m, n, k, 42);
        let r = run_matmul(id, m, n, k, &a, &b).unwrap();
        let want = host_ref(m, n, k, &a, &b);
        for (i, (&got, &w)) in r.c.iter().zip(&want).enumerate() {
            assert!(
                (got - w).abs() <= 1e-9 * w.abs().max(1.0),
                "{}: C[{i}] = {got} want {w} ({m}x{n}x{k})",
                id.name()
            );
        }
        r
    }

    #[test]
    fn cube8_smallest() {
        let r = check(ConfigId::Base32Fc, 8, 8, 8);
        assert!(r.cycles > 0);
    }

    #[test]
    fn cube32_all_configs_correct() {
        for id in ConfigId::all() {
            let r = check(id, 32, 32, 32);
            assert!(
                r.utilization() > 0.5,
                "{} utilization {:.3} too low",
                id.name(),
                r.utilization()
            );
        }
    }

    #[test]
    fn rectangular_multi_tile() {
        let r = check(ConfigId::Zonl48Db, 64, 32, 16);
        assert!(r.plan.tiling.passes() >= 1);
    }

    #[test]
    fn tiled_128_cube_zonl() {
        let r = check(ConfigId::Zonl64Db, 128, 64, 128);
        assert!(r.plan.tiling.passes() > 1, "must run multiple passes");
    }

    #[test]
    fn zonl_beats_baseline_utilization() {
        let (a, b) = test_matrices(32, 32, 32, 7);
        let base =
            run_matmul(ConfigId::Base32Fc, 32, 32, 32, &a, &b).unwrap();
        let zonl =
            run_matmul(ConfigId::Zonl48Db, 32, 32, 32, &a, &b).unwrap();
        assert!(
            zonl.utilization() > base.utilization(),
            "zonl {:.3} vs base {:.3}",
            zonl.utilization(),
            base.utilization()
        );
    }

    #[test]
    fn dims_validation() {
        assert!(check_dims(12, 8, 8).is_err());
        assert!(check_dims(8, 8, 8).is_ok());
        assert!(check_dims(0, 8, 8).is_err());
    }

    #[test]
    fn fused_epilogues_bit_exact_vs_host() {
        use crate::kernels::epilogue::{Activation, Epilogue};
        let (m, n, k) = (16, 16, 16);
        let (a, b) = test_matrices(m, n, k, 42);
        let bias = test_bias(n, 42);
        for epi in [
            Epilogue { bias: true, act: None },
            Epilogue { bias: false, act: Some(Activation::Relu) },
            Epilogue { bias: true, act: Some(Activation::Relu) },
            Epilogue { bias: true, act: Some(Activation::Gelu) },
        ] {
            let r = run_matmul_fused(
                ConfigId::Zonl48Db,
                m,
                n,
                k,
                epi,
                &a,
                &b,
                &bias,
            )
            .unwrap();
            let want = host_ref_fused(m, n, k, epi, &a, &b, &bias);
            assert_eq!(r.c, want, "bit-exact fused output ({})", epi.name());
            assert_eq!(
                r.perf.fpu_ops_total,
                (m * n * k + m * n * epi.ops_per_elem()) as u64,
                "{}: one FPU op per MAC + per epilogue element",
                epi.name()
            );
        }
    }

    #[test]
    fn fused_relu_clamps_negatives() {
        use crate::kernels::epilogue::{Activation, Epilogue};
        let epi = Epilogue { bias: false, act: Some(Activation::Relu) };
        let (m, n, k) = (8, 8, 8);
        let (a, b) = test_matrices(m, n, k, 7);
        let r = run_matmul_fused(
            ConfigId::Base32Fc,
            m,
            n,
            k,
            epi,
            &a,
            &b,
            &[],
        )
        .unwrap();
        assert!(r.c.iter().all(|&x| x >= 0.0));
        let plain = host_ref(m, n, k, &a, &b);
        assert!(
            plain.iter().any(|&x| x < 0.0),
            "test data must exercise the clamp"
        );
    }
}
