//! Fused GEMM epilogues — bias add and activation executed inside the
//! compute cores' C-writeback pass, with zero extra TCDM round-trips.
//!
//! Fusion strategy (mirrors what hand-optimized Snitch kernels do):
//!
//! * **bias** costs *no* extra issue slots: the peeled first
//!   k-iteration becomes `fmadd acc, a, b, bias` instead of
//!   `fmul acc, a, b`, initializing each accumulator with its column's
//!   bias. The bias operand streams through the 4th SSR (ft3); the DM
//!   core loads the per-tile bias slice alongside each B tile.
//! * **activation** costs one extra writeback row (8 ops per outer
//!   iteration): the last k-iteration accumulates into the register
//!   file instead of streaming out, and a `fmax.d`/`fgelu.d` row
//!   writes the activated results through ft2.
//!
//! Either way the C tile never leaves the register file between the
//! GEMM and the elementwise work — no TCDM (let alone main-memory)
//! round-trip, which is the whole point (see the ROOFLINE/TROOP
//! motivation in PAPERS.md).
//!
//! [`Epilogue::apply`] is the host-side oracle: it performs the exact
//! FP operations in the exact order the generated kernel issues them,
//! so cycle-backend outputs stay bit-identical to host references.

/// Elementwise activation applied in the writeback row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Activation {
    /// `fmax.d(x, 0.0)`.
    Relu,
    /// Tanh-approximated GeLU (`isa::gelu`, the `fgelu.d` unit).
    Gelu,
}

impl Activation {
    pub fn name(&self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Gelu => "gelu",
        }
    }

    pub fn apply(&self, x: f64) -> f64 {
        match self {
            Activation::Relu => x.max(0.0),
            Activation::Gelu => crate::isa::gelu(x),
        }
    }
}

/// A fused GEMM epilogue: optional bias add + optional activation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct Epilogue {
    /// Initialize accumulators with the per-column bias vector.
    pub bias: bool,
    pub act: Option<Activation>,
}

impl Epilogue {
    pub const NONE: Epilogue = Epilogue { bias: false, act: None };

    pub fn is_none(&self) -> bool {
        !self.bias && self.act.is_none()
    }

    /// Extra 8-wide writeback rows per outer iteration (bias is free —
    /// it rides the peeled first k-iteration).
    pub fn extra_rows(&self) -> usize {
        usize::from(self.act.is_some())
    }

    /// Extra FP ops per output element (the analytic model's epilogue
    /// issue-cost regressor).
    pub fn ops_per_elem(&self) -> usize {
        self.extra_rows()
    }

    /// Host-side oracle for one output element. `acc0` is the first
    /// k-iteration's product `a0*b0`; callers accumulate the remaining
    /// k-1 iterations over the returned seed exactly like the kernel
    /// (fused multiply-add over ascending k), then pass the final
    /// accumulator through [`Epilogue::finish`].
    pub fn seed(&self, a0: f64, b0: f64, bias: f64) -> f64 {
        if self.bias {
            a0.mul_add(b0, bias)
        } else {
            a0 * b0
        }
    }

    /// Host-side oracle for the writeback row.
    pub fn finish(&self, acc: f64) -> f64 {
        match self.act {
            None => acc,
            Some(a) => a.apply(acc),
        }
    }

    pub fn name(&self) -> String {
        match (self.bias, self.act) {
            (false, None) => "none".to_string(),
            (true, None) => "bias".to_string(),
            (false, Some(a)) => a.name().to_string(),
            (true, Some(a)) => format!("bias+{}", a.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_names() {
        assert_eq!(Epilogue::NONE.extra_rows(), 0);
        assert!(Epilogue::NONE.is_none());
        let b = Epilogue { bias: true, act: None };
        assert_eq!(b.extra_rows(), 0, "bias rides the peeled fmul row");
        assert_eq!(b.name(), "bias");
        let br = Epilogue { bias: true, act: Some(Activation::Relu) };
        assert_eq!(br.extra_rows(), 1);
        assert_eq!(br.name(), "bias+relu");
        let g = Epilogue { bias: false, act: Some(Activation::Gelu) };
        assert_eq!(g.name(), "gelu");
    }

    #[test]
    fn oracle_matches_fp_semantics() {
        let e = Epilogue { bias: true, act: Some(Activation::Relu) };
        // seed = fmadd(a0, b0, bias), finish = fmax(acc, 0)
        assert_eq!(e.seed(2.0, 3.0, 0.5), 2.0f64.mul_add(3.0, 0.5));
        assert_eq!(e.finish(-1.5), 0.0);
        assert_eq!(e.finish(1.5), 1.5);
        let plain = Epilogue::NONE;
        assert_eq!(plain.seed(2.0, 3.0, 99.0), 6.0, "bias ignored");
        assert_eq!(plain.finish(-1.5), -1.5);
    }
}
