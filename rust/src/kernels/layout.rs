//! TCDM buffer placement — where the double-buffered A/B/C tiles live.
//!
//! Two schemes:
//!
//! * **Grouped** (default — the paper's layout, §III-B + footnote 5):
//!   every matrix tile is confined to its *own superbank* (8-bank
//!   group), stored as 64-byte chunks strided by the hyperbank row
//!   (`banks_per_hyperbank * 8` bytes).  The B stream then saturates
//!   only B's banks, A and C traffic never crosses into it, and the
//!   "3 reads + 1 write per core" budget maps onto 24 conflict-free
//!   banks.  Six buffers (2 phases x {A,B,C}) want six groups — which
//!   is exactly why the paper builds the 48-bank (2x24) Dobu
//!   configuration.  On 32-bank clusters only 4 groups exist, so phase
//!   buffers must share groups and double-buffered DMA traffic
//!   collides with compute — the conflict loss Fig. 5 shows for
//!   Base32fc/Zonl32fc.
//! * **Linear**: tiles stored row-major, interleaved across all banks
//!   (with optional +pad words per row).  Kept for the layout ablation
//!   bench; it suffers cross-matrix bank interference.

use crate::mem::{Topology, BANKS_PER_SUPERBANK, TCDM_BASE};

use super::epilogue::Epilogue;
use super::tiling::Tiling;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayoutKind {
    /// Superbank-confined matrices (the paper's bank-aware layout).
    Grouped,
    /// Row-major across all banks with `pad` extra words per row.
    Linear { pad_words: u32 },
}

/// Address-generation parameters for one buffer.
#[derive(Clone, Copy, Debug)]
pub struct BufDesc {
    /// Address of element 0.
    pub base: u32,
    /// Stride between consecutive 8-word chunks (Grouped) or unused
    /// (Linear, where chunks are contiguous within a row).
    pub chunk_stride: u32,
    /// Stride between consecutive *rows* of the tile, in bytes.
    pub row_stride: u32,
}

#[derive(Clone, Copy, Debug)]
pub struct BufferMap {
    pub kind: LayoutKind,
    /// Per-phase descriptors (index = pass % 2).
    pub a: [BufDesc; 2],
    pub b: [BufDesc; 2],
    pub c: [BufDesc; 2],
    /// Per-phase bias slice for fused epilogues (`nt` words, stacked in
    /// the C tile's bank group); absent for plain GEMMs.
    pub bias: Option<[BufDesc; 2]>,
}

fn align64(x: u32) -> u32 {
    (x + 63) & !63
}

/// Linear placement (the ablation baseline).
fn plan_linear(
    t: &Tiling,
    topology: Topology,
    tcdm_bytes: usize,
    pad_words: u32,
    with_bias: bool,
) -> BufferMap {
    let pad = pad_words * 8;
    let a_row = t.k as u32 * 8 + pad;
    let b_row = t.nt as u32 * 8 + pad;
    let c_row = t.nt as u32 * 8 + pad;
    let a_bytes = align64(a_row * t.mt as u32);
    let b_bytes = align64(b_row * t.k as u32);
    let c_bytes = align64(c_row * t.mt as u32);
    let bias_bytes = if with_bias {
        align64(t.nt as u32 * 8)
    } else {
        0
    };
    let phase_bytes = a_bytes + b_bytes + c_bytes + bias_bytes;

    let phase_base: [u32; 2] = match topology {
        Topology::Fc { .. } => {
            assert!(2 * phase_bytes <= tcdm_bytes as u32,
                    "buffers exceed TCDM");
            [TCDM_BASE, TCDM_BASE + phase_bytes]
        }
        Topology::Dobu { .. } => {
            let half = (tcdm_bytes / 2) as u32;
            assert!(phase_bytes <= half,
                    "phase buffers exceed a hyperbank");
            [TCDM_BASE, TCDM_BASE + half]
        }
    };
    let d = |base: u32, row: u32| BufDesc {
        base,
        chunk_stride: 64, // contiguous chunks
        row_stride: row,
    };
    let bias = if with_bias {
        Some([
            d(phase_base[0] + a_bytes + b_bytes + c_bytes, 0),
            d(phase_base[1] + a_bytes + b_bytes + c_bytes, 0),
        ])
    } else {
        None
    };
    BufferMap {
        kind: LayoutKind::Linear { pad_words },
        a: [d(phase_base[0], a_row), d(phase_base[1], a_row)],
        b: [
            d(phase_base[0] + a_bytes, b_row),
            d(phase_base[1] + a_bytes, b_row),
        ],
        c: [
            d(phase_base[0] + a_bytes + b_bytes, c_row),
            d(phase_base[1] + a_bytes + b_bytes, c_row),
        ],
        bias,
    }
}

/// Bank-group assignment per configuration: global group ids for
/// [phase][matrix] with matrices ordered A, B, C.
///
/// With >= 6 groups every buffer gets a private superbank (zero
/// compute/DMA bank sharing).  With 4 groups (32 banks) the assignment
/// minimizes sharing against the *highest-duty* compute stream (B):
/// next-phase A/B loads land on the current A and C groups (1/8 and
/// 1/K duty) — B's group is never shared.
pub fn group_assignment(topology: Topology) -> [[usize; 3]; 2] {
    let groups = topology.total_banks() / BANKS_PER_SUPERBANK;
    let gph = topology.banks_per_hyperbank() / BANKS_PER_SUPERBANK;
    match topology {
        Topology::Fc { .. } => match groups {
            4 => [[0, 1, 2], [3, 0, 2]], // B1 -> A0's group, C shared
            _ => [[0, 1, 2], [3, 4, 5]],
        },
        Topology::Dobu { .. } => {
            // phase p in hyperbank p: first 3 groups of each hyperbank
            [[0, 1, 2], [gph, gph + 1, gph + 2]]
        }
    }
}

/// Grouped placement: buffer base = its group's first bank row; chunks
/// stride by one hyperbank row.
fn plan_grouped(
    t: &Tiling,
    topology: Topology,
    tcdm_bytes: usize,
    with_bias: bool,
) -> BufferMap {
    let bph = topology.banks_per_hyperbank();
    let gph = bph / BANKS_PER_SUPERBANK; // groups per hyperbank
    let hyper_bytes = (tcdm_bytes / topology.hyperbanks()) as u32;
    let chunk_stride = (bph * 8) as u32;
    let assign = group_assignment(topology);

    // capacity check: a group stores one 64B chunk per hyperbank row.
    let rows = hyper_bytes / chunk_stride;
    let group_cap_bytes = rows * 64;
    let bias_bytes = if with_bias { t.nt as u32 * 8 } else { 0 };
    let words =
        [t.mt * t.k, t.k * t.nt, t.mt * t.nt].map(|w| w as u32 * 8);
    // per-group occupancy (groups may be shared on 32-bank configs);
    // the bias slice stacks in the C group.
    let mut occupancy = vec![0u32; topology.total_banks() / 8];
    for p in 0..2 {
        for (mi, &bytes) in words.iter().enumerate() {
            occupancy[assign[p][mi]] += bytes;
        }
        occupancy[assign[p][2]] += bias_bytes;
    }
    for (g, &occ) in occupancy.iter().enumerate() {
        assert!(
            occ <= group_cap_bytes,
            "bank group {g} over capacity: {occ} > {group_cap_bytes}"
        );
    }

    // Shared groups stack their buffers at different chunk offsets.
    let mut next_chunk = vec![0u32; topology.total_banks() / 8];
    let mut desc = |g: usize, tile_words: usize, row_words: usize| {
        let hyper = g / gph;
        let g_local = (g % gph) as u32;
        let base = TCDM_BASE
            + hyper as u32 * hyper_bytes
            + g_local * 64
            + next_chunk[g] * chunk_stride;
        let chunks = (tile_words as u32 * 8).div_ceil(64);
        next_chunk[g] += chunks;
        BufDesc {
            base,
            chunk_stride,
            row_stride: (row_words as u32 / 8) * chunk_stride,
        }
    };

    let a = [
        desc(assign[0][0], t.mt * t.k, t.k),
        desc(assign[1][0], t.mt * t.k, t.k),
    ];
    let b = [
        desc(assign[0][1], t.k * t.nt, t.nt),
        desc(assign[1][1], t.k * t.nt, t.nt),
    ];
    let c = [
        desc(assign[0][2], t.mt * t.nt, t.nt),
        desc(assign[1][2], t.mt * t.nt, t.nt),
    ];
    let bias = if with_bias {
        Some([
            desc(assign[0][2], t.nt, t.nt),
            desc(assign[1][2], t.nt, t.nt),
        ])
    } else {
        None
    };
    BufferMap { kind: LayoutKind::Grouped, a, b, c, bias }
}

pub fn plan_buffers(
    t: &Tiling,
    topology: Topology,
    tcdm_bytes: usize,
    kind: LayoutKind,
) -> BufferMap {
    plan_buffers_fused(t, topology, tcdm_bytes, kind, Epilogue::NONE)
}

/// [`plan_buffers`] with a fused epilogue: bias epilogues additionally
/// place the double-buffered `nt`-word bias slices.
pub fn plan_buffers_fused(
    t: &Tiling,
    topology: Topology,
    tcdm_bytes: usize,
    kind: LayoutKind,
    epi: Epilogue,
) -> BufferMap {
    // Grouped layout needs 8-word-aligned rows (chunk granularity).
    match kind {
        LayoutKind::Grouped => {
            assert!(t.k % 8 == 0 && t.nt % 8 == 0);
            plan_grouped(t, topology, tcdm_bytes, epi.bias)
        }
        LayoutKind::Linear { pad_words } => {
            plan_linear(t, topology, tcdm_bytes, pad_words, epi.bias)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Tcdm;

    fn t32() -> Tiling {
        Tiling { m: 32, n: 32, k: 32, mt: 32, nt: 32 }
    }

    #[test]
    fn linear_packs_sequentially() {
        let m = plan_buffers(&t32(), Topology::Fc { banks: 32 },
                             128 * 1024, LayoutKind::Linear { pad_words: 0 });
        assert_eq!(m.a[0].base, TCDM_BASE);
        assert!(m.b[0].base > m.a[0].base);
        assert_eq!(m.a[0].row_stride, 256);
    }

    #[test]
    fn grouped_each_matrix_in_own_superbank() {
        for topo in [
            Topology::Fc { banks: 64 },
            Topology::Dobu { banks_per_hyper: 24 },
            Topology::Dobu { banks_per_hyper: 32 },
        ] {
            let bytes = if topo.total_banks() == 48 {
                96 * 1024
            } else {
                128 * 1024
            };
            let m = plan_buffers(&t32(), topo, bytes, LayoutKind::Grouped);
            let tcdm = Tcdm::new(topo, bytes);
            let mut groups_seen = std::collections::HashSet::new();
            for (p, bufs) in
                [(0, [m.a[0], m.b[0], m.c[0]]),
                 (1, [m.a[1], m.b[1], m.c[1]])]
            {
                let _ = p;
                for d in bufs {
                    // walk the whole tile; all words in one superbank
                    let words = 32 * 32;
                    let mut sb = std::collections::HashSet::new();
                    for i in 0..words {
                        let addr = d.base
                            + (i / 8) as u32 * d.chunk_stride
                            + (i % 8) as u32 * 8;
                        sb.insert(
                            tcdm.superbank_of_bank(tcdm.bank_of(addr)),
                        );
                    }
                    assert_eq!(sb.len(), 1, "{topo:?}: spans {sb:?}");
                    groups_seen.insert(*sb.iter().next().unwrap());
                }
            }
            assert_eq!(groups_seen.len(), 6,
                       "{topo:?}: six private groups");
        }
    }

    #[test]
    fn grouped_32banks_shares_minimally() {
        let topo = Topology::Fc { banks: 32 };
        let m = plan_buffers(&t32(), topo, 128 * 1024, LayoutKind::Grouped);
        let tcdm = Tcdm::new(topo, 128 * 1024);
        let group = |d: &BufDesc| tcdm.superbank_of_bank(tcdm.bank_of(d.base));
        // B streams (full duty) never share with anything.
        assert_ne!(group(&m.b[0]), group(&m.b[1]));
        assert_ne!(group(&m.b[0]), group(&m.a[0]));
        assert_ne!(group(&m.b[0]), group(&m.c[0]));
        assert_ne!(group(&m.b[0]), group(&m.a[1]));
        assert_ne!(group(&m.b[1]), group(&m.a[1]));
        // the shared pairs stack at distinct chunk offsets
        assert_eq!(group(&m.a[0]), group(&m.b[1]));
        assert_ne!(m.a[0].base, m.b[1].base);
        assert_eq!(group(&m.c[0]), group(&m.c[1]));
    }

    #[test]
    fn grouped_dobu_phase_isolated_by_hyperbank() {
        let topo = Topology::Dobu { banks_per_hyper: 24 };
        let m = plan_buffers(&t32(), topo, 96 * 1024, LayoutKind::Grouped);
        let tcdm = Tcdm::new(topo, 96 * 1024);
        for d in [m.a[0], m.b[0], m.c[0]] {
            assert_eq!(tcdm.hyperbank_of(d.base), 0);
        }
        for d in [m.a[1], m.b[1], m.c[1]] {
            assert_eq!(tcdm.hyperbank_of(d.base), 1);
        }
    }

    #[test]
    #[should_panic(expected = "over capacity")]
    fn grouped_capacity_enforced() {
        let t = Tiling { m: 64, n: 64, k: 64, mt: 64, nt: 64 };
        let _ = plan_buffers(&t, Topology::Dobu { banks_per_hyper: 24 },
                             96 * 1024, LayoutKind::Grouped);
    }

    #[test]
    fn bias_slice_stacks_in_c_group() {
        let topo = Topology::Dobu { banks_per_hyper: 24 };
        let m = plan_buffers_fused(
            &t32(),
            topo,
            96 * 1024,
            LayoutKind::Grouped,
            Epilogue { bias: true, act: None },
        );
        let tcdm = Tcdm::new(topo, 96 * 1024);
        let bias = m.bias.expect("bias descriptors present");
        for p in 0..2 {
            let group = |b: u32| tcdm.superbank_of_bank(tcdm.bank_of(b));
            assert_eq!(group(bias[p].base), group(m.c[p].base));
            assert_ne!(bias[p].base, m.c[p].base, "stacked, not aliased");
        }
        // plain plans carry no bias buffers
        let plain =
            plan_buffers(&t32(), topo, 96 * 1024, LayoutKind::Grouped);
        assert!(plain.bias.is_none());
    }

    #[test]
    fn chunk_addressing_is_8_word_aligned() {
        let m = plan_buffers(&t32(), Topology::Fc { banks: 64 },
                             128 * 1024, LayoutKind::Grouped);
        for d in [m.a[0], m.b[0], m.c[0], m.a[1], m.b[1], m.c[1]] {
            assert_eq!(d.base % 64, 0);
            assert_eq!(d.chunk_stride % 64, 0);
            assert_eq!(d.row_stride % 64, 0);
        }
    }
}
