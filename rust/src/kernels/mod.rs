//! The paper's workload: matmul kernel generation, L1 tiling, TCDM
//! buffer layout, the end-to-end GEMM driver, and the batched
//! `GemmService` that memoizes plans across backend runs.

pub mod codegen;
pub mod driver;
pub mod layout;
pub mod service;
pub mod tiling;

pub use codegen::{build_programs, N_CORES, UNROLL};
pub use driver::{
    host_ref, plan_gemm, run_matmul, run_matmul_layout, test_matrices,
    GemmPlan, GemmResult,
};
pub use layout::{plan_buffers, BufferMap, LayoutKind};
pub use service::{problem_seed, GemmJob, GemmService, ServiceStats};
pub use tiling::{choose_tiling, Tiling};
