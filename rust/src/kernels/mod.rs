//! The paper's workload: matmul kernel generation (with fused
//! bias/activation epilogues), L1 tiling, TCDM buffer layout, the
//! end-to-end GEMM driver, and the batched `GemmService` that memoizes
//! plans across backend runs.

pub mod codegen;
pub mod driver;
pub mod epilogue;
pub mod layout;
pub mod service;
pub mod tiling;

pub use codegen::{build_programs, build_programs_fused, N_CORES, UNROLL};
pub use driver::{
    host_ref, host_ref_fused, plan_gemm, plan_gemm_fused, run_matmul,
    run_matmul_fused, run_matmul_layout, test_bias, test_matrices,
    GemmPlan, GemmResult,
};
pub use epilogue::{Activation, Epilogue};
pub use layout::{plan_buffers, plan_buffers_fused, BufferMap, LayoutKind};
pub use service::{problem_seed, GemmJob, GemmService, ServiceStats};
pub use tiling::{
    choose_shard_grid, choose_tiling, choose_tiling_for, Shard,
    ShardGrid, Tiling,
};
