//! The paper's workload: matmul kernel generation, L1 tiling, TCDM
//! buffer layout, and the end-to-end GEMM driver.

pub mod codegen;
pub mod driver;
pub mod layout;
pub mod tiling;

pub use codegen::{build_programs, N_CORES, UNROLL};
pub use driver::{
    host_ref, plan_gemm, run_matmul, run_matmul_layout, test_matrices,
    GemmPlan, GemmResult,
};
pub use layout::{plan_buffers, BufferMap, LayoutKind};
pub use tiling::{choose_tiling, Tiling};
