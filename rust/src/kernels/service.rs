//! `GemmService` — the batched, cache-aware front door to the
//! simulation backends.
//!
//! Sweeps evaluate the same `(M, N, K, config, layout)` point many
//! times (and thousands of distinct points): the service memoizes the
//! expensive pure prefix of every run — tile selection, buffer
//! placement, and code generation — as a shared [`PreparedGemm`], and
//! drains batched submissions through
//! `coordinator::runner::parallel_map` so all workers hit one plan
//! cache. Programs are `Arc`-shared into each `Cluster`, so a cache
//! hit allocates no instruction streams.
//!
//! The backend is chosen at construction ([`GemmService::cycle`],
//! [`GemmService::analytic`], or any custom `SimBackend`), which is
//! how the CLI's `--backend {cycle,analytic}` flag and the
//! calibration flow are wired.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::backend::{
    Analytic, BackendKind, Calibration, CycleAccurate, PreparedGemm,
    Replay, ShardedGemm, SimBackend,
};
use crate::cluster::ConfigId;
use crate::coordinator::runner;
use crate::fabric::{FabricConfig, FabricResult};

use super::codegen::build_programs_fused;
use super::driver::{
    check_dims, plan_gemm_fused, test_bias, test_matrices, GemmResult,
};
use super::epilogue::Epilogue;
use super::layout::LayoutKind;
use super::tiling::choose_shard_grid;

/// Plan-cache key.
pub type PlanKey = (usize, usize, usize, ConfigId, LayoutKind, Epilogue);

/// The paper's deterministic operand seed for a problem size (kept
/// identical across configs so numerics can be cross-checked).
pub fn problem_seed(m: usize, n: usize, k: usize) -> u64 {
    (m as u64) << 32 | (n as u64) << 16 | k as u64
}

/// One batched submission.
#[derive(Clone, Copy, Debug)]
pub struct GemmJob {
    pub config: ConfigId,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub layout: LayoutKind,
    /// Fused epilogue compiled into the kernels.
    pub epi: Epilogue,
    /// Seed for operand generation (functional backends only).
    pub seed: u64,
}

impl GemmJob {
    /// A job with the canonical per-problem operand seed.
    pub fn for_problem(
        config: ConfigId,
        m: usize,
        n: usize,
        k: usize,
        layout: LayoutKind,
    ) -> GemmJob {
        GemmJob {
            config,
            m,
            n,
            k,
            layout,
            epi: Epilogue::NONE,
            seed: problem_seed(m, n, k),
        }
    }

    /// [`GemmJob::for_problem`] with a fused epilogue.
    pub fn fused(
        config: ConfigId,
        m: usize,
        n: usize,
        k: usize,
        layout: LayoutKind,
        epi: Epilogue,
    ) -> GemmJob {
        GemmJob { epi, ..GemmJob::for_problem(config, m, n, k, layout) }
    }
}

/// Plan-cache counters (snapshot).
///
/// Accounting is *exact* even under concurrent `run_batch` first
/// touches: every `prepare` counts exactly one hit or one miss, and a
/// miss is counted only by the racer whose plan actually entered the
/// cache — so `plan_misses` always equals the number of distinct
/// cached plans, independent of thread count. The serving simulator
/// reports these numbers directly (and its determinism property
/// compares them bit for bit), which is why they must not wobble with
/// scheduling.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub plan_hits: u64,
    pub plan_misses: u64,
}

impl ServiceStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.plan_hits + self.plan_misses;
        if total == 0 {
            0.0
        } else {
            self.plan_hits as f64 / total as f64
        }
    }

    /// Counters accumulated since an `earlier` snapshot of the same
    /// service — the run-local delta the serve report carries. The
    /// counters are monotone, so plain saturating subtraction is
    /// exact (and a mismatched snapshot can't underflow into garbage).
    pub fn delta_since(&self, earlier: &ServiceStats) -> ServiceStats {
        ServiceStats {
            plan_hits: self
                .plan_hits
                .saturating_sub(earlier.plan_hits),
            plan_misses: self
                .plan_misses
                .saturating_sub(earlier.plan_misses),
        }
    }
}

pub struct GemmService {
    backend: Box<dyn SimBackend>,
    plans: RwLock<HashMap<PlanKey, Arc<PreparedGemm>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl GemmService {
    pub fn new(backend: Box<dyn SimBackend>) -> Self {
        Self {
            backend,
            plans: RwLock::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Cycle-accurate service (ground truth; FastPath stepping).
    pub fn cycle() -> Self {
        Self::new(Box::new(CycleAccurate::default()))
    }

    /// Cycle-accurate service on the pre-FastPath per-cycle stepper —
    /// the differential baseline for equivalence tests and benches.
    pub fn cycle_naive() -> Self {
        Self::new(Box::new(CycleAccurate::naive()))
    }

    /// Replay/memo tier over the cycle engine: first run per shape
    /// simulates, repeats replay cached timing.
    pub fn replay() -> Self {
        Self::new(Box::new(Replay::default()))
    }

    /// Analytic service with the shipped default calibration.
    pub fn analytic() -> Self {
        Self::new(Box::new(Analytic::default()))
    }

    /// Analytic service with a fitted calibration.
    pub fn analytic_with(cal: Calibration) -> Self {
        Self::new(Box::new(Analytic::with(cal)))
    }

    pub fn of_kind(kind: BackendKind) -> Self {
        match kind {
            BackendKind::Cycle => Self::cycle(),
            BackendKind::Analytic => Self::analytic(),
            BackendKind::Replay => Self::replay(),
        }
    }

    /// [`GemmService::of_kind`] with the FastPath toggle threaded
    /// through (the analytic model has no stepper and ignores it).
    pub fn of_kind_ff(kind: BackendKind, fast_forward: bool) -> Self {
        let cyc = CycleAccurate { fast_forward, threads: 0 };
        match kind {
            BackendKind::Cycle => Self::new(Box::new(cyc)),
            BackendKind::Replay => {
                Self::new(Box::new(Replay::with(cyc)))
            }
            BackendKind::Analytic => Self::analytic(),
        }
    }

    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// Whether the backend consumes operand data (functional
    /// simulation). True for the cycle and replay tiers.
    pub fn needs_data(&self) -> bool {
        self.backend.needs_data()
    }

    /// Memo-tier hit/miss counters when the backend replays timing
    /// (`None` for engines that simulate every submission).
    pub fn memo_stats(&self) -> Option<crate::backend::ReplayStats> {
        self.backend.memo_stats()
    }

    /// Memoized planning: tile selection + buffer placement + code
    /// generation, keyed by `(M, N, K, config, layout, epilogue)`.
    pub fn prepare(
        &self,
        config: ConfigId,
        m: usize,
        n: usize,
        k: usize,
        layout: LayoutKind,
    ) -> Result<Arc<PreparedGemm>> {
        self.prepare_fused(config, m, n, k, layout, Epilogue::NONE)
    }

    /// [`GemmService::prepare`] with a fused epilogue.
    pub fn prepare_fused(
        &self,
        config: ConfigId,
        m: usize,
        n: usize,
        k: usize,
        layout: LayoutKind,
        epi: Epilogue,
    ) -> Result<Arc<PreparedGemm>> {
        let key: PlanKey = (m, n, k, config, layout, epi);
        if let Some(p) = self.plans.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(p));
        }
        // Build outside the write lock; racing misses both build and
        // the first insert wins (plans are deterministic, so either
        // copy is equivalent). Only the inserting winner counts a
        // miss — losers found the entry present at insert time and
        // count hits — so the hit/miss split is exact regardless of
        // how many workers raced the first touch.
        let cfg = config.cluster_config();
        let plan = plan_gemm_fused(&cfg, m, n, k, layout, epi)?;
        let programs = if self.backend.needs_programs() {
            build_programs_fused(&cfg, &plan.tiling, &plan.map, epi)
                .into_iter()
                .map(Arc::new)
                .collect()
        } else {
            Vec::new()
        };
        let prep = Arc::new(PreparedGemm {
            config,
            plan,
            programs,
            lint_cache: Default::default(),
        });
        let mut w = self.plans.write().unwrap();
        match w.entry(key) {
            Entry::Occupied(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Ok(Arc::clone(e.get()))
            }
            Entry::Vacant(v) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Ok(Arc::clone(v.insert(prep)))
            }
        }
    }

    /// Evaluate one GEMM with explicit operands.
    pub fn run(
        &self,
        config: ConfigId,
        m: usize,
        n: usize,
        k: usize,
        layout: LayoutKind,
        a: &[f64],
        b: &[f64],
    ) -> Result<GemmResult> {
        let prep = self.prepare(config, m, n, k, layout)?;
        self.backend.run(&prep, a, b)
    }

    /// Evaluate one fused GEMM (`epilogue(A x B [+ bias])`) with
    /// explicit operands.
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused(
        &self,
        config: ConfigId,
        m: usize,
        n: usize,
        k: usize,
        layout: LayoutKind,
        epi: Epilogue,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
    ) -> Result<GemmResult> {
        let prep = self.prepare_fused(config, m, n, k, layout, epi)?;
        self.backend.run_fused(&prep, a, b, bias)
    }

    /// Evaluate one batched job (operands generated from its seed when
    /// the backend is functional).
    pub fn run_job(&self, job: &GemmJob) -> Result<GemmResult> {
        let prep = self.prepare_fused(
            job.config, job.m, job.n, job.k, job.layout, job.epi,
        )?;
        if self.backend.needs_data() {
            let (a, b) = test_matrices(job.m, job.n, job.k, job.seed);
            let bias = if job.epi.bias {
                test_bias(job.n, job.seed)
            } else {
                Vec::new()
            };
            self.backend.run_fused(&prep, &a, &b, &bias)
        } else {
            self.backend.run(&prep, &[], &[])
        }
    }

    /// Shard-aware planning: partition M x N across `clusters`
    /// clusters (K stays shard-local) and prepare the *one* uniform
    /// per-shard plan through the plan cache — every cluster of the
    /// fabric reuses the same `PreparedGemm`, so a fabric run costs a
    /// single plan-cache entry.
    #[allow(clippy::too_many_arguments)]
    pub fn prepare_sharded(
        &self,
        config: ConfigId,
        m: usize,
        n: usize,
        k: usize,
        layout: LayoutKind,
        epi: Epilogue,
        clusters: usize,
    ) -> Result<ShardedGemm> {
        check_dims(m, n, k)?;
        let grid = choose_shard_grid(m, n, clusters);
        let prep =
            self.prepare_fused(config, grid.sm, grid.sn, k, layout, epi)?;
        Ok(ShardedGemm {
            config,
            m,
            n,
            k,
            grid,
            shards: grid.shards(),
            prep,
        })
    }

    /// Evaluate one GEMM sharded across a cluster fabric: scatter
    /// operand blocks, run all clusters in lockstep against the
    /// shared NoC, gather C. On the cycle backend the gathered C is
    /// bit-identical to the single-cluster run — K stays shard-local,
    /// so every output element keeps its FMA association order.
    #[allow(clippy::too_many_arguments)]
    pub fn run_sharded(
        &self,
        config: ConfigId,
        m: usize,
        n: usize,
        k: usize,
        layout: LayoutKind,
        epi: Epilogue,
        a: &[f64],
        b: &[f64],
        bias: &[f64],
        fabric: &FabricConfig,
    ) -> Result<FabricResult> {
        let sh = self.prepare_sharded(
            config,
            m,
            n,
            k,
            layout,
            epi,
            fabric.clusters,
        )?;
        self.backend.run_sharded(&sh, &fabric.noc, a, b, bias)
    }

    /// [`GemmService::run_sharded`] for a batched job (operands
    /// generated from its seed when the backend is functional).
    pub fn run_sharded_job(
        &self,
        job: &GemmJob,
        fabric: &FabricConfig,
    ) -> Result<FabricResult> {
        let sh = self.prepare_sharded(
            job.config,
            job.m,
            job.n,
            job.k,
            job.layout,
            job.epi,
            fabric.clusters,
        )?;
        if self.backend.needs_data() {
            let (a, b) = test_matrices(job.m, job.n, job.k, job.seed);
            let bias = if job.epi.bias {
                test_bias(job.n, job.seed)
            } else {
                Vec::new()
            };
            self.backend.run_sharded(&sh, &fabric.noc, &a, &b, &bias)
        } else {
            self.backend.run_sharded(&sh, &fabric.noc, &[], &[], &[])
        }
    }

    /// Drain a batch across `threads` workers; results preserve the
    /// submission order.
    pub fn run_batch(
        &self,
        jobs: &[GemmJob],
        threads: usize,
    ) -> Result<Vec<GemmResult>> {
        runner::parallel_map(jobs, threads, |j| self.run_job(j))
    }

    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            plan_hits: self.hits.load(Ordering::Relaxed),
            plan_misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{host_ref, run_matmul};

    #[test]
    fn cycle_service_matches_driver() {
        let svc = GemmService::cycle();
        let (m, n, k) = (16, 16, 16);
        let (a, b) = test_matrices(m, n, k, 42);
        let via_svc = svc
            .run(ConfigId::Zonl48Db, m, n, k, LayoutKind::Grouped, &a, &b)
            .unwrap();
        let via_drv =
            run_matmul(ConfigId::Zonl48Db, m, n, k, &a, &b).unwrap();
        assert_eq!(via_svc.c, via_drv.c, "bit-for-bit output");
        assert_eq!(via_svc.cycles, via_drv.cycles);
        assert_eq!(
            via_svc.perf.window_cycles,
            via_drv.perf.window_cycles
        );
    }

    #[test]
    fn plan_cache_hits_on_repeat() {
        let svc = GemmService::cycle();
        let job = GemmJob::for_problem(
            ConfigId::Base32Fc,
            16,
            16,
            16,
            LayoutKind::Grouped,
        );
        let r1 = svc.run_job(&job).unwrap();
        let r2 = svc.run_job(&job).unwrap();
        assert_eq!(r1.cycles, r2.cycles, "deterministic replay");
        let s = svc.stats();
        assert_eq!(s.plan_misses, 1);
        assert!(s.plan_hits >= 1);
        assert!(s.hit_rate() > 0.0);
    }

    #[test]
    fn batch_preserves_order_and_numerics() {
        let svc = GemmService::cycle();
        let jobs: Vec<GemmJob> = [(8, 8, 8), (16, 8, 8), (8, 16, 24)]
            .iter()
            .map(|&(m, n, k)| {
                GemmJob::for_problem(
                    ConfigId::Zonl64Db,
                    m,
                    n,
                    k,
                    LayoutKind::Grouped,
                )
            })
            .collect();
        let rows = svc.run_batch(&jobs, 2).unwrap();
        assert_eq!(rows.len(), jobs.len());
        for (job, r) in jobs.iter().zip(&rows) {
            assert_eq!(r.plan.tiling.m, job.m);
            assert_eq!(r.plan.tiling.n, job.n);
            let (a, b) = test_matrices(job.m, job.n, job.k, job.seed);
            let want = host_ref(job.m, job.n, job.k, &a, &b);
            for (g, w) in r.c.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-9 * w.abs().max(1.0));
            }
        }
    }

    #[test]
    fn fused_jobs_cache_separately_and_match_driver() {
        use crate::kernels::epilogue::{Activation, Epilogue};
        use crate::kernels::{host_ref_fused, run_matmul_fused, test_bias};
        let svc = GemmService::cycle();
        let epi = Epilogue { bias: true, act: Some(Activation::Relu) };
        let plain = GemmJob::for_problem(
            ConfigId::Zonl48Db,
            16,
            16,
            16,
            LayoutKind::Grouped,
        );
        let fused = GemmJob::fused(
            ConfigId::Zonl48Db,
            16,
            16,
            16,
            LayoutKind::Grouped,
            epi,
        );
        svc.run_job(&plain).unwrap();
        let r = svc.run_job(&fused).unwrap();
        // distinct plans: the epilogue is part of the cache key
        assert_eq!(svc.stats().plan_misses, 2);
        let (a, b) = test_matrices(16, 16, 16, fused.seed);
        let bias = test_bias(16, fused.seed);
        let want = host_ref_fused(16, 16, 16, epi, &a, &b, &bias);
        assert_eq!(r.c, want, "fused batched job matches the oracle");
        let via_drv = run_matmul_fused(
            ConfigId::Zonl48Db,
            16,
            16,
            16,
            epi,
            &a,
            &b,
            &bias,
        )
        .unwrap();
        assert_eq!(r.c, via_drv.c);
        assert_eq!(r.cycles, via_drv.cycles);
    }

    #[test]
    fn sharded_cycle_matches_single_cluster_bit_exact() {
        use crate::fabric::FabricConfig;
        let svc = GemmService::cycle();
        let (m, n, k) = (32, 32, 16);
        let (a, b) = test_matrices(m, n, k, 13);
        let lone = svc
            .run(ConfigId::Zonl48Db, m, n, k, LayoutKind::Grouped, &a, &b)
            .unwrap();
        let fab = svc
            .run_sharded(
                ConfigId::Zonl48Db,
                m,
                n,
                k,
                LayoutKind::Grouped,
                crate::kernels::Epilogue::NONE,
                &a,
                &b,
                &[],
                &FabricConfig::new(4),
            )
            .unwrap();
        assert_eq!(fab.clusters(), 4);
        assert_eq!(fab.c, lone.c, "gathered C must be bit-identical");
        assert!(fab.cycles < lone.cycles, "4 shards finish sooner");
    }

    #[test]
    fn sharded_plans_share_one_cache_entry() {
        use crate::fabric::FabricConfig;
        let svc = GemmService::analytic();
        let job = GemmJob::for_problem(
            ConfigId::Zonl48Db,
            64,
            64,
            64,
            LayoutKind::Grouped,
        );
        svc.run_sharded_job(&job, &FabricConfig::new(4)).unwrap();
        let s = svc.stats();
        assert_eq!(s.plan_misses, 1, "uniform shards = one plan");
        // Re-running the same sharded job is a pure cache hit.
        svc.run_sharded_job(&job, &FabricConfig::new(4)).unwrap();
        let s2 = svc.stats();
        assert_eq!(s2.plan_misses, 1);
        assert!(s2.plan_hits >= 1);
    }

    #[test]
    fn sharded_single_cluster_fabric_degenerates_cleanly() {
        use crate::fabric::FabricConfig;
        let svc = GemmService::cycle();
        let (m, n, k) = (16, 16, 16);
        let (a, b) = test_matrices(m, n, k, 5);
        let lone = svc
            .run(ConfigId::Zonl48Db, m, n, k, LayoutKind::Grouped, &a, &b)
            .unwrap();
        let fab = svc
            .run_sharded(
                ConfigId::Zonl48Db,
                m,
                n,
                k,
                LayoutKind::Grouped,
                crate::kernels::Epilogue::NONE,
                &a,
                &b,
                &[],
                &FabricConfig::single(),
            )
            .unwrap();
        assert_eq!(fab.clusters(), 1);
        assert_eq!(fab.c, lone.c);
        assert_eq!(
            fab.cycles, lone.cycles,
            "1-cluster fabric is cycle-identical to the plain run"
        );
    }

    #[test]
    fn analytic_service_needs_no_data() {
        let svc = GemmService::analytic();
        let job = GemmJob::for_problem(
            ConfigId::Zonl48Db,
            32,
            32,
            32,
            LayoutKind::Grouped,
        );
        let r = svc.run_job(&job).unwrap();
        assert!(r.c.is_empty(), "no functional output");
        assert!(r.perf.utilization > 0.8);
        assert!(r.cycles > 0);
    }

    #[test]
    fn analytic_batch_is_fast_and_cached() {
        let svc = GemmService::analytic();
        let mut jobs = Vec::new();
        for _ in 0..4 {
            for (m, n, k) in [(32, 32, 32), (64, 64, 64)] {
                jobs.push(GemmJob::for_problem(
                    ConfigId::Zonl48Db,
                    m,
                    n,
                    k,
                    LayoutKind::Grouped,
                ));
            }
        }
        let rows = svc.run_batch(&jobs, 4).unwrap();
        assert_eq!(rows.len(), 8);
        // Two distinct plans; the exact accounting pins the split
        // even though first touches raced across 4 workers.
        let s = svc.stats();
        assert_eq!(s.plan_hits + s.plan_misses, 8);
        assert_eq!(s.plan_misses, 2, "{s:?}");
        assert_eq!(s.plan_hits, 6, "{s:?}");
        // A sequential replay is served entirely from the cache.
        let before = svc.stats();
        svc.run_batch(&jobs, 1).unwrap();
        let after = svc.stats();
        assert_eq!(after.plan_hits, before.plan_hits + 8);
        assert_eq!(after.plan_misses, before.plan_misses);
    }

    #[test]
    fn concurrent_first_touch_accounting_is_exact() {
        // Regression: 16 identical jobs racing on 8 workers used to
        // count several misses for the single distinct plan, skewing
        // hit_rate(). Exactly one miss must be recorded no matter how
        // the first touches interleave.
        for round in 0..4 {
            let svc = GemmService::analytic();
            let jobs: Vec<GemmJob> = (0..16)
                .map(|_| {
                    GemmJob::for_problem(
                        ConfigId::Zonl48Db,
                        32,
                        32,
                        32,
                        LayoutKind::Grouped,
                    )
                })
                .collect();
            svc.run_batch(&jobs, 8).unwrap();
            let s = svc.stats();
            assert_eq!(s.plan_misses, 1, "round {round}: {s:?}");
            assert_eq!(s.plan_hits, 15, "round {round}: {s:?}");
            assert!((s.hit_rate() - 15.0 / 16.0).abs() < 1e-12);
        }
    }

    #[test]
    fn concurrent_memo_tier_first_touches_are_exact() {
        // Same discipline, one tier up: 16 identical jobs racing on 8
        // workers against the replay backend's shape memo. The insert
        // winner books the single miss; every loser replays a hit —
        // at any interleaving.
        for round in 0..4 {
            let svc = GemmService::replay();
            let jobs: Vec<GemmJob> = (0..16)
                .map(|_| {
                    GemmJob::for_problem(
                        ConfigId::Zonl48Db,
                        32,
                        32,
                        32,
                        LayoutKind::Grouped,
                    )
                })
                .collect();
            svc.run_batch(&jobs, 8).unwrap();
            let ms = svc.memo_stats().expect("replay tier has stats");
            assert_eq!(ms.misses, 1, "round {round}: {ms:?}");
            assert_eq!(ms.hits, 15, "round {round}: {ms:?}");
        }
    }

    #[test]
    fn stats_delta_since_subtracts_snapshots() {
        let svc = GemmService::analytic();
        let job = GemmJob::for_problem(
            ConfigId::Zonl48Db,
            32,
            32,
            32,
            LayoutKind::Grouped,
        );
        svc.run_job(&job).unwrap();
        let snap = svc.stats();
        svc.run_job(&job).unwrap();
        svc.run_job(&job).unwrap();
        let d = svc.stats().delta_since(&snap);
        assert_eq!(d, ServiceStats { plan_hits: 2, plan_misses: 0 });
        // A stale (larger) snapshot saturates instead of wrapping.
        let zero = ServiceStats::default().delta_since(&snap);
        assert_eq!(zero, ServiceStats::default());
    }
}
