//! L1 tile-size selection.
//!
//! The cluster computes C = A x B by tiling M and N and keeping the
//! full K dimension resident (`kt == k`), which is what the paper's
//! kernel (Fig. 1b) assumes: every outer-loop iteration computes a
//! *complete* dot product, so C tiles are written exactly once and the
//! multi-pass C-accumulation problem never arises.
//!
//! Budget: double-buffered A, B *and* C tiles must fit the TCDM
//! (DESIGN.md §5): `2*(mt*k + k*nt + mt*nt)*8 <= tcdm_bytes`.

/// A tile plan for one problem/config pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tiling {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Tile height (rows of A/C); multiple of 8, divides m.
    pub mt: usize,
    /// Tile width (cols of B/C); multiple of 8, divides n.
    pub nt: usize,
}

impl Tiling {
    pub fn passes(&self) -> usize {
        (self.m / self.mt) * (self.n / self.nt)
    }

    pub fn grid(&self) -> (usize, usize) {
        (self.m / self.mt, self.n / self.nt)
    }

    /// Bytes of one phase's buffer set (A + B + C tiles).
    pub fn phase_bytes(&self) -> usize {
        (self.mt * self.k + self.k * self.nt + self.mt * self.nt) * 8
    }

    pub fn fits(&self, tcdm_bytes: usize) -> bool {
        2 * self.phase_bytes() <= tcdm_bytes
    }
}

/// Multiples of 8 that divide `x`, descending.
fn tile_candidates(x: usize) -> Vec<usize> {
    assert!(x % 8 == 0 && x > 0, "problem dims must be multiples of 8");
    let mut v: Vec<usize> =
        (1..=x / 8).map(|i| i * 8).filter(|t| x % t == 0).collect();
    v.sort_unstable_by(|a, b| b.cmp(a));
    v
}

/// Per-matrix word budget under the grouped (superbank-confined)
/// layout: one 8-bank group holds 16 KiB = 2048 words in the 48-bank
/// configuration (96 KiB / 6 groups) — the paper's footnote-5 "every
/// matrix within 8 banks" capacity. Applying it uniformly keeps tile
/// choices identical across configurations (fair comparison).
pub const GROUP_WORDS: usize = 2048;

/// Pick the tile maximizing per-pass compute, preferring square-ish
/// tiles (less DMA traffic per flop), subject to the TCDM budget and
/// the per-matrix group capacity.
pub fn choose_tiling(
    m: usize,
    n: usize,
    k: usize,
    tcdm_bytes: usize,
) -> Option<Tiling> {
    choose_tiling_for(m, n, k, tcdm_bytes, false)
}

/// [`choose_tiling`] with epilogue awareness: a fused bias epilogue
/// double-buffers an extra `nt`-word bias slice that shares the C
/// tile's bank group, tightening both the TCDM budget and the C
/// group's capacity.
pub fn choose_tiling_for(
    m: usize,
    n: usize,
    k: usize,
    tcdm_bytes: usize,
    bias: bool,
) -> Option<Tiling> {
    let mut best: Option<(i64, Tiling)> = None;
    for mt in tile_candidates(m) {
        for nt in tile_candidates(n) {
            let t = Tiling { m, n, k, mt, nt };
            let bias_words = if bias { nt } else { 0 };
            if 2 * (t.phase_bytes() + bias_words * 8) > tcdm_bytes {
                continue;
            }
            if mt * k > GROUP_WORDS
                || k * nt > GROUP_WORDS
                || mt * nt + bias_words > GROUP_WORDS
            {
                continue;
            }
            // score: compute volume first, then balance.
            let score = (mt * nt) as i64 * 1000
                - (mt as i64 - nt as i64).abs();
            if best.map_or(true, |(s, _)| score > s) {
                best = Some((score, t));
            }
        }
    }
    best.map(|(_, t)| t)
}

// ------------------------------------------------------------------
// Fabric-level sharding (multi-cluster partitioner)
// ------------------------------------------------------------------

/// One block of the fabric-level M x N shard grid. K stays local to
/// every shard (complete dot products, like the L1 tiling), so shards
/// never reduce across clusters and the gathered C is bit-identical
/// to a single-cluster run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// Grid coordinates (row-major over the `gm x gn` grid).
    pub row: usize,
    pub col: usize,
    /// Element offsets of this block in the full problem.
    pub m0: usize,
    pub n0: usize,
    /// Block shape (uniform across the grid).
    pub m: usize,
    pub n: usize,
}

/// The fabric-level partition: `gm x gn` uniform `sm x sn` blocks.
///
/// Invariants (enforced by [`choose_shard_grid`]):
/// * `gm * sm == m`, `gn * sn == n` — the grid tiles the problem
///   exactly, no remainder shards;
/// * `sm % 8 == 0`, `sn % 8 == 0` — every block stays on the
///   cluster's 8-grid (and `sn` on the UNROLL grid), so each shard is
///   itself a valid GEMM problem;
/// * all blocks identical — one `PreparedGemm` (plan-cache entry)
///   serves every cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardGrid {
    pub gm: usize,
    pub gn: usize,
    pub sm: usize,
    pub sn: usize,
}

impl ShardGrid {
    /// Clusters the grid keeps busy (`<=` the fabric size; the
    /// partitioner may leave clusters idle on indivisible problems).
    pub fn used_clusters(&self) -> usize {
        self.gm * self.gn
    }

    /// Row-major shard list.
    pub fn shards(&self) -> Vec<Shard> {
        let mut v = Vec::with_capacity(self.used_clusters());
        for row in 0..self.gm {
            for col in 0..self.gn {
                v.push(Shard {
                    row,
                    col,
                    m0: row * self.sm,
                    n0: col * self.sn,
                    m: self.sm,
                    n: self.sn,
                });
            }
        }
        v
    }
}

/// Choose the M x N shard grid for `clusters` clusters: maximize the
/// number of busy clusters, then minimize fabric DMA traffic — a
/// `gm x gn` grid moves `(m*gn + n*gm) * k` operand words over the
/// NoC, so skewed problems prefer splitting their long dimension.
/// Falls back toward fewer clusters (ultimately `1 x 1`) when the
/// dims don't divide on the 8-grid.
pub fn choose_shard_grid(m: usize, n: usize, clusters: usize) -> ShardGrid {
    let clusters = clusters.max(1);
    let mut best = ShardGrid { gm: 1, gn: 1, sm: m, sn: n };
    let mut best_used = 1usize;
    let mut best_traffic = usize::MAX;
    for gm in 1..=clusters {
        if gm * 8 > m || m % (gm * 8) != 0 {
            continue;
        }
        for gn in 1..=clusters / gm {
            if gn * 8 > n || n % (gn * 8) != 0 {
                continue;
            }
            let used = gm * gn;
            let traffic = m * gn + n * gm;
            if used > best_used
                || (used == best_used && traffic < best_traffic)
            {
                best_used = used;
                best_traffic = traffic;
                best = ShardGrid { gm, gn, sm: m / gm, sn: n / gn };
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cube32_fits_single_tile() {
        let t = choose_tiling(32, 32, 32, 128 * 1024).unwrap();
        assert_eq!((t.mt, t.nt), (32, 32));
        assert_eq!(t.passes(), 1);
    }

    #[test]
    fn cube128_needs_tiling() {
        let t = choose_tiling(128, 128, 128, 128 * 1024).unwrap();
        assert!(t.fits(128 * 1024));
        assert!(t.passes() > 1);
        assert_eq!(128 % t.mt, 0);
        assert_eq!(128 % t.nt, 0);
        // Group capacity caps each matrix at 2048 words: 16x16 tiles.
        assert_eq!((t.mt, t.nt), (16, 16));
        assert!(t.mt * t.k <= GROUP_WORDS);
    }

    #[test]
    fn cube128_in_96kib() {
        let t = choose_tiling(128, 128, 128, 96 * 1024).unwrap();
        assert!(t.fits(96 * 1024));
        assert!(2 * t.phase_bytes() <= 96 * 1024);
    }

    #[test]
    fn non_pow2_sizes() {
        for &(m, n, k) in
            &[(24, 40, 120), (8, 8, 8), (120, 8, 128), (104, 56, 72)]
        {
            for &bytes in &[96 * 1024, 128 * 1024] {
                let t = choose_tiling(m, n, k, bytes)
                    .unwrap_or_else(|| panic!("no tiling {m}x{n}x{k}"));
                assert_eq!(m % t.mt, 0);
                assert_eq!(n % t.nt, 0);
                assert!(t.mt % 8 == 0 && t.nt % 8 == 0);
                assert!(t.fits(bytes));
                assert!(t.mt * t.k <= GROUP_WORDS);
                assert!(t.k * t.nt <= GROUP_WORDS);
            }
        }
    }

    #[test]
    fn bias_budget_tightens_c_group() {
        // 64x32 tiles put C exactly at the 2048-word group capacity;
        // a fused bias epilogue must shrink the pick.
        let plain = choose_tiling_for(64, 64, 8, 128 * 1024, false).unwrap();
        assert_eq!(plain.mt * plain.nt, 2048);
        let fused = choose_tiling_for(64, 64, 8, 128 * 1024, true).unwrap();
        assert!(
            fused.mt * fused.nt + fused.nt <= GROUP_WORDS,
            "bias slice must fit the C group: {fused:?}"
        );
    }

    #[test]
    fn shard_grid_uses_all_clusters_when_divisible() {
        let g = choose_shard_grid(128, 128, 4);
        assert_eq!(g.used_clusters(), 4);
        assert_eq!((g.gm * g.sm, g.gn * g.sn), (128, 128));
        // square problem: balanced 2x2 beats 4x1 / 1x4 on traffic
        assert_eq!((g.gm, g.gn), (2, 2));
        let shards = g.shards();
        assert_eq!(shards.len(), 4);
        assert_eq!(shards[3], Shard {
            row: 1,
            col: 1,
            m0: 64,
            n0: 64,
            m: 64,
            n: 64,
        });
    }

    #[test]
    fn shard_grid_splits_the_long_dimension() {
        // 256 x 32: splitting N into 4 would leave 8-wide slivers and
        // cost 256*4 words of A replication; 4x1 over M is cheaper.
        let g = choose_shard_grid(256, 32, 4);
        assert_eq!(g.used_clusters(), 4);
        assert_eq!((g.gm, g.gn), (4, 1));
        assert_eq!((g.sm, g.sn), (64, 32));
    }

    #[test]
    fn shard_grid_degrades_on_indivisible_dims() {
        // 24 x 24 over 4 clusters: 2x2 fits (12 is not a multiple of
        // 8, so 2-way splits are illegal) -> 3-way splits work on the
        // 8-grid; 3x1 or 1x3 uses 3 of the 4 clusters.
        let g = choose_shard_grid(24, 24, 4);
        assert_eq!(g.used_clusters(), 3);
        assert!(g.sm % 8 == 0 && g.sn % 8 == 0);
        // 8 x 8 cannot split at all.
        let tiny = choose_shard_grid(8, 8, 4);
        assert_eq!(tiny.used_clusters(), 1);
        assert_eq!((tiny.sm, tiny.sn), (8, 8));
    }

    #[test]
    fn shard_grid_covers_problem_exactly() {
        for &(m, n, c) in &[
            (64, 64, 2),
            (64, 64, 4),
            (128, 96, 4),
            (96, 64, 8),
            (40, 72, 6),
        ] {
            let g = choose_shard_grid(m, n, c);
            assert!(g.used_clusters() <= c);
            let mut covered = vec![false; m * n];
            for s in g.shards() {
                assert_eq!((s.m, s.n), (g.sm, g.sn), "uniform blocks");
                for i in s.m0..s.m0 + s.m {
                    for j in s.n0..s.n0 + s.n {
                        assert!(!covered[i * n + j], "overlap at {i},{j}");
                        covered[i * n + j] = true;
                    }
                }
            }
            assert_eq!(
                covered.iter().filter(|&&x| x).count(),
                m * n,
                "{m}x{n}/{c}: grid must tile the problem exactly"
            );
        }
    }

    #[test]
    fn prefers_larger_then_square() {
        let t = choose_tiling(64, 64, 8, 128 * 1024).unwrap();
        // k tiny: group capacity (not total TCDM) is the binding
        // constraint: 64x8=512 words per A tile fits, C=64x64=4096
        // words does not -> 32x64 or 64x32.
        assert_eq!(t.mt * t.nt, 2048);
    }
}
