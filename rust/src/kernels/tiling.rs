//! L1 tile-size selection.
//!
//! The cluster computes C = A x B by tiling M and N and keeping the
//! full K dimension resident (`kt == k`), which is what the paper's
//! kernel (Fig. 1b) assumes: every outer-loop iteration computes a
//! *complete* dot product, so C tiles are written exactly once and the
//! multi-pass C-accumulation problem never arises.
//!
//! Budget: double-buffered A, B *and* C tiles must fit the TCDM
//! (DESIGN.md §5): `2*(mt*k + k*nt + mt*nt)*8 <= tcdm_bytes`.

/// A tile plan for one problem/config pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tiling {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Tile height (rows of A/C); multiple of 8, divides m.
    pub mt: usize,
    /// Tile width (cols of B/C); multiple of 8, divides n.
    pub nt: usize,
}

impl Tiling {
    pub fn passes(&self) -> usize {
        (self.m / self.mt) * (self.n / self.nt)
    }

    pub fn grid(&self) -> (usize, usize) {
        (self.m / self.mt, self.n / self.nt)
    }

    /// Bytes of one phase's buffer set (A + B + C tiles).
    pub fn phase_bytes(&self) -> usize {
        (self.mt * self.k + self.k * self.nt + self.mt * self.nt) * 8
    }

    pub fn fits(&self, tcdm_bytes: usize) -> bool {
        2 * self.phase_bytes() <= tcdm_bytes
    }
}

/// Multiples of 8 that divide `x`, descending.
fn tile_candidates(x: usize) -> Vec<usize> {
    assert!(x % 8 == 0 && x > 0, "problem dims must be multiples of 8");
    let mut v: Vec<usize> =
        (1..=x / 8).map(|i| i * 8).filter(|t| x % t == 0).collect();
    v.sort_unstable_by(|a, b| b.cmp(a));
    v
}

/// Per-matrix word budget under the grouped (superbank-confined)
/// layout: one 8-bank group holds 16 KiB = 2048 words in the 48-bank
/// configuration (96 KiB / 6 groups) — the paper's footnote-5 "every
/// matrix within 8 banks" capacity. Applying it uniformly keeps tile
/// choices identical across configurations (fair comparison).
pub const GROUP_WORDS: usize = 2048;

/// Pick the tile maximizing per-pass compute, preferring square-ish
/// tiles (less DMA traffic per flop), subject to the TCDM budget and
/// the per-matrix group capacity.
pub fn choose_tiling(
    m: usize,
    n: usize,
    k: usize,
    tcdm_bytes: usize,
) -> Option<Tiling> {
    choose_tiling_for(m, n, k, tcdm_bytes, false)
}

/// [`choose_tiling`] with epilogue awareness: a fused bias epilogue
/// double-buffers an extra `nt`-word bias slice that shares the C
/// tile's bank group, tightening both the TCDM budget and the C
/// group's capacity.
pub fn choose_tiling_for(
    m: usize,
    n: usize,
    k: usize,
    tcdm_bytes: usize,
    bias: bool,
) -> Option<Tiling> {
    let mut best: Option<(i64, Tiling)> = None;
    for mt in tile_candidates(m) {
        for nt in tile_candidates(n) {
            let t = Tiling { m, n, k, mt, nt };
            let bias_words = if bias { nt } else { 0 };
            if 2 * (t.phase_bytes() + bias_words * 8) > tcdm_bytes {
                continue;
            }
            if mt * k > GROUP_WORDS
                || k * nt > GROUP_WORDS
                || mt * nt + bias_words > GROUP_WORDS
            {
                continue;
            }
            // score: compute volume first, then balance.
            let score = (mt * nt) as i64 * 1000
                - (mt as i64 - nt as i64).abs();
            if best.map_or(true, |(s, _)| score > s) {
                best = Some((score, t));
            }
        }
    }
    best.map(|(_, t)| t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cube32_fits_single_tile() {
        let t = choose_tiling(32, 32, 32, 128 * 1024).unwrap();
        assert_eq!((t.mt, t.nt), (32, 32));
        assert_eq!(t.passes(), 1);
    }

    #[test]
    fn cube128_needs_tiling() {
        let t = choose_tiling(128, 128, 128, 128 * 1024).unwrap();
        assert!(t.fits(128 * 1024));
        assert!(t.passes() > 1);
        assert_eq!(128 % t.mt, 0);
        assert_eq!(128 % t.nt, 0);
        // Group capacity caps each matrix at 2048 words: 16x16 tiles.
        assert_eq!((t.mt, t.nt), (16, 16));
        assert!(t.mt * t.k <= GROUP_WORDS);
    }

    #[test]
    fn cube128_in_96kib() {
        let t = choose_tiling(128, 128, 128, 96 * 1024).unwrap();
        assert!(t.fits(96 * 1024));
        assert!(2 * t.phase_bytes() <= 96 * 1024);
    }

    #[test]
    fn non_pow2_sizes() {
        for &(m, n, k) in
            &[(24, 40, 120), (8, 8, 8), (120, 8, 128), (104, 56, 72)]
        {
            for &bytes in &[96 * 1024, 128 * 1024] {
                let t = choose_tiling(m, n, k, bytes)
                    .unwrap_or_else(|| panic!("no tiling {m}x{n}x{k}"));
                assert_eq!(m % t.mt, 0);
                assert_eq!(n % t.nt, 0);
                assert!(t.mt % 8 == 0 && t.nt % 8 == 0);
                assert!(t.fits(bytes));
                assert!(t.mt * t.k <= GROUP_WORDS);
                assert!(t.k * t.nt <= GROUP_WORDS);
            }
        }
    }

    #[test]
    fn bias_budget_tightens_c_group() {
        // 64x32 tiles put C exactly at the 2048-word group capacity;
        // a fused bias epilogue must shrink the pick.
        let plain = choose_tiling_for(64, 64, 8, 128 * 1024, false).unwrap();
        assert_eq!(plain.mt * plain.nt, 2048);
        let fused = choose_tiling_for(64, 64, 8, 128 * 1024, true).unwrap();
        assert!(
            fused.mt * fused.nt + fused.nt <= GROUP_WORDS,
            "bias slice must fit the C group: {fused:?}"
        );
    }

    #[test]
    fn prefers_larger_then_square() {
        let t = choose_tiling(64, 64, 8, 128 * 1024).unwrap();
        // k tiny: group capacity (not total TCDM) is the binding
        // constraint: 64x8=512 words per A tile fits, C=64x64=4096
        // words does not -> 32x64 or 64x32.
        assert_eq!(t.mt * t.nt, 2048);
    }
}
