//! # zerostall
//!
//! A cycle-accurate, functional co-design framework for energy-efficient
//! RISC-V compute clusters, reproducing *"Towards Zero-Stall Matrix
//! Multiplication on Energy-Efficient RISC-V Clusters for Machine
//! Learning Acceleration"* (ETH Zurich, 2025).
//!
//! The crate models the full Snitch cluster — cores, FREP sequencer,
//! SSR streamers, multi-banked TCDM behind fully-connected or
//! double-buffering-aware (Dobu) interconnects, and the 512-bit DMA —
//! plus the paper's evaluation harness: area/power models, the OpenGeMM
//! comparator, and the Fig. 5 / Table I / Table II experiments.
//!
//! Evaluation runs through two [`backend`] engines behind one
//! `SimBackend` trait — the cycle-accurate machine model and a
//! calibrated first-order analytic model — fronted by the batched,
//! plan-memoizing `kernels::GemmService`. Above that sit the
//! NetGraph DAG scheduler (`coordinator::net`), the multi-cluster
//! `fabric`, and ServeSim (`coordinator::serve`), a deterministic
//! request-level serving simulator with FIFO and continuous-batching
//! policies.
//!
//! See DESIGN.md for the system inventory and architecture notes.

pub mod backend;
pub mod cluster;
pub mod coordinator;
pub mod core;
pub mod dma;
pub mod fabric;
pub mod isa;
pub mod kernels;
pub mod mem;
pub mod model;
pub mod opengemm;
pub mod profile;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod ssr;
pub mod util;
pub mod verify;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
