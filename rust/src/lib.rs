//! # zerostall
//!
//! A cycle-accurate, functional co-design framework for energy-efficient
//! RISC-V compute clusters, reproducing *"Towards Zero-Stall Matrix
//! Multiplication on Energy-Efficient RISC-V Clusters for Machine
//! Learning Acceleration"* (ETH Zurich, 2025).
//!
//! The crate models the full Snitch cluster — cores, FREP sequencer,
//! SSR streamers, multi-banked TCDM behind fully-connected or
//! double-buffering-aware (Dobu) interconnects, and the 512-bit DMA —
//! plus the paper's evaluation harness: area/power models, the OpenGeMM
//! comparator, and the Fig. 5 / Table I / Table II experiments.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod cluster;
pub mod coordinator;
pub mod core;
pub mod dma;
pub mod isa;
pub mod kernels;
pub mod mem;
pub mod model;
pub mod opengemm;
pub mod runtime;
pub mod ssr;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
