//! zerostall CLI — see `zerostall help`.

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    zerostall::coordinator::cli::main_with_args(args)
}
