//! Per-cycle TCDM interconnect arbitration.
//!
//! Models the request path of both interconnects:
//!
//! * core ports → (fully-connected crossbar within each hyperbank) →
//!   single-ported banks, round-robin arbitration per bank;
//! * the DMA's 512-bit branch → superbank mux: when the DMA targets a
//!   superbank, the mux grants the whole superbank to either the DMA
//!   beat or the core side (round-robin on contention), exactly like
//!   the mux at each superbank in the baseline Snitch cluster [7];
//! * in the Dobu topology the demux stage places core and DMA traffic
//!   in their addressed hyperbanks first — requests in different
//!   hyperbanks are conflict-free by construction.
//!
//! The arbiter is allocation-free on the hot path: callers reuse a
//! request buffer, grants are returned through a parallel slice.

use super::{Tcdm, BANKS_PER_SUPERBANK};

/// One 64-bit core-side request (SSR streamer or LSU).
#[derive(Clone, Copy, Debug)]
pub struct PortRequest {
    /// Global requestor id (core * 5 + {ssr0..ssr3, lsu}).
    pub port: u16,
    pub addr: u32,
    pub write: bool,
    /// Write data (bits) — ignored for reads.
    pub data: u64,
}

/// One DMA beat: up to 8 consecutive words within one superbank row.
#[derive(Clone, Copy, Debug)]
pub struct DmaBeat {
    pub addr: u32,
    pub n_words: u8,
    pub write: bool,
    pub data: [u64; 8],
}

#[derive(Clone, Copy, Debug, Default)]
pub struct XbarStats {
    pub core_grants: u64,
    /// Core requests that lost their bank's round-robin to another
    /// core port (bank-level losses only — disjoint from
    /// `core_conflicts_dma`).
    pub core_conflicts: u64,
    /// Core requests whose whole superbank was captured by a granted
    /// DMA beat, one count per losing port per cycle.
    pub core_conflicts_dma: u64,
    pub dma_grants: u64,
    pub dma_conflicts: u64,
    /// Cycles in which at least one core request was denied — the
    /// per-cycle contention footprint StallScope's report quotes next
    /// to the per-request counters above.
    pub conflict_cycles: u64,
}

impl XbarStats {
    /// All denied-and-retried core requests, regardless of cause.
    pub fn core_conflicts_total(&self) -> u64 {
        self.core_conflicts + self.core_conflicts_dma
    }
}

/// Outcome of one arbitration cycle.
pub struct ArbOutcome {
    pub dma_granted: bool,
    /// Read data for the DMA beat (when it was a granted read).
    pub dma_read: [u64; 8],
}

pub struct Interconnect {
    n_ports: usize,
    /// Round-robin pointer per bank.
    rr_bank: Vec<u16>,
    /// Per-superbank mux: true = DMA has priority next contest.
    rr_superbank: Vec<bool>,
    /// Scratch: winning request index per bank this cycle (reused).
    winner: Vec<u32>,
    /// Scratch: banks touched this cycle.
    touched: Vec<u32>,
    pub stats: XbarStats,
}

const NO_WINNER: u32 = u32::MAX;

impl Interconnect {
    pub fn new(total_banks: usize, n_ports: usize) -> Self {
        Self {
            n_ports,
            rr_bank: vec![0; total_banks],
            rr_superbank: vec![true; total_banks / BANKS_PER_SUPERBANK],
            winner: vec![NO_WINNER; total_banks],
            touched: Vec::with_capacity(64),
            stats: XbarStats::default(),
        }
    }

    /// Arbitrate one cycle.
    ///
    /// * `reqs` — core-side requests; `grants[i]` is set true when
    ///   `reqs[i]` wins its bank (reads additionally deposit data in
    ///   `read_data[i]`).
    /// * `dma` — at most one DMA beat.
    ///
    /// Memory side effects (bank reads/writes) are applied for winners.
    pub fn arbitrate(
        &mut self,
        tcdm: &mut Tcdm,
        reqs: &[PortRequest],
        grants: &mut [bool],
        read_data: &mut [u64],
        dma: Option<&DmaBeat>,
    ) -> ArbOutcome {
        debug_assert_eq!(reqs.len(), grants.len());
        debug_assert_eq!(reqs.len(), read_data.len());

        // ---- DMA superbank claim ------------------------------------
        // A beat touches banks [first_bank .. first_bank + n) which by
        // construction lie within one superbank of one hyperbank.
        let mut dma_sb: Option<usize> = None;
        if let Some(b) = dma {
            debug_assert!(b.n_words >= 1 && b.n_words as usize <= 8);
            let bank0 = tcdm.bank_of(b.addr);
            debug_assert_eq!(
                tcdm.superbank_of_bank(bank0),
                tcdm.superbank_of_bank(
                    tcdm.bank_of(b.addr + (b.n_words as u32 - 1) * 8)
                ),
                "DMA beat crosses a superbank boundary"
            );
            dma_sb = Some(tcdm.superbank_of_bank(bank0));
        }

        // ---- per-bank round-robin among core requests ----------------
        // Single pass: keep the candidate with the smallest rr distance.
        self.touched.clear();
        let mut core_wants_dma_sb = false;
        for (i, r) in reqs.iter().enumerate() {
            let bank = tcdm.bank_of(r.addr);
            if Some(tcdm.superbank_of_bank(bank)) == dma_sb {
                core_wants_dma_sb = true;
            }
            let cur = self.winner[bank];
            if cur == NO_WINNER {
                self.winner[bank] = i as u32;
                self.touched.push(bank as u32);
            } else {
                let rr = self.rr_bank[bank] as i32;
                let dist = |p: u16| -> i32 {
                    let d = p as i32 - rr;
                    if d < 0 {
                        d + self.n_ports as i32
                    } else {
                        d
                    }
                };
                if dist(r.port) < dist(reqs[cur as usize].port) {
                    self.winner[bank] = i as u32;
                }
            }
        }

        // ---- superbank mux: DMA vs core side -------------------------
        let mut dma_granted = false;
        if let (Some(b), Some(sb)) = (dma, dma_sb) {
            let contested = core_wants_dma_sb;
            if !contested || self.rr_superbank[sb] {
                dma_granted = true;
            }
            if contested {
                // Alternate priority after every contested cycle.
                self.rr_superbank[sb] = !dma_granted;
            }
            if dma_granted {
                self.stats.dma_grants += 1;
            } else {
                self.stats.dma_conflicts += 1;
            }
            let _ = b;
        }

        // ---- commit ---------------------------------------------------
        let mut out = ArbOutcome {
            dma_granted,
            dma_read: [0u64; 8],
        };
        if dma_granted {
            let b = dma.unwrap();
            for w in 0..b.n_words as usize {
                let addr = b.addr + (w as u32) * 8;
                if b.write {
                    tcdm.write_u64(addr, b.data[w]);
                } else {
                    out.dma_read[w] = tcdm.read_u64(addr);
                }
            }
        }

        let mut granted = 0usize;
        for &bank_u in &self.touched {
            let bank = bank_u as usize;
            let w = self.winner[bank];
            self.winner[bank] = NO_WINNER; // reset scratch for next cycle
            let sb = tcdm.superbank_of_bank(bank);
            if dma_granted && Some(sb) == dma_sb {
                // whole superbank captured by the DMA beat this cycle
                continue;
            }
            let i = w as usize;
            let r = &reqs[i];
            if r.write {
                tcdm.write_u64(r.addr, r.data);
            } else {
                read_data[i] = tcdm.read_u64(r.addr);
            }
            grants[i] = true;
            granted += 1;
            self.rr_bank[bank] = (r.port + 1) % self.n_ports as u16;
        }

        // ---- stats ----------------------------------------------------
        // Split the losers by cause: every request whose superbank a
        // granted DMA beat captured lost to the mux (one count per
        // port), everything else lost its bank's round-robin.
        self.stats.core_grants += granted as u64;
        let mut dma_captured = 0u64;
        if dma_granted && core_wants_dma_sb {
            for (i, r) in reqs.iter().enumerate() {
                if !grants[i]
                    && tcdm.superbank_of_bank(tcdm.bank_of(r.addr))
                        == dma_sb.unwrap()
                {
                    dma_captured += 1;
                }
            }
        }
        self.stats.core_conflicts_dma += dma_captured;
        self.stats.core_conflicts +=
            ((reqs.len() - granted) as u64).saturating_sub(dma_captured);
        if reqs.len() > granted {
            self.stats.conflict_cycles += 1;
        }

        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{Topology, TCDM_BASE};

    fn tcdm32() -> Tcdm {
        Tcdm::new(Topology::Fc { banks: 32 }, 128 * 1024)
    }

    fn run(
        xbar: &mut Interconnect,
        tcdm: &mut Tcdm,
        reqs: &[PortRequest],
        dma: Option<&DmaBeat>,
    ) -> (Vec<bool>, Vec<u64>, ArbOutcome) {
        let mut grants = vec![false; reqs.len()];
        let mut data = vec![0u64; reqs.len()];
        let o = xbar.arbitrate(tcdm, reqs, &mut grants, &mut data, dma);
        (grants, data, o)
    }

    fn rd(port: u16, addr: u32) -> PortRequest {
        PortRequest { port, addr, write: false, data: 0 }
    }

    #[test]
    fn distinct_banks_all_granted() {
        let mut tcdm = tcdm32();
        let mut x = Interconnect::new(32, 36);
        let reqs: Vec<_> =
            (0..24).map(|i| rd(i, TCDM_BASE + (i as u32) * 8)).collect();
        let (grants, _, _) = run(&mut x, &mut tcdm, &reqs, None);
        assert!(grants.iter().all(|&g| g), "no conflicts across 24 banks");
        assert_eq!(x.stats.core_conflicts, 0);
    }

    #[test]
    fn same_bank_serializes_round_robin() {
        let mut tcdm = tcdm32();
        let mut x = Interconnect::new(32, 8);
        let reqs: Vec<_> = (0..4).map(|p| rd(p, TCDM_BASE)).collect();
        let (g1, _, _) = run(&mut x, &mut tcdm, &reqs, None);
        assert_eq!(g1.iter().filter(|&&g| g).count(), 1);
        assert!(g1[0], "rr starts at port 0");
        // Next cycle the pointer moved past port 0.
        let (g2, _, _) = run(&mut x, &mut tcdm, &reqs, None);
        assert!(g2[1], "rr advances");
        assert_eq!(x.stats.core_conflicts, 6);
    }

    #[test]
    fn read_returns_written_value() {
        let mut tcdm = tcdm32();
        tcdm.write_f64(TCDM_BASE + 8, 7.5);
        let mut x = Interconnect::new(32, 8);
        let reqs = vec![rd(0, TCDM_BASE + 8)];
        let (g, d, _) = run(&mut x, &mut tcdm, &reqs, None);
        assert!(g[0]);
        assert_eq!(f64::from_bits(d[0]), 7.5);
    }

    #[test]
    fn write_commits_only_on_grant() {
        let mut tcdm = tcdm32();
        let mut x = Interconnect::new(32, 8);
        let w1 = PortRequest {
            port: 0,
            addr: TCDM_BASE,
            write: true,
            data: 1.0f64.to_bits(),
        };
        let w2 = PortRequest {
            port: 1,
            addr: TCDM_BASE,
            write: true,
            data: 2.0f64.to_bits(),
        };
        let (g, _, _) = run(&mut x, &mut tcdm, &[w1, w2], None);
        assert!(g[0] && !g[1]);
        assert_eq!(tcdm.read_f64(TCDM_BASE), 1.0);
    }

    #[test]
    fn dma_beat_takes_whole_superbank() {
        let mut tcdm = tcdm32();
        let mut x = Interconnect::new(32, 36);
        let beat = DmaBeat {
            addr: TCDM_BASE, // banks 0..8 = superbank 0
            n_words: 8,
            write: true,
            data: [42; 8],
        };
        // Core requests to banks 3 (inside sb0) and 9 (outside).
        let reqs = vec![rd(0, TCDM_BASE + 3 * 8), rd(1, TCDM_BASE + 9 * 8)];
        let (g, _, o) = run(&mut x, &mut tcdm, &reqs, Some(&beat));
        assert!(o.dma_granted, "DMA has first priority");
        assert!(!g[0], "bank 3 captured by DMA");
        assert!(g[1], "bank 9 unaffected");
        assert_eq!(tcdm.read_u64(TCDM_BASE + 7 * 8), 42);
        // Contested: priority flips to the core side next cycle.
        let (g2, _, o2) = run(&mut x, &mut tcdm, &reqs, Some(&beat));
        assert!(!o2.dma_granted);
        assert!(g2[0] && g2[1]);
    }

    #[test]
    fn dma_uncontested_always_granted() {
        let mut tcdm = tcdm32();
        let mut x = Interconnect::new(32, 36);
        let beat = DmaBeat {
            addr: TCDM_BASE + 64, // superbank 1
            n_words: 8,
            write: false,
            data: [0; 8],
        };
        for _ in 0..5 {
            let (_, _, o) = run(&mut x, &mut tcdm, &[], Some(&beat));
            assert!(o.dma_granted);
        }
        assert_eq!(x.stats.dma_conflicts, 0);
    }

    #[test]
    fn dma_read_beat_returns_data() {
        let mut tcdm = tcdm32();
        for w in 0..8 {
            tcdm.write_u64(TCDM_BASE + w * 8, 100 + w as u64);
        }
        let mut x = Interconnect::new(32, 36);
        let beat = DmaBeat {
            addr: TCDM_BASE,
            n_words: 8,
            write: false,
            data: [0; 8],
        };
        let (_, _, o) = run(&mut x, &mut tcdm, &[], Some(&beat));
        assert!(o.dma_granted);
        assert_eq!(o.dma_read, [100, 101, 102, 103, 104, 105, 106, 107]);
    }

    #[test]
    fn dobu_hyperbank_isolation() {
        // Cores in hyperbank 0, DMA in hyperbank 1: never a conflict.
        let mut tcdm =
            Tcdm::new(Topology::Dobu { banks_per_hyper: 24 }, 96 * 1024);
        let mut x = Interconnect::new(48, 36);
        let half = 48 * 1024;
        let beat = DmaBeat {
            addr: TCDM_BASE + half, // hyperbank 1, superbank 3
            n_words: 8,
            write: true,
            data: [7; 8],
        };
        let reqs: Vec<_> =
            (0..24).map(|i| rd(i, TCDM_BASE + (i as u32) * 8)).collect();
        for _ in 0..10 {
            let (g, _, o) = run(&mut x, &mut tcdm, &reqs, Some(&beat));
            assert!(o.dma_granted);
            assert!(g.iter().all(|&gg| gg));
        }
        assert_eq!(x.stats.core_conflicts, 0);
        assert_eq!(x.stats.dma_conflicts, 0);
    }

    #[test]
    fn dma_mux_losers_counted_per_port() {
        // Acceptance: a cycle with k ports losing to the DMA mux
        // reports exactly k in the DMA-conflict counter and 0
        // bank-level conflicts.
        let mut tcdm = tcdm32();
        let mut x = Interconnect::new(32, 36);
        let beat = DmaBeat {
            addr: TCDM_BASE, // superbank 0 (banks 0..8)
            n_words: 8,
            write: true,
            data: [5; 8],
        };
        // k = 3 ports to three *distinct* banks inside superbank 0:
        // none of them conflicts at the bank level, all lose to the mux.
        let reqs: Vec<_> =
            (0..3).map(|p| rd(p, TCDM_BASE + (p as u32) * 8)).collect();
        let (g, _, o) = run(&mut x, &mut tcdm, &reqs, Some(&beat));
        assert!(o.dma_granted, "DMA wins the first contested cycle");
        assert!(g.iter().all(|&gg| !gg), "all ports captured");
        assert_eq!(x.stats.core_conflicts_dma, 3, "one count per port");
        assert_eq!(x.stats.core_conflicts, 0, "no bank-level losses");
    }

    #[test]
    fn conflict_split_is_disjoint_and_complete() {
        // Mixed cycle: 2 ports to one bank outside the DMA superbank
        // (1 bank-level loser) + 2 ports to distinct banks inside it
        // (2 mux losers).
        let mut tcdm = tcdm32();
        let mut x = Interconnect::new(32, 36);
        let beat = DmaBeat {
            addr: TCDM_BASE, // superbank 0
            n_words: 8,
            write: true,
            data: [9; 8],
        };
        let reqs = vec![
            rd(0, TCDM_BASE),          // bank 0, captured
            rd(1, TCDM_BASE + 8),      // bank 1, captured
            rd(2, TCDM_BASE + 9 * 8),  // bank 9, wins
            rd(3, TCDM_BASE + 9 * 8),  // bank 9, bank-level loser
        ];
        let (g, _, o) = run(&mut x, &mut tcdm, &reqs, Some(&beat));
        assert!(o.dma_granted);
        assert_eq!(g, vec![false, false, true, false]);
        assert_eq!(x.stats.core_conflicts_dma, 2);
        assert_eq!(x.stats.core_conflicts, 1);
        assert_eq!(
            x.stats.core_conflicts_total(),
            3,
            "split partitions the losers"
        );
        // A denied DMA beat charges nothing to the DMA counter.
        let (g2, _, o2) = run(&mut x, &mut tcdm, &reqs, Some(&beat));
        assert!(!o2.dma_granted, "priority flipped to the core side");
        assert!(g2[0] && g2[1]);
        assert_eq!(x.stats.core_conflicts_dma, 2, "unchanged");
    }

    #[test]
    fn rr_fairness_over_many_cycles() {
        let mut tcdm = tcdm32();
        let mut x = Interconnect::new(32, 4);
        let reqs: Vec<_> = (0..4).map(|p| rd(p, TCDM_BASE)).collect();
        let mut wins = [0u32; 4];
        for _ in 0..400 {
            let (g, _, _) = run(&mut x, &mut tcdm, &reqs, None);
            for (i, &gg) in g.iter().enumerate() {
                if gg {
                    wins[i] += 1;
                }
            }
        }
        for &w in &wins {
            assert_eq!(w, 100, "perfect round-robin under saturation");
        }
    }
}
