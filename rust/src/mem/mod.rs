//! Memory subsystem: TCDM (multi-banked L1 scratchpad), main memory,
//! and the address map.
//!
//! Two bank organizations model the paper's §III-B:
//!
//! * **Fully-connected** (`Fc`): one flat set of banks, words
//!   interleaved across all of them; every core port reaches every bank
//!   through the all-to-all crossbar; the DMA reaches any *superbank*
//!   (8 consecutive banks) through its own branch, arbitrated by a mux
//!   at each superbank.
//! * **Dobu** (`Dobu`): two *hyperbanks*, each a contiguous address
//!   region with words interleaved across its own banks (the address
//!   MSB selects the hyperbank; each hyperbank is addressed like the
//!   original TCDM).  A demux stage after the per-hyperbank crossbar
//!   routes each request, so compute and DMA traffic in different
//!   hyperbanks can never conflict — the zero-conflict property
//!   double-buffered kernels exploit.

pub mod interconnect;

pub use interconnect::{DmaBeat, Interconnect, PortRequest, XbarStats};

/// TCDM base address (cluster-local scratchpad).
pub const TCDM_BASE: u32 = 0x1000_0000;
/// Main (off-cluster) memory base address.
pub const MAIN_MEM_BASE: u32 = 0x8000_0000;
/// Banks per superbank (the DMA's 512-bit beat spans exactly one).
pub const BANKS_PER_SUPERBANK: usize = 8;

/// Bank organization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Flat interleaving over `banks` banks.
    Fc { banks: usize },
    /// Two hyperbanks of `banks_per_hyper` banks each.
    Dobu { banks_per_hyper: usize },
}

impl Topology {
    pub fn total_banks(&self) -> usize {
        match *self {
            Topology::Fc { banks } => banks,
            Topology::Dobu { banks_per_hyper } => 2 * banks_per_hyper,
        }
    }

    pub fn hyperbanks(&self) -> usize {
        match *self {
            Topology::Fc { .. } => 1,
            Topology::Dobu { .. } => 2,
        }
    }

    pub fn banks_per_hyperbank(&self) -> usize {
        match *self {
            Topology::Fc { banks } => banks,
            Topology::Dobu { banks_per_hyper } => banks_per_hyper,
        }
    }
}

/// The tightly-coupled data memory.
pub struct Tcdm {
    pub topology: Topology,
    pub bytes: usize,
    /// Cached words-per-hyperbank (avoids a division per access).
    half_words: usize,
    words: Vec<u64>,
}

impl Tcdm {
    pub fn new(topology: Topology, bytes: usize) -> Self {
        assert_eq!(bytes % 8, 0);
        let banks = topology.total_banks();
        assert_eq!(
            bytes / 8 % banks,
            0,
            "TCDM words must divide evenly across banks"
        );
        assert_eq!(banks % BANKS_PER_SUPERBANK, 0);
        Self {
            topology,
            bytes,
            half_words: bytes / 8 / topology.hyperbanks(),
            words: vec![0u64; bytes / 8],
        }
    }

    pub fn contains(&self, addr: u32) -> bool {
        addr >= TCDM_BASE && addr < TCDM_BASE + self.bytes as u32
    }

    #[inline]
    fn word_index(&self, addr: u32) -> usize {
        debug_assert!(self.contains(addr), "TCDM OOB: {addr:#x}");
        debug_assert_eq!(addr % 8, 0, "unaligned TCDM access: {addr:#x}");
        ((addr - TCDM_BASE) / 8) as usize
    }

    /// Hyperbank of an address (always 0 for Fc).
    #[inline]
    pub fn hyperbank_of(&self, addr: u32) -> usize {
        match self.topology {
            Topology::Fc { .. } => 0,
            Topology::Dobu { .. } => {
                (self.word_index(addr) >= self.half_words) as usize
            }
        }
    }

    /// Global bank id of an address.
    #[inline]
    pub fn bank_of(&self, addr: u32) -> usize {
        let w = self.word_index(addr);
        match self.topology {
            Topology::Fc { banks } => w % banks,
            Topology::Dobu { banks_per_hyper } => {
                if w >= self.half_words {
                    banks_per_hyper
                        + (w - self.half_words) % banks_per_hyper
                } else {
                    w % banks_per_hyper
                }
            }
        }
    }

    /// Superbank id of a bank.
    #[inline]
    pub fn superbank_of_bank(&self, bank: usize) -> usize {
        bank / BANKS_PER_SUPERBANK
    }

    #[inline]
    pub fn read_u64(&self, addr: u32) -> u64 {
        self.words[self.word_index(addr)]
    }

    #[inline]
    pub fn write_u64(&mut self, addr: u32, v: u64) {
        let i = self.word_index(addr);
        self.words[i] = v;
    }

    #[inline]
    pub fn read_f64(&self, addr: u32) -> f64 {
        f64::from_bits(self.read_u64(addr))
    }

    #[inline]
    pub fn write_f64(&mut self, addr: u32, v: f64) {
        self.write_u64(addr, v.to_bits());
    }
}

/// Flat main memory (the cluster's view of L2/HBM behind the DMA).
pub struct MainMemory {
    words: Vec<u64>,
    pub bytes: usize,
}

impl MainMemory {
    pub fn new(bytes: usize) -> Self {
        assert_eq!(bytes % 8, 0);
        Self {
            words: vec![0u64; bytes / 8],
            bytes,
        }
    }

    pub fn contains(&self, addr: u32) -> bool {
        addr >= MAIN_MEM_BASE && addr < MAIN_MEM_BASE + self.bytes as u32
    }

    #[inline]
    fn idx(&self, addr: u32) -> usize {
        debug_assert!(self.contains(addr), "main-mem OOB: {addr:#x}");
        debug_assert_eq!(addr % 8, 0);
        ((addr - MAIN_MEM_BASE) / 8) as usize
    }

    #[inline]
    pub fn read_u64(&self, addr: u32) -> u64 {
        self.words[self.idx(addr)]
    }

    #[inline]
    pub fn write_u64(&mut self, addr: u32, v: u64) {
        let i = self.idx(addr);
        self.words[i] = v;
    }

    #[inline]
    pub fn read_f64(&self, addr: u32) -> f64 {
        f64::from_bits(self.read_u64(addr))
    }

    #[inline]
    pub fn write_f64(&mut self, addr: u32, v: f64) {
        self.write_u64(addr, v.to_bits());
    }

    /// Bulk helpers for experiment setup/readback.
    pub fn write_slice_f64(&mut self, addr: u32, xs: &[f64]) {
        for (i, &x) in xs.iter().enumerate() {
            self.write_f64(addr + (i as u32) * 8, x);
        }
    }

    pub fn read_vec_f64(&self, addr: u32, n: usize) -> Vec<f64> {
        (0..n).map(|i| self.read_f64(addr + (i as u32) * 8)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_interleaving() {
        let t = Tcdm::new(Topology::Fc { banks: 32 }, 128 * 1024);
        assert_eq!(t.bank_of(TCDM_BASE), 0);
        assert_eq!(t.bank_of(TCDM_BASE + 8), 1);
        assert_eq!(t.bank_of(TCDM_BASE + 8 * 31), 31);
        assert_eq!(t.bank_of(TCDM_BASE + 8 * 32), 0);
        assert_eq!(t.hyperbank_of(TCDM_BASE + 64 * 1024), 0);
    }

    #[test]
    fn dobu_hyperbank_split() {
        // zonl48db: 96 KiB, 2x24 banks.
        let t = Tcdm::new(Topology::Dobu { banks_per_hyper: 24 }, 96 * 1024);
        let half = 48 * 1024;
        assert_eq!(t.hyperbank_of(TCDM_BASE), 0);
        assert_eq!(t.hyperbank_of(TCDM_BASE + half - 8), 0);
        assert_eq!(t.hyperbank_of(TCDM_BASE + half), 1);
        // interleave restarts inside each hyperbank
        assert_eq!(t.bank_of(TCDM_BASE), 0);
        assert_eq!(t.bank_of(TCDM_BASE + 8 * 24), 0);
        assert_eq!(t.bank_of(TCDM_BASE + half), 24);
        assert_eq!(t.bank_of(TCDM_BASE + half + 8 * 23), 47);
    }

    #[test]
    fn dobu_addresses_cover_all_banks() {
        let t = Tcdm::new(Topology::Dobu { banks_per_hyper: 32 }, 128 * 1024);
        let mut seen = vec![false; 64];
        for w in 0..(128 * 1024 / 8) {
            seen[t.bank_of(TCDM_BASE + w * 8)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn storage_roundtrip() {
        let mut t = Tcdm::new(Topology::Fc { banks: 32 }, 128 * 1024);
        t.write_f64(TCDM_BASE + 0x100, 3.25);
        assert_eq!(t.read_f64(TCDM_BASE + 0x100), 3.25);
        let mut m = MainMemory::new(1 << 20);
        m.write_slice_f64(MAIN_MEM_BASE, &[1.0, 2.0, 3.0]);
        assert_eq!(m.read_vec_f64(MAIN_MEM_BASE, 3), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "divide evenly")]
    fn uneven_banking_rejected() {
        let _ = Tcdm::new(Topology::Fc { banks: 48 }, 100 * 1024);
    }

    #[test]
    fn topology_accessors() {
        let fc = Topology::Fc { banks: 64 };
        assert_eq!(fc.total_banks(), 64);
        assert_eq!(fc.hyperbanks(), 1);
        let db = Topology::Dobu { banks_per_hyper: 24 };
        assert_eq!(db.total_banks(), 48);
        assert_eq!(db.hyperbanks(), 2);
        assert_eq!(db.banks_per_hyperbank(), 24);
    }
}
