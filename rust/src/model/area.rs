//! Analytic area model in gate equivalents — regenerates Table I.
//!
//! The paper reports post-P&R areas from Fusion Compiler in GF12LP+
//! (1 GE = 0.121 um^2). We have no P&R flow, so areas come from a
//! structural model: per-component GE counts with scaling laws for the
//! pieces that change across configurations —
//!
//! * crossbar cell area   ∝ ports x banks-per-hyperbank (crosspoints),
//! * Dobu demux stage     ∝ total banks,
//! * bank periphery       ∝ total banks,
//! * SRAM macro area      = n_macros x (fixed + per-KiB term),
//! * ZONL sequencer delta = per-core constant (bigger RB + N loop
//!   controllers + detectors).
//!
//! Constants are calibrated against the paper's Base32fc row and the
//! published deltas (DESIGN.md substitution table); EXPERIMENTS.md
//! compares modeled vs published for every row.

use crate::cluster::ConfigId;
use crate::mem::Topology;

/// Calibrated constants (MGE / mm).
mod cal {
    /// Compute + control + baseline sequencers + misc cell area that
    /// does not vary with banking (MGE).
    pub const CELL_FIXED: f64 = 2.980;
    /// ZONL sequencer upgrade per core (MGE) — 2x RB + nest controller
    /// + starting/ending-loop detectors.
    pub const SEQ_ZONL_PER_CORE: f64 = 0.0167;
    /// Crossbar cell area per crosspoint = per (port x bank) (MGE).
    pub const XBAR_PER_CROSSPOINT: f64 = 0.000638;
    /// Dobu demux stage per bank (MGE).
    pub const DEMUX_PER_BANK: f64 = 0.00147;
    /// Bank periphery (request queue, mux) per bank (MGE).
    pub const BANK_PERIPH: f64 = 0.003;
    /// SRAM macro: fixed overhead per macro (MGE).
    pub const MACRO_FIXED: f64 = 0.0094;
    /// SRAM macro: per-KiB bitcell area (MGE/KiB).
    pub const MACRO_PER_KIB: f64 = 0.00945;
    /// Wire length model (mm).
    pub const WIRE_FIXED: f64 = 19.2;
    pub const WIRE_SEQ_ZONL: f64 = 0.8;
    pub const WIRE_PER_CROSSPOINT: f64 = 0.0064;
    pub const WIRE_PER_DEMUX_BANK: f64 = 0.0197;
    pub const WIRE_PER_BANK: f64 = 0.02;
    /// Interconnect request ports (8 compute x 4 + DM LSU + DMA) of
    /// the paper's silicon. The simulator's 4th (epilogue-bias) SSR is
    /// an extension on top of that hardware and is deliberately *not*
    /// counted here, so Table I keeps reproducing the paper.
    pub const PORTS: f64 = 33.0;
    /// GF12LP+ gate equivalent in um^2 (the paper's conversion).
    pub const UM2_PER_GE: f64 = 0.121;
}

/// Area breakdown for one configuration (MGE / mm, Table I columns).
#[derive(Clone, Copy, Debug)]
pub struct AreaBreakdown {
    pub id: ConfigId,
    pub cell_mge: f64,
    pub macro_mge: f64,
    pub wire_mm: f64,
    // component split (Table II columns)
    pub compute_mge: f64,
    pub mem_mge: f64,
    pub interco_mge: f64,
    pub ctrl_mge: f64,
}

impl AreaBreakdown {
    pub fn total_mge(&self) -> f64 {
        self.cell_mge + self.macro_mge
    }

    pub fn total_mm2(&self) -> f64 {
        self.total_mge() * 1e6 * cal::UM2_PER_GE / 1e6 // um^2 -> mm^2
    }
}

/// Crossbar crosspoints for a topology (the per-hyperbank crossbar of
/// Fig. 3 — Dobu doubles hyperbanks, not crossbar width).
fn crosspoints(t: Topology) -> f64 {
    cal::PORTS * t.banks_per_hyperbank() as f64
}

fn demux_banks(t: Topology) -> f64 {
    match t {
        Topology::Fc { .. } => 0.0,
        Topology::Dobu { .. } => t.total_banks() as f64,
    }
}

/// Number of SRAM macros: one per bank (Snitch convention).
fn macro_area(t: Topology, tcdm_bytes: usize) -> f64 {
    let banks = t.total_banks() as f64;
    let kib_per_bank = tcdm_bytes as f64 / 1024.0 / banks;
    banks * (cal::MACRO_FIXED + cal::MACRO_PER_KIB * kib_per_bank)
}

pub fn area(id: ConfigId) -> AreaBreakdown {
    let cfg = id.cluster_config();
    let t = cfg.topology;
    let zonl = cfg.zonl as u8 as f64;
    let n_seq_cores = (cfg.n_compute + 1) as f64; // DM core has one too

    let xbar = cal::XBAR_PER_CROSSPOINT * crosspoints(t);
    let demux = cal::DEMUX_PER_BANK * demux_banks(t);
    let periph = cal::BANK_PERIPH * t.total_banks() as f64;
    let seq_delta = zonl * cal::SEQ_ZONL_PER_CORE * n_seq_cores;
    let cell = cal::CELL_FIXED + seq_delta + xbar + demux + periph;
    let macro_mge = macro_area(t, cfg.tcdm_bytes);

    let wire = cal::WIRE_FIXED
        + zonl * cal::WIRE_SEQ_ZONL
        + cal::WIRE_PER_CROSSPOINT * crosspoints(t)
        + cal::WIRE_PER_DEMUX_BANK * demux_banks(t)
        + cal::WIRE_PER_BANK * t.total_banks() as f64;

    // Table II component split: compute = cores+FPUs (constant), the
    // interconnect = xbar+demux+periph, ctrl = the rest of the cell
    // area (frontends, sequencers, DM, clocking).
    let compute = 1.48;
    let interco = xbar + demux + periph;
    let ctrl = cell - compute - interco;
    AreaBreakdown {
        id,
        cell_mge: cell,
        macro_mge,
        wire_mm: wire,
        compute_mge: compute,
        mem_mge: macro_mge,
        interco_mge: interco,
        ctrl_mge: ctrl,
    }
}

/// Render Table I: one row per configuration, increments vs Base32fc.
pub fn table1() -> Vec<AreaBreakdown> {
    ConfigId::all().map(area).to_vec()
}

/// NoC area constants (MGE), structured like the cluster crossbar
/// model: each cluster contributes a link switch, plus a shared
/// L2-side mux that grows with the cluster count.
mod noc_cal {
    /// Per-cluster 512-bit link switch + buffering (MGE).
    pub const LINK_PER_CLUSTER: f64 = 0.045;
    /// Shared L2-side arbitration/mux tree per cluster port (MGE).
    pub const L2_MUX_PER_CLUSTER: f64 = 0.018;
}

/// Fabric area: `clusters` cluster instances plus the shared NoC.
/// The NoC lands in the interconnect component (it is one), so Table
/// II-style component splits keep working at fabric scale.
pub fn fabric_area(id: ConfigId, clusters: usize) -> AreaBreakdown {
    let clusters = clusters.max(1);
    let one = area(id);
    let n = clusters as f64;
    let noc = n
        * (noc_cal::LINK_PER_CLUSTER + noc_cal::L2_MUX_PER_CLUSTER);
    AreaBreakdown {
        id,
        cell_mge: one.cell_mge * n + noc,
        macro_mge: one.macro_mge * n,
        wire_mm: one.wire_mm * n,
        compute_mge: one.compute_mge * n,
        mem_mge: one.mem_mge * n,
        interco_mge: one.interco_mge * n + noc,
        ctrl_mge: one.ctrl_mge * n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn by_id(id: ConfigId) -> AreaBreakdown {
        area(id)
    }

    #[test]
    fn base32fc_matches_paper_calibration() {
        let a = by_id(ConfigId::Base32Fc);
        assert!((a.cell_mge - 3.75).abs() < 0.02, "cell {}", a.cell_mge);
        assert!((a.macro_mge - 1.51).abs() < 0.03, "macro {}", a.macro_mge);
        assert!((a.wire_mm - 26.6).abs() < 0.3, "wire {}", a.wire_mm);
        assert!((a.total_mge() - 5.26).abs() < 0.05);
    }

    #[test]
    fn zonl_overhead_small() {
        // Paper: ZONL support adds <3% to total cluster area.
        let b = by_id(ConfigId::Base32Fc).total_mge();
        let z = by_id(ConfigId::Zonl32Fc).total_mge();
        let pct = (z - b) / b * 100.0;
        assert!(pct > 0.0 && pct < 3.0, "zonl overhead {pct:.2}%");
    }

    #[test]
    fn fc64_is_expensive_dobu_is_cheap() {
        // Paper: Zonl64fc +23% total, Zonl64db +12%, Zonl48db +1%.
        let b = by_id(ConfigId::Base32Fc).total_mge();
        let pct = |id: ConfigId| (by_id(id).total_mge() - b) / b * 100.0;
        let fc64 = pct(ConfigId::Zonl64Fc);
        let db64 = pct(ConfigId::Zonl64Db);
        let db48 = pct(ConfigId::Zonl48Db);
        assert!(fc64 > 18.0 && fc64 < 28.0, "fc64 {fc64:.1}%");
        assert!(db64 > 8.0 && db64 < 16.0, "db64 {db64:.1}%");
        assert!(db48 > -1.0 && db48 < 3.0, "db48 {db48:.1}%");
        assert!(fc64 > db64 && db64 > db48);
    }

    #[test]
    fn fabric_area_scales_with_noc_overhead() {
        let one = area(ConfigId::Zonl48Db);
        let fab = fabric_area(ConfigId::Zonl48Db, 4);
        assert_eq!(fabric_area(ConfigId::Zonl48Db, 1).id, one.id);
        // 4 clusters cost a bit more than 4x one cluster (the NoC)...
        assert!(fab.total_mge() > 4.0 * one.total_mge());
        // ...but the NoC tax stays small (< 2% of the fabric).
        let noc = fab.total_mge() - 4.0 * one.total_mge();
        assert!(
            noc / fab.total_mge() < 0.02,
            "NoC share {:.3}",
            noc / fab.total_mge()
        );
        let single_fab = fabric_area(ConfigId::Zonl48Db, 1).total_mge();
        assert!(
            (single_fab - (one.total_mge() + 0.063)).abs() < 1e-9,
            "1-cluster fabric = cluster + one NoC port: {single_fab}"
        );
    }

    #[test]
    fn wire_ordering_matches_figure4() {
        let w = |id: ConfigId| by_id(id).wire_mm;
        assert!(w(ConfigId::Zonl64Fc) > w(ConfigId::Zonl64Db));
        assert!(w(ConfigId::Zonl64Db) > w(ConfigId::Zonl48Db));
        // 48db wire ~= baseline (paper: -0.2%)
        let rel = (w(ConfigId::Zonl48Db) - w(ConfigId::Base32Fc))
            / w(ConfigId::Base32Fc);
        assert!(rel.abs() < 0.03, "48db wire delta {rel:.3}");
    }

    #[test]
    fn macro_area_tracks_capacity_and_count() {
        // 64 half-size banks cost more than 32 full-size (paper: 1.81
        // vs 1.51); 48 half-size at 96 KiB cost less (1.39).
        let m32 = by_id(ConfigId::Base32Fc).macro_mge;
        let m64 = by_id(ConfigId::Zonl64Fc).macro_mge;
        let m48 = by_id(ConfigId::Zonl48Db).macro_mge;
        assert!(m64 > m32);
        assert!(m48 < m32);
        assert!((m64 - 1.81).abs() < 0.05);
    }

    #[test]
    fn component_split_sums_to_cell() {
        for id in ConfigId::all() {
            let a = by_id(id);
            let sum = a.compute_mge + a.interco_mge + a.ctrl_mge;
            assert!((sum - a.cell_mge).abs() < 1e-9);
        }
    }
}
