//! Routing-congestion proxy — the Fig. 4 substitute.
//!
//! Fig. 4 shows placed-and-routed layouts with the "sum of overflow
//! routes" metric highlighting congestion hot-spots.  Without a P&R
//! flow we compute a structural proxy: routing *demand* is the modeled
//! wire length (area model), routing *supply* scales with cell area
//! (more standard-cell area = more routing tracks over it).  The
//! overflow score is the demand exceeding a utilization-derated
//! supply, which reproduces the figure's qualitative story: the
//! 64-bank fully-connected crossbar overflows badly, the Dobu variants
//! route like the baseline.

use crate::cluster::ConfigId;

use super::area;

/// Routing tracks deliverable per MGE of cell area (mm of wire),
/// derated to the ~80% utilization P&R tools sustain.
const SUPPLY_MM_PER_MGE: f64 = 7.3;
const SUPPLY_DERATE: f64 = 1.0;

#[derive(Clone, Copy, Debug)]
pub struct CongestionReport {
    pub id: ConfigId,
    /// Routing demand (modeled wire length, mm).
    pub demand_mm: f64,
    /// Derated routing supply (mm).
    pub supply_mm: f64,
    /// Sum-of-overflow-routes proxy (mm of unroutable demand).
    pub overflow_mm: f64,
    /// demand / supply.
    pub pressure: f64,
}

pub fn congestion(id: ConfigId) -> CongestionReport {
    let a = area::area(id);
    let supply = a.cell_mge * SUPPLY_MM_PER_MGE * SUPPLY_DERATE;
    let overflow = (a.wire_mm - supply).max(0.0);
    CongestionReport {
        id,
        demand_mm: a.wire_mm,
        supply_mm: supply,
        overflow_mm: overflow,
        pressure: a.wire_mm / supply,
    }
}

/// ASCII rendition of Fig. 4: a bar per config, '#' marks overflow.
pub fn render_fig4() -> String {
    let mut out = String::new();
    out.push_str(
        "Fig. 4 proxy — routing pressure (demand/supply), '#' = overflow\n",
    );
    for id in ConfigId::all() {
        let c = congestion(id);
        let bars = (c.pressure * 40.0).round() as usize;
        let cap = 40usize; // pressure 1.0
        let (ok, over) = if bars > cap {
            (cap, bars - cap)
        } else {
            (bars, 0)
        };
        out.push_str(&format!(
            "{:<10} |{}{}| {:.3}{}\n",
            id.name(),
            "=".repeat(ok),
            "#".repeat(over),
            c.pressure,
            if over > 0 { "  << CONGESTED" } else { "" },
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc64_overflows_dobu_does_not() {
        // The qualitative content of Fig. 4.
        let fc64 = congestion(ConfigId::Zonl64Fc);
        let db64 = congestion(ConfigId::Zonl64Db);
        let base = congestion(ConfigId::Base32Fc);
        assert!(fc64.overflow_mm > 0.0, "fc64 must overflow");
        assert_eq!(db64.overflow_mm, 0.0, "dobu64 routes cleanly");
        assert!(fc64.pressure > db64.pressure);
        assert!((db64.pressure - base.pressure).abs() < 0.12);
    }

    #[test]
    fn pressure_ordering() {
        let p = |id| congestion(id).pressure;
        assert!(p(ConfigId::Zonl64Fc) > p(ConfigId::Zonl64Db));
        assert!(p(ConfigId::Zonl64Db) >= p(ConfigId::Zonl48Db) - 0.05);
    }

    #[test]
    fn render_mentions_congestion() {
        let s = render_fig4();
        assert!(s.contains("CONGESTED"));
        assert!(s.contains("zonl64fc"));
    }
}
