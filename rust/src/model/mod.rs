//! Physical-design models: area (Table I), power/energy (Table II,
//! Fig. 5) and the routing-congestion proxy (Fig. 4).

pub mod area;
pub mod congestion;
pub mod power;

pub use area::{area, fabric_area, table1, AreaBreakdown};
pub use congestion::{congestion, render_fig4, CongestionReport};
pub use power::{energy, fabric_energy, EnergyReport, FabricEnergy, PowerBreakdown};
