//! Event-based power/energy model — the Fig. 5 power & energy-eff
//! boxes and the Table II power breakdown.
//!
//! The paper extracts switching activity from post-layout simulation
//! and feeds PrimeTime (TT, 25C, 0.8V, 1 GHz). We instead charge a
//! calibrated energy per architectural event counted by the simulator
//! (FPU op, TCDM access via a given interconnect, conflict retry, I$
//! vs ring-buffer fetch, DMA beat) on top of per-domain static/clock
//! power. Constants are calibrated to Table II's Base32fc column; the
//! *deltas* across configurations then follow from activity and
//! structure (interconnect energy scales with crossbar width, macro
//! access energy with macro capacity).

use crate::cluster::{ClusterPerf, ConfigId};
use crate::mem::Topology;

/// Calibrated per-event energies (pJ) and static powers (mW) at 1 GHz.
mod cal {
    /// DP FMA incl. FP register file access.
    pub const E_FPU_OP: f64 = 12.7;
    /// Compute-domain static + clock (8 FPUs + cores).
    pub const P_COMP_STATIC: f64 = 10.0;
    /// SRAM access: fixed part (pJ).
    pub const E_MACRO_FIXED: f64 = 2.9;
    /// SRAM access: per-KiB-of-macro-capacity part (pJ/KiB).
    pub const E_MACRO_PER_KIB: f64 = 0.58;
    /// Memory-domain static (mW).
    pub const P_MEM_STATIC: f64 = 5.0;
    /// Interconnect traversal per access, per bank-per-hyperbank
    /// (pJ / 32 banks at the baseline -> 4.05 pJ).
    pub const E_IC_PER_BPH: f64 = 0.1266;
    /// Dobu demux stage per access (pJ).
    pub const E_IC_DEMUX: f64 = 0.8;
    /// Arbitration energy of a denied (retried) request (pJ).
    pub const E_CONFLICT: f64 = 1.0;
    /// DMA beat (512-bit) energy, both endpoints (pJ).
    pub const E_DMA_BEAT: f64 = 20.0;
    /// Control-domain static + clock (frontends, DM core, cluster
    /// fabric) (mW).
    pub const P_CTRL_STATIC: f64 = 160.0;
    /// Instruction fetch from the L0 I$ (pJ).
    pub const E_ICACHE_FETCH: f64 = 2.5;
    /// Instruction re-issue from the FREP ring buffer (pJ) — the
    /// energy win of fetching loop bodies from the RB (§III-A).
    pub const E_RB_REPLAY: f64 = 0.5;
    /// A 512-bit beat traversing the shared fabric NoC into L2 (pJ):
    /// long wires + the L2 macro access, on top of the cluster-local
    /// `E_DMA_BEAT` already charged per beat.
    pub const E_NOC_BEAT: f64 = 35.0;
    /// Extra ZONL sequencer leakage+clock per core (mW).
    pub const P_SEQ_ZONL: f64 = 0.33;
    /// Integer instruction execute (pJ).
    pub const E_INT_OP: f64 = 1.2;
    /// Frontend issue activity per cycle per core when running (mW
    /// equivalent is folded into P_CTRL_STATIC).
    pub const CORES_WITH_SEQ: f64 = 9.0;
}

/// Power split in mW (Table II columns).
#[derive(Clone, Copy, Debug, Default)]
pub struct PowerBreakdown {
    pub compute_mw: f64,
    pub mem_mw: f64,
    pub interco_mw: f64,
    pub ctrl_mw: f64,
}

impl PowerBreakdown {
    pub fn total_mw(&self) -> f64 {
        self.compute_mw + self.mem_mw + self.interco_mw + self.ctrl_mw
    }
}

/// Full energy report for one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct EnergyReport {
    pub power: PowerBreakdown,
    /// Total energy over the compute window (uJ).
    pub energy_uj: f64,
    /// DP Gflop/s at 1 GHz (paper peak convention: util x 8).
    pub gflops: f64,
    /// DP Gflop/s/W.
    pub gflops_per_w: f64,
    /// DP Gflop/s/mm^2.
    pub gflops_per_mm2: f64,
}

/// Per-access interconnect energy for a topology (pJ).
fn e_interconnect(t: Topology) -> f64 {
    let bph = t.banks_per_hyperbank() as f64;
    let demux = match t {
        Topology::Fc { .. } => 0.0,
        Topology::Dobu { .. } => cal::E_IC_DEMUX,
    };
    cal::E_IC_PER_BPH * bph + demux
}

/// Per-access SRAM energy given macro capacity (pJ).
fn e_macro(t: Topology, tcdm_bytes: usize) -> f64 {
    let kib_per_bank =
        tcdm_bytes as f64 / 1024.0 / t.total_banks() as f64;
    cal::E_MACRO_FIXED + cal::E_MACRO_PER_KIB * kib_per_bank
}

/// Evaluate the model over a run's perf counters.
pub fn energy(id: ConfigId, perf: &ClusterPerf) -> EnergyReport {
    let cfg = id.cluster_config();
    let t = cfg.topology;
    let cycles = perf.window_cycles.max(1) as f64;
    let secs = cycles * 1e-9; // 1 GHz
    let to_mw = |pj: f64| pj * 1e-12 / secs * 1e3;

    // --- compute domain ---
    let compute_mw = cal::P_COMP_STATIC
        + to_mw(cal::E_FPU_OP * perf.fpu_ops_total as f64);

    // --- memory domain (SRAM macros) ---
    let accesses = perf.tcdm_core_accesses as f64
        + perf.dma_beats as f64 * 8.0;
    let mem_mw =
        cal::P_MEM_STATIC + to_mw(e_macro(t, cfg.tcdm_bytes) * accesses);

    // --- interconnect domain ---
    // A retried request burns arbitration energy whether it lost its
    // bank's round-robin or the DMA superbank mux, so both halves of
    // the conflict split are charged.
    let retries = perf.conflicts_total() as f64;
    let interco_mw = to_mw(
        e_interconnect(t) * perf.tcdm_core_accesses as f64
            + cal::E_CONFLICT * retries
            + cal::E_DMA_BEAT * perf.dma_beats as f64,
    );

    // --- control domain ---
    let zonl = cfg.zonl as u8 as f64;
    let ctrl_mw = cal::P_CTRL_STATIC
        + zonl * cal::P_SEQ_ZONL * cal::CORES_WITH_SEQ
        + to_mw(
            cal::E_ICACHE_FETCH * perf.icache_fetches as f64
                + cal::E_RB_REPLAY * perf.rb_replays as f64
                + cal::E_INT_OP * perf.int_instrs as f64,
        );

    let power = PowerBreakdown { compute_mw, mem_mw, interco_mw, ctrl_mw };
    let total_w = power.total_mw() / 1e3;
    let gflops = perf.utilization * 8.0;
    let area = super::area::area(id);
    EnergyReport {
        power,
        energy_uj: total_w * secs * 1e6,
        gflops,
        gflops_per_w: gflops / total_w,
        gflops_per_mm2: gflops / area.total_mm2(),
    }
}

/// Fabric-level energy rollup: per-cluster event energy plus the NoC
/// links' transfer energy, over the fabric's end-to-end time.
#[derive(Clone, Debug)]
pub struct FabricEnergy {
    /// One report per busy cluster, in shard order.
    pub per_cluster: Vec<EnergyReport>,
    /// NoC link energy for all beats that crossed it (uJ).
    pub noc_uj: f64,
    /// Cluster energies + NoC energy (uJ).
    pub total_uj: f64,
    /// Average fabric power over `fabric_cycles` (mW).
    pub power_mw: f64,
    /// Fabric throughput: mean per-cluster utilization x 8 DPGflop/s
    /// x busy clusters (the paper's peak convention, scaled out).
    pub gflops: f64,
    pub gflops_per_w: f64,
}

/// Evaluate the model over a fabric run's per-cluster counters.
pub fn fabric_energy(
    id: ConfigId,
    perfs: &[ClusterPerf],
    fabric_cycles: u64,
) -> FabricEnergy {
    let per_cluster: Vec<EnergyReport> =
        perfs.iter().map(|p| energy(id, p)).collect();
    let noc_beats: u64 = perfs.iter().map(|p| p.dma_beats).sum();
    let noc_uj = cal::E_NOC_BEAT * noc_beats as f64 * 1e-6;
    let total_uj =
        per_cluster.iter().map(|e| e.energy_uj).sum::<f64>() + noc_uj;
    let secs = fabric_cycles.max(1) as f64 * 1e-9;
    let power_mw = total_uj * 1e-6 / secs * 1e3;
    let n = perfs.len().max(1) as f64;
    let mean_util =
        perfs.iter().map(|p| p.utilization).sum::<f64>() / n;
    let gflops = mean_util * 8.0 * perfs.len() as f64;
    let total_w = (power_mw / 1e3).max(1e-12);
    FabricEnergy {
        per_cluster,
        noc_uj,
        total_uj,
        power_mw,
        gflops,
        gflops_per_w: gflops / total_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{run_matmul, test_matrices};

    fn run(id: ConfigId) -> EnergyReport {
        let (a, b) = test_matrices(32, 32, 32, 3);
        let r = run_matmul(id, 32, 32, 32, &a, &b).unwrap();
        energy(id, &r.perf)
    }

    #[test]
    fn base32fc_total_power_near_table2() {
        // Paper: 340.4 mW on the 32^3 kernel.
        let e = run(ConfigId::Base32Fc);
        let total = e.power.total_mw();
        assert!(
            (total - 340.4).abs() / 340.4 < 0.10,
            "total {total:.1} mW vs 340.4"
        );
        // Component sanity: ctrl dominates, compute ~100-120 mW.
        assert!(e.power.ctrl_mw > 150.0);
        assert!(e.power.compute_mw > 90.0 && e.power.compute_mw < 130.0);
    }

    #[test]
    fn zonl48db_energy_efficiency_beats_baseline() {
        // Paper: +8% median energy efficiency; on 32^3 Table II gives
        // 23.2 vs 22.4 DPGflop/s/W (+3.6%).
        let b = run(ConfigId::Base32Fc);
        let z = run(ConfigId::Zonl48Db);
        assert!(
            z.gflops_per_w > b.gflops_per_w,
            "48db {:.1} vs base {:.1}",
            z.gflops_per_w,
            b.gflops_per_w
        );
        // In the right absolute range (paper: 22.4 / 23.2).
        assert!(b.gflops_per_w > 19.0 && b.gflops_per_w < 26.0);
        assert!(z.gflops_per_w > 20.0 && z.gflops_per_w < 27.0);
    }

    #[test]
    fn fc64_burns_more_interconnect_power() {
        // Paper: Zonl64fc costs +12% median energy vs Zonl32fc.
        let z32 = run(ConfigId::Zonl32Fc);
        let z64 = run(ConfigId::Zonl64Fc);
        assert!(
            z64.power.interco_mw > 1.5 * z32.power.interco_mw,
            "fc64 interco {:.1} vs fc32 {:.1}",
            z64.power.interco_mw,
            z32.power.interco_mw
        );
        // And the Dobu version avoids most of that cost.
        let db64 = run(ConfigId::Zonl64Db);
        assert!(db64.power.interco_mw < 1.2 * z32.power.interco_mw);
    }

    #[test]
    fn fabric_energy_rolls_up_clusters_plus_noc() {
        let (a, b) = test_matrices(32, 32, 32, 3);
        let r =
            run_matmul(ConfigId::Zonl48Db, 32, 32, 32, &a, &b).unwrap();
        let single = energy(ConfigId::Zonl48Db, &r.perf);
        let perfs = vec![r.perf.clone(); 4];
        let fe = fabric_energy(
            ConfigId::Zonl48Db,
            &perfs,
            r.perf.window_cycles,
        );
        assert_eq!(fe.per_cluster.len(), 4);
        assert!(fe.noc_uj > 0.0, "NoC beats must cost energy");
        let want = 4.0 * single.energy_uj + fe.noc_uj;
        assert!(
            (fe.total_uj - want).abs() < 1e-9,
            "{} vs {want}",
            fe.total_uj
        );
        assert!((fe.gflops - 4.0 * single.gflops).abs() < 1e-9);
        assert!(
            fe.gflops_per_w < single.gflops_per_w,
            "the NoC tax makes the fabric slightly less efficient"
        );
    }

    #[test]
    fn energy_positive_and_consistent() {
        for id in ConfigId::all() {
            let e = run(id);
            assert!(e.energy_uj > 0.0);
            assert!(e.gflops > 5.0 && e.gflops <= 8.0);
            assert!(e.gflops_per_mm2 > 5.0);
        }
    }
}
