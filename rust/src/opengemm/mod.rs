//! OpenGeMM comparator model [6] — the specialized-accelerator row of
//! Table II.
//!
//! OpenGeMM couples a Snitch control core with a GEMM accelerator and
//! tightly-coupled wide memory banks. For an arithmetic-precision-
//! agnostic comparison the paper swaps its 8x8x8 INT8 core for a
//! 2x2x2-FP64 SIMD equivalent, giving the same 8 DPGflop/s peak as the
//! cluster, and scales the published power by 4.92x for technology
//! (0.7x), voltage and frequency (prop. V^2 f); areas convert at
//! 1 GE_TSMC16 = 0.138 um^2.
//!
//! The cycle model reproduces OpenGeMM's utilization behaviour: an
//! output-stationary 2x2x2 datapath (8 MACs/cycle) with a per-launch
//! control/config overhead and a systolic fill/drain term.  Calibrated
//! to the published ~95% on 32^3 and 99.34% peak on large workloads.

/// Result of the comparator cycle model for one GEMM.
#[derive(Clone, Copy, Debug)]
pub struct OpenGemmRun {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub cycles: u64,
    pub utilization: f64,
    pub gflops: f64,
}

/// Per-launch control overhead (CSR config through the Snitch control
/// core + accelerator start), cycles.
const LAUNCH_OVERHEAD: u64 = 100;

/// Cycle model: ideal MNK/8 plus launch + fill/drain + preload ramp.
pub fn run(m: usize, n: usize, k: usize) -> OpenGemmRun {
    let ideal = (m * n * k) as u64 / 8;
    let fill_drain = 2 * k as u64; // systolic array fill + drain
    let preload = (m * n) as u64 / 16; // output tile init/writeback ramp
    let cycles = ideal + LAUNCH_OVERHEAD + fill_drain + preload;
    let utilization = ideal as f64 / cycles as f64;
    OpenGemmRun {
        m,
        n,
        k,
        cycles,
        utilization,
        gflops: utilization * 8.0,
    }
}

/// Area breakdown (MGE), Table II conventions.
#[derive(Clone, Copy, Debug)]
pub struct OpenGemmArea {
    pub compute_mge: f64,
    pub mem_interco_mge: f64,
    pub ctrl_mge: f64,
}

impl OpenGemmArea {
    pub fn total_mge(&self) -> f64 {
        self.compute_mge + self.mem_interco_mge + self.ctrl_mge
    }

    pub fn total_mm2(&self) -> f64 {
        self.total_mge() * 0.121 // reported in GF12 GE like the others
    }
}

/// Published/derived Table II area row.
///
/// The paper's Table II totals are self-consistent with
/// `total = comp + L1 + ctrl` (the separately-listed interconnect
/// share is folded into the memory column); for OpenGeMM the published
/// total is 3.85 MGE with mem+interco 2.44 and ctrl 0.86, leaving
/// 0.55 MGE for the dense 2x2x2 FP64 datapath (8 tightly-arrayed FMA
/// lanes — far smaller than 8 independent Snitch FPU complexes).
pub fn area() -> OpenGemmArea {
    OpenGemmArea {
        compute_mge: 3.85 - 2.44 - 0.86,
        mem_interco_mge: 2.44,
        ctrl_mge: 0.86,
    }
}

/// Power breakdown (mW) at a given utilization; compute power scales
/// with activity around the published 106.3 mW @ 95%.
#[derive(Clone, Copy, Debug)]
pub struct OpenGemmPower {
    pub compute_mw: f64,
    pub mem_interco_mw: f64,
    pub ctrl_mw: f64,
}

impl OpenGemmPower {
    pub fn total_mw(&self) -> f64 {
        self.compute_mw + self.mem_interco_mw + self.ctrl_mw
    }
}

pub fn power(utilization: f64) -> OpenGemmPower {
    OpenGemmPower {
        compute_mw: 106.3 * (utilization / 0.95),
        mem_interco_mw: 90.2,
        ctrl_mw: 93.0,
    }
}

/// The complete Table II row on a 32^3 kernel.
pub fn table2_row() -> (OpenGemmRun, OpenGemmArea, OpenGemmPower) {
    let r = run(32, 32, 32);
    (r, area(), power(r.utilization))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn util_95_on_cube32() {
        let r = run(32, 32, 32);
        assert!(
            (r.utilization - 0.95).abs() < 0.01,
            "32^3 util {:.3}",
            r.utilization
        );
    }

    #[test]
    fn util_saturates_to_published_peak() {
        let r = run(128, 128, 128);
        assert!(
            r.utilization > 0.99 && r.utilization < 0.9951,
            "128^3 util {:.4}",
            r.utilization
        );
    }

    #[test]
    fn small_sizes_lose_like_an_accelerator() {
        let r = run(8, 8, 8);
        assert!(r.utilization < 0.40, "8^3 util {:.3}", r.utilization);
    }

    #[test]
    fn table2_row_matches_paper() {
        let (r, a, p) = table2_row();
        assert!((a.total_mge() - 3.85).abs() < 0.01);
        assert!((p.total_mw() - 289.5).abs() / 289.5 < 0.02,
                "power {:.1}", p.total_mw());
        let eff = r.gflops / (p.total_mw() / 1e3);
        assert!((eff - 26.3).abs() < 1.0, "energy eff {eff:.1}");
        // area efficiency ~16.3 DPGflop/s/mm^2
        let aeff = r.gflops / a.total_mm2();
        assert!((aeff - 16.3).abs() < 1.0, "area eff {aeff:.1}");
    }
}
