//! StallScope — per-cycle stall attribution for the cluster model.
//!
//! The paper's headline (96.1–99.4% utilization via zero-overhead loop
//! nests and a zero-conflict memory subsystem) is a claim about *where
//! the residual stall cycles go*. This module makes the simulator a
//! diagnosable instrument:
//!
//! * a nine-class **taxonomy** ([`StallClass`]) covering every cycle of
//!   every core: each active cycle is attributed to exactly one class
//!   by the cluster's classifier (`Cluster::attribute_cycle`), so the
//!   **conservation invariant** `useful + Σ stalls == cycles` holds
//!   bit-exactly per core by construction — and is still *checked*
//!   ([`CoreStalls::check`]) because the cycle counter and the class
//!   buckets are incremented at different sites;
//! * a **mergeable aggregate** ([`StallProfile`]): per-core counters
//!   roll up core → cluster ([`StallProfile::totals`]) → fabric
//!   ([`StallProfile::merge_parallel`]) → multi-layer run
//!   ([`StallProfile::merge_serial`]), and
//!   [`StallProfile::utilization`] decomposes the existing
//!   `ClusterPerf` utilization exactly (`Useful` counts the same
//!   events as `fpu_ops`, over the same compute window);
//! * a **Chrome `trace_event` exporter** ([`trace`]) with per-core
//!   stall tracks, a DMA track, and barrier markers — load the JSON in
//!   `chrome://tracing` / Perfetto;
//! * a **roofline** module ([`roofline`]) placing measured layers
//!   against the compute, L1-DMA, and NoC bandwidth ceilings.
//!
//! The cycle backend fills profiles from measurement; the analytic
//! backend fills the same structure from its calibrated terms
//! (`backend::analytic::predict_perf_noc`), which is what the
//! cycle-vs-analytic breakdown differential tests compare.

pub mod roofline;
pub mod telemetry;
pub mod trace;

pub use roofline::{Bound, Ceilings, RooflinePoint};
pub use telemetry::{SpanKind, Telemetry};
pub use trace::{ChromeTrace, TraceBuf};

/// Number of attribution classes (the full taxonomy).
pub const N_CLASSES: usize = 9;

/// Where one core-cycle went. Every active cycle of every core lands
/// in exactly one class.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum StallClass {
    /// The FPU issued one op this cycle (a MAC or an epilogue op).
    Useful = 0,
    /// Frontend busy with non-FP work while the sequencer was empty:
    /// loop management, address arithmetic, CSR toggles, SSR re-arm,
    /// scalar LSU traffic — the paper's §III-A control overhead.
    ControlOverhead = 1,
    /// An SSR operand (or write-FIFO slot) was not ready and no TCDM
    /// denial explains it: stream start-up / pipeline latency.
    SsrOperandWait = 2,
    /// Register-file RAW hazard or a full FPU pipeline.
    RawHazard = 3,
    /// A TCDM request of this core (SSR stream or LSU) lost
    /// arbitration this cycle — bank round-robin or the DMA superbank
    /// mux. The paper's "zero-conflict" claim is about this bucket.
    BankConflict = 4,
    /// Parked at a barrier (or the DM core polling `dmstat`) while the
    /// cluster DMA engine still moves data: double-buffer fill/drain
    /// on the critical path.
    DmaWait = 5,
    /// Parked at a barrier waiting for *peer cores* (DMA idle).
    Barrier = 6,
    /// Waiting on DMA whose branch the fabric NoC gated off the shared
    /// links this cycle (multi-cluster contention).
    NocGated = 7,
    /// Frontend parked on an in-order drain point (fsd ordering, SSR
    /// disable): the FP subsystem empties before control continues.
    Drain = 8,
}

impl StallClass {
    pub fn all() -> [StallClass; N_CLASSES] {
        [
            StallClass::Useful,
            StallClass::ControlOverhead,
            StallClass::SsrOperandWait,
            StallClass::RawHazard,
            StallClass::BankConflict,
            StallClass::DmaWait,
            StallClass::Barrier,
            StallClass::NocGated,
            StallClass::Drain,
        ]
    }

    /// Stable machine-readable name (CSV column headers).
    pub fn name(&self) -> &'static str {
        match self {
            StallClass::Useful => "useful",
            StallClass::ControlOverhead => "control_overhead",
            StallClass::SsrOperandWait => "ssr_operand_wait",
            StallClass::RawHazard => "raw_hazard",
            StallClass::BankConflict => "bank_conflict",
            StallClass::DmaWait => "dma_wait",
            StallClass::Barrier => "barrier",
            StallClass::NocGated => "noc_gated",
            StallClass::Drain => "drain",
        }
    }

    /// Human label (trace spans, report tables).
    pub fn label(&self) -> &'static str {
        match self {
            StallClass::Useful => "Useful",
            StallClass::ControlOverhead => "ControlOverhead",
            StallClass::SsrOperandWait => "SsrOperandWait",
            StallClass::RawHazard => "RawHazard",
            StallClass::BankConflict => "BankConflict",
            StallClass::DmaWait => "DmaWait",
            StallClass::Barrier => "Barrier",
            StallClass::NocGated => "NocGated",
            StallClass::Drain => "Drain",
        }
    }
}

/// Frontend state snapshot at FP-tick time — the raw material the
/// classifier turns into a [`StallClass`] when the sequencer had
/// nothing to issue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrontPhase {
    /// Executing integer/control instructions (or fetch bubbles).
    Running,
    /// Waiting for a TCDM LSU grant.
    Lsu,
    /// Parked on an in-order drain point.
    Drain,
    /// Parked at a barrier.
    Barrier,
}

/// What one core's FP subsystem did in one cycle. Recorded by
/// `Core::fp_tick`, consumed (exactly once) by the cluster classifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FpEvent {
    /// One op issued to the FPU.
    Issued,
    /// Blocked on an empty SSR read FIFO.
    SsrEmpty,
    /// Blocked reserving a write-FIFO slot.
    WFifoFull,
    /// Blocked on a register-file RAW hazard.
    RawHazard,
    /// The FPU pipeline could not accept an issue.
    FpuFull,
    /// The sequencer had nothing to issue; carries the frontend state.
    NoInstr(FrontPhase),
}

/// One core's attribution counters. Invariant:
/// `counts.iter().sum() == cycles` (checked, not assumed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CoreStalls {
    /// Active cycles (cycles the core was stepped before halting).
    pub cycles: u64,
    /// Per-class cycle counts, indexed by `StallClass as usize`.
    pub counts: [u64; N_CLASSES],
}

impl CoreStalls {
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn useful(&self) -> u64 {
        self.counts[StallClass::Useful as usize]
    }

    /// The conservation invariant for this core.
    pub fn check(&self) -> Result<(), String> {
        let t = self.total();
        if t == self.cycles {
            Ok(())
        } else {
            Err(format!(
                "stall conservation violated: classes sum to {t}, core \
                 was active {} cycles ({:?})",
                self.cycles, self.counts
            ))
        }
    }

    fn add(&mut self, o: &CoreStalls) {
        self.cycles += o.cycles;
        for (a, b) in self.counts.iter_mut().zip(&o.counts) {
            *a += b;
        }
    }
}

/// The mergeable stall-attribution aggregate: per-core counters for
/// one cluster run (compute cores first, the DM core last), plus the
/// compute window the utilization decomposition is measured over.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StallProfile {
    /// `per_core[..n_compute]` are compute cores; any trailing entries
    /// are DM cores (one per merged cluster).
    pub per_core: Vec<CoreStalls>,
    pub n_compute: usize,
    /// Compute-window length (same window `ClusterPerf` measures
    /// utilization over). `merge_serial` sums windows;
    /// `merge_parallel` keeps the longest.
    pub window_cycles: u64,
    /// Utilization denominator: compute-core-cycles of window
    /// (`window_cycles x n_compute` for a single run, summed across
    /// merges). Tracked separately so merging profiles of *different*
    /// core counts (sharded + unsharded layers) keeps the weighted
    /// mean exact instead of charging every window to every core.
    pub window_core_cycles: u64,
}

impl StallProfile {
    pub fn is_empty(&self) -> bool {
        self.per_core.is_empty()
    }

    fn compute_cores(&self) -> &[CoreStalls] {
        &self.per_core[..self.n_compute.min(self.per_core.len())]
    }

    /// DM-core entries (everything past the compute cores).
    pub fn dm_cores(&self) -> &[CoreStalls] {
        let n = self.n_compute.min(self.per_core.len());
        &self.per_core[n..]
    }

    /// Class totals over the *compute* cores — the decomposition of
    /// the utilization metric. (DM cores are profiled too, but they
    /// have no FPU and would dilute the shares.)
    pub fn totals(&self) -> [u64; N_CLASSES] {
        let mut t = [0u64; N_CLASSES];
        for c in self.compute_cores() {
            for (a, b) in t.iter_mut().zip(&c.counts) {
                *a += b;
            }
        }
        t
    }

    pub fn useful_total(&self) -> u64 {
        self.totals()[StallClass::Useful as usize]
    }

    /// Total attributed compute-core cycles.
    pub fn cycles_total(&self) -> u64 {
        self.compute_cores().iter().map(|c| c.cycles).sum()
    }

    /// FPU utilization as the decomposition reports it: useful cycles
    /// over the compute-core-cycles of window. On the cycle backend a
    /// single-run profile equals `ClusterPerf::utilization` bit for
    /// bit — `Useful` increments on precisely the events `fpu_ops`
    /// counts, and `window_core_cycles == window_cycles * n_compute`
    /// (exact in f64: both factors and the product are integers well
    /// below 2^53). Merged profiles report the window-weighted mean.
    pub fn utilization(&self) -> f64 {
        crate::util::stats::ratio(
            self.useful_total() as f64,
            self.window_core_cycles as f64,
        )
    }

    /// Per-class share of all attributed compute-core cycles.
    pub fn shares(&self) -> [f64; N_CLASSES] {
        let totals = self.totals();
        let all = self.cycles_total() as f64;
        let mut s = [0.0f64; N_CLASSES];
        for (out, &t) in s.iter_mut().zip(&totals) {
            *out = crate::util::stats::ratio(t as f64, all);
        }
        s
    }

    /// The conservation invariant over every profiled core.
    pub fn check_conservation(&self) -> Result<(), String> {
        for (i, c) in self.per_core.iter().enumerate() {
            c.check().map_err(|e| format!("core {i}: {e}"))?;
        }
        Ok(())
    }

    /// Merge profiles of runs that happened *in sequence on the same
    /// cores* (e.g. the layers of a network): counters add index-wise,
    /// windows add. Profiles of different shapes (or empty ones, as
    /// the analytic elementwise-pass stub produces) concatenate /
    /// pass through instead.
    pub fn merge_serial(&mut self, other: &StallProfile) {
        if other.is_empty() {
            return;
        }
        if self.is_empty() {
            *self = other.clone();
            return;
        }
        if self.per_core.len() == other.per_core.len()
            && self.n_compute == other.n_compute
        {
            for (a, b) in self.per_core.iter_mut().zip(&other.per_core) {
                a.add(b);
            }
        } else {
            // Shape change mid-sequence (e.g. a layer sharded across a
            // different cluster count): fall back to concatenation so
            // no cycle is ever dropped.
            let dm: Vec<CoreStalls> = self.dm_cores().to_vec();
            let n = self.n_compute.min(self.per_core.len());
            self.per_core.truncate(n);
            self.per_core.extend(other.compute_cores());
            self.per_core.extend(dm);
            self.per_core.extend(other.dm_cores());
            self.n_compute += other.n_compute;
        }
        self.window_cycles += other.window_cycles;
        self.window_core_cycles += other.window_core_cycles;
    }

    /// Merge profiles of clusters that ran *in parallel* (a fabric):
    /// compute cores concatenate, DM cores follow, the window is the
    /// longest cluster's (lockstep semantics).
    pub fn merge_parallel(profiles: &[StallProfile]) -> StallProfile {
        let mut out = StallProfile::default();
        let mut dms: Vec<CoreStalls> = Vec::new();
        for p in profiles {
            out.per_core.extend(p.compute_cores());
            dms.extend(p.dm_cores());
            out.n_compute += p.n_compute.min(p.per_core.len());
            out.window_cycles = out.window_cycles.max(p.window_cycles);
            out.window_core_cycles += p.window_core_cycles;
        }
        out.per_core.extend(dms);
        out
    }
}

/// Distribute fractional per-class cycle predictions onto integer
/// buckets that sum to `total` exactly (largest-remainder rounding) —
/// how the analytic backend keeps its *predicted* profile on the same
/// conservation invariant as the measured one.
pub fn quantize(buckets: &[f64; N_CLASSES], total: u64) -> [u64; N_CLASSES] {
    let mut out = [0u64; N_CLASSES];
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(N_CLASSES);
    let mut floor_sum = 0u64;
    for (i, &b) in buckets.iter().enumerate() {
        let b = if b.is_finite() && b > 0.0 { b } else { 0.0 };
        let f = b.floor();
        out[i] = f as u64;
        floor_sum += out[i];
        fracs.push((i, b - f));
    }
    if floor_sum > total {
        // Numeric overshoot: trim from the largest buckets.
        let mut excess = floor_sum - total;
        let mut order: Vec<usize> = (0..N_CLASSES).collect();
        order.sort_by(|&a, &b| out[b].cmp(&out[a]).then(a.cmp(&b)));
        for i in order {
            let take = excess.min(out[i]);
            out[i] -= take;
            excess -= take;
            if excess == 0 {
                break;
            }
        }
        return out;
    }
    // Hand the remainder to the largest fractional parts
    // (deterministic tie-break on index).
    let mut rem = total - floor_sum;
    fracs.sort_by(|a, b| {
        b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
    });
    let mut i = 0;
    while rem > 0 {
        out[fracs[i % N_CLASSES].0] += 1;
        rem -= 1;
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn core(cycles: u64, useful: u64) -> CoreStalls {
        let mut c = CoreStalls { cycles, counts: [0; N_CLASSES] };
        c.counts[StallClass::Useful as usize] = useful;
        c.counts[StallClass::Barrier as usize] = cycles - useful;
        c
    }

    #[test]
    fn class_names_are_unique_and_ordered() {
        let all = StallClass::all();
        assert_eq!(all.len(), N_CLASSES);
        for (i, c) in all.iter().enumerate() {
            assert_eq!(*c as usize, i);
        }
        let names: std::collections::HashSet<_> =
            all.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), N_CLASSES);
    }

    #[test]
    fn conservation_check_catches_leaks() {
        let ok = core(10, 7);
        assert!(ok.check().is_ok());
        let mut bad = ok;
        bad.counts[StallClass::Drain as usize] += 1;
        assert!(bad.check().is_err());
    }

    #[test]
    fn utilization_decomposes_and_guards_zero_window() {
        let p = StallProfile {
            per_core: vec![core(100, 90), core(100, 80)],
            n_compute: 2,
            window_cycles: 100,
            window_core_cycles: 200,
        };
        assert!((p.utilization() - 0.85).abs() < 1e-12);
        let shares = p.shares();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let z = StallProfile::default();
        assert_eq!(z.utilization(), 0.0, "zero window must not NaN");
    }

    #[test]
    fn serial_merge_adds_parallel_merge_concats() {
        let a = StallProfile {
            per_core: vec![core(10, 8), core(4, 0)],
            n_compute: 1,
            window_cycles: 10,
            window_core_cycles: 10,
        };
        let mut s = a.clone();
        s.merge_serial(&a);
        assert_eq!(s.per_core[0].cycles, 20);
        assert_eq!(s.window_cycles, 20);
        assert_eq!(s.window_core_cycles, 20);
        assert_eq!(s.n_compute, 1);
        assert!((s.utilization() - 0.8).abs() < 1e-12);
        s.check_conservation().unwrap();

        let p = StallProfile::merge_parallel(&[a.clone(), a.clone()]);
        assert_eq!(p.n_compute, 2);
        assert_eq!(p.per_core.len(), 4, "2 compute + 2 DM");
        assert_eq!(p.window_cycles, 10);
        assert_eq!(p.window_core_cycles, 20);
        assert_eq!(p.dm_cores().len(), 2);
        p.check_conservation().unwrap();
    }

    #[test]
    fn heterogeneous_serial_merge_keeps_weighted_utilization() {
        // A 1-compute-core layer at 100% followed by a 2-core layer
        // at 100% must merge to 100%, not get each window charged to
        // every core (the old `window * total_cores` denominator).
        let one = StallProfile {
            per_core: vec![core(10, 10)],
            n_compute: 1,
            window_cycles: 10,
            window_core_cycles: 10,
        };
        let two = StallProfile {
            per_core: vec![core(5, 5), core(5, 5)],
            n_compute: 2,
            window_cycles: 5,
            window_core_cycles: 10,
        };
        let mut m = one.clone();
        m.merge_serial(&two);
        assert_eq!(m.n_compute, 3);
        assert_eq!(m.window_core_cycles, 20);
        assert!((m.utilization() - 1.0).abs() < 1e-12, "{}", m.utilization());
        m.check_conservation().unwrap();
    }

    #[test]
    fn serial_merge_with_empty_is_identity() {
        let a = StallProfile {
            per_core: vec![core(10, 8)],
            n_compute: 1,
            window_cycles: 10,
            window_core_cycles: 10,
        };
        let mut s = StallProfile::default();
        s.merge_serial(&a);
        assert_eq!(s, a);
        let mut s2 = a.clone();
        s2.merge_serial(&StallProfile::default());
        assert_eq!(s2, a);
    }

    #[test]
    fn quantize_conserves_exactly() {
        let mut b = [0.0f64; N_CLASSES];
        b[0] = 10.4;
        b[1] = 3.3;
        b[5] = 7.3;
        let q = quantize(&b, 21);
        assert_eq!(q.iter().sum::<u64>(), 21);
        // Largest remainder (.4 on bucket 0) takes the spare cycle.
        assert_eq!(q[0], 11);
        assert_eq!(q[1], 3);
        assert_eq!(q[5], 7);
        // Overshoot path trims instead of panicking.
        let q2 = quantize(&b, 15);
        assert_eq!(q2.iter().sum::<u64>(), 15);
        // NaN / negative inputs are treated as zero.
        b[2] = f64::NAN;
        b[3] = -4.0;
        let q3 = quantize(&b, 21);
        assert_eq!(q3.iter().sum::<u64>(), 21);
        assert_eq!(q3[3], 0);
    }
}
