//! Roofline placement for measured (or predicted) GEMM layers.
//!
//! Operational intensity is FPU ops per DMA byte (the repo's flop
//! convention: one op per issued FMA, matching `GemmResult::gflops`),
//! attained performance is ops per compute-window cycle, and three
//! ceilings bound it:
//!
//! * **compute** — 8 ops/cycle per cluster (8 single-issue FPUs);
//! * **L1 DMA** — one 512-bit beat per cycle per cluster (64 B/cycle)
//!   feeding the double-buffered tiles;
//! * **NoC** — on a multi-cluster fabric the shared links sustain
//!   `budget x 64` B/cycle *total*, which can sit below the aggregate
//!   L1 ceiling.
//!
//! A layer is *memory-* or *NoC-bound* when its intensity puts the
//! bandwidth roof below the compute roof — the diagnostic that tells
//! the next optimization where to aim (TROOP / know-your-rooflines).

use crate::fabric::NocConfig;
use crate::util::stats::ratio;

/// Bytes one DMA beat moves (512-bit engine).
pub const BEAT_BYTES: f64 = 64.0;
/// Peak FPU ops per cycle per cluster (8 cores x 1 op).
pub const CLUSTER_OPS_PER_CYCLE: f64 = 8.0;

/// The three ceilings for a fabric of `clusters` clusters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ceilings {
    pub clusters: usize,
    /// Aggregate compute roof (ops/cycle).
    pub compute_ops_per_cycle: f64,
    /// Aggregate L1 DMA bandwidth (bytes/cycle).
    pub l1_bytes_per_cycle: f64,
    /// Shared NoC bandwidth (bytes/cycle); `f64::INFINITY` on a
    /// single cluster (private link).
    pub noc_bytes_per_cycle: f64,
}

impl Ceilings {
    pub fn new(clusters: usize, noc: &NocConfig) -> Self {
        let clusters = clusters.max(1);
        Self {
            clusters,
            compute_ops_per_cycle: CLUSTER_OPS_PER_CYCLE
                * clusters as f64,
            l1_bytes_per_cycle: BEAT_BYTES * clusters as f64,
            noc_bytes_per_cycle: if clusters > 1 {
                BEAT_BYTES * noc.budget() as f64
            } else {
                f64::INFINITY
            },
        }
    }
}

/// Which roof caps a point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
    Noc,
}

impl Bound {
    pub fn name(&self) -> &'static str {
        match self {
            Bound::Compute => "compute",
            Bound::Memory => "memory",
            Bound::Noc => "noc",
        }
    }
}

/// One layer (or request-mix) placed on the roofline.
#[derive(Clone, Debug, PartialEq)]
pub struct RooflinePoint {
    pub name: String,
    /// Total FPU ops (MACs + fused-epilogue ops).
    pub ops: u64,
    /// Total DMA bytes moved.
    pub bytes: u64,
    /// Operational intensity (ops/byte).
    pub oi: f64,
    /// Attained ops/cycle over the compute window.
    pub attained_ops_per_cycle: f64,
    /// `min(compute, oi x l1_bw, oi x noc_bw)` — the roof above this
    /// point.
    pub roof_ops_per_cycle: f64,
    pub bound: Bound,
}

impl RooflinePoint {
    /// Fraction of the governing roof actually attained.
    pub fn attainment(&self) -> f64 {
        ratio(self.attained_ops_per_cycle, self.roof_ops_per_cycle)
    }
}

/// Place one measured point. `window_cycles` is the compute window the
/// ops were issued over (fabric runs pass the longest shard window and
/// aggregate ops/bytes, so attained is fabric-level).
pub fn point(
    name: impl Into<String>,
    ops: u64,
    bytes: u64,
    window_cycles: u64,
    ceil: &Ceilings,
) -> RooflinePoint {
    let oi = ratio(ops as f64, bytes as f64);
    let mem_roof = oi * ceil.l1_bytes_per_cycle;
    let noc_roof = if ceil.noc_bytes_per_cycle.is_finite() {
        oi * ceil.noc_bytes_per_cycle
    } else {
        f64::INFINITY
    };
    let mut roof = ceil.compute_ops_per_cycle;
    let mut bound = Bound::Compute;
    if mem_roof < roof && bytes > 0 {
        roof = mem_roof;
        bound = Bound::Memory;
    }
    if noc_roof < roof && bytes > 0 {
        roof = noc_roof;
        bound = Bound::Noc;
    }
    RooflinePoint {
        name: name.into(),
        ops,
        bytes,
        oi,
        attained_ops_per_cycle: ratio(
            ops as f64,
            window_cycles as f64,
        ),
        roof_ops_per_cycle: roof,
        bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_bound_at_high_intensity() {
        let c = Ceilings::new(1, &NocConfig::default());
        // 1 op/byte >> 8/64: the compute roof governs.
        let p = point("hot", 64_000, 64_000, 10_000, &c);
        assert_eq!(p.bound, Bound::Compute);
        assert_eq!(p.roof_ops_per_cycle, 8.0);
        assert!((p.attained_ops_per_cycle - 6.4).abs() < 1e-12);
        assert!(p.attainment() > 0.7 && p.attainment() < 0.9);
    }

    #[test]
    fn memory_bound_at_low_intensity() {
        let c = Ceilings::new(1, &NocConfig::default());
        // 1 op per 16 bytes: mem roof = 64/16 = 4 < 8.
        let p = point("thin", 1000, 16_000, 1000, &c);
        assert_eq!(p.bound, Bound::Memory);
        assert!((p.roof_ops_per_cycle - 4.0).abs() < 1e-12);
    }

    #[test]
    fn noc_roof_kicks_in_on_starved_fabrics() {
        // 4 clusters behind a 1-beat NoC: noc bw 64 < l1 bw 256.
        let noc = NocConfig { links: 1, beats_per_link: 1 };
        let c = Ceilings::new(4, &noc);
        assert_eq!(c.compute_ops_per_cycle, 32.0);
        assert_eq!(c.l1_bytes_per_cycle, 256.0);
        assert_eq!(c.noc_bytes_per_cycle, 64.0);
        let p = point("sharded", 1000, 16_000, 1000, &c);
        assert_eq!(p.bound, Bound::Noc);
        assert!((p.roof_ops_per_cycle - 4.0).abs() < 1e-12);
        // Single cluster never reports a NoC bound.
        let c1 = Ceilings::new(1, &noc);
        assert!(c1.noc_bytes_per_cycle.is_infinite());
        assert_ne!(point("s", 1000, 16_000, 1000, &c1).bound, Bound::Noc);
    }

    #[test]
    fn zero_denominators_stay_finite() {
        let c = Ceilings::new(1, &NocConfig::default());
        let p = point("empty", 0, 0, 0, &c);
        assert_eq!(p.oi, 0.0);
        assert_eq!(p.attained_ops_per_cycle, 0.0);
        assert!(p.attainment().is_finite());
    }
}
