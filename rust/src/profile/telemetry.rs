//! TimeScope — a deterministic virtual-time telemetry bus.
//!
//! Every serving-tier report used to be an end-of-run aggregate; this
//! module makes the *time-resolved* signals first-class: counters,
//! gauges and bucketed histograms keyed by `(metric, label-set)` and
//! sampled into fixed virtual-time windows of `window` cycles, plus
//! per-request lifecycle spans (arrive → admit/shed → queue →
//! dispatch → complete, with retry edges across fabric faults).
//!
//! Determinism discipline (DESIGN.md §15):
//!
//! * **window assignment is pure virtual time** — `window_of(t) =
//!   t / window`, no wall clock anywhere;
//! * **shards merge bucket-wise** exactly like
//!   [`CycleHistogram`](crate::util::stats::CycleHistogram):
//!   counters add, gauge cells combine min/max/sum/n (all
//!   commutative and associative, so shard order cannot matter),
//!   window histograms merge per bucket, span streams concatenate
//!   and canonically re-sort at [`Telemetry::seal`];
//! * **the stream folds into the FNV-1a run digest**
//!   ([`Telemetry::fold`]) in canonical `BTreeMap` order, so
//!   bit-identity across host thread counts is machine-checked by
//!   NodeSim's digest harness, not asserted in prose.

use std::collections::BTreeMap;

use super::trace::{ChromeTrace, TraceEvent};
use crate::util::stats::{CycleHistogram, Fnv64};

/// Default window width (cycles) for `--telemetry` when no
/// `--telemetry-window` is given: 1 Mcycle, ~1 ms at 1 GHz.
pub const DEFAULT_WINDOW: u64 = 1_000_000;

/// `(metric, label-set)` series key. Metrics are static program
/// identifiers; labels are a small rendered set like `fabric=1`.
pub type SeriesKey = (&'static str, String);

// ---------------------------------------------------------- spans --

/// Lifecycle span classes. Discriminants are part of the digest
/// stream — append-only, never renumber.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SpanKind {
    /// Waiting in a fabric queue (one span per attempt).
    Queue = 0,
    /// In service on a fabric.
    Service = 1,
    /// A fabric outage (down → restore, or down → end of run).
    Outage = 2,
    /// One dispatch wave of the serve event core.
    Wave = 3,
    /// Whole request lifetime (arrival → completion).
    Request = 4,
    /// Retry edge: an orphaned request re-entering the router
    /// (instant).
    Retry = 5,
    /// Request shed (instant).
    Shed = 6,
    /// Autoscaler park/unpark decision (instant; `detail` is 1 for
    /// park, 0 for unpark).
    Scale = 7,
}

impl SpanKind {
    pub fn label(&self) -> &'static str {
        match self {
            SpanKind::Queue => "queue",
            SpanKind::Service => "service",
            SpanKind::Outage => "outage",
            SpanKind::Wave => "wave",
            SpanKind::Request => "request",
            SpanKind::Retry => "retry",
            SpanKind::Shed => "shed",
            SpanKind::Scale => "scale",
        }
    }

    pub fn code(&self) -> u64 {
        *self as u64
    }
}

/// One lifecycle span. Instants are zero-length (`start == end`).
/// The derived `Ord` (field order: start, end, kind, pid, id,
/// detail) is the canonical stream order [`Telemetry::seal`] sorts
/// into, so shard concatenation order cannot leak into the digest.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SpanRec {
    pub start: u64,
    pub end: u64,
    pub kind: SpanKind,
    /// Track id (fabric index in NodeSim, 0 in ServeSim).
    pub pid: u32,
    /// Request id (or wave index for `Wave` spans).
    pub id: u64,
    /// Kind-specific payload (retry count, ops in wave, shed reason).
    pub detail: u64,
}

// ---------------------------------------------------- window cells --

/// Per-window gauge cell. Merge combines min/max/sum/n — all
/// commutative, so "last write" (which would depend on shard order)
/// is deliberately not representable. Reports read `max` (spikes)
/// and `mean()` (levels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GaugeCell {
    pub min: u64,
    pub max: u64,
    pub sum: u128,
    pub n: u64,
}

impl GaugeCell {
    fn of(v: u64) -> Self {
        Self { min: v, max: v, sum: v as u128, n: 1 }
    }

    fn absorb(&mut self, v: u64) {
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.sum += v as u128;
        self.n += 1;
    }

    fn merge(&mut self, o: &GaugeCell) {
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
        self.sum += o.sum;
        self.n += o.n;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }
}

/// Sparse per-window histogram sharing [`CycleHistogram`]'s bucket
/// geometry (exact below 32, then 32 sub-buckets per octave), stored
/// as a `BTreeMap` so thousands of mostly-empty windows stay cheap.
/// Merge is bucket-wise exact, like the dense histogram it mirrors.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WindowHist {
    counts: BTreeMap<u32, u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl WindowHist {
    pub fn record(&mut self, v: u64) {
        let idx = CycleHistogram::bucket_index(v) as u32;
        *self.counts.entry(idx).or_insert(0) += 1;
        if self.total == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.total += 1;
        self.sum += v as u128;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Upper-bucket-bound quantile, clamped to observed min/max —
    /// same semantics as [`CycleHistogram::quantile`].
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.total as f64).ceil() as u64)
            .clamp(1, self.total);
        let mut acc = 0u64;
        for (&idx, &c) in &self.counts {
            acc += c;
            if acc >= target {
                let (_, hi) =
                    CycleHistogram::bucket_bounds(idx as usize);
                return hi.min(self.max).max(self.min);
            }
        }
        self.max
    }

    pub fn merge(&mut self, o: &WindowHist) {
        if o.total == 0 {
            return;
        }
        for (&idx, &c) in &o.counts {
            *self.counts.entry(idx).or_insert(0) += c;
        }
        if self.total == 0 {
            self.min = o.min;
            self.max = o.max;
        } else {
            self.min = self.min.min(o.min);
            self.max = self.max.max(o.max);
        }
        self.total += o.total;
        self.sum += o.sum;
    }
}

// ------------------------------------------------------- registry --

/// The windowed metric registry plus the span stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Telemetry {
    window: u64,
    /// Virtual end of the observed run ([`Telemetry::seal`]); rows
    /// for dense counters are emitted for every window up to here.
    end: u64,
    counters: BTreeMap<SeriesKey, BTreeMap<u64, u64>>,
    gauges: BTreeMap<SeriesKey, BTreeMap<u64, GaugeCell>>,
    hists: BTreeMap<SeriesKey, BTreeMap<u64, WindowHist>>,
    spans: Vec<SpanRec>,
}

impl Telemetry {
    pub fn new(window: u64) -> Self {
        Self {
            window: window.max(1),
            end: 0,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
            spans: Vec::new(),
        }
    }

    pub fn window(&self) -> u64 {
        self.window
    }

    /// Virtual end of the run (set by [`Telemetry::seal`]).
    pub fn end(&self) -> u64 {
        self.end
    }

    /// Window index of virtual time `t` — pure integer arithmetic on
    /// virtual time; an event *exactly on* a boundary `k*W` belongs
    /// to window `k` (half-open windows `[kW, (k+1)W)`).
    pub fn window_of(&self, t: u64) -> u64 {
        t / self.window
    }

    /// Index of the last window touched by the sealed run. A
    /// zero-length run still reports window 0 (empty).
    pub fn last_window(&self) -> u64 {
        if self.end == 0 {
            0
        } else {
            (self.end - 1) / self.window
        }
    }

    // ------------------------------------------------- recording --

    /// Add `delta` to a counter in the window containing `t`.
    pub fn count(
        &mut self,
        metric: &'static str,
        labels: &str,
        t: u64,
        delta: u64,
    ) {
        if delta == 0 {
            return;
        }
        let w = self.window_of(t);
        *self
            .counters
            .entry((metric, labels.to_string()))
            .or_default()
            .entry(w)
            .or_insert(0) += delta;
    }

    /// Attribute the half-open cycle span `[start, end)` to a
    /// counter, split exactly across every window it overlaps — the
    /// primitive behind the `Σ per-window busy == fabric total busy`
    /// conservation invariant. Zero-length spans are no-ops.
    pub fn count_span(
        &mut self,
        metric: &'static str,
        labels: &str,
        start: u64,
        end: u64,
    ) {
        if end <= start {
            return;
        }
        let series = self
            .counters
            .entry((metric, labels.to_string()))
            .or_default();
        let mut w = start / self.window;
        loop {
            let w_start = w * self.window;
            let w_end = w_start + self.window;
            let lo = start.max(w_start);
            let hi = end.min(w_end);
            if hi > lo {
                *series.entry(w).or_insert(0) += hi - lo;
            }
            if end <= w_end {
                break;
            }
            w += 1;
        }
    }

    /// Sample a gauge in the window containing `t`.
    pub fn gauge(
        &mut self,
        metric: &'static str,
        labels: &str,
        t: u64,
        value: u64,
    ) {
        let w = self.window_of(t);
        self.gauges
            .entry((metric, labels.to_string()))
            .or_default()
            .entry(w)
            .and_modify(|c| c.absorb(value))
            .or_insert_with(|| GaugeCell::of(value));
    }

    /// Record a value into the window histogram containing `t`.
    pub fn observe(
        &mut self,
        metric: &'static str,
        labels: &str,
        t: u64,
        value: u64,
    ) {
        let w = self.window_of(t);
        self.hists
            .entry((metric, labels.to_string()))
            .or_default()
            .entry(w)
            .or_default()
            .record(value);
    }

    /// Record a lifecycle span.
    pub fn span(
        &mut self,
        kind: SpanKind,
        pid: u32,
        id: u64,
        start: u64,
        end: u64,
        detail: u64,
    ) {
        debug_assert!(end >= start, "span ends before it starts");
        self.spans.push(SpanRec { start, end, kind, pid, id, detail });
    }

    /// Record an instant marker (zero-length span).
    pub fn instant(
        &mut self,
        kind: SpanKind,
        pid: u32,
        id: u64,
        t: u64,
        detail: u64,
    ) {
        self.span(kind, pid, id, t, t, detail);
    }

    /// Close the stream at virtual time `end`: fixes the dense
    /// window range and sorts spans into canonical order. Call after
    /// all shards are merged; idempotent.
    pub fn seal(&mut self, end: u64) {
        self.end = self.end.max(end);
        self.spans.sort_unstable();
    }

    /// Merge another shard into this one. Commutative and
    /// associative by construction (counter adds, gauge cell
    /// min/max/sum/n, bucket-wise histogram adds, span
    /// concatenation + canonical re-sort at seal) — the same
    /// discipline as `CycleHistogram` shard merging.
    pub fn merge(&mut self, other: &Telemetry) {
        assert_eq!(
            self.window, other.window,
            "cannot merge telemetry shards with different windows"
        );
        for (k, series) in &other.counters {
            let dst = self.counters.entry(k.clone()).or_default();
            for (&w, &v) in series {
                *dst.entry(w).or_insert(0) += v;
            }
        }
        for (k, series) in &other.gauges {
            let dst = self.gauges.entry(k.clone()).or_default();
            for (&w, cell) in series {
                dst.entry(w)
                    .and_modify(|c| c.merge(cell))
                    .or_insert(*cell);
            }
        }
        for (k, series) in &other.hists {
            let dst = self.hists.entry(k.clone()).or_default();
            for (&w, h) in series {
                dst.entry(w).or_default().merge(h);
            }
        }
        self.spans.extend_from_slice(&other.spans);
        self.end = self.end.max(other.end);
    }

    // --------------------------------------------------- queries --

    pub fn counter_window(
        &self,
        metric: &'static str,
        labels: &str,
        w: u64,
    ) -> u64 {
        self.counters
            .get(&(metric, labels.to_string()))
            .and_then(|s| s.get(&w))
            .copied()
            .unwrap_or(0)
    }

    pub fn counter_total(
        &self,
        metric: &'static str,
        labels: &str,
    ) -> u64 {
        self.counters
            .get(&(metric, labels.to_string()))
            .map(|s| s.values().sum())
            .unwrap_or(0)
    }

    pub fn gauge_window(
        &self,
        metric: &'static str,
        labels: &str,
        w: u64,
    ) -> Option<GaugeCell> {
        self.gauges
            .get(&(metric, labels.to_string()))
            .and_then(|s| s.get(&w))
            .copied()
    }

    pub fn hist_window(
        &self,
        metric: &'static str,
        labels: &str,
        w: u64,
    ) -> Option<&WindowHist> {
        self.hists
            .get(&(metric, labels.to_string()))
            .and_then(|s| s.get(&w))
    }

    pub fn spans(&self) -> &[SpanRec] {
        &self.spans
    }

    pub fn series_count(&self) -> usize {
        self.counters.len() + self.gauges.len() + self.hists.len()
    }

    /// Iterate counter series in canonical order (CSV emission).
    pub fn counter_series(
        &self,
    ) -> impl Iterator<Item = (&SeriesKey, &BTreeMap<u64, u64>)> {
        self.counters.iter()
    }

    pub fn gauge_series(
        &self,
    ) -> impl Iterator<Item = (&SeriesKey, &BTreeMap<u64, GaugeCell>)>
    {
        self.gauges.iter()
    }

    pub fn hist_series(
        &self,
    ) -> impl Iterator<Item = (&SeriesKey, &BTreeMap<u64, WindowHist>)>
    {
        self.hists.iter()
    }

    // ---------------------------------------------------- digest --

    /// Fold the whole sealed stream into an FNV-1a hash in canonical
    /// order. Every field is folded fixed-width (u64/LE) so adjacent
    /// fields can never alias; section separators keep an empty
    /// section from aliasing a neighbouring one.
    pub fn fold(&self, h: &mut Fnv64) {
        const SEP: u64 = 0x7E1E_5C0E_7E1E_5C0E;
        h.write_u64(self.window);
        h.write_u64(self.end);
        h.write_u64(SEP);
        h.write_u64(self.counters.len() as u64);
        for ((metric, labels), series) in &self.counters {
            Self::fold_key(h, metric, labels);
            h.write_u64(series.len() as u64);
            for (&w, &v) in series {
                h.write_u64(w);
                h.write_u64(v);
            }
        }
        h.write_u64(SEP);
        h.write_u64(self.gauges.len() as u64);
        for ((metric, labels), series) in &self.gauges {
            Self::fold_key(h, metric, labels);
            h.write_u64(series.len() as u64);
            for (&w, c) in series {
                h.write_u64(w);
                h.write_u64(c.min);
                h.write_u64(c.max);
                h.write_u64(c.sum as u64);
                h.write_u64((c.sum >> 64) as u64);
                h.write_u64(c.n);
            }
        }
        h.write_u64(SEP);
        h.write_u64(self.hists.len() as u64);
        for ((metric, labels), series) in &self.hists {
            Self::fold_key(h, metric, labels);
            h.write_u64(series.len() as u64);
            for (&w, hist) in series {
                h.write_u64(w);
                h.write_u64(hist.total);
                h.write_u64(hist.sum as u64);
                h.write_u64((hist.sum >> 64) as u64);
                h.write_u64(hist.min);
                h.write_u64(hist.max);
                h.write_u64(hist.counts.len() as u64);
                for (&idx, &c) in &hist.counts {
                    h.write_u64(idx as u64);
                    h.write_u64(c);
                }
            }
        }
        h.write_u64(SEP);
        h.write_u64(self.spans.len() as u64);
        for s in &self.spans {
            h.write_u64(s.start);
            h.write_u64(s.end);
            h.write_u64(s.kind.code());
            h.write_u64(s.pid as u64);
            h.write_u64(s.id);
            h.write_u64(s.detail);
        }
    }

    fn fold_key(h: &mut Fnv64, metric: &str, labels: &str) {
        h.write_u64(metric.len() as u64);
        h.write_bytes(metric.as_bytes());
        h.write_u64(labels.len() as u64);
        h.write_bytes(labels.as_bytes());
    }

    /// Standalone digest of the stream (tests; NodeSim folds via
    /// [`Telemetry::fold`] on top of its row digest).
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        self.fold(&mut h);
        h.finish()
    }

    // ----------------------------------------------- trace export --

    /// Export lifecycle spans + gauge time series as a Chrome
    /// `trace_event` timeline: one process per track id
    /// (`{process_prefix} {pid}`), one thread per span kind, counter
    /// samples per gauge window (window max). Loads in
    /// `chrome://tracing` / Perfetto alongside StallScope traces.
    pub fn to_chrome(&self, process_prefix: &str) -> ChromeTrace {
        let mut t = ChromeTrace::default();
        let mut pids: Vec<u32> =
            self.spans.iter().map(|s| s.pid).collect();
        pids.sort_unstable();
        pids.dedup();
        if pids.is_empty() {
            pids.push(0);
        }
        for &pid in &pids {
            t.processes
                .push((pid, format!("{process_prefix} {pid}")));
            for kind in [
                SpanKind::Queue,
                SpanKind::Service,
                SpanKind::Outage,
                SpanKind::Wave,
                SpanKind::Request,
            ] {
                t.tracks.push((
                    pid,
                    kind.code() as u32,
                    kind.label().to_string(),
                ));
            }
        }
        for s in &self.spans {
            if s.end > s.start {
                t.events.push(TraceEvent::Span {
                    pid: s.pid,
                    tid: s.kind.code() as u32,
                    name: s.kind.label(),
                    ts: s.start,
                    dur: s.end - s.start,
                });
            } else {
                t.events.push(TraceEvent::Instant {
                    pid: s.pid,
                    name: format!("{} id={}", s.kind.label(), s.id),
                    ts: s.start,
                });
            }
        }
        for ((metric, labels), series) in &self.gauges {
            let name = if labels.is_empty() {
                (*metric).to_string()
            } else {
                format!("{metric}{{{labels}}}")
            };
            for (&w, cell) in series {
                t.events.push(TraceEvent::Counter {
                    pid: pids[0],
                    name: name.clone(),
                    ts: w * self.window,
                    value: cell.max,
                });
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;

    #[test]
    fn window_assignment_is_half_open() {
        let tel = Telemetry::new(100);
        // An event exactly on a boundary belongs to the *opening*
        // window: [kW, (k+1)W).
        assert_eq!(tel.window_of(0), 0);
        assert_eq!(tel.window_of(99), 0);
        assert_eq!(tel.window_of(100), 1);
        assert_eq!(tel.window_of(101), 1);
    }

    #[test]
    fn zero_length_run_is_benign() {
        let mut tel = Telemetry::new(100);
        tel.seal(0);
        assert_eq!(tel.end(), 0);
        assert_eq!(tel.last_window(), 0);
        assert_eq!(tel.counter_total("x", ""), 0);
        assert_eq!(tel.series_count(), 0);
    }

    #[test]
    fn trailing_partial_window_is_counted() {
        let mut tel = Telemetry::new(100);
        tel.count("c", "", 250, 1);
        tel.seal(251);
        // end=251 → windows 0, 1 and a trailing partial window 2.
        assert_eq!(tel.last_window(), 2);
        assert_eq!(tel.counter_window("c", "", 2), 1);
        // An end exactly on a boundary does NOT open a new window.
        let mut tel2 = Telemetry::new(100);
        tel2.seal(200);
        assert_eq!(tel2.last_window(), 1);
    }

    #[test]
    fn count_span_splits_exactly_across_windows() {
        let mut tel = Telemetry::new(100);
        // [50, 250): 50 cycles in w0, 100 in w1, 50 in w2.
        tel.count_span("busy", "fabric=0", 50, 250);
        assert_eq!(tel.counter_window("busy", "fabric=0", 0), 50);
        assert_eq!(tel.counter_window("busy", "fabric=0", 1), 100);
        assert_eq!(tel.counter_window("busy", "fabric=0", 2), 50);
        assert_eq!(tel.counter_total("busy", "fabric=0"), 200);
        // A span ending exactly on a boundary puts nothing in the
        // next window; zero-length spans record nothing.
        tel.count_span("busy", "fabric=1", 100, 200);
        assert_eq!(tel.counter_window("busy", "fabric=1", 2), 0);
        assert_eq!(tel.counter_window("busy", "fabric=1", 1), 100);
        tel.count_span("busy", "fabric=2", 70, 70);
        assert_eq!(tel.counter_total("busy", "fabric=2"), 0);
    }

    #[test]
    fn prop_count_span_conserves_total_length() {
        check(
            &Config::default(),
            |rng: &mut Rng| {
                let n = rng.range(0, 12);
                (0..n)
                    .map(|_| {
                        let a = rng.below(5_000);
                        (a, a + rng.below(3_000))
                    })
                    .map(|(a, b)| vec![a, b])
                    .collect::<Vec<Vec<u64>>>()
            },
            |spans: &Vec<Vec<u64>>| {
                let mut tel = Telemetry::new(257);
                let mut want = 0u64;
                for s in spans {
                    if s.len() != 2 || s[1] < s[0] {
                        continue;
                    }
                    tel.count_span("busy", "f", s[0], s[1]);
                    want += s[1] - s[0];
                }
                let got = tel.counter_total("busy", "f");
                if got != want {
                    return Err(format!(
                        "window split lost cycles: {got} != {want}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gauge_cells_track_min_max_mean() {
        let mut tel = Telemetry::new(100);
        tel.gauge("q", "fabric=0", 10, 3);
        tel.gauge("q", "fabric=0", 20, 9);
        tel.gauge("q", "fabric=0", 30, 6);
        let c = tel.gauge_window("q", "fabric=0", 0).unwrap();
        assert_eq!(c.min, 3);
        assert_eq!(c.max, 9);
        assert_eq!(c.n, 3);
        assert!((c.mean() - 6.0).abs() < 1e-12);
        assert!(tel.gauge_window("q", "fabric=0", 1).is_none());
    }

    #[test]
    fn window_hist_matches_cycle_histogram_quantiles() {
        let mut wh = WindowHist::default();
        let mut ch = CycleHistogram::new();
        for v in [1u64, 31, 32, 33, 1000, 50_000, 7] {
            wh.record(v);
            ch.record(v);
        }
        assert_eq!(wh.count(), ch.count());
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(wh.quantile(q), ch.quantile(q), "q={q}");
        }
        assert!((wh.mean() - ch.mean()).abs() < 1e-9);
    }

    #[test]
    fn merge_is_commutative_and_matches_single_stream() {
        // Feed one event stream into 1 shard and into k shards in
        // two different merge orders: all three must be identical —
        // the CycleHistogram shard discipline, re-proved here.
        let mut rng = Rng::new(0x7E1E);
        let events: Vec<(u64, u64)> = (0..200)
            .map(|_| (rng.below(10_000), rng.below(50)))
            .collect();
        let mut seq = Telemetry::new(1000);
        let mut a = Telemetry::new(1000);
        let mut b = Telemetry::new(1000);
        let mut c = Telemetry::new(1000);
        for (i, &(t, v)) in events.iter().enumerate() {
            seq.count("c", "x", t, v);
            seq.gauge("g", "x", t, v);
            seq.observe("h", "x", t, v);
            seq.span(SpanKind::Queue, 0, i as u64, t, t + v, 0);
            let shard = match i % 3 {
                0 => &mut a,
                1 => &mut b,
                _ => &mut c,
            };
            shard.count("c", "x", t, v);
            shard.gauge("g", "x", t, v);
            shard.observe("h", "x", t, v);
            shard.span(SpanKind::Queue, 0, i as u64, t, t + v, 0);
        }
        seq.seal(10_050);
        let mut ab = a.clone();
        ab.merge(&b);
        ab.merge(&c);
        ab.seal(10_050);
        let mut cb = c.clone();
        cb.merge(&a);
        cb.merge(&b);
        cb.seal(10_050);
        assert_eq!(ab, seq, "sharded merge deviates from sequential");
        assert_eq!(cb, seq, "merge depends on shard order");
        assert_eq!(ab.digest(), seq.digest());
    }

    #[test]
    fn digest_is_sensitive_to_every_section() {
        let mut base = Telemetry::new(100);
        base.count("c", "", 5, 1);
        base.gauge("g", "", 5, 2);
        base.observe("h", "", 5, 3);
        base.span(SpanKind::Service, 1, 7, 5, 9, 0);
        base.seal(10);
        let d0 = base.digest();
        let mut m = base.clone();
        m.count("c", "", 5, 1);
        m.seal(10);
        assert_ne!(m.digest(), d0, "counter change must move digest");
        let mut m = base.clone();
        m.gauge("g", "", 5, 3);
        m.seal(10);
        assert_ne!(m.digest(), d0, "gauge change must move digest");
        let mut m = base.clone();
        m.instant(SpanKind::Retry, 1, 7, 6, 1);
        m.seal(10);
        assert_ne!(m.digest(), d0, "span change must move digest");
        let mut m = base.clone();
        m.seal(11);
        assert_ne!(m.digest(), d0, "end change must move digest");
    }

    #[test]
    fn chrome_export_is_structurally_sound() {
        let mut tel = Telemetry::new(100);
        tel.span(SpanKind::Service, 1, 42, 10, 60, 0);
        tel.instant(SpanKind::Shed, 1, 43, 70, 2);
        tel.gauge("queue_depth", "fabric=1", 20, 5);
        tel.seal(100);
        let t = tel.to_chrome("fabric");
        let j = t.to_json();
        assert!(j.contains("\"ph\":\"X\""), "span event missing");
        assert!(j.contains("\"ph\":\"i\""), "instant missing");
        assert!(j.contains("\"ph\":\"C\""), "counter missing");
        assert!(j.contains("queue_depth{fabric=1}"));
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "unbalanced JSON"
        );
    }
}
