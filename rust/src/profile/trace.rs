//! Chrome `trace_event` export for StallScope.
//!
//! Per-cycle class attributions are run-length encoded into complete
//! ("ph":"X") spans — one track per core plus a DMA track per cluster
//! — with barrier instants and sequencer-occupancy counter samples at
//! span transitions. The JSON loads directly in `chrome://tracing` or
//! Perfetto (`ts`/`dur` are cycles, displayed as microseconds).
//!
//! A `TraceBuf` is attached to one `Cluster` (`Cluster::trace`) for
//! one run; `ChromeTrace` stitches many buffers (layers of a network,
//! clusters of a fabric) onto one timeline via each buffer's `t0`
//! offset.

use std::io;
use std::path::Path;

use super::{StallClass, N_CLASSES};

/// Track-state code space: `0..N_CLASSES` are stall classes, then the
/// DMA-track states.
pub const CODE_DMA_BUSY: u8 = N_CLASSES as u8;
pub const CODE_DMA_GATED: u8 = N_CLASSES as u8 + 1;
/// Idle runs are tracked for RLE correctness but emit no span.
pub const CODE_IDLE: u8 = u8::MAX;

fn code_label(code: u8) -> &'static str {
    if (code as usize) < N_CLASSES {
        StallClass::all()[code as usize].label()
    } else if code == CODE_DMA_BUSY {
        "DmaBusy"
    } else if code == CODE_DMA_GATED {
        "DmaGated(NoC)"
    } else {
        "Idle"
    }
}

/// One exportable event (pid already resolved).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    Span { pid: u32, tid: u32, name: &'static str, ts: u64, dur: u64 },
    Instant { pid: u32, name: String, ts: u64 },
    Counter { pid: u32, name: String, ts: u64, value: u64 },
}

/// Per-cluster trace collector: `n_tracks` run-length-encoded state
/// tracks (cores 0..n, DMA last) on a timeline starting at `t0`.
#[derive(Clone, Debug)]
pub struct TraceBuf {
    pid: u32,
    t0: u64,
    /// Open run per track: (code, start cycle — already t0-shifted).
    open: Vec<Option<(u8, u64)>>,
    pub events: Vec<TraceEvent>,
}

impl TraceBuf {
    pub fn new(pid: u32, n_tracks: usize, t0: u64) -> Self {
        Self {
            pid,
            t0,
            open: vec![None; n_tracks],
            events: Vec::new(),
        }
    }

    pub fn pid(&self) -> u32 {
        self.pid
    }

    fn flush(&mut self, track: usize, end_ts: u64) {
        if let Some((code, start)) = self.open[track].take() {
            if code != CODE_IDLE && end_ts > start {
                self.events.push(TraceEvent::Span {
                    pid: self.pid,
                    tid: track as u32,
                    name: code_label(code),
                    ts: start,
                    dur: end_ts - start,
                });
            }
        }
    }

    /// Record `track`'s state for `cycle`. Returns true when this
    /// started a new run (a state transition) — callers hang counter
    /// samples off transitions to bound trace size.
    pub fn record(&mut self, track: usize, cycle: u64, code: u8) -> bool {
        let ts = self.t0 + cycle;
        match self.open[track] {
            Some((open_code, _)) if open_code == code => false,
            _ => {
                self.flush(track, ts);
                self.open[track] = Some((code, ts));
                true
            }
        }
    }

    /// Process-scoped instant marker (barrier releases, layer starts).
    pub fn instant(&mut self, name: impl Into<String>, cycle: u64) {
        self.events.push(TraceEvent::Instant {
            pid: self.pid,
            name: name.into(),
            ts: self.t0 + cycle,
        });
    }

    /// Counter sample (e.g. sequencer ring-buffer occupancy).
    pub fn counter(&mut self, track: usize, cycle: u64, value: u64) {
        self.events.push(TraceEvent::Counter {
            pid: self.pid,
            name: format!("rb_occupancy.core{track}"),
            ts: self.t0 + cycle,
            value,
        });
    }

    /// Close every open run at `end_cycle` (cluster halt).
    pub fn finish(&mut self, end_cycle: u64) {
        let ts = self.t0 + end_cycle;
        for track in 0..self.open.len() {
            self.flush(track, ts);
        }
    }
}

/// A complete exportable trace: stitched buffers plus track labels.
#[derive(Clone, Debug, Default)]
pub struct ChromeTrace {
    pub events: Vec<TraceEvent>,
    /// `(pid, tid, label)` thread-name metadata.
    pub tracks: Vec<(u32, u32, String)>,
    /// `(pid, label)` process-name metadata.
    pub processes: Vec<(u32, String)>,
}

impl ChromeTrace {
    /// Absorb one finished buffer.
    pub fn push(&mut self, buf: TraceBuf) {
        self.events.extend(buf.events);
    }

    /// Register a process (cluster) and its track labels once.
    pub fn label_cluster(&mut self, pid: u32, n_compute: usize) {
        if self.processes.iter().any(|(p, _)| *p == pid) {
            return;
        }
        self.processes.push((pid, format!("cluster {pid}")));
        for c in 0..n_compute {
            self.tracks.push((pid, c as u32, format!("core {c}")));
        }
        self.tracks.push((pid, n_compute as u32, "dm core".into()));
        self.tracks.push((pid, n_compute as u32 + 1, "dma".into()));
    }

    /// Serialize to Chrome trace-event JSON. Names come from fixed
    /// palettes or `format!` of plain identifiers, so no JSON string
    /// escaping is needed beyond what we generate.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(64 + self.events.len() * 96);
        out.push_str("{\"traceEvents\":[");
        let mut first = true;
        let mut sep = |out: &mut String| {
            if !first {
                out.push(',');
            }
            first = false;
            out.push('\n');
        };
        for (pid, name) in &self.processes {
            sep(&mut out);
            out.push_str(&format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\
                 \"tid\":0,\"args\":{{\"name\":\"{name}\"}}}}"
            ));
        }
        for (pid, tid, name) in &self.tracks {
            sep(&mut out);
            out.push_str(&format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\
                 \"tid\":{tid},\"args\":{{\"name\":\"{name}\"}}}}"
            ));
        }
        for e in &self.events {
            sep(&mut out);
            match e {
                TraceEvent::Span { pid, tid, name, ts, dur } => {
                    out.push_str(&format!(
                        "{{\"name\":\"{name}\",\"cat\":\"stall\",\
                         \"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\
                         \"ts\":{ts},\"dur\":{dur}}}"
                    ));
                }
                TraceEvent::Instant { pid, name, ts } => {
                    out.push_str(&format!(
                        "{{\"name\":\"{name}\",\"cat\":\"sync\",\
                         \"ph\":\"i\",\"s\":\"p\",\"pid\":{pid},\
                         \"tid\":0,\"ts\":{ts}}}"
                    ));
                }
                TraceEvent::Counter { pid, name, ts, value } => {
                    out.push_str(&format!(
                        "{{\"name\":\"{name}\",\"ph\":\"C\",\
                         \"pid\":{pid},\"tid\":0,\"ts\":{ts},\
                         \"args\":{{\"value\":{value}}}}}"
                    ));
                }
            }
        }
        out.push_str("\n],\"displayTimeUnit\":\"ns\",\"otherData\":\
                      {\"tool\":\"zerostall StallScope\",\
                      \"time_unit\":\"cycles\"}}");
        out
    }

    pub fn write(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rle_merges_runs_and_reports_transitions() {
        let mut b = TraceBuf::new(0, 2, 0);
        assert!(b.record(0, 0, 0), "first record opens a run");
        assert!(!b.record(0, 1, 0), "same state extends");
        assert!(!b.record(0, 2, 0));
        assert!(b.record(0, 3, 6), "state change flushes");
        b.finish(5);
        assert_eq!(
            b.events,
            vec![
                TraceEvent::Span {
                    pid: 0,
                    tid: 0,
                    name: "Useful",
                    ts: 0,
                    dur: 3
                },
                TraceEvent::Span {
                    pid: 0,
                    tid: 0,
                    name: "Barrier",
                    ts: 3,
                    dur: 2
                },
            ]
        );
    }

    #[test]
    fn idle_runs_emit_no_span() {
        let mut b = TraceBuf::new(1, 1, 0);
        b.record(0, 0, CODE_IDLE);
        b.record(0, 5, CODE_DMA_BUSY);
        b.finish(8);
        assert_eq!(b.events.len(), 1);
        assert!(matches!(
            &b.events[0],
            TraceEvent::Span { name: "DmaBusy", ts: 5, dur: 3, .. }
        ));
    }

    #[test]
    fn t0_offsets_the_timeline() {
        let mut b = TraceBuf::new(0, 1, 1000);
        b.record(0, 0, 0);
        b.finish(4);
        assert!(matches!(
            &b.events[0],
            TraceEvent::Span { ts: 1000, dur: 4, .. }
        ));
    }

    #[test]
    fn json_is_structurally_sound() {
        let mut t = ChromeTrace::default();
        t.label_cluster(0, 2);
        let mut b = TraceBuf::new(0, 4, 0);
        b.record(0, 0, 0);
        b.record(0, 4, 4);
        b.instant("barrier", 4);
        b.counter(0, 4, 17);
        b.finish(9);
        t.push(b);
        let j = t.to_json();
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"ph\":\"i\""));
        assert!(j.contains("\"ph\":\"C\""));
        assert!(j.contains("\"ph\":\"M\""));
        assert!(j.contains("BankConflict"));
        assert!(j.contains("thread_name"));
        assert!(j.ends_with("}"));
        // Balanced braces/brackets (no escapes or strings with braces
        // are ever emitted, so raw counting is sound).
        let opens = j.matches('{').count();
        let closes = j.matches('}').count();
        assert_eq!(opens, closes, "unbalanced JSON braces");
        assert_eq!(
            j.matches('[').count(),
            j.matches(']').count(),
            "unbalanced JSON brackets"
        );
    }
}
