//! PJRT golden-model runtime.
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py` (HLO
//! *text* — see DESIGN.md for why not serialized protos) and executes
//! them on the PJRT CPU client through the `xla` crate.  Python never
//! runs here; the artifacts are the only bridge.
//!
//! The golden model validates the *functional* output of the simulated
//! cluster: `golden_matmul` composes the `matmul_acc_32` tile
//! executable (one double-buffer iteration, `C + A @ B` on 32^3 tiles,
//! zero-padded) over the K/M/N grid for any size in the paper's
//! {8..128}^3 evaluation space.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A compiled AOT artifact ready to execute.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT client: {e:?}"))?;
        let dir = artifacts_dir.as_ref().to_path_buf();
        anyhow::ensure!(
            dir.join("manifest.json").exists(),
            "artifacts not built — run `make artifacts` (looked in {})",
            dir.display()
        );
        Ok(Self { client, dir })
    }

    /// Default artifacts location (repo-relative).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn load(&self, name: &str) -> Result<Artifact> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("path utf8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        Ok(Artifact { exe, name: name.to_string() })
    }
}

impl Artifact {
    /// Execute on f64 matrices; `shapes` give each input's dims.
    pub fn run_f64(
        &self,
        inputs: &[(&[f64], &[usize])],
    ) -> Result<Vec<f64>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
            lits.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?
            [0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
        out.to_vec::<f64>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
    }
}

/// Tile size of the accumulate artifact.
const T: usize = 32;

/// Golden `C = A @ B` for any (m, n, k) multiples of 8 up to 128+:
/// zero-pads to 32-multiples and composes `matmul_acc_32` over the
/// tile grid — the same double-buffer iteration structure the
/// simulated cluster executes.
pub fn golden_matmul(
    rt: &Runtime,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
) -> Result<Vec<f64>> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let acc = rt.load("matmul_acc_32")?;
    let pad = |d: usize| d.div_ceil(T) * T;
    let (mp, np, kp) = (pad(m), pad(n), pad(k));
    let mut ap = vec![0.0; mp * kp];
    for i in 0..m {
        ap[i * kp..i * kp + k].copy_from_slice(&a[i * k..(i + 1) * k]);
    }
    let mut bp = vec![0.0; kp * np];
    for i in 0..k {
        bp[i * np..i * np + n].copy_from_slice(&b[i * n..(i + 1) * n]);
    }
    let mut cp = vec![0.0; mp * np];

    let mut a_tile = vec![0.0; T * T];
    let mut b_tile = vec![0.0; T * T];
    let mut c_tile = vec![0.0; T * T];
    for it in 0..mp / T {
        for jt in 0..np / T {
            c_tile.fill(0.0);
            for kt in 0..kp / T {
                for r in 0..T {
                    let src = (it * T + r) * kp + kt * T;
                    a_tile[r * T..(r + 1) * T]
                        .copy_from_slice(&ap[src..src + T]);
                }
                for r in 0..T {
                    let src = (kt * T + r) * np + jt * T;
                    b_tile[r * T..(r + 1) * T]
                        .copy_from_slice(&bp[src..src + T]);
                }
                c_tile = acc.run_f64(&[
                    (&c_tile, &[T, T]),
                    (&a_tile, &[T, T]),
                    (&b_tile, &[T, T]),
                ])?;
            }
            for r in 0..T {
                let dst = (it * T + r) * np + jt * T;
                cp[dst..dst + T].copy_from_slice(&c_tile[r * T..(r + 1) * T]);
            }
        }
    }
    // strip padding
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        c[i * n..(i + 1) * n].copy_from_slice(&cp[i * np..i * np + n]);
    }
    Ok(c)
}

/// Relative-error comparison between simulator output and golden model
/// (association orders differ: fused fmadd chain vs XLA dot).
pub fn max_rel_error(got: &[f64], want: &[f64]) -> f64 {
    got.iter()
        .zip(want)
        .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
        .fold(0.0, f64::max)
}
