//! Stream Semantic Registers (SSRs) — Snitch's data movers [4].
//!
//! Each compute core has four streamers mapped onto ft0/ft1/ft2/ft3
//! (ft3 — the fused-epilogue bias stream — is our extension over the
//! stock three-streamer Snitch):
//! reads of an enabled stream register pop from the streamer's data
//! FIFO (filled by a 4-deep affine address generator prefetching from
//! TCDM), writes push into the write FIFO (drained to TCDM in the
//! background).  Each streamer owns one 64-bit TCDM port, so a core
//! presents up to 3 requests per cycle to the interconnect — the
//! 3 reads + 1 write budget the paper's §III-B bandwidth math uses
//! (the LSU shares the write port in real Snitch; we give the LSU its
//! own request slot, which matters only outside SSR hot loops).
//!
//! The *element repeat* feature serves each streamed element `r+1`
//! times before advancing — Fig. 1b streams one A element to all
//! `unroll` fmadds this way, cutting the A stream's bandwidth by 8x.

use crate::isa::SsrField;

/// Data FIFO depth per streamer (Snitch default).
pub const SSR_FIFO_DEPTH: usize = 4;
/// Maximum address-generation dimensions.
pub const SSR_DIMS: usize = 4;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SsrMode {
    Idle,
    Read,
    Write,
}

#[derive(Clone, Debug)]
pub struct Streamer {
    pub mode: SsrMode,
    base: u32,
    /// Iteration counts per dim (config writes `n-1`, we store `n`).
    bounds: [u32; SSR_DIMS],
    /// Byte strides per dim.
    strides: [i32; SSR_DIMS],
    dims: u8,
    /// Serve each element `repeat + 1` times.
    repeat: u32,

    // --- address generator state ---
    idx: [u32; SSR_DIMS],
    addr: u32,
    exhausted: bool,

    // --- data FIFO ---
    fifo: [f64; SSR_FIFO_DEPTH],
    head: usize,
    len: usize,
    /// Reads: how many times the current head has been served.
    rep_served: u32,
    /// Writes: FIFO slots promised to in-flight FPU ops.
    reserved: usize,

    // --- statistics ---
    pub total_requests: u64,
    pub conflicts: u64,
    /// Cycle of the most recent arbitration loss (StallScope's
    /// bank-conflict attribution probes this with `denied_at`).
    last_denied: u64,
}

impl Default for Streamer {
    fn default() -> Self {
        Self::new()
    }
}

impl Streamer {
    pub fn new() -> Self {
        Self {
            mode: SsrMode::Idle,
            base: 0,
            bounds: [1; SSR_DIMS],
            strides: [0; SSR_DIMS],
            dims: 0,
            repeat: 0,
            idx: [0; SSR_DIMS],
            addr: 0,
            exhausted: true,
            fifo: [0.0; SSR_FIFO_DEPTH],
            head: 0,
            len: 0,
            rep_served: 0,
            reserved: 0,
            total_requests: 0,
            conflicts: 0,
            last_denied: u64::MAX,
        }
    }

    /// This stream's TCDM request lost arbitration on cycle `now`.
    pub fn note_denied(&mut self, now: u64) {
        self.last_denied = now;
    }

    /// Did this stream lose arbitration on cycle `now`?
    pub fn denied_at(&self, now: u64) -> bool {
        self.last_denied == now
    }

    /// Apply a `scfgw` config write.
    pub fn config(&mut self, field: SsrField, value: u32) {
        match field {
            SsrField::Repeat => self.repeat = value,
            SsrField::Bound(d) => self.bounds[d as usize] = value + 1,
            SsrField::Stride(d) => self.strides[d as usize] = value as i32,
            SsrField::ReadBase(d) => self.arm(SsrMode::Read, d + 1, value),
            SsrField::WriteBase(d) => self.arm(SsrMode::Write, d + 1, value),
        }
    }

    fn arm(&mut self, mode: SsrMode, dims: u8, base: u32) {
        assert!(dims as usize <= SSR_DIMS);
        assert_eq!(base % 8, 0, "SSR base must be 8-byte aligned");
        self.mode = mode;
        self.dims = dims;
        self.base = base;
        self.idx = [0; SSR_DIMS];
        self.addr = base;
        self.exhausted = false;
        self.head = 0;
        self.len = 0;
        self.rep_served = 0;
        self.reserved = 0;
    }

    pub fn disarm(&mut self) {
        self.mode = SsrMode::Idle;
        self.exhausted = true;
        self.len = 0;
        self.reserved = 0;
    }

    /// Advance the odometer to the next address.
    fn advance_gen(&mut self) {
        for d in 0..self.dims as usize {
            self.idx[d] += 1;
            self.addr = self.addr.wrapping_add(self.strides[d] as u32);
            if self.idx[d] < self.bounds[d] {
                return;
            }
            // carry: unwind this dim
            self.addr = self.addr.wrapping_sub(
                (self.strides[d] as u32).wrapping_mul(self.bounds[d]),
            );
            self.idx[d] = 0;
        }
        self.exhausted = true;
    }

    // ------------------------------------------------ read side ----

    /// TCDM read request this cycle, if the generator is live and the
    /// FIFO has room.
    #[inline(always)]
    pub fn read_request(&self) -> Option<u32> {
        if self.mode == SsrMode::Read
            && !self.exhausted
            && self.len < SSR_FIFO_DEPTH
        {
            Some(self.addr)
        } else {
            None
        }
    }

    /// A read was granted: push data, advance the generator.
    pub fn read_granted(&mut self, data: f64) {
        debug_assert!(self.len < SSR_FIFO_DEPTH);
        let tail = (self.head + self.len) % SSR_FIFO_DEPTH;
        self.fifo[tail] = data;
        self.len += 1;
        self.advance_gen();
    }

    /// Is an operand available for the FPU this cycle?
    #[inline(always)]
    pub fn can_pop(&self) -> bool {
        self.mode == SsrMode::Read && self.len > 0
    }

    /// Consume one operand (honouring element repeat).
    #[inline(always)]
    pub fn pop(&mut self) -> f64 {
        debug_assert!(self.can_pop());
        let v = self.fifo[self.head];
        self.rep_served += 1;
        if self.rep_served > self.repeat {
            self.rep_served = 0;
            self.head = (self.head + 1) % SSR_FIFO_DEPTH;
            self.len -= 1;
        }
        v
    }

    // ----------------------------------------------- write side ----

    /// Reserve a write-FIFO slot at FPU issue time (so the writeback
    /// can never block the pipeline).
    pub fn can_reserve(&self) -> bool {
        self.mode == SsrMode::Write
            && self.len + self.reserved < SSR_FIFO_DEPTH
    }

    pub fn reserve(&mut self) {
        debug_assert!(self.can_reserve());
        self.reserved += 1;
    }

    /// FPU writeback arrives: convert a reservation into data.
    pub fn push_write(&mut self, value: f64) {
        debug_assert!(self.reserved > 0);
        self.reserved -= 1;
        let tail = (self.head + self.len) % SSR_FIFO_DEPTH;
        self.fifo[tail] = value;
        self.len += 1;
    }

    /// TCDM write request this cycle (head of the write FIFO).
    pub fn write_request(&self) -> Option<(u32, f64)> {
        if self.mode == SsrMode::Write && self.len > 0 && !self.exhausted {
            Some((self.addr, self.fifo[self.head]))
        } else {
            None
        }
    }

    /// The write was granted: pop and advance.
    pub fn write_granted(&mut self) {
        debug_assert!(self.len > 0);
        self.head = (self.head + 1) % SSR_FIFO_DEPTH;
        self.len -= 1;
        self.advance_gen();
    }

    /// Fully drained (barrier condition)?
    pub fn drained(&self) -> bool {
        match self.mode {
            SsrMode::Idle => true,
            SsrMode::Read => true, // reads may be abandoned at disable
            SsrMode::Write => self.len == 0 && self.reserved == 0,
        }
    }

    /// Total elements this generator walks (for tests).
    pub fn total_elems(&self) -> u64 {
        (0..self.dims as usize)
            .map(|d| self.bounds[d] as u64)
            .product()
    }
}

/// Software oracle: the exact address sequence an armed generator
/// walks. Used by unit and property tests.
pub fn oracle_addresses(
    base: u32,
    bounds: &[u32],
    strides: &[i32],
) -> Vec<u32> {
    let dims = bounds.len();
    assert_eq!(dims, strides.len());
    let mut out = Vec::new();
    let mut idx = vec![0u32; dims];
    loop {
        let mut addr = base as i64;
        for d in 0..dims {
            addr += idx[d] as i64 * strides[d] as i64;
        }
        out.push(addr as u32);
        // odometer
        let mut d = 0;
        loop {
            if d == dims {
                return out;
            }
            idx[d] += 1;
            if idx[d] < bounds[d] {
                break;
            }
            idx[d] = 0;
            d += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed_read(base: u32, bounds: &[u32], strides: &[i32]) -> Streamer {
        let mut s = Streamer::new();
        for (d, (&b, &st)) in bounds.iter().zip(strides).enumerate() {
            s.config(SsrField::Bound(d as u8), b - 1);
            s.config(SsrField::Stride(d as u8), st as u32);
        }
        s.config(SsrField::ReadBase(bounds.len() as u8 - 1), base);
        s
    }

    /// Drain a read streamer completely, returning the request trace.
    fn drain_reads(s: &mut Streamer) -> Vec<u32> {
        let mut addrs = Vec::new();
        let mut guard = 0;
        while let Some(a) = s.read_request() {
            addrs.push(a);
            s.read_granted(0.0);
            // consume to keep the FIFO from filling
            while s.can_pop() {
                s.pop();
            }
            guard += 1;
            assert!(guard < 100_000);
        }
        addrs
    }

    #[test]
    fn addrgen_1d() {
        let mut s = armed_read(0x1000, &[4], &[8]);
        assert_eq!(
            drain_reads(&mut s),
            oracle_addresses(0x1000, &[4], &[8])
        );
    }

    #[test]
    fn addrgen_2d_row_major() {
        // 3 rows of 4 elements, row stride 64 bytes.
        let got = drain_reads(&mut armed_read(0, &[4, 3], &[8, 64]));
        assert_eq!(got, oracle_addresses(0, &[4, 3], &[8, 64]));
        assert_eq!(got.len(), 12);
        assert_eq!(got[4], 64);
    }

    #[test]
    fn addrgen_4d_with_zero_stride() {
        // The B-matrix pattern: u(8), then repeat the j-block (stride 0).
        let bounds = [4u32, 2, 3, 2];
        let strides = [8i32, 32, 0, 256];
        let got = drain_reads(&mut armed_read(0x800, &bounds, &strides));
        assert_eq!(got, oracle_addresses(0x800, &bounds, &strides));
    }

    #[test]
    fn addrgen_negative_stride() {
        let got = drain_reads(&mut armed_read(0x100, &[4], &[-8]));
        assert_eq!(got, vec![0x100, 0xF8, 0xF0, 0xE8]);
    }

    #[test]
    fn repeat_serves_element_n_times() {
        let mut s = Streamer::new();
        s.config(SsrField::Bound(0), 1); // 2 elements
        s.config(SsrField::Stride(0), 8);
        s.config(SsrField::Repeat, 2); // serve 3x each
        s.config(SsrField::ReadBase(0), 0);
        s.read_granted(1.5);
        s.read_granted(2.5);
        let mut got = Vec::new();
        for _ in 0..6 {
            assert!(s.can_pop());
            got.push(s.pop());
        }
        assert_eq!(got, vec![1.5, 1.5, 1.5, 2.5, 2.5, 2.5]);
        assert!(!s.can_pop());
    }

    #[test]
    fn fifo_backpressure() {
        let mut s = armed_read(0, &[100], &[8]);
        for i in 0..SSR_FIFO_DEPTH {
            assert!(s.read_request().is_some());
            s.read_granted(i as f64);
        }
        assert!(s.read_request().is_none(), "FIFO full");
        s.pop();
        assert!(s.read_request().is_some());
    }

    #[test]
    fn write_stream_reserve_push_drain() {
        let mut s = Streamer::new();
        s.config(SsrField::Bound(0), 3); // 4 writes
        s.config(SsrField::Stride(0), 8);
        s.config(SsrField::WriteBase(0), 0x40);
        assert!(s.can_reserve());
        s.reserve();
        s.reserve();
        assert!(!s.drained());
        s.push_write(1.0);
        s.push_write(2.0);
        assert_eq!(s.write_request(), Some((0x40, 1.0)));
        s.write_granted();
        assert_eq!(s.write_request(), Some((0x48, 2.0)));
        s.write_granted();
        assert!(s.drained());
        assert!(s.write_request().is_none());
    }

    #[test]
    fn write_reserve_respects_capacity() {
        let mut s = Streamer::new();
        s.config(SsrField::WriteBase(0), 0);
        for _ in 0..SSR_FIFO_DEPTH {
            assert!(s.can_reserve());
            s.reserve();
        }
        assert!(!s.can_reserve());
    }

    #[test]
    fn exhaustion_total_elems() {
        let s = armed_read(0, &[4, 3, 2], &[8, 32, 96]);
        assert_eq!(s.total_elems(), 24);
        let mut s2 = s.clone();
        assert_eq!(drain_reads(&mut s2).len(), 24);
        assert!(s2.read_request().is_none());
    }
}
