//! Tiny benchmark harness (criterion is not available offline).
//!
//! Used by the `[[bench]]` targets (all `harness = false`): warmup,
//! timed iterations, and a robust summary (median + MAD) printed in a
//! criterion-like format so `cargo bench` output stays familiar.

use std::time::{Duration, Instant};

pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    min_iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(1),
            min_iters: 5,
        }
    }
}

pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mad: Duration,
    pub mean: Duration,
}

impl Sample {
    pub fn print(&self) {
        println!(
            "{:<44} time: [{:>12} median] mad: {:>10} mean: {:>12} ({} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mad),
            fmt_dur(self.mean),
            self.iters
        );
    }

    /// items/second given how many logical items one iteration processes.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
        }
    }

    /// Benchmark `f`, which should perform ONE logical iteration and
    /// return a value (kept opaque to prevent dead-code elimination).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Sample {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup || warm_iters < 1 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_iter = start.elapsed() / warm_iters.max(1) as u32;

        // Choose a batch size targeting ~20 samples in the budget.
        let target_sample = (self.measure / 20).max(Duration::from_micros(50));
        let batch = (target_sample.as_nanos() / per_iter.as_nanos().max(1))
            .clamp(1, 1 << 20) as u64;

        let mut samples: Vec<Duration> = Vec::new();
        let mut total_iters = 0u64;
        let begin = Instant::now();
        while begin.elapsed() < self.measure
            || samples.len() < self.min_iters as usize
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed() / batch as u32);
            total_iters += batch;
        }

        samples.sort();
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let mut devs: Vec<i128> = samples
            .iter()
            .map(|s| (s.as_nanos() as i128 - median.as_nanos() as i128).abs())
            .collect();
        devs.sort();
        let mad = Duration::from_nanos(devs[devs.len() / 2] as u64);

        let s = Sample {
            name: name.to_string(),
            iters: total_iters,
            median,
            mad,
            mean,
        };
        s.print();
        s
    }
}

/// One machine-readable bench row for the `BENCH_*.json` artifacts
/// CI uploads — wall time, simulated cycles/sec, and speedup vs the
/// naive-stepping baseline, so the perf trajectory is tracked across
/// PRs.
#[derive(Clone, Debug)]
pub struct JsonRow {
    pub name: String,
    pub wall_s: f64,
    pub sim_cycles: u64,
    pub sim_cycles_per_sec: f64,
    pub speedup_vs_naive: f64,
    /// Logical items per wall second (requests/s for the serve rows,
    /// layers/s for net rows); 0 when the row has no item notion.
    pub items_per_sec: f64,
}

impl JsonRow {
    /// Build a row from a measured sample. `naive` is the baseline
    /// sample the speedup is computed against (the row *is* the
    /// baseline when `None`).
    pub fn new(
        name: &str,
        sample: &Sample,
        sim_cycles: u64,
        naive: Option<&Sample>,
    ) -> JsonRow {
        let wall = sample.median.as_secs_f64().max(1e-12);
        JsonRow {
            name: name.to_string(),
            wall_s: wall,
            sim_cycles,
            sim_cycles_per_sec: sim_cycles as f64 / wall,
            speedup_vs_naive: naive
                .map(|n| n.median.as_secs_f64() / wall)
                .unwrap_or(1.0),
            items_per_sec: 0.0,
        }
    }

    /// Attach an item-throughput figure (e.g. `requests / wall_s`).
    pub fn with_items_per_sec(mut self, ips: f64) -> JsonRow {
        self.items_per_sec = ips;
        self
    }
}

/// Repository root: the parent of the crate's manifest directory
/// (`rust/` lives one level below it). Benches write the committed
/// `BENCH_*.json` baselines here so the path is stable whether cargo
/// runs from the workspace root or from `rust/`.
pub fn repo_root() -> std::path::PathBuf {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).to_path_buf()
}

/// Write rows as a JSON array (hand-rolled; serde is unavailable
/// offline). Names are bench identifiers — no escaping needed beyond
/// rejecting quotes/backslashes outright.
pub fn write_json(
    path: &std::path::Path,
    rows: &[JsonRow],
) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        assert!(
            !r.name.contains('"') && !r.name.contains('\\'),
            "bench name must not need JSON escaping: {}",
            r.name
        );
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"wall_s\": {:.6}, \
             \"sim_cycles\": {}, \"sim_cycles_per_sec\": {:.1}, \
             \"speedup_vs_naive\": {:.3}, \
             \"items_per_sec\": {:.1}}}{}\n",
            r.name,
            r.wall_s,
            r.sim_cycles,
            r.sim_cycles_per_sec,
            r.speedup_vs_naive,
            r.items_per_sec,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    std::fs::write(path, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_rows_roundtrip_shape() {
        let dir = std::env::temp_dir().join("zerostall-bench-json-test");
        std::fs::create_dir_all(&dir).unwrap();
        let s = Sample {
            name: "x".into(),
            iters: 1,
            median: Duration::from_millis(10),
            mad: Duration::ZERO,
            mean: Duration::from_millis(10),
        };
        let fast = Sample {
            name: "x".into(),
            iters: 1,
            median: Duration::from_millis(1),
            mad: Duration::ZERO,
            mean: Duration::from_millis(1),
        };
        let rows = vec![
            JsonRow::new("naive", &s, 1_000_000, None),
            JsonRow::new("fast", &fast, 1_000_000, Some(&s))
                .with_items_per_sec(24.0 / 0.001),
        ];
        assert!(rows[1].speedup_vs_naive > 9.0);
        assert_eq!(rows[0].items_per_sec, 0.0);
        assert!(rows[1].items_per_sec > 0.0);
        let path = dir.join("BENCH_test.json");
        write_json(&path, &rows).unwrap();
        let txt = std::fs::read_to_string(&path).unwrap();
        assert!(txt.starts_with("[\n"));
        assert!(txt.contains("\"speedup_vs_naive\""));
        assert!(txt.contains("\"items_per_sec\""));
        assert!(txt.trim_end().ends_with(']'));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn repo_root_is_manifest_parent() {
        let root = repo_root();
        assert!(root.join("rust").join("Cargo.toml").exists());
    }

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                // keep the loop opaque in release builds
                acc = acc.wrapping_add(std::hint::black_box(i) * i);
            }
            acc
        });
        assert!(s.iters > 0);
        assert!(s.mean.as_nanos() < 1_000_000, "suspiciously slow");
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
