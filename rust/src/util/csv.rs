//! Minimal CSV writer for experiment outputs (`results/*.csv`).

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows (excluding the header).
    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    /// RFC-4180 quoting: cells containing separators, quotes, or
    /// *any* line break (LF or CR — bare CR corrupted columns before)
    /// are wrapped in quotes with embedded quotes doubled.
    fn escape(cell: &str) -> String {
        if cell.contains([',', '"', '\n', '\r']) {
            format!("\"{}\"", cell.replace('"', "\"\""))
        } else {
            cell.to_string()
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let esc = |cells: &[String]| {
            cells.iter().map(|c| Self::escape(c)).collect::<Vec<_>>()
                .join(",")
        };
        let _ = writeln!(out, "{}", esc(&self.header));
        for r in &self.rows {
            let _ = writeln!(out, "{}", esc(r));
        }
        out
    }

    pub fn write(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_string())
    }
}

/// Format a float with fixed decimals, trimming noise for reports.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let mut c = Csv::new(vec!["a", "b"]);
        c.row(vec!["1", "2"]).row(vec!["x,y", "q\"z"]);
        let s = c.to_string();
        assert_eq!(s, "a,b\n1,2\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    fn rfc4180_quotes_all_breaking_cells() {
        // Regression: stall-taxonomy labels / trace paths carrying
        // commas, quotes, CR, or LF must survive a round trip intact.
        let mut c = Csv::new(vec!["label", "path"]);
        c.row(vec!["a,b", "C:\\x \"y\""])
            .row(vec!["line1\nline2", "cr\rcell"]);
        let s = c.to_string();
        assert_eq!(
            s,
            "label,path\n\
             \"a,b\",\"C:\\x \"\"y\"\"\"\n\
             \"line1\nline2\",\"cr\rcell\"\n"
        );
        // Every risky cell is quoted; quotes are doubled.
        assert!(s.contains("\"cr\rcell\""), "bare CR must be quoted");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        Csv::new(vec!["a"]).row(vec!["1", "2"]);
    }

    #[test]
    fn float_format() {
        assert_eq!(f(3.14159, 2), "3.14");
    }
}
