//! Leveled `key=value` status logger for CLI diagnostics.
//!
//! The CLI used to sprinkle ad-hoc `eprintln!` prose; every status
//! line now goes through [`info`]/[`debug`] and renders as one
//! grep-able structured line on stderr:
//!
//! ```text
//! level=info event=node_serve fabrics=4 router=p2c requests=100000
//! ```
//!
//! `--quiet` maps to [`set_level`]`(Level::Quiet)`, which silences
//! status lines without touching report/CSV artifacts (stdout and
//! files are never routed through here). Values containing spaces,
//! quotes or `=` are double-quoted with embedded quotes doubled, so
//! the lines stay machine-splittable.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Suppress all status lines (`--quiet`).
    Quiet = 0,
    /// Normal CLI status lines (default).
    Info = 1,
    /// Extra diagnostics.
    Debug = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        1 => Level::Info,
        _ => Level::Debug,
    }
}

/// Quote a value only when needed to keep the line splittable on
/// spaces: anything containing whitespace, `"` or `=` is wrapped in
/// double quotes with embedded quotes doubled.
fn quote(v: &str) -> String {
    if v.is_empty()
        || v.contains(char::is_whitespace)
        || v.contains('"')
        || v.contains('=')
    {
        format!("\"{}\"", v.replace('"', "\"\""))
    } else {
        v.to_string()
    }
}

/// Render one structured line (pure; unit-tested directly).
pub fn format_line(
    level: Level,
    event: &str,
    kv: &[(&str, String)],
) -> String {
    let lvl = match level {
        Level::Quiet => "quiet",
        Level::Info => "info",
        Level::Debug => "debug",
    };
    let mut out = format!("level={lvl} event={}", quote(event));
    for (k, v) in kv {
        out.push(' ');
        out.push_str(k);
        out.push('=');
        out.push_str(&quote(v));
    }
    out
}

fn emit(at: Level, event: &str, kv: &[(&str, String)]) {
    if level() >= at {
        eprintln!("{}", format_line(at, event, kv));
    }
}

/// Normal status line (suppressed by `--quiet`).
pub fn info(event: &str, kv: &[(&str, String)]) {
    emit(Level::Info, event, kv);
}

/// Extra diagnostics (shown only at `Level::Debug`).
pub fn debug(event: &str, kv: &[(&str, String)]) {
    emit(Level::Debug, event, kv);
}

/// Convenience: stringify a displayable value for the kv slice.
pub fn v(x: impl std::fmt::Display) -> String {
    x.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_values_stay_bare() {
        let line = format_line(
            Level::Info,
            "serve",
            &[("requests", v(100)), ("router", v("p2c"))],
        );
        assert_eq!(
            line,
            "level=info event=serve requests=100 router=p2c"
        );
    }

    #[test]
    fn risky_values_are_quoted() {
        let line = format_line(
            Level::Info,
            "node_serve",
            &[
                ("fault", "t=300,fabric=1".to_string()),
                ("msg", "two words".to_string()),
                ("q", "say \"hi\"".to_string()),
                ("empty", String::new()),
            ],
        );
        assert_eq!(
            line,
            "level=info event=node_serve \
             fault=\"t=300,fabric=1\" msg=\"two words\" \
             q=\"say \"\"hi\"\"\" empty=\"\""
        );
    }

    #[test]
    fn level_order_gates_emission() {
        assert!(Level::Quiet < Level::Info);
        assert!(Level::Info < Level::Debug);
    }
}
