//! Small self-contained utilities (no external crates are available in
//! this environment beyond `xla`/`anyhow`, so the RNG, statistics,
//! property-testing and CSV helpers live here).

pub mod bench;
pub mod csv;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
