//! Mini property-testing framework.
//!
//! `proptest` is not available offline, so this module provides the
//! subset the test-suite needs: seeded case generation, a configurable
//! case count, and greedy input shrinking for failures (halving numeric
//! fields via the `Shrink` trait).  Failures report the master seed and
//! case index so they replay exactly.

use super::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Env overrides let CI widen coverage without code changes.
        let cases = std::env::var("PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self { cases, seed }
    }
}

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized {
    /// Candidate shrinks, in decreasing preference. Empty = atomic.
    fn shrinks(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![self / 2, self - 1]
        }
    }
}

impl Shrink for u64 {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![self / 2, self - 1]
        }
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Drop halves, drop one element, shrink one element.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        for i in 0..self.len().min(4) {
            for s in self[i].shrinks() {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

/// Run `prop` against `cases` inputs drawn by `gen`.  On failure, tries
/// up to 200 shrink steps and panics with the minimal failing input's
/// debug representation.
pub fn check<T, G, P>(cfg: &Config, mut gen: G, mut prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut master = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = master.fork(case as u64);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Shrink.
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut budget = 200usize;
            'outer: while budget > 0 {
                for cand in best.shrinks() {
                    budget = budget.saturating_sub(1);
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={}, case={case}):\n  input: {:?}\n  \
                 error: {}",
                cfg.seed, best, best_msg
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            &Config { cases: 50, seed: 1 },
            |r| r.range(0, 100),
            |&x| {
                if x <= 100 {
                    Ok(())
                } else {
                    Err(format!("{x} > 100"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(
            &Config { cases: 50, seed: 2 },
            |r| r.range(0, 100),
            |&x| {
                if x < 50 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            },
        );
    }

    #[test]
    fn shrinking_reaches_boundary() {
        // Capture the panic message and confirm the shrunk witness is the
        // boundary value 50, not an arbitrary large one.
        let res = std::panic::catch_unwind(|| {
            check(
                &Config { cases: 50, seed: 3 },
                |r| r.range(0, 10_000),
                |&x| {
                    if x < 50 {
                        Ok(())
                    } else {
                        Err("boundary".into())
                    }
                },
            );
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("input: 50"), "unshrunk witness: {msg}");
    }

    #[test]
    fn vec_shrink_produces_smaller() {
        let v = vec![4usize, 5, 6];
        assert!(v.shrinks().iter().all(|s| s.len() < v.len()
            || s.iter().sum::<usize>() < v.iter().sum::<usize>()));
    }
}
