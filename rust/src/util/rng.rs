//! Deterministic PRNGs: SplitMix64 (seeding) and Xoshiro256** (streams).
//!
//! Experiments must be exactly reproducible across runs and thread
//! counts, so every consumer derives its own stream from a master seed.

/// SplitMix64 — used to expand a single `u64` seed into stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (e.g. per experiment sample).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire's method, bias-free for our sizes).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // 128-bit multiply rejection sampling
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller (good enough for test data).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick one element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn fork_independent() {
        let mut r = Rng::new(5);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn normal_mean_approx_zero() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.normal()).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
    }
}
