//! Descriptive statistics for experiment reporting (Fig. 5 box plots).

/// Five-number summary plus mean — exactly what a box plot needs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    pub n: usize,
}

impl BoxStats {
    pub fn iqr(&self) -> f64 {
        self.q3 - self.q1
    }

    /// Values outside `[q1 - 1.5 IQR, q3 + 1.5 IQR]` (Tukey fences),
    /// matching how the paper's Fig. 5 marks outliers.
    pub fn outliers(&self, xs: &[f64]) -> Vec<f64> {
        let lo = self.q1 - 1.5 * self.iqr();
        let hi = self.q3 + 1.5 * self.iqr();
        xs.iter().copied().filter(|&x| x < lo || x > hi).collect()
    }

    /// Smallest / largest non-outlier values (box-plot whisker ends).
    pub fn whiskers(&self, xs: &[f64]) -> (f64, f64) {
        let lo = self.q1 - 1.5 * self.iqr();
        let hi = self.q3 + 1.5 * self.iqr();
        let mut wlo = f64::INFINITY;
        let mut whi = f64::NEG_INFINITY;
        for &x in xs {
            if x >= lo && x <= hi {
                wlo = wlo.min(x);
                whi = whi.max(x);
            }
        }
        (wlo, whi)
    }
}

/// Linear-interpolated quantile (type-7, numpy default) of a sorted slice.
fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

pub fn box_stats(xs: &[f64]) -> BoxStats {
    assert!(!xs.is_empty(), "box_stats of empty slice");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("NaN in stats input"));
    BoxStats {
        min: s[0],
        q1: quantile_sorted(&s, 0.25),
        median: quantile_sorted(&s, 0.5),
        q3: quantile_sorted(&s, 0.75),
        max: *s.last().unwrap(),
        mean: s.iter().sum::<f64>() / s.len() as f64,
        n: s.len(),
    }
}

pub fn median(xs: &[f64]) -> f64 {
    box_stats(xs).median
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / xs.len().max(1) as f64)
        .sqrt()
}

/// Geometric mean (used for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn quartiles_numpy_type7() {
        // numpy.percentile([1..5], [25, 50, 75]) == [2.0, 3.0, 4.0]
        let s = box_stats(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.q3, 4.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn single_element() {
        let s = box_stats(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.q1, 7.0);
        assert_eq!(s.max, 7.0);
    }

    #[test]
    fn outliers_tukey() {
        let mut xs: Vec<f64> = (1..=20).map(|x| x as f64).collect();
        xs.push(100.0);
        let s = box_stats(&xs);
        let out = s.outliers(&xs);
        assert_eq!(out, vec![100.0]);
        let (wlo, whi) = s.whiskers(&xs);
        assert_eq!(wlo, 1.0);
        assert_eq!(whi, 20.0);
    }

    #[test]
    fn geomean_of_twos() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }
}
