//! Descriptive statistics for experiment reporting (Fig. 5 box
//! plots) and streaming percentile accounting for the serving
//! simulator ([`CycleHistogram`]).

/// Five-number summary plus mean — exactly what a box plot needs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    pub n: usize,
}

impl BoxStats {
    pub fn iqr(&self) -> f64 {
        self.q3 - self.q1
    }

    /// Values outside `[q1 - 1.5 IQR, q3 + 1.5 IQR]` (Tukey fences),
    /// matching how the paper's Fig. 5 marks outliers.
    pub fn outliers(&self, xs: &[f64]) -> Vec<f64> {
        let lo = self.q1 - 1.5 * self.iqr();
        let hi = self.q3 + 1.5 * self.iqr();
        xs.iter().copied().filter(|&x| x < lo || x > hi).collect()
    }

    /// Smallest / largest non-outlier values (box-plot whisker ends).
    pub fn whiskers(&self, xs: &[f64]) -> (f64, f64) {
        let lo = self.q1 - 1.5 * self.iqr();
        let hi = self.q3 + 1.5 * self.iqr();
        let mut wlo = f64::INFINITY;
        let mut whi = f64::NEG_INFINITY;
        for &x in xs {
            if x >= lo && x <= hi {
                wlo = wlo.min(x);
                whi = whi.max(x);
            }
        }
        (wlo, whi)
    }
}

/// Linear-interpolated quantile (type-7, numpy default) of a sorted slice.
fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

pub fn box_stats(xs: &[f64]) -> BoxStats {
    assert!(!xs.is_empty(), "box_stats of empty slice");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("NaN in stats input"));
    BoxStats {
        min: s[0],
        q1: quantile_sorted(&s, 0.25),
        median: quantile_sorted(&s, 0.5),
        q3: quantile_sorted(&s, 0.75),
        max: *s.last().unwrap(),
        mean: s.iter().sum::<f64>() / s.len() as f64,
        n: s.len(),
    }
}

pub fn median(xs: &[f64]) -> f64 {
    box_stats(xs).median
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / xs.len().max(1) as f64)
        .sqrt()
}

/// Geometric mean (used for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// `num / den`, defined as 0.0 when the denominator is zero — the
/// guard every report-facing ratio (utilization, conflict rate, hit
/// rate, throughput) funnels through so zero-cycle windows can never
/// print `NaN`/`inf`.
pub fn ratio(num: f64, den: f64) -> f64 {
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

// ------------------------------------------- streaming percentiles --

/// Sub-buckets per power of two: 32 means values above the linear
/// range land in buckets at most `1/32` (~3.1%) wide relative to
/// their value.
const SUB: usize = 32;
const SUB_SHIFT: u32 = 5;
/// Index space covering all of `u64` (60 octave rows of 32).
const NUM_BUCKETS: usize = (64 - SUB_SHIFT as usize + 1) * SUB;

/// Streaming cycle histogram — the serving engine's percentile
/// accountant (HDR-style).
///
/// Values below 32 are counted exactly; larger values fall into
/// log2-octave rows split into 32 sub-buckets, bounding the relative
/// quantile error at ~3.1%. `record` is O(1) with no allocation,
/// histograms merge bucket-wise, and the whole structure is
/// bit-for-bit deterministic — `ServeReport` equality (the serve
/// determinism property) compares it directly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CycleHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for CycleHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl CycleHistogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; NUM_BUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Bucket index of a value (exact below 32, then
    /// `32 + 32*octave + sub`).
    pub fn bucket_index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as usize;
        let shift = msb - SUB_SHIFT as usize;
        SUB + shift * SUB + ((v >> shift) as usize & (SUB - 1))
    }

    /// Inclusive `[lo, hi]` value range of a bucket.
    pub fn bucket_bounds(idx: usize) -> (u64, u64) {
        if idx < SUB {
            return (idx as u64, idx as u64);
        }
        let shift = ((idx - SUB) / SUB) as u32;
        let sub = ((idx - SUB) % SUB) as u64;
        let lo = (1u64 << (shift + SUB_SHIFT)) + (sub << shift);
        (lo, lo + ((1u64 << shift) - 1))
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_index(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Value at or above fraction `q` of recorded samples (upper
    /// bucket bound, clamped to the observed min/max). `q` outside
    /// `[0, 1]` is clamped; an empty histogram reports 0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.total as f64).ceil() as u64)
            .clamp(1, self.total);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            acc += c;
            if acc >= target {
                let (_, hi) = Self::bucket_bounds(i);
                return hi.min(self.max).max(self.min);
            }
        }
        self.max
    }

    /// Merge another histogram into this one (bucket-wise; min/max
    /// and mean stay exact).
    pub fn merge(&mut self, other: &CycleHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

// ------------------------------------------------------ run digest --

/// Streaming FNV-1a 64-bit fold — the run-digest primitive NodeSim's
/// checksum harness is built on.
///
/// FNV-1a is byte-order-defined, allocation-free, and has published
/// test vectors, which makes the digest stable across platforms,
/// thread counts, and refactors: fold a canonical tuple stream in a
/// canonical order (the caller sorts) and any two runs of the same
/// scenario either agree on all 64 bits or differ loudly. Not a
/// cryptographic hash — it detects divergence, not adversaries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;

    pub fn new() -> Self {
        Fnv64(Self::OFFSET)
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    /// Fold a `u64` as its 8 little-endian bytes (fixed-width, so
    /// adjacent fields can never alias each other's byte streams).
    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn quartiles_numpy_type7() {
        // numpy.percentile([1..5], [25, 50, 75]) == [2.0, 3.0, 4.0]
        let s = box_stats(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.q3, 4.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn single_element() {
        let s = box_stats(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.q1, 7.0);
        assert_eq!(s.max, 7.0);
    }

    #[test]
    fn outliers_tukey() {
        let mut xs: Vec<f64> = (1..=20).map(|x| x as f64).collect();
        xs.push(100.0);
        let s = box_stats(&xs);
        let out = s.outliers(&xs);
        assert_eq!(out, vec![100.0]);
        let (wlo, whi) = s.whiskers(&xs);
        assert_eq!(wlo, 1.0);
        assert_eq!(whi, 20.0);
    }

    #[test]
    fn geomean_of_twos() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn fnv64_known_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(Fnv64::new().finish(), 0xcbf29ce484222325);
        let mut h = Fnv64::new();
        h.write_bytes(b"a");
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
        let mut h = Fnv64::new();
        h.write_bytes(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn fnv64_u64_is_le_bytes() {
        let mut a = Fnv64::new();
        a.write_u64(0x0102030405060708);
        let mut b = Fnv64::new();
        b.write_bytes(&[8, 7, 6, 5, 4, 3, 2, 1]);
        assert_eq!(a, b);
        // Field order matters: (x, y) != (y, x).
        let mut xy = Fnv64::new();
        xy.write_u64(1);
        xy.write_u64(2);
        let mut yx = Fnv64::new();
        yx.write_u64(2);
        yx.write_u64(1);
        assert_ne!(xy.finish(), yx.finish());
    }

    #[test]
    fn ratio_guards_zero_denominator() {
        assert_eq!(ratio(3.0, 4.0), 0.75);
        assert_eq!(ratio(3.0, 0.0), 0.0);
        assert_eq!(ratio(0.0, 0.0), 0.0);
        assert!(ratio(1.0, 0.0).is_finite());
    }

    #[test]
    fn histogram_buckets_tile_u64_contiguously() {
        // Every sampled value maps into a bucket whose bounds contain
        // it, and bucket boundaries are seamless at the octave edges.
        for v in (0u64..200)
            .chain([1023, 1024, 1025, u32::MAX as u64, u64::MAX / 2])
        {
            let i = CycleHistogram::bucket_index(v);
            let (lo, hi) = CycleHistogram::bucket_bounds(i);
            assert!(lo <= v && v <= hi, "v={v} i={i} [{lo},{hi}]");
        }
        for i in 0..500usize {
            let (_, hi) = CycleHistogram::bucket_bounds(i);
            let (lo2, _) = CycleHistogram::bucket_bounds(i + 1);
            assert_eq!(hi + 1, lo2, "gap between buckets {i}/{}", i + 1);
        }
        assert!(CycleHistogram::bucket_index(u64::MAX) < NUM_BUCKETS);
    }

    #[test]
    fn histogram_exact_below_linear_range() {
        let mut h = CycleHistogram::new();
        for v in [1u64, 2, 3, 4, 5] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 5);
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(0.5), 3);
        assert_eq!(h.quantile(1.0), 5);
        assert!((h.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_relative_error_bounded() {
        // ~3.1% bucket width: quantiles of a large-value stream stay
        // within the bound of the exact order statistic.
        let mut h = CycleHistogram::new();
        let xs: Vec<u64> = (0..1000).map(|i| 10_000 + 37 * i).collect();
        for &x in &xs {
            h.record(x);
        }
        for q in [0.5, 0.95, 0.99] {
            let exact =
                xs[((q * xs.len() as f64).ceil() as usize - 1)
                    .min(xs.len() - 1)];
            let got = h.quantile(q);
            let err =
                (got as f64 - exact as f64).abs() / exact as f64;
            assert!(
                err <= 1.0 / 32.0 + 1e-9,
                "q={q}: got {got}, exact {exact}, err {err:.4}"
            );
            assert!(got >= exact, "upper-bound semantics");
        }
    }

    #[test]
    fn histogram_merge_matches_single_stream() {
        let mut a = CycleHistogram::new();
        let mut b = CycleHistogram::new();
        let mut all = CycleHistogram::new();
        for i in 0..500u64 {
            let v = 100 + i * 13;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a, all, "merge is exact");
    }

    #[test]
    fn histogram_empty_is_benign() {
        let h = CycleHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn prop_sharded_merge_is_bit_identical_to_sequential() {
        // MegaServe accumulates latency into per-model shards and
        // merges at the end; the report is only trustworthy if a
        // k-way sharded feed merges bit-identically to one stream —
        // including values straddling the exact-<32 / ~3.1%-above
        // boundary (31/32/33) and octave edges.
        use crate::util::prop::{check, Config};
        let base = Config::default();
        check(
            &Config { cases: base.cases, seed: base.seed ^ 0x44157 },
            |rng| {
                let n = rng.range(0, 64);
                (0..n)
                    .map(|_| match rng.range(0, 5) {
                        0 => rng.below(4), // tiny exact values
                        1 => 31,           // last exact bucket
                        2 => 32,           // first log bucket
                        3 => 33,
                        _ => rng.below(1u64 << rng.range(1, 40)),
                    })
                    .collect::<Vec<u64>>()
            },
            |vals: &Vec<u64>| {
                for shards in [1usize, 2, 3, 7] {
                    let mut seq = CycleHistogram::new();
                    let mut parts: Vec<CycleHistogram> = (0..shards)
                        .map(|_| CycleHistogram::new())
                        .collect();
                    for (i, &v) in vals.iter().enumerate() {
                        seq.record(v);
                        parts[i % shards].record(v);
                    }
                    let mut merged = CycleHistogram::new();
                    for p in &parts {
                        merged.merge(p);
                    }
                    if merged != seq {
                        return Err(format!(
                            "{shards}-way shard merge deviates from \
                             sequential feed ({} values)",
                            vals.len()
                        ));
                    }
                    // The derived quantiles agree by construction,
                    // but pin the headline ones anyway.
                    for q in [0.5, 0.95, 0.99] {
                        if merged.quantile(q) != seq.quantile(q) {
                            return Err(format!(
                                "q={q} differs after merge"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_quantiles_are_monotone_in_q() {
        // quantile(q) must be nondecreasing in q for any recorded
        // multiset — shrinking drives the failing value set down to a
        // minimal counterexample if the scan ever regresses.
        use crate::util::prop::{check, Config};
        let base = Config::default();
        check(
            &Config {
                cases: (base.cases / 2).max(16),
                seed: base.seed ^ 0x40070,
            },
            |rng| {
                let n = rng.range(1, 48);
                (0..n)
                    .map(|_| rng.below(1u64 << rng.range(1, 50)))
                    .collect::<Vec<u64>>()
            },
            |vals: &Vec<u64>| {
                if vals.is_empty() {
                    return Ok(());
                }
                let mut h = CycleHistogram::new();
                for &v in vals {
                    h.record(v);
                }
                let qs: Vec<f64> =
                    (0..=20).map(|i| i as f64 / 20.0).collect();
                let mut prev = 0u64;
                for &q in &qs {
                    let cur = h.quantile(q);
                    if cur < prev {
                        return Err(format!(
                            "quantile({q}) = {cur} < previous {prev}"
                        ));
                    }
                    if cur < h.min() || cur > h.max() {
                        return Err(format!(
                            "quantile({q}) = {cur} outside \
                             [{}, {}]",
                            h.min(),
                            h.max()
                        ));
                    }
                    prev = cur;
                }
                Ok(())
            },
        );
    }
}
